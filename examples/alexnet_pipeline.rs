//! ALI end-to-end: INT8 Alexnet-style inference through the whole stack —
//! each conv layer scheduled by the §5 explorer, simulated on the MPRA
//! model, and the artifact-sized layer executed functionally through
//! PJRT with numerics checked against a direct convolution.

use gta::coordinator::{Coordinator, ExecKind, Request};
use gta::precision::Precision;
use gta::runtime::{default_artifact_dir, HostTensor};
use gta::sim::{vpu::VpuSim, Platform};
use gta::util::rng::Rng;
use gta::{GtaConfig, TensorOp};

fn main() -> anyhow::Result<()> {
    let dir = default_artifact_dir();
    let have_artifacts = dir.join("manifest.json").exists();
    let coord = if have_artifacts {
        Coordinator::with_engine(GtaConfig::lanes16(), dir)?
    } else {
        println!("(artifacts not built; running simulation-only)");
        Coordinator::new(GtaConfig::lanes16())
    };

    // ---- the Table 2 ALI workload, layer by layer ----
    let w = gta::workloads::ali();
    println!("ALI: {} ({} ops, {} MACs)", w.description, w.ops.len(), w.total_macs());
    let vpu = VpuSim::default();
    let mut gta_total = 0u64;
    let mut vpu_total = 0u64;
    for (i, op) in w.ops.iter().enumerate() {
        let resp = coord.handle(Request { id: i as u64, op: *op, exec: ExecKind::Simulate });
        let v = vpu.run(op);
        gta_total += resp.sim.cycles;
        vpu_total += v.cycles;
        if let (TensorOp::PGemm(g), Some(sched)) = (op, resp.schedule) {
            println!(
                "  layer {:>2}: GEMM {:>4}x{:<5}x{:<5} -> {:<4} {:>2}x{:<2} kseg {:<2} | {:>9} cyc (Ara {:>10})",
                i,
                g.m,
                g.n,
                g.k,
                sched.config.dataflow.name(),
                sched.config.arrangement.lane_rows,
                sched.config.arrangement.lane_cols,
                sched.config.k_segments,
                resp.sim.cycles,
                v.cycles
            );
        }
    }
    println!(
        "total: GTA {} cycles vs Ara {} ({:.1}x speedup at equal clock)",
        gta_total,
        vpu_total,
        vpu_total as f64 / gta_total as f64
    );

    // ---- functional layer through PJRT ----
    if have_artifacts {
        let mut rng = Rng::new(77);
        let (c, hw, k, r) = (64usize, 15usize, 64usize, 3usize);
        let x: Vec<i32> = (0..c * hw * hw).map(|_| rng.range_i64(-128, 127) as i32).collect();
        let wgt: Vec<i32> = (0..k * c * r * r).map(|_| rng.range_i64(-128, 127) as i32).collect();
        let resp = coord.handle(Request {
            id: 999,
            op: TensorOp::gemm(64, 169, 576, Precision::Int8),
            exec: ExecKind::Functional {
                artifact: "alexnet_conv_i8".into(),
                inputs: vec![HostTensor::I32(x.clone()), HostTensor::I32(wgt.clone())],
            },
        });
        let got = resp.outputs.unwrap()[0].as_i32().unwrap().to_vec();
        // direct conv oracle
        let o = hw - r + 1;
        let mut checked = 0;
        for kk in (0..k).step_by(17) {
            for y in (0..o).step_by(5) {
                for xx in (0..o).step_by(5) {
                    let mut acc = 0i64;
                    for ch in 0..c {
                        for dr in 0..r {
                            for ds in 0..r {
                                acc += x[ch * hw * hw + (y + dr) * hw + (xx + ds)] as i64
                                    * wgt[kk * c * r * r + ch * r * r + dr * r + ds] as i64;
                            }
                        }
                    }
                    assert_eq!(got[kk * o * o + y * o + xx] as i64, acc);
                    checked += 1;
                }
            }
        }
        println!("functional conv layer via PJRT: {checked} spot-checked outputs exact ✓");
        println!("{}", coord.metrics.snapshot().render());
    }
    Ok(())
}
