//! BNM end-to-end: 512-bit modular-arithmetic-style big-number products
//! (the encryption/scientific-computing workload of Table 2), computed on
//! the MPRA functional model through PJRT, carry-propagated by the Fig. 3
//! accumulator model, and cross-checked against exact integer arithmetic.
//!
//! This is the purest demonstration of §3.1: a 512-bit multiplication IS
//! a rank-1 limb p-GEMM on the systolic array.

use gta::precision::{accumulator, limbs, Precision};
use gta::runtime::{default_artifact_dir, Engine, HostTensor};
use gta::sim::{gta::GtaSim, vpu::VpuSim, Platform};
use gta::util::rng::Rng;
use gta::TensorOp;

fn main() -> anyhow::Result<()> {
    let dir = default_artifact_dir();
    if !dir.join("manifest.json").exists() {
        println!("artifacts not built — run `make artifacts` first");
        return Ok(());
    }
    let engine = Engine::load_filtered(&dir, |n| n == "bignum_mul_64")?;
    let mut rng = Rng::new(0x5EED);

    println!("512-bit big-number products on the MPRA (L=64 limbs):");
    let mut total_ns = 0u128;
    for trial in 0..8 {
        let a: Vec<u8> = (0..64).map(|_| rng.range_u64(0, 255) as u8).collect();
        let b: Vec<u8> = (0..64).map(|_| rng.range_u64(0, 255) as u8).collect();

        // L1/L2/L3 path: Pallas limb outer-product via PJRT
        let t0 = std::time::Instant::now();
        let out = engine.execute(
            "bignum_mul_64",
            &[
                HostTensor::I32(a.iter().map(|&v| v as i32).collect()),
                HostTensor::I32(b.iter().map(|&v| v as i32).collect()),
            ],
        )?;
        total_ns += t0.elapsed().as_nanos();
        let pre: Vec<i64> = out[0].as_i32().unwrap().iter().map(|&v| v as i64).collect();

        // accumulator: carry propagation (Fig. 3's job, not the array's)
        let product = accumulator::carry_propagate(&pre);

        // oracle: schoolbook on the host
        let want = accumulator::carry_propagate(&limbs::bignum_mul_precarry(&a, &b));
        assert_eq!(product, want, "trial {trial} mismatch");
        if trial == 0 {
            let dec = accumulator::limbs_to_decimal(&product);
            println!("  example product ({} digits): {}…", dec.len(), &dec[..32.min(dec.len())]);
        }
    }
    println!("  8/8 products exact; mean PJRT latency {:.1} µs", total_ns as f64 / 8.0 / 1e3);

    // How the simulators see this workload
    let w = gta::workloads::bnm();
    let gta_sim = GtaSim::table1();
    let vpu = VpuSim::default();
    let (g, v) = (gta_sim.run_all(&w.ops), vpu.run_all(&w.ops));
    println!("\nsimulated {} ({} ops):", w.description, w.ops.len());
    println!(
        "  GTA {} cycles vs Ara {} cycles ({:.1}x)",
        g.cycles,
        v.cycles,
        v.cycles as f64 / g.cycles as f64
    );

    // rank-1 p-GEMM shape per §3.2
    if let TensorOp::PGemm(pg) = w.ops[0] {
        assert_eq!((pg.m, pg.n, pg.k), (64, 64, 1));
        assert_eq!(pg.precision, Precision::Int8);
    }
    Ok(())
}
