//! END-TO-END DRIVER (EXPERIMENTS.md §E2E): serve a mixed stream of
//! tensor-operator requests through the full system — L3 coordinator
//! scheduling every p-GEMM via the §5 explorer, simulating cycles and
//! traffic on the MPRA model, and executing functional tiles through the
//! batched serve path (admission queue + coalescing dispatch) with inline
//! numeric verification. With AOT artifacts present the tiles run on
//! PJRT; without them the rust-oracle soft backend drives the identical
//! path, so the example works in every build.
//!
//! ```bash
//! make artifacts && cargo run --release --example e2e_serve [N] [workers]
//! ```

use gta::runtime::default_artifact_dir;
use gta::serve::{run_mixed_stream, run_mixed_stream_soft};

fn main() -> anyhow::Result<()> {
    let args: Vec<String> = std::env::args().collect();
    let n: u64 = args.get(1).and_then(|s| s.parse().ok()).unwrap_or(256);
    let workers: usize = args.get(2).and_then(|s| s.parse().ok()).unwrap_or(4);

    let dir = default_artifact_dir();
    let pjrt = if dir.join("manifest.json").exists() {
        println!("serving {n} mixed requests on {workers} workers (PJRT artifacts)…\n");
        // artifacts exist but the engine may still be a non-pjrt stub
        run_mixed_stream(dir, n, workers).map_err(|e| {
            println!("PJRT path unavailable ({e:#}); using the soft backend instead…\n");
        })
    } else {
        println!(
            "serving {n} mixed requests on {workers} workers \
             (artifacts not built — soft rust-oracle backend)…\n"
        );
        Err(())
    };
    let summary = match pjrt {
        Ok(s) => s,
        Err(()) => run_mixed_stream_soft(n, workers)?,
    };
    print!("{}", summary.render());

    // hard gates: every functional tile must verify, none may error
    assert_eq!(summary.errors, 0, "requests came back with errors");
    assert_eq!(summary.verified_failed, 0, "numeric verification failed");
    assert_eq!(summary.functional, summary.verified_ok);
    println!("\ne2e OK: all {} functional tiles numerically exact", summary.verified_ok);
    Ok(())
}
