//! END-TO-END DRIVER (EXPERIMENTS.md §E2E): serve a mixed stream of
//! tensor-operator requests through the full system — L3 coordinator
//! scheduling every p-GEMM via the §5 explorer, simulating cycles and
//! traffic on the MPRA model, and executing functional tiles through the
//! AOT-compiled Pallas kernels on PJRT with inline numeric verification.
//!
//! ```bash
//! make artifacts && cargo run --release --example e2e_serve [N] [workers]
//! ```

use gta::runtime::default_artifact_dir;
use gta::serve::run_mixed_stream;

fn main() -> anyhow::Result<()> {
    let args: Vec<String> = std::env::args().collect();
    let n: u64 = args.get(1).and_then(|s| s.parse().ok()).unwrap_or(256);
    let workers: usize = args.get(2).and_then(|s| s.parse().ok()).unwrap_or(4);

    let dir = default_artifact_dir();
    if !dir.join("manifest.json").exists() {
        anyhow::bail!("artifacts not built — run `make artifacts` first");
    }
    println!("serving {n} mixed requests on {workers} workers…\n");
    let summary = run_mixed_stream(dir, n, workers)?;
    print!("{}", summary.render());

    // hard gates: every functional tile must verify
    assert_eq!(summary.verified_failed, 0, "numeric verification failed");
    assert_eq!(summary.functional, summary.verified_ok);
    println!("\ne2e OK: all {} functional tiles numerically exact", summary.verified_ok);
    Ok(())
}
