//! Loopback network serving demo: a 2-shard soft rack behind a
//! `NetServer` on an ephemeral TCP port, driven by the seeded open-loop
//! `GtaClient` replay — the whole `gta serve --listen` / `gta client
//! --connect --stream` path in one process, no artifacts or PJRT
//! required.
//!
//! ```bash
//! cargo run --release --example net_serve [N_REQUESTS] [WORKERS]
//! ```

use gta::coordinator::rack::policy_by_name;
use gta::coordinator::{CoalesceConfig, ServeOptions};
use gta::net::NetServer;
use gta::serve::{run_open_loop_client, shard_configs, soft_rack};
use std::sync::Arc;

fn main() -> anyhow::Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let n: u64 = args.first().and_then(|s| s.parse().ok()).unwrap_or(64);
    let workers: usize = args.get(1).and_then(|s| s.parse().ok()).unwrap_or(4);
    let (rate, seed) = (5_000.0, 2024u64);

    let rack = soft_rack(
        shard_configs(2, &[]),
        CoalesceConfig::with_adaptive_window(),
        policy_by_name("rr").expect("rr is a known policy"),
    )?;
    let mut server =
        NetServer::spawn(Arc::clone(&rack), "127.0.0.1:0", ServeOptions::with_workers(workers))?;
    println!(
        "serving a 2-shard soft rack on {} — replaying {n} mixed requests \
         as seeded Poisson arrivals at {rate} req/s over TCP\n",
        server.addr()
    );

    let summary = run_open_loop_client(&server.addr().to_string(), n, rate, seed)?;
    print!("{}", summary.render());

    assert_eq!(summary.requests, n, "one response per request, over the wire");
    assert_eq!(summary.errors, 0);
    assert_eq!(summary.verified_failed, 0, "numerics survive the round trip");
    server.shutdown();
    println!("\nnet serve OK: {n} requests round-tripped and verified over TCP");
    Ok(())
}
