//! Quickstart: the five-minute tour of the GTA library.
//!
//! ```bash
//! make artifacts          # once: AOT-compile the Pallas kernels
//! cargo run --release --example quickstart
//! ```

use gta::ops::classify::{classify, OpClass};
use gta::precision::Precision;
use gta::report;
use gta::sim::{gta::GtaSim, vpu::VpuSim, Platform};
use gta::{scheduler, GtaConfig, PGemm, TensorOp};

fn main() -> anyhow::Result<()> {
    // 1. Describe a tensor operator: one Alexnet conv layer as a p-GEMM.
    let conv3 = PGemm::new(384, 169, 2304, Precision::Int8);
    println!("operator: conv3 as p-GEMM {}x{}x{} INT8", conv3.m, conv3.n, conv3.k);
    println!(
        "  arithmetic intensity {:.1}, class {:?}",
        conv3.arithmetic_intensity(),
        classify(&TensorOp::PGemm(conv3))
    );
    assert_eq!(classify(&TensorOp::PGemm(conv3)), OpClass::PGemm);

    // 2. Explore the §5 scheduling space on a 16-lane GTA and pick the
    //    least-sum-of-squares schedule.
    let cfg = GtaConfig::lanes16();
    let cands = scheduler::explore(&conv3, &cfg);
    let best = scheduler::select(&cands);
    println!(
        "\nschedule: explored {} candidates; selected {} on a {}x{} lane grid, k-seg {}",
        cands.len(),
        best.config.dataflow.name(),
        best.config.arrangement.lane_rows,
        best.config.arrangement.lane_cols,
        best.config.k_segments,
    );
    println!(
        "  -> {} cycles, {} bytes of memory traffic, {:.0}% utilization",
        best.report.cycles,
        best.report.memory_access(),
        best.report.utilization * 100.0
    );

    // 3. Compare against the original VPU on the same operator.
    let gta = GtaSim::table1();
    let vpu = VpuSim::default();
    let op = TensorOp::PGemm(conv3);
    let (g, v) = (gta.run(&op), vpu.run(&op));
    println!(
        "\nGTA vs Ara on this layer: {:.1}x fewer cycles, {:.1}x less memory traffic",
        v.cycles as f64 / g.cycles as f64,
        v.memory_access() as f64 / g.memory_access() as f64
    );

    // 4. Table 3 — the derived SIMD gains.
    println!();
    print!("{}", report::render_table3());

    // 5. Functional numerics through PJRT (skipped if artifacts absent).
    let dir = gta::runtime::default_artifact_dir();
    if dir.join("manifest.json").exists() {
        let engine = gta::runtime::Engine::load_filtered(&dir, |n| n == "mpra_gemm_i8_64")?;
        let a = vec![2i32; 64 * 64];
        let b = vec![3i32; 64 * 64];
        let out = engine.execute(
            "mpra_gemm_i8_64",
            &[gta::runtime::HostTensor::I32(a), gta::runtime::HostTensor::I32(b)],
        )?;
        let c0 = out[0].as_i32().unwrap()[0];
        println!("\nfunctional check via PJRT: (2·3)·64 = {c0} ✓");
        assert_eq!(c0, 2 * 3 * 64);
    } else {
        println!("\n(run `make artifacts` to enable the functional PJRT path)");
    }
    Ok(())
}
