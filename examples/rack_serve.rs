//! RACK DRIVER: shard the mixed e2e request stream across a
//! heterogeneous multi-GTA rack — two 16-lane shards and two 4-lane
//! shards behind a round-robin router — with ONE schedule cache shared
//! rack-wide. Every shard runs its own soft rust-oracle backend behind
//! its own (adaptive-window) coalescing dispatcher, so the whole thing
//! works offline in every build.
//!
//! What to look for in the output: the per-shard utilization/traffic
//! report, and rack-wide schedule-cache hits — a shape scheduled on one
//! 16-lane shard is a cache hit when the router later lands it on the
//! other (equal `GtaConfig`, equal fingerprint), while the 4-lane shards
//! keep their own entries in the same memo.
//!
//! ```bash
//! cargo run --release --example rack_serve [N] [workers]
//! ```

use gta::coordinator::rack::policy_by_name;
use gta::coordinator::CoalesceConfig;
use gta::serve::{mixed_stream, run_stream_rack, soft_rack};
use gta::GtaConfig;

fn main() -> anyhow::Result<()> {
    let args: Vec<String> = std::env::args().collect();
    let n: u64 = args.get(1).and_then(|s| s.parse().ok()).unwrap_or(256);
    let workers: usize = args.get(2).and_then(|s| s.parse().ok()).unwrap_or(4);

    let configs = vec![
        GtaConfig::lanes16(),
        GtaConfig::lanes16(),
        GtaConfig::with_lanes(4),
        GtaConfig::with_lanes(4),
    ];
    let shards = configs.len();
    println!(
        "serving {n} mixed requests on {workers} workers across {shards} shards \
         (16/16/4/4 lanes, round-robin, shared schedule cache)…\n"
    );
    let rack = soft_rack(
        configs,
        CoalesceConfig::with_adaptive_window(),
        policy_by_name("rr").expect("rr is a built-in policy"),
    )?;
    let (requests, expected) = mixed_stream(n);
    let summary = run_stream_rack(&rack, requests, &expected, workers);
    print!("{}", summary.render());

    // hard gates: the single-GTA serving contract must hold rack-wide
    assert_eq!(summary.requests, n, "one response per request, rack-wide");
    assert_eq!(summary.errors, 0, "requests came back with errors");
    assert_eq!(summary.verified_failed, 0, "numeric verification failed");
    assert_eq!(summary.functional, summary.verified_ok);

    let rs = summary.shards.as_ref().expect("rack runs carry per-shard telemetry");
    assert_eq!(rs.shards.len(), shards);
    let routed: u64 = rs.shards.iter().map(|t| t.routed).sum();
    assert_eq!(routed, n, "every request was routed to exactly one shard");
    assert!(
        rs.aggregate.schedule_cache_hits > 0,
        "repeated shapes must hit the rack-shared schedule cache"
    );

    println!(
        "\nrack OK: {n} requests over {shards} shards, {} rack-wide cache hits \
         ({} searches), {} functional tiles numerically exact",
        rs.aggregate.schedule_cache_hits,
        rs.aggregate.schedule_cache_misses,
        summary.verified_ok
    );
    Ok(())
}
