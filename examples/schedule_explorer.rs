//! Schedule-space explorer: regenerates the Fig. 9 scatter — the joint
//! precision × dataflow × array-resize space for one Alexnet conv layer —
//! and renders it as an ASCII scatter plus a CSV dump for plotting.

use gta::report;
use std::io::Write as _;

fn main() -> anyhow::Result<()> {
    let pts = report::fig9();

    // CSV for external plotting
    let csv_path = std::path::Path::new("target/fig9_schedule_space.csv");
    std::fs::create_dir_all("target").ok();
    let mut f = std::fs::File::create(csv_path)?;
    writeln!(f, "precision,dataflow,arrangement,k_segments,cycles_ratio,mem_ratio,selected")?;
    for p in &pts {
        writeln!(
            f,
            "{},{},{},{},{:.6},{:.6},{}",
            p.precision, p.dataflow, p.arrangement, p.k_segments, p.cycles_ratio, p.mem_ratio, p.selected
        )?;
    }
    println!("wrote {} candidates to {}", pts.len(), csv_path.display());

    // ASCII scatter per precision (log-ish bucketing)
    for prec in ["INT8", "FP16", "FP32"] {
        println!("\n=== {prec}: cycles-ratio (x) vs memory-ratio (y), * = selected ===");
        let mine: Vec<_> = pts.iter().filter(|p| p.precision == prec).collect();
        let max_c = mine.iter().map(|p| p.cycles_ratio).fold(1.0f64, f64::max);
        let max_m = mine.iter().map(|p| p.mem_ratio).fold(1.0f64, f64::max);
        const W: usize = 64;
        const H: usize = 16;
        let mut grid = vec![vec![' '; W + 1]; H + 1];
        for p in &mine {
            let x = ((p.cycles_ratio.ln() / max_c.ln().max(1e-9)) * W as f64) as usize;
            let y = ((p.mem_ratio.ln() / max_m.ln().max(1e-9)) * H as f64) as usize;
            let cell = &mut grid[H - y.min(H)][x.min(W)];
            *cell = if p.selected {
                '*'
            } else if *cell == ' ' {
                match p.dataflow.as_str() {
                    "WS" => 'w',
                    "IS" => 'i',
                    "OS" => 'o',
                    _ => 's',
                }
            } else {
                *cell
            };
        }
        for row in &grid {
            println!("  |{}", row.iter().collect::<String>());
        }
        println!("  +{}", "-".repeat(W + 1));
        println!(
            "  1.0 .. {:.1}x cycles; {} candidates (w=WS i=IS o=OS s=SIMD)",
            max_c,
            mine.len()
        );
        // the Fig 9 headline: the distribution is nonlinear in precision
        let sel = mine.iter().find(|p| p.selected).unwrap();
        println!(
            "  selected: {} {} kseg={} at ({:.2}x cycles, {:.2}x mem)",
            sel.dataflow, sel.arrangement, sel.k_segments, sel.cycles_ratio, sel.mem_ratio
        );
    }
    Ok(())
}
