//! STREAMING DRIVER: the long-lived `RackSession` ingest/egress surface
//! — the replacement for batch-in/batch-out serving. One session is
//! opened over a two-shard soft-backend rack; the driver thread submits
//! the mixed e2e stream one request at a time (getting back a `Ticket`
//! per admission) while consuming `Response`s as they complete, out of
//! submission order. `close()` drains everything in flight and returns
//! the final summary with per-shard telemetry.
//!
//! ```bash
//! cargo run --release --example stream_serve [N] [workers]
//! ```

use gta::coordinator::{CoalesceConfig, ServeOptions};
use gta::serve::{mixed_stream, soft_rack};
use gta::GtaConfig;
use std::collections::HashSet;

fn main() -> anyhow::Result<()> {
    let args: Vec<String> = std::env::args().collect();
    let n: u64 = args.get(1).and_then(|s| s.parse().ok()).unwrap_or(256);
    let workers: usize = args.get(2).and_then(|s| s.parse().ok()).unwrap_or(4);

    let rack = soft_rack(
        vec![GtaConfig::lanes16(), GtaConfig::lanes16()],
        CoalesceConfig::with_adaptive_window(),
        gta::coordinator::rack::policy_by_name("least").expect("built-in policy"),
    )?;
    println!("streaming {n} mixed requests through one RackSession ({workers} workers)…\n");

    let session = rack.open_session(ServeOptions::with_workers(workers));
    let (requests, _expected) = mixed_stream(n);

    let mut tickets = HashSet::new();
    let mut completed = 0u64;
    let mut out_of_order = 0u64;
    let mut last_id: Option<u64> = None;
    for req in requests {
        let ticket = session.submit(req).expect("blocking admission cannot reject");
        tickets.insert(ticket.id);
        // interleave: consume whatever has already completed
        while let Some(resp) = session.try_recv() {
            assert!(tickets.remove(&resp.id), "response without a ticket");
            if last_id.is_some_and(|prev| resp.id < prev) {
                out_of_order += 1;
            }
            last_id = Some(resp.id);
            completed += 1;
        }
    }
    let mid_stats = session.stats();
    println!(
        "all {} submitted; {} already consumed mid-stream ({} out of submission order), \
         {} outstanding, queue depth {}",
        mid_stats.submitted, completed, out_of_order, mid_stats.outstanding, mid_stats.queue_depth
    );

    // drain the rest as they complete, then close for the summary
    for resp in session.iter() {
        assert!(tickets.remove(&resp.id), "response without a ticket");
        completed += 1;
    }
    let summary = session.close();
    print!("{}", summary.render());

    assert_eq!(completed, n, "exactly one response per submitted request");
    assert!(tickets.is_empty(), "every ticket was answered");
    assert_eq!(summary.requests, n);
    assert_eq!(summary.errors, 0, "no request may error in the happy path");

    // the session is closed: further submissions must fail loudly, not
    // silently vanish
    let (mut late, _) = mixed_stream(1);
    let err = session.submit(late.remove(0)).expect_err("closed session rejects");
    println!("\nsubmit after close -> {err:?} (tickets are never silently dropped)");
    println!("stream OK: {n} requests, {out_of_order} completions out of submission order");
    Ok(())
}
