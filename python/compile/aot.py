"""AOT compiler: lower every L2 entry point to HLO text + manifest.

Interchange format is HLO *text*, NOT a serialized HloModuleProto: jax≥0.5
emits protos with 64-bit instruction ids which the rust side's
xla_extension 0.5.1 rejects (`proto.id() <= INT_MAX`); the text parser
reassigns ids and round-trips cleanly (see /opt/xla-example/README.md).

Run once via ``make artifacts``; the rust binary is self-contained after.

Usage: cd python && python -m compile.aot --out ../artifacts
"""

from __future__ import annotations

import argparse
import hashlib
import json
import os

import jax

jax.config.update("jax_enable_x64", True)  # INT64 limb path needs i64

import jax.numpy as jnp  # noqa: E402
from jax._src.lib import xla_client as xc  # noqa: E402

from . import model  # noqa: E402

S = jax.ShapeDtypeStruct


def entries():
    """name -> (fn, [input ShapeDtypeStructs], doc).

    Shapes are the tile sizes the rust coordinator dispatches; it pads
    workload tiles up to these artifact shapes (runtime/artifacts.rs).
    """
    i32, i64, f32, bf16 = jnp.int32, jnp.int64, jnp.float32, jnp.bfloat16
    e = {}
    e["mpra_gemm_i8_64"] = (
        model.mpra_gemm_fn(1),
        [S((64, 64), i32), S((64, 64), i32)],
        "INT8 GEMM tile on the 1-limb MPRA path",
    )
    e["mpra_gemm_i16_64"] = (
        model.mpra_gemm_fn(2),
        [S((64, 64), i32), S((64, 64), i32)],
        "INT16 GEMM tile on the 2-limb MPRA path",
    )
    e["mpra_gemm_i32_64"] = (
        model.mpra_gemm_fn(4),
        [S((64, 64), i32), S((64, 64), i32)],
        "INT32 GEMM tile on the 4-limb MPRA path",
    )
    e["mpra_gemm_i64_32"] = (
        model.mpra_gemm_fn(8),
        [S((32, 32), i64), S((32, 32), i64)],
        "INT64 GEMM tile on the 8-limb MPRA path",
    )
    e["bignum_mul_64"] = (
        model.bignum_fn(),
        [S((64,), i32), S((64,), i32)],
        "BNM: 64-limb (512-bit) pre-carry big-number product",
    )
    e["matmul_f32_128"] = (
        model.matmul_f32_fn(),
        [S((128, 128), f32), S((128, 128), f32)],
        "f32 GEMM tile (FP mantissa path building block)",
    )
    e["alexnet_conv_i8"] = (
        model.alexnet_conv_int8_fn(c=64, hw=15, k=64, r=3),
        [S((64, 15, 15), i32), S((64, 64, 3, 3), i32)],
        "ALI: Alexnet-style INT8 conv layer via im2col + 1-limb MPRA GEMM",
    )
    e["ffl_bf16"] = (
        model.ffl_bf16_fn(),
        [S((16, 256), f32), S((256, 1024), f32), S((1024, 256), f32)],
        "FFL: GPT-3 feed-forward slice, BP16-quantized operands, f32 I/O",
    )
    e["pca_cov_f32"] = (
        model.pca_cov_fn(),
        [S((256, 64), f32)],
        "PCA: covariance GEMM XtX/(n-1)",
    )
    e["nerf_mlp_f32"] = (
        model.nerf_mlp_fn(),
        [S((128, 64), f32), S((64, 256), f32), S((256, 64), f32)],
        "Nerf: MLP block, two f32 GEMMs + relu",
    )
    e["rgb_convert_i8"] = (
        model.rgb_convert_int8_fn(),
        [S((3, 3), i32), S((3, 1024), i32)],
        "RGB: SRGB2XYZ 3x3 colour matrix over a 1024-pixel panel, INT8",
    )
    e["fir_i16"] = (
        model.fir_int16_fn(n=256, taps=64),
        [S((319,), i32), S((64,), i32)],
        "FFE: 64-tap FIR over 256 samples, INT16 (2-limb MPRA path)",
    )
    e["md_update_i32"] = (
        model.md_update_int32_fn(),
        [S((64, 64), i32), S((64, 32), i32), S((32, 64), i32)],
        "MD: blocked-LU trailing update A22 -= A21@A12, INT32 (4-limb)",
    )
    return e


def to_hlo_text(lowered) -> str:
    """stablehlo -> XlaComputation -> HLO text (id-safe interchange)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _dtype_tag(dt) -> str:
    return {
        "int32": "s32",
        "int64": "s64",
        "float32": "f32",
        "bfloat16": "bf16",
    }[jnp.dtype(dt).name]


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument("--only", default=None, help="comma-separated entry names")
    args = ap.parse_args()
    os.makedirs(args.out, exist_ok=True)

    manifest = {}
    manifest_path = os.path.join(args.out, "manifest.json")
    names = entries()
    only = set(args.only.split(",")) if args.only else None
    if only and os.path.exists(manifest_path):
        # partial rebuild: keep the existing entries we are not touching
        with open(manifest_path) as f:
            manifest = json.load(f)
    for name, (fn, specs, doc) in names.items():
        if only and name not in only:
            continue
        lowered = jax.jit(fn).lower(*specs)
        text = to_hlo_text(lowered)
        path = os.path.join(args.out, f"{name}.hlo.txt")
        with open(path, "w") as f:
            f.write(text)
        abstract = jax.eval_shape(fn, *specs)
        manifest[name] = {
            "doc": doc,
            "file": f"{name}.hlo.txt",
            "sha256": hashlib.sha256(text.encode()).hexdigest(),
            "inputs": [
                {"shape": list(s.shape), "dtype": _dtype_tag(s.dtype)}
                for s in specs
            ],
            "outputs": [
                {"shape": list(o.shape), "dtype": _dtype_tag(o.dtype)}
                for o in abstract
            ],
        }
        print(f"lowered {name}: {len(text)} chars -> {path}")
    with open(manifest_path, "w") as f:
        json.dump(manifest, f, indent=2, sort_keys=True)
    print(f"wrote manifest with {len(manifest)} entries")


if __name__ == "__main__":
    main()
