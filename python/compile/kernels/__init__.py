# L1: Pallas kernels for the paper's compute hot-spots.
from .bignum import bignum_mul  # noqa: F401
from .mpra_gemm import mpra_gemm  # noqa: F401
from .tiled_matmul import tiled_matmul  # noqa: F401
