"""L1 Pallas kernel: big-number multiplication as a limb outer product.

The BNM workload (Table 2) — arbitrary-precision multiplication for
scientific computing / encryption — is the purest form of the paper's §3.1
similarity: a big-number product is the polynomial product of its limb
vectors, i.e. an outer product (a rank-1 p-GEMM) followed by anti-diagonal
accumulation. The carry chain belongs to the accumulator (Fig. 3) and is
performed by the coordinator (rust/src/precision/accumulator.rs) /
ref.carry_propagate — exactly the paper's split between array and
accumulator.

interpret=True for CPU PJRT (see mpra_gemm.py).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _bignum_kernel(a_ref, b_ref, o_ref, *, l: int):
    """c[k] = Σ_{i+j=k} a_i·b_j, computed as a shifted rank-1 GEMM.

    The outer product is the p-GEMM the scheduler maps onto the array
    (M=L, N=L, K=1); the anti-diagonal sum is the systolic column-direction
    accumulation. Implemented with a static unroll over the L rows — each
    row is one "partial product flowing downward" (Fig. 1b).
    """
    a = a_ref[...]
    b = b_ref[...]
    outer = a[:, None] * b[None, :]  # (L, L) limb cross-products
    acc = jnp.zeros((2 * l - 1,), o_ref.dtype)
    for i in range(l):
        # row i lands at output positions i .. i+L-1 (shift by one limb per
        # row — the systolic skew)
        acc = acc.at[i : i + l].add(outer[i])
    o_ref[...] = acc


@functools.partial(jax.jit, static_argnames=("interpret",))
def bignum_mul(
    a_limbs: jnp.ndarray, b_limbs: jnp.ndarray, *, interpret: bool = True
) -> jnp.ndarray:
    """Pre-carry limb product of two L-limb big numbers (int32 limbs 0..255).

    Output is (2L-1,) int32 column sums; max column value L·255² < 2^31 for
    L up to ~33000 limbs, far beyond the artifact sizes.
    """
    (l,) = a_limbs.shape
    assert a_limbs.shape == b_limbs.shape
    if l == 1:
        # degenerate single-limb case: one PE, one product
        def kernel(a_ref, b_ref, o_ref):
            o_ref[...] = a_ref[...] * b_ref[...]

    else:
        kernel = functools.partial(_bignum_kernel, l=l)
    return pl.pallas_call(
        kernel,
        out_shape=jax.ShapeDtypeStruct((2 * l - 1,), a_limbs.dtype),
        interpret=interpret,
    )(a_limbs, b_limbs)
