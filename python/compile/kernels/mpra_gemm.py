"""L1 Pallas kernel: the MPRA datapath as a limb-decomposed GEMM.

This is the functional model of the paper's §3.1 insight: an ``8n``-bit
multiplication *is* an ``n×n`` matrix of 8-bit limb cross-products, so a
multi-precision GEMM maps onto the same systolic schedule as an ordinary
GEMM. The kernel computes ``C = A @ B`` for INT8/16/32/64 operands using
ONLY 8-bit × 8-bit limb products (each ≤ 16 bits), the way the MPRA's 8-bit
PEs do, and shift-adds them in the accumulator (Fig. 3).

Hardware adaptation (DESIGN.md §Hardware-Adaptation): on the TPU-style
programming model the limb cross-products are expressed as extra
contraction work so the MXU performs them; BlockSpec tiles the A/B panels
through VMEM the way the systolic array streams SRAM panels. The dataflow
choice (WS/IS/OS) of the real hardware is a *scheduling* property — the
rust simulator models its cycles/traffic; numerically all dataflows
produce this kernel's result.

interpret=True is mandatory: real-TPU lowering emits a Mosaic custom-call
the CPU PJRT plugin cannot execute.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _mpra_kernel(x_ref, y_ref, o_ref, *, n_limbs: int, width: int):
    """One (bm × bk) · (bk × bn) tile of the limb GEMM.

    Grid is (M/bm, N/bn, K/bk); the K axis revisits o_ref, accumulating —
    the Output-Stationary pattern (the C tile is the resident operand).
    """

    @pl.when(pl.program_id(2) == 0)
    def _zero():
        o_ref[...] = jnp.zeros_like(o_ref)

    x = x_ref[...]
    y = y_ref[...]
    acc = jnp.zeros(o_ref.shape, o_ref.dtype)

    def limb(v, i):
        # Little-endian limbs; the TOP limb is sign-extended (arithmetic
        # shift, no mask) so that signed operands recompose exactly —
        # the signed-MSB limb scheme the multi-precision accumulator
        # (Fig. 3) implements in hardware. Lower limbs are unsigned.
        return v >> (8 * i) if i == n_limbs - 1 else (v >> (8 * i)) & 0xFF

    # n² limb cross-products; each 8b×8b product fits in 16 bits, exactly
    # what a single 8-bit PE emits. Terms shifted past the accumulator
    # width vanish mod 2^width and are skipped (the hardware never wires
    # them).
    for i in range(n_limbs):
        xi = limb(x, i)
        for j in range(n_limbs):
            shift = 8 * (i + j)
            if shift >= width:
                continue
            yj = limb(y, j)
            # the MXU contraction: limb panel × limb panel
            prod = jax.lax.dot_general(
                xi,
                yj,
                (((1,), (0,)), ((), ())),
                preferred_element_type=o_ref.dtype,
            )
            acc = acc + (prod << shift)
    o_ref[...] = o_ref[...] + acc


def _block(m: int, b: int) -> int:
    """Largest divisor of m not exceeding b (block sizes must tile evenly)."""
    b = min(m, b)
    while m % b:
        b -= 1
    return b


@functools.partial(
    jax.jit, static_argnames=("n_limbs", "bm", "bk", "bn", "interpret")
)
def mpra_gemm(
    a: jnp.ndarray,
    b: jnp.ndarray,
    *,
    n_limbs: int,
    bm: int = 32,
    bk: int = 32,
    bn: int = 32,
    interpret: bool = True,
) -> jnp.ndarray:
    """``C = A @ B`` (mod 2^width) computed from 8-bit limb products.

    a: (M, K), b: (K, N); int32 or int64. ``n_limbs`` is the precision in
    limbs (INT8→1 … INT64→8); values wider than 8·n_limbs bits are valid —
    extra limbs are simply zero — but the hardware analogue would occupy
    more PEs.
    """
    m, k = a.shape
    k2, n = b.shape
    assert k == k2, f"contraction mismatch {k} vs {k2}"
    assert a.dtype == b.dtype and a.dtype in (jnp.int32, jnp.int64)
    width = jnp.iinfo(a.dtype).bits
    bm, bk, bn = _block(m, bm), _block(k, bk), _block(n, bn)
    grid = (m // bm, n // bn, k // bk)
    kernel = functools.partial(_mpra_kernel, n_limbs=n_limbs, width=width)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), a.dtype),
        interpret=interpret,
    )(a, b)
