"""Pure-jnp reference oracles for the L1 Pallas kernels.

These are the correctness ground truth: every Pallas kernel in this package
is checked against the corresponding function here (pytest + hypothesis).
Nothing in this file uses Pallas; it is plain jnp so that an independent
code path validates the kernels.

Precision model (shared with rust/src/precision):
  an ``8n``-bit integer is ``n`` unsigned 8-bit limbs, little-endian;
  FP mantissas map to INT8/12/24/53 (BP16/FP16/FP32/FP64), i.e. 1/2/3/7 limbs.
"""

from __future__ import annotations

import jax.numpy as jnp

# Number of 8-bit limbs per supported precision tag. Mirrors
# rust/src/precision/mod.rs::Precision::limbs().
LIMBS = {
    "int8": 1,
    "int16": 2,
    "int32": 4,
    "int64": 8,
    "bp16": 1,   # bfloat16 mantissa ≈ INT8
    "fp16": 2,   # INT12 mantissa -> 2 limbs
    "fp32": 3,   # INT24 mantissa -> 3 limbs
    "fp64": 7,   # INT53 mantissa -> 7 limbs
}


def limb_decompose(x: jnp.ndarray, n_limbs: int) -> jnp.ndarray:
    """Split integers into unsigned 8-bit limbs (little-endian).

    Returns an array with a trailing limb axis of length ``n_limbs``.
    Works for signed inputs: limbs are the two's-complement bit pattern,
    so ``limb_recompose(limb_decompose(x, n)) == x (mod 2^(8n))``.
    """
    limbs = [(x >> (8 * i)) & 0xFF for i in range(n_limbs)]
    return jnp.stack(limbs, axis=-1)


def limb_recompose(limbs: jnp.ndarray) -> jnp.ndarray:
    """Inverse of :func:`limb_decompose` (modulo the accumulator width)."""
    n = limbs.shape[-1]
    acc = jnp.zeros(limbs.shape[:-1], dtype=limbs.dtype)
    for i in range(n):
        acc = acc + (limbs[..., i] << (8 * i))
    return acc


def gemm_ref(a: jnp.ndarray, b: jnp.ndarray) -> jnp.ndarray:
    """Full-precision GEMM oracle (the thing the MPRA must reproduce)."""
    return a @ b


def mpra_gemm_ref(a: jnp.ndarray, b: jnp.ndarray, n_limbs: int) -> jnp.ndarray:
    """Limb-decomposed GEMM, written the way §3.1 of the paper describes it.

    Each scalar product a_ik * b_kj is expanded into the n² cross-products of
    its 8-bit limbs; cross-products at the same shift amount are summed down
    the "column direction" exactly as the systolic array does. Because limbs
    are the two's-complement bit pattern, the result equals ``a @ b`` under
    the accumulator's wrap-around (mod 2^width) semantics.
    """
    width = jnp.iinfo(a.dtype).bits

    def limb(v, i):
        # top limb sign-extended, lower limbs unsigned — the signed-MSB
        # limb scheme (matches the kernel and the Fig. 3 accumulator)
        return v >> (8 * i) if i == n_limbs - 1 else (v >> (8 * i)) & 0xFF

    acc = jnp.zeros((a.shape[0], b.shape[1]), dtype=a.dtype)
    for i in range(n_limbs):
        ai = limb(a, i)
        for j in range(n_limbs):
            shift = 8 * (i + j)
            if shift >= width:
                continue  # vanishes modulo 2^width
            bj = limb(b, j)
            acc = acc + ((ai @ bj) << shift)
    return acc


def bignum_mul_ref(a_limbs: jnp.ndarray, b_limbs: jnp.ndarray) -> jnp.ndarray:
    """Schoolbook big-number product, *pre carry propagation*.

    ``c[k] = sum_{i+j=k} a_i * b_j`` — the polynomial (limb) product the
    paper's Fig. 1 places on the array; carries are the accumulator's job
    (Fig. 3) and are applied by the rust coordinator / `carry_propagate`.
    """
    la, lb = a_limbs.shape[-1], b_limbs.shape[-1]
    out = jnp.zeros(a_limbs.shape[:-1] + (la + lb - 1,), dtype=a_limbs.dtype)
    for i in range(la):
        out = out.at[..., i : i + lb].add(a_limbs[..., i : i + 1] * b_limbs)
    return out


def carry_propagate(c) -> "jnp.ndarray":
    """Normalize a pre-carry limb product back to 8-bit limbs.

    Sequential by nature (matches the accumulator's carry chain); only used
    by tests — the rust side has its own implementation.
    """
    import numpy as np

    c = np.asarray(c, dtype=np.int64)
    out = np.zeros(c.shape[-1] + 8, dtype=np.int64)
    carry = 0
    for k in range(c.shape[-1]):
        v = int(c[k]) + carry
        out[k] = v & 0xFF
        carry = v >> 8
    k = c.shape[-1]
    while carry and k < out.shape[0]:
        out[k] = carry & 0xFF
        carry >>= 8
        k += 1
    return jnp.asarray(out, dtype=jnp.int64)


def im2col(x: jnp.ndarray, r: int, s: int) -> jnp.ndarray:
    """(C,H,W) -> (C*R*S, OH*OW) patch matrix (valid padding, stride 1).

    Layout: for channel c, kernel offset (dr, ds) -> row c*R*S + dr*S + ds.
    Must match model.py's im2col (the L2 model reuses this function).
    """
    c, h, w = x.shape
    oh, ow = h - r + 1, w - s + 1
    rows = []
    for ch in range(c):
        for dr in range(r):
            for ds in range(s):
                rows.append(x[ch, dr : dr + oh, ds : ds + ow].reshape(-1))
    return jnp.stack(rows, axis=0)


def conv_im2col_ref(x: jnp.ndarray, w: jnp.ndarray) -> jnp.ndarray:
    """Direct convolution oracle for the im2col-GEMM lowering.

    x: (C, H, W), w: (K, C, R, S), valid padding, stride 1 -> (K, OH, OW).
    """
    k, c, r, s = w.shape
    oh, ow = x.shape[1] - r + 1, x.shape[2] - s + 1
    cols = im2col(x, r, s)  # (C*R*S, OH*OW)
    out = w.reshape(k, -1) @ cols
    return out.reshape(k, oh, ow)


def ffl_ref(x: jnp.ndarray, w1: jnp.ndarray, w2: jnp.ndarray) -> jnp.ndarray:
    """GPT-style feed-forward layer oracle: relu(x@W1)@W2."""
    return jnp.maximum(x @ w1, 0.0) @ w2


def pca_cov_ref(x: jnp.ndarray) -> jnp.ndarray:
    """Covariance GEMM oracle: centered Xᵀ X / (n-1)."""
    xc = x - x.mean(axis=0, keepdims=True)
    return (xc.T @ xc) / (x.shape[0] - 1)
