"""L1 Pallas kernel: VMEM-tiled f32 GEMM (the MXU hot path for FP workloads).

Used by the L2 workload models (Alexnet conv im2col, GPT-3 FFL, PCA, Nerf
MLP) for the floating-point precisions, where the MPRA's role is mantissa
multiplication and the functional result is an ordinary GEMM. BlockSpec
expresses the HBM↔VMEM panel schedule that the paper's systolic array does
with its SRAM streams: the C tile is output-stationary across the K grid
axis; A/B panels are double-buffered by the pipeline machinery.

interpret=True for CPU PJRT (see mpra_gemm.py).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _matmul_kernel(x_ref, y_ref, o_ref):
    """Output-stationary tile: the C block stays resident across the K grid
    axis (the OS dataflow of the paper); A/B panels stream past it."""

    @pl.when(pl.program_id(2) == 0)
    def _zero():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += jax.lax.dot_general(
        x_ref[...],
        y_ref[...],
        (((1,), (0,)), ((), ())),
        preferred_element_type=o_ref.dtype,
    )


def _block(m: int, b: int) -> int:
    """Largest divisor of m not exceeding b (blocks must tile evenly)."""
    b = min(m, b)
    while m % b:
        b -= 1
    return b


@functools.partial(jax.jit, static_argnames=("bm", "bk", "bn", "interpret"))
def tiled_matmul(
    a: jnp.ndarray,
    b: jnp.ndarray,
    *,
    bm: int = 64,
    bk: int = 64,
    bn: int = 64,
    interpret: bool = True,
) -> jnp.ndarray:
    """``a @ b`` with explicit VMEM tiling; bf16/f32 in, f32 accumulate."""
    m, k = a.shape
    k2, n = b.shape
    assert k == k2, f"contraction mismatch {k} vs {k2}"
    bm, bk, bn = _block(m, bm), _block(k, bk), _block(n, bn)
    grid = (m // bm, n // bn, k // bk)
    return pl.pallas_call(
        _matmul_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        interpret=interpret,
    )(a, b)
