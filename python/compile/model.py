"""L2: JAX compute graphs for the paper's workloads, calling the L1 kernels.

Each entry here is a jit-able function over fixed example shapes; aot.py
lowers them once to HLO text and the rust coordinator executes them via
PJRT. Python never runs on the request path.

The functions mirror the p-GEMM decompositions in Table 2:
  BNM  -> bignum_mul (limb outer-product p-GEMM)
  RGB  -> 3x3 colour-matrix GEMM, INT8 (mpra_gemm, 1 limb)
  ALI  -> Alexnet conv via im2col GEMM, INT8
  ALT/Nerf -> f32 GEMMs (tiled_matmul)
  FFL  -> GPT-3 feed-forward, BP16 mantissa (bf16 in, f32 accum)
  PCA  -> covariance GEMM, f64 modelled at f32 artifact precision with the
          limb path carrying the FP64-mantissa (7-limb) case for integers
  MD   -> blocked matrix decomposition GEMM update, INT32 fixed-point
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .kernels import bignum_mul, mpra_gemm, tiled_matmul
from .kernels.ref import im2col


# ----------------------------------------------------------------- p-GEMM --
def mpra_gemm_fn(n_limbs: int):
    """Raw MPRA GEMM entry (one per integer precision)."""

    def fn(a, b):
        return (mpra_gemm(a, b, n_limbs=n_limbs),)

    return fn


def matmul_f32_fn():
    """Raw f32 tiled GEMM entry (FP workload building block)."""

    def fn(a, b):
        return (tiled_matmul(a, b),)

    return fn


def bignum_fn():
    """BNM: pre-carry limb product (carries done by the rust accumulator)."""

    def fn(a_limbs, b_limbs):
        return (bignum_mul(a_limbs, b_limbs),)

    return fn


# -------------------------------------------------------------- workloads --
def alexnet_conv_int8_fn(c: int, hw: int, k: int, r: int):
    """ALI: one Alexnet conv layer, INT8, lowered to im2col GEMM.

    x: (C, H, W) int32 holding int8 values; w: (K, C, R, S). The GEMM runs
    through the MPRA limb kernel with n_limbs=1 — the paper's INT8 inference
    path. M=K_out, N=OH*OW, K=C*R*S.
    """

    def fn(x, w):
        cols = im2col(x, r, r)  # (C*R*S, OH*OW)
        wmat = w.reshape(k, c * r * r)
        out = mpra_gemm(wmat, cols, n_limbs=1)
        return (out,)

    return fn


def ffl_bf16_fn():
    """FFL: GPT-3 feed-forward slice, BP16 (bf16) weights, f32 accumulate.

    BP16's mantissa is 8 bits == one limb — the MPRA's best case (Table 3:
    16x SIMD gain). I/O is f32 (the runtime's host format); operands are
    quantized through bf16 on entry, exactly what the BP16 datapath sees.
    """

    def fn(x, w1, w2):
        q = lambda t: t.astype(jnp.bfloat16).astype(jnp.float32)
        h = tiled_matmul(q(x), q(w1))
        h = jnp.maximum(h, 0.0)
        out = tiled_matmul(q(h), q(w2))
        return (out,)

    return fn


def pca_cov_fn():
    """PCA: covariance GEMM XᵀX/(n-1) after centering."""

    def fn(x):
        xc = x - x.mean(axis=0, keepdims=True)
        cov = tiled_matmul(xc.T, xc) / (x.shape[0] - 1)
        return (cov,)

    return fn


def nerf_mlp_fn():
    """Nerf: one positional-encoding MLP block (two f32 GEMMs + relu)."""

    def fn(x, w1, w2):
        h = jnp.maximum(tiled_matmul(x, w1), 0.0)
        return (tiled_matmul(h, w2),)

    return fn


def md_update_int32_fn():
    """MD: blocked LU-style trailing-update GEMM, INT32 fixed point.

    A_22 -= A_21 @ A_12 is the GEMM that dominates blocked decompositions;
    runs through the 4-limb MPRA path (wrap-around fixed-point semantics).
    """

    def fn(a22, a21, a12):
        prod = mpra_gemm(a21, a12, n_limbs=4)
        return (a22 - prod,)

    return fn


def rgb_convert_int8_fn():
    """RGB: SRGB2XYZ colour conversion — a 3×3 matrix times a pixel
    panel, INT8 through the 1-limb MPRA path (Table 2's RGB workload)."""

    def fn(mat, img):
        # mat: (3,3), img: (3, P) channel-major pixels
        return (mpra_gemm(mat, img, n_limbs=1, bm=3, bk=3),)

    return fn


def fir_int16_fn(n: int, taps: int):
    """FFE: a `taps`-tap FIR over `n` samples, INT16 (2-limb MPRA path).

    The delay-line matrix is built by static window gathers (the vector
    Map op of the lowering); the filter itself is the (1, n, taps)
    p-GEMM of Table 2's FFE workload.
    """

    def fn(x, h):
        # x: (n + taps - 1,), h: (taps,)
        windows = jnp.stack([x[t : t + n] for t in range(taps)], axis=0)  # (taps, n)
        y = mpra_gemm(h[None, :], windows, n_limbs=2, bm=1)
        return (y,)

    return fn
