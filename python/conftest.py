import os
import sys

# Make the build-path package (compile/) importable when pytest runs
# from the repository root (e.g. `pytest python/tests/`).
sys.path.insert(0, os.path.dirname(__file__))
