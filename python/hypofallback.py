"""Deterministic stand-in for `hypothesis` when it is not installed.

The offline test image ships jax/numpy/pytest but not hypothesis; rather
than erroring at collection, the property tests fall back to a small
fixed sweep of pseudo-random samples per test (seeded, so failures
reproduce). Only the surface the tests use is implemented: `given` with
keyword strategies, a pass-through `settings`, and
`strategies.integers` / `strategies.sampled_from`.
"""

import random


class _Strategy:
    def __init__(self, sample):
        self.sample = sample


class strategies:
    @staticmethod
    def integers(min_value, max_value):
        return _Strategy(lambda rng: rng.randint(min_value, max_value))

    @staticmethod
    def sampled_from(elements):
        xs = list(elements)
        return _Strategy(lambda rng: xs[rng.randrange(len(xs))])


def settings(*_args, **_kwargs):
    """No-op decorator factory (max_examples/deadline are ignored)."""

    def deco(fn):
        return fn

    return deco


_FALLBACK_EXAMPLES = 15


def given(**strategy_kwargs):
    def deco(fn):
        def wrapper():
            for case in range(_FALLBACK_EXAMPLES):
                rng = random.Random(0xC0FFEE + case)
                kwargs = {k: s.sample(rng) for k, s in strategy_kwargs.items()}
                try:
                    fn(**kwargs)
                except Exception as e:  # surface the failing sample
                    raise AssertionError(
                        f"property case {case} failed with args {kwargs}: {e}"
                    ) from e

        wrapper.__name__ = fn.__name__
        wrapper.__doc__ = fn.__doc__
        return wrapper

    return deco
