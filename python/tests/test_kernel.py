"""Kernel-vs-reference correctness: the CORE numeric signal of the repo.

Every Pallas kernel is compared against the pure-jnp oracle in
kernels/ref.py, including hypothesis sweeps over shapes, dtypes and value
ranges (the paper's multi-precision claim is an *exactness* claim for the
integer limb paths, so integer comparisons are exact, not allclose).
"""

import jax

jax.config.update("jax_enable_x64", True)

import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ModuleNotFoundError:  # offline image: deterministic fallback sweep
    from hypofallback import given, settings, strategies as st

from compile.kernels import bignum_mul, mpra_gemm, tiled_matmul
from compile.kernels import ref


RNG = np.random.default_rng(0)


def _randi(shape, bits, dtype=np.int32, rng=RNG):
    """Random signed integers occupying the full `bits`-bit range."""
    lo, hi = -(1 << (bits - 1)), (1 << (bits - 1)) - 1
    return jnp.asarray(rng.integers(lo, hi + 1, size=shape), dtype=dtype)


# --------------------------------------------------------------- mpra_gemm --
@pytest.mark.parametrize("n_limbs,bits", [(1, 8), (2, 16), (4, 32)])
@pytest.mark.parametrize("mkn", [(8, 8, 8), (16, 32, 8), (64, 64, 64), (13, 7, 5)])
def test_mpra_gemm_matches_exact_gemm(n_limbs, bits, mkn):
    """Limb-decomposed GEMM == exact GEMM when no accumulator overflow."""
    m, k, n = mkn
    # keep values small enough that the true product fits in int32
    a = _randi((m, k), min(bits, 10))
    b = _randi((k, n), min(bits, 10))
    got = mpra_gemm(a, b, n_limbs=n_limbs)
    want = ref.gemm_ref(a, b)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@pytest.mark.parametrize("n_limbs", [1, 2, 4])
def test_mpra_gemm_matches_limb_ref(n_limbs):
    """Kernel == the independently-written limb oracle at full range
    (wrap-around mod 2^32 semantics, the accumulator's behaviour)."""
    a = _randi((32, 32), 8 * n_limbs)
    b = _randi((32, 32), 8 * n_limbs)
    got = mpra_gemm(a, b, n_limbs=n_limbs)
    want = ref.mpra_gemm_ref(a, b, n_limbs)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_mpra_gemm_int64_path():
    """INT64 (8-limb) path: exact vs wide numpy product."""
    a = _randi((16, 16), 20, dtype=np.int64)
    b = _randi((16, 16), 20, dtype=np.int64)
    got = mpra_gemm(a, b, n_limbs=8)
    want = np.asarray(a, dtype=np.int64) @ np.asarray(b, dtype=np.int64)
    np.testing.assert_array_equal(np.asarray(got), want)


def test_mpra_gemm_wraps_like_hardware():
    """Overflow wraps mod 2^32 — two's-complement accumulator semantics."""
    a = jnp.full((4, 4), 1 << 20, dtype=jnp.int32)
    b = jnp.full((4, 4), 1 << 20, dtype=jnp.int32)
    got = np.asarray(mpra_gemm(a, b, n_limbs=4))
    want = (np.full((4, 4), np.int64(1) << 40) * 4) % (1 << 32)
    want = want.astype(np.uint32).astype(np.int32)
    np.testing.assert_array_equal(got, want)


@settings(max_examples=25, deadline=None)
@given(
    m=st.integers(1, 24),
    k=st.integers(1, 24),
    n=st.integers(1, 24),
    n_limbs=st.sampled_from([1, 2, 4]),
    seed=st.integers(0, 2**31 - 1),
)
def test_mpra_gemm_hypothesis_shapes(m, k, n, n_limbs, seed):
    """Property: arbitrary (possibly prime) shapes and precisions agree with
    the limb oracle under wrap semantics."""
    rng = np.random.default_rng(seed)
    a = _randi((m, k), 8 * n_limbs, rng=rng)
    b = _randi((k, n), 8 * n_limbs, rng=rng)
    got = mpra_gemm(a, b, n_limbs=n_limbs)
    want = ref.mpra_gemm_ref(a, b, n_limbs)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@settings(max_examples=10, deadline=None)
@given(
    bm=st.sampled_from([8, 16, 32]),
    bk=st.sampled_from([8, 16, 32]),
    bn=st.sampled_from([8, 16, 32]),
)
def test_mpra_gemm_block_shape_invariance(bm, bk, bn):
    """Property: the BlockSpec schedule never changes the numbers."""
    a = _randi((32, 32), 16)
    b = _randi((32, 32), 16)
    want = ref.mpra_gemm_ref(a, b, 2)
    got = mpra_gemm(a, b, n_limbs=2, bm=bm, bk=bk, bn=bn)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


# ------------------------------------------------------------ tiled_matmul --
@pytest.mark.parametrize("mkn", [(16, 16, 16), (128, 128, 128), (24, 56, 40)])
def test_tiled_matmul_matches_ref(mkn):
    m, k, n = mkn
    a = jnp.asarray(RNG.standard_normal((m, k)), dtype=jnp.float32)
    b = jnp.asarray(RNG.standard_normal((k, n)), dtype=jnp.float32)
    got = tiled_matmul(a, b)
    np.testing.assert_allclose(
        np.asarray(got), np.asarray(a) @ np.asarray(b), rtol=1e-5, atol=1e-5
    )


@settings(max_examples=20, deadline=None)
@given(
    m=st.integers(1, 48),
    k=st.integers(1, 48),
    n=st.integers(1, 48),
    seed=st.integers(0, 2**31 - 1),
)
def test_tiled_matmul_hypothesis(m, k, n, seed):
    rng = np.random.default_rng(seed)
    a = jnp.asarray(rng.standard_normal((m, k)), dtype=jnp.float32)
    b = jnp.asarray(rng.standard_normal((k, n)), dtype=jnp.float32)
    got = tiled_matmul(a, b)
    np.testing.assert_allclose(
        np.asarray(got), np.asarray(a) @ np.asarray(b), rtol=1e-4, atol=1e-4
    )


def test_tiled_matmul_bf16_inputs_f32_accum():
    a = jnp.asarray(RNG.standard_normal((32, 32)), dtype=jnp.bfloat16)
    b = jnp.asarray(RNG.standard_normal((32, 32)), dtype=jnp.bfloat16)
    got = tiled_matmul(a.astype(jnp.float32), b.astype(jnp.float32))
    want = np.asarray(a, dtype=np.float32) @ np.asarray(b, dtype=np.float32)
    np.testing.assert_allclose(np.asarray(got), want, rtol=1e-5, atol=1e-5)


# ----------------------------------------------------------------- bignum --
def test_bignum_matches_python_bigint():
    """End-to-end §3.1 check: limb outer-product + carry == python int mult."""
    l = 64
    a_limbs = jnp.asarray(RNG.integers(0, 256, size=l), dtype=jnp.int32)
    b_limbs = jnp.asarray(RNG.integers(0, 256, size=l), dtype=jnp.int32)
    pre = bignum_mul(a_limbs, b_limbs)
    carried = ref.carry_propagate(pre)
    got = sum(int(v) << (8 * i) for i, v in enumerate(np.asarray(carried)))
    a_int = sum(int(v) << (8 * i) for i, v in enumerate(np.asarray(a_limbs)))
    b_int = sum(int(v) << (8 * i) for i, v in enumerate(np.asarray(b_limbs)))
    assert got == a_int * b_int


def test_bignum_matches_ref():
    a = jnp.asarray(RNG.integers(0, 256, size=16), dtype=jnp.int32)
    b = jnp.asarray(RNG.integers(0, 256, size=16), dtype=jnp.int32)
    np.testing.assert_array_equal(
        np.asarray(bignum_mul(a, b)), np.asarray(ref.bignum_mul_ref(a, b))
    )


@settings(max_examples=20, deadline=None)
@given(l=st.integers(1, 48), seed=st.integers(0, 2**31 - 1))
def test_bignum_hypothesis(l, seed):
    rng = np.random.default_rng(seed)
    a = jnp.asarray(rng.integers(0, 256, size=l), dtype=jnp.int32)
    b = jnp.asarray(rng.integers(0, 256, size=l), dtype=jnp.int32)
    pre = bignum_mul(a, b)
    carried = ref.carry_propagate(pre)
    got = sum(int(v) << (8 * i) for i, v in enumerate(np.asarray(carried)))
    a_int = sum(int(v) << (8 * i) for i, v in enumerate(np.asarray(a)))
    b_int = sum(int(v) << (8 * i) for i, v in enumerate(np.asarray(b)))
    assert got == a_int * b_int


# ------------------------------------------------------- limb decomposition --
@settings(max_examples=30, deadline=None)
@given(
    n_limbs=st.sampled_from([1, 2, 4]),
    seed=st.integers(0, 2**31 - 1),
)
def test_limb_roundtrip(n_limbs, seed):
    rng = np.random.default_rng(seed)
    x = _randi((16,), 8 * n_limbs, rng=rng)
    limbs = ref.limb_decompose(x, n_limbs)
    back = ref.limb_recompose(limbs)
    mask = np.int64((1 << (8 * n_limbs)) - 1)
    np.testing.assert_array_equal(
        np.asarray(back, dtype=np.int64) & mask,
        np.asarray(x, dtype=np.int64) & mask,
    )
