"""L2 model graphs vs direct oracles + AOT manifest sanity."""

import jax

jax.config.update("jax_enable_x64", True)

import jax.numpy as jnp
import numpy as np

from compile import model
from compile.kernels import ref


RNG = np.random.default_rng(1)


def test_alexnet_conv_int8_matches_direct_conv():
    c, hw, k, r = 8, 9, 4, 3
    fn = model.alexnet_conv_int8_fn(c=c, hw=hw, k=k, r=r)
    x = jnp.asarray(RNG.integers(-128, 128, size=(c, hw, hw)), dtype=jnp.int32)
    w = jnp.asarray(RNG.integers(-128, 128, size=(k, c, r, r)), dtype=jnp.int32)
    (got,) = fn(x, w)
    want = ref.conv_im2col_ref(x, w).reshape(k, -1)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_ffl_bf16_matches_oracle():
    fn = model.ffl_bf16_fn()
    x = jnp.asarray(RNG.standard_normal((4, 32)), dtype=jnp.float32)
    w1 = jnp.asarray(RNG.standard_normal((32, 64)), dtype=jnp.float32)
    w2 = jnp.asarray(RNG.standard_normal((64, 32)), dtype=jnp.float32)
    (got,) = fn(x, w1, w2)
    # oracle applies the same BP16 quantization the datapath sees
    q = lambda t: np.asarray(jnp.asarray(t).astype(jnp.bfloat16), dtype=np.float32)
    h = np.maximum(q(x) @ q(w1), 0.0)
    want = q(h) @ q(w2)
    np.testing.assert_allclose(np.asarray(got), want, rtol=2e-2, atol=2e-2)


def test_pca_cov_matches_oracle():
    fn = model.pca_cov_fn()
    x = jnp.asarray(RNG.standard_normal((64, 16)), dtype=jnp.float32)
    (got,) = fn(x)
    np.testing.assert_allclose(
        np.asarray(got), np.asarray(ref.pca_cov_ref(x)), rtol=1e-4, atol=1e-4
    )


def test_nerf_mlp_matches_oracle():
    fn = model.nerf_mlp_fn()
    x = jnp.asarray(RNG.standard_normal((16, 8)), dtype=jnp.float32)
    w1 = jnp.asarray(RNG.standard_normal((8, 32)), dtype=jnp.float32)
    w2 = jnp.asarray(RNG.standard_normal((32, 8)), dtype=jnp.float32)
    (got,) = fn(x, w1, w2)
    want = ref.ffl_ref(np.asarray(x), np.asarray(w1), np.asarray(w2))
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5, atol=1e-5)


def test_md_update_int32_matches_numpy():
    fn = model.md_update_int32_fn()
    a22 = jnp.asarray(RNG.integers(-100, 100, size=(16, 16)), dtype=jnp.int32)
    a21 = jnp.asarray(RNG.integers(-100, 100, size=(16, 8)), dtype=jnp.int32)
    a12 = jnp.asarray(RNG.integers(-100, 100, size=(8, 16)), dtype=jnp.int32)
    (got,) = fn(a22, a21, a12)
    want = np.asarray(a22) - np.asarray(a21) @ np.asarray(a12)
    np.testing.assert_array_equal(np.asarray(got), want)


def test_all_aot_entries_lower_and_eval():
    """Every AOT entry traces, lowers to HLO text, and eval_shape agrees."""
    from compile import aot

    for name, (fn, specs, _doc) in aot.entries().items():
        lowered = jax.jit(fn).lower(*specs)
        text = aot.to_hlo_text(lowered)
        assert "HloModule" in text, name
        outs = jax.eval_shape(fn, *specs)
        assert len(outs) >= 1, name


def test_rgb_convert_matches_direct():
    fn = model.rgb_convert_int8_fn()
    mat = jnp.asarray(RNG.integers(-128, 128, size=(3, 3)), dtype=jnp.int32)
    img = jnp.asarray(RNG.integers(-128, 128, size=(3, 64)), dtype=jnp.int32)
    (got,) = fn(mat, img)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(mat) @ np.asarray(img))


def test_fir_matches_direct_convolution():
    n, taps = 32, 8
    fn = model.fir_int16_fn(n=n, taps=taps)
    x = jnp.asarray(RNG.integers(-3000, 3000, size=(n + taps - 1,)), dtype=jnp.int32)
    h = jnp.asarray(RNG.integers(-3000, 3000, size=(taps,)), dtype=jnp.int32)
    (got,) = fn(x, h)
    want = np.array(
        [sum(int(h[t]) * int(x[i + t]) for t in range(taps)) for i in range(n)],
        dtype=np.int64,
    )
    np.testing.assert_array_equal(np.asarray(got).ravel().astype(np.int64), want)
