//! Ablation: what each §5 scheduling ingredient buys.
//!
//! For every p-GEMM in the Table 2 suite, compare the best achievable
//! cycles AND memory under the full joint space (dataflow × arrangement ×
//! K-seg × tile-dir) against restricted spaces: single fixed dataflow,
//! no array resize, no K-segmentation. Prints the cost of each
//! restriction — the evidence behind the paper's joint-optimization claim.

use gta::arch::Dataflow;
use gta::scheduler::{self, explorer, Candidate};
use gta::util::bench::bench;
use gta::workloads;
use gta::{GtaConfig, PGemm};

#[derive(Default)]
struct Tally {
    cycles: u64,
    mem: u64,
}

impl Tally {
    fn add_best(&mut self, cands: &[Candidate], keep: impl Fn(&Candidate) -> bool) {
        let filtered: Vec<&Candidate> = cands.iter().filter(|c| keep(c)).collect();
        if filtered.is_empty() {
            // restriction expressible nowhere: charge the unrestricted best
            self.cycles += cands.iter().map(|c| c.report.cycles).min().unwrap();
            self.mem += cands.iter().map(|c| c.report.memory_access()).min().unwrap();
            return;
        }
        self.cycles += filtered.iter().map(|c| c.report.cycles).min().unwrap();
        self.mem += filtered.iter().map(|c| c.report.memory_access()).min().unwrap();
    }
}

fn main() {
    let gta = GtaConfig::lanes16();
    let default_arr = gta
        .arrangements()
        .into_iter()
        .find(|a| a.lane_rows == a.lane_cols)
        .unwrap_or(gta.arrangements()[0]);

    let mut full = Tally::default();
    let mut ws_only = Tally::default();
    let mut no_resize = Tally::default();
    let mut no_kseg = Tally::default();
    // separate tally for the small operators (where utilization levers
    // matter; the big Cover1 GEMMs are work-bound under any schedule)
    let mut full_small = Tally::default();
    let mut no_kseg_small = Tally::default();
    let mut no_resize_small = Tally::default();

    // every suite p-GEMM swept concurrently through the batch explorer
    // (repeated layer shapes share one sweep via the memo)
    let all_ops: Vec<PGemm> = workloads::suite_pgemms();
    let n_ops = all_ops.len() as u64;
    let sets = explorer::explore_batch(&all_ops, &gta);
    for (g, cands) in all_ops.iter().zip(&sets) {
        full.add_best(cands, |_| true);
        ws_only.add_best(cands, |c| c.config.dataflow == Dataflow::WS);
        no_resize.add_best(cands, |c| c.config.arrangement == default_arr);
        no_kseg.add_best(cands, |c| c.config.k_segments == 1);
        if g.macs() < 2_000_000 {
            full_small.add_best(cands, |_| true);
            no_kseg_small.add_best(cands, |c| c.config.k_segments == 1);
            no_resize_small.add_best(cands, |c| c.config.arrangement == default_arr);
        }
    }
    println!("=== Ablation: best-achievable under scheduling restrictions ({n_ops} suite p-GEMMs) ===");
    let row = |name: &str, t: &Tally| {
        println!(
            "  {:<24} {:>14} cycles (+{:>5.1}%)   {:>16} mem bytes (+{:>5.1}%)",
            name,
            t.cycles,
            (t.cycles as f64 / full.cycles as f64 - 1.0) * 100.0,
            t.mem,
            (t.mem as f64 / full.mem as f64 - 1.0) * 100.0,
        );
    };
    row("full joint search", &full);
    row("WS-only dataflow", &ws_only);
    row("no array resize", &no_resize);
    row("no K-segmentation", &no_kseg);
    println!("  --- small operators only (< 2M MACs) ---");
    let row_small = |name: &str, t: &Tally| {
        println!(
            "  {:<24} {:>14} cycles (+{:>5.1}%)",
            name,
            t.cycles,
            (t.cycles as f64 / full_small.cycles as f64 - 1.0) * 100.0,
        );
    };
    row_small("full joint search", &full_small);
    row_small("no array resize", &no_resize_small);
    row_small("no K-segmentation", &no_kseg_small);
    assert!(ws_only.cycles >= full.cycles && ws_only.mem >= full.mem);
    assert!(no_resize.cycles >= full.cycles);
    assert!(no_kseg.cycles >= full.cycles);
    assert!(
        ws_only.cycles > full.cycles || no_resize.cycles > full.cycles,
        "at least one restriction must hurt, else the joint space is pointless"
    );
    println!();

    let g = gta::PGemm::new(384, 169, 2304, gta::Precision::Int8);
    bench("ablation/full_space_explore", || {
        std::hint::black_box(scheduler::explore(std::hint::black_box(&g), &gta));
    });
}
