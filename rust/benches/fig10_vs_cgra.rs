//! Fig. 10 — GTA vs CGRA (HyCube) on the p-GEMM operators of every
//! workload. Paper targets: 25.83× speedup, 8.76× memory efficiency.

use gta::report;
use gta::sim::{cgra::CgraSim, Platform};
use gta::util::bench::bench;
use gta::workloads;

fn main() {
    let cmp = report::fig10();
    println!("=== Fig 10: GTA vs CGRA, p-GEMM ops (paper avg: 25.83x / 8.76x) ===");
    print!("{}", report::render_comparison(&cmp));
    assert!(cmp.rows.iter().all(|r| r.speedup >= 1.0), "GTA must win cycles");
    assert!(cmp.avg_speedup > 10.0, "CGRA gap should be large");
    assert!(cmp.avg_mem_saving > 2.0);
    println!();

    let cgra = CgraSim::default();
    for w in workloads::suite_pgemm_only() {
        bench(&format!("fig10/cgra/{}", w.name), || {
            std::hint::black_box(cgra.run_all(std::hint::black_box(&w.ops)));
        });
    }
}
