//! Fig. 2 — operator classification scatter (algorithmic parallelism ×
//! arithmetic intensity), plus classification-throughput timing.

use gta::ops::classify::{classify, fig2_points};
use gta::precision::Precision;
use gta::util::bench::bench;
use gta::util::rng::Rng;
use gta::{PGemm, TensorOp};

fn main() {
    println!("=== Fig 2: operator classification ===");
    for p in fig2_points() {
        println!(
            "  {:<8} parallelism={:>12.1} intensity={:>8.2} -> {:?}",
            p.family, p.parallelism, p.intensity, p.class
        );
    }
    println!();

    // classification is on the coordinator's request path: time it
    let mut rng = Rng::new(1);
    let ops: Vec<TensorOp> = (0..4096)
        .map(|_| {
            TensorOp::PGemm(PGemm::new(
                rng.range_u64(1, 512),
                rng.range_u64(1, 512),
                rng.range_u64(1, 512),
                *rng.choose(&Precision::ALL),
            ))
        })
        .collect();
    bench("fig2/classify_4096_random_ops", || {
        for op in &ops {
            std::hint::black_box(classify(std::hint::black_box(op)));
        }
    });
}
