//! Fig. 5 — dataflow pattern matching: the six coverage cases on the
//! 64-lane / 64×64-array running example, plus pattern-classifier timing.

use gta::report;
use gta::scheduler::pattern::{classify, max_k_segments, ragged_idle_fraction, TileDir};
use gta::sim::systolic::MappedGemm;
use gta::util::bench::bench;
use gta::util::rng::Rng;

fn main() {
    println!("=== Fig 5: dataflow pattern matching (64x64 array) ===");
    for r in report::fig5() {
        println!(
            "  {:<24} mapped {:>4}x{:<5} -> {:<9} max_k_seg={}",
            r.workload, r.mapped.0, r.mapped.1, r.coverage, r.max_k_segments
        );
    }
    println!();

    let mut rng = Rng::new(5);
    let cases: Vec<MappedGemm> = (0..8192)
        .map(|_| MappedGemm {
            rows: rng.range_u64(1, 4096),
            cols: rng.range_u64(1, 4096),
            temporal: rng.range_u64(1, 4096),
        })
        .collect();
    bench("fig5/classify_8192_mappings", || {
        for &g in &cases {
            std::hint::black_box(classify(std::hint::black_box(g), 64, 64));
        }
    });
    bench("fig5/kseg_and_ragged_8192", || {
        for &g in &cases {
            std::hint::black_box(max_k_segments(g, 64, 64));
            std::hint::black_box(ragged_idle_fraction(g, 64, 64, TileDir::Lateral));
        }
    });
}
