//! Fig. 6 — MPRA energy per precision and operating mode, plus energy
//! model throughput.

use gta::arch::energy::{fig6_rows, mpra_mac_pj, total_energy_pj};
use gta::arch::Dataflow;
use gta::precision::Precision;
use gta::util::bench::bench;

fn main() {
    println!("=== Fig 6: MPRA energy per full-array cycle (pJ) ===");
    for r in fig6_rows() {
        println!(
            "  {:<6} WS={:>6.2} OS={:>6.2} SIMD={:>6.2}  (Ara unit {:>6.2})",
            r.precision, r.ws_pj, r.os_pj, r.simd_pj, r.ara_unit_pj
        );
    }
    // the paper's qualitative claims, asserted
    let rows = fig6_rows();
    assert!(rows.windows(2).all(|w| (w[0].ws_pj - w[1].ws_pj).abs() < 1e-9));
    assert!(rows.iter().all(|r| r.os_pj > r.ws_pj && r.simd_pj < r.ws_pj));
    println!("(flat across precision; OS > WS > SIMD — as the paper reports)\n");

    bench("fig6/mac_energy_all_precisions_x1e5", || {
        for _ in 0..100_000 {
            for p in Precision::ALL {
                std::hint::black_box(mpra_mac_pj(p, Dataflow::WS));
            }
        }
    });
    bench("fig6/total_energy_1e6_calls", || {
        for i in 0..1_000_000u64 {
            std::hint::black_box(total_energy_pj(i, Precision::Int8, Dataflow::OS, i * 2, i / 4));
        }
    });
}
