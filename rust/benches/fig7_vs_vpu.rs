//! Fig. 7 — GTA vs the original VPU (Ara): computing-cycle speedup and
//! memory-access saving per Table 2 workload. Paper targets: average
//! 6.45× speedup, 7.76× memory saving.

use gta::report;
use gta::sim::{gta::GtaSim, vpu::VpuSim, Platform};
use gta::util::bench::bench;
use gta::workloads;

fn main() {
    let cmp = report::fig7();
    println!("=== Fig 7: GTA vs VPU (paper avg: 6.45x speed / 7.76x mem) ===");
    print!("{}", report::render_comparison(&cmp));
    // shape checks: GTA must win cycles on every workload, memory on the
    // reuse-bearing ones, with averages in the paper's order of magnitude
    assert!(cmp.rows.iter().all(|r| r.speedup > 1.0), "GTA must win cycles");
    assert!(cmp.avg_speedup > 3.0 && cmp.avg_speedup < 20.0);
    assert!(cmp.avg_mem_saving > 2.0);
    println!();

    // steady-state simulator throughput (schedule cache warm)
    let gta = GtaSim::table1();
    let vpu = VpuSim::default();
    for w in workloads::suite() {
        bench(&format!("fig7/gta/{}", w.name), || {
            std::hint::black_box(gta.run_all(std::hint::black_box(&w.ops)));
        });
        bench(&format!("fig7/vpu/{}", w.name), || {
            std::hint::black_box(vpu.run_all(std::hint::black_box(&w.ops)));
        });
    }
}
