//! Fig. 8 — GTA vs GPGPU (NVIDIA H100) at equal silicon area (§6.3):
//! p-GEMM ops on tensor cores, vector ops on CUDA cores. Paper targets:
//! average 3.39× speedup, 5.35× memory saving (our geomean is the
//! comparable statistic — see EXPERIMENTS.md).

use gta::report;
use gta::sim::{gpgpu::GpgpuSim, Platform};
use gta::util::bench::bench;
use gta::workloads;

fn main() {
    let cmp = report::fig8();
    println!(
        "=== Fig 8: GTA vs GPGPU at equal area ({} GTA lanes; paper avg: 3.39x / 5.35x) ===",
        GpgpuSim::equal_area_gta_lanes()
    );
    print!("{}", report::render_comparison(&cmp));
    assert!(cmp.geomean_speedup > 1.0, "GTA should win overall");
    assert!(cmp.avg_mem_saving > 2.0);
    println!();

    let gpu = GpgpuSim::default();
    for w in workloads::suite() {
        bench(&format!("fig8/gpgpu/{}", w.name), || {
            std::hint::black_box(gpu.run_all(std::hint::black_box(&w.ops)));
        });
    }
}
