//! Fig. 9 — the mixed precision × dataflow scheduling scatter for one
//! Alexnet conv layer, plus scheduler-exploration timing (the §5 search
//! is on the coordinator's request path — its cost matters).

use gta::precision::Precision;
use gta::report;
use gta::util::bench::bench;
use gta::{scheduler, GtaConfig, PGemm};

fn main() {
    println!("=== Fig 9: schedule space (Alexnet conv3, 3 precisions) ===");
    let pts = report::fig9();
    for p in &pts {
        if p.selected {
            println!(
                "  {:<6} selected: {:<4} {:<6} kseg={} (cycles {:.2}x, mem {:.2}x of min)",
                p.precision, p.dataflow, p.arrangement, p.k_segments, p.cycles_ratio, p.mem_ratio
            );
        }
    }
    println!("  {} candidates total across the three precisions", pts.len());
    // the Fig 9 observation: distributions differ nonlinearly by precision
    let spread = |prec: &str| -> f64 {
        pts.iter()
            .filter(|p| p.precision == prec)
            .map(|p| p.cycles_ratio)
            .fold(0.0, f64::max)
    };
    assert!(spread("INT8") != spread("FP32"), "precision must reshape the space");
    println!();

    let gta16 = GtaConfig::lanes16();
    for p in [Precision::Int8, Precision::Fp16, Precision::Fp32] {
        let g = PGemm::new(384, 169, 2304, p);
        bench(&format!("fig9/explore_conv3_{}", p.name()), || {
            std::hint::black_box(scheduler::explore(std::hint::black_box(&g), &gta16));
        });
    }
    // the full schedule (explore + select) at the e2e configs
    for lanes in [4u32, 16, 64] {
        let cfg = GtaConfig::with_lanes(lanes);
        let g = PGemm::new(384, 169, 2304, Precision::Int8);
        bench(&format!("fig9/schedule_{}lanes", lanes), || {
            std::hint::black_box(scheduler::schedule(std::hint::black_box(&g), &cfg));
        });
    }
}
