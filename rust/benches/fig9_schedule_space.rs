//! Fig. 9 — the mixed precision × dataflow scheduling scatter for one
//! Alexnet conv layer, plus scheduler-exploration timing (the §5 search
//! is on the coordinator's request path — its cost matters), plus the
//! multi-operator comparison the parallel explorer exists for: batch
//! scheduling across the worker pool vs the sequential sweep.

use gta::precision::Precision;
use gta::report;
use gta::scheduler::explorer;
use gta::util::bench::bench;
use gta::{scheduler, GtaConfig, PGemm};
use std::time::{Duration, Instant};

fn main() {
    println!("=== Fig 9: schedule space (Alexnet conv3, 3 precisions) ===");
    let pts = report::fig9();
    for p in &pts {
        if p.selected {
            println!(
                "  {:<6} selected: {:<4} {:<6} kseg={} (cycles {:.2}x, mem {:.2}x of min)",
                p.precision, p.dataflow, p.arrangement, p.k_segments, p.cycles_ratio, p.mem_ratio
            );
        }
    }
    println!("  {} candidates total across the three precisions", pts.len());
    // the Fig 9 observation: distributions differ nonlinearly by precision
    let spread = |prec: &str| -> f64 {
        pts.iter()
            .filter(|p| p.precision == prec)
            .map(|p| p.cycles_ratio)
            .fold(0.0, f64::max)
    };
    assert!(spread("INT8") != spread("FP32"), "precision must reshape the space");
    println!();

    let gta16 = GtaConfig::lanes16();
    for p in [Precision::Int8, Precision::Fp16, Precision::Fp32] {
        let g = PGemm::new(384, 169, 2304, p);
        bench(&format!("fig9/explore_conv3_{}", p.name()), || {
            std::hint::black_box(scheduler::explore(std::hint::black_box(&g), &gta16));
        });
    }
    // the full schedule (explore + select) at the e2e configs
    for lanes in [4u32, 16, 64] {
        let cfg = GtaConfig::with_lanes(lanes);
        let g = PGemm::new(384, 169, 2304, Precision::Int8);
        bench(&format!("fig9/schedule_{}lanes", lanes), || {
            std::hint::black_box(scheduler::schedule(std::hint::black_box(&g), &cfg));
        });
    }
    println!();

    // ---- multi-operator workload: parallel batch vs sequential sweep ----
    // Distinct shapes only, and a fresh explorer per run, so the timing
    // isolates worker-pool concurrency rather than memo hits.
    let ops = distinct_multi_op_workload();
    let workers = explorer::default_workers();
    println!(
        "=== batch exploration: {} distinct operators, {} workers ===",
        ops.len(),
        workers
    );

    let t_seq = best_of(3, || {
        for g in &ops {
            std::hint::black_box(scheduler::schedule(std::hint::black_box(g), &gta16));
        }
    });
    let t_par = best_of(3, || {
        let ex = explorer::Explorer::new();
        std::hint::black_box(ex.schedule_batch(std::hint::black_box(&ops), &gta16, workers));
    });
    println!("  sequential sweep : {t_seq:>12?}");
    println!(
        "  parallel batch   : {t_par:>12?}  ({:.2}x)",
        t_seq.as_secs_f64() / t_par.as_secs_f64().max(1e-12)
    );

    // determinism: the parallel batch must select the exact schedules the
    // sequential sweep selects, operator for operator
    let batch = scheduler::schedule_batch(&ops, &gta16);
    for (g, cand) in ops.iter().zip(&batch) {
        let seq = scheduler::schedule(g, &gta16);
        assert_eq!(cand.config, seq.config, "batch diverged on {g:?}");
        assert_eq!(cand.report, seq.report);
    }
    println!("  determinism: {} batch selections identical to sequential", batch.len());

    // The wall-clock claim needs real parallel headroom to be a stable
    // assertion; on 1-2 core (or heavily loaded) machines just report.
    if workers >= 4 {
        assert!(
            t_par < t_seq,
            "parallel explorer must beat the sequential sweep on a multi-op \
             workload ({t_par:?} vs {t_seq:?}, {workers} workers)"
        );
    } else {
        println!("  ({workers} workers: reporting only, wall-clock assertion needs >=4)");
    }
}

/// ~200 distinct p-GEMM shapes spanning the Table 2 suite's range of
/// aspect ratios and precisions (deterministic, duplicates removed).
fn distinct_multi_op_workload() -> Vec<PGemm> {
    let mut seen = std::collections::HashSet::new();
    let mut ops = Vec::new();
    let precisions = [Precision::Int8, Precision::Bp16, Precision::Fp32, Precision::Int32];
    let ms = [8u64, 24, 64, 96, 169, 256, 384, 512];
    let ns = [13 * 13, 27 * 27, 48, 169, 512];
    let ks = [64u64, 576, 1152, 2304];
    for (i, &m) in ms.iter().enumerate() {
        for (j, &n) in ns.iter().enumerate() {
            for (l, &k) in ks.iter().enumerate() {
                let p = precisions[(i + j + l) % precisions.len()];
                let g = PGemm::new(m, n, k, p);
                if seen.insert(g) {
                    ops.push(g);
                }
            }
        }
    }
    ops
}

/// Minimum wall time of `n` runs of `f` (steadier than a single sample).
fn best_of(n: u32, mut f: impl FnMut()) -> Duration {
    let mut best = Duration::MAX;
    for _ in 0..n {
        let t = Instant::now();
        f();
        best = best.min(t.elapsed());
    }
    best
}
