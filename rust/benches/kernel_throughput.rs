//! Kernel throughput: the plane-decomposed limb kernels vs the scalar
//! §3.1 oracle, and the parallel `execute_batch` fan-out vs serial
//! execution — the first kernel-level baseline of the BENCH_*.json
//! trajectory (see ROADMAP "Perf-trajectory harness").
//!
//! Three legs, all on pinned seeds so reruns measure the same work:
//!
//! 1. **Per-artifact tile cost** — each serve-path artifact executed
//!    through `SoftBackend` (plane kernels + thread-local workspace)
//!    against the pre-plane scalar path (whole-matrix i32→i64 widening +
//!    `limb_gemm`, which re-decomposes both scalars per MAC). Outputs are
//!    compared bit-for-bit before anything is timed.
//! 2. **Bignum pre-carry** — the allocation-free workspace variant vs the
//!    naive per-call-allocating oracle.
//! 3. **Batch scaling** — `execute_batch` (scoped worker fan-out) vs the
//!    same items executed one at a time, per batch size.
//!
//! Prints human-readable lines and writes machine-readable
//! **`BENCH_kernels.json`** to the working directory (committed as
//! `rust/BENCH_kernels.json`, the tracked baseline). Schema
//! (`"schema": "gta.bench.kernels/1"`):
//!
//! ```json
//! {
//!   "schema": "gta.bench.kernels/1", // bump on layout changes
//!   "seed": 2024,                    // operand-generation seed
//!   "provisional": false,            // true only in the placeholder
//!   "tiles": [
//!     {"artifact": "mpra_gemm_i8_64", "n_limbs": 1,
//!      "oracle_ns_per_tile": 0, "plane_ns_per_tile": 0, "speedup": 0},
//!     ...
//!   ],
//!   "batch": [
//!     {"batch": 1, "serial_ns_per_item": 0,
//!      "parallel_ns_per_item": 0, "speedup": 0},
//!     ...
//!   ]
//! }
//! ```
//!
//! Gate: the plane path must be **≥ 10x** the scalar-oracle path on the
//! 64×64 i8 tile (the serve path's dominant artifact); the batch legs
//! are recorded but not gated (CI machines have unpredictable core
//! counts).

use gta::precision::limbs;
use gta::runtime::{ExecBackend, HostTensor, SoftBackend};
use gta::util::bench::bench_with_budget;
use gta::util::json::Json;
use gta::util::rng::Rng;
use std::hint::black_box;
use std::time::Duration;

const SEED: u64 = 2024;
const DIM: usize = 64;
const BUDGET: Duration = Duration::from_millis(300);

fn obj(fields: Vec<(&str, Json)>) -> Json {
    Json::Obj(fields.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
}

/// One 64×64 operand tile with entries uniform in `[lo, hi]`.
fn tile(rng: &mut Rng, lo: i64, hi: i64) -> Vec<i32> {
    (0..DIM * DIM).map(|_| rng.range_i64(lo, hi) as i32).collect()
}

/// The pre-plane SoftBackend tile path, kept verbatim as the measured
/// oracle: widen both operands, run the scalar limb GEMM (which
/// re-decomposes per MAC), narrow the result.
fn oracle_tile(a: &[i32], b: &[i32], n_limbs: u32) -> Vec<i32> {
    let a64: Vec<i64> = a.iter().map(|&v| v as i64).collect();
    let b64: Vec<i64> = b.iter().map(|&v| v as i64).collect();
    limbs::limb_gemm(&a64, &b64, DIM, DIM, DIM, n_limbs, 32)
        .iter()
        .map(|&v| v as i32)
        .collect()
}

fn main() {
    let be = SoftBackend;
    let mut rng = Rng::new(SEED);
    println!(
        "kernel throughput: plane kernels vs scalar oracle, {DIM}x{DIM} tiles, seed {SEED}\n"
    );

    // ---- leg 1: per-artifact tile cost --------------------------------
    let mut tiles_json = Vec::new();
    let mut i8_speedup = 0.0;
    for &(artifact, n_limbs, lo, hi) in &[
        ("mpra_gemm_i8_64", 1u32, -128i64, 127i64),
        ("mpra_gemm_i16_64", 2, -32768, 32767),
    ] {
        let a = tile(&mut rng, lo, hi);
        let b = tile(&mut rng, lo, hi);
        let inputs = vec![HostTensor::I32(a.clone()), HostTensor::I32(b.clone())];
        // bit-identity first: a fast wrong kernel is worthless
        let want = oracle_tile(&a, &b, n_limbs);
        let got = be.execute(artifact, &inputs).expect("soft backend executes its own tile");
        assert_eq!(
            got[0].as_i32().expect("i32 tile out"),
            want.as_slice(),
            "{artifact}: plane path diverged from the scalar oracle"
        );

        let oracle = bench_with_budget(&format!("{artifact} scalar oracle"), BUDGET, &mut || {
            black_box(oracle_tile(black_box(&a), black_box(&b), n_limbs));
        });
        let plane = bench_with_budget(&format!("{artifact} plane kernel"), BUDGET, &mut || {
            black_box(be.execute(artifact, black_box(&inputs)).unwrap());
        });
        let oracle_ns = oracle.median.as_nanos() as f64;
        let plane_ns = plane.median.as_nanos() as f64;
        let speedup = oracle_ns / plane_ns;
        println!("  -> {artifact}: {speedup:.1}x over the scalar oracle\n");
        if artifact == "mpra_gemm_i8_64" {
            i8_speedup = speedup;
        }
        tiles_json.push(obj(vec![
            ("artifact", Json::Str(artifact.to_string())),
            ("n_limbs", Json::Num(n_limbs as f64)),
            ("oracle_ns_per_tile", Json::Num(oracle_ns)),
            ("plane_ns_per_tile", Json::Num(plane_ns)),
            ("speedup", Json::Num(speedup)),
        ]));
    }

    // ---- leg 2: bignum pre-carry --------------------------------------
    {
        let a: Vec<i32> = (0..DIM).map(|_| rng.range_i64(0, 255) as i32).collect();
        let b: Vec<i32> = (0..DIM).map(|_| rng.range_i64(0, 255) as i32).collect();
        let a8: Vec<u8> = a.iter().map(|&v| v as u8).collect();
        let b8: Vec<u8> = b.iter().map(|&v| v as u8).collect();
        let inputs = vec![HostTensor::I32(a), HostTensor::I32(b)];
        let want = limbs::bignum_mul_precarry(&a8, &b8);
        let got = be.execute("bignum_mul_64", &inputs).unwrap();
        assert_eq!(
            got[0].as_i32().unwrap().iter().map(|&v| v as i64).collect::<Vec<i64>>(),
            want,
            "bignum fast path diverged from the naive oracle"
        );

        let naive = bench_with_budget("bignum_mul_64 naive oracle", BUDGET, &mut || {
            black_box(limbs::bignum_mul_precarry(black_box(&a8), black_box(&b8)));
        });
        let fast = bench_with_budget("bignum_mul_64 workspace", BUDGET, &mut || {
            black_box(be.execute("bignum_mul_64", black_box(&inputs)).unwrap());
        });
        let naive_ns = naive.median.as_nanos() as f64;
        let fast_ns = fast.median.as_nanos() as f64;
        let speedup = naive_ns / fast_ns;
        println!("  -> bignum_mul_64: {speedup:.1}x over the naive oracle\n");
        tiles_json.push(obj(vec![
            ("artifact", Json::Str("bignum_mul_64".to_string())),
            ("n_limbs", Json::Num(64.0)),
            ("oracle_ns_per_tile", Json::Num(naive_ns)),
            ("plane_ns_per_tile", Json::Num(fast_ns)),
            ("speedup", Json::Num(speedup)),
        ]));
    }

    // ---- leg 3: batch scaling -----------------------------------------
    let mut batch_json = Vec::new();
    for &size in &[1usize, 2, 4, 8, 16] {
        let batch: Vec<Vec<HostTensor>> = (0..size)
            .map(|_| {
                vec![
                    HostTensor::I32(tile(&mut rng, -128, 127)),
                    HostTensor::I32(tile(&mut rng, -128, 127)),
                ]
            })
            .collect();
        // parallel fan-out must be bit-identical to serial execution
        let serial_out: Vec<_> =
            batch.iter().map(|i| be.execute("mpra_gemm_i8_64", i).unwrap()).collect();
        let parallel_out = be.execute_batch("mpra_gemm_i8_64", &batch);
        for (s, p) in serial_out.iter().zip(&parallel_out) {
            assert_eq!(s, p.as_ref().unwrap(), "batch={size}: parallel diverged from serial");
        }

        let serial = bench_with_budget(&format!("batch={size:<2} serial"), BUDGET, &mut || {
            for inputs in &batch {
                black_box(be.execute("mpra_gemm_i8_64", black_box(inputs)).unwrap());
            }
        });
        let parallel = bench_with_budget(&format!("batch={size:<2} parallel"), BUDGET, &mut || {
            black_box(be.execute_batch("mpra_gemm_i8_64", black_box(&batch)));
        });
        let serial_ns = serial.median.as_nanos() as f64 / size as f64;
        let parallel_ns = parallel.median.as_nanos() as f64 / size as f64;
        let speedup = serial_ns / parallel_ns;
        println!("  -> batch {size}: {speedup:.2}x over serial\n");
        batch_json.push(obj(vec![
            ("batch", Json::Num(size as f64)),
            ("serial_ns_per_item", Json::Num(serial_ns)),
            ("parallel_ns_per_item", Json::Num(parallel_ns)),
            ("speedup", Json::Num(speedup)),
        ]));
    }

    // ---- report + gate ------------------------------------------------
    let report = obj(vec![
        ("schema", Json::Str("gta.bench.kernels/1".to_string())),
        ("seed", Json::Num(SEED as f64)),
        ("provisional", Json::Bool(false)),
        ("tiles", Json::Arr(tiles_json)),
        ("batch", Json::Arr(batch_json)),
    ]);
    std::fs::write("BENCH_kernels.json", report.render() + "\n")
        .expect("writing BENCH_kernels.json");
    println!("wrote BENCH_kernels.json");

    assert!(
        i8_speedup >= 10.0,
        "plane kernel must be >= 10x the scalar oracle on mpra_gemm_i8_64, got {i8_speedup:.1}x"
    );
    println!("kernel gate passed: mpra_gemm_i8_64 plane path {i8_speedup:.1}x >= 10x");
}
