//! Wire-protocol and connection-scaling cost: v1 (JSON tensor bodies)
//! vs v2 (zero-copy binary tensor frames), the full loopback-TCP
//! replay under every protocol version and both server architectures
//! (threaded `NetServer`, event-loop `EventServer`) against the
//! in-process path, and session-multiplexing scaling. What to look
//! for:
//!
//! * the frame-codec microbench prints encode+decode time and wire
//!   bytes per dtype — the v2 acceptance targets (large f32 tensors
//!   ≥10x faster to encode+decode, ≥5x smaller on the wire) are
//!   asserted, the i32/i64 ratios are informational;
//! * the replay section serves the SAME seeded open-loop workload
//!   in-process, over TCP at v1 (forced), at v2, and over the
//!   event-loop server at v3 — all four verify identically (the wire
//!   changes the transport, not the answers), and the per-request
//!   overhead of each path is printed side by side;
//! * the mux section replays a fixed workload sliced across K logical
//!   sessions on one event-loop connection (K = 1, 8, 64) — the
//!   per-session overhead of v3 multiplexing.
//!
//! ```bash
//! cargo bench --bench net_throughput
//! ```
//!
//! Besides the human-readable report, the run writes a
//! machine-readable **`BENCH_net.json`** to the working directory
//! (committed as `rust/BENCH_net.json`, the tracked baseline). Schema
//! (`"schema": "gta.bench.net/1"`):
//!
//! ```json
//! {
//!   "schema": "gta.bench.net/1",   // bump on layout changes
//!   "seed": 2024,                  // the open-loop arrival seed
//!   "provisional": false,          // true = placeholder, numbers not
//!                                  //   from a real run of this tree
//!   "codec": [                     // one row per dtype
//!     {"dtype": "f32", "v1_wire_bytes": 0, "v2_wire_bytes": 0,
//!      "encdec_speedup": 0.0, "wire_bytes_ratio": 0.0}],
//!   "replay": [                    // one row per offered rate
//!     {"rate_rps": 0.0, "in_process_rps": 0.0, "v1_rps": 0.0,
//!      "v2_rps": 0.0, "event_loop_v3_rps": 0.0}],
//!   "mux": [                       // one row per session count
//!     {"sessions": 1, "requests": 0, "throughput_rps": 0.0}]
//! }
//! ```
//!
//! Counts and byte totals are exact and reproducible (seeded workload,
//! deterministic codecs); the `*_rps`/`*_speedup` fields are wall-time
//! measurements and vary with the machine — compare trends, not
//! digits.

use gta::coordinator::rack::policy_by_name;
use gta::coordinator::{CoalesceConfig, ExecKind, Request, Response, ServeOptions};
use gta::net::proto::{self, Frame, FrameType};
use gta::net::{EventServer, NetServer};
use gta::ops::TensorOp;
use gta::precision::Precision;
use gta::runtime::HostTensor;
use gta::serve::{
    mixed_stream, run_client_mux, run_open_loop_client, run_open_loop_client_proto,
    run_open_loop_stream, shard_configs, soft_rack,
};
use gta::sim::SimReport;
use gta::util::json::Json;
use std::sync::Arc;
use std::time::{Duration, Instant};

const ELEMS: usize = 65_536;
const ITERS: u32 = 5;

/// f32 payload with full mantissas spread across negative decimal
/// exponents — representative of real activation tensors, and the
/// worst case for the v1 JSON path (each element renders as ~17
/// significant digits plus leading zeros when promoted to f64).
fn f32_payload(n: usize) -> Vec<f32> {
    (0..n)
        .map(|i| {
            let mant = (i as u32).wrapping_mul(2_654_435_761) & 0x007f_ffff;
            let v = f32::from_bits(0x3f80_0000 | mant); // [1, 2)
            let scaled = v * 10f32.powi(-((i % 7) as i32));
            if i % 2 == 0 {
                scaled
            } else {
                -scaled
            }
        })
        .collect()
}

fn request_for(t: &HostTensor) -> Request {
    Request {
        id: 7,
        op: TensorOp::gemm(256, 256, 256, Precision::Fp32),
        exec: ExecKind::Functional {
            artifact: "bench_tensor_frames".to_string(),
            inputs: vec![t.clone(), t.clone()],
        },
    }
}

fn response_for(t: &HostTensor) -> Response {
    Response {
        id: 7,
        shard: 0,
        schedule: None,
        sim: SimReport { cycles: 123_456, freq_mhz: 1000, ..SimReport::default() },
        outputs: Some(vec![t.clone()]),
        error: None,
        latency: Duration::from_micros(250),
    }
}

struct CodecCost {
    encode_s: f64,
    decode_s: f64,
    wire_bytes: usize,
}

/// Encode one request + one response as full frames `ITERS` times,
/// then decode them back; `sink` defeats dead-code elimination.
fn measure<E, D>(mut encode: E, mut decode: D) -> CodecCost
where
    E: FnMut(&mut Vec<u8>),
    D: FnMut(&[u8]) -> usize,
{
    let mut buf = Vec::new();
    let t0 = Instant::now();
    for _ in 0..ITERS {
        buf.clear();
        encode(&mut buf);
    }
    let encode_s = t0.elapsed().as_secs_f64();
    let wire_bytes = buf.len();
    let mut sink = 0usize;
    let t0 = Instant::now();
    for _ in 0..ITERS {
        sink += decode(&buf);
    }
    let decode_s = t0.elapsed().as_secs_f64();
    assert_eq!(sink, ITERS as usize * 3 * ELEMS, "decoded tensors kept every element");
    CodecCost { encode_s, decode_s, wire_bytes }
}

fn decoded_elems(req: &Request, resp: &Response) -> usize {
    let ins: usize = match &req.exec {
        ExecKind::Functional { inputs, .. } => inputs.iter().map(HostTensor::len).sum(),
        ExecKind::Simulate => 0,
    };
    let outs: usize = resp.outputs.as_ref().map_or(0, |o| o.iter().map(HostTensor::len).sum());
    ins + outs
}

fn codec_comparison(name: &str, t: HostTensor) -> CodecRow {
    let req = request_for(&t);
    let resp = response_for(&t);

    let v1 = measure(
        |buf| {
            proto::write_frame(buf, &Frame::new(FrameType::Submit, 7, proto::encode_request(&req)))
                .unwrap();
            proto::write_frame(
                buf,
                &Frame::new(FrameType::Response, 7, proto::encode_response(&resp)),
            )
            .unwrap();
        },
        |bytes| {
            let mut r = bytes;
            let f1 = proto::read_frame(&mut r).unwrap();
            let rq = proto::decode_request(&f1.body).unwrap();
            let f2 = proto::read_frame(&mut r).unwrap();
            let rs = proto::decode_response(&f2.body).unwrap();
            decoded_elems(&rq, &rs)
        },
    );
    let v2 = measure(
        |buf| {
            proto::write_frame(
                buf,
                &Frame::binary(FrameType::SubmitBin, 7, proto::encode_request_bin(&req)),
            )
            .unwrap();
            proto::write_frame(
                buf,
                &Frame::binary(FrameType::ResponseBin, 7, proto::encode_response_bin(&resp)),
            )
            .unwrap();
        },
        |bytes| {
            let mut r = bytes;
            let f1 = proto::read_frame(&mut r).unwrap();
            let rq = proto::decode_request_bin(f1.id, &f1.bin).unwrap();
            let f2 = proto::read_frame(&mut r).unwrap();
            let rs = proto::decode_response_bin(&f2.bin).unwrap();
            decoded_elems(&rq, &rs)
        },
    );

    let speed = (v1.encode_s + v1.decode_s) / (v2.encode_s + v2.decode_s);
    let bytes = v1.wire_bytes as f64 / v2.wire_bytes as f64;
    println!(
        "  {name:<4} v1 {:>9.2}ms enc {:>9.2}ms dec {:>10} B | v2 {:>7.2}ms enc {:>7.2}ms dec \
         {:>9} B | enc+dec {speed:>5.1}x  bytes {bytes:>4.2}x",
        v1.encode_s * 1e3 / ITERS as f64,
        v1.decode_s * 1e3 / ITERS as f64,
        v1.wire_bytes,
        v2.encode_s * 1e3 / ITERS as f64,
        v2.decode_s * 1e3 / ITERS as f64,
        v2.wire_bytes,
    );
    CodecRow { dtype: name.to_string(), speed, bytes, v1_wire: v1.wire_bytes, v2_wire: v2.wire_bytes }
}

struct CodecRow {
    dtype: String,
    speed: f64,
    bytes: f64,
    v1_wire: usize,
    v2_wire: usize,
}

fn obj(fields: Vec<(&str, Json)>) -> Json {
    Json::Obj(fields.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
}

fn main() {
    println!(
        "frame codec: one Submit (2 x {ELEMS}-elem inputs) + one Response \
         (1 x {ELEMS}-elem output), v1 JSON vs v2 binary, averaged over {ITERS} iters\n"
    );
    let i32s: Vec<i32> = (0..ELEMS).map(|i| (i as i32).wrapping_mul(-1_640_531_527)).collect();
    let i64s: Vec<i64> =
        (0..ELEMS).map(|i| (i as i64).wrapping_mul(-7_046_029_254_386_353_131)).collect();
    let mut codec_rows = vec![
        codec_comparison("i32", HostTensor::I32(i32s)),
        codec_comparison("i64", HostTensor::I64(i64s)),
        codec_comparison("f32", HostTensor::F32(f32_payload(ELEMS))),
    ];
    let f32_row = codec_rows.last().expect("f32 row");
    let (speed, bytes) = (f32_row.speed, f32_row.bytes);
    assert!(
        speed >= 10.0,
        "v2 target: large-tensor encode+decode >=10x faster than v1, got {speed:.1}x"
    );
    assert!(bytes >= 5.0, "v2 target: wire bytes >=5x smaller than v1, got {bytes:.2}x");
    println!(
        "\nv2 targets met on the f32 large-tensor frames: {speed:.1}x encode+decode \
         (>=10x required), {bytes:.2}x wire bytes (>=5x required)\n"
    );

    let n = 256u64;
    let workers = 4usize;
    let seed = 2024u64;
    let mk_rack = || {
        soft_rack(
            shard_configs(2, &[]),
            CoalesceConfig::with_adaptive_window(),
            policy_by_name("rr").expect("rr is a known policy"),
        )
        .expect("soft rack builds offline")
    };
    println!(
        "open-loop transport comparison: {n} mixed requests, 2-shard soft rack, \
         {workers} workers, seeded Poisson arrivals\n"
    );
    let mut replay_rows = Vec::new();
    for rate in [2_000.0f64, 20_000.0] {
        let local_rack = mk_rack();
        let (reqs, expected) = mixed_stream(n);
        let local = run_open_loop_stream(&local_rack, reqs, &expected, workers, rate, seed);

        let mut wire = Vec::new();
        for proto_version in [1u64, 2] {
            let served = mk_rack();
            let mut server = NetServer::spawn(
                Arc::clone(&served),
                "127.0.0.1:0",
                ServeOptions::with_workers(workers),
            )
            .expect("loopback bind");
            let summary =
                run_open_loop_client_proto(&server.addr().to_string(), n, rate, seed, proto_version)
                    .expect("loopback replay");
            server.shutdown();
            wire.push((proto_version, summary));
        }

        // the same workload through the event-loop server at v3
        let served = mk_rack();
        let mut ev =
            EventServer::spawn(Arc::clone(&served), "127.0.0.1:0", ServeOptions::with_workers(workers))
                .expect("loopback bind");
        let ev_summary = run_open_loop_client(&ev.addr().to_string(), n, rate, seed)
            .expect("event-loop replay");
        ev.shutdown();

        for (name, s) in [
            ("in-process".to_string(), &local),
            (format!("loopback v{}", wire[0].0), &wire[0].1),
            (format!("loopback v{}", wire[1].0), &wire[1].1),
            ("event loop v3".to_string(), &ev_summary),
        ] {
            assert_eq!(s.requests, n, "{name}: one response per request");
            assert_eq!(s.errors, 0, "{name}");
            assert_eq!(s.verified_failed, 0, "{name}: numerics stay exact");
            assert_eq!(
                s.verified_ok, local.verified_ok,
                "{name}: the wire changes the transport, not the answers"
            );
        }

        let us = |s: &gta::serve::ServeSummary| (s.wall_seconds - local.wall_seconds) * 1e6 / n as f64;
        println!(
            "offered {rate:>8.0} req/s: in-process {:>8.1} req/s  v1 {:>8.1} req/s \
             ({:>+7.1} us/req)  v2 {:>8.1} req/s ({:>+7.1} us/req)  ev-loop v3 {:>8.1} req/s \
             ({:>+7.1} us/req)",
            local.throughput_rps,
            wire[0].1.throughput_rps,
            us(&wire[0].1),
            wire[1].1.throughput_rps,
            us(&wire[1].1),
            ev_summary.throughput_rps,
            us(&ev_summary),
        );
        replay_rows.push(obj(vec![
            ("rate_rps", Json::Num(rate)),
            ("in_process_rps", Json::Num(local.throughput_rps)),
            ("v1_rps", Json::Num(wire[0].1.throughput_rps)),
            ("v2_rps", Json::Num(wire[1].1.throughput_rps)),
            ("event_loop_v3_rps", Json::Num(ev_summary.throughput_rps)),
        ]));
    }

    // session-multiplexing scaling: the same workload sliced across K
    // logical sessions on ONE event-loop connection
    println!("\nsession multiplexing: {n} mixed requests over one connection, K sessions\n");
    let served = mk_rack();
    let mut ev =
        EventServer::spawn(served, "127.0.0.1:0", ServeOptions::with_workers(workers))
            .expect("loopback bind");
    let mut mux_rows = Vec::new();
    for sessions in [1u32, 8, 64] {
        let s = run_client_mux(&ev.addr().to_string(), n, sessions).expect("mux replay");
        assert_eq!(s.requests, n, "K={sessions}: one response per request");
        assert_eq!(s.errors, 0, "K={sessions}");
        assert_eq!(s.verified_failed, 0, "K={sessions}: slicing changes nothing");
        println!("  K={sessions:<3} {:>8.1} req/s", s.throughput_rps);
        mux_rows.push(obj(vec![
            ("sessions", Json::Num(sessions as f64)),
            ("requests", Json::Num(n as f64)),
            ("throughput_rps", Json::Num(s.throughput_rps)),
        ]));
    }
    ev.shutdown();

    // the machine-readable baseline (schema in the module docs)
    let report = obj(vec![
        ("schema", Json::Str("gta.bench.net/1".to_string())),
        ("seed", Json::Num(seed as f64)),
        ("provisional", Json::Bool(false)),
        (
            "codec",
            Json::Arr(
                codec_rows
                    .drain(..)
                    .map(|r| {
                        obj(vec![
                            ("dtype", Json::Str(r.dtype)),
                            ("v1_wire_bytes", Json::Num(r.v1_wire as f64)),
                            ("v2_wire_bytes", Json::Num(r.v2_wire as f64)),
                            ("encdec_speedup", Json::Num(r.speed)),
                            ("wire_bytes_ratio", Json::Num(r.bytes)),
                        ])
                    })
                    .collect(),
            ),
        ),
        ("replay", Json::Arr(replay_rows)),
        ("mux", Json::Arr(mux_rows)),
    ]);
    std::fs::write("BENCH_net.json", report.render() + "\n").expect("write BENCH_net.json");
    println!(
        "\nnet throughput OK: v1, v2 and event-loop v3 wire paths verified against the \
         in-process path; machine-readable baseline written to BENCH_net.json"
    );
}
