//! Transport overhead: the SAME seeded open-loop workload replayed (a)
//! through an in-process `RackSession` and (b) through a loopback TCP
//! `NetServer`/`GtaClient` pair, at the same arrival rate. What to look
//! for:
//!
//! * both paths serve every request with zero errors and identical
//!   verification counts (the wire changes the transport, not the
//!   answers);
//! * the per-request overhead of framing + JSON + loopback TCP, printed
//!   as µs/request — the price of leaving the process.
//!
//! ```bash
//! cargo bench --bench net_throughput
//! ```

use gta::coordinator::rack::policy_by_name;
use gta::coordinator::{CoalesceConfig, ServeOptions};
use gta::net::NetServer;
use gta::serve::{
    mixed_stream, run_open_loop_client, run_open_loop_stream, shard_configs, soft_rack,
};
use std::sync::Arc;

fn main() {
    let n = 256u64;
    let workers = 4usize;
    let seed = 2024u64;
    println!(
        "open-loop transport comparison: {n} mixed requests, 2-shard soft rack, \
         {workers} workers, seeded Poisson arrivals\n"
    );
    for rate in [2_000.0f64, 20_000.0] {
        let mk_rack = || {
            soft_rack(
                shard_configs(2, &[]),
                CoalesceConfig::with_adaptive_window(),
                policy_by_name("rr").expect("rr is a known policy"),
            )
            .expect("soft rack builds offline")
        };

        let local_rack = mk_rack();
        let (reqs, expected) = mixed_stream(n);
        let local = run_open_loop_stream(&local_rack, reqs, &expected, workers, rate, seed);

        let served = mk_rack();
        let mut server = NetServer::spawn(
            Arc::clone(&served),
            "127.0.0.1:0",
            ServeOptions::with_workers(workers),
        )
        .expect("loopback bind");
        let wire = run_open_loop_client(&server.addr().to_string(), n, rate, seed)
            .expect("loopback replay");
        server.shutdown();

        for (name, s) in [("in-process", &local), ("loopback TCP", &wire)] {
            assert_eq!(s.requests, n, "{name}: one response per request");
            assert_eq!(s.errors, 0, "{name}");
            assert_eq!(s.verified_failed, 0, "{name}: numerics stay exact");
        }
        assert_eq!(
            wire.verified_ok, local.verified_ok,
            "the wire changes the transport, not the answers"
        );

        let overhead_us =
            (wire.wall_seconds - local.wall_seconds) * 1e6 / n as f64;
        println!(
            "offered {rate:>8.0} req/s: in-process {:>8.1} req/s  loopback {:>8.1} req/s  \
             (overhead {overhead_us:>+7.1} us/req)",
            local.throughput_rps, wire.throughput_rps,
        );
    }
    println!("\nnet throughput OK: wire path verified against the in-process path");
}
