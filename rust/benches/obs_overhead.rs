//! Observability overhead: what the span-tracing layer costs the
//! serving hot path when it is OFF (the always-paid price) and when it
//! is ON — the gate the obs subsystem ships under (see
//! `docs/observability.md`).
//!
//! Three legs, pinned seed, deterministic shapes:
//!
//! 1. **Disabled emit** — `obs::emit` with tracing off is one atomic
//!    load + branch; measured per call.
//! 2. **Enabled emit** — the full seqlock ring push, measured per call.
//! 3. **Serve baseline** — the seeded mixed stream through the
//!    soft-backend rack (tracing off), giving the per-request latency
//!    the emit cost is compared against.
//!
//! Prints human-readable lines and writes machine-readable
//! **`BENCH_obs.json`** (committed as `rust/BENCH_obs.json`). Schema
//! (`"schema": "gta.bench.obs/1"`):
//!
//! ```json
//! {
//!   "schema": "gta.bench.obs/1",
//!   "seed": 2024,
//!   "provisional": false,
//!   "emit_disabled_ns": 0,
//!   "emit_enabled_ns": 0,
//!   "hist_record_ns": 0,
//!   "serve_ns_per_request": 0,
//!   "emits_per_request": 8,
//!   "disabled_overhead_pct": 0
//! }
//! ```
//!
//! Gate: the disabled-tracing cost — `emit_disabled_ns` ×
//! `emits_per_request`, the whole price a non-tracing run pays — must
//! stay under **1%** of the measured per-request serve latency.

use gta::obs::{self, Histogram, SpanEvent, Stage};
use gta::util::bench::bench_with_budget;
use gta::util::json::Json;
use std::hint::black_box;
use std::time::Duration;

const SEED: u64 = 2024;
const BUDGET: Duration = Duration::from_millis(300);
/// Inner repetitions per timed closure call (amortizes timer overhead).
const INNER: u64 = 1024;
/// Span emissions per verified request on the traced serve path:
/// admit + route + schedule + coalesce + execute + respond, plus the
/// sweep and net spans a worst-case request adds.
const EMITS_PER_REQUEST: f64 = 8.0;

fn obj(fields: Vec<(&str, Json)>) -> Json {
    Json::Obj(fields.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
}

fn main() {
    println!("obs overhead: span emit vs the serve hot path, seed {SEED}\n");
    let ev = SpanEvent {
        trace_id: 7,
        stage: Stage::Execute,
        shard: 0,
        start_us: 1,
        dur_us: 2,
        extra: 3,
    };

    // ---- leg 1: emit with tracing OFF (one load + branch) -------------
    obs::reset();
    obs::set_enabled(false);
    let disabled = bench_with_budget("emit (tracing off)", BUDGET, &mut || {
        for _ in 0..INNER {
            obs::emit(black_box(&ev));
        }
    });

    // ---- leg 2: emit with tracing ON (seqlock ring push) --------------
    obs::set_enabled(true);
    let enabled = bench_with_budget("emit (tracing on)", BUDGET, &mut || {
        for _ in 0..INNER {
            obs::emit(black_box(&ev));
        }
    });
    obs::set_enabled(false);
    obs::reset();

    // informational: the always-on per-stage histogram record
    let mut h = Histogram::new();
    let hist = bench_with_budget("histogram record", BUDGET, &mut || {
        for i in 0..INNER {
            h.record(black_box(i));
        }
    });
    black_box(h.count());

    let disabled_ns = disabled.median.as_nanos() as f64 / INNER as f64;
    let enabled_ns = enabled.median.as_nanos() as f64 / INNER as f64;
    let hist_ns = hist.median.as_nanos() as f64 / INNER as f64;
    println!(
        "  -> emit: {disabled_ns:.2} ns/call off, {enabled_ns:.2} ns/call on; \
         histogram record {hist_ns:.2} ns/call\n"
    );

    // ---- leg 3: the serve path itself (tracing off) -------------------
    let summary = gta::serve::run_mixed_stream_soft_rack(256, 4, 2, &[], "least")
        .expect("soft-backend rack serve");
    let ns_per_request = summary.wall_seconds * 1e9 / summary.requests.max(1) as f64;
    let overhead_pct = disabled_ns * EMITS_PER_REQUEST / ns_per_request * 100.0;
    println!(
        "  -> serve: {:.0} ns/request over {} request(s); disabled tracing adds \
         {EMITS_PER_REQUEST} x {disabled_ns:.2} ns = {overhead_pct:.4}%\n",
        ns_per_request, summary.requests
    );

    // ---- report + gate ------------------------------------------------
    let report = obj(vec![
        ("schema", Json::Str("gta.bench.obs/1".to_string())),
        ("seed", Json::Num(SEED as f64)),
        ("provisional", Json::Bool(false)),
        ("emit_disabled_ns", Json::Num(disabled_ns)),
        ("emit_enabled_ns", Json::Num(enabled_ns)),
        ("hist_record_ns", Json::Num(hist_ns)),
        ("serve_ns_per_request", Json::Num(ns_per_request)),
        ("emits_per_request", Json::Num(EMITS_PER_REQUEST)),
        ("disabled_overhead_pct", Json::Num(overhead_pct)),
    ]);
    std::fs::write("BENCH_obs.json", report.render() + "\n").expect("writing BENCH_obs.json");
    println!("wrote BENCH_obs.json");

    assert!(
        overhead_pct < 1.0,
        "disabled span tracing must cost < 1% of a request \
         ({EMITS_PER_REQUEST} emits x {disabled_ns:.2} ns vs {ns_per_request:.0} ns/request \
         = {overhead_pct:.4}%)"
    );
    println!("obs gate passed: disabled tracing costs {overhead_pct:.4}% < 1% of a request");
}
