//! Rack scaling: the same functional tile stream on 1-, 2- and 4-shard
//! soft-backend racks. Each shard owns its own executor thread +
//! coalescing dispatcher, so extra shards multiply the serial dispatch
//! capacity that bounds the one-shard path; the shared schedule cache
//! means the schedule search cost is paid once regardless of shard
//! count. Prints req/s per shard count and the speedup over one shard.

use gta::coordinator::rack::policy_by_name;
use gta::coordinator::{CoalesceConfig, Request};
use gta::serve::{gemm_tile_request, soft_rack};
use gta::GtaConfig;
use std::time::Instant;

fn run(shards: usize, n: u64, workers: usize) -> f64 {
    let rack = soft_rack(
        vec![GtaConfig::lanes16(); shards],
        CoalesceConfig::default(),
        policy_by_name("rr").unwrap(),
    )
    .unwrap();
    let requests: Vec<Request> =
        (0..n).map(|i| gemm_tile_request(i, "mpra_gemm_i8_64", i as i32 * 7)).collect();
    let t0 = Instant::now();
    let responses = rack.serve(requests, workers);
    let wall = t0.elapsed().as_secs_f64();
    assert_eq!(responses.len(), n as usize);
    assert!(responses.iter().all(|r| r.is_ok()));
    let snap = rack.snapshot();
    let rps = n as f64 / wall.max(1e-9);
    println!(
        "{shards} shard(s): {n:>5} tiles on {workers} workers: {wall:>7.3}s = {rps:>9.1} req/s  \
         (batches={}, rack cache hits={})",
        snap.aggregate.batches, snap.aggregate.schedule_cache_hits
    );
    rps
}

fn main() {
    let n = 256u64;
    let workers = 8usize;
    println!("rack scaling: same-shape INT8 64x64 MPRA tiles, soft backend, round-robin\n");
    let base = run(1, n, workers);
    for shards in [2usize, 4] {
        let rps = run(shards, n, workers);
        println!("  -> {shards}-shard speedup over 1 shard: {:.2}x", rps / base.max(1e-9));
    }
}
