//! Serving-path bench: what same-shape coalescing buys on a functional
//! tile stream. Drives the full batched serve path (admission queue +
//! coalescing dispatcher + executor thread) on the soft rust-oracle
//! backend — the dispatch overhead being amortized is the real
//! per-invocation channel round-trip, identical to the PJRT deployment's.
//!
//! Prints req/s with coalescing disabled (window 0 -> every dispatch is a
//! singleton) vs enabled, plus the observed batch-size histogram.

use gta::coordinator::{CoalesceConfig, Request};
use gta::serve::{gemm_tile_request, soft_coordinator};
use gta::GtaConfig;
use std::time::{Duration, Instant};

fn run(label: &str, coalesce: CoalesceConfig, n: u64, workers: usize) -> f64 {
    let coord = soft_coordinator(GtaConfig::lanes16(), coalesce).unwrap();
    let requests: Vec<Request> =
        (0..n).map(|i| gemm_tile_request(i, "mpra_gemm_i8_64", i as i32 * 7)).collect();
    let t0 = Instant::now();
    let responses = coord.serve(requests, workers);
    let wall = t0.elapsed().as_secs_f64();
    assert_eq!(responses.len(), n as usize);
    assert!(responses.iter().all(|r| r.is_ok()));
    let snap = coord.metrics.snapshot();
    let rps = n as f64 / wall.max(1e-9);
    println!(
        "{label:<28} {n:>5} tiles on {workers} workers: {wall:>7.3}s = {rps:>9.1} req/s  \
         batches={} mean={:.2} max={} hist={:?}",
        snap.batches,
        snap.mean_batch(),
        snap.max_batch,
        snap.batch_hist
    );
    rps
}

fn main() {
    let n = 256u64;
    let workers = 8usize;
    println!("serve coalescing: same-shape INT8 64x64 MPRA tiles, soft backend\n");
    let solo = run(
        "uncoalesced (window 0)",
        CoalesceConfig { window: Duration::ZERO, max_batch: 1, ..Default::default() },
        n,
        workers,
    );
    let batched = run(
        "coalesced (2ms, batch<=32)",
        CoalesceConfig { window: Duration::from_millis(2), max_batch: 32, ..Default::default() },
        n,
        workers,
    );
    let adaptive = run(
        "adaptive window",
        CoalesceConfig::with_adaptive_window(),
        n,
        workers,
    );
    println!("\ncoalescing speedup: {:.2}x (adaptive {:.2}x)", batched / solo.max(1e-9), adaptive / solo.max(1e-9));
}
