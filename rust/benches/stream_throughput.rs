//! Open-loop streaming throughput: the mixed e2e stream fed through one
//! long-lived `RackSession` as a seeded Poisson arrival process, swept
//! across arrival rates. What to look for:
//!
//! * **sustained throughput** tracks the offered rate until the rack
//!   saturates, at which point blocking admission turns overload into
//!   backpressure (throughput plateaus, nothing is lost);
//! * **the adaptive coalescing window engages**: at sparse rates it
//!   collapses toward 0 (no latency tax on light traffic), while
//!   sustained arrivals must leave it non-zero
//!   (`coalesce_window_us > 0`) with mean batches > 1 — the acceptance
//!   gate for the streaming redesign.
//!
//! ```bash
//! cargo bench --bench stream_throughput
//! ```

use gta::serve::run_open_loop_soft_rack;

fn main() {
    let n = 384u64;
    let workers = 8usize;
    let shards = 2usize;
    let seed = 2024u64;
    println!(
        "open-loop streaming: {n} mixed requests, {shards}-shard soft rack, \
         {workers} workers, seeded Poisson arrivals\n"
    );
    let mut sustained_window = 0u64;
    for rate in [500.0f64, 5_000.0, 50_000.0] {
        let s = run_open_loop_soft_rack(n, workers, shards, &[], "rr", rate, seed)
            .expect("soft rack builds offline");
        assert_eq!(s.requests, n, "one response per request, streaming included");
        assert_eq!(s.errors, 0);
        assert_eq!(s.verified_failed, 0, "streamed numerics stay exact");
        println!(
            "offered {rate:>8.0} req/s -> served {:>8.1} req/s  \
             window={:>5}us  batches={} (mean {:.2}, max {})  p99={}us",
            s.throughput_rps,
            s.coalesce_window_us,
            s.coalesced_batches,
            s.metrics.mean_batch(),
            s.max_batch,
            s.metrics.p99_us,
        );
        if rate >= 5_000.0 {
            sustained_window = sustained_window.max(s.coalesce_window_us);
        }
    }
    // the headline acceptance: under sustained arrival rates the
    // adaptive controller must have chosen a non-zero window at some
    // point (max across the sustained sweep, so one overloaded-runner
    // singleton-batch run cannot flake the build)
    assert!(
        sustained_window > 0,
        "sustained open-loop arrivals must engage the adaptive coalescing window"
    );
    println!("\nstream throughput OK: adaptive window engaged ({sustained_window}us) under load");
}
