//! Table 3 — SIMD gains for all data types.
//!
//! Regenerates the table (derived from the MPRA SIMD throughput model,
//! asserting the paper's exact values) and times the vector-mode
//! simulator on a SIMD sweep across all eight precisions.

use gta::precision::Precision;
use gta::report;
use gta::sim::{gta::GtaSim, Platform};
use gta::util::bench::bench;
use gta::{TensorOp, VectorKind};

fn main() {
    println!("=== Table 3: SIMD gains for all data types ===");
    print!("{}", report::render_table3());

    // assert the paper's exact numbers as part of the bench run
    let paper = [8.0, 4.0, 2.0, 1.0, 16.0, 4.0, 3.56, 1.3];
    for (row, want) in report::table3().iter().zip(paper) {
        assert!(
            (row.1 - want).abs() / want < 0.01,
            "{}: {} != paper {}",
            row.0.name(),
            row.1,
            want
        );
    }
    println!("(all eight gains match the paper exactly)\n");

    let sim = GtaSim::table1();
    for p in Precision::ALL {
        let op = TensorOp::vector(1 << 20, p, VectorKind::Map);
        bench(&format!("table3/simd_vector_1M_{}", p.name()), || {
            std::hint::black_box(sim.run(std::hint::black_box(&op)));
        });
    }
}
