//! `gta analyze` — a dependency-free invariant linter that encodes this
//! repo's bug history as machine-checked rules.
//!
//! Three of the first seven PRs fixed the same two bug classes: silent
//! `as`-narrowing truncation in decoders (PR 6's `get_u32`/`get_usize`
//! hardening, PR 8's `bignum64` `as u8` fix) and panics that lose admitted
//! work (PR 2's catch_unwind serving fix). This module turns those lessons
//! into rules that run on every CI push, so the classes cannot silently
//! come back.
//!
//! The scanner is deliberately lexer-level — no `syn`, no dependencies.
//! [`lex`] walks the source once with full string/char/comment awareness
//! (raw strings, nested block comments, `\<newline>` string continuations,
//! lifetimes vs. char literals) and blanks non-code text, so the rules can
//! run cheap substring scans over *code only* without false positives from
//! doc comments or string payloads. Trailing `#[cfg(test)]` items are
//! masked out by brace tracking ([`test_mask`]).
//!
//! Rules (see `docs/analysis.md` for the table with originating PRs):
//!
//! - **R1** no silent narrowing `as` casts in decoder/wire/limb modules
//! - **R2** no `unwrap()`/`expect()`/`panic!`/literal index in the serving
//!   hot path outside `#[cfg(test)]`
//! - **R3** `lock().unwrap()` must use a poison-mapping idiom or carry a
//!   `// lint: poison-safe <reason>`
//! - **R4** every `Ordering::Relaxed` needs a `// lint: relaxed-ok <reason>`
//! - **R5** no `process::exit`/`todo!`/`unimplemented!` outside `main.rs`
//! - **R6** public decode/parse fns must return `Result`/`Option`
//! - **R7** capacity reservations in frame codecs need a bounded-size
//!   justification (`Vec::with_capacity(attacker_controlled)` guard)
//! - **R8** bench JSON writers must stamp a `gta.bench.<name>/<n>` schema tag
//! - **R0** (engine-level) a suppression directive without a reason
//!
//! Suppression: `// lint: allow(R1) <reason>`, `// lint: poison-safe
//! <reason>` (= allow(R3)), `// lint: relaxed-ok <reason>` (= allow(R4)),
//! on the finding's line or the line above. The reason is mandatory.
//! Pre-existing findings live in `analysis/BASELINE.json` as per-(rule,
//! file) ceilings: counts at or under the ceiling pass (tracked for
//! burn-down), anything new fails.

use crate::util::json::{self, Json};
use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

/// Schema tag stamped on the JSON report (`--format json`).
pub const REPORT_SCHEMA: &str = "gta.analysis.report/1";
/// Schema tag a baseline file must carry.
pub const BASELINE_SCHEMA: &str = "gta.analysis.baseline/1";

/// One rule violation at a source location.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Finding {
    pub rule: &'static str,
    /// Path normalized to start at the `src/` or `benches/` component, so
    /// `--dir rust/src`, `--dir src` and `--dir .` agree on keys.
    pub file: String,
    /// 1-based line number.
    pub line: usize,
    pub message: String,
}

/// A grandfathered (rule, file) group: pre-existing findings at or under
/// the committed ceiling, tracked for burn-down rather than failing.
#[derive(Debug, Clone)]
pub struct Grandfathered {
    pub rule: String,
    pub file: String,
    pub count: usize,
    pub max: usize,
    pub note: String,
}

/// Per-(rule, file) ceiling from `analysis/BASELINE.json`.
#[derive(Debug, Clone)]
pub struct BaselineEntry {
    pub rule: String,
    pub file: String,
    pub max: usize,
    pub note: String,
}

#[derive(Debug, Clone, Default)]
pub struct Baseline {
    pub entries: Vec<BaselineEntry>,
}

/// The outcome of an `analyze` run: what fails, what is grandfathered.
#[derive(Debug, Clone)]
pub struct Report {
    pub dir: String,
    pub files_scanned: usize,
    pub failing: Vec<Finding>,
    pub grandfathered: Vec<Grandfathered>,
}

impl Report {
    pub fn ok(&self) -> bool {
        self.failing.is_empty()
    }
}

// ---------------------------------------------------------------------------
// Lexer: blank strings/chars/comments out of code, keep comment text.
// ---------------------------------------------------------------------------

/// One source line after lexing: `code` has every string/char/comment
/// character replaced by a space (structure like braces and casts intact),
/// `comment` holds the text of any comments on the line.
#[derive(Debug, Default, Clone)]
pub struct Line {
    pub code: String,
    pub comment: String,
}

#[derive(PartialEq)]
enum LexState {
    Code,
    LineComment,
    BlockComment,
    Str,
    RawStr,
}

fn is_word(c: char) -> bool {
    c.is_alphanumeric() || c == '_'
}

/// Split `src` into [`Line`]s with string/char/comment interiors blanked.
/// Handles nested block comments, raw strings (`r"`, `r#"`, `br#"`), byte
/// strings, `\<newline>` string continuations, and the lifetime-vs-char
/// (`'a` vs `'a'`) ambiguity by lookahead.
pub fn lex(src: &str) -> Vec<Line> {
    let chars: Vec<char> = src.chars().collect();
    let n = chars.len();
    let mut lines = Vec::new();
    let mut cur = Line::default();
    let mut state = LexState::Code;
    let mut depth = 0usize; // block comment nesting
    let mut hashes = 0usize; // raw string fence
    let mut escaped = false; // pending escape inside "..."
    let mut i = 0usize;
    while i < n {
        let c = chars[i];
        if c == '\n' {
            lines.push(std::mem::take(&mut cur));
            if state == LexState::LineComment {
                state = LexState::Code;
            }
            escaped = false; // \<newline> string continuation
            i += 1;
            continue;
        }
        match state {
            LexState::Code => {
                if c == '/' && chars.get(i + 1) == Some(&'/') {
                    state = LexState::LineComment;
                    i += 2;
                    continue;
                }
                if c == '/' && chars.get(i + 1) == Some(&'*') {
                    state = LexState::BlockComment;
                    depth = 1;
                    i += 2;
                    continue;
                }
                if c == '"' {
                    state = LexState::Str;
                    escaped = false;
                    cur.code.push(' ');
                    i += 1;
                    continue;
                }
                let word_before = i > 0 && is_word(chars[i - 1]);
                if c == 'r' && !word_before {
                    // r"..." / r#"..."#
                    let mut j = i + 1;
                    let mut h = 0usize;
                    while chars.get(j) == Some(&'#') {
                        h += 1;
                        j += 1;
                    }
                    if chars.get(j) == Some(&'"') {
                        state = LexState::RawStr;
                        hashes = h;
                        for _ in i..=j {
                            cur.code.push(' ');
                        }
                        i = j + 1;
                        continue;
                    }
                }
                if c == 'b' && !word_before {
                    // b"..." and br#"..."# byte strings (b'.' is a char)
                    if chars.get(i + 1) == Some(&'"') {
                        state = LexState::Str;
                        escaped = false;
                        cur.code.push_str("  ");
                        i += 2;
                        continue;
                    }
                    if chars.get(i + 1) == Some(&'r') {
                        let mut j = i + 2;
                        let mut h = 0usize;
                        while chars.get(j) == Some(&'#') {
                            h += 1;
                            j += 1;
                        }
                        if chars.get(j) == Some(&'"') {
                            state = LexState::RawStr;
                            hashes = h;
                            for _ in i..=j {
                                cur.code.push(' ');
                            }
                            i = j + 1;
                            continue;
                        }
                    }
                }
                if c == '\'' {
                    // char literal vs lifetime: '\..' or 'x' is a literal
                    if chars.get(i + 1) == Some(&'\\') {
                        let mut j = i + 2;
                        if j < n {
                            j += 1; // the escaped char itself
                        }
                        while j < n && chars[j] != '\'' && chars[j] != '\n' {
                            j += 1;
                        }
                        let end = j.min(n.saturating_sub(1));
                        for _ in i..=end {
                            cur.code.push(' ');
                        }
                        i = j + 1;
                        continue;
                    }
                    if chars.get(i + 2) == Some(&'\'') {
                        cur.code.push_str("   ");
                        i += 3;
                        continue;
                    }
                    cur.code.push(c); // a lifetime: keep, harmless in code
                    i += 1;
                    continue;
                }
                cur.code.push(c);
                i += 1;
            }
            LexState::LineComment => {
                cur.comment.push(c);
                i += 1;
            }
            LexState::BlockComment => {
                if c == '/' && chars.get(i + 1) == Some(&'*') {
                    depth += 1;
                    i += 2;
                } else if c == '*' && chars.get(i + 1) == Some(&'/') {
                    depth -= 1;
                    i += 2;
                    if depth == 0 {
                        state = LexState::Code;
                    }
                } else {
                    cur.comment.push(c);
                    i += 1;
                }
            }
            LexState::Str => {
                if escaped {
                    escaped = false;
                } else if c == '\\' {
                    escaped = true;
                } else if c == '"' {
                    state = LexState::Code;
                    cur.code.push(' ');
                    i += 1;
                    continue;
                }
                i += 1;
            }
            LexState::RawStr => {
                if c == '"' {
                    let mut j = i + 1;
                    let mut h = 0usize;
                    while h < hashes && chars.get(j) == Some(&'#') {
                        h += 1;
                        j += 1;
                    }
                    if h == hashes {
                        state = LexState::Code;
                        i = j;
                        continue;
                    }
                }
                i += 1;
            }
        }
    }
    if !cur.code.is_empty() || !cur.comment.is_empty() {
        lines.push(cur);
    }
    lines
}

/// Per-line mask: `true` for lines inside a trailing `#[cfg(test)]`-gated
/// item (the attribute line through the close of its brace block).
pub fn test_mask(lines: &[Line]) -> Vec<bool> {
    let mut mask = vec![false; lines.len()];
    let mut k = 0usize;
    while k < lines.len() {
        if lines[k].code.trim_start().starts_with("#[cfg(test)]") {
            let mut depth = 0i64;
            let mut opened = false;
            let mut j = k;
            while j < lines.len() {
                mask[j] = true;
                for ch in lines[j].code.chars() {
                    match ch {
                        '{' => {
                            depth += 1;
                            opened = true;
                        }
                        '}' => depth -= 1,
                        _ => {}
                    }
                }
                if opened && depth <= 0 {
                    break;
                }
                j += 1;
            }
            k = j + 1;
            continue;
        }
        k += 1;
    }
    mask
}

// ---------------------------------------------------------------------------
// Suppression directives.
// ---------------------------------------------------------------------------

/// Parsed from line comments: `lint: allow(R1,R2) reason`,
/// `lint: poison-safe reason`, `lint: relaxed-ok reason`. Returns
/// (line -> allowed rule ids, malformed-directive R0 findings). An allow
/// covers the directive's own line and the line below it.
fn suppressions(lines: &[Line], file: &str) -> (BTreeMap<usize, Vec<String>>, Vec<Finding>) {
    let mut allow: BTreeMap<usize, Vec<String>> = BTreeMap::new();
    let mut bad = Vec::new();
    for (idx0, line) in lines.iter().enumerate() {
        let ln = idx0 + 1;
        let Some(at) = line.comment.find("lint:") else { continue };
        let rest = line.comment[at + "lint:".len()..].trim_start();
        let (rules, reason): (Vec<String>, &str) = if let Some(r) = rest.strip_prefix("allow(") {
            match r.split_once(')') {
                Some((ids, reason)) => (
                    ids.split(',').map(|s| s.trim().to_string()).filter(|s| !s.is_empty()).collect(),
                    reason,
                ),
                None => (Vec::new(), ""),
            }
        } else if let Some(reason) = rest.strip_prefix("poison-safe") {
            (vec!["R3".to_string()], reason)
        } else if let Some(reason) = rest.strip_prefix("relaxed-ok") {
            (vec!["R4".to_string()], reason)
        } else {
            bad.push(Finding {
                rule: "R0",
                file: file.to_string(),
                line: ln,
                message: "unrecognized lint: directive (want allow(Rn)/poison-safe/relaxed-ok)"
                    .to_string(),
            });
            continue;
        };
        if rules.is_empty() || reason.trim().is_empty() {
            bad.push(Finding {
                rule: "R0",
                file: file.to_string(),
                line: ln,
                message: "suppression directive without a reason (the reason is mandatory)"
                    .to_string(),
            });
            continue;
        }
        for target in [ln, ln + 1] {
            allow.entry(target).or_default().extend(rules.iter().cloned());
        }
    }
    (allow, bad)
}

// ---------------------------------------------------------------------------
// Rule scopes + detectors.
// ---------------------------------------------------------------------------

/// Normalize a path so baseline keys are stable however `--dir` points at
/// the tree: keep from the last `src`/`benches` component onward.
pub fn norm_path(path: &str) -> String {
    let parts: Vec<&str> = path.split(['/', '\\']).filter(|p| !p.is_empty() && *p != ".").collect();
    for anchor in ["src", "benches"] {
        if let Some(k) = parts.iter().rposition(|p| *p == anchor) {
            return parts[k..].join("/");
        }
    }
    parts.last().copied().unwrap_or(path).to_string()
}

/// R1: decoder/wire/limb modules where a silently narrowing `as` cast has
/// historically produced plausible-looking wrong answers.
fn in_scope_r1(p: &str) -> bool {
    p.starts_with("src/net/")
        || p.starts_with("src/precision/")
        || p == "src/util/json.rs"
        || p == "src/sim/trace.rs"
        || p == "src/coordinator/lane_scheduler.rs"
}

/// R2: the serving hot path, where a panic loses admitted work.
fn in_scope_r2(p: &str) -> bool {
    p.starts_with("src/net/")
        || p.starts_with("src/runtime/")
        || p == "src/coordinator/session.rs"
        || p == "src/serve.rs"
}

const NARROW: [&str; 8] = ["u8", "u16", "u32", "i8", "i16", "i32", "usize", "isize"];
const R2_TOKENS: [&str; 4] = [".unwrap()", ".expect(", "panic!(", "unreachable!("];

/// Scan `code` for `as <narrow-int>` casts; returns the narrow type names
/// in order of appearance (a line can hold several casts).
fn narrowing_casts(code: &str) -> Vec<&'static str> {
    let b: Vec<char> = code.chars().collect();
    let mut out = Vec::new();
    let mut i = 0usize;
    while i + 1 < b.len() {
        let is_as = b[i] == 'a'
            && b[i + 1] == 's'
            && (i == 0 || !is_word(b[i - 1]))
            && b.get(i + 2).is_some_and(|c| c.is_whitespace());
        if !is_as {
            i += 1;
            continue;
        }
        let mut j = i + 2;
        while b.get(j).is_some_and(|c| c.is_whitespace()) {
            j += 1;
        }
        let start = j;
        while b.get(j).is_some_and(|&c| is_word(c)) {
            j += 1;
        }
        let ident: String = b[start..j].iter().collect();
        if let Some(t) = NARROW.iter().find(|t| **t == ident) {
            out.push(*t);
        }
        i = j.max(i + 1);
    }
    out
}

/// `x[0]`-style literal slice indexing: word/`)`/`]` then `[digits]`.
fn has_literal_index(code: &str) -> bool {
    let b: Vec<char> = code.chars().collect();
    for i in 1..b.len() {
        if b[i] != '[' {
            continue;
        }
        let prev = b[i - 1];
        if !(is_word(prev) || prev == ')' || prev == ']') {
            continue;
        }
        let mut j = i + 1;
        let start = j;
        while b.get(j).is_some_and(|c| c.is_ascii_digit()) {
            j += 1;
        }
        if j > start && b.get(j) == Some(&']') {
            return true;
        }
    }
    false
}

/// R6: find `pub fn decode_*` / `pub fn parse*` headers; returns
/// (line index, fn name) pairs for signature accumulation.
fn decode_fn_headers(lines: &[Line]) -> Vec<(usize, String)> {
    let mut out = Vec::new();
    for (idx, line) in lines.iter().enumerate() {
        let code = &line.code;
        for pat in ["pub fn ", "pub(crate) fn "] {
            if let Some(at) = code.find(pat) {
                let name: String = code[at + pat.len()..]
                    .chars()
                    .take_while(|&c| is_word(c))
                    .collect();
                if name.starts_with("decode_") || name.starts_with("parse") {
                    out.push((idx, name));
                }
            }
        }
    }
    out
}

// ---------------------------------------------------------------------------
// The scanner.
// ---------------------------------------------------------------------------

/// Run every rule over one source text. `label` is the path used for rule
/// scoping and finding locations — tests pass hot-path labels with fixture
/// bodies to exercise rules without touching the real tree.
pub fn scan_source(label: &str, src: &str) -> Vec<Finding> {
    let p = norm_path(label);
    let lines = lex(src);
    let mask = test_mask(&lines);
    let (allow, mut out) = suppressions(&lines, &p);
    let allowed = |ln: usize, rule: &str| {
        allow.get(&ln).is_some_and(|rules| rules.iter().any(|r| r.as_str() == rule))
    };
    let emit = |out: &mut Vec<Finding>, rule: &'static str, ln: usize, msg: String| {
        if !allowed(ln, rule) {
            out.push(Finding { rule, file: p.clone(), line: ln, message: msg });
        }
    };

    // R8 (file-level): a bench that writes a BENCH_*.json baseline must
    // stamp the machine-readable schema tag bench-check validates.
    if p.starts_with("benches/") && src.contains("BENCH_") && !src.contains("gta.bench.") {
        emit(
            &mut out,
            "R8",
            1,
            "bench writes BENCH_*.json without a gta.bench.<name>/<n> schema tag".to_string(),
        );
    }

    // R6: decode/parse signatures must admit failure.
    if p.starts_with("src/net/") || p.starts_with("src/precision/") || p == "src/util/json.rs" {
        for (idx, name) in decode_fn_headers(&lines) {
            if mask[idx] {
                continue;
            }
            let mut sig = String::new();
            let mut j = idx;
            loop {
                sig.push_str(&lines[j].code);
                sig.push(' ');
                if lines[j].code.contains('{') || lines[j].code.contains(';') || j + 1 >= lines.len()
                {
                    break;
                }
                j += 1;
            }
            let head = sig.split('{').next().unwrap_or("");
            if !head.contains("Result") && !head.contains("Option") {
                emit(
                    &mut out,
                    "R6",
                    idx + 1,
                    format!("pub decode/parse fn `{name}` does not return Result/Option"),
                );
            }
        }
    }

    for (idx0, line) in lines.iter().enumerate() {
        if mask[idx0] {
            continue;
        }
        let ln = idx0 + 1;
        let code = &line.code;
        if in_scope_r1(&p) {
            for t in narrowing_casts(code) {
                emit(
                    &mut out,
                    "R1",
                    ln,
                    format!(
                        "narrowing `as {t}` in a decoder/wire/limb module — use the checked \
                         get_u32/get_usize/try_into idiom (PR 6, PR 8)"
                    ),
                );
            }
        }
        if in_scope_r2(&p) && !code.contains(".lock()") {
            for tok in R2_TOKENS {
                if code.contains(tok) {
                    emit(
                        &mut out,
                        "R2",
                        ln,
                        format!("`{tok}` in the serving hot path loses admitted work (PR 2)"),
                    );
                }
            }
            if has_literal_index(code) {
                emit(
                    &mut out,
                    "R2",
                    ln,
                    "unchecked literal slice index in the serving hot path".to_string(),
                );
            }
        }
        if p.starts_with("src/") {
            if code.contains(".lock().unwrap()") {
                emit(
                    &mut out,
                    "R3",
                    ln,
                    "lock().unwrap() without poison mapping — use the lock_writer pattern, \
                     unwrap_or_else(|e| e.into_inner()), or justify with `// lint: poison-safe`"
                        .to_string(),
                );
            }
            if code.contains("Ordering::Relaxed") {
                emit(
                    &mut out,
                    "R4",
                    ln,
                    "Ordering::Relaxed without a `// lint: relaxed-ok <why>` justification"
                        .to_string(),
                );
            }
            if p != "src/main.rs" {
                for tok in ["process::exit", "todo!(", "unimplemented!("] {
                    if code.contains(tok) {
                        emit(&mut out, "R5", ln, format!("`{tok}` outside main.rs"));
                    }
                }
            }
        }
        if p.starts_with("src/net/") && (code.contains("with_capacity(") || code.contains(".reserve("))
        {
            emit(
                &mut out,
                "R7",
                ln,
                "capacity reservation in a frame codec path — justify that the size is \
                 bounded before allocating (hostile length words must be cap-checked first)"
                    .to_string(),
            );
        }
    }
    out.sort_by(|a, b| (&a.file, a.line, a.rule).cmp(&(&b.file, b.line, b.rule)));
    out
}

/// Recursively scan every `.rs` file under `dir`, skipping `target/`,
/// `tests/`, `fixtures/` and hidden directories. Returns
/// (files scanned, findings).
pub fn scan_dir(dir: &Path) -> std::io::Result<(usize, Vec<Finding>)> {
    let mut files = Vec::new();
    collect_rs_files(dir, &mut files)?;
    files.sort();
    let mut findings = Vec::new();
    for f in &files {
        let src = std::fs::read_to_string(f)?;
        findings.extend(scan_source(&f.to_string_lossy(), &src));
    }
    findings.sort_by(|a, b| (&a.file, a.line, a.rule).cmp(&(&b.file, b.line, b.rule)));
    Ok((files.len(), findings))
}

fn collect_rs_files(dir: &Path, out: &mut Vec<PathBuf>) -> std::io::Result<()> {
    for entry in std::fs::read_dir(dir)? {
        let entry = entry?;
        let path = entry.path();
        let name = entry.file_name().to_string_lossy().into_owned();
        if path.is_dir() {
            if name.starts_with('.') || matches!(name.as_str(), "target" | "tests" | "fixtures") {
                continue;
            }
            collect_rs_files(&path, out)?;
        } else if name.ends_with(".rs") {
            out.push(path);
        }
    }
    Ok(())
}

// ---------------------------------------------------------------------------
// Baseline: committed per-(rule, file) ceilings for grandfathered findings.
// ---------------------------------------------------------------------------

/// Parse `analysis/BASELINE.json`. Errors are strings so the CLI can wrap
/// them with the path.
pub fn parse_baseline(text: &str) -> Result<Baseline, String> {
    let j = json::parse(text).map_err(|e| format!("{e}"))?;
    let schema = j.get("schema").and_then(Json::as_str).unwrap_or("");
    if schema != BASELINE_SCHEMA {
        return Err(format!("schema {schema:?} is not {BASELINE_SCHEMA}"));
    }
    let mut entries = Vec::new();
    for e in j.get("entries").and_then(Json::as_arr).unwrap_or(&[]) {
        let rule = e
            .get("rule")
            .and_then(Json::as_str)
            .ok_or("baseline entry missing \"rule\"")?
            .to_string();
        let file = e
            .get("file")
            .and_then(Json::as_str)
            .ok_or("baseline entry missing \"file\"")?
            .to_string();
        let max = e.get("max").and_then(Json::as_u64).ok_or("baseline entry missing \"max\"")?
            as usize;
        let note = e.get("note").and_then(Json::as_str).unwrap_or("").to_string();
        entries.push(BaselineEntry { rule, file, max, note });
    }
    Ok(Baseline { entries })
}

/// Render a baseline (e.g. for `--write-baseline`).
pub fn render_baseline(b: &Baseline) -> String {
    let entries: Vec<Json> = b
        .entries
        .iter()
        .map(|e| {
            let mut m = BTreeMap::new();
            m.insert("rule".to_string(), Json::Str(e.rule.clone()));
            m.insert("file".to_string(), Json::Str(e.file.clone()));
            m.insert("max".to_string(), Json::Num(e.max as f64));
            m.insert("note".to_string(), Json::Str(e.note.clone()));
            Json::Obj(m)
        })
        .collect();
    let mut top = BTreeMap::new();
    top.insert("schema".to_string(), Json::Str(BASELINE_SCHEMA.to_string()));
    top.insert("entries".to_string(), Json::Arr(entries));
    Json::Obj(top).render()
}

/// Build a fresh baseline that exactly covers `findings` (burn-down seed).
pub fn baseline_from_findings(findings: &[Finding], note: &str) -> Baseline {
    let mut counts: BTreeMap<(String, String), usize> = BTreeMap::new();
    for f in findings {
        *counts.entry((f.rule.to_string(), f.file.clone())).or_default() += 1;
    }
    Baseline {
        entries: counts
            .into_iter()
            .map(|((rule, file), max)| BaselineEntry { rule, file, max, note: note.to_string() })
            .collect(),
    }
}

/// Split findings into (failing, grandfathered) under the baseline's
/// per-(rule, file) ceilings: a group at or under its ceiling is tracked,
/// a group over it fails wholesale (the new finding is in there somewhere,
/// and the fix is to not add it).
pub fn apply_baseline(findings: Vec<Finding>, baseline: &Baseline) -> (Vec<Finding>, Vec<Grandfathered>) {
    let mut groups: BTreeMap<(String, String), Vec<Finding>> = BTreeMap::new();
    for f in findings {
        groups.entry((f.rule.to_string(), f.file.clone())).or_default().push(f);
    }
    let mut failing = Vec::new();
    let mut grandfathered = Vec::new();
    for ((rule, file), group) in groups {
        let entry = baseline.entries.iter().find(|e| e.rule == rule && e.file == file);
        match entry {
            Some(e) if group.len() <= e.max => grandfathered.push(Grandfathered {
                rule,
                file,
                count: group.len(),
                max: e.max,
                note: e.note.clone(),
            }),
            _ => failing.extend(group),
        }
    }
    failing.sort_by(|a, b| (&a.file, a.line, a.rule).cmp(&(&b.file, b.line, b.rule)));
    (failing, grandfathered)
}

/// Default baseline location for a scan root: `<dir>/analysis/BASELINE.json`
/// (scanning a crate root), else `<dir>/../analysis/BASELINE.json`
/// (scanning `rust/src` directly).
pub fn resolve_baseline_path(dir: &Path) -> Option<PathBuf> {
    let in_dir = dir.join("analysis").join("BASELINE.json");
    if in_dir.is_file() {
        return Some(in_dir);
    }
    let sibling = dir.join("..").join("analysis").join("BASELINE.json");
    if sibling.is_file() {
        return Some(sibling);
    }
    None
}

// ---------------------------------------------------------------------------
// Output.
// ---------------------------------------------------------------------------

/// Machine-readable report (`--format json`), schema [`REPORT_SCHEMA`] —
/// validated by `gta bench-check --analysis` in CI.
pub fn report_json(r: &Report) -> Json {
    let findings: Vec<Json> = r
        .failing
        .iter()
        .map(|f| {
            let mut m = BTreeMap::new();
            m.insert("rule".to_string(), Json::Str(f.rule.to_string()));
            m.insert("file".to_string(), Json::Str(f.file.clone()));
            m.insert("line".to_string(), Json::Num(f.line as f64));
            m.insert("message".to_string(), Json::Str(f.message.clone()));
            Json::Obj(m)
        })
        .collect();
    let grandfathered: Vec<Json> = r
        .grandfathered
        .iter()
        .map(|g| {
            let mut m = BTreeMap::new();
            m.insert("rule".to_string(), Json::Str(g.rule.clone()));
            m.insert("file".to_string(), Json::Str(g.file.clone()));
            m.insert("count".to_string(), Json::Num(g.count as f64));
            m.insert("max".to_string(), Json::Num(g.max as f64));
            m.insert("note".to_string(), Json::Str(g.note.clone()));
            Json::Obj(m)
        })
        .collect();
    let mut top = BTreeMap::new();
    top.insert("schema".to_string(), Json::Str(REPORT_SCHEMA.to_string()));
    top.insert("dir".to_string(), Json::Str(r.dir.clone()));
    top.insert("files_scanned".to_string(), Json::Num(r.files_scanned as f64));
    top.insert("ok".to_string(), Json::Bool(r.ok()));
    top.insert("findings".to_string(), Json::Arr(findings));
    top.insert("grandfathered".to_string(), Json::Arr(grandfathered));
    Json::Obj(top)
}

/// Human-readable report (`--format text`, the default).
pub fn render_text(r: &Report) -> String {
    let mut s = format!("gta analyze: scanned {} file(s) under {}\n", r.files_scanned, r.dir);
    for f in &r.failing {
        s.push_str(&format!("  FAIL {} {}:{} — {}\n", f.rule, f.file, f.line, f.message));
    }
    for g in &r.grandfathered {
        let slack = if g.count < g.max {
            format!(" (can tighten max to {})", g.count)
        } else {
            String::new()
        };
        s.push_str(&format!(
            "  baselined {} {}: {}/{} finding(s){} — {}\n",
            g.rule, g.file, g.count, g.max, slack, g.note
        ));
    }
    if r.ok() {
        s.push_str(&format!(
            "analysis OK: 0 new finding(s), {} grandfathered group(s)\n",
            r.grandfathered.len()
        ));
    } else {
        s.push_str(&format!(
            "analysis FAILED: {} finding(s) not covered by suppressions or the baseline\n",
            r.failing.len()
        ));
    }
    s
}
