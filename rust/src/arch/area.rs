//! Area model: the synthesized numbers the paper reports (Table 1, §6.1)
//! and the same-area normalization used for the cross-platform comparisons
//! (§6.3 "configure different number of MPRA to match the same area").


/// Table 1 — evaluated platforms.
#[derive(Debug, Clone)]
pub struct PlatformInfo {
    pub name: &'static str,
    pub node_nm: u32,
    pub freq_mhz: u32,
    pub area_mm2: f64,
    pub compute_units: &'static str,
    pub precisions: &'static str,
}

/// The four Table 1 columns.
pub fn table1() -> Vec<PlatformInfo> {
    vec![
        PlatformInfo {
            name: "GTA",
            node_nm: 14,
            freq_mhz: 1000,
            area_mm2: 0.35,
            compute_units: "4 lanes (8x8 MPRA each)",
            precisions: "INT8/16/32/64, BP16, FP16/32/64",
        },
        PlatformInfo {
            name: "VPU-Ara",
            node_nm: 14,
            freq_mhz: 250,
            area_mm2: 0.33,
            compute_units: "4 lanes (per-precision MACs)",
            precisions: "INT8/16/32/64, BP16, FP16/32/64",
        },
        PlatformInfo {
            name: "GPGPU-NVIDIA H100",
            node_nm: 4,
            freq_mhz: 1755,
            area_mm2: 814.0,
            compute_units: "528 tensor cores + CUDA cores",
            precisions: "FP64, TF32, FP32, INT32, BP16, FP16, FP8, INT8",
        },
        PlatformInfo {
            name: "CGRA-hycube",
            node_nm: 28,
            freq_mhz: 704,
            area_mm2: 7.82,
            compute_units: "4x4 word-level PEs",
            precisions: "INT8/16/32/64, BP16, FP16/32/64",
        },
    ]
}

/// §6.1 synthesized fractions.
pub mod fractions {
    /// A lane with an 8×8 MPRA uses this fraction of the original Ara
    /// lane's *computation* area while covering all integer precisions.
    pub const MPRA_LANE_OF_ARA_LANE: f64 = 0.6076;
    /// Control/interconnect overhead over the original 4-lane Ara.
    pub const CONTROL_OVERHEAD: f64 = 0.0606;
    /// With FP post-processing units added the lane is ≈ the original.
    pub const LANE_WITH_FP_OF_ARA_LANE: f64 = 1.0;
}

/// Per-lane area in mm² for GTA at 14 nm (Table 1: 4 lanes = 0.35 mm²).
pub const GTA_LANE_AREA_MM2: f64 = 0.35 / 4.0;

/// Published logic transistor density (MTr/mm²) for the nodes in Table 1.
/// Real density gains are far below ideal quadratic scaling (SRAM and
/// analog barely shrink), so the §6.3 same-area normalization uses these
/// measured figures rather than `(node ratio)²`.
fn mtr_per_mm2(node_nm: u32) -> f64 {
    match node_nm {
        4 => 137.0,  // TSMC N4 class
        5 => 130.0,  // TSMC N5
        7 => 91.0,   // TSMC N7
        14 => 29.0,  // Intel 14 / TSMC 16FF class
        16 => 29.0,
        22 => 16.5,  // GF 22FDX class (Ara's node family)
        28 => 15.3,  // TSMC 28HPC
        other => 29.0 * (14.0 / other as f64).powi(2), // fallback: ideal
    }
}

/// Area multiplier when re-targeting logic from `from_nm` to `to_nm`:
/// `area_to = area_from · density(from)/density(to)`.
pub fn density_scale(from_nm: u32, to_nm: u32) -> f64 {
    mtr_per_mm2(from_nm) / mtr_per_mm2(to_nm)
}

/// How many GTA lanes fit in `area_mm2` of silicon at `node_nm`,
/// normalizing the foreign area to GTA's 14 nm node.
pub fn gta_lanes_for_area(area_mm2: f64, node_nm: u32) -> u32 {
    let at14 = area_mm2 * density_scale(node_nm, 14);
    (at14 / GTA_LANE_AREA_MM2).floor().max(1.0) as u32
}

/// Area efficiency in peak 8-bit MACs/cycle/mm² for a GTA instance —
/// the paper's headline "better area efficiency" metric.
pub fn gta_area_efficiency(lanes: u32) -> f64 {
    let pes = lanes as f64 * 64.0;
    pes / (lanes as f64 * GTA_LANE_AREA_MM2)
}

/// Ara's peak 8-bit ops/cycle/mm²: 4 lanes, 8 INT8 MACs each, 0.33 mm².
pub fn ara_area_efficiency() -> f64 {
    (4.0 * 8.0) / 0.33
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table1_has_four_platforms() {
        let t = table1();
        assert_eq!(t.len(), 4);
        assert_eq!(t[0].name, "GTA");
        assert!((t[2].area_mm2 - 814.0).abs() < 1e-9);
    }

    #[test]
    fn density_scaling_follows_published_density() {
        // 28nm logic re-targeted to 14nm roughly halves
        assert!((density_scale(28, 14) - 15.3 / 29.0).abs() < 1e-12);
        // 4nm logic re-targeted to 14nm grows ~4.7x (NOT ideal 12.25x)
        let g = density_scale(4, 14);
        assert!((4.0..6.0).contains(&g), "got {g}");
        assert!((density_scale(14, 14) - 1.0).abs() < 1e-12);
    }

    #[test]
    fn gta_beats_ara_area_efficiency() {
        // 64 PEs in 60.76% of the MAC area that held 8 INT8 MACs:
        // the §6.1 area-efficiency claim
        assert!(gta_area_efficiency(4) > ara_area_efficiency());
    }

    #[test]
    fn same_area_normalization_monotone() {
        // more foreign area -> at least as many equivalent GTA lanes
        let a = gta_lanes_for_area(1.0, 14);
        let b = gta_lanes_for_area(2.0, 14);
        assert!(b >= a);
        assert!(gta_lanes_for_area(0.0001, 14) >= 1); // floor at 1 lane
    }

    #[test]
    fn hycube_area_maps_to_lane_budget() {
        // 7.82 mm² @28nm ≈ 4.1 mm² @14nm ≈ ~47 GTA lanes
        let lanes = gta_lanes_for_area(7.82, 28);
        assert!((40..=55).contains(&lanes), "got {lanes}");
    }
}
