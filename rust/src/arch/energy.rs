//! Energy model (Fig. 6 and §6.1): per-operation compute energy for the
//! MPRA in its three operating modes vs. the original Ara lane units, plus
//! memory-access energy (the dominant term the paper's data-reuse argument
//! targets).
//!
//! Constants are 14 nm-class estimates in pJ, anchored so that the Fig. 6
//! qualitative claims hold: (i) MPRA energy is approximately flat across
//! precision, (ii) slightly above the original lane's single-precision
//! unit, (iii) memory access dwarfs compute, so traffic savings dominate.

use super::Dataflow;
use crate::precision::Precision;

/// pJ for one 8-bit PE MAC (multiplier + operand regs + pipeline reg).
pub const PE_MAC_PJ: f64 = 0.25;
/// pJ for the multi-precision accumulator per partial product combined.
pub const ACCUM_PJ: f64 = 0.05;
/// pJ of slide-unit transfer per 64-bit beat between lanes.
pub const SLIDE_PJ: f64 = 0.08;
/// pJ per byte read/written from the lane SRAM operand buffer.
pub const SRAM_PJ_PER_BYTE: f64 = 1.25;
/// pJ per byte moved from DRAM.
pub const DRAM_PJ_PER_BYTE: f64 = 160.0;

/// Energy of ONE full-array MPRA cycle (all 64 PEs active) in a mode.
/// The array is precision-agnostic — limbs, not words, flow through the
/// PEs — which is exactly why Fig. 6 is flat across the x-axis.
pub fn mpra_cycle_pj(mode: Dataflow) -> f64 {
    let pes = 64.0;
    match mode {
        // WS/IS: one operand resident -> fewer register swaps
        Dataflow::WS | Dataflow::IS => pes * PE_MAC_PJ + 8.0 * ACCUM_PJ + 2.0 * SLIDE_PJ,
        // OS: three operand streams in flight
        Dataflow::OS => pes * PE_MAC_PJ + 8.0 * ACCUM_PJ + 3.0 * SLIDE_PJ,
        // SIMD: accumulators idle, PEs run independent mults
        Dataflow::Simd => pes * PE_MAC_PJ + 1.0 * SLIDE_PJ,
    }
}

/// Energy of one MAC *at workload precision* on the MPRA: `n²` limb MACs
/// plus accumulator combining.
pub fn mpra_mac_pj(p: Precision, mode: Dataflow) -> f64 {
    let n = p.limbs() as f64;
    let slide = match mode {
        Dataflow::OS => 3.0,
        Dataflow::WS | Dataflow::IS => 2.0,
        Dataflow::Simd => 1.0,
    };
    n * n * PE_MAC_PJ + (n * n - 1.0).max(0.0) * ACCUM_PJ + slide * SLIDE_PJ / 8.0
}

/// Energy of one MAC on the original Ara lane's dedicated unit for this
/// precision (wide multipliers grow superlinearly; dedicated units skip
/// the accumulator tree).
pub fn ara_mac_pj(p: Precision) -> f64 {
    // quadratic multiplier-energy in operand width, normalized so the
    // 8-bit unit matches one PE.
    let w = p.multiplier_bits() as f64 / 8.0;
    let fp_overhead = if p.is_float() { 1.3 } else { 1.0 }; // align/normalize
    w * w * PE_MAC_PJ * fp_overhead
}

/// Fig. 6 series: MPRA energy per full-array cycle for every precision ×
/// mode (flat in precision by construction of the limb datapath).
#[derive(Debug, Clone)]
pub struct Fig6Row {
    pub precision: String,
    pub ws_pj: f64,
    pub os_pj: f64,
    pub simd_pj: f64,
    pub ara_unit_pj: f64,
}

pub fn fig6_rows() -> Vec<Fig6Row> {
    Precision::ALL
        .iter()
        .map(|&p| {
            // per-cycle energy of a fully-occupied array in each mode; the
            // array does 64/n² word-MACs per cycle at precision p
            let macs_per_cycle = 64.0 / (p.limbs() as f64 * p.limbs() as f64);
            Fig6Row {
                precision: p.name().to_string(),
                ws_pj: mpra_cycle_pj(Dataflow::WS),
                os_pj: mpra_cycle_pj(Dataflow::OS),
                simd_pj: mpra_cycle_pj(Dataflow::Simd),
                ara_unit_pj: ara_mac_pj(p) * macs_per_cycle.min(8.0 / (p.limbs() as f64)),
            }
        })
        .collect()
}

/// Total energy of a simulated run.
pub fn total_energy_pj(
    compute_macs: u64,
    precision: Precision,
    mode: Dataflow,
    sram_bytes: u64,
    dram_bytes: u64,
) -> f64 {
    compute_macs as f64 * mpra_mac_pj(precision, mode)
        + sram_bytes as f64 * SRAM_PJ_PER_BYTE
        + dram_bytes as f64 * DRAM_PJ_PER_BYTE
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fig6_flat_across_precision() {
        let rows = fig6_rows();
        let first = rows[0].ws_pj;
        for r in &rows {
            assert!((r.ws_pj - first).abs() < 1e-9, "MPRA energy must be flat");
            assert!(r.os_pj > r.ws_pj, "OS moves more operands than WS");
            assert!(r.simd_pj < r.ws_pj, "SIMD idles the accumulator");
        }
    }

    #[test]
    fn mpra_slightly_above_dedicated_unit_at_native_precision() {
        // §6.1: "MPRA's average energy consumption is a little higher than
        // original lane's computation unit"
        let mpra = mpra_mac_pj(Precision::Int32, Dataflow::WS);
        let ara = ara_mac_pj(Precision::Int32);
        assert!(mpra > ara);
        assert!(mpra < ara * 2.0, "but not dramatically higher");
    }

    #[test]
    fn memory_energy_dominates() {
        // one DRAM byte costs more than hundreds of PE MACs — the reuse
        // argument of the paper
        assert!(DRAM_PJ_PER_BYTE > 100.0 * PE_MAC_PJ);
        assert!(SRAM_PJ_PER_BYTE > PE_MAC_PJ);
    }

    #[test]
    fn total_energy_monotone_in_traffic() {
        let e1 = total_energy_pj(1000, Precision::Int8, Dataflow::WS, 100, 10);
        let e2 = total_energy_pj(1000, Precision::Int8, Dataflow::WS, 100, 20);
        assert!(e2 > e1);
    }
}
