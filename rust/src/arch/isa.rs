//! SysCSR instruction encoding (Fig. 4c): the three-level interconnect
//! configuration packed into the CSR word layout the lane scheduler
//! writes, plus the per-lane mask-register image the Mask Match Mechanism
//! loads (Fig. 4e).
//!
//! Word layout (64-bit CSR):
//! ```text
//!   [63:56] magic/version   [55:48] lane_rows   [47:40] lane_cols
//!   [39:38] systolic mode   [37:32] mask width  [31:0]  reserved
//! ```
//! Mask sets are written through a separate data port, one word per lane.

use super::{Arrangement, Dataflow, SysCsr};

const MAGIC: u64 = 0x9A;

/// Encode the Global Layout + Systolic Mode fields into the CSR word.
pub fn encode_csr(csr: &SysCsr, mask_bits: u32) -> u64 {
    let mode = match csr.systolic_mode {
        Dataflow::WS => 0u64,
        Dataflow::IS => 1,
        Dataflow::OS => 2,
        Dataflow::Simd => 3,
    };
    (MAGIC << 56)
        | ((csr.global_layout.lane_rows as u64 & 0xFF) << 48)
        | ((csr.global_layout.lane_cols as u64 & 0xFF) << 40)
        | (mode << 38)
        | ((mask_bits as u64 & 0x3F) << 32)
}

/// Decode a CSR word back into layout + mode (+ mask width). Returns
/// `None` on a bad magic or malformed field — the hardware would raise an
/// illegal-CSR exception.
pub fn decode_csr(word: u64, lanes: u32) -> Option<(SysCsr, u32)> {
    if (word >> 56) & 0xFF != MAGIC {
        return None;
    }
    let lane_rows = ((word >> 48) & 0xFF) as u32;
    let lane_cols = ((word >> 40) & 0xFF) as u32;
    if lane_rows == 0 || lane_cols == 0 || lane_rows * lane_cols != lanes {
        return None;
    }
    let mode = match (word >> 38) & 0x3 {
        0 => Dataflow::WS,
        1 => Dataflow::IS,
        2 => Dataflow::OS,
        _ => Dataflow::Simd,
    };
    let mask_bits = ((word >> 32) & 0x3F) as u32;
    Some((
        SysCsr {
            global_layout: Arrangement::new(lane_rows, lane_cols),
            systolic_mode: mode,
            mask_groups: vec![0; lanes as usize],
        },
        mask_bits,
    ))
}

/// Pack the per-lane mask groups into the mask-register image (one
/// `mask_bits`-wide field per lane, little-endian lane order).
pub fn encode_masks(masks: &[u32], mask_bits: u32) -> Vec<u64> {
    assert!(mask_bits > 0 && mask_bits <= 16);
    let per_word = 64 / mask_bits as usize;
    let mut words = vec![0u64; masks.len().div_ceil(per_word)];
    for (lane, &m) in masks.iter().enumerate() {
        assert!(m < (1 << mask_bits), "mask {m} exceeds width {mask_bits}");
        let (w, slot) = (lane / per_word, lane % per_word);
        words[w] |= (m as u64) << (slot as u32 * mask_bits);
    }
    words
}

/// Unpack the mask-register image.
pub fn decode_masks(words: &[u64], lanes: usize, mask_bits: u32) -> Vec<u32> {
    let per_word = 64 / mask_bits as usize;
    let field = (1u64 << mask_bits) - 1;
    (0..lanes)
        .map(|lane| {
            let (w, slot) = (lane / per_word, lane % per_word);
            ((words[w] >> (slot as u32 * mask_bits)) & field) as u32
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::GtaConfig;
    use crate::util::rng::{property, Rng};

    #[test]
    fn csr_roundtrip() {
        let cfg = GtaConfig::lanes16();
        for mode in Dataflow::ALL {
            for arr in cfg.arrangements() {
                let csr = SysCsr::whole_array(&cfg, arr, mode);
                let word = encode_csr(&csr, cfg.mask_bits);
                let (back, bits) = decode_csr(word, cfg.lanes).unwrap();
                assert_eq!(back.global_layout, arr);
                assert_eq!(back.systolic_mode, mode);
                assert_eq!(bits, cfg.mask_bits);
            }
        }
    }

    #[test]
    fn csr_rejects_garbage() {
        assert!(decode_csr(0, 16).is_none(), "bad magic");
        let cfg = GtaConfig::lanes16();
        let csr = SysCsr::whole_array(&cfg, Arrangement::new(4, 4), Dataflow::WS);
        let word = encode_csr(&csr, 4);
        // layout that doesn't match the lane count
        assert!(decode_csr(word, 8).is_none());
    }

    #[test]
    fn mask_image_roundtrip() {
        property("mask image roundtrip", 100, |rng: &mut Rng| {
            let bits = *rng.choose(&[1u32, 2, 4, 8]);
            let lanes = rng.range_u64(1, 64) as usize;
            let masks: Vec<u32> =
                (0..lanes).map(|_| rng.range_u64(0, (1 << bits) - 1) as u32).collect();
            let words = encode_masks(&masks, bits);
            assert_eq!(decode_masks(&words, lanes, bits), masks);
        });
    }

    #[test]
    fn mask_image_is_dense() {
        // 16 lanes × 4 bits = exactly one 64-bit word
        let masks: Vec<u32> = (0..16).map(|i| i % 16).collect();
        assert_eq!(encode_masks(&masks, 4).len(), 1);
    }
}
