//! GTA hardware architecture model (§4): lanes, MPRA geometry, the SysCSR
//! three-level interconnect configuration (Global Layout / Systolic Mode /
//! Mask Group) and the mask-match partitioning mechanism of Fig. 4.

pub mod area;
pub mod isa;
pub mod energy;


/// Systolic dataflows supported by the array (§3.1) plus the VPU-native
/// SIMD mode (§5: "some p-GEMM operators may get better result from
/// vectorization").
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Dataflow {
    /// Weight-Stationary: B panel resident, inputs stream.
    WS,
    /// Input-Stationary: A panel resident (dual of WS).
    IS,
    /// Output-Stationary: C tile resident, operands stream K-deep.
    OS,
    /// VPU vector mode on the reconfigured MPRA.
    Simd,
}

impl Dataflow {
    pub const SYSTOLIC: [Dataflow; 3] = [Dataflow::WS, Dataflow::IS, Dataflow::OS];
    pub const ALL: [Dataflow; 4] = [Dataflow::WS, Dataflow::IS, Dataflow::OS, Dataflow::Simd];

    pub fn name(self) -> &'static str {
        match self {
            Dataflow::WS => "WS",
            Dataflow::IS => "IS",
            Dataflow::OS => "OS",
            Dataflow::Simd => "SIMD",
        }
    }
}

/// Logical arrangement of the lanes' MPRAs into one systolic array
/// ("array arrangement", §4.2): `lane_rows × lane_cols` grid of
/// 8×8 MPRA blocks.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct Arrangement {
    pub lane_rows: u32,
    pub lane_cols: u32,
}

impl Arrangement {
    pub fn new(lane_rows: u32, lane_cols: u32) -> Self {
        assert!(lane_rows > 0 && lane_cols > 0);
        Arrangement { lane_rows, lane_cols }
    }

    pub fn lanes(&self) -> u32 {
        self.lane_rows * self.lane_cols
    }
}

/// Configuration of a GTA instance.
///
/// `Eq + Hash` so a config can key the scheduler's shared memo caches
/// (`scheduler::cache`) alongside the operator shape.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct GtaConfig {
    /// Number of VPU lanes, each hosting one MPRA (Table 1 default: 4).
    pub lanes: u32,
    /// PE rows per MPRA (paper fixes 8 so one row covers 8×n-bit WS/IS).
    pub mpra_rows: u32,
    /// PE columns per MPRA.
    pub mpra_cols: u32,
    /// Clock in MHz (post-MPRA synthesis: 1 GHz, §6.1).
    pub freq_mhz: u32,
    /// Per-lane SRAM (operand buffer) in KiB.
    pub sram_kib: u32,
    /// Vector register length in 64-bit elements (Ara-style VLEN/64).
    pub vlen64: u32,
    /// Width of the mask bit sets — how many sub-array partitions the
    /// mask-match mechanism can express (§4.2).
    pub mask_bits: u32,
}

impl Default for GtaConfig {
    fn default() -> Self {
        // Table 1 GTA column: 14nm, 1 GHz, 4 lanes, all eight precisions.
        GtaConfig {
            lanes: 4,
            mpra_rows: 8,
            mpra_cols: 8,
            freq_mhz: 1000,
            sram_kib: 16,
            vlen64: 64,
            mask_bits: 4,
        }
    }
}

impl GtaConfig {
    /// A 16-lane high-performance instance (the Fig. 4 running example).
    pub fn lanes16() -> Self {
        GtaConfig { lanes: 16, ..Default::default() }
    }

    pub fn with_lanes(lanes: u32) -> Self {
        assert!(lanes > 0);
        GtaConfig { lanes, ..Default::default() }
    }

    /// PEs across the whole accelerator.
    pub fn total_pes(&self) -> u32 {
        self.lanes * self.mpra_rows * self.mpra_cols
    }

    /// All logical array shapes the slide unit can realize: factor pairs
    /// of the lane count (§4.2 "several array rearrangements").
    pub fn arrangements(&self) -> Vec<Arrangement> {
        let n = self.lanes;
        (1..=n)
            .filter(|d| n % d == 0)
            .map(|d| Arrangement::new(d, n / d))
            .collect()
    }

    /// Physical PE grid of an arrangement.
    pub fn array_shape(&self, a: Arrangement) -> (u64, u64) {
        assert_eq!(a.lanes(), self.lanes, "arrangement must use every lane");
        (
            (a.lane_rows * self.mpra_rows) as u64,
            (a.lane_cols * self.mpra_cols) as u64,
        )
    }

    /// Compact stable identity of this configuration (FNV-1a over every
    /// field). The schedule-cache memos key on the full `GtaConfig`, so
    /// rack shards with equal fingerprints share cache entries rack-wide
    /// while heterogeneous shards coexist in the same memo; telemetry
    /// reports this value so an operator can see which shards pool.
    pub fn fingerprint(&self) -> u64 {
        let fields = [
            self.lanes,
            self.mpra_rows,
            self.mpra_cols,
            self.freq_mhz,
            self.sram_kib,
            self.vlen64,
            self.mask_bits,
        ];
        let mut h: u64 = 0xcbf2_9ce4_8422_2325;
        for f in fields {
            for b in f.to_le_bytes() {
                h ^= b as u64;
                h = h.wrapping_mul(0x100_0000_01b3);
            }
        }
        h
    }
}

/// The Systolic Control and Status Register (Fig. 4c): the three-level
/// interconnect configuration the lane scheduler writes before launching
/// an operator.
#[derive(Debug, Clone, PartialEq)]
pub struct SysCsr {
    /// Global Layout: logical lane grid → slide-unit shuffle program.
    pub global_layout: Arrangement,
    /// Systolic Mode: what moves between lanes each beat.
    pub systolic_mode: Dataflow,
    /// Mask Group: one mask word per lane; lanes sharing a mask form a
    /// sub-region that may exchange data (Fig. 4e).
    pub mask_groups: Vec<u32>,
}

impl SysCsr {
    /// Program the CSR for a whole-array single-tenant launch.
    pub fn whole_array(cfg: &GtaConfig, layout: Arrangement, mode: Dataflow) -> Self {
        SysCsr {
            global_layout: layout,
            systolic_mode: mode,
            mask_groups: vec![0; cfg.lanes as usize],
        }
    }

    /// Number of inter-lane operand streams the slide unit must move per
    /// beat in this mode (§4.2: OS moves three operand sets; WS/IS move
    /// an input stream + a partial-sum stream).
    pub fn streams_per_beat(&self) -> u32 {
        match self.systolic_mode {
            Dataflow::OS => 3,
            Dataflow::WS | Dataflow::IS => 2,
            Dataflow::Simd => 0,
        }
    }

    /// Partition lanes by mask value (the Mask Match Mechanism): data may
    /// only move between lanes with identical masks.
    pub fn partitions(&self) -> Vec<Vec<usize>> {
        let mut groups: std::collections::BTreeMap<u32, Vec<usize>> = Default::default();
        for (lane, &m) in self.mask_groups.iter().enumerate() {
            groups.entry(m).or_default().push(lane);
        }
        groups.into_values().collect()
    }

    /// Check the CSR against a config: every lane masked, and no more
    /// distinct partitions than the mask width can express.
    pub fn validate(&self, cfg: &GtaConfig) -> Result<(), String> {
        if self.mask_groups.len() != cfg.lanes as usize {
            return Err(format!(
                "mask set count {} != lanes {}",
                self.mask_groups.len(),
                cfg.lanes
            ));
        }
        if self.global_layout.lanes() != cfg.lanes {
            return Err(format!(
                "global layout {}x{} does not use all {} lanes",
                self.global_layout.lane_rows, self.global_layout.lane_cols, cfg.lanes
            ));
        }
        let parts = self.partitions().len() as u32;
        if parts > (1 << self.mask_bits_needed(cfg)) {
            return Err("partition count exceeds mask width".into());
        }
        Ok(())
    }

    fn mask_bits_needed(&self, cfg: &GtaConfig) -> u32 {
        cfg.mask_bits
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_matches_table1() {
        let c = GtaConfig::default();
        assert_eq!(c.lanes, 4);
        assert_eq!(c.freq_mhz, 1000);
        assert_eq!(c.total_pes(), 4 * 64);
    }

    #[test]
    fn arrangements_are_factor_pairs() {
        let c = GtaConfig::lanes16();
        let arrs = c.arrangements();
        assert_eq!(arrs.len(), 5); // 1x16 2x8 4x4 8x2 16x1
        for a in &arrs {
            assert_eq!(a.lanes(), 16);
        }
        // 4x4 lanes of 8x8 PEs = 32x32 logical array
        let (r, cshape) = c.array_shape(Arrangement::new(4, 4));
        assert_eq!((r, cshape), (32, 32));
    }

    #[test]
    fn syscsr_streams_by_mode() {
        let cfg = GtaConfig::default();
        let layout = Arrangement::new(2, 2);
        assert_eq!(SysCsr::whole_array(&cfg, layout, Dataflow::OS).streams_per_beat(), 3);
        assert_eq!(SysCsr::whole_array(&cfg, layout, Dataflow::WS).streams_per_beat(), 2);
        assert_eq!(SysCsr::whole_array(&cfg, layout, Dataflow::Simd).streams_per_beat(), 0);
    }

    #[test]
    fn mask_match_partitions() {
        let cfg = GtaConfig::lanes16();
        let mut csr = SysCsr::whole_array(&cfg, Arrangement::new(4, 4), Dataflow::WS);
        // split into 2 sub-regions: lanes 0-7 vs 8-15
        for lane in 8..16 {
            csr.mask_groups[lane] = 1;
        }
        let parts = csr.partitions();
        assert_eq!(parts.len(), 2);
        assert_eq!(parts[0], (0..8).collect::<Vec<_>>());
        assert_eq!(parts[1], (8..16).collect::<Vec<_>>());
        assert!(csr.validate(&cfg).is_ok());
    }

    #[test]
    fn syscsr_validation_catches_bad_layout() {
        let cfg = GtaConfig::default(); // 4 lanes
        let csr = SysCsr::whole_array(&GtaConfig::lanes16(), Arrangement::new(4, 4), Dataflow::WS);
        assert!(csr.validate(&cfg).is_err());
    }
}
