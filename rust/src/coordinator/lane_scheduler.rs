//! Lane scheduler: the multi-tenant partition allocator behind the Mask
//! Match Mechanism (§4.2, Fig. 4e). Concurrent operators get disjoint
//! contiguous lane groups; each group's lanes share a mask word, so the
//! slide unit only moves data within a group.

use crate::arch::{Arrangement, GtaConfig, SysCsr};
use std::collections::BTreeMap;

/// Identifier of an allocated partition.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub struct PartitionId(pub u32);

/// A granted partition: which lanes, which mask value.
#[derive(Debug, Clone)]
pub struct Partition {
    pub id: PartitionId,
    pub lanes: Vec<u32>,
    pub mask: u32,
}

/// Point-in-time lane occupancy of one allocator — the per-shard slice
/// of the rack-level free-lane accounting (see `coordinator::rack`).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct LaneUsage {
    pub total: u32,
    pub free: u32,
    pub live_partitions: usize,
}

/// Allocator over the lane pool.
#[derive(Debug)]
pub struct LaneAllocator {
    config: GtaConfig,
    /// lane -> owning partition (None = free)
    owner: Vec<Option<PartitionId>>,
    next_id: u32,
    live: BTreeMap<PartitionId, Partition>,
}

impl LaneAllocator {
    pub fn new(config: GtaConfig) -> Self {
        LaneAllocator {
            // lint: allow(R1) u32 -> usize is a lossless widening on every supported target
            owner: vec![None; config.lanes as usize],
            config,
            next_id: 0,
            live: BTreeMap::new(),
        }
    }

    pub fn free_lanes(&self) -> u32 {
        // owner.len() == config.lanes, which is a u32 by construction
        u32::try_from(self.owner.iter().filter(|o| o.is_none()).count()).unwrap_or(u32::MAX)
    }

    /// Occupancy snapshot for rack-level accounting.
    pub fn usage(&self) -> LaneUsage {
        LaneUsage {
            total: self.config.lanes,
            free: self.free_lanes(),
            live_partitions: self.live.len(),
        }
    }

    /// How many partitions the mask word width can express.
    fn max_partitions(&self) -> u64 {
        1u64 << self.config.mask_bits.min(32)
    }

    /// The all-ones "parked" mask for free lanes.
    fn parked_mask(&self) -> u32 {
        // max_partitions() <= 1 << 32, so the all-ones word fits a u32
        u32::try_from(self.max_partitions() - 1).unwrap_or(u32::MAX)
    }

    /// Next partition id not currently live. Ids recycle: a counter that
    /// wrapped past u32::MAX skips ids still in use instead of colliding
    /// (live partitions are bounded by the mask width, so a free id is
    /// found within `live.len() + 1` probes).
    fn fresh_id(&self) -> Option<PartitionId> {
        let mut id = self.next_id;
        for _ in 0..=self.live.len() {
            let cand = PartitionId(id);
            if !self.live.contains_key(&cand) {
                return Some(cand);
            }
            id = id.wrapping_add(1);
        }
        None
    }

    /// Allocate `n` contiguous lanes (contiguity is what the slide unit's
    /// shuffle program requires). Returns None — never panics — when
    /// fragmented/full, when the mask width cannot express another
    /// partition, or when no partition id is free.
    pub fn allocate(&mut self, n: u32) -> Option<Partition> {
        if n == 0 || n > self.config.lanes {
            return None;
        }
        let max_parts = self.max_partitions();
        if self.live.len() as u64 >= max_parts {
            return None;
        }
        // Pick identity BEFORE touching `owner`: every early return must
        // leave the allocator unchanged. (The pre-rack code unwrapped the
        // mask search after marking lanes, so an exhausted mask space
        // panicked mid-mutation and leaked the marked lanes.)
        let used: Vec<u32> = self.live.values().map(|p| p.mask).collect();
        let mask = (0..max_parts)
            .map(|m| u32::try_from(m).unwrap_or(u32::MAX))
            .find(|m| !used.contains(m))?;
        let id = self.fresh_id()?;
        // first-fit contiguous scan
        let lanes = self.owner.len();
        // lint: allow(R1) u32 -> usize is a lossless widening on every supported target
        let want = n as usize;
        let mut start = 0usize;
        while start + want <= lanes {
            if self.owner[start..start + want].iter().all(Option::is_none) {
                // start indexes a u32-sized lane table, so it fits a u32
                let base = u32::try_from(start).unwrap_or(u32::MAX);
                let lane_ids: Vec<u32> = (base..base + n).collect();
                for slot in &mut self.owner[start..start + want] {
                    *slot = Some(id);
                }
                let part = Partition { id, lanes: lane_ids, mask };
                self.live.insert(id, part.clone());
                self.next_id = id.0.wrapping_add(1);
                return Some(part);
            }
            start += 1;
        }
        None
    }

    /// Release a partition's lanes.
    pub fn release(&mut self, id: PartitionId) -> bool {
        if self.live.remove(&id).is_none() {
            return false;
        }
        for o in self.owner.iter_mut() {
            if *o == Some(id) {
                *o = None;
            }
        }
        true
    }

    /// Produce the SysCSR mask-group field for the current allocation:
    /// owned lanes carry their partition's mask; free lanes get the
    /// all-ones "parked" mask.
    pub fn mask_groups(&self) -> Vec<u32> {
        let parked = self.parked_mask();
        self.owner
            .iter()
            .map(|o| match o {
                // a stale owner entry (a bug) degrades to the parked
                // mask instead of panicking the serving path
                Some(id) => self.live.get(id).map_or(parked, |p| p.mask),
                None => parked,
            })
            .collect()
    }

    /// Build a SysCSR for one live partition (sub-array launch).
    pub fn syscsr_for(&self, id: PartitionId, mode: crate::arch::Dataflow) -> Option<SysCsr> {
        let part = self.live.get(&id)?;
        // a partition's lane list is bounded by config.lanes, a u32
        let n = u32::try_from(part.lanes.len()).unwrap_or(u32::MAX);
        // widest arrangement that factors the partition
        let rows = (1..=n).rev().find(|d| n % d == 0 && *d * *d <= n).unwrap_or(1);
        Some(SysCsr {
            global_layout: Arrangement::new(rows, n / rows),
            systolic_mode: mode,
            mask_groups: self.mask_groups(),
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::Dataflow;

    #[test]
    fn allocate_release_cycle() {
        let mut a = LaneAllocator::new(GtaConfig::lanes16());
        let p1 = a.allocate(8).unwrap();
        let p2 = a.allocate(8).unwrap();
        assert_eq!(a.free_lanes(), 0);
        assert!(a.allocate(1).is_none(), "pool exhausted");
        assert_ne!(p1.mask, p2.mask, "partitions must have distinct masks");
        assert!(a.release(p1.id));
        assert_eq!(a.free_lanes(), 8);
        assert!(a.allocate(8).is_some());
        assert!(!a.release(p1.id), "double release rejected");
        let _ = p2;
    }

    #[test]
    fn contiguity_respected() {
        let mut a = LaneAllocator::new(GtaConfig::lanes16());
        let p1 = a.allocate(6).unwrap();
        let _p2 = a.allocate(6).unwrap();
        a.release(p1.id);
        // 6 free at the front, 4 at the back: a 5-lane ask fits in front
        let p3 = a.allocate(5).unwrap();
        assert_eq!(p3.lanes, vec![0, 1, 2, 3, 4]);
        // 8 contiguous no longer exists
        assert!(a.allocate(8).is_none());
    }

    #[test]
    fn mask_groups_reflect_ownership() {
        let mut a = LaneAllocator::new(GtaConfig::lanes16());
        let p = a.allocate(4).unwrap();
        let masks = a.mask_groups();
        assert_eq!(masks.len(), 16);
        for l in 0..4 {
            assert_eq!(masks[l], p.mask);
        }
        let parked = (1 << 4) - 1;
        assert!(masks[4..].iter().all(|&m| m == parked));
    }

    #[test]
    fn partition_count_bounded_by_mask_width() {
        let mut cfg = GtaConfig::lanes16();
        cfg.mask_bits = 1; // only 2 expressible partitions
        let mut a = LaneAllocator::new(cfg);
        assert!(a.allocate(2).is_some());
        assert!(a.allocate(2).is_some());
        assert!(a.allocate(2).is_none(), "mask width exhausted");
    }

    #[test]
    fn churn_past_max_parts_recycles_masks_without_panicking() {
        let mut cfg = GtaConfig::lanes16();
        cfg.mask_bits = 2; // 4 expressible partitions
        let mut a = LaneAllocator::new(cfg);
        // far more lifetime allocations than max_parts: masks must recycle
        for round in 0..64 {
            let p = a.allocate(4).unwrap_or_else(|| panic!("round {round} must allocate"));
            assert!(p.mask < 4, "mask within width: {}", p.mask);
            assert!(a.release(p.id));
        }
        // exhausting the mask space is a None, not a panic, and leaves
        // the pool untouched (no lanes leaked by a partial allocation)
        let held: Vec<Partition> = (0..4).map(|_| a.allocate(2).unwrap()).collect();
        assert!(a.allocate(2).is_none(), "mask width exhausted");
        assert_eq!(a.free_lanes(), 16 - 8, "failed allocate must not leak lanes");
        let masks: std::collections::HashSet<u32> = held.iter().map(|p| p.mask).collect();
        assert_eq!(masks.len(), 4, "all four masks in use, none duplicated");
        for p in &held {
            assert!(a.release(p.id));
        }
        assert_eq!(a.free_lanes(), 16);
        assert_eq!(a.usage(), LaneUsage { total: 16, free: 16, live_partitions: 0 });
    }

    #[test]
    fn partition_ids_recycle_across_u32_wrap() {
        let mut a = LaneAllocator::new(GtaConfig::lanes16());
        a.next_id = u32::MAX;
        let p1 = a.allocate(2).unwrap();
        assert_eq!(p1.id, PartitionId(u32::MAX));
        let p2 = a.allocate(2).unwrap();
        assert_eq!(p2.id, PartitionId(0), "id counter wraps instead of overflowing");
        a.next_id = u32::MAX; // force a probe over the still-live id
        let p3 = a.allocate(2).unwrap();
        assert_ne!(p3.id, p1.id, "live ids are skipped, not reissued");
        assert_eq!(a.usage().live_partitions, 3);
    }

    #[test]
    fn usage_tracks_allocation_lifecycle() {
        let mut a = LaneAllocator::new(GtaConfig::lanes16());
        assert_eq!(a.usage(), LaneUsage { total: 16, free: 16, live_partitions: 0 });
        let p = a.allocate(6).unwrap();
        assert_eq!(a.usage(), LaneUsage { total: 16, free: 10, live_partitions: 1 });
        a.release(p.id);
        assert_eq!(a.usage().free, 16);
    }

    #[test]
    fn syscsr_from_partition_validates() {
        let cfg = GtaConfig::lanes16();
        let mut a = LaneAllocator::new(cfg);
        let p = a.allocate(4).unwrap();
        let csr = a.syscsr_for(p.id, Dataflow::WS).unwrap();
        assert_eq!(csr.global_layout.lanes(), 4);
        assert_eq!(csr.mask_groups.len(), 16);
    }
}
