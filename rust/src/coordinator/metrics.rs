//! Coordinator metrics: request counters, schedule-cache statistics,
//! admission/coalescing telemetry and latency percentiles, shared across
//! worker threads.
//!
//! Latencies are kept in a fixed-size reservoir (Vitter's Algorithm R)
//! instead of an unbounded vector, so a long-lived server records
//! millions of requests in O(1) memory while p50/p95/p99 stay within
//! sampling error; the mean is exact (running sum / count).

use crate::util::rng::Rng;
use std::collections::BTreeMap;
use std::sync::Mutex;
use std::time::Duration;

/// Latency samples retained for percentile estimation. 4096 samples put
/// the p99 estimate within ~a tenth of a percentile rank of truth.
pub const LATENCY_RESERVOIR_CAP: usize = 4096;

#[derive(Debug, Default)]
struct Inner {
    requests: u64,
    pgemm_ops: u64,
    vector_ops: u64,
    functional_execs: u64,
    functional_errors: u64,
    schedule_cache_hits: u64,
    schedule_cache_misses: u64,
    per_artifact: BTreeMap<String, u64>,
    // admission queue
    admission_rejected: u64,
    admission_requeued: u64,
    queue_peak_depth: u64,
    // coalescing dispatcher
    batches: u64,
    batched_requests: u64,
    batch_hist: BTreeMap<u64, u64>,
    // latency reservoir (Algorithm R); rng seeded lazily on first overflow
    lat_count: u64,
    lat_sum_us: u64,
    lat_reservoir: Vec<u64>,
    lat_rng: Option<Rng>,
}

/// Thread-safe metrics sink.
#[derive(Debug, Default)]
pub struct Metrics {
    inner: Mutex<Inner>,
}

/// A frozen snapshot for reporting.
#[derive(Debug, Clone)]
pub struct Snapshot {
    pub requests: u64,
    pub pgemm_ops: u64,
    pub vector_ops: u64,
    pub functional_execs: u64,
    pub functional_errors: u64,
    pub schedule_cache_hits: u64,
    pub schedule_cache_misses: u64,
    pub per_artifact: BTreeMap<String, u64>,
    pub admission_rejected: u64,
    pub admission_requeued: u64,
    pub queue_peak_depth: u64,
    /// Coalesced dispatches issued to the executor.
    pub batches: u64,
    /// Functional invocations carried by those dispatches.
    pub batched_requests: u64,
    /// batch size -> number of dispatches of that size.
    pub batch_hist: BTreeMap<u64, u64>,
    /// Largest coalesced batch dispatched.
    pub max_batch: u64,
    /// Latencies recorded (reservoir holds at most
    /// [`LATENCY_RESERVOIR_CAP`] of them).
    pub latency_count: u64,
    pub p50_us: u64,
    pub p95_us: u64,
    pub p99_us: u64,
    pub mean_us: f64,
}

impl Metrics {
    pub fn record_request(&self, is_pgemm: bool, latency: Duration) {
        let mut m = self.inner.lock().unwrap();
        m.requests += 1;
        if is_pgemm {
            m.pgemm_ops += 1;
        } else {
            m.vector_ops += 1;
        }
        let us = latency.as_micros() as u64;
        m.lat_count += 1;
        m.lat_sum_us += us;
        if m.lat_reservoir.len() < LATENCY_RESERVOIR_CAP {
            m.lat_reservoir.push(us);
        } else {
            // Algorithm R: keep each of the lat_count samples with equal
            // probability CAP/count
            let count = m.lat_count;
            let j = m.lat_rng.get_or_insert_with(|| Rng::new(0x6A7A_5EED)).next_u64() % count;
            if (j as usize) < LATENCY_RESERVOIR_CAP {
                m.lat_reservoir[j as usize] = us;
            }
        }
    }

    pub fn record_functional(&self, artifact: &str) {
        let mut m = self.inner.lock().unwrap();
        m.functional_execs += 1;
        *m.per_artifact.entry(artifact.to_string()).or_insert(0) += 1;
    }

    /// A functional execution that came back as an error (the request
    /// still gets a response — this is the drop-free failure path).
    pub fn record_functional_error(&self) {
        self.inner.lock().unwrap().functional_errors += 1;
    }

    pub fn record_cache(&self, hit: bool) {
        let mut m = self.inner.lock().unwrap();
        if hit {
            m.schedule_cache_hits += 1;
        } else {
            m.schedule_cache_misses += 1;
        }
    }

    /// Admission-queue depth observed after an admit (peak is kept).
    pub fn record_queue_depth(&self, depth: usize) {
        let mut m = self.inner.lock().unwrap();
        m.queue_peak_depth = m.queue_peak_depth.max(depth as u64);
    }

    pub fn record_admission_rejected(&self) {
        self.inner.lock().unwrap().admission_rejected += 1;
    }

    pub fn record_admission_requeued(&self) {
        self.inner.lock().unwrap().admission_requeued += 1;
    }

    /// One coalesced dispatch of `size` same-(artifact, shape) requests.
    pub fn record_batch(&self, size: usize) {
        let mut m = self.inner.lock().unwrap();
        m.batches += 1;
        m.batched_requests += size as u64;
        *m.batch_hist.entry(size as u64).or_insert(0) += 1;
    }

    pub fn snapshot(&self) -> Snapshot {
        let m = self.inner.lock().unwrap();
        let mut lat = m.lat_reservoir.clone();
        lat.sort_unstable();
        let pct = |p: f64| -> u64 {
            if lat.is_empty() {
                0
            } else {
                lat[((lat.len() as f64 - 1.0) * p) as usize]
            }
        };
        Snapshot {
            requests: m.requests,
            pgemm_ops: m.pgemm_ops,
            vector_ops: m.vector_ops,
            functional_execs: m.functional_execs,
            functional_errors: m.functional_errors,
            schedule_cache_hits: m.schedule_cache_hits,
            schedule_cache_misses: m.schedule_cache_misses,
            per_artifact: m.per_artifact.clone(),
            admission_rejected: m.admission_rejected,
            admission_requeued: m.admission_requeued,
            queue_peak_depth: m.queue_peak_depth,
            batches: m.batches,
            batched_requests: m.batched_requests,
            batch_hist: m.batch_hist.clone(),
            max_batch: m.batch_hist.keys().next_back().copied().unwrap_or(0),
            latency_count: m.lat_count,
            p50_us: pct(0.50),
            p95_us: pct(0.95),
            p99_us: pct(0.99),
            mean_us: if m.lat_count == 0 {
                0.0
            } else {
                m.lat_sum_us as f64 / m.lat_count as f64
            },
        }
    }
}

impl Snapshot {
    /// Mean coalesced batch size (1.0 when nothing was batched).
    pub fn mean_batch(&self) -> f64 {
        if self.batches == 0 {
            1.0
        } else {
            self.batched_requests as f64 / self.batches as f64
        }
    }

    pub fn render(&self) -> String {
        let mut s = format!(
            "requests={} (pgemm={} vector={})  functional={} ({} errors)  cache {}/{} hit\n\
             latency: p50={}us p95={}us p99={}us mean={:.1}us ({} recorded)\n\
             serving: queue peak={}  batches={} (mean {:.2}, max {})  \
             admission rejected={} requeued={}\n",
            self.requests,
            self.pgemm_ops,
            self.vector_ops,
            self.functional_execs,
            self.functional_errors,
            self.schedule_cache_hits,
            self.schedule_cache_hits + self.schedule_cache_misses,
            self.p50_us,
            self.p95_us,
            self.p99_us,
            self.mean_us,
            self.latency_count,
            self.queue_peak_depth,
            self.batches,
            self.mean_batch(),
            self.max_batch,
            self.admission_rejected,
            self.admission_requeued,
        );
        for (name, n) in &self.per_artifact {
            s.push_str(&format!("  artifact {name}: {n} execs\n"));
        }
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn percentiles_and_counts() {
        let m = Metrics::default();
        for i in 1..=100u64 {
            m.record_request(i % 2 == 0, Duration::from_micros(i));
        }
        m.record_cache(true);
        m.record_cache(false);
        m.record_functional("k");
        let s = m.snapshot();
        assert_eq!(s.requests, 100);
        assert_eq!(s.pgemm_ops, 50);
        assert_eq!(s.p50_us, 50);
        assert_eq!(s.p99_us, 99);
        assert_eq!(s.schedule_cache_hits, 1);
        assert_eq!(s.per_artifact["k"], 1);
        assert!(s.render().contains("p50=50us"));
    }

    #[test]
    fn latency_reservoir_is_bounded_with_percentiles_in_sampling_error() {
        let m = Metrics::default();
        let n = 50_000u64;
        for i in 1..=n {
            m.record_request(false, Duration::from_micros(i));
        }
        let s = m.snapshot();
        assert_eq!(s.latency_count, n);
        // memory stays bounded
        assert!(m.inner.lock().unwrap().lat_reservoir.len() <= LATENCY_RESERVOIR_CAP);
        // mean is exact, percentiles within sampling error of the uniform
        // 1..=n distribution (a generous 5% of range for cap=4096)
        assert!((s.mean_us - (n + 1) as f64 / 2.0).abs() < 1.0);
        let tol = n as f64 * 0.05;
        assert!((s.p50_us as f64 - n as f64 * 0.50).abs() < tol, "p50={}", s.p50_us);
        assert!((s.p95_us as f64 - n as f64 * 0.95).abs() < tol, "p95={}", s.p95_us);
        assert!((s.p99_us as f64 - n as f64 * 0.99).abs() < tol, "p99={}", s.p99_us);
    }

    #[test]
    fn serving_counters_roll_up() {
        let m = Metrics::default();
        m.record_queue_depth(3);
        m.record_queue_depth(9);
        m.record_queue_depth(5);
        m.record_admission_rejected();
        m.record_admission_requeued();
        m.record_admission_requeued();
        m.record_batch(1);
        m.record_batch(4);
        m.record_batch(4);
        m.record_functional_error();
        let s = m.snapshot();
        assert_eq!(s.queue_peak_depth, 9);
        assert_eq!(s.admission_rejected, 1);
        assert_eq!(s.admission_requeued, 2);
        assert_eq!(s.batches, 3);
        assert_eq!(s.batched_requests, 9);
        assert_eq!(s.batch_hist[&4], 2);
        assert_eq!(s.max_batch, 4);
        assert!((s.mean_batch() - 3.0).abs() < 1e-12);
        assert_eq!(s.functional_errors, 1);
        assert!(s.render().contains("batches=3"));
    }
}
