//! Coordinator metrics: request counters, schedule-cache statistics,
//! admission/coalescing telemetry and latency percentiles, shared across
//! worker threads. In a multi-GTA rack every shard owns one [`Metrics`];
//! [`ShardTelemetry`]/[`RackSnapshot`] roll the per-shard snapshots into
//! the rack-wide aggregate utilization/traffic report.
//!
//! Latencies are kept in a fixed-size reservoir (Vitter's Algorithm R)
//! instead of an unbounded vector, so a long-lived server records
//! millions of requests in O(1) memory while p50/p95/p99 stay within
//! sampling error; the mean is exact (running sum / count). Alongside
//! the reservoir, every latency also lands in a log-bucket
//! [`Histogram`] and per-stage timings in a [`StageHists`]
//! (`record_stage`) — unlike reservoirs, those merge **exactly** across
//! shards, so [`Snapshot::absorb`] derives aggregate p50/p95/p99 from
//! the merged buckets instead of the old lossy worst-shard maximum
//! (see `docs/observability.md`).

use super::lane_scheduler::LaneUsage;
use crate::obs::{Histogram, Stage, StageHists};
use crate::util::rng::Rng;
use std::collections::BTreeMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;
use std::time::Duration;

/// Latency samples retained for percentile estimation. 4096 samples put
/// the p99 estimate within ~a tenth of a percentile rank of truth.
pub const LATENCY_RESERVOIR_CAP: usize = 4096;

#[derive(Debug, Default)]
struct Inner {
    requests: u64,
    pgemm_ops: u64,
    vector_ops: u64,
    functional_execs: u64,
    functional_errors: u64,
    schedule_cache_hits: u64,
    schedule_cache_misses: u64,
    per_artifact: BTreeMap<String, u64>,
    // admission queue
    admission_rejected: u64,
    admission_requeued: u64,
    queue_peak_depth: u64,
    // coalescing dispatcher
    batches: u64,
    batched_requests: u64,
    batch_hist: BTreeMap<u64, u64>,
    batch_exec_us: u64,
    // simulated work (one record per handled request)
    sim_cycles: u64,
    sim_util_sum: f64,
    // live coalescing window (static config or the adaptive controller's
    // latest choice)
    coalesce_window_us: u64,
    // latency reservoir (Algorithm R); rng seeded lazily on first overflow
    lat_count: u64,
    lat_sum_us: u64,
    lat_reservoir: Vec<u64>,
    lat_rng: Option<Rng>,
    // exact-merging log-bucket histograms: whole-request latency plus
    // per-pipeline-stage timings (always on — they live under the same
    // mutex the counters already take)
    lat_hist: Histogram,
    stage_hist: StageHists,
}

/// Thread-safe metrics sink.
#[derive(Debug, Default)]
pub struct Metrics {
    inner: Mutex<Inner>,
    /// Smoothed request latency (µs, f64 bits) kept OUTSIDE the mutex so
    /// routing policies can read it per-request without taking the lock.
    lat_ewma_bits: AtomicU64,
}

/// A frozen snapshot for reporting.
#[derive(Debug, Clone, Default)]
pub struct Snapshot {
    pub requests: u64,
    pub pgemm_ops: u64,
    pub vector_ops: u64,
    pub functional_execs: u64,
    pub functional_errors: u64,
    pub schedule_cache_hits: u64,
    pub schedule_cache_misses: u64,
    pub per_artifact: BTreeMap<String, u64>,
    pub admission_rejected: u64,
    pub admission_requeued: u64,
    pub queue_peak_depth: u64,
    /// Coalesced dispatches issued to the executor.
    pub batches: u64,
    /// Functional invocations carried by those dispatches.
    pub batched_requests: u64,
    /// batch size -> number of dispatches of that size.
    pub batch_hist: BTreeMap<u64, u64>,
    /// Largest coalesced batch dispatched.
    pub max_batch: u64,
    /// Cumulative wall time (µs) the executor thread spent inside backend
    /// `execute_batch` calls — against `batched_requests` it gives the
    /// served kernel cost per tile (the number the parallel soft-backend
    /// fan-out drives down).
    pub batch_exec_us: u64,
    /// Total simulated GTA cycles across handled requests.
    pub sim_cycles: u64,
    /// Mean simulated PE utilization across handled requests.
    pub mean_sim_utilization: f64,
    /// Coalescing window in effect at snapshot time (µs): the static
    /// config, or the adaptive controller's latest choice.
    pub coalesce_window_us: u64,
    /// Smoothed (EWMA, α=0.25) request latency in µs — the live load
    /// signal routing policies read (percentiles below are reservoir
    /// estimates; this one tracks the present, not the whole run).
    pub latency_ewma_us: f64,
    /// Latencies recorded (reservoir holds at most
    /// [`LATENCY_RESERVOIR_CAP`] of them).
    pub latency_count: u64,
    pub p50_us: u64,
    pub p95_us: u64,
    pub p99_us: u64,
    pub mean_us: f64,
    /// Log-bucket histogram of every recorded request latency. Merges
    /// exactly in [`Snapshot::absorb`], which is where the aggregate
    /// p50/p95/p99 above come from once more than one shard
    /// contributes.
    pub lat_hist: Histogram,
    /// Per-pipeline-stage latency histograms (admit, route, schedule,
    /// coalesce, execute, respond, …) — the `ServeSummary` breakdown
    /// table and the `Stats` wire frame read these.
    pub stage_hist: StageHists,
}

impl Metrics {
    pub fn record_request(&self, is_pgemm: bool, latency: Duration) {
        let mut m = self.inner.lock().unwrap_or_else(|e| e.into_inner());
        m.requests += 1;
        if is_pgemm {
            m.pgemm_ops += 1;
        } else {
            m.vector_ops += 1;
        }
        let us = latency.as_micros() as u64;
        m.lat_count += 1;
        m.lat_sum_us += us;
        m.lat_hist.record(us);
        let ewma = if m.lat_count == 1 {
            us as f64
        } else {
            // lint: relaxed-ok ewma cell is self-contained; updates happen under the inner mutex
            0.75 * f64::from_bits(self.lat_ewma_bits.load(Ordering::Relaxed)) + 0.25 * us as f64
        };
        // lint: relaxed-ok ewma cell is self-contained; updates happen under the inner mutex
        self.lat_ewma_bits.store(ewma.to_bits(), Ordering::Relaxed);
        if m.lat_reservoir.len() < LATENCY_RESERVOIR_CAP {
            m.lat_reservoir.push(us);
        } else {
            // Algorithm R: keep each of the lat_count samples with equal
            // probability CAP/count
            let count = m.lat_count;
            let j = m.lat_rng.get_or_insert_with(|| Rng::new(0x6A7A_5EED)).next_u64() % count;
            if (j as usize) < LATENCY_RESERVOIR_CAP {
                m.lat_reservoir[j as usize] = us;
            }
        }
    }

    pub fn record_functional(&self, artifact: &str) {
        let mut m = self.inner.lock().unwrap_or_else(|e| e.into_inner());
        m.functional_execs += 1;
        *m.per_artifact.entry(artifact.to_string()).or_insert(0) += 1;
    }

    /// A functional execution that came back as an error (the request
    /// still gets a response — this is the drop-free failure path).
    pub fn record_functional_error(&self) {
        self.inner.lock().unwrap_or_else(|e| e.into_inner()).functional_errors += 1;
    }

    pub fn record_cache(&self, hit: bool) {
        let mut m = self.inner.lock().unwrap_or_else(|e| e.into_inner());
        if hit {
            m.schedule_cache_hits += 1;
        } else {
            m.schedule_cache_misses += 1;
        }
    }

    /// Admission-queue depth observed after an admit (peak is kept).
    pub fn record_queue_depth(&self, depth: usize) {
        let mut m = self.inner.lock().unwrap_or_else(|e| e.into_inner());
        m.queue_peak_depth = m.queue_peak_depth.max(depth as u64);
    }

    pub fn record_admission_rejected(&self) {
        self.inner.lock().unwrap_or_else(|e| e.into_inner()).admission_rejected += 1;
    }

    pub fn record_admission_requeued(&self) {
        self.inner.lock().unwrap_or_else(|e| e.into_inner()).admission_requeued += 1;
    }

    /// Simulated cycles/utilization of one handled request (called once
    /// per request, so the utilization mean weights by request count).
    pub fn record_sim(&self, cycles: u64, utilization: f64) {
        let mut m = self.inner.lock().unwrap_or_else(|e| e.into_inner());
        m.sim_cycles += cycles;
        m.sim_util_sum += utilization;
    }

    /// Time one request spent in one pipeline stage (µs). Always on —
    /// this is the per-stage breakdown `ServeSummary` and the `Stats`
    /// wire frame report, independent of span tracing being enabled.
    pub fn record_stage(&self, stage: Stage, us: u64) {
        self.inner.lock().unwrap_or_else(|e| e.into_inner()).stage_hist.record(stage, us);
    }

    /// Smoothed request latency in µs (0.0 before the first request).
    /// Lock-free — safe to call once per shard per routed request.
    pub fn latency_ewma_us(&self) -> f64 {
        // lint: relaxed-ok ewma cell is self-contained; a stale read only ages the load signal
        f64::from_bits(self.lat_ewma_bits.load(Ordering::Relaxed))
    }

    /// The coalescing window currently in effect (static or adaptive).
    pub fn record_window(&self, us: u64) {
        self.inner.lock().unwrap_or_else(|e| e.into_inner()).coalesce_window_us = us;
    }

    /// One coalesced dispatch of `size` same-(artifact, shape) requests.
    pub fn record_batch(&self, size: usize) {
        let mut m = self.inner.lock().unwrap_or_else(|e| e.into_inner());
        m.batches += 1;
        m.batched_requests += size as u64;
        *m.batch_hist.entry(size as u64).or_insert(0) += 1;
    }

    /// Wall time of one backend `execute_batch` call, measured on the
    /// executor thread around the whole (possibly parallel) fan-out.
    pub fn record_batch_exec(&self, us: u64) {
        self.inner.lock().unwrap_or_else(|e| e.into_inner()).batch_exec_us += us;
    }

    pub fn snapshot(&self) -> Snapshot {
        let m = self.inner.lock().unwrap_or_else(|e| e.into_inner());
        let mut lat = m.lat_reservoir.clone();
        lat.sort_unstable();
        let pct = |p: f64| -> u64 {
            if lat.is_empty() {
                0
            } else {
                lat[((lat.len() as f64 - 1.0) * p) as usize]
            }
        };
        Snapshot {
            requests: m.requests,
            pgemm_ops: m.pgemm_ops,
            vector_ops: m.vector_ops,
            functional_execs: m.functional_execs,
            functional_errors: m.functional_errors,
            schedule_cache_hits: m.schedule_cache_hits,
            schedule_cache_misses: m.schedule_cache_misses,
            per_artifact: m.per_artifact.clone(),
            admission_rejected: m.admission_rejected,
            admission_requeued: m.admission_requeued,
            queue_peak_depth: m.queue_peak_depth,
            batches: m.batches,
            batched_requests: m.batched_requests,
            batch_hist: m.batch_hist.clone(),
            max_batch: m.batch_hist.keys().next_back().copied().unwrap_or(0),
            batch_exec_us: m.batch_exec_us,
            sim_cycles: m.sim_cycles,
            mean_sim_utilization: if m.requests == 0 {
                0.0
            } else {
                m.sim_util_sum / m.requests as f64
            },
            coalesce_window_us: m.coalesce_window_us,
            // lint: relaxed-ok ewma cell is self-contained; see latency_ewma_us
            latency_ewma_us: f64::from_bits(self.lat_ewma_bits.load(Ordering::Relaxed)),
            latency_count: m.lat_count,
            lat_hist: m.lat_hist.clone(),
            stage_hist: m.stage_hist.clone(),
            p50_us: pct(0.50),
            p95_us: pct(0.95),
            p99_us: pct(0.99),
            mean_us: if m.lat_count == 0 {
                0.0
            } else {
                m.lat_sum_us as f64 / m.lat_count as f64
            },
        }
    }
}

impl Snapshot {
    /// Mean coalesced batch size (1.0 when nothing was batched).
    pub fn mean_batch(&self) -> f64 {
        if self.batches == 0 {
            1.0
        } else {
            self.batched_requests as f64 / self.batches as f64
        }
    }

    /// Fold another shard's snapshot into this one for a rack-level
    /// aggregate: counters, histograms and sim cycles sum; means are
    /// re-weighted by their sample counts; `queue_peak_depth`,
    /// `max_batch`, the coalescing window and the latency EWMA take the
    /// per-shard maximum. The latency percentiles are derived from the
    /// **exactly merged** log-bucket histograms — correct to bucket
    /// resolution however many shards contribute — falling back to the
    /// old conservative worst-shard maximum only when a contributing
    /// snapshot carries no histogram (a pre-histogram wire peer).
    pub fn absorb(&mut self, o: &Snapshot) {
        // weighted means first, while `self` still holds its own counts
        let lat_n = self.latency_count + o.latency_count;
        if lat_n > 0 {
            self.mean_us = (self.mean_us * self.latency_count as f64
                + o.mean_us * o.latency_count as f64)
                / lat_n as f64;
        }
        // a recency signal, not a lifetime one: count-weighting would let
        // a long-lived shard's stale EWMA mask a currently-slow shard, so
        // the aggregate takes the conservative worst-shard value (like
        // the latency tails below)
        self.latency_ewma_us = self.latency_ewma_us.max(o.latency_ewma_us);
        let req_n = self.requests + o.requests;
        if req_n > 0 {
            self.mean_sim_utilization = (self.mean_sim_utilization * self.requests as f64
                + o.mean_sim_utilization * o.requests as f64)
                / req_n as f64;
        }
        self.requests += o.requests;
        self.pgemm_ops += o.pgemm_ops;
        self.vector_ops += o.vector_ops;
        self.functional_execs += o.functional_execs;
        self.functional_errors += o.functional_errors;
        self.schedule_cache_hits += o.schedule_cache_hits;
        self.schedule_cache_misses += o.schedule_cache_misses;
        for (name, n) in &o.per_artifact {
            *self.per_artifact.entry(name.clone()).or_insert(0) += n;
        }
        self.admission_rejected += o.admission_rejected;
        self.admission_requeued += o.admission_requeued;
        self.queue_peak_depth = self.queue_peak_depth.max(o.queue_peak_depth);
        self.batches += o.batches;
        self.batched_requests += o.batched_requests;
        for (sz, cnt) in &o.batch_hist {
            *self.batch_hist.entry(*sz).or_insert(0) += cnt;
        }
        self.max_batch = self.max_batch.max(o.max_batch);
        self.batch_exec_us += o.batch_exec_us;
        self.sim_cycles += o.sim_cycles;
        self.coalesce_window_us = self.coalesce_window_us.max(o.coalesce_window_us);
        self.latency_count += o.latency_count;
        self.lat_hist.merge(&o.lat_hist);
        self.stage_hist.merge(&o.stage_hist);
        if self.lat_hist.count() == self.latency_count && self.latency_count > 0 {
            // every recorded latency is in the merged histogram: the
            // aggregate percentiles are exact to bucket resolution
            self.p50_us = self.lat_hist.value_at_quantile(0.50);
            self.p95_us = self.lat_hist.value_at_quantile(0.95);
            self.p99_us = self.lat_hist.value_at_quantile(0.99);
        } else {
            // a contributor lacked histogram data (old-version wire
            // peer): keep the legacy conservative worst-shard tail
            self.p50_us = self.p50_us.max(o.p50_us);
            self.p95_us = self.p95_us.max(o.p95_us);
            self.p99_us = self.p99_us.max(o.p99_us);
        }
    }

    pub fn render(&self) -> String {
        let mut s = format!(
            "requests={} (pgemm={} vector={})  functional={} ({} errors)  cache {}/{} hit\n\
             latency: p50={}us p95={}us p99={}us mean={:.1}us ewma={:.1}us ({} recorded)\n\
             serving: queue peak={}  batches={} (mean {:.2}, max {}, window {}us, exec {}us)  \
             admission rejected={} requeued={}\n",
            self.requests,
            self.pgemm_ops,
            self.vector_ops,
            self.functional_execs,
            self.functional_errors,
            self.schedule_cache_hits,
            self.schedule_cache_hits + self.schedule_cache_misses,
            self.p50_us,
            self.p95_us,
            self.p99_us,
            self.mean_us,
            self.latency_ewma_us,
            self.latency_count,
            self.queue_peak_depth,
            self.batches,
            self.mean_batch(),
            self.max_batch,
            self.coalesce_window_us,
            self.batch_exec_us,
            self.admission_rejected,
            self.admission_requeued,
        );
        for (name, n) in &self.per_artifact {
            s.push_str(&format!("  artifact {name}: {n} execs\n"));
        }
        s
    }
}

/// Per-shard slice of a rack's telemetry: the shard's own [`Snapshot`]
/// plus its identity (config fingerprint — shards with equal
/// fingerprints share schedule-cache entries rack-wide), routing share
/// and lane occupancy.
#[derive(Debug, Clone)]
pub struct ShardTelemetry {
    pub shard: usize,
    pub lanes: u32,
    /// [`crate::arch::GtaConfig::fingerprint`] of the shard's config.
    pub config_fingerprint: u64,
    /// Requests the routing policy placed on this shard.
    pub routed: u64,
    /// Requests waiting to enter or sitting in an admission queue for
    /// this shard, not yet picked up by a worker — the live
    /// queue-pressure gauge a session exposes per shard.
    pub queued: u64,
    pub lane_usage: LaneUsage,
    pub snapshot: Snapshot,
}

/// Live network-serving gauges and counters, attached to a
/// [`RackSnapshot`] when the rack is fronted by a server: connection
/// and logical-session gauges (current, not cumulative) plus total
/// wire bytes in each direction summed over all connections, live and
/// closed.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct NetGauges {
    pub active_connections: u64,
    pub active_sessions: u64,
    pub bytes_in: u64,
    pub bytes_out: u64,
}

impl NetGauges {
    pub fn render(&self) -> String {
        format!(
            "  net: {} connections, {} sessions active  wire bytes in={} out={}\n",
            self.active_connections, self.active_sessions, self.bytes_in, self.bytes_out,
        )
    }
}

/// Rack-wide telemetry: per-shard counters plus the aggregate rollup
/// (the ROADMAP "aggregate utilization/traffic per shard" report).
#[derive(Debug, Clone)]
pub struct RackSnapshot {
    pub shards: Vec<ShardTelemetry>,
    pub aggregate: Snapshot,
    /// Network-serving gauges — `None` for a rack not behind a server.
    pub net: Option<NetGauges>,
}

impl RackSnapshot {
    pub fn from_shards(shards: Vec<ShardTelemetry>) -> RackSnapshot {
        let mut aggregate = Snapshot::default();
        for t in &shards {
            aggregate.absorb(&t.snapshot);
        }
        RackSnapshot { shards, aggregate, net: None }
    }

    /// Attach live network gauges (builder-style, used by the servers).
    pub fn with_net(mut self, net: NetGauges) -> RackSnapshot {
        self.net = Some(net);
        self
    }

    /// Fraction of rack traffic the given shard carried (0.0 when the
    /// rack has routed nothing yet).
    pub fn traffic_share(&self, shard: usize) -> f64 {
        let total: u64 = self.shards.iter().map(|t| t.routed).sum();
        match self.shards.get(shard) {
            Some(t) if total > 0 => t.routed as f64 / total as f64,
            _ => 0.0,
        }
    }

    pub fn render(&self) -> String {
        let mut s = format!("rack: {} shards, per-shard utilization/traffic\n", self.shards.len());
        for t in &self.shards {
            s.push_str(&format!(
                "  shard {} [{} lanes, cfg {:016x}]: routed={} ({:.1}% of traffic, {} queued)  \
                 util={:.1}%  sim cycles={}  cache {}/{} hit  errors={}  \
                 lanes free {}/{} ({} partitions)\n",
                t.shard,
                t.lanes,
                t.config_fingerprint,
                t.routed,
                self.traffic_share(t.shard) * 100.0,
                t.queued,
                t.snapshot.mean_sim_utilization * 100.0,
                t.snapshot.sim_cycles,
                t.snapshot.schedule_cache_hits,
                t.snapshot.schedule_cache_hits + t.snapshot.schedule_cache_misses,
                t.snapshot.functional_errors,
                t.lane_usage.free,
                t.lane_usage.total,
                t.lane_usage.live_partitions,
            ));
        }
        if let Some(net) = &self.net {
            s.push_str(&net.render());
        }
        s.push_str(&format!("  rack aggregate: {}", self.aggregate.render()));
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn percentiles_and_counts() {
        let m = Metrics::default();
        for i in 1..=100u64 {
            m.record_request(i % 2 == 0, Duration::from_micros(i));
        }
        m.record_cache(true);
        m.record_cache(false);
        m.record_functional("k");
        let s = m.snapshot();
        assert_eq!(s.requests, 100);
        assert_eq!(s.pgemm_ops, 50);
        assert_eq!(s.p50_us, 50);
        assert_eq!(s.p99_us, 99);
        assert_eq!(s.schedule_cache_hits, 1);
        assert_eq!(s.per_artifact["k"], 1);
        assert!(s.render().contains("p50=50us"));
    }

    #[test]
    fn latency_reservoir_is_bounded_with_percentiles_in_sampling_error() {
        let m = Metrics::default();
        let n = 50_000u64;
        for i in 1..=n {
            m.record_request(false, Duration::from_micros(i));
        }
        let s = m.snapshot();
        assert_eq!(s.latency_count, n);
        // memory stays bounded
        assert!(m.inner.lock().unwrap().lat_reservoir.len() <= LATENCY_RESERVOIR_CAP);
        // mean is exact, percentiles within sampling error of the uniform
        // 1..=n distribution (a generous 5% of range for cap=4096)
        assert!((s.mean_us - (n + 1) as f64 / 2.0).abs() < 1.0);
        let tol = n as f64 * 0.05;
        assert!((s.p50_us as f64 - n as f64 * 0.50).abs() < tol, "p50={}", s.p50_us);
        assert!((s.p95_us as f64 - n as f64 * 0.95).abs() < tol, "p95={}", s.p95_us);
        assert!((s.p99_us as f64 - n as f64 * 0.99).abs() < tol, "p99={}", s.p99_us);
    }

    #[test]
    fn latency_ewma_tracks_the_recent_level() {
        let m = Metrics::default();
        assert_eq!(m.latency_ewma_us(), 0.0, "no samples yet");
        m.record_request(false, Duration::from_micros(100));
        assert!((m.latency_ewma_us() - 100.0).abs() < 1e-9, "first sample seeds the ewma");
        for _ in 0..64 {
            m.record_request(false, Duration::from_micros(10));
        }
        let ewma = m.latency_ewma_us();
        assert!(ewma < 12.0, "ewma converges to the recent level, got {ewma}");
        assert!((m.snapshot().latency_ewma_us - ewma).abs() < 1e-12);
    }

    #[test]
    fn serving_counters_roll_up() {
        let m = Metrics::default();
        m.record_queue_depth(3);
        m.record_queue_depth(9);
        m.record_queue_depth(5);
        m.record_admission_rejected();
        m.record_admission_requeued();
        m.record_admission_requeued();
        m.record_batch(1);
        m.record_batch(4);
        m.record_batch(4);
        m.record_batch_exec(120);
        m.record_batch_exec(80);
        m.record_functional_error();
        let s = m.snapshot();
        assert_eq!(s.queue_peak_depth, 9);
        assert_eq!(s.admission_rejected, 1);
        assert_eq!(s.admission_requeued, 2);
        assert_eq!(s.batches, 3);
        assert_eq!(s.batched_requests, 9);
        assert_eq!(s.batch_hist[&4], 2);
        assert_eq!(s.max_batch, 4);
        assert_eq!(s.batch_exec_us, 200, "execute_batch wall times sum");
        assert!((s.mean_batch() - 3.0).abs() < 1e-12);
        assert_eq!(s.functional_errors, 1);
        assert!(s.render().contains("batches=3"));
        assert!(s.render().contains("exec 200us"), "{}", s.render());
    }

    #[test]
    fn sim_and_window_counters() {
        let m = Metrics::default();
        m.record_request(true, Duration::from_micros(5));
        m.record_request(false, Duration::from_micros(5));
        m.record_sim(100, 0.5);
        m.record_sim(300, 1.0);
        m.record_window(250);
        let s = m.snapshot();
        assert_eq!(s.sim_cycles, 400);
        assert!((s.mean_sim_utilization - 0.75).abs() < 1e-12);
        assert_eq!(s.coalesce_window_us, 250);
        assert!(s.render().contains("window 250us"), "{}", s.render());
    }

    #[test]
    fn rack_snapshot_aggregates_per_shard_counters() {
        let a = Metrics::default();
        let b = Metrics::default();
        for i in 0..10u64 {
            a.record_request(true, Duration::from_micros(10));
            a.record_sim(50, 0.8);
            if i < 5 {
                b.record_request(false, Duration::from_micros(30));
                b.record_sim(20, 0.2);
            }
        }
        a.record_cache(true);
        b.record_cache(false);
        b.record_functional_error();
        a.record_batch(4);
        b.record_batch(2);
        a.record_batch_exec(300);
        b.record_batch_exec(150);
        let tele = |shard: usize, routed: u64, snapshot: Snapshot| ShardTelemetry {
            shard,
            lanes: 16,
            config_fingerprint: 7,
            routed,
            queued: 0,
            lane_usage: LaneUsage { total: 16, free: 16, live_partitions: 0 },
            snapshot,
        };
        let rs = RackSnapshot::from_shards(vec![
            tele(0, 10, a.snapshot()),
            tele(1, 5, b.snapshot()),
        ]);
        assert_eq!(rs.aggregate.requests, 15);
        assert_eq!(rs.aggregate.pgemm_ops, 10);
        assert_eq!(rs.aggregate.vector_ops, 5);
        assert_eq!(rs.aggregate.sim_cycles, 10 * 50 + 5 * 20);
        assert_eq!(rs.aggregate.schedule_cache_hits, 1);
        assert_eq!(rs.aggregate.schedule_cache_misses, 1);
        assert_eq!(rs.aggregate.functional_errors, 1);
        assert_eq!(rs.aggregate.batches, 2);
        assert_eq!(rs.aggregate.batched_requests, 6);
        assert_eq!(rs.aggregate.max_batch, 4);
        assert_eq!(rs.aggregate.batch_exec_us, 450, "exec wall time sums across shards");
        // weighted means: (10·0.8 + 5·0.2)/15 and (10·10 + 5·30)/15
        assert!((rs.aggregate.mean_sim_utilization - 0.6).abs() < 1e-9);
        assert!((rs.aggregate.mean_us - (10.0 * 10.0 + 5.0 * 30.0) / 15.0).abs() < 1e-9);
        // traffic shares
        assert!((rs.traffic_share(0) - 2.0 / 3.0).abs() < 1e-12);
        assert!((rs.traffic_share(1) - 1.0 / 3.0).abs() < 1e-12);
        assert_eq!(rs.traffic_share(9), 0.0);
        let rendered = rs.render();
        assert!(rendered.contains("shard 0"), "{rendered}");
        assert!(rendered.contains("rack aggregate"), "{rendered}");
        assert!(!rendered.contains("net:"), "no net gauges unless attached: {rendered}");
    }

    #[test]
    fn absorb_derives_aggregate_percentiles_from_merged_histograms() {
        use crate::obs::hist::bucket_of;
        // two shards with very different latency distributions: the old
        // `.max()` merge would report shard B's tail as the aggregate
        // p50; the histogram merge must land in the same bucket as the
        // sorted-oracle over ALL samples
        let a = Metrics::default();
        let b = Metrics::default();
        let mut all = Vec::new();
        let mut rng = Rng::new(42);
        for _ in 0..2_000u64 {
            let v = rng.range_u64(10, 100); // fast shard
            a.record_request(false, Duration::from_micros(v));
            all.push(v);
        }
        for _ in 0..500u64 {
            let v = rng.range_u64(5_000, 50_000); // slow shard
            b.record_request(false, Duration::from_micros(v));
            all.push(v);
        }
        let mut agg = a.snapshot();
        agg.absorb(&b.snapshot());
        all.sort_unstable();
        for (q, got) in [(0.50, agg.p50_us), (0.95, agg.p95_us), (0.99, agg.p99_us)] {
            let rank = ((q * all.len() as f64).ceil() as usize).clamp(1, all.len());
            let exact = all[rank - 1];
            assert_eq!(
                bucket_of(got),
                bucket_of(exact),
                "q={q}: merged {got} vs oracle {exact} must share a bucket"
            );
        }
        // the old behavior would have been max(a.p50, b.p50) ≈ b's p50
        // (thousands of µs); the merged p50 must sit in the fast band
        assert!(agg.p50_us < 1_000, "aggregate p50 {} polluted by worst-shard merge", agg.p50_us);
        assert_eq!(agg.lat_hist.count(), agg.latency_count);
    }

    #[test]
    fn absorb_falls_back_to_max_for_histogramless_peers() {
        let m = Metrics::default();
        for i in 1..=100u64 {
            m.record_request(false, Duration::from_micros(i));
        }
        let mut agg = m.snapshot();
        // a pre-histogram wire peer: counts but an empty lat_hist
        let mut old = Snapshot { latency_count: 10, p50_us: 7_777, p95_us: 8_888, p99_us: 9_999, ..Snapshot::default() };
        old.mean_us = 8_000.0;
        agg.absorb(&old);
        assert_eq!(agg.p99_us, 9_999, "legacy max fallback when hist is incomplete");
        assert_eq!(agg.latency_count, 110);
    }

    #[test]
    fn stage_histograms_record_and_aggregate() {
        use crate::obs::Stage;
        let a = Metrics::default();
        let b = Metrics::default();
        a.record_stage(Stage::Admit, 10);
        a.record_stage(Stage::Execute, 400);
        b.record_stage(Stage::Admit, 12);
        let mut agg = a.snapshot();
        agg.absorb(&b.snapshot());
        assert_eq!(agg.stage_hist.get(Stage::Admit).count(), 2);
        assert_eq!(agg.stage_hist.get(Stage::Execute).count(), 1);
        assert_eq!(agg.stage_hist.get(Stage::Route).count(), 0);
    }

    #[test]
    fn net_gauges_render_when_attached() {
        let rs = RackSnapshot::from_shards(Vec::new()).with_net(NetGauges {
            active_connections: 3,
            active_sessions: 7,
            bytes_in: 1024,
            bytes_out: 2048,
        });
        let rendered = rs.render();
        assert!(
            rendered.contains("net: 3 connections, 7 sessions active  wire bytes in=1024 out=2048"),
            "{rendered}"
        );
    }
}
