//! Coordinator metrics: request counters, schedule-cache statistics and
//! latency percentiles, shared across worker threads.

use std::collections::BTreeMap;
use std::sync::Mutex;
use std::time::Duration;

#[derive(Debug, Default)]
struct Inner {
    requests: u64,
    pgemm_ops: u64,
    vector_ops: u64,
    functional_execs: u64,
    schedule_cache_hits: u64,
    schedule_cache_misses: u64,
    per_artifact: BTreeMap<String, u64>,
    latencies_us: Vec<u64>,
}

/// Thread-safe metrics sink.
#[derive(Debug, Default)]
pub struct Metrics {
    inner: Mutex<Inner>,
}

/// A frozen snapshot for reporting.
#[derive(Debug, Clone)]
pub struct Snapshot {
    pub requests: u64,
    pub pgemm_ops: u64,
    pub vector_ops: u64,
    pub functional_execs: u64,
    pub schedule_cache_hits: u64,
    pub schedule_cache_misses: u64,
    pub per_artifact: BTreeMap<String, u64>,
    pub p50_us: u64,
    pub p95_us: u64,
    pub p99_us: u64,
    pub mean_us: f64,
}

impl Metrics {
    pub fn record_request(&self, is_pgemm: bool, latency: Duration) {
        let mut m = self.inner.lock().unwrap();
        m.requests += 1;
        if is_pgemm {
            m.pgemm_ops += 1;
        } else {
            m.vector_ops += 1;
        }
        m.latencies_us.push(latency.as_micros() as u64);
    }

    pub fn record_functional(&self, artifact: &str) {
        let mut m = self.inner.lock().unwrap();
        m.functional_execs += 1;
        *m.per_artifact.entry(artifact.to_string()).or_insert(0) += 1;
    }

    pub fn record_cache(&self, hit: bool) {
        let mut m = self.inner.lock().unwrap();
        if hit {
            m.schedule_cache_hits += 1;
        } else {
            m.schedule_cache_misses += 1;
        }
    }

    pub fn snapshot(&self) -> Snapshot {
        let m = self.inner.lock().unwrap();
        let mut lat = m.latencies_us.clone();
        lat.sort_unstable();
        let pct = |p: f64| -> u64 {
            if lat.is_empty() {
                0
            } else {
                lat[((lat.len() as f64 - 1.0) * p) as usize]
            }
        };
        Snapshot {
            requests: m.requests,
            pgemm_ops: m.pgemm_ops,
            vector_ops: m.vector_ops,
            functional_execs: m.functional_execs,
            schedule_cache_hits: m.schedule_cache_hits,
            schedule_cache_misses: m.schedule_cache_misses,
            per_artifact: m.per_artifact.clone(),
            p50_us: pct(0.50),
            p95_us: pct(0.95),
            p99_us: pct(0.99),
            mean_us: if lat.is_empty() {
                0.0
            } else {
                lat.iter().sum::<u64>() as f64 / lat.len() as f64
            },
        }
    }
}

impl Snapshot {
    pub fn render(&self) -> String {
        let mut s = format!(
            "requests={} (pgemm={} vector={})  functional={}  cache {}/{} hit\n\
             latency: p50={}us p95={}us p99={}us mean={:.1}us\n",
            self.requests,
            self.pgemm_ops,
            self.vector_ops,
            self.functional_execs,
            self.schedule_cache_hits,
            self.schedule_cache_hits + self.schedule_cache_misses,
            self.p50_us,
            self.p95_us,
            self.p99_us,
            self.mean_us,
        );
        for (name, n) in &self.per_artifact {
            s.push_str(&format!("  artifact {name}: {n} execs\n"));
        }
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn percentiles_and_counts() {
        let m = Metrics::default();
        for i in 1..=100u64 {
            m.record_request(i % 2 == 0, Duration::from_micros(i));
        }
        m.record_cache(true);
        m.record_cache(false);
        m.record_functional("k");
        let s = m.snapshot();
        assert_eq!(s.requests, 100);
        assert_eq!(s.pgemm_ops, 50);
        assert_eq!(s.p50_us, 50);
        assert_eq!(s.p99_us, 99);
        assert_eq!(s.schedule_cache_hits, 1);
        assert_eq!(s.per_artifact["k"], 1);
        assert!(s.render().contains("p50=50us"));
    }
}
