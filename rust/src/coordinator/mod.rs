//! L3 coordinator: the GTA "lane scheduler + runtime" — classifies and
//! schedules incoming tensor operators (§5), simulates them on the MPRA
//! model, and (when an AOT artifact exists) executes the *functional*
//! result through the PJRT engine so numerics are real, not modeled.
//!
//! Since the rack refactor this is a **two-level architecture**: the
//! serving machinery (shard state, request handling, routing, the
//! shard-aware `serve` loop) lives in [`rack`] — a [`rack::Rack`] owns N
//! [`rack::Shard`]s, each one GTA instance with its own config,
//! simulator, lane allocator, metrics and (optionally) an execution
//! backend behind its own coalescing dispatcher, while ALL shards share
//! one [`crate::scheduler::Explorer`] so a shape scheduled on any shard
//! is a rack-wide cache hit for every shard with the same config.
//! [`Coordinator`] is the stable single-GTA façade: a one-shard rack
//! with the exact pre-rack API and behavior.
//!
//! Threading model: PJRT handles are not `Send`, so one dedicated executor
//! thread per shard owns that shard's backend
//! ([`crate::runtime::ExecBackend`], normally the PJRT [`Engine`]);
//! scheduling/simulation workers scale across cores. Functional requests
//! do not talk to the executor directly — they submit to a per-shard
//! **coalescing dispatcher** thread that groups same-`(artifact, shape)`
//! invocations arriving within a short window into one
//! [`ExecJob::RunBatch`], amortizing the per-request channel round-trip
//! that otherwise makes the single executor thread the serial bottleneck
//! (the GPTPU lesson: batch small offloaded tensor ops). The window is
//! optionally **adaptive** ([`AdaptiveWindow`]): sustained arrivals grow
//! it toward a cap, singleton batches shrink it toward ~0 so light
//! traffic pays no added latency. Request streams enter through a
//! bounded [`AdmissionQueue`] with backpressure, and every failure —
//! functional error, panic, rejection — comes back as a [`Response`]
//! carrying a per-request error: `serve` returns exactly one response
//! per request, always.
//!
//! Since the streaming redesign the primary ingest surface is the
//! long-lived [`RackSession`] ([`Rack::open_session`] /
//! [`Coordinator::open_session`]): the admission queue and worker pool
//! run continuously, callers submit requests as they arrive and read
//! responses as they complete, and the batch `serve`/`serve_with` are
//! thin submit-all-then-drain wrappers over one session — so batch and
//! streaming modes share one code path and one completion-ordering rule
//! ([`order_responses`]).

pub mod lane_scheduler;
pub mod metrics;
pub mod rack;
pub mod session;

pub use rack::{
    order_responses, unserved_response, CapacityWeighted, LeastLoaded, Rack, RoundRobin,
    RoutePolicy, ShapeAffinity, Shard, ShardStatus, BUSY_MESSAGE,
};
pub use metrics::NetGauges;
pub use session::{NotifyFn, RackSession, SessionStats, SubmitError, Ticket, WorkerPool};

use crate::arch::GtaConfig;
use crate::obs;
use crate::ops::{PGemm, TensorOp};
use crate::runtime::manifest::DType;
use crate::runtime::{Engine, ExecBackend, HostTensor};
use crate::scheduler::Candidate;
use crate::sim::SimReport;
use anyhow::{anyhow, Result};
use metrics::Metrics;
use std::collections::{HashMap, VecDeque};
use std::path::PathBuf;
use std::sync::{mpsc, Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

/// Distinct operator shapes the schedule caches retain before shedding
/// least-recently-used entries (bounded memory on a long-lived server).
pub const DEFAULT_SCHEDULE_CAPACITY: usize = 32_768;

/// Default admission-queue slots for [`Coordinator::serve`].
pub const DEFAULT_QUEUE_CAPACITY: usize = 256;

/// What the caller wants done with an operator.
#[derive(Debug, Clone)]
pub enum ExecKind {
    /// Schedule + simulate only (cycle/traffic report).
    Simulate,
    /// Schedule + simulate, AND execute the named artifact with these
    /// inputs on the PJRT engine, returning real numerics.
    Functional { artifact: String, inputs: Vec<HostTensor> },
}

/// A request to the coordinator.
#[derive(Debug, Clone)]
pub struct Request {
    pub id: u64,
    pub op: TensorOp,
    pub exec: ExecKind,
}

/// The coordinator's answer. Failures are data, not panics: a functional
/// error, worker panic or admission rejection fills `error` and the
/// response is still delivered, so streams never silently shrink.
#[derive(Debug)]
pub struct Response {
    pub id: u64,
    /// Which rack shard answered (always 0 through a single
    /// [`Coordinator`]).
    pub shard: usize,
    /// The §5 schedule chosen (None for pure vector ops).
    pub schedule: Option<Candidate>,
    /// Simulated cycles/traffic on the GTA model.
    pub sim: SimReport,
    /// Functional outputs (when requested, an engine is attached, and
    /// execution succeeded).
    pub outputs: Option<Vec<HostTensor>>,
    /// Why this request produced no (valid) outputs, if it didn't.
    pub error: Option<String>,
    pub latency: Duration,
}

impl Response {
    pub fn is_ok(&self) -> bool {
        self.error.is_none()
    }
}

/// Per-invocation reply channel for functional execution results.
type Reply = mpsc::Sender<Result<Vec<HostTensor>>>;

/// Job sent to the executor thread.
enum ExecJob {
    Run {
        artifact: String,
        inputs: Vec<HostTensor>,
        reply: Reply,
    },
    /// A coalesced batch of same-artifact invocations; results are
    /// scattered back to the per-invocation reply channels. Each item
    /// carries its request's trace id so the executor can attribute an
    /// `Execute` span per batch member.
    RunBatch {
        artifact: String,
        items: Vec<(Vec<HostTensor>, Reply, u64)>,
    },
    Names {
        reply: mpsc::Sender<Vec<String>>,
    },
    Shutdown,
}

/// Handle to the dedicated executor thread that owns the backend.
pub struct Executor {
    tx: mpsc::Sender<ExecJob>,
    handle: Option<std::thread::JoinHandle<()>>,
}

impl Executor {
    /// Spawn the executor on the PJRT engine; blocks until the engine has
    /// compiled all artifacts (or failed).
    pub fn spawn(dir: PathBuf) -> Result<Executor> {
        Self::spawn_backend(move || Ok(Box::new(Engine::load(&dir)?) as Box<dyn ExecBackend>))
    }

    /// Spawn the executor on an arbitrary backend. `make` runs on the
    /// executor thread itself (PJRT handles are not `Send`); this call
    /// blocks until it returns.
    pub fn spawn_backend<F>(make: F) -> Result<Executor>
    where
        F: FnOnce() -> Result<Box<dyn ExecBackend>> + Send + 'static,
    {
        Self::spawn_backend_with_metrics(make, None)
    }

    /// [`Executor::spawn_backend`] with a metrics sink: the executor
    /// thread times every backend `execute_batch` call (wall time around
    /// the whole — possibly parallel — fan-out) into `batch_exec_us`.
    pub fn spawn_backend_with_metrics<F>(
        make: F,
        metrics: Option<Arc<Metrics>>,
    ) -> Result<Executor>
    where
        F: FnOnce() -> Result<Box<dyn ExecBackend>> + Send + 'static,
    {
        let (tx, rx) = mpsc::channel::<ExecJob>();
        let (ready_tx, ready_rx) = mpsc::channel::<Result<()>>();
        let handle = std::thread::Builder::new()
            .name("gta-executor".into())
            .spawn(move || {
                let backend = match make() {
                    Ok(b) => {
                        let _ = ready_tx.send(Ok(()));
                        b
                    }
                    Err(e) => {
                        let _ = ready_tx.send(Err(e));
                        return;
                    }
                };
                while let Ok(job) = rx.recv() {
                    match job {
                        ExecJob::Run { artifact, inputs, reply } => {
                            let _ = reply.send(backend.execute(&artifact, &inputs));
                        }
                        ExecJob::RunBatch { artifact, items } => {
                            let mut inputs = Vec::with_capacity(items.len());
                            let mut replies = Vec::with_capacity(items.len());
                            let mut traces = Vec::with_capacity(items.len());
                            for (i, r, t) in items {
                                inputs.push(i);
                                replies.push(r);
                                traces.push(t);
                            }
                            let exec_start = obs::now_us();
                            let t0 = Instant::now();
                            let results = backend.execute_batch(&artifact, &inputs);
                            let wall_us = t0.elapsed().as_micros() as u64;
                            if let Some(m) = &metrics {
                                m.record_batch_exec(wall_us);
                            }
                            // each batch member's Execute span/stage is
                            // the batch wall window it rode in
                            let size = traces.len() as u64;
                            for &trace in &traces {
                                if let Some(m) = &metrics {
                                    m.record_stage(obs::Stage::Execute, wall_us);
                                }
                                obs::emit(&obs::SpanEvent {
                                    trace_id: trace,
                                    stage: obs::Stage::Execute,
                                    shard: obs::NO_SHARD,
                                    start_us: exec_start,
                                    dur_us: wall_us,
                                    extra: size,
                                });
                            }
                            for (reply, res) in replies.into_iter().zip(results) {
                                let _ = reply.send(res);
                            }
                        }
                        ExecJob::Names { reply } => {
                            let _ = reply.send(backend.names());
                        }
                        ExecJob::Shutdown => break,
                    }
                }
            })?;
        ready_rx
            .recv()
            .map_err(|_| anyhow!("executor thread died during backend load"))??;
        Ok(Executor { tx, handle: Some(handle) })
    }

    /// Execute an artifact synchronously through the executor thread
    /// (bypasses coalescing — one invocation, one dispatch).
    pub fn execute(&self, artifact: &str, inputs: Vec<HostTensor>) -> Result<Vec<HostTensor>> {
        let (reply, rx) = mpsc::channel();
        self.tx
            .send(ExecJob::Run { artifact: artifact.to_string(), inputs, reply })
            .map_err(|_| anyhow!("executor gone"))?;
        rx.recv().map_err(|_| anyhow!("executor dropped reply"))?
    }

    /// Artifact names the backend compiled.
    pub fn names(&self) -> Result<Vec<String>> {
        let (reply, rx) = mpsc::channel();
        self.tx
            .send(ExecJob::Names { reply })
            .map_err(|_| anyhow!("executor gone"))?;
        rx.recv().map_err(|_| anyhow!("executor dropped reply"))
    }
}

impl Drop for Executor {
    fn drop(&mut self) {
        let _ = self.tx.send(ExecJob::Shutdown);
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

/// Coalescing knobs (see `docs/serving.md`).
#[derive(Debug, Clone, Copy)]
pub struct CoalesceConfig {
    /// How long the first invocation of a group waits for same-shape
    /// company before the group is dispatched (the *initial* window when
    /// `adaptive` is set).
    pub window: Duration,
    /// Hard cap on one dispatched batch; a group reaching it flushes
    /// immediately.
    pub max_batch: usize,
    /// Adaptive-window bounds: `Some` lets the dispatcher retune
    /// `window` from observed traffic, `None` keeps it fixed.
    pub adaptive: Option<AdaptiveWindow>,
}

impl Default for CoalesceConfig {
    fn default() -> Self {
        CoalesceConfig { window: Duration::from_millis(1), max_batch: 32, adaptive: None }
    }
}

impl CoalesceConfig {
    /// Default knobs with the adaptive controller enabled.
    pub fn with_adaptive_window() -> Self {
        CoalesceConfig { adaptive: Some(AdaptiveWindow::default()), ..Default::default() }
    }
}

/// Bounds for the adaptive coalescing window: the dispatcher retunes the
/// live window within `[min, max]` from the observed inter-arrival gap
/// and batch-size histogram — toward ~`min` when mean batch size is 1
/// (waiting buys nothing, so light traffic pays no added latency),
/// toward `max` under sustained same-shape arrivals (deeper batches).
#[derive(Debug, Clone, Copy)]
pub struct AdaptiveWindow {
    pub min: Duration,
    pub max: Duration,
}

impl Default for AdaptiveWindow {
    fn default() -> Self {
        AdaptiveWindow { min: Duration::ZERO, max: Duration::from_millis(8) }
    }
}

/// The adaptive-window rule, pure so it is unit-testable. `gap_ewma_us`
/// is the smoothed inter-arrival gap, `batch_ewma` the smoothed flushed
/// batch size.
///
/// * Sustained arrivals: the target is a window long enough to collect
///   ~`max_batch` arrivals (`gap × (max_batch − 1)`), clamped to bounds.
/// * Sparse arrivals (no company even within the max window): the
///   target falls to `min` — waiting cannot fill a batch.
/// * The histogram veto: if flushes stay ~singletons despite an open
///   window (arrivals never share a shape), halve — latency is being
///   paid for nothing.
///
/// The live window moves halfway toward the target each flush, so it
/// converges geometrically and never jumps on one outlier.
fn tuned_window(
    current_us: u64,
    gap_ewma_us: f64,
    batch_ewma: f64,
    max_batch: usize,
    bounds: AdaptiveWindow,
) -> u64 {
    let min = bounds.min.as_micros() as u64;
    let max = (bounds.max.as_micros() as u64).max(min);
    let mut desired = if gap_ewma_us > max as f64 {
        min
    } else {
        ((gap_ewma_us * max_batch.saturating_sub(1) as f64).round() as u64).clamp(min, max)
    };
    if batch_ewma < 1.25 {
        desired = desired.min(current_us / 2).max(min);
    }
    (current_us + desired).div_ceil(2).clamp(min, max)
}

/// Dispatcher-side state of the adaptive controller (a no-op shell when
/// the config is not adaptive — the window then never moves).
struct WindowCtl {
    window_us: u64,
    bounds: Option<AdaptiveWindow>,
    max_batch: usize,
    gap_ewma_us: f64,
    batch_ewma: f64,
    last_arrival: Option<Instant>,
}

impl WindowCtl {
    fn new(cfg: &CoalesceConfig) -> WindowCtl {
        WindowCtl {
            window_us: cfg.window.as_micros() as u64,
            bounds: cfg.adaptive,
            max_batch: cfg.max_batch.max(1),
            // neutral prior: assume arrivals pace the configured window
            gap_ewma_us: cfg.window.as_micros() as f64,
            batch_ewma: 1.0,
            last_arrival: None,
        }
    }

    fn window(&self) -> Duration {
        Duration::from_micros(self.window_us)
    }

    fn on_arrival(&mut self, now: Instant) {
        if let Some(prev) = self.last_arrival.replace(now) {
            let gap = now.saturating_duration_since(prev).as_micros() as f64;
            self.gap_ewma_us = 0.75 * self.gap_ewma_us + 0.25 * gap;
        }
    }

    fn on_flush(&mut self, size: usize) {
        self.batch_ewma = 0.75 * self.batch_ewma + 0.25 * size as f64;
        if let Some(bounds) = self.bounds {
            self.window_us =
                tuned_window(self.window_us, self.gap_ewma_us, self.batch_ewma, self.max_batch, bounds);
        }
    }
}

/// One functional invocation in flight from a worker to the dispatcher.
struct DispatchJob {
    artifact: String,
    inputs: Vec<HostTensor>,
    reply: Reply,
    /// The request's trace id — rides through to the executor so the
    /// `Coalesce`/`Execute` spans attribute to the right request.
    trace: u64,
    /// `obs::now_us()` at submit: the start of the coalescing wait.
    t_enq_us: u64,
}

/// Batches group by artifact plus input signature: artifacts are
/// fixed-shape, but a malformed request must not ride along with (or
/// poison) well-formed batch-mates.
type GroupKey = (String, Vec<(DType, usize)>);

fn group_key(job: &DispatchJob) -> GroupKey {
    (job.artifact.clone(), job.inputs.iter().map(|t| (t.dtype(), t.len())).collect())
}

/// Dispatch one coalesced group to the executor (or fail every member's
/// reply if the executor is gone). `artifact` is the group key's —
/// reused rather than re-cloned from a member.
fn flush_group(
    artifact: String,
    jobs: Vec<DispatchJob>,
    exec_tx: &mpsc::Sender<ExecJob>,
    metrics: &Metrics,
) {
    if jobs.is_empty() {
        return;
    }
    let size = jobs.len() as u64;
    metrics.record_batch(jobs.len());
    // each member's Coalesce span/stage: enqueue → this flush
    let now = obs::now_us();
    for j in &jobs {
        let wait = now.saturating_sub(j.t_enq_us);
        metrics.record_stage(obs::Stage::Coalesce, wait);
        obs::emit(&obs::SpanEvent {
            trace_id: j.trace,
            stage: obs::Stage::Coalesce,
            shard: obs::NO_SHARD,
            start_us: j.t_enq_us,
            dur_us: wait,
            extra: size,
        });
    }
    let items: Vec<(Vec<HostTensor>, Reply, u64)> =
        jobs.into_iter().map(|j| (j.inputs, j.reply, j.trace)).collect();
    if let Err(mpsc::SendError(ExecJob::RunBatch { items, .. })) =
        exec_tx.send(ExecJob::RunBatch { artifact, items })
    {
        for (_, reply, _) in items {
            let _ = reply.send(Err(anyhow!("executor shut down before dispatch")));
        }
    }
}

/// The dispatcher thread: accumulate same-`(artifact, shape)` invocations
/// into groups, flush each group when it reaches `max_batch` or its
/// window expires, and flush everything on shutdown — a pending
/// invocation is never dropped.
fn dispatcher_loop(
    rx: mpsc::Receiver<DispatchJob>,
    exec_tx: mpsc::Sender<ExecJob>,
    cfg: CoalesceConfig,
    metrics: Arc<Metrics>,
) {
    let mut ctl = WindowCtl::new(&cfg);
    metrics.record_window(ctl.window_us);
    let mut groups: HashMap<GroupKey, (Vec<DispatchJob>, Instant)> = HashMap::new();
    loop {
        // Nothing pending: sleep on the channel. Groups pending: sleep at
        // most until the nearest window deadline.
        let next = if groups.is_empty() {
            match rx.recv() {
                Ok(job) => Some(job),
                Err(_) => break,
            }
        } else {
            let nearest = groups.values().map(|(_, deadline)| *deadline).min().unwrap();
            match nearest.checked_duration_since(Instant::now()) {
                None => None, // a deadline already passed
                Some(wait) => match rx.recv_timeout(wait) {
                    Ok(job) => Some(job),
                    Err(mpsc::RecvTimeoutError::Timeout) => None,
                    Err(mpsc::RecvTimeoutError::Disconnected) => break,
                },
            }
        };
        match next {
            Some(job) => {
                ctl.on_arrival(Instant::now());
                let key = group_key(&job);
                let group = groups
                    .entry(key.clone())
                    .or_insert_with(|| (Vec::new(), Instant::now() + ctl.window()));
                group.0.push(job);
                if group.0.len() >= cfg.max_batch.max(1) {
                    if let Some((jobs, _)) = groups.remove(&key) {
                        ctl.on_flush(jobs.len());
                        flush_group(key.0, jobs, &exec_tx, &metrics);
                        metrics.record_window(ctl.window_us);
                    }
                }
            }
            None => {
                let now = Instant::now();
                let due: Vec<GroupKey> =
                    groups.iter().filter(|(_, v)| v.1 <= now).map(|(k, _)| k.clone()).collect();
                for key in due {
                    if let Some((jobs, _)) = groups.remove(&key) {
                        ctl.on_flush(jobs.len());
                        flush_group(key.0, jobs, &exec_tx, &metrics);
                    }
                }
                metrics.record_window(ctl.window_us);
            }
        }
    }
    for (key, (jobs, _)) in groups.drain() {
        flush_group(key.0, jobs, &exec_tx, &metrics);
    }
}

/// Handle to the coalescing dispatcher thread.
struct Dispatcher {
    /// `None` after shutdown begins. (Mutex keeps the handle `Sync`
    /// across worker threads on every supported toolchain.)
    tx: Mutex<Option<mpsc::Sender<DispatchJob>>>,
    handle: Option<std::thread::JoinHandle<()>>,
}

impl Dispatcher {
    fn spawn(exec_tx: mpsc::Sender<ExecJob>, cfg: CoalesceConfig, metrics: Arc<Metrics>) -> Dispatcher {
        let (tx, rx) = mpsc::channel::<DispatchJob>();
        let handle = std::thread::Builder::new()
            .name("gta-coalesce-dispatch".into())
            .spawn(move || dispatcher_loop(rx, exec_tx, cfg, metrics))
            .expect("spawning dispatcher thread");
        Dispatcher { tx: Mutex::new(Some(tx)), handle: Some(handle) }
    }

    /// Submit one functional invocation and wait for its (possibly
    /// batched) execution result. `trace` is the owning request's trace
    /// id (its ticket id) for span attribution.
    fn submit(&self, artifact: String, inputs: Vec<HostTensor>, trace: u64) -> Result<Vec<HostTensor>> {
        let (reply, rx) = mpsc::channel();
        {
            let guard = self.tx.lock().unwrap_or_else(|e| e.into_inner());
            let tx = guard.as_ref().ok_or_else(|| anyhow!("dispatcher shut down"))?;
            tx.send(DispatchJob { artifact, inputs, reply, trace, t_enq_us: obs::now_us() })
                .map_err(|_| anyhow!("dispatcher gone"))?;
        }
        rx.recv().map_err(|_| anyhow!("dispatcher dropped reply"))?
    }
}

impl Drop for Dispatcher {
    fn drop(&mut self) {
        drop(self.tx.lock().unwrap_or_else(|e| e.into_inner()).take());
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

/// What `admit` does when the queue is at capacity.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum AdmissionPolicy {
    /// Block the caller until a slot frees (backpressure).
    Block,
    /// Fail with [`AdmitError::Busy`], handing the item back. The session
    /// submit path softens the failure with up to `retries` requeue
    /// attempts spaced `backoff_us` apart (each counted as
    /// `admission_requeued` in [`Metrics`]) before the Busy surfaces to
    /// the caller — over the wire, as a `Busy` frame. The queue itself
    /// never retries: `AdmissionQueue::admit` fails fast regardless of
    /// the fields.
    Reject {
        /// Requeue attempts before giving up (0 = fail on first full).
        retries: u32,
        /// Sleep between attempts, in microseconds.
        backoff_us: u64,
    },
}

impl AdmissionPolicy {
    /// The default fail-fast policy: one 100µs-spaced requeue retry,
    /// exactly the pre-tunable hard-coded behavior.
    pub fn reject() -> AdmissionPolicy {
        AdmissionPolicy::Reject { retries: 1, backoff_us: 100 }
    }

    /// Fail-fast with no retry at all (first full queue is final).
    pub fn reject_now() -> AdmissionPolicy {
        AdmissionPolicy::Reject { retries: 0, backoff_us: 0 }
    }
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum AdmitError {
    /// At capacity under [`AdmissionPolicy::Reject`].
    Busy,
    /// The queue was closed; no further admissions.
    Closed,
}

struct QueueState<T> {
    items: VecDeque<T>,
    closed: bool,
}

/// Bounded MPMC admission queue: producers `admit` (blocking or
/// fail-fast per [`AdmissionPolicy`]), consumers `pop` until the queue is
/// closed *and* drained. The bound is what turns an overload into
/// backpressure at the door instead of unbounded memory growth inside.
pub struct AdmissionQueue<T> {
    state: Mutex<QueueState<T>>,
    not_full: Condvar,
    not_empty: Condvar,
    capacity: usize,
}

impl<T> AdmissionQueue<T> {
    pub fn new(capacity: usize) -> AdmissionQueue<T> {
        AdmissionQueue {
            state: Mutex::new(QueueState { items: VecDeque::new(), closed: false }),
            not_full: Condvar::new(),
            not_empty: Condvar::new(),
            capacity: capacity.max(1),
        }
    }

    /// Admit `item`, applying `policy` when at capacity. On failure the
    /// item is handed back so the caller can synthesize a response for it.
    pub fn admit(&self, item: T, policy: AdmissionPolicy) -> std::result::Result<(), (T, AdmitError)> {
        let mut s = self.state.lock().unwrap_or_else(|e| e.into_inner());
        loop {
            if s.closed {
                return Err((item, AdmitError::Closed));
            }
            if s.items.len() < self.capacity {
                s.items.push_back(item);
                self.not_empty.notify_one();
                return Ok(());
            }
            match policy {
                AdmissionPolicy::Reject { .. } => return Err((item, AdmitError::Busy)),
                AdmissionPolicy::Block => {
                    s = self.not_full.wait(s).unwrap_or_else(|e| e.into_inner())
                }
            }
        }
    }

    /// Next item; blocks while the queue is open and empty. `None` once
    /// closed and drained.
    pub fn pop(&self) -> Option<T> {
        let mut s = self.state.lock().unwrap_or_else(|e| e.into_inner());
        loop {
            if let Some(item) = s.items.pop_front() {
                self.not_full.notify_one();
                return Some(item);
            }
            if s.closed {
                return None;
            }
            s = self.not_empty.wait(s).unwrap_or_else(|e| e.into_inner());
        }
    }

    /// Close the queue: pending items still drain, new admissions fail.
    pub fn close(&self) {
        let mut s = self.state.lock().unwrap_or_else(|e| e.into_inner());
        s.closed = true;
        self.not_empty.notify_all();
        self.not_full.notify_all();
    }

    pub fn depth(&self) -> usize {
        self.state.lock().unwrap_or_else(|e| e.into_inner()).items.len()
    }

    pub fn capacity(&self) -> usize {
        self.capacity
    }
}

/// Knobs for the batched serve path (see `docs/serving.md`).
#[derive(Debug, Clone, Copy)]
pub struct ServeOptions {
    pub workers: usize,
    /// Admission queue slots; admissions past this apply `policy`.
    pub queue_capacity: usize,
    pub policy: AdmissionPolicy,
}

impl ServeOptions {
    pub fn with_workers(workers: usize) -> ServeOptions {
        ServeOptions { workers, ..ServeOptions::default() }
    }
}

impl Default for ServeOptions {
    fn default() -> Self {
        ServeOptions {
            workers: 4,
            queue_capacity: DEFAULT_QUEUE_CAPACITY,
            policy: AdmissionPolicy::Block,
        }
    }
}

/// The coordinator: the stable single-GTA façade over a one-shard
/// [`Rack`]. Every entry point routes to that shard, so existing callers
/// keep the exact pre-rack behavior, while multi-GTA deployments build a
/// [`Rack`] directly (or reach this one through [`Coordinator::rack`]).
pub struct Coordinator {
    pub gta: GtaConfig,
    /// Shard 0's metrics (the only shard) — kept as a field so
    /// `coord.metrics.snapshot()` works exactly as before the rack
    /// refactor.
    pub metrics: Arc<Metrics>,
    rack: Arc<Rack>,
}

impl Coordinator {
    /// Simulation-only coordinator.
    pub fn new(gta: GtaConfig) -> Coordinator {
        Self::from_rack(Rack::sim_only(vec![gta], Box::new(RoundRobin::default())))
    }

    fn from_rack(rack: Rack) -> Coordinator {
        let rack = Arc::new(rack);
        Coordinator {
            gta: rack.shard(0).gta,
            metrics: Arc::clone(&rack.shard(0).metrics),
            rack,
        }
    }

    /// Coordinator with a functional PJRT engine attached.
    pub fn with_engine(gta: GtaConfig, artifact_dir: PathBuf) -> Result<Coordinator> {
        Self::with_engine_opts(gta, artifact_dir, CoalesceConfig::default())
    }

    /// [`Coordinator::with_engine`] with explicit coalescing knobs.
    pub fn with_engine_opts(
        gta: GtaConfig,
        artifact_dir: PathBuf,
        coalesce: CoalesceConfig,
    ) -> Result<Coordinator> {
        Ok(Self::from_rack(Rack::with_backend(
            vec![gta],
            move |_shard| Ok(Box::new(Engine::load(&artifact_dir)?) as Box<dyn ExecBackend>),
            coalesce,
            Box::new(RoundRobin::default()),
        )?))
    }

    /// Coordinator over an arbitrary execution backend (e.g. the offline
    /// [`crate::runtime::SoftBackend`]). `make` runs on the executor
    /// thread.
    pub fn with_backend<F>(gta: GtaConfig, make: F) -> Result<Coordinator>
    where
        F: FnOnce() -> Result<Box<dyn ExecBackend>> + Send + 'static,
    {
        Self::with_backend_opts(gta, make, CoalesceConfig::default())
    }

    /// [`Coordinator::with_backend`] with explicit coalescing knobs.
    pub fn with_backend_opts<F>(
        gta: GtaConfig,
        make: F,
        coalesce: CoalesceConfig,
    ) -> Result<Coordinator>
    where
        F: FnOnce() -> Result<Box<dyn ExecBackend>> + Send + 'static,
    {
        // adapt the one-shot factory to the rack's per-shard factory:
        // one shard, so it is called exactly once
        let make = Mutex::new(Some(make));
        Ok(Self::from_rack(Rack::with_backend(
            vec![gta],
            move |_shard| {
                (make
                    .lock()
                    .unwrap_or_else(|e| e.into_inner())
                    .take()
                    .expect("single-shard factory runs once"))()
            },
            coalesce,
            Box::new(RoundRobin::default()),
        )?))
    }

    /// The underlying one-shard [`Rack`] — the bridge from the
    /// single-GTA API to the shard-aware one.
    pub fn rack(&self) -> &Arc<Rack> {
        &self.rack
    }

    fn shard(&self) -> &Shard {
        self.rack.shard(0)
    }

    pub fn has_engine(&self) -> bool {
        self.shard().has_engine()
    }

    pub fn executor(&self) -> Option<&Executor> {
        self.shard().executor()
    }

    pub fn fresh_id(&self) -> u64 {
        self.rack.fresh_id()
    }

    /// Schedule a p-GEMM (memoized; concurrent requests for the same
    /// shape run the search exactly once).
    pub fn schedule(&self, g: &PGemm) -> Candidate {
        self.shard().schedule(g)
    }

    /// Schedule a batch of p-GEMMs concurrently across the explorer's
    /// worker pool. Results are in input order; repeated shapes within
    /// the batch (and across earlier requests) share one search.
    pub fn schedule_batch(&self, ops: &[PGemm]) -> Vec<Candidate> {
        self.shard().schedule_batch(ops)
    }

    /// Handle one request synchronously. Never panics on functional
    /// failure: the error travels in [`Response::error`] instead.
    /// Routed through the rack so routed/in-flight telemetry matches
    /// the `serve` path (with one shard, routing is trivially shard 0).
    pub fn handle(&self, req: Request) -> Response {
        self.rack.handle(req)
    }

    /// [`Coordinator::handle`] hardened for worker threads: a panic
    /// anywhere in the pipeline becomes an error-carrying response, so a
    /// bad request can never kill a worker and eat its queue share.
    pub fn handle_caught(&self, req: Request) -> Response {
        self.rack.handle_caught(req)
    }

    /// Serve a batch of requests on `workers` threads through the default
    /// admission queue (blocking backpressure). Functional jobs coalesce
    /// through the dispatcher into batched executor dispatches;
    /// scheduling/simulation parallelizes. Responses are returned sorted
    /// by request id, exactly one per request.
    pub fn serve(&self, requests: Vec<Request>, workers: usize) -> Vec<Response> {
        self.rack.serve(requests, workers)
    }

    /// [`Coordinator::serve`] with explicit admission-queue knobs.
    pub fn serve_with(&self, requests: Vec<Request>, opts: ServeOptions) -> Vec<Response> {
        self.rack.serve_with(requests, opts)
    }

    /// Open a long-lived streaming session over this coordinator (the
    /// one-shard special case of [`Rack::open_session`]): submit
    /// requests as they arrive, consume responses as they complete. See
    /// [`RackSession`].
    pub fn open_session(&self, opts: ServeOptions) -> RackSession {
        self.rack.open_session(opts)
    }
}

fn panic_message(p: &(dyn std::any::Any + Send)) -> String {
    if let Some(s) = p.downcast_ref::<&str>() {
        (*s).to_string()
    } else if let Some(s) = p.downcast_ref::<String>() {
        s.clone()
    } else {
        "opaque panic payload".to_string()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ops::VectorKind;
    use crate::precision::Precision;
    use crate::runtime::{SoftBackend, FAIL_ARTIFACT};
    use crate::serve::gemm_tile_request as gemm_tile;

    fn soft(coalesce: CoalesceConfig) -> Arc<Coordinator> {
        crate::serve::soft_coordinator(GtaConfig::lanes16(), coalesce).unwrap()
    }

    #[test]
    fn simulate_only_requests() {
        let c = Coordinator::new(GtaConfig::default());
        let r = c.handle(Request {
            id: 7,
            op: TensorOp::gemm(64, 64, 64, Precision::Int8),
            exec: ExecKind::Simulate,
        });
        assert_eq!(r.id, 7);
        assert!(r.schedule.is_some());
        assert!(r.sim.cycles > 0);
        assert!(r.outputs.is_none());
        assert!(r.is_ok());
    }

    #[test]
    fn schedule_cache_hits_on_repeat() {
        let c = Coordinator::new(GtaConfig::default());
        let g = PGemm::new(128, 64, 256, Precision::Bp16);
        let a = c.schedule(&g);
        let b = c.schedule(&g);
        assert_eq!(a.config, b.config);
        let snap = c.metrics.snapshot();
        assert_eq!(snap.schedule_cache_hits, 1);
        assert_eq!(snap.schedule_cache_misses, 1);
    }

    #[test]
    fn serve_parallel_preserves_ids() {
        let c = Arc::new(Coordinator::new(GtaConfig::default()));
        let reqs: Vec<Request> = (0..32)
            .map(|i| Request {
                id: i,
                op: if i % 2 == 0 {
                    TensorOp::gemm(32 + i, 32, 32, Precision::Int16)
                } else {
                    TensorOp::vector(1024, Precision::Int16, VectorKind::Map)
                },
                exec: ExecKind::Simulate,
            })
            .collect();
        let resps = c.serve(reqs, 4);
        assert_eq!(resps.len(), 32);
        for (i, r) in resps.iter().enumerate() {
            assert_eq!(r.id, i as u64);
        }
        assert_eq!(c.metrics.snapshot().requests, 32);
    }

    #[test]
    fn schedule_batch_matches_sequential_and_dedups() {
        let c = Coordinator::new(GtaConfig::default());
        let a = PGemm::new(96, 169, 576, Precision::Int8);
        let b = PGemm::new(64, 64, 256, Precision::Bp16);
        let batch = c.schedule_batch(&[a, b, a, b, a]);
        assert_eq!(batch.len(), 5);
        assert_eq!(batch[0].config, batch[2].config);
        assert_eq!(batch[1].config, batch[3].config);
        let snap = c.metrics.snapshot();
        assert_eq!(snap.schedule_cache_misses, 2, "two distinct shapes");
        assert_eq!(snap.schedule_cache_hits, 3);
        // later single requests are pure cache hits with identical picks
        assert_eq!(c.schedule(&a).config, batch[0].config);
        assert_eq!(c.metrics.snapshot().schedule_cache_hits, 4);
    }

    #[test]
    fn vector_ops_bypass_scheduler() {
        let c = Coordinator::new(GtaConfig::default());
        let r = c.handle(Request {
            id: 0,
            op: TensorOp::vector(4096, Precision::Fp32, VectorKind::Activation),
            exec: ExecKind::Simulate,
        });
        assert!(r.schedule.is_none());
        assert!(r.sim.cycles > 0);
    }

    #[test]
    fn functional_failure_is_an_error_not_a_panic() {
        let c = soft(CoalesceConfig::default());
        let resp = c.handle(gemm_tile(3, FAIL_ARTIFACT, 0));
        assert_eq!(resp.id, 3);
        assert!(resp.outputs.is_none());
        let err = resp.error.expect("failure must surface as an error");
        assert!(err.contains(FAIL_ARTIFACT), "error names the artifact: {err}");
        assert_eq!(c.metrics.snapshot().functional_errors, 1);
        // the coordinator is still fully serviceable afterwards
        let ok = c.handle(gemm_tile(4, "mpra_gemm_i8_64", 1));
        assert!(ok.is_ok());
        assert!(ok.outputs.is_some());
    }

    #[test]
    fn functional_without_engine_errors_cleanly() {
        let c = Coordinator::new(GtaConfig::default());
        let resp = c.handle(gemm_tile(0, "mpra_gemm_i8_64", 0));
        assert!(resp.outputs.is_none());
        assert!(resp.error.unwrap().contains("no engine"));
    }

    #[test]
    fn admission_queue_blocks_rejects_and_closes() {
        let q: AdmissionQueue<i32> = AdmissionQueue::new(2);
        assert_eq!(q.capacity(), 2);
        assert!(q.admit(1, AdmissionPolicy::reject()).is_ok());
        assert!(q.admit(2, AdmissionPolicy::reject()).is_ok());
        assert_eq!(q.admit(3, AdmissionPolicy::reject()).unwrap_err(), (3, AdmitError::Busy));
        assert_eq!(q.depth(), 2);
        // Block policy exerts backpressure: the admit parks until pop
        std::thread::scope(|scope| {
            let q = &q;
            scope.spawn(move || q.admit(3, AdmissionPolicy::Block).unwrap());
            std::thread::sleep(Duration::from_millis(10));
            assert_eq!(q.pop(), Some(1));
        });
        assert_eq!(q.depth(), 2);
        q.close();
        assert_eq!(q.admit(9, AdmissionPolicy::Block).unwrap_err().1, AdmitError::Closed);
        assert_eq!(q.pop(), Some(2), "pending items drain after close");
        assert_eq!(q.pop(), Some(3));
        assert_eq!(q.pop(), None);
    }

    #[test]
    fn adaptive_window_grows_under_sustained_arrivals() {
        let bounds = AdaptiveWindow { min: Duration::ZERO, max: Duration::from_millis(8) };
        // tight arrivals (20us apart), healthy batches: the window must
        // climb toward the cap from a cold start
        let mut w = 0u64;
        for _ in 0..64 {
            w = tuned_window(w, 20.0, 4.0, 32, bounds);
        }
        let target = 20u64 * 31; // gap × (max_batch − 1)
        assert!(
            w >= target / 2 && w <= bounds.max.as_micros() as u64,
            "sustained arrivals should grow the window toward {target}us, got {w}us"
        );
    }

    #[test]
    fn adaptive_window_shrinks_to_floor_when_batches_are_singletons() {
        let bounds = AdaptiveWindow { min: Duration::ZERO, max: Duration::from_millis(8) };
        // sparse arrivals (gaps beyond the max window), batch size ~1:
        // the window must collapse toward ~0 so light traffic pays no
        // added latency
        let mut w = Duration::from_millis(4).as_micros() as u64;
        for _ in 0..64 {
            w = tuned_window(w, 50_000.0, 1.0, 32, bounds);
        }
        assert!(w <= 2, "singleton traffic should drive the window to ~0, got {w}us");
    }

    #[test]
    fn adaptive_window_stays_within_bounds_and_fixed_config_never_moves() {
        let bounds =
            AdaptiveWindow { min: Duration::from_micros(10), max: Duration::from_micros(100) };
        for gap in [0.0, 1.0, 50.0, 1e9] {
            for batch in [1.0, 1.2, 8.0] {
                for cur in [0u64, 10, 100, 5000] {
                    let w = tuned_window(cur, gap, batch, 32, bounds);
                    assert!((10..=100).contains(&w), "gap={gap} batch={batch} cur={cur} -> {w}");
                }
            }
        }
        // a non-adaptive controller never changes its window
        let mut ctl = WindowCtl::new(&CoalesceConfig::default());
        let before = ctl.window_us;
        ctl.on_arrival(Instant::now());
        ctl.on_flush(1);
        ctl.on_flush(32);
        assert_eq!(ctl.window_us, before);
    }

    #[test]
    fn adaptive_serve_reports_the_chosen_window() {
        // e2e smoke: the adaptive config drives a real stream and the
        // chosen window lands in the metrics snapshot within bounds
        let c = soft(CoalesceConfig::with_adaptive_window());
        let reqs: Vec<Request> =
            (0..32).map(|i| gemm_tile(i, "mpra_gemm_i8_64", i as i32)).collect();
        let resps = c.serve(reqs, 4);
        assert_eq!(resps.len(), 32);
        let snap = c.metrics.snapshot();
        let bounds = AdaptiveWindow::default();
        assert!(
            snap.coalesce_window_us <= bounds.max.as_micros() as u64,
            "window {}us beyond the cap",
            snap.coalesce_window_us
        );
    }

    #[test]
    fn serve_with_reject_policy_never_loses_requests() {
        let c = Arc::new(Coordinator::new(GtaConfig::default()));
        let reqs: Vec<Request> = (0..64)
            .map(|i| Request {
                id: i,
                op: TensorOp::vector(256, Precision::Int8, VectorKind::Map),
                exec: ExecKind::Simulate,
            })
            .collect();
        let opts = ServeOptions { workers: 2, queue_capacity: 2, policy: AdmissionPolicy::reject() };
        let resps = c.serve_with(reqs, opts);
        assert_eq!(resps.len(), 64, "every request gets a response, served or rejected");
        let busy = resps.iter().filter(|r| r.error.is_some()).count() as u64;
        let snap = c.metrics.snapshot();
        assert_eq!(snap.admission_rejected, busy);
        assert_eq!(snap.requests + busy, 64);
    }

    #[test]
    fn coalesced_serve_is_bit_identical_to_direct_execution() {
        // generous window so concurrent workers land in shared batches
        let c = soft(CoalesceConfig {
            window: Duration::from_millis(25),
            max_batch: 8,
            ..Default::default()
        });
        let reqs: Vec<Request> =
            (0..16).map(|i| gemm_tile(i, "mpra_gemm_i8_64", i as i32 * 17)).collect();
        let direct: Vec<Vec<HostTensor>> = reqs
            .iter()
            .map(|r| match &r.exec {
                ExecKind::Functional { artifact, inputs } => {
                    SoftBackend.execute(artifact, inputs).unwrap()
                }
                ExecKind::Simulate => unreachable!(),
            })
            .collect();
        let resps = c.serve(reqs, 8);
        assert_eq!(resps.len(), 16);
        for (r, want) in resps.iter().zip(&direct) {
            assert!(r.is_ok(), "unexpected error: {:?}", r.error);
            assert_eq!(r.outputs.as_ref().unwrap(), want, "batched == sequential numerics");
        }
        let snap = c.metrics.snapshot();
        assert_eq!(snap.batched_requests, 16, "every functional exec went through a batch");
        assert!(snap.max_batch > 1, "same-shape tiles must coalesce: hist {:?}", snap.batch_hist);
    }
}
