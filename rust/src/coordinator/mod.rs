//! L3 coordinator: the GTA "lane scheduler + runtime" — classifies and
//! schedules incoming tensor operators (§5), simulates them on the MPRA
//! model, and (when an AOT artifact exists) executes the *functional*
//! result through the PJRT engine so numerics are real, not modeled.
//!
//! Threading model: PJRT handles are not `Send`, so one dedicated executor
//! thread owns the [`Engine`]; scheduling/simulation workers scale across
//! cores and talk to it over a channel. Python never runs here — the
//! binary is self-contained once `make artifacts` has produced the HLO.

pub mod lane_scheduler;
pub mod metrics;

use crate::arch::GtaConfig;
use crate::ops::{PGemm, TensorOp};
use crate::runtime::{Engine, HostTensor};
use crate::scheduler::{self, explorer, Candidate};
use crate::sim::gta::GtaSim;
use crate::sim::{Platform, SimReport};
use anyhow::{anyhow, Result};
use metrics::Metrics;
use std::path::PathBuf;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{mpsc, Arc, Mutex};
use std::time::{Duration, Instant};

/// What the caller wants done with an operator.
#[derive(Debug, Clone)]
pub enum ExecKind {
    /// Schedule + simulate only (cycle/traffic report).
    Simulate,
    /// Schedule + simulate, AND execute the named artifact with these
    /// inputs on the PJRT engine, returning real numerics.
    Functional { artifact: String, inputs: Vec<HostTensor> },
}

/// A request to the coordinator.
#[derive(Debug, Clone)]
pub struct Request {
    pub id: u64,
    pub op: TensorOp,
    pub exec: ExecKind,
}

/// The coordinator's answer.
#[derive(Debug)]
pub struct Response {
    pub id: u64,
    /// The §5 schedule chosen (None for pure vector ops).
    pub schedule: Option<Candidate>,
    /// Simulated cycles/traffic on the GTA model.
    pub sim: SimReport,
    /// Functional outputs (when requested and an engine is attached).
    pub outputs: Option<Vec<HostTensor>>,
    pub latency: Duration,
}

/// Job sent to the executor thread.
enum ExecJob {
    Run {
        artifact: String,
        inputs: Vec<HostTensor>,
        reply: mpsc::Sender<Result<Vec<HostTensor>>>,
    },
    Names {
        reply: mpsc::Sender<Vec<String>>,
    },
    Shutdown,
}

/// Handle to the dedicated PJRT executor thread.
pub struct Executor {
    tx: mpsc::Sender<ExecJob>,
    handle: Option<std::thread::JoinHandle<()>>,
}

impl Executor {
    /// Spawn the executor; blocks until the engine has compiled all
    /// artifacts (or failed).
    pub fn spawn(dir: PathBuf) -> Result<Executor> {
        let (tx, rx) = mpsc::channel::<ExecJob>();
        let (ready_tx, ready_rx) = mpsc::channel::<Result<()>>();
        let handle = std::thread::Builder::new()
            .name("gta-pjrt-executor".into())
            .spawn(move || {
                let engine = match Engine::load(&dir) {
                    Ok(e) => {
                        let _ = ready_tx.send(Ok(()));
                        e
                    }
                    Err(e) => {
                        let _ = ready_tx.send(Err(e));
                        return;
                    }
                };
                while let Ok(job) = rx.recv() {
                    match job {
                        ExecJob::Run { artifact, inputs, reply } => {
                            let _ = reply.send(engine.execute(&artifact, &inputs));
                        }
                        ExecJob::Names { reply } => {
                            let _ = reply
                                .send(engine.names().iter().map(|s| s.to_string()).collect());
                        }
                        ExecJob::Shutdown => break,
                    }
                }
            })?;
        ready_rx
            .recv()
            .map_err(|_| anyhow!("executor thread died during engine load"))??;
        Ok(Executor { tx, handle: Some(handle) })
    }

    /// Execute an artifact synchronously through the executor thread.
    pub fn execute(&self, artifact: &str, inputs: Vec<HostTensor>) -> Result<Vec<HostTensor>> {
        let (reply, rx) = mpsc::channel();
        self.tx
            .send(ExecJob::Run { artifact: artifact.to_string(), inputs, reply })
            .map_err(|_| anyhow!("executor gone"))?;
        rx.recv().map_err(|_| anyhow!("executor dropped reply"))?
    }

    /// Artifact names the engine compiled.
    pub fn names(&self) -> Result<Vec<String>> {
        let (reply, rx) = mpsc::channel();
        self.tx
            .send(ExecJob::Names { reply })
            .map_err(|_| anyhow!("executor gone"))?;
        rx.recv().map_err(|_| anyhow!("executor dropped reply"))
    }
}

impl Drop for Executor {
    fn drop(&mut self) {
        let _ = self.tx.send(ExecJob::Shutdown);
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

/// The coordinator.
pub struct Coordinator {
    pub gta: GtaConfig,
    sim: GtaSim,
    executor: Option<Executor>,
    /// §5 exploration through the shared explorer: repeated operator
    /// shapes schedule in O(1) off the memo, concurrent requests for the
    /// same shape dedup onto one search (a large hot-path win; §Perf),
    /// and batch requests fan the search across a worker pool.
    explorer: scheduler::Explorer,
    pub metrics: Metrics,
    next_id: AtomicU64,
}

impl Coordinator {
    /// Simulation-only coordinator.
    pub fn new(gta: GtaConfig) -> Coordinator {
        Coordinator {
            sim: GtaSim::new(gta),
            gta,
            executor: None,
            explorer: scheduler::Explorer::new(),
            metrics: Metrics::default(),
            next_id: AtomicU64::new(0),
        }
    }

    /// Coordinator with a functional PJRT engine attached.
    pub fn with_engine(gta: GtaConfig, artifact_dir: PathBuf) -> Result<Coordinator> {
        let mut c = Coordinator::new(gta);
        c.executor = Some(Executor::spawn(artifact_dir)?);
        Ok(c)
    }

    pub fn has_engine(&self) -> bool {
        self.executor.is_some()
    }

    pub fn executor(&self) -> Option<&Executor> {
        self.executor.as_ref()
    }

    pub fn fresh_id(&self) -> u64 {
        self.next_id.fetch_add(1, Ordering::Relaxed)
    }

    /// Schedule a p-GEMM (memoized; concurrent requests for the same
    /// shape run the search exactly once).
    pub fn schedule(&self, g: &PGemm) -> Candidate {
        let (cand, computed) = self.explorer.schedule(g, &self.gta);
        self.metrics.record_cache(!computed);
        cand
    }

    /// Schedule a batch of p-GEMMs concurrently across the explorer's
    /// worker pool. Results are in input order; repeated shapes within
    /// the batch (and across earlier requests) share one search.
    pub fn schedule_batch(&self, ops: &[PGemm]) -> Vec<Candidate> {
        self.explorer
            .schedule_batch(ops, &self.gta, explorer::default_workers())
            .into_iter()
            .map(|(cand, computed)| {
                self.metrics.record_cache(!computed);
                cand
            })
            .collect()
    }

    /// Handle one request synchronously.
    pub fn handle(&self, req: Request) -> Response {
        let t0 = Instant::now();
        let (schedule, sim) = match &req.op {
            TensorOp::PGemm(g) => {
                let cand = self.schedule(g);
                (Some(cand), cand.report)
            }
            TensorOp::Vector(_) => (None, self.sim.run(&req.op)),
        };
        let outputs = match &req.exec {
            ExecKind::Simulate => None,
            ExecKind::Functional { artifact, inputs } => match &self.executor {
                Some(ex) => {
                    self.metrics.record_functional(artifact);
                    Some(ex.execute(artifact, inputs.clone()).unwrap_or_else(|e| {
                        panic!("functional execution of {artifact} failed: {e:#}")
                    }))
                }
                None => None,
            },
        };
        let latency = t0.elapsed();
        self.metrics
            .record_request(matches!(req.op, TensorOp::PGemm(_)), latency);
        Response { id: req.id, schedule, sim, outputs, latency }
    }

    /// Serve a batch of requests on `workers` threads. Functional jobs
    /// serialize through the single PJRT executor; scheduling/simulation
    /// parallelizes. Responses are returned sorted by request id.
    pub fn serve(self: &Arc<Self>, requests: Vec<Request>, workers: usize) -> Vec<Response> {
        let queue = Arc::new(Mutex::new(std::collections::VecDeque::from(requests)));
        let (tx, rx) = mpsc::channel::<Response>();
        let mut handles = Vec::new();
        for w in 0..workers.max(1) {
            let queue = Arc::clone(&queue);
            let tx = tx.clone();
            let me = Arc::clone(self);
            handles.push(
                std::thread::Builder::new()
                    .name(format!("gta-worker-{w}"))
                    .spawn(move || loop {
                        let req = { queue.lock().unwrap().pop_front() };
                        match req {
                            Some(r) => {
                                let resp = me.handle(r);
                                if tx.send(resp).is_err() {
                                    break;
                                }
                            }
                            None => break,
                        }
                    })
                    .unwrap(),
            );
        }
        drop(tx);
        let mut out: Vec<Response> = rx.into_iter().collect();
        for h in handles {
            let _ = h.join();
        }
        out.sort_by_key(|r| r.id);
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ops::VectorKind;
    use crate::precision::Precision;

    #[test]
    fn simulate_only_requests() {
        let c = Coordinator::new(GtaConfig::default());
        let r = c.handle(Request {
            id: 7,
            op: TensorOp::gemm(64, 64, 64, Precision::Int8),
            exec: ExecKind::Simulate,
        });
        assert_eq!(r.id, 7);
        assert!(r.schedule.is_some());
        assert!(r.sim.cycles > 0);
        assert!(r.outputs.is_none());
    }

    #[test]
    fn schedule_cache_hits_on_repeat() {
        let c = Coordinator::new(GtaConfig::default());
        let g = PGemm::new(128, 64, 256, Precision::Bp16);
        let a = c.schedule(&g);
        let b = c.schedule(&g);
        assert_eq!(a.config, b.config);
        let snap = c.metrics.snapshot();
        assert_eq!(snap.schedule_cache_hits, 1);
        assert_eq!(snap.schedule_cache_misses, 1);
    }

    #[test]
    fn serve_parallel_preserves_ids() {
        let c = Arc::new(Coordinator::new(GtaConfig::default()));
        let reqs: Vec<Request> = (0..32)
            .map(|i| Request {
                id: i,
                op: if i % 2 == 0 {
                    TensorOp::gemm(32 + i, 32, 32, Precision::Int16)
                } else {
                    TensorOp::vector(1024, Precision::Int16, VectorKind::Map)
                },
                exec: ExecKind::Simulate,
            })
            .collect();
        let resps = c.serve(reqs, 4);
        assert_eq!(resps.len(), 32);
        for (i, r) in resps.iter().enumerate() {
            assert_eq!(r.id, i as u64);
        }
        assert_eq!(c.metrics.snapshot().requests, 32);
    }

    #[test]
    fn schedule_batch_matches_sequential_and_dedups() {
        let c = Coordinator::new(GtaConfig::default());
        let a = PGemm::new(96, 169, 576, Precision::Int8);
        let b = PGemm::new(64, 64, 256, Precision::Bp16);
        let batch = c.schedule_batch(&[a, b, a, b, a]);
        assert_eq!(batch.len(), 5);
        assert_eq!(batch[0].config, batch[2].config);
        assert_eq!(batch[1].config, batch[3].config);
        let snap = c.metrics.snapshot();
        assert_eq!(snap.schedule_cache_misses, 2, "two distinct shapes");
        assert_eq!(snap.schedule_cache_hits, 3);
        // later single requests are pure cache hits with identical picks
        assert_eq!(c.schedule(&a).config, batch[0].config);
        assert_eq!(c.metrics.snapshot().schedule_cache_hits, 4);
    }

    #[test]
    fn vector_ops_bypass_scheduler() {
        let c = Coordinator::new(GtaConfig::default());
        let r = c.handle(Request {
            id: 0,
            op: TensorOp::vector(4096, Precision::Fp32, VectorKind::Activation),
            exec: ExecKind::Simulate,
        });
        assert!(r.schedule.is_none());
        assert!(r.sim.cycles > 0);
    }
}
