//! The multi-GTA rack: N [`Shard`]s — each one GTA instance with its own
//! [`GtaConfig`], simulator, lane allocator, metrics and (optionally) an
//! execution backend behind its own coalescing dispatcher — behind one
//! [`RoutePolicy`] and ONE shared schedule cache.
//!
//! The paper evaluates a single GTA array, but its scheduling space
//! (dataflow × precision × array resize, Fig. 9) extends naturally to a
//! rack of heterogeneous instances: a 16-lane shard and a 4-lane shard
//! explore *different* spaces for the same operator, and the shared
//! [`Explorer`] memoizes both — the cache keys carry the full
//! `GtaConfig`, so heterogeneous shards coexist in one memo while shards
//! with equal configs (equal [`GtaConfig::fingerprint`]s) hit each
//! other's entries rack-wide.
//!
//! Serving contract, rack-wide: exactly one [`Response`] per [`Request`],
//! sorted by id, failures as data. Shard isolation follows: one shard's
//! functional failures (or panics) can never drop another shard's
//! responses, because every failure is already a per-request error.
//!
//! [`super::Coordinator`] is the one-shard special case of this layer.

use super::lane_scheduler::{LaneAllocator, LaneUsage, Partition, PartitionId};
use super::metrics::{Metrics, RackSnapshot, ShardTelemetry};
use super::session::{RackSession, SubmitError, WorkerPool};
use super::{
    panic_message, AdmitError, CoalesceConfig, Dispatcher, ExecKind, Executor, Request, Response,
    ServeOptions, DEFAULT_SCHEDULE_CAPACITY,
};
use crate::arch::GtaConfig;
use crate::obs::{self, Stage};
use crate::ops::{PGemm, TensorOp};
use crate::runtime::ExecBackend;
use crate::scheduler::{explorer, Candidate, Explorer};
use crate::sim::gta::GtaSim;
use crate::sim::{Platform, SimReport};
use anyhow::Result;
use std::hash::{Hash, Hasher};
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

/// One GTA instance inside a rack.
pub struct Shard {
    pub id: usize,
    pub gta: GtaConfig,
    sim: GtaSim,
    /// The rack-shared §5 exploration state: one memo across all shards,
    /// keyed by `(PGemm, GtaConfig)` — a shape scheduled here is a cache
    /// hit on every same-config shard.
    explorer: Arc<Explorer>,
    /// Per-shard coalescing dispatcher. Declared before `executor`:
    /// fields drop in order, so shutdown flushes pending batches into a
    /// still-live executor.
    dispatcher: Option<Dispatcher>,
    executor: Option<Executor>,
    /// Per-shard multi-tenant lane partitions; [`Rack::allocate_lanes`]
    /// does the rack-level accounting over these.
    allocator: Mutex<LaneAllocator>,
    pub metrics: Arc<Metrics>,
    /// Requests the routing policy placed here (monotonic).
    pub(super) routed: AtomicU64,
    /// Requests admitted but not yet answered — the load signal
    /// [`LeastLoaded`] routing reads.
    pub(super) in_flight: AtomicU64,
    /// Requests routed here that are waiting to enter or sitting in a
    /// serve/session queue, not yet picked up by a worker — the
    /// per-shard queue-pressure gauge routing policies see (a subset of
    /// `in_flight`; includes a submitter currently blocked in `admit`).
    pub(super) queued: AtomicU64,
}

impl Shard {
    /// `metrics` is created by the caller (not in here) so backend racks
    /// can hand the same sink to the executor thread first — that is how
    /// `batch_exec_us` lands in the shard's own snapshot.
    fn new(
        id: usize,
        gta: GtaConfig,
        explorer: Arc<Explorer>,
        executor: Option<Executor>,
        coalesce: CoalesceConfig,
        metrics: Arc<Metrics>,
    ) -> Shard {
        let dispatcher = executor
            .as_ref()
            .map(|e| Dispatcher::spawn(e.tx.clone(), coalesce, Arc::clone(&metrics)));
        Shard {
            id,
            gta,
            sim: GtaSim::new(gta),
            explorer,
            dispatcher,
            executor,
            allocator: Mutex::new(LaneAllocator::new(gta)),
            metrics,
            routed: AtomicU64::new(0),
            in_flight: AtomicU64::new(0),
            queued: AtomicU64::new(0),
        }
    }

    pub fn has_engine(&self) -> bool {
        self.executor.is_some()
    }

    pub fn executor(&self) -> Option<&Executor> {
        self.executor.as_ref()
    }

    /// Requests the routing policy has placed on this shard so far.
    pub fn routed(&self) -> u64 {
        // lint: relaxed-ok monotonic load gauge; a stale read only skews one routing choice
        self.routed.load(Ordering::Relaxed)
    }

    /// Requests currently admitted but unanswered.
    pub fn in_flight(&self) -> u64 {
        // lint: relaxed-ok load gauge read per routing decision; staleness is tolerated by design
        self.in_flight.load(Ordering::Relaxed)
    }

    /// Requests waiting to enter or sitting in an admission queue for
    /// this shard, not yet picked up by a worker (live queue pressure;
    /// subset of `in_flight`).
    pub fn queued(&self) -> u64 {
        // lint: relaxed-ok load gauge read per routing decision; staleness is tolerated by design
        self.queued.load(Ordering::Relaxed)
    }

    /// Schedule a p-GEMM for THIS shard's config through the rack-shared
    /// explorer (cache hits may have been computed by any shard).
    pub fn schedule(&self, g: &PGemm) -> Candidate {
        let (cand, computed) = self.explorer.schedule(g, &self.gta);
        self.metrics.record_cache(!computed);
        cand
    }

    /// Schedule a batch of p-GEMMs concurrently across the explorer's
    /// worker pool. Results are in input order; repeated shapes within
    /// the batch (and across earlier rack-wide requests) share one search.
    pub fn schedule_batch(&self, ops: &[PGemm]) -> Vec<Candidate> {
        self.explorer
            .schedule_batch(ops, &self.gta, explorer::default_workers())
            .into_iter()
            .map(|(cand, computed)| {
                self.metrics.record_cache(!computed);
                cand
            })
            .collect()
    }

    /// Handle one request on this shard. Never panics on functional
    /// failure: the error travels in [`Response::error`] instead.
    ///
    /// Observability: the whole call runs under `obs::with_trace(req.id)`
    /// so nested code (the explorer's sweep) attributes its spans to this
    /// request; the schedule/simulate phase emits a `Schedule` span
    /// (`extra` = 1 on a cache hit), functional work gets `Coalesce` +
    /// `Execute` spans from the dispatcher/executor, and response
    /// assembly a `Respond` span. Per-stage timings also land in the
    /// always-on metrics histograms.
    pub fn handle(&self, req: Request) -> Response {
        let t0 = Instant::now();
        let trace = obs::TraceCtx::new(req.id);
        let _tg = obs::with_trace(req.id);
        let sched_start = obs::now_us();
        let mut cache_hit = 0u64;
        let (schedule, sim) = match &req.op {
            TensorOp::PGemm(g) => {
                let (cand, computed) = self.explorer.schedule(g, &self.gta);
                self.metrics.record_cache(!computed);
                cache_hit = u64::from(!computed);
                (Some(cand), cand.report)
            }
            TensorOp::Vector(_) => (None, self.sim.run(&req.op)),
        };
        self.metrics.record_sim(sim.cycles, sim.utilization);
        self.metrics
            .record_stage(Stage::Schedule, obs::now_us().saturating_sub(sched_start));
        trace.emit_since(Stage::Schedule, self.id as u16, sched_start, cache_hit);
        let (outputs, error) = match &req.exec {
            ExecKind::Simulate => (None, None),
            ExecKind::Functional { artifact, inputs } => match &self.dispatcher {
                Some(d) => {
                    self.metrics.record_functional(artifact);
                    match d.submit(artifact.clone(), inputs.clone(), req.id) {
                        Ok(outs) => (Some(outs), None),
                        Err(e) => {
                            self.metrics.record_functional_error();
                            (None, Some(format!("functional execution of {artifact} failed: {e:#}")))
                        }
                    }
                }
                None => {
                    (None, Some(format!("functional request for {artifact:?}: no engine attached")))
                }
            },
        };
        let respond_start = obs::now_us();
        let latency = t0.elapsed();
        self.metrics
            .record_request(matches!(req.op, TensorOp::PGemm(_)), latency);
        let resp = Response { id: req.id, shard: self.id, schedule, sim, outputs, error, latency };
        self.metrics
            .record_stage(Stage::Respond, obs::now_us().saturating_sub(respond_start));
        trace.emit_since(Stage::Respond, self.id as u16, respond_start, 0);
        resp
    }

    /// [`Shard::handle`] hardened for worker threads: a panic anywhere in
    /// the pipeline becomes an error-carrying response, so a bad request
    /// can never kill a worker and eat its queue share.
    pub fn handle_caught(&self, req: Request) -> Response {
        let id = req.id;
        match std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| self.handle(req))) {
            Ok(resp) => resp,
            Err(p) => Response {
                id,
                shard: self.id,
                schedule: None,
                sim: SimReport::default(),
                outputs: None,
                error: Some(format!("worker panicked: {}", panic_message(&p))),
                latency: Duration::ZERO,
            },
        }
    }

    /// Allocate `n` contiguous lanes on this shard's array.
    pub fn allocate_lanes(&self, n: u32) -> Option<Partition> {
        self.allocator.lock().unwrap_or_else(|e| e.into_inner()).allocate(n)
    }

    /// Release a partition previously granted by this shard.
    pub fn release_lanes(&self, id: PartitionId) -> bool {
        self.allocator.lock().unwrap_or_else(|e| e.into_inner()).release(id)
    }

    pub fn lane_usage(&self) -> LaneUsage {
        self.allocator.lock().unwrap_or_else(|e| e.into_inner()).usage()
    }

    /// Load/identity view for routing policies. Deliberately cheap —
    /// atomics and copies only, no locks — because the serve feeder
    /// builds one per shard per routed request.
    pub fn status(&self) -> ShardStatus {
        ShardStatus {
            id: self.id,
            gta: self.gta,
            in_flight: self.in_flight(),
            routed: self.routed(),
            queued: self.queued(),
            latency_ewma_us: self.metrics.latency_ewma_us(),
        }
    }

    /// Per-shard telemetry for the rack report.
    pub fn telemetry(&self) -> ShardTelemetry {
        ShardTelemetry {
            shard: self.id,
            lanes: self.gta.lanes,
            config_fingerprint: self.gta.fingerprint(),
            routed: self.routed(),
            queued: self.queued(),
            lane_usage: self.lane_usage(),
            snapshot: self.metrics.snapshot(),
        }
    }
}

/// What a routing policy sees of each shard at decision time. Capacity
/// signals derivable from the config (e.g. `gta.lanes`) live in `gta`;
/// lane-allocator occupancy is intentionally absent — reading it takes
/// the allocator lock, and routing runs once per request. Everything
/// here is an atomic read.
#[derive(Debug, Clone, Copy)]
pub struct ShardStatus {
    pub id: usize,
    pub gta: GtaConfig,
    pub in_flight: u64,
    /// Requests this shard has been handed so far (monotonic) — the
    /// long-run traffic share [`CapacityWeighted`] balances.
    pub routed: u64,
    /// Live queue depth: admitted for this shard, not yet picked up.
    pub queued: u64,
    /// Smoothed request latency (µs) from the shard's [`Metrics`] —
    /// 0.0 until the shard has answered its first request.
    pub latency_ewma_us: f64,
}

/// Places each request on a shard. `serve` routes from a single feeder
/// thread in submission order, so a policy that is a deterministic
/// function of (its own state, the request, the statuses) yields a
/// reproducible assignment for a fixed stream.
pub trait RoutePolicy: Send + Sync {
    fn name(&self) -> &'static str;
    /// Index into `shards` (`len ≥ 1`). Out-of-range picks are clamped
    /// by the rack.
    fn route(&self, req: &Request, shards: &[ShardStatus]) -> usize;
}

/// Strict rotation over the shards, independent of load or shape.
#[derive(Debug, Default)]
pub struct RoundRobin {
    next: AtomicUsize,
}

impl RoutePolicy for RoundRobin {
    fn name(&self) -> &'static str {
        "round-robin"
    }

    fn route(&self, _req: &Request, shards: &[ShardStatus]) -> usize {
        // lint: relaxed-ok pure rotation counter; no data is published through it
        self.next.fetch_add(1, Ordering::Relaxed) % shards.len().max(1)
    }
}

/// Fewest in-flight requests wins; ties break on the live queue depth,
/// then the latency EWMA (send equal load to the shard that is
/// answering faster), then the lowest shard id.
#[derive(Debug, Default)]
pub struct LeastLoaded;

impl RoutePolicy for LeastLoaded {
    fn name(&self) -> &'static str {
        "least-loaded"
    }

    fn route(&self, _req: &Request, shards: &[ShardStatus]) -> usize {
        shards
            .iter()
            .min_by_key(|s| (s.in_flight, s.queued, s.latency_ewma_us as u64, s.id))
            .map(|s| s.id)
            .unwrap_or(0)
    }
}

/// Traffic proportional to shard capacity: each decision goes to the
/// shard with the lowest per-lane traffic share `(routed + 1) / lanes`
/// (ties → lowest id), so over a sustained stream a 4-lane shard
/// settles at exactly half an 8-lane shard's traffic. Only the
/// monotonic `routed` counter feeds the score, so a single submitter
/// gets a fully deterministic split (live queue/latency feedback is
/// [`LeastLoaded`]'s job).
#[derive(Debug, Default)]
pub struct CapacityWeighted;

impl RoutePolicy for CapacityWeighted {
    fn name(&self) -> &'static str {
        "capacity-weighted"
    }

    fn route(&self, _req: &Request, shards: &[ShardStatus]) -> usize {
        shards
            .iter()
            .min_by(|a, b| {
                let per_lane = |s: &ShardStatus| (s.routed + 1) as f64 / s.gta.lanes.max(1) as f64;
                per_lane(a)
                    .partial_cmp(&per_lane(b))
                    .unwrap_or(std::cmp::Ordering::Equal)
                    .then(a.id.cmp(&b.id))
            })
            .map(|s| s.id)
            .unwrap_or(0)
    }
}

/// Same shape (and artifact) always lands on the same shard: maximizes
/// same-`(artifact, shape)` coalescing inside that shard's dispatcher
/// and keeps each shape's schedule hot in exactly one shard's working
/// set — the Systolic-Tensor-Array observation that array-shape
/// diversity pays when work with an affinity stays put.
#[derive(Debug, Default)]
pub struct ShapeAffinity;

impl RoutePolicy for ShapeAffinity {
    fn name(&self) -> &'static str {
        "shape-affinity"
    }

    fn route(&self, req: &Request, shards: &[ShardStatus]) -> usize {
        let mut h = std::collections::hash_map::DefaultHasher::new();
        req.op.hash(&mut h);
        if let ExecKind::Functional { artifact, .. } = &req.exec {
            artifact.hash(&mut h);
        }
        (h.finish() as usize) % shards.len().max(1)
    }
}

/// Look up a routing policy by its CLI name.
pub fn policy_by_name(name: &str) -> Option<Box<dyn RoutePolicy>> {
    match name {
        "rr" | "round-robin" => Some(Box::new(RoundRobin::default())),
        "least" | "least-loaded" => Some(Box::new(LeastLoaded)),
        "affinity" | "shape-affinity" => Some(Box::new(ShapeAffinity)),
        "capacity" | "capacity-weighted" => Some(Box::new(CapacityWeighted)),
        _ => None,
    }
}

/// One shared completion-ordering rule for every drain path (batch
/// `serve_with` and streaming [`RackSession::drain`] both end here, so
/// the two modes cannot diverge): responses sort by request id.
pub fn order_responses(responses: &mut [Response]) {
    responses.sort_by_key(|r| r.id);
}

/// The one routing step shared by [`Rack::route`] and the session's
/// submit path: snapshot every shard's status, ask the policy, clamp
/// out-of-range picks.
pub(super) fn route_on(policy: &dyn RoutePolicy, shards: &[Arc<Shard>], req: &Request) -> usize {
    let statuses: Vec<ShardStatus> = shards.iter().map(|s| s.status()).collect();
    policy.route(req, &statuses).min(shards.len() - 1)
}

/// The per-request error message a `Busy` rejection synthesizes — ONE
/// string shared by the batch wrapper ([`Rack::serve_with`]) and the
/// network client (`net::client`), so in-process and over-the-wire
/// replays stay comparable response-for-response.
pub const BUSY_MESSAGE: &str = "busy: admission queue at capacity";

/// A response for a request that never reached a shard worker (admission
/// rejection, closed session, wire-level `Busy`) — the one synthesized
/// shape shared by the batch wrapper and the network client.
pub fn unserved_response(id: u64, shard: usize, msg: String) -> Response {
    Response {
        id,
        shard,
        schedule: None,
        sim: SimReport::default(),
        outputs: None,
        error: Some(msg),
        latency: Duration::ZERO,
    }
}

/// N GTA shards behind one routing policy and one shared schedule cache.
pub struct Rack {
    shards: Vec<Arc<Shard>>,
    /// The rack-shared exploration state (exposed so callers can read
    /// memo-level hit/miss/eviction counters across the whole rack).
    pub explorer: Arc<Explorer>,
    /// Shared with every open [`RackSession`], so concurrent sessions
    /// (and repeated `serve_with` calls) advance ONE routing state.
    policy: Arc<dyn RoutePolicy>,
    next_id: AtomicU64,
}

impl Rack {
    /// Simulation-only rack: one shard per config, no execution backends.
    pub fn sim_only(configs: Vec<GtaConfig>, policy: Box<dyn RoutePolicy>) -> Rack {
        assert!(!configs.is_empty(), "a rack needs at least one shard");
        let explorer = Arc::new(Explorer::with_capacity(DEFAULT_SCHEDULE_CAPACITY));
        let shards = configs
            .into_iter()
            .enumerate()
            .map(|(i, gta)| {
                Arc::new(Shard::new(
                    i,
                    gta,
                    Arc::clone(&explorer),
                    None,
                    CoalesceConfig::default(),
                    Arc::new(Metrics::default()),
                ))
            })
            .collect();
        Rack { shards, explorer, policy: Arc::from(policy), next_id: AtomicU64::new(0) }
    }

    /// A rack whose every shard gets its own execution backend from
    /// `make` (called with the shard index, on that shard's executor
    /// thread) and its own coalescing dispatcher — batching is per-shard
    /// by construction.
    pub fn with_backend<F>(
        configs: Vec<GtaConfig>,
        make: F,
        coalesce: CoalesceConfig,
        policy: Box<dyn RoutePolicy>,
    ) -> Result<Rack>
    where
        F: Fn(usize) -> Result<Box<dyn ExecBackend>> + Send + Sync + 'static,
    {
        assert!(!configs.is_empty(), "a rack needs at least one shard");
        let explorer = Arc::new(Explorer::with_capacity(DEFAULT_SCHEDULE_CAPACITY));
        let make = Arc::new(make);
        let mut shards = Vec::with_capacity(configs.len());
        for (i, gta) in configs.into_iter().enumerate() {
            let mk = Arc::clone(&make);
            // the shard's metrics exist before its executor so the
            // executor thread can time execute_batch into the same sink
            let metrics = Arc::new(Metrics::default());
            let executor =
                Executor::spawn_backend_with_metrics(move || mk(i), Some(Arc::clone(&metrics)))?;
            shards.push(Arc::new(Shard::new(
                i,
                gta,
                Arc::clone(&explorer),
                Some(executor),
                coalesce,
                metrics,
            )));
        }
        Ok(Rack { shards, explorer, policy: Arc::from(policy), next_id: AtomicU64::new(0) })
    }

    pub fn shards(&self) -> &[Arc<Shard>] {
        &self.shards
    }

    pub fn shard(&self, i: usize) -> &Arc<Shard> {
        &self.shards[i]
    }

    pub fn len(&self) -> usize {
        self.shards.len()
    }

    pub fn is_empty(&self) -> bool {
        self.shards.is_empty()
    }

    pub fn policy_name(&self) -> &'static str {
        self.policy.name()
    }

    pub fn fresh_id(&self) -> u64 {
        // lint: relaxed-ok unique-id counter; only uniqueness matters, not ordering
        self.next_id.fetch_add(1, Ordering::Relaxed)
    }

    /// Current status of every shard (what the policy sees).
    pub fn statuses(&self) -> Vec<ShardStatus> {
        self.shards.iter().map(|s| s.status()).collect()
    }

    /// Pick a shard for `req` (does not mark it routed or in flight).
    pub fn route(&self, req: &Request) -> usize {
        route_on(self.policy.as_ref(), &self.shards, req)
    }

    /// Handle one request synchronously on whichever shard the policy
    /// picks.
    pub fn handle(&self, req: Request) -> Response {
        self.handle_on(req, Shard::handle)
    }

    /// [`Rack::handle`] hardened against panics (see
    /// [`Shard::handle_caught`]).
    pub fn handle_caught(&self, req: Request) -> Response {
        self.handle_on(req, Shard::handle_caught)
    }

    fn handle_on(&self, req: Request, run: impl Fn(&Shard, Request) -> Response) -> Response {
        let sidx = self.route(&req);
        let shard = &self.shards[sidx];
        // lint: relaxed-ok load gauges: routing tolerates stale reads, so updates need no ordering
        shard.routed.fetch_add(1, Ordering::Relaxed);
        // lint: relaxed-ok load gauges: routing tolerates stale reads, so updates need no ordering
        shard.in_flight.fetch_add(1, Ordering::Relaxed);
        let resp = run(shard, req);
        // lint: relaxed-ok load gauges: routing tolerates stale reads, so updates need no ordering
        shard.in_flight.fetch_sub(1, Ordering::Relaxed);
        resp
    }

    /// Open a long-lived streaming session over this rack: the admission
    /// queue and the routing/scheduling/simulation workers are spawned
    /// once and run continuously; the caller feeds [`RackSession::submit`]
    /// and consumes completions with `recv`/`try_recv`/`iter` as they
    /// finish (out of submission order), then `drain`/`close` shuts the
    /// session down without dropping in-flight work. The batch
    /// [`Rack::serve_with`] is a thin wrapper over one of these.
    pub fn open_session(&self, opts: ServeOptions) -> RackSession {
        RackSession::open(self.shards.clone(), Arc::clone(&self.policy), opts)
    }

    /// [`Rack::open_session`], but thread-less: execution rides the
    /// shared [`WorkerPool`] instead of per-session worker threads, so
    /// a server multiplexing thousands of logical sessions stays at
    /// O(pool) threads. Semantics (admission bounds, backpressure,
    /// drain/close, telemetry) are identical to [`Rack::open_session`].
    pub fn open_session_on(&self, opts: ServeOptions, pool: &Arc<WorkerPool>) -> RackSession {
        RackSession::open_on_pool(self.shards.clone(), Arc::clone(&self.policy), opts, pool)
    }

    /// Serve a batch of requests across the rack on `workers` threads
    /// through the default admission queue (blocking backpressure).
    pub fn serve(&self, requests: Vec<Request>, workers: usize) -> Vec<Response> {
        self.serve_with(requests, ServeOptions::with_workers(workers))
    }

    /// [`Rack::serve`] with explicit admission-queue knobs — a thin
    /// wrapper over a [`RackSession`]: submit everything, then drain.
    /// Each request is routed (this thread, submission order —
    /// deterministic for a deterministic policy), admitted to the
    /// session's bounded queue, and handled by its shard; functional
    /// work coalesces inside that shard's own dispatcher. Exactly one
    /// response per request, sorted by id — a shard's failures never
    /// drop another shard's responses.
    pub fn serve_with(&self, requests: Vec<Request>, opts: ServeOptions) -> Vec<Response> {
        let n = requests.len();
        let session = self.open_session(opts);
        // Rejections become responses here, not errors: the batch
        // contract is one response per request, served or not.
        let mut out: Vec<Response> = Vec::with_capacity(n);
        for req in requests {
            match session.try_submit(req) {
                Ok(_ticket) => {}
                Err(SubmitError { id, shard, error }) => {
                    let msg = match error {
                        AdmitError::Busy => BUSY_MESSAGE,
                        AdmitError::Closed => "admission queue closed",
                    };
                    out.push(unserved_response(id, shard.unwrap_or(0), msg.to_string()));
                }
            }
        }
        out.append(&mut session.drain());
        assert_eq!(out.len(), n, "serve must yield exactly one response per request");
        order_responses(&mut out);
        out
    }

    /// Rack-wide telemetry: per-shard counters plus the aggregate rollup.
    pub fn snapshot(&self) -> RackSnapshot {
        RackSnapshot::from_shards(self.shards.iter().map(|s| s.telemetry()).collect())
    }

    /// Rack-level free-lane count across every shard.
    pub fn free_lanes(&self) -> u32 {
        self.shards.iter().map(|s| s.lane_usage().free).sum()
    }

    /// Allocate `n` contiguous lanes on the shard with the most free
    /// lanes that can take them (ties break to the lowest shard id);
    /// falls through to less-free shards on fragmentation/mask limits.
    pub fn allocate_lanes(&self, n: u32) -> Option<(usize, Partition)> {
        // snapshot occupancy once, then sort the snapshot — the key
        // must not re-read a mutex-guarded value mid-sort
        let mut order: Vec<(usize, u32)> =
            self.shards.iter().map(|s| (s.id, s.lane_usage().free)).collect();
        order.sort_by_key(|&(id, free)| (std::cmp::Reverse(free), id));
        for (id, _) in order {
            if let Some(p) = self.shards[id].allocate_lanes(n) {
                return Some((id, p));
            }
        }
        None
    }

    /// Release a partition granted by [`Rack::allocate_lanes`].
    pub fn release_lanes(&self, shard: usize, id: PartitionId) -> bool {
        self.shards.get(shard).is_some_and(|s| s.release_lanes(id))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ops::VectorKind;
    use crate::precision::Precision;

    fn sim_rack(lanes: &[u32], policy: Box<dyn RoutePolicy>) -> Rack {
        Rack::sim_only(lanes.iter().map(|&l| GtaConfig::with_lanes(l)).collect(), policy)
    }

    fn sim_req(id: u64) -> Request {
        Request {
            id,
            op: TensorOp::gemm(64, 64, 64, Precision::Int8),
            exec: ExecKind::Simulate,
        }
    }

    #[test]
    fn round_robin_rotates_and_least_loaded_picks_idle() {
        let rack = sim_rack(&[16, 16, 16], Box::new(RoundRobin::default()));
        let picks: Vec<usize> = (0..6).map(|i| rack.route(&sim_req(i))).collect();
        assert_eq!(picks, vec![0, 1, 2, 0, 1, 2]);

        let rack = sim_rack(&[16, 16, 16], Box::new(LeastLoaded));
        rack.shard(0).in_flight.store(5, Ordering::Relaxed);
        rack.shard(1).in_flight.store(1, Ordering::Relaxed);
        rack.shard(2).in_flight.store(3, Ordering::Relaxed);
        assert_eq!(rack.route(&sim_req(0)), 1);
    }

    #[test]
    fn least_loaded_ties_break_on_the_latency_ewma() {
        let rack = sim_rack(&[16, 16], Box::new(LeastLoaded));
        rack.shard(0).metrics.record_request(false, Duration::from_micros(500));
        rack.shard(1).metrics.record_request(false, Duration::from_micros(50));
        assert_eq!(rack.route(&sim_req(0)), 1, "equal load -> the faster shard wins");
    }

    #[test]
    fn capacity_weighted_splits_traffic_proportionally_to_lanes() {
        let rack = sim_rack(&[8, 4], Box::new(CapacityWeighted));
        let mut counts = [0u64; 2];
        for i in 0..12 {
            let sidx = rack.route(&sim_req(i));
            // routing reads the routed counter; mimic the submit path
            rack.shard(sidx).routed.fetch_add(1, Ordering::Relaxed);
            counts[sidx] += 1;
        }
        assert_eq!(counts, [8, 4], "traffic share equals lane share");
    }

    #[test]
    fn shape_affinity_is_a_pure_function_of_the_shape() {
        let rack = sim_rack(&[16, 16, 16, 16], Box::new(ShapeAffinity));
        let a = Request {
            id: 0,
            op: TensorOp::gemm(96, 169, 576, Precision::Int8),
            exec: ExecKind::Simulate,
        };
        let b = Request {
            id: 99,
            op: TensorOp::gemm(96, 169, 576, Precision::Int8),
            exec: ExecKind::Simulate,
        };
        let c = Request {
            id: 1,
            op: TensorOp::vector(4096, Precision::Fp32, VectorKind::Map),
            exec: ExecKind::Simulate,
        };
        assert_eq!(rack.route(&a), rack.route(&b), "same shape, same shard — id irrelevant");
        let _ = rack.route(&c); // different shape may differ; must not panic
    }

    #[test]
    fn sim_rack_serves_across_shards_with_one_response_per_request() {
        let rack = sim_rack(&[16, 4], Box::new(RoundRobin::default()));
        let reqs: Vec<Request> = (0..16).map(sim_req).collect();
        let resps = rack.serve(reqs, 4);
        assert_eq!(resps.len(), 16);
        for (i, r) in resps.iter().enumerate() {
            assert_eq!(r.id, i as u64);
            assert!(r.is_ok());
            assert_eq!(r.shard, i % 2, "round-robin assignment recorded on the response");
        }
        let snap = rack.snapshot();
        assert_eq!(snap.aggregate.requests, 16);
        assert_eq!(snap.shards[0].routed, 8);
        assert_eq!(snap.shards[1].routed, 8);
        // same shape on two HETEROGENEOUS configs: two searches rack-wide
        // (one per distinct config), everything else memo hits
        assert_eq!(snap.aggregate.schedule_cache_misses, 2);
        assert_eq!(snap.aggregate.schedule_cache_hits, 14);
        assert_eq!(rack.explorer.selected.misses(), 2);
    }

    #[test]
    fn rack_lane_accounting_spreads_and_aggregates() {
        let rack = sim_rack(&[16, 16], Box::new(RoundRobin::default()));
        assert_eq!(rack.free_lanes(), 32);
        let (s1, p1) = rack.allocate_lanes(8).unwrap();
        let (s2, _p2) = rack.allocate_lanes(8).unwrap();
        assert_ne!(s1, s2, "second grant goes to the now-freer shard");
        assert_eq!(rack.free_lanes(), 16);
        // a 12-lane ask no longer fits either shard contiguously
        assert!(rack.allocate_lanes(12).is_none());
        assert!(rack.release_lanes(s1, p1.id));
        assert!(!rack.release_lanes(s1, p1.id), "double release rejected");
        assert!(!rack.release_lanes(99, p1.id), "unknown shard rejected");
        assert_eq!(rack.free_lanes(), 24);
        let usage = rack.shard(s2).lane_usage();
        assert_eq!((usage.total, usage.free, usage.live_partitions), (16, 8, 1));
    }
}
