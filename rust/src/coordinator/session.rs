//! The long-lived streaming serving session: the inversion of the old
//! batch-in/batch-out `serve` loop. A [`RackSession`] owns the bounded
//! admission queue and the scheduling/simulation worker threads for its
//! whole lifetime; callers [`submit`](RackSession::submit) requests one
//! at a time (non-blocking or backpressured per [`AdmissionPolicy`]) and
//! consume [`Response`]s **as they complete** — out of submission order —
//! through [`recv`](RackSession::recv)/[`try_recv`](RackSession::try_recv)/
//! [`iter`](RackSession::iter). [`close`](RackSession::close) drains
//! every in-flight request and returns the final [`ServeSummary`].
//!
//! The per-shard coalescing dispatchers and executor threads are owned
//! by the rack's shards and were already long-lived; what the session
//! adds is a continuously running ingest/egress surface over them, so
//! the adaptive coalescing window finally sees realistic open-loop
//! arrivals instead of a pre-materialized batch (the GPTPU
//! request-queue model). `Rack::serve_with` is now a thin wrapper:
//! submit everything, then [`drain`](RackSession::drain).
//!
//! Every method takes `&self` (lifecycle counters are atomics, the
//! completion channel and worker handles sit behind mutexes), so one
//! session can be driven from two threads at once — which is exactly
//! what the network transport does: `net::server`'s reader thread
//! submits while its writer thread pumps
//! [`recv_timeout`](RackSession::recv_timeout) completions back to the
//! socket.
//!
//! Determinism: routing happens on the submitting thread in submission
//! order, exactly like the old single feeder — a deterministic policy
//! over a fixed stream from ONE submitting thread yields the same shard
//! assignment (and therefore bit-identical responses) as the batch
//! path. Concurrent submitters keep every delivery guarantee but
//! interleave routing decisions nondeterministically.

use super::metrics::RackSnapshot;
use super::rack::{order_responses, route_on, RoutePolicy, Shard};
use super::{AdmissionPolicy, AdmissionQueue, AdmitError, Request, Response, ServeOptions};
use crate::serve::ServeSummary;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{mpsc, Arc, Mutex};
use std::time::{Duration, Instant};

/// Receipt for one admitted request: its id and the shard the router
/// placed it on. The matching [`Response`] carries the same `id` and
/// `shard`, so tickets pair submissions with out-of-order completions.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Ticket {
    pub id: u64,
    pub shard: usize,
}

/// A rejected submission, with everything the caller needs to
/// synthesize a response for it (the batch wrapper does exactly that).
#[derive(Debug, Clone, Copy)]
pub struct SubmitError {
    /// Id of the request handed back.
    pub id: u64,
    /// Shard the router had picked before admission failed; `None` when
    /// the session was already closed (the request was never routed).
    pub shard: Option<usize>,
    pub error: AdmitError,
}

/// Live counters for one session (see [`RackSession::stats`]).
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct SessionStats {
    /// Tickets issued (requests admitted to the queue).
    pub submitted: u64,
    /// Responses handed to the caller (or folded in by `drain`/`close`).
    pub completed: u64,
    /// Submissions finally rejected with [`AdmitError::Busy`].
    pub rejected: u64,
    /// Admitted but not yet consumed: `submitted - completed`.
    pub outstanding: u64,
    /// Requests currently sitting in the admission queue.
    pub queue_depth: usize,
}

/// A long-lived ingest/egress handle over a rack (or the coordinator's
/// one-shard facade). See the module docs for the lifecycle; dropping a
/// session without closing it shuts the workers down cleanly (in-flight
/// work is still executed, its responses are discarded).
pub struct RackSession {
    shards: Vec<Arc<Shard>>,
    policy: Arc<dyn RoutePolicy>,
    queue: Arc<AdmissionQueue<(usize, Request)>>,
    /// Completion channel. The mutex makes consumption `&self`; there is
    /// still effectively one consumer at a time (a blocked `recv` holds
    /// the lock until a response or channel disconnect arrives).
    rx: Mutex<mpsc::Receiver<Response>>,
    workers: Mutex<Vec<std::thread::JoinHandle<()>>>,
    opts: ServeOptions,
    opened: Instant,
    closed: AtomicBool,
    // lifecycle counters (atomics: submit and recv may run on different
    // threads — the network server's reader/writer split)
    submitted: AtomicU64,
    completed: AtomicU64,
    rejected: AtomicU64,
    errors: AtomicU64,
    functional: AtomicU64,
    total_sim_cycles: AtomicU64,
}

impl RackSession {
    /// Spawn the session's worker pool over `shards`. Called through
    /// [`super::rack::Rack::open_session`] /
    /// [`super::Coordinator::open_session`].
    pub(super) fn open(
        shards: Vec<Arc<Shard>>,
        policy: Arc<dyn RoutePolicy>,
        opts: ServeOptions,
    ) -> RackSession {
        let queue = Arc::new(AdmissionQueue::<(usize, Request)>::new(opts.queue_capacity));
        let (tx, rx) = mpsc::channel::<Response>();
        let workers = (0..opts.workers.max(1))
            .map(|w| {
                let queue = Arc::clone(&queue);
                let tx = tx.clone();
                let shards = shards.clone();
                std::thread::Builder::new()
                    .name(format!("gta-session-worker-{w}"))
                    .spawn(move || {
                        while let Some((sidx, req)) = queue.pop() {
                            let shard = &shards[sidx];
                            shard.queued.fetch_sub(1, Ordering::Relaxed);
                            let resp = shard.handle_caught(req);
                            shard.in_flight.fetch_sub(1, Ordering::Relaxed);
                            if tx.send(resp).is_err() {
                                break;
                            }
                        }
                    })
                    .expect("spawning session worker thread")
            })
            .collect();
        RackSession {
            shards,
            policy,
            queue,
            rx: Mutex::new(rx),
            workers: Mutex::new(workers),
            opts,
            opened: Instant::now(),
            closed: AtomicBool::new(false),
            submitted: AtomicU64::new(0),
            completed: AtomicU64::new(0),
            rejected: AtomicU64::new(0),
            errors: AtomicU64::new(0),
            functional: AtomicU64::new(0),
            total_sim_cycles: AtomicU64::new(0),
        }
    }

    /// Submit one request. Routes on THIS thread in call order (see the
    /// module docs on determinism), then admits to the bounded queue
    /// under the session's [`AdmissionPolicy`]: `Block` exerts
    /// backpressure by stalling the caller until a slot frees;
    /// `Reject { retries, backoff_us }` requeues up to `retries` times,
    /// sleeping `backoff_us` between attempts (each counted as
    /// `admission_requeued`), then fails fast with [`AdmitError::Busy`]
    /// (counted as `admission_rejected`). After [`close`](Self::close)/
    /// [`drain`](Self::drain) every submission fails with an explicit
    /// [`AdmitError::Closed`] — tickets are never silently dropped.
    pub fn submit(&self, req: Request) -> Result<Ticket, AdmitError> {
        self.try_submit(req).map_err(|e| e.error)
    }

    /// [`submit`](Self::submit), but the rejection hands back the id and
    /// routed shard so the caller can synthesize a per-request response
    /// (what the batch `serve_with` wrapper does, and what the network
    /// server turns into a wire-level `Busy` frame).
    pub fn try_submit(&self, req: Request) -> Result<Ticket, SubmitError> {
        let id = req.id;
        if self.is_closed() {
            return Err(SubmitError { id, shard: None, error: AdmitError::Closed });
        }
        let is_functional = matches!(req.exec, super::ExecKind::Functional { .. });
        let sidx = route_on(self.policy.as_ref(), &self.shards, &req);
        let shard = Arc::clone(&self.shards[sidx]);
        shard.routed.fetch_add(1, Ordering::Relaxed);
        shard.in_flight.fetch_add(1, Ordering::Relaxed);
        shard.queued.fetch_add(1, Ordering::Relaxed);
        // Count the submission BEFORE admitting (and roll back on
        // rejection): once the item is in the queue a concurrent
        // consumer thread — the network server's egress pump — may count
        // the completion immediately, and `completed > submitted` would
        // underflow `outstanding`.
        self.submitted.fetch_add(1, Ordering::Relaxed);
        if is_functional {
            self.functional.fetch_add(1, Ordering::Relaxed);
        }
        // the Reject policy's tunable requeue loop: retry a Busy up to
        // `retries` times before surfacing it
        let mut attempt = self.queue.admit((sidx, req), self.opts.policy);
        if let AdmissionPolicy::Reject { retries, backoff_us } = self.opts.policy {
            let mut tries = 0u32;
            loop {
                match attempt {
                    Err((item, AdmitError::Busy)) if tries < retries => {
                        tries += 1;
                        shard.metrics.record_admission_requeued();
                        if backoff_us > 0 {
                            std::thread::sleep(Duration::from_micros(backoff_us));
                        }
                        attempt = self.queue.admit(item, self.opts.policy);
                    }
                    other => {
                        attempt = other;
                        break;
                    }
                }
            }
        }
        match attempt {
            Ok(()) => {
                shard.metrics.record_queue_depth(self.queue.depth());
                Ok(Ticket { id, shard: sidx })
            }
            Err((_, error)) => {
                self.submitted.fetch_sub(1, Ordering::Relaxed);
                if is_functional {
                    self.functional.fetch_sub(1, Ordering::Relaxed);
                }
                if error == AdmitError::Busy {
                    shard.metrics.record_admission_rejected();
                    self.rejected.fetch_add(1, Ordering::Relaxed);
                }
                shard.in_flight.fetch_sub(1, Ordering::Relaxed);
                shard.queued.fetch_sub(1, Ordering::Relaxed);
                Err(SubmitError { id, shard: Some(sidx), error })
            }
        }
    }

    /// Next completed response, blocking while work is outstanding.
    /// Returns `None` when nothing is outstanding (so a submit/recv loop
    /// can never deadlock on its own session) or after the workers shut
    /// down.
    pub fn recv(&self) -> Option<Response> {
        if self.outstanding() == 0 {
            return None;
        }
        match self.rx.lock().unwrap().recv() {
            Ok(resp) => Some(self.count(resp)),
            Err(_) => None,
        }
    }

    /// Next completed response if one is ready right now.
    pub fn try_recv(&self) -> Option<Response> {
        match self.rx.lock().unwrap().try_recv() {
            Ok(resp) => Some(self.count(resp)),
            Err(_) => None,
        }
    }

    /// Next completed response, waiting at most `timeout` — regardless
    /// of whether anything is currently outstanding (a concurrent
    /// submitter may admit work at any moment). `None` on timeout or
    /// after the workers shut down; pair with
    /// [`is_closed`](Self::is_closed) to tell the two apart. This is the
    /// egress pump's accessor: `net::server`'s writer thread calls it in
    /// a loop while the reader thread keeps submitting.
    pub fn recv_timeout(&self, timeout: Duration) -> Option<Response> {
        match self.rx.lock().unwrap().recv_timeout(timeout) {
            Ok(resp) => Some(self.count(resp)),
            Err(_) => None,
        }
    }

    /// Blocking iterator over completions: yields until every currently
    /// outstanding request has been consumed, then stops (submit more
    /// and iterate again, or interleave — see [`recv`](Self::recv)).
    pub fn iter(&self) -> impl Iterator<Item = Response> + '_ {
        std::iter::from_fn(move || self.recv())
    }

    /// Tickets admitted but not yet consumed by the caller.
    /// (Saturating: with a concurrent submitter and consumer the two
    /// loads are not one atomic snapshot.)
    pub fn outstanding(&self) -> u64 {
        self.submitted
            .load(Ordering::Relaxed)
            .saturating_sub(self.completed.load(Ordering::Relaxed))
    }

    /// Whether [`drain`](Self::drain)/[`close`](Self::close) has begun:
    /// all subsequent submissions fail with [`AdmitError::Closed`].
    pub fn is_closed(&self) -> bool {
        self.closed.load(Ordering::Relaxed)
    }

    /// The options this session was opened with.
    pub fn opts(&self) -> ServeOptions {
        self.opts
    }

    /// Live session counters (queue depth, submitted/completed/rejected).
    pub fn stats(&self) -> SessionStats {
        let submitted = self.submitted.load(Ordering::Relaxed);
        let completed = self.completed.load(Ordering::Relaxed);
        SessionStats {
            submitted,
            completed,
            rejected: self.rejected.load(Ordering::Relaxed),
            outstanding: submitted.saturating_sub(completed),
            queue_depth: self.queue.depth(),
        }
    }

    /// Fold one consumed response into the lifecycle counters.
    fn count(&self, resp: Response) -> Response {
        self.completed.fetch_add(1, Ordering::Relaxed);
        self.total_sim_cycles.fetch_add(resp.sim.cycles, Ordering::Relaxed);
        if resp.error.is_some() {
            self.errors.fetch_add(1, Ordering::Relaxed);
        }
        resp
    }

    /// Stop admissions, let the workers drain every queued and in-flight
    /// request, and return all not-yet-consumed responses, ordered by
    /// the same completion-ordering rule as the batch path
    /// ([`order_responses`] — sorted by id). Subsequent
    /// [`submit`](Self::submit)s fail with [`AdmitError::Closed`]. A
    /// concurrent consumer (e.g. a still-running egress pump) may take
    /// some of the final responses instead; they are folded into the
    /// session counters either way.
    pub fn drain(&self) -> Vec<Response> {
        self.closed.store(true, Ordering::SeqCst);
        self.queue.close();
        let handles: Vec<_> = self.workers.lock().unwrap().drain(..).collect();
        for h in handles {
            let _ = h.join();
        }
        // workers are gone: everything they completed is in the channel
        let mut out = Vec::new();
        {
            let rx = self.rx.lock().unwrap();
            while let Ok(resp) = rx.try_recv() {
                out.push(self.count(resp));
            }
        }
        order_responses(&mut out);
        out
    }

    /// Drain in-flight work ([`drain`](Self::drain) — unconsumed
    /// responses are folded into the summary counters and dropped; call
    /// `drain` first to keep them) and return the final session summary:
    /// lifecycle counters, wall-clock throughput, the rack-wide metrics
    /// rollup and per-shard telemetry. Verification counters are zero —
    /// checking outputs against an oracle is the driver's job
    /// (`serve::run_stream` and friends), not the session's.
    pub fn close(&self) -> ServeSummary {
        let unconsumed = self.drain();
        drop(unconsumed); // already folded into the counters by drain()
        let wall = self.opened.elapsed().as_secs_f64();
        let shards = RackSnapshot::from_shards(self.shards.iter().map(|s| s.telemetry()).collect());
        let snap = shards.aggregate.clone();
        let completed = self.completed.load(Ordering::Relaxed);
        ServeSummary {
            requests: completed,
            functional: self.functional.load(Ordering::Relaxed),
            verified_ok: 0,
            verified_failed: 0,
            errors: self.errors.load(Ordering::Relaxed),
            prescheduled: 0,
            coalesced_batches: snap.batches,
            max_batch: snap.max_batch,
            coalesce_window_us: snap.coalesce_window_us,
            shards: Some(shards),
            wall_seconds: wall,
            throughput_rps: completed as f64 / wall.max(1e-9),
            total_sim_cycles: self.total_sim_cycles.load(Ordering::Relaxed),
            metrics: snap,
        }
    }
}

impl Drop for RackSession {
    fn drop(&mut self) {
        if !self.is_closed() || !self.workers.lock().unwrap().is_empty() {
            let _ = self.drain();
        }
    }
}
