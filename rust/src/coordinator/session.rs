//! The long-lived streaming serving session: the inversion of the old
//! batch-in/batch-out `serve` loop. A [`RackSession`] owns the bounded
//! admission queue and the scheduling/simulation worker threads for its
//! whole lifetime; callers [`submit`](RackSession::submit) requests one
//! at a time (non-blocking or backpressured per [`AdmissionPolicy`]) and
//! consume [`Response`]s **as they complete** — out of submission order —
//! through [`recv`](RackSession::recv)/[`try_recv`](RackSession::try_recv)/
//! [`iter`](RackSession::iter). [`close`](RackSession::close) drains
//! every in-flight request and returns the final [`ServeSummary`].
//!
//! The per-shard coalescing dispatchers and executor threads are owned
//! by the rack's shards and were already long-lived; what the session
//! adds is a continuously running ingest/egress surface over them, so
//! the adaptive coalescing window finally sees realistic open-loop
//! arrivals instead of a pre-materialized batch (the GPTPU
//! request-queue model). `Rack::serve_with` is now a thin wrapper:
//! submit everything, then [`drain`](RackSession::drain).
//!
//! Determinism: routing happens on the submitting thread in submission
//! order, exactly like the old single feeder — a deterministic policy
//! over a fixed stream from one thread yields the same shard assignment
//! (and therefore bit-identical responses) as the batch path.

use super::metrics::RackSnapshot;
use super::rack::{order_responses, route_on, RoutePolicy, Shard};
use super::{AdmissionPolicy, AdmissionQueue, AdmitError, Request, Response, ServeOptions};
use crate::serve::ServeSummary;
use std::sync::atomic::Ordering;
use std::sync::{mpsc, Arc};
use std::time::{Duration, Instant};

/// Receipt for one admitted request: its id and the shard the router
/// placed it on. The matching [`Response`] carries the same `id` and
/// `shard`, so tickets pair submissions with out-of-order completions.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Ticket {
    pub id: u64,
    pub shard: usize,
}

/// A rejected submission, with everything the caller needs to
/// synthesize a response for it (the batch wrapper does exactly that).
#[derive(Debug, Clone, Copy)]
pub struct SubmitError {
    /// Id of the request handed back.
    pub id: u64,
    /// Shard the router had picked before admission failed; `None` when
    /// the session was already closed (the request was never routed).
    pub shard: Option<usize>,
    pub error: AdmitError,
}

/// Live counters for one session (see [`RackSession::stats`]).
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct SessionStats {
    /// Tickets issued (requests admitted to the queue).
    pub submitted: u64,
    /// Responses handed to the caller (or folded in by `drain`/`close`).
    pub completed: u64,
    /// Submissions finally rejected with [`AdmitError::Busy`].
    pub rejected: u64,
    /// Admitted but not yet consumed: `submitted - completed`.
    pub outstanding: u64,
    /// Requests currently sitting in the admission queue.
    pub queue_depth: usize,
}

/// A long-lived ingest/egress handle over a rack (or the coordinator's
/// one-shard facade). See the module docs for the lifecycle; dropping a
/// session without closing it shuts the workers down cleanly (in-flight
/// work is still executed, its responses are discarded).
pub struct RackSession {
    shards: Vec<Arc<Shard>>,
    policy: Arc<dyn RoutePolicy>,
    queue: Arc<AdmissionQueue<(usize, Request)>>,
    rx: mpsc::Receiver<Response>,
    workers: Vec<std::thread::JoinHandle<()>>,
    opts: ServeOptions,
    opened: Instant,
    closed: bool,
    // lifecycle counters (single-owner, so plain fields suffice)
    submitted: u64,
    completed: u64,
    rejected: u64,
    errors: u64,
    functional: u64,
    total_sim_cycles: u64,
}

impl RackSession {
    /// Spawn the session's worker pool over `shards`. Called through
    /// [`super::rack::Rack::open_session`] /
    /// [`super::Coordinator::open_session`].
    pub(super) fn open(
        shards: Vec<Arc<Shard>>,
        policy: Arc<dyn RoutePolicy>,
        opts: ServeOptions,
    ) -> RackSession {
        let queue = Arc::new(AdmissionQueue::<(usize, Request)>::new(opts.queue_capacity));
        let (tx, rx) = mpsc::channel::<Response>();
        let workers = (0..opts.workers.max(1))
            .map(|w| {
                let queue = Arc::clone(&queue);
                let tx = tx.clone();
                let shards = shards.clone();
                std::thread::Builder::new()
                    .name(format!("gta-session-worker-{w}"))
                    .spawn(move || {
                        while let Some((sidx, req)) = queue.pop() {
                            let shard = &shards[sidx];
                            shard.queued.fetch_sub(1, Ordering::Relaxed);
                            let resp = shard.handle_caught(req);
                            shard.in_flight.fetch_sub(1, Ordering::Relaxed);
                            if tx.send(resp).is_err() {
                                break;
                            }
                        }
                    })
                    .expect("spawning session worker thread")
            })
            .collect();
        RackSession {
            shards,
            policy,
            queue,
            rx,
            workers,
            opts,
            opened: Instant::now(),
            closed: false,
            submitted: 0,
            completed: 0,
            rejected: 0,
            errors: 0,
            functional: 0,
            total_sim_cycles: 0,
        }
    }

    /// Submit one request. Routes on THIS thread in call order (see the
    /// module docs on determinism), then admits to the bounded queue
    /// under the session's [`AdmissionPolicy`]: `Block` exerts
    /// backpressure by stalling the caller until a slot frees; `Reject`
    /// retries once after 100µs (counted as `admission_requeued`), then
    /// fails fast with [`AdmitError::Busy`] (counted as
    /// `admission_rejected`). After [`close`](Self::close)/
    /// [`drain`](Self::drain) every submission fails with an explicit
    /// [`AdmitError::Closed`] — tickets are never silently dropped.
    pub fn submit(&mut self, req: Request) -> Result<Ticket, AdmitError> {
        self.try_submit(req).map_err(|e| e.error)
    }

    /// [`submit`](Self::submit), but the rejection hands back the id and
    /// routed shard so the caller can synthesize a per-request response
    /// (what the batch `serve_with` wrapper does).
    pub fn try_submit(&mut self, req: Request) -> Result<Ticket, SubmitError> {
        let id = req.id;
        if self.closed {
            return Err(SubmitError { id, shard: None, error: AdmitError::Closed });
        }
        let is_functional = matches!(req.exec, super::ExecKind::Functional { .. });
        let sidx = route_on(self.policy.as_ref(), &self.shards, &req);
        let shard = Arc::clone(&self.shards[sidx]);
        shard.routed.fetch_add(1, Ordering::Relaxed);
        shard.in_flight.fetch_add(1, Ordering::Relaxed);
        shard.queued.fetch_add(1, Ordering::Relaxed);
        // one requeue attempt on Busy before giving up, as the old
        // batch feeder did
        let mut requeued = false;
        let attempt = match self.queue.admit((sidx, req), self.opts.policy) {
            Err((item, AdmitError::Busy)) => {
                requeued = true;
                shard.metrics.record_admission_requeued();
                std::thread::sleep(Duration::from_micros(100));
                self.queue.admit(item, AdmissionPolicy::Reject)
            }
            other => other,
        };
        match attempt {
            Ok(()) => {
                shard.metrics.record_queue_depth(self.queue.depth());
                self.submitted += 1;
                self.functional += is_functional as u64;
                Ok(Ticket { id, shard: sidx })
            }
            Err((_, error)) => {
                if requeued {
                    shard.metrics.record_admission_rejected();
                    self.rejected += 1;
                }
                shard.in_flight.fetch_sub(1, Ordering::Relaxed);
                shard.queued.fetch_sub(1, Ordering::Relaxed);
                Err(SubmitError { id, shard: Some(sidx), error })
            }
        }
    }

    /// Next completed response, blocking while work is outstanding.
    /// Returns `None` when nothing is outstanding (so a submit/recv loop
    /// can never deadlock on its own session) or after the workers shut
    /// down.
    pub fn recv(&mut self) -> Option<Response> {
        if self.outstanding() == 0 {
            return None;
        }
        match self.rx.recv() {
            Ok(resp) => Some(self.count(resp)),
            Err(_) => None,
        }
    }

    /// Next completed response if one is ready right now.
    pub fn try_recv(&mut self) -> Option<Response> {
        match self.rx.try_recv() {
            Ok(resp) => Some(self.count(resp)),
            Err(_) => None,
        }
    }

    /// Blocking iterator over completions: yields until every currently
    /// outstanding request has been consumed, then stops (submit more
    /// and iterate again, or interleave — the session is one owner).
    pub fn iter(&mut self) -> impl Iterator<Item = Response> + '_ {
        std::iter::from_fn(move || self.recv())
    }

    /// Tickets admitted but not yet consumed by the caller.
    pub fn outstanding(&self) -> u64 {
        self.submitted - self.completed
    }

    /// Live session counters (queue depth, submitted/completed/rejected).
    pub fn stats(&self) -> SessionStats {
        SessionStats {
            submitted: self.submitted,
            completed: self.completed,
            rejected: self.rejected,
            outstanding: self.outstanding(),
            queue_depth: self.queue.depth(),
        }
    }

    /// Fold one consumed response into the lifecycle counters.
    fn count(&mut self, resp: Response) -> Response {
        self.completed += 1;
        self.total_sim_cycles += resp.sim.cycles;
        if resp.error.is_some() {
            self.errors += 1;
        }
        resp
    }

    /// Stop admissions, let the workers drain every queued and in-flight
    /// request, and return all not-yet-consumed responses, ordered by
    /// the same completion-ordering rule as the batch path
    /// ([`order_responses`] — sorted by id). Subsequent
    /// [`submit`](Self::submit)s fail with [`AdmitError::Closed`].
    pub fn drain(&mut self) -> Vec<Response> {
        self.closed = true;
        self.queue.close();
        for h in self.workers.drain(..) {
            let _ = h.join();
        }
        // workers are gone: everything they completed is in the channel
        let mut out = Vec::new();
        while let Ok(resp) = self.rx.try_recv() {
            out.push(self.count(resp));
        }
        order_responses(&mut out);
        out
    }

    /// Drain in-flight work ([`drain`](Self::drain) — unconsumed
    /// responses are folded into the summary counters and dropped; call
    /// `drain` first to keep them) and return the final session summary:
    /// lifecycle counters, wall-clock throughput, the rack-wide metrics
    /// rollup and per-shard telemetry. Verification counters are zero —
    /// checking outputs against an oracle is the driver's job
    /// (`serve::run_stream` and friends), not the session's.
    pub fn close(&mut self) -> ServeSummary {
        let unconsumed = self.drain();
        drop(unconsumed); // already folded into the counters by drain()
        let wall = self.opened.elapsed().as_secs_f64();
        let shards = RackSnapshot::from_shards(self.shards.iter().map(|s| s.telemetry()).collect());
        let snap = shards.aggregate.clone();
        ServeSummary {
            requests: self.completed,
            functional: self.functional,
            verified_ok: 0,
            verified_failed: 0,
            errors: self.errors,
            prescheduled: 0,
            coalesced_batches: snap.batches,
            max_batch: snap.max_batch,
            coalesce_window_us: snap.coalesce_window_us,
            shards: Some(shards),
            wall_seconds: wall,
            throughput_rps: self.completed as f64 / wall.max(1e-9),
            total_sim_cycles: self.total_sim_cycles,
            metrics: snap,
        }
    }
}

impl Drop for RackSession {
    fn drop(&mut self) {
        if !self.closed || !self.workers.is_empty() {
            let _ = self.drain();
        }
    }
}
