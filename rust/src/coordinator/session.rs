//! The long-lived streaming serving session: the inversion of the old
//! batch-in/batch-out `serve` loop. A [`RackSession`] owns the bounded
//! admission queue and the scheduling/simulation worker threads for its
//! whole lifetime; callers [`submit`](RackSession::submit) requests one
//! at a time (non-blocking or backpressured per [`AdmissionPolicy`]) and
//! consume [`Response`]s **as they complete** — out of submission order —
//! through [`recv`](RackSession::recv)/[`try_recv`](RackSession::try_recv)/
//! [`iter`](RackSession::iter). [`close`](RackSession::close) drains
//! every in-flight request and returns the final [`ServeSummary`].
//!
//! The per-shard coalescing dispatchers and executor threads are owned
//! by the rack's shards and were already long-lived; what the session
//! adds is a continuously running ingest/egress surface over them, so
//! the adaptive coalescing window finally sees realistic open-loop
//! arrivals instead of a pre-materialized batch (the GPTPU
//! request-queue model). `Rack::serve_with` is now a thin wrapper:
//! submit everything, then [`drain`](RackSession::drain).
//!
//! Every method takes `&self` (lifecycle counters are atomics, the
//! completion channel and worker handles sit behind mutexes), so one
//! session can be driven from two threads at once — which is exactly
//! what the network transport does: `net::server`'s reader thread
//! submits while its writer thread pumps
//! [`recv_timeout`](RackSession::recv_timeout) completions back to the
//! socket.
//!
//! Determinism: routing happens on the submitting thread in submission
//! order, exactly like the old single feeder — a deterministic policy
//! over a fixed stream from ONE submitting thread yields the same shard
//! assignment (and therefore bit-identical responses) as the batch
//! path. Concurrent submitters keep every delivery guarantee but
//! interleave routing decisions nondeterministically.

use super::metrics::RackSnapshot;
use super::rack::{order_responses, route_on, RoutePolicy, Shard};
use super::{AdmissionPolicy, AdmissionQueue, AdmitError, Request, Response, ServeOptions};
use crate::obs::{self, Stage};
use crate::serve::ServeSummary;
use std::collections::VecDeque;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{mpsc, Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

/// Completion-notification callback: invoked by a worker after each
/// response lands in the session's completion channel. Used by the
/// event-loop server to wake its `poll` instead of parking a thread in
/// [`RackSession::recv_timeout`]; must be cheap and must not block.
pub type NotifyFn = Arc<dyn Fn() + Send + Sync>;

/// The per-session state a pool worker needs to execute one admitted
/// request: the session's own bounded queue (the worker pops exactly
/// one item per dispatched token), its completion channel, the pending
/// counter `drain` waits on, and the notify hook.
struct SessionWork {
    shards: Vec<Arc<Shard>>,
    queue: Arc<AdmissionQueue<(usize, Request)>>,
    tx: mpsc::Sender<Response>,
    pending: Mutex<u64>,
    idle: Condvar,
    notify: Arc<Mutex<Option<NotifyFn>>>,
}

impl SessionWork {
    /// Service one dispatch token: pop one item from the session queue
    /// (present by construction — exactly one token is enqueued per
    /// admitted item and pool workers are the queue's only consumers),
    /// execute it, deliver the response, then account the token.
    fn run_one(&self) {
        if let Some((sidx, req)) = self.queue.pop() {
            let shard = &self.shards[sidx];
            // lint: relaxed-ok load gauge; routing tolerates stale reads
            shard.queued.fetch_sub(1, Ordering::Relaxed);
            let resp = shard.handle_caught(req);
            // lint: relaxed-ok load gauge; routing tolerates stale reads
            shard.in_flight.fetch_sub(1, Ordering::Relaxed);
            let _ = self.tx.send(resp);
        }
        {
            let mut p = self.pending.lock().unwrap_or_else(|e| e.into_inner());
            *p = p.saturating_sub(1);
            if *p == 0 {
                self.idle.notify_all();
            }
        }
        let cb = self.notify.lock().unwrap_or_else(|e| e.into_inner()).clone();
        if let Some(cb) = cb {
            cb();
        }
    }

    /// Block until every dispatched token has been serviced (the
    /// pool-mode replacement for joining dedicated worker threads).
    fn wait_idle(&self) {
        let mut p = self.pending.lock().unwrap_or_else(|e| e.into_inner());
        while *p > 0 {
            // a poisoned wait still hands the guard back: recover it so a
            // panicked worker degrades to its own error, not a cascade
            p = self.idle.wait(p).unwrap_or_else(|e| e.into_inner());
        }
    }
}

struct PoolInner {
    /// Dispatch tokens: one per admitted request, each naming the
    /// session whose queue holds the actual item. Unbounded, but its
    /// length is capped by the sum of the bounded per-session queues.
    tokens: Mutex<VecDeque<Arc<SessionWork>>>,
    ready: Condvar,
    closed: AtomicBool,
}

/// A fixed pool of worker threads servicing MANY sessions — the
/// event-loop server's execution backend, where thread count must be
/// O(pool), not O(connections). Sessions opened with
/// [`super::rack::Rack::open_session_on`] spawn no threads of their
/// own; every admitted request instead dispatches one token here, and
/// whichever pool worker picks it up pops that one item from the
/// session's own bounded queue. Admission bounds, backpressure and
/// per-shard gauges are byte-for-byte the dedicated-thread semantics —
/// only thread ownership moves.
pub struct WorkerPool {
    inner: Arc<PoolInner>,
    handles: Mutex<Vec<std::thread::JoinHandle<()>>>,
}

impl WorkerPool {
    /// Spawn `threads` (min 1) pool workers.
    pub fn new(threads: usize) -> WorkerPool {
        let inner = Arc::new(PoolInner {
            tokens: Mutex::new(VecDeque::new()),
            ready: Condvar::new(),
            closed: AtomicBool::new(false),
        });
        let handles = (0..threads.max(1))
            .map(|w| {
                let inner = Arc::clone(&inner);
                std::thread::Builder::new()
                    .name(format!("gta-pool-worker-{w}"))
                    .spawn(move || loop {
                        let work = {
                            let mut q = inner.tokens.lock().unwrap_or_else(|e| e.into_inner());
                            loop {
                                if let Some(w) = q.pop_front() {
                                    break Some(w);
                                }
                                // lint: relaxed-ok shutdown flag re-checked under the tokens mutex
                                if inner.closed.load(Ordering::Relaxed) {
                                    break None;
                                }
                                // recover a poisoned wait: the queue of
                                // dispatch tokens stays structurally valid
                                q = inner.ready.wait(q).unwrap_or_else(|e| e.into_inner());
                            }
                        };
                        match work {
                            Some(w) => w.run_one(),
                            None => return,
                        }
                    })
                    // lint: allow(R2) pool construction is pre-serving: a failed spawn is startup failure, not admitted-work loss
                    .expect("spawning pool worker thread")
            })
            .collect();
        WorkerPool { inner, handles: Mutex::new(handles) }
    }

    /// Worker threads in the pool.
    pub fn workers(&self) -> usize {
        self.handles.lock().unwrap_or_else(|e| e.into_inner()).len()
    }

    /// Enqueue one dispatch token. After [`shutdown`](Self::shutdown)
    /// the token is serviced inline on the calling thread instead —
    /// liveness over parallelism on the rare post-shutdown submit.
    fn dispatch(&self, work: Arc<SessionWork>) {
        // lint: relaxed-ok a racing shutdown still services the token (inline or by a live worker)
        if self.inner.closed.load(Ordering::Relaxed) {
            work.run_one();
            return;
        }
        self.inner.tokens.lock().unwrap_or_else(|e| e.into_inner()).push_back(work);
        self.inner.ready.notify_one();
    }

    /// Stop the workers: already-dispatched tokens are still serviced
    /// (a pool shutdown never strands an admitted request), then the
    /// threads exit and are joined.
    pub fn shutdown(&self) {
        self.inner.closed.store(true, Ordering::SeqCst);
        self.inner.ready.notify_all();
        let handles: Vec<_> =
            self.handles.lock().unwrap_or_else(|e| e.into_inner()).drain(..).collect();
        for h in handles {
            let _ = h.join();
        }
    }
}

impl Drop for WorkerPool {
    fn drop(&mut self) {
        self.shutdown();
    }
}

/// Receipt for one admitted request: its id and the shard the router
/// placed it on. The matching [`Response`] carries the same `id` and
/// `shard`, so tickets pair submissions with out-of-order completions.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Ticket {
    pub id: u64,
    pub shard: usize,
}

/// A rejected submission, with everything the caller needs to
/// synthesize a response for it (the batch wrapper does exactly that).
#[derive(Debug, Clone, Copy)]
pub struct SubmitError {
    /// Id of the request handed back.
    pub id: u64,
    /// Shard the router had picked before admission failed; `None` when
    /// the session was already closed (the request was never routed).
    pub shard: Option<usize>,
    pub error: AdmitError,
}

/// Live counters for one session (see [`RackSession::stats`]).
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct SessionStats {
    /// Tickets issued (requests admitted to the queue).
    pub submitted: u64,
    /// Responses handed to the caller (or folded in by `drain`/`close`).
    pub completed: u64,
    /// Submissions finally rejected with [`AdmitError::Busy`].
    pub rejected: u64,
    /// Admitted but not yet consumed: `submitted - completed`.
    pub outstanding: u64,
    /// Requests currently sitting in the admission queue.
    pub queue_depth: usize,
}

/// A long-lived ingest/egress handle over a rack (or the coordinator's
/// one-shard facade). See the module docs for the lifecycle; dropping a
/// session without closing it shuts the workers down cleanly (in-flight
/// work is still executed, its responses are discarded).
pub struct RackSession {
    shards: Vec<Arc<Shard>>,
    policy: Arc<dyn RoutePolicy>,
    queue: Arc<AdmissionQueue<(usize, Request)>>,
    /// Completion channel. The mutex makes consumption `&self`; there is
    /// still effectively one consumer at a time (a blocked `recv` holds
    /// the lock until a response or channel disconnect arrives).
    rx: Mutex<mpsc::Receiver<Response>>,
    workers: Mutex<Vec<std::thread::JoinHandle<()>>>,
    /// Pool mode (see [`WorkerPool`]): `workers` stays empty and every
    /// admitted request dispatches one token to the shared pool.
    pool: Option<(Arc<WorkerPool>, Arc<SessionWork>)>,
    /// Completion-notification hook, shared with whichever workers
    /// (dedicated or pooled) execute this session's requests.
    notify: Arc<Mutex<Option<NotifyFn>>>,
    opts: ServeOptions,
    opened: Instant,
    closed: AtomicBool,
    // lifecycle counters (atomics: submit and recv may run on different
    // threads — the network server's reader/writer split)
    submitted: AtomicU64,
    completed: AtomicU64,
    rejected: AtomicU64,
    errors: AtomicU64,
    functional: AtomicU64,
    total_sim_cycles: AtomicU64,
}

impl RackSession {
    /// Spawn the session's worker pool over `shards`. Called through
    /// [`super::rack::Rack::open_session`] /
    /// [`super::Coordinator::open_session`].
    pub(super) fn open(
        shards: Vec<Arc<Shard>>,
        policy: Arc<dyn RoutePolicy>,
        opts: ServeOptions,
    ) -> RackSession {
        Self::build(shards, policy, opts, None)
    }

    /// Open a session that spawns NO threads of its own: execution is
    /// delegated to the shared [`WorkerPool`], so a server can hold
    /// thousands of live sessions with O(pool) threads. Called through
    /// [`super::rack::Rack::open_session_on`]. `opts.workers` is
    /// ignored in this mode (the pool's size governs).
    pub(super) fn open_on_pool(
        shards: Vec<Arc<Shard>>,
        policy: Arc<dyn RoutePolicy>,
        opts: ServeOptions,
        pool: &Arc<WorkerPool>,
    ) -> RackSession {
        Self::build(shards, policy, opts, Some(Arc::clone(pool)))
    }

    fn build(
        shards: Vec<Arc<Shard>>,
        policy: Arc<dyn RoutePolicy>,
        opts: ServeOptions,
        pool: Option<Arc<WorkerPool>>,
    ) -> RackSession {
        let queue = Arc::new(AdmissionQueue::<(usize, Request)>::new(opts.queue_capacity));
        let (tx, rx) = mpsc::channel::<Response>();
        let notify: Arc<Mutex<Option<NotifyFn>>> = Arc::new(Mutex::new(None));
        let (workers, pool) = match pool {
            Some(pool) => {
                let work = Arc::new(SessionWork {
                    shards: shards.clone(),
                    queue: Arc::clone(&queue),
                    tx,
                    pending: Mutex::new(0),
                    idle: Condvar::new(),
                    notify: Arc::clone(&notify),
                });
                (Vec::new(), Some((pool, work)))
            }
            None => {
                let workers = (0..opts.workers.max(1))
                    .map(|w| {
                        let queue = Arc::clone(&queue);
                        let tx = tx.clone();
                        let shards = shards.clone();
                        let notify = Arc::clone(&notify);
                        std::thread::Builder::new()
                            .name(format!("gta-session-worker-{w}"))
                            .spawn(move || {
                                while let Some((sidx, req)) = queue.pop() {
                                    let shard = &shards[sidx];
                                    // lint: relaxed-ok load gauge; routing tolerates stale reads
                                    shard.queued.fetch_sub(1, Ordering::Relaxed);
                                    let resp = shard.handle_caught(req);
                                    // lint: relaxed-ok load gauge; routing tolerates stale reads
                                    shard.in_flight.fetch_sub(1, Ordering::Relaxed);
                                    if tx.send(resp).is_err() {
                                        break;
                                    }
                                    let cb = notify
                                        .lock()
                                        .unwrap_or_else(|e| e.into_inner())
                                        .clone();
                                    if let Some(cb) = cb {
                                        cb();
                                    }
                                }
                            })
                            // lint: allow(R2) session construction is pre-serving: a failed spawn is startup failure, not admitted-work loss
                            .expect("spawning session worker thread")
                    })
                    .collect();
                (workers, None)
            }
        };
        RackSession {
            shards,
            policy,
            queue,
            rx: Mutex::new(rx),
            workers: Mutex::new(workers),
            pool,
            notify,
            opts,
            opened: Instant::now(),
            closed: AtomicBool::new(false),
            submitted: AtomicU64::new(0),
            completed: AtomicU64::new(0),
            rejected: AtomicU64::new(0),
            errors: AtomicU64::new(0),
            functional: AtomicU64::new(0),
            total_sim_cycles: AtomicU64::new(0),
        }
    }

    /// Submit one request. Routes on THIS thread in call order (see the
    /// module docs on determinism), then admits to the bounded queue
    /// under the session's [`AdmissionPolicy`]: `Block` exerts
    /// backpressure by stalling the caller until a slot frees;
    /// `Reject { retries, backoff_us }` requeues up to `retries` times,
    /// sleeping `backoff_us` between attempts (each counted as
    /// `admission_requeued`), then fails fast with [`AdmitError::Busy`]
    /// (counted as `admission_rejected`). After [`close`](Self::close)/
    /// [`drain`](Self::drain) every submission fails with an explicit
    /// [`AdmitError::Closed`] — tickets are never silently dropped.
    pub fn submit(&self, req: Request) -> Result<Ticket, AdmitError> {
        self.try_submit(req).map_err(|e| e.error)
    }

    /// [`submit`](Self::submit), but the rejection hands back the id and
    /// routed shard so the caller can synthesize a per-request response
    /// (what the batch `serve_with` wrapper does, and what the network
    /// server turns into a wire-level `Busy` frame).
    pub fn try_submit(&self, req: Request) -> Result<Ticket, SubmitError> {
        let id = req.id;
        if self.is_closed() {
            return Err(SubmitError { id, shard: None, error: AdmitError::Closed });
        }
        // span bookkeeping: the Admit span covers this whole call
        // (routing + queue admission incl. requeue retries); the Route
        // span is the nested policy decision alone. trace id = ticket id.
        let trace = obs::TraceCtx::new(id);
        let admit_start = obs::now_us();
        let is_functional = matches!(req.exec, super::ExecKind::Functional { .. });
        let sidx = route_on(self.policy.as_ref(), &self.shards, &req);
        let shard = Arc::clone(&self.shards[sidx]);
        shard
            .metrics
            .record_stage(Stage::Route, obs::now_us().saturating_sub(admit_start));
        trace.emit_since(Stage::Route, sidx as u16, admit_start, sidx as u64);
        // lint: relaxed-ok load gauges: routing tolerates stale reads, so updates need no ordering
        shard.routed.fetch_add(1, Ordering::Relaxed);
        // lint: relaxed-ok load gauges: routing tolerates stale reads, so updates need no ordering
        shard.in_flight.fetch_add(1, Ordering::Relaxed);
        // lint: relaxed-ok load gauges: routing tolerates stale reads, so updates need no ordering
        shard.queued.fetch_add(1, Ordering::Relaxed);
        // Count the submission BEFORE admitting (and roll back on
        // rejection): once the item is in the queue a concurrent
        // consumer thread — the network server's egress pump — may count
        // the completion immediately, and `completed > submitted` would
        // underflow `outstanding`.
        // lint: relaxed-ok lifecycle counter; outstanding() is documented as a non-atomic snapshot
        self.submitted.fetch_add(1, Ordering::Relaxed);
        if is_functional {
            // lint: relaxed-ok lifecycle counter; see submitted above
            self.functional.fetch_add(1, Ordering::Relaxed);
        }
        // the Reject policy's tunable requeue loop: retry a Busy up to
        // `retries` times before surfacing it
        let mut requeues = 0u64;
        let mut attempt = self.queue.admit((sidx, req), self.opts.policy);
        if let AdmissionPolicy::Reject { retries, backoff_us } = self.opts.policy {
            let mut tries = 0u32;
            loop {
                match attempt {
                    Err((item, AdmitError::Busy)) if tries < retries => {
                        tries += 1;
                        requeues += 1;
                        shard.metrics.record_admission_requeued();
                        if backoff_us > 0 {
                            std::thread::sleep(Duration::from_micros(backoff_us));
                        }
                        attempt = self.queue.admit(item, self.opts.policy);
                    }
                    other => {
                        attempt = other;
                        break;
                    }
                }
            }
        }
        match attempt {
            Ok(()) => {
                shard
                    .metrics
                    .record_stage(Stage::Admit, obs::now_us().saturating_sub(admit_start));
                trace.emit_since(Stage::Admit, sidx as u16, admit_start, requeues);
                shard.metrics.record_queue_depth(self.queue.depth());
                if let Some((pool, work)) = &self.pool {
                    *work.pending.lock().unwrap_or_else(|e| e.into_inner()) += 1;
                    pool.dispatch(Arc::clone(work));
                }
                Ok(Ticket { id, shard: sidx })
            }
            Err((_, error)) => {
                // lint: relaxed-ok lifecycle counter; see submitted above
                self.submitted.fetch_sub(1, Ordering::Relaxed);
                if is_functional {
                    // lint: relaxed-ok lifecycle counter; see submitted above
                    self.functional.fetch_sub(1, Ordering::Relaxed);
                }
                if error == AdmitError::Busy {
                    shard.metrics.record_admission_rejected();
                    // lint: relaxed-ok lifecycle counter; see submitted above
                    self.rejected.fetch_add(1, Ordering::Relaxed);
                }
                // lint: relaxed-ok load gauges: routing tolerates stale reads
                shard.in_flight.fetch_sub(1, Ordering::Relaxed);
                // lint: relaxed-ok load gauges: routing tolerates stale reads
                shard.queued.fetch_sub(1, Ordering::Relaxed);
                Err(SubmitError { id, shard: Some(sidx), error })
            }
        }
    }

    /// Next completed response, blocking while work is outstanding.
    /// Returns `None` when nothing is outstanding (so a submit/recv loop
    /// can never deadlock on its own session) or after the workers shut
    /// down.
    pub fn recv(&self) -> Option<Response> {
        if self.outstanding() == 0 {
            return None;
        }
        match self.rx.lock().unwrap_or_else(|e| e.into_inner()).recv() {
            Ok(resp) => Some(self.count(resp)),
            Err(_) => None,
        }
    }

    /// Next completed response if one is ready right now.
    pub fn try_recv(&self) -> Option<Response> {
        match self.rx.lock().unwrap_or_else(|e| e.into_inner()).try_recv() {
            Ok(resp) => Some(self.count(resp)),
            Err(_) => None,
        }
    }

    /// Next completed response, waiting at most `timeout` — regardless
    /// of whether anything is currently outstanding (a concurrent
    /// submitter may admit work at any moment). `None` on timeout or
    /// after the workers shut down; pair with
    /// [`is_closed`](Self::is_closed) to tell the two apart. This is the
    /// egress pump's accessor: `net::server`'s writer thread calls it in
    /// a loop while the reader thread keeps submitting.
    pub fn recv_timeout(&self, timeout: Duration) -> Option<Response> {
        match self.rx.lock().unwrap_or_else(|e| e.into_inner()).recv_timeout(timeout) {
            Ok(resp) => Some(self.count(resp)),
            Err(_) => None,
        }
    }

    /// Blocking iterator over completions: yields until every currently
    /// outstanding request has been consumed, then stops (submit more
    /// and iterate again, or interleave — see [`recv`](Self::recv)).
    pub fn iter(&self) -> impl Iterator<Item = Response> + '_ {
        std::iter::from_fn(move || self.recv())
    }

    /// Tickets admitted but not yet consumed by the caller.
    /// (Saturating: with a concurrent submitter and consumer the two
    /// loads are not one atomic snapshot.)
    pub fn outstanding(&self) -> u64 {
        // lint: relaxed-ok monotone counters; the doc notes the pair is not one atomic snapshot
        let submitted = self.submitted.load(Ordering::Relaxed);
        // lint: relaxed-ok monotone counters; the doc notes the pair is not one atomic snapshot
        let completed = self.completed.load(Ordering::Relaxed);
        submitted.saturating_sub(completed)
    }

    /// Whether [`drain`](Self::drain)/[`close`](Self::close) has begun:
    /// all subsequent submissions fail with [`AdmitError::Closed`].
    pub fn is_closed(&self) -> bool {
        // lint: relaxed-ok flag read; seal() publishes with SeqCst and stale reads only delay rejection
        self.closed.load(Ordering::Relaxed)
    }

    /// Whether the admission queue has a free slot RIGHT NOW. Only
    /// meaningful to a sole submitter (depth can rise concurrently
    /// otherwise) — which the event-loop server is: it checks this
    /// before decoding the next `Submit` so a `Block`-policy session
    /// exerts backpressure by pausing the connection's reads instead of
    /// parking the loop thread in `admit`.
    pub fn has_capacity(&self) -> bool {
        self.queue.depth() < self.queue.capacity()
    }

    /// Install (or clear) the completion-notification hook: called by a
    /// worker after each response is delivered to the completion
    /// channel. The event-loop server registers a wakeup-fd write here
    /// and then consumes with [`try_recv`](Self::try_recv) only — no
    /// thread ever parks in [`recv_timeout`](Self::recv_timeout). The
    /// callback runs on worker threads: keep it cheap, never block.
    pub fn set_notify(&self, f: Option<NotifyFn>) {
        *self.notify.lock().unwrap_or_else(|e| e.into_inner()) = f;
    }

    /// Non-blocking first half of [`drain`](Self::drain): stop
    /// admissions (subsequent submits fail with
    /// [`AdmitError::Closed`]) and let workers finish what was
    /// admitted, WITHOUT waiting for them. The event loop seals a
    /// session the moment a drain/close request arrives, keeps pumping
    /// completions, and calls `drain`/[`close`](Self::close) — then
    /// instant — once [`outstanding`](Self::outstanding) hits zero.
    pub fn seal(&self) {
        self.closed.store(true, Ordering::SeqCst);
        self.queue.close();
    }

    /// The options this session was opened with.
    pub fn opts(&self) -> ServeOptions {
        self.opts
    }

    /// Live session counters (queue depth, submitted/completed/rejected).
    pub fn stats(&self) -> SessionStats {
        // lint: relaxed-ok monotone counters; stats() is an advisory snapshot
        let submitted = self.submitted.load(Ordering::Relaxed);
        // lint: relaxed-ok monotone counters; stats() is an advisory snapshot
        let completed = self.completed.load(Ordering::Relaxed);
        SessionStats {
            submitted,
            completed,
            // lint: relaxed-ok monotone counters; stats() is an advisory snapshot
            rejected: self.rejected.load(Ordering::Relaxed),
            outstanding: submitted.saturating_sub(completed),
            queue_depth: self.queue.depth(),
        }
    }

    /// Fold one consumed response into the lifecycle counters.
    fn count(&self, resp: Response) -> Response {
        // lint: relaxed-ok monotone counter; only summed at close, no ordering needed
        self.completed.fetch_add(1, Ordering::Relaxed);
        // lint: relaxed-ok monotone counter; only summed at close, no ordering needed
        self.total_sim_cycles.fetch_add(resp.sim.cycles, Ordering::Relaxed);
        if resp.error.is_some() {
            // lint: relaxed-ok monotone counter; only summed at close, no ordering needed
            self.errors.fetch_add(1, Ordering::Relaxed);
        }
        resp
    }

    /// Stop admissions, let the workers drain every queued and in-flight
    /// request, and return all not-yet-consumed responses, ordered by
    /// the same completion-ordering rule as the batch path
    /// ([`order_responses`] — sorted by id). Subsequent
    /// [`submit`](Self::submit)s fail with [`AdmitError::Closed`]. A
    /// concurrent consumer (e.g. a still-running egress pump) may take
    /// some of the final responses instead; they are folded into the
    /// session counters either way.
    pub fn drain(&self) -> Vec<Response> {
        self.seal();
        if let Some((_, work)) = &self.pool {
            // pool mode: wait for the last dispatched token, not threads
            work.wait_idle();
        }
        let handles: Vec<_> = self
            .workers
            .lock()
            .unwrap_or_else(|e| e.into_inner())
            .drain(..)
            .collect();
        for h in handles {
            let _ = h.join();
        }
        // workers are done: everything they completed is in the channel
        let mut out = Vec::new();
        {
            let rx = self.rx.lock().unwrap_or_else(|e| e.into_inner());
            while let Ok(resp) = rx.try_recv() {
                out.push(self.count(resp));
            }
        }
        order_responses(&mut out);
        out
    }

    /// Drain in-flight work ([`drain`](Self::drain) — unconsumed
    /// responses are folded into the summary counters and dropped; call
    /// `drain` first to keep them) and return the final session summary:
    /// lifecycle counters, wall-clock throughput, the rack-wide metrics
    /// rollup and per-shard telemetry. Verification counters are zero —
    /// checking outputs against an oracle is the driver's job
    /// (`serve::run_stream` and friends), not the session's.
    pub fn close(&self) -> ServeSummary {
        let unconsumed = self.drain();
        drop(unconsumed); // already folded into the counters by drain()
        let wall = self.opened.elapsed().as_secs_f64();
        let shards = RackSnapshot::from_shards(self.shards.iter().map(|s| s.telemetry()).collect());
        let snap = shards.aggregate.clone();
        // lint: relaxed-ok monotone counters read after drain(): workers have joined
        let completed = self.completed.load(Ordering::Relaxed);
        ServeSummary {
            requests: completed,
            // lint: relaxed-ok monotone counters read after drain(): workers have joined
            functional: self.functional.load(Ordering::Relaxed),
            verified_ok: 0,
            verified_failed: 0,
            // lint: relaxed-ok monotone counters read after drain(): workers have joined
            errors: self.errors.load(Ordering::Relaxed),
            prescheduled: 0,
            coalesced_batches: snap.batches,
            max_batch: snap.max_batch,
            coalesce_window_us: snap.coalesce_window_us,
            shards: Some(shards),
            wall_seconds: wall,
            throughput_rps: completed as f64 / wall.max(1e-9),
            // lint: relaxed-ok monotone counters read after drain(): workers have joined
            total_sim_cycles: self.total_sim_cycles.load(Ordering::Relaxed),
            metrics: snap,
        }
    }
}

impl Drop for RackSession {
    fn drop(&mut self) {
        if !self.is_closed() || !self.workers.lock().unwrap_or_else(|e| e.into_inner()).is_empty() {
            let _ = self.drain();
        }
    }
}
