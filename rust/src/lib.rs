//! # GTA — a General Tensor Accelerator (reproduction)
//!
//! Library reproduction of *"GTA: a new General Tensor Accelerator with
//! Better Area Efficiency and Data Reuse"* (CS.AR 2024): the MPRA
//! multi-precision systolic model, the p-GEMM/vector operator
//! classification, the joint dataflow × precision × array-resize
//! scheduling space, cycle/traffic simulators for GTA and the three
//! baselines (Ara VPU, H100 GPGPU, HyCube CGRA), and a tokio + PJRT
//! execution runtime that runs the AOT-compiled Pallas functional model
//! of the MPRA datapath.
//!
//! Layered per DESIGN.md:
//! * [`precision`] / [`ops`] / [`lowering`] — the operator algebra (§3)
//! * [`arch`] — MPRA/lane/SysCSR hardware model (§4)
//! * [`scheduler`] — scheduling-space exploration (§5). The cost model
//!   and least-sum-of-squares selection live in the module root; the
//!   search engine is `scheduler::explorer` — a worker-pool sweep over
//!   the dataflow × arrangement × K-segmentation × tile-direction space
//!   with Pareto lower-bound pruning and batch entry points
//!   (`explore_batch` / `schedule_batch`), all memoized through the
//!   compute-once shared caches in `scheduler::cache` (keyed by
//!   `(PGemm, GtaConfig)` per sweep/selection and
//!   `(PGemm, GtaConfig, ScheduleConfig)` per evaluation), so repeated
//!   operators in a workload schedule in O(1) and concurrent requests
//!   dedup onto a single search
//! * [`sim`] — cycle-accurate-style platform simulators (§6); the GTA
//!   simulator batch-schedules a workload's distinct p-GEMMs through the
//!   explorer pool before accumulating
//! * [`workloads`] — the Table 2 suite
//! * [`runtime`] / [`coordinator`] — the L3 execution engine: an
//!   `ExecBackend` (the PJRT engine behind the `pjrt` feature, a clean-
//!   failing stub offline, or the always-available `SoftBackend` limb
//!   oracle) owned by a dedicated executor thread, fed by a coalescing
//!   dispatcher (optionally adaptive-window) that batches same-shape
//!   functional tiles, behind a bounded admission queue with
//!   backpressure (see `docs/serving.md`). Since the rack refactor the
//!   serving machinery lives in `coordinator::rack`: a `Rack` shards
//!   requests across N GTA instances via a `RoutePolicy`
//!   (round-robin / least-loaded / shape-affinity / capacity-weighted),
//!   every shard owning its own config + lane allocator + backend +
//!   metrics while ALL shards share one `scheduler::Explorer` memo;
//!   `Coordinator` is the one-shard special case (see
//!   `docs/sharding.md`). The primary ingest surface is the long-lived
//!   streaming `coordinator::RackSession` (`open_session` →
//!   submit/recv as requests arrive and complete → `close`), with
//!   batch `serve`/`serve_with` as thin wrappers over it and an
//!   open-loop seeded arrival driver in `serve`
//!   (`gta serve --stream`, see `docs/serving.md`)
//! * [`net`] — the session over a real transport: a dependency-free
//!   TCP wire protocol (length-prefixed frames, JSON bodies), a
//!   `NetServer` giving every accepted connection its own
//!   `RackSession` against one shared `Rack`, and the blocking
//!   `GtaClient` mirror of the session API
//!   (`gta serve --listen` / `gta client --connect`, see
//!   `docs/transport.md`)
//! * [`report`] — regenerates every table and figure of the paper
//! * [`analysis`] — `gta analyze`, the dependency-free invariant linter
//!   that encodes the repo's bug history (narrowing casts in decoders,
//!   panics in the serving hot path, unpoisoned locks, …) as
//!   machine-checked rules with a suppression/baseline workflow
//!   (see `docs/analysis.md`)
//! * [`obs`] — end-to-end request tracing and exact latency histograms:
//!   per-stage `SpanEvent`s in lock-light bounded rings (gated by one
//!   atomic flag), log-bucket histograms that merge exactly across
//!   shards, Chrome `trace_event` export (`gta trace`) and the live
//!   `Stats` wire frame (`gta stats --connect`, see
//!   `docs/observability.md`)

pub mod analysis;
pub mod arch;
pub mod coordinator;
pub mod net;
pub mod obs;
pub mod util;
pub mod lowering;
pub mod ops;
pub mod precision;
pub mod report;
pub mod runtime;
pub mod scheduler;
pub mod serve;
pub mod sim;
pub mod verify;
pub mod workloads;

pub use arch::{Arrangement, Dataflow, GtaConfig, SysCsr};
pub use ops::{PGemm, TensorOp, VectorKind, VectorOp};
pub use precision::Precision;
