//! # GTA — a General Tensor Accelerator (reproduction)
//!
//! Library reproduction of *"GTA: a new General Tensor Accelerator with
//! Better Area Efficiency and Data Reuse"* (CS.AR 2024): the MPRA
//! multi-precision systolic model, the p-GEMM/vector operator
//! classification, the joint dataflow × precision × array-resize
//! scheduling space, cycle/traffic simulators for GTA and the three
//! baselines (Ara VPU, H100 GPGPU, HyCube CGRA), and a tokio + PJRT
//! execution runtime that runs the AOT-compiled Pallas functional model
//! of the MPRA datapath.
//!
//! Layered per DESIGN.md:
//! * [`precision`] / [`ops`] / [`lowering`] — the operator algebra (§3)
//! * [`arch`] — MPRA/lane/SysCSR hardware model (§4)
//! * [`scheduler`] — scheduling-space exploration (§5)
//! * [`sim`] — cycle-accurate-style platform simulators (§6)
//! * [`workloads`] — the Table 2 suite
//! * [`runtime`] / [`coordinator`] — the L3 execution engine
//! * [`report`] — regenerates every table and figure of the paper

pub mod arch;
pub mod coordinator;
pub mod util;
pub mod lowering;
pub mod ops;
pub mod precision;
pub mod report;
pub mod runtime;
pub mod scheduler;
pub mod serve;
pub mod sim;
pub mod verify;
pub mod workloads;

pub use arch::{Arrangement, Dataflow, GtaConfig, SysCsr};
pub use ops::{PGemm, TensorOp, VectorKind, VectorOp};
pub use precision::Precision;
