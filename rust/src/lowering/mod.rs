//! Lowering: turn domain operators into the p-GEMM + vector decomposition
//! GTA executes (§3.2). Each function returns the operator list in
//! execution order; the coordinator schedules each element independently.
//!
//! Where the paper cites TTGT ("tensor contractions can be rewritten as
//! Transpose-Transpose-GEMM-Transpose sequences"), the transposes appear
//! as vector `Map` passes around the central p-GEMM.

use crate::ops::{TensorOp, VectorKind};
use crate::precision::Precision;

/// `conv2d` via im2col: (C,H,W) ⊛ (K,C,R,S), valid padding, stride `stride`
/// → GEMM `M=K, N=OH·OW, K=C·R·S` plus the im2col gather (a Map pass).
pub fn conv2d_im2col(
    c: u64,
    h: u64,
    w: u64,
    k: u64,
    r: u64,
    s: u64,
    stride: u64,
    p: Precision,
) -> Vec<TensorOp> {
    assert!(h >= r && w >= s && stride >= 1);
    let oh = (h - r) / stride + 1;
    let ow = (w - s) / stride + 1;
    vec![
        // im2col patch gather: one copy per patch element
        TensorOp::vector(c * r * s * oh * ow, p, VectorKind::Map),
        TensorOp::gemm(k, oh * ow, c * r * s, p),
    ]
}

/// Dense layer: `y[B,N] = x[B,K]·W[K,N]` (+ bias + activation vector ops).
pub fn dense(b: u64, k: u64, n: u64, p: Precision, activation: bool) -> Vec<TensorOp> {
    let mut ops = vec![TensorOp::gemm(b, n, k, p)];
    ops.push(TensorOp::vector(b * n, p, VectorKind::Axpy)); // bias
    if activation {
        ops.push(TensorOp::vector(b * n, p, VectorKind::Activation));
    }
    ops
}

/// Tensor contraction via TTGT: contract a (I,J,K)×(K,L) style problem.
/// `outer` = product of uncontracted lhs dims, `inner` = contracted dim,
/// `rhs` = product of uncontracted rhs dims.
pub fn contraction_ttgt(outer: u64, inner: u64, rhs: u64, p: Precision) -> Vec<TensorOp> {
    vec![
        TensorOp::vector(outer * inner, p, VectorKind::Map), // transpose in
        TensorOp::gemm(outer, rhs, inner, p),
        TensorOp::vector(outer * rhs, p, VectorKind::Map), // transpose out
    ]
}

/// MTTKRP (matricized-tensor × Khatri-Rao product): X(1)·(C ⊙ B) for an
/// I×J×K tensor and rank-R factors — GEMM (I, R, J·K) after matricization.
pub fn mttkrp(i: u64, j: u64, k: u64, rank: u64, p: Precision) -> Vec<TensorOp> {
    vec![
        TensorOp::vector(j * k * rank, p, VectorKind::Map), // Khatri-Rao product
        TensorOp::gemm(i, rank, j * k, p),
    ]
}

/// Big-number multiplication (BNM): two `l`-limb operands → the rank-1
/// limb p-GEMM (outer product) + carry pass (§3.1, Fig. 1).
pub fn bignum_mul(l: u64) -> Vec<TensorOp> {
    vec![
        TensorOp::gemm(l, l, 1, Precision::Int8), // limb outer product
        TensorOp::vector(2 * l - 1, Precision::Int32, VectorKind::Reduce), // carry chain
    ]
}

/// FIR filter (audio FFE): `taps`-tap filter over `n` samples — a GEMV-like
/// p-GEMM (1, n, taps) expressed over the delay-line matrix.
pub fn fir_filter(n: u64, taps: u64, p: Precision) -> Vec<TensorOp> {
    vec![
        TensorOp::vector(n, p, VectorKind::Map), // delay-line window gather
        TensorOp::gemm(1, n, taps, p),
    ]
}

/// Colour-space conversion (SRGB2XYZ): 3×3 matrix × `pixels` columns.
pub fn color_convert(pixels: u64, p: Precision) -> Vec<TensorOp> {
    vec![
        TensorOp::gemm(3, pixels, 3, p),
        TensorOp::vector(3 * pixels, p, VectorKind::Activation), // gamma
    ]
}

/// PCA: covariance GEMM (D,D,N) + eigen iterations as GEMV p-GEMMs.
pub fn pca(n: u64, d: u64, iters: u64, p: Precision) -> Vec<TensorOp> {
    let mut ops = vec![
        TensorOp::vector(n * d, p, VectorKind::Map), // centering
        TensorOp::gemm(d, d, n, p),                  // XᵀX
    ];
    for _ in 0..iters {
        ops.push(TensorOp::gemm(d, 1, d, p)); // power-iteration GEMV
        ops.push(TensorOp::vector(d, p, VectorKind::Reduce)); // normalize
    }
    ops
}

/// Blocked matrix decomposition (LU-style) trailing updates: for an
/// `n`×`n` matrix with block size `b`, each step k does a (n-kb)² × b GEMM.
pub fn matrix_decomposition(n: u64, b: u64, p: Precision) -> Vec<TensorOp> {
    assert!(b > 0 && n >= b);
    let mut ops = Vec::new();
    let steps = n / b;
    for step in 0..steps {
        let rem = n - (step + 1) * b;
        // panel factorization: vector-heavy (division, scaling)
        ops.push(TensorOp::vector((n - step * b) * b, p, VectorKind::Axpy));
        if rem > 0 {
            // trailing update A22 -= A21·A12
            ops.push(TensorOp::gemm(rem, rem, b, p));
        }
    }
    ops
}

/// NTT butterfly stages (encryption): n·log n butterflies, vector-mode
/// (no reuse), plus twiddle multiplication.
pub fn ntt(n: u64, p: Precision) -> Vec<TensorOp> {
    let log_n = 64 - (n - 1).leading_zeros() as u64;
    vec![TensorOp::vector(n * log_n, p, VectorKind::Axpy)]
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ops::classify::{classify, OpClass};

    #[test]
    fn conv_gemm_dims_match_im2col() {
        let ops = conv2d_im2col(64, 15, 15, 64, 3, 3, 1, Precision::Int8);
        match ops[1] {
            TensorOp::PGemm(g) => {
                assert_eq!(g.m, 64);
                assert_eq!(g.n, 13 * 13);
                assert_eq!(g.k, 64 * 9);
            }
            _ => panic!("expected GEMM"),
        }
    }

    #[test]
    fn conv_total_macs_equal_direct_conv() {
        // direct conv MACs = K·OH·OW·C·R·S == GEMM M·N·K
        let (c, h, w, k, r) = (16u64, 10u64, 10u64, 8u64, 3u64);
        let ops = conv2d_im2col(c, h, w, k, r, r, 1, Precision::Int8);
        let gemm_macs = match ops[1] {
            TensorOp::PGemm(g) => g.macs(),
            _ => unreachable!(),
        };
        let oh = h - r + 1;
        assert_eq!(gemm_macs, k * oh * oh * c * r * r);
    }

    #[test]
    fn bignum_is_rank1_pgemm() {
        let ops = bignum_mul(64);
        match ops[0] {
            TensorOp::PGemm(g) => {
                assert_eq!((g.m, g.n, g.k), (64, 64, 1));
                assert_eq!(g.precision, Precision::Int8);
            }
            _ => panic!(),
        }
    }

    #[test]
    fn decomposition_shrinks_updates() {
        let ops = matrix_decomposition(256, 32, Precision::Int32);
        let gemms: Vec<_> = ops
            .iter()
            .filter_map(|o| match o {
                TensorOp::PGemm(g) => Some(g.m),
                _ => None,
            })
            .collect();
        assert_eq!(gemms.len(), 7); // last step has no trailing block
        assert!(gemms.windows(2).all(|w| w[0] > w[1]));
    }

    #[test]
    fn ntt_is_vector_class() {
        for op in ntt(8192, Precision::Int64) {
            assert_eq!(classify(&op), OpClass::Vector);
        }
    }

    #[test]
    fn dense_contains_pgemm_and_vector() {
        let ops = dense(16, 256, 1024, Precision::Bp16, true);
        assert_eq!(ops.len(), 3);
        assert!(matches!(ops[0], TensorOp::PGemm(_)));
        assert!(matches!(ops[1], TensorOp::Vector(_)));
    }
}
