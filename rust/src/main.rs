//! `gta` — CLI for the GTA reproduction: regenerate the paper's tables and
//! figures, run workloads on any platform simulator, explore schedules,
//! and drive the functional PJRT path.

use anyhow::{anyhow, bail, Result};
use gta::ops::PGemm;
use gta::precision::Precision;
use gta::report;
use gta::runtime::default_artifact_dir;
use gta::sim::{cgra::CgraSim, gpgpu::GpgpuSim, gta::GtaSim, vpu::VpuSim, Platform};
use gta::workloads;
use gta::{scheduler, GtaConfig};

const USAGE: &str = "\
gta — General Tensor Accelerator reproduction

USAGE:
  gta table1|table2|table3          print a paper table
  gta fig2|fig5|fig6|fig7|fig8|fig9|fig10
                                    regenerate a paper figure's data
  gta run --workload <NAME|all> [--platform gta|vpu|gpgpu|cgra] [--lanes N]
                                    simulate a Table 2 workload
  gta schedule --gemm MxNxK --precision <p> [--lanes N]
                                    explore + select a schedule (§5)
  gta verify [--artifacts DIR]      run every AOT artifact via PJRT and
                                    check numerics against the rust oracle
  gta serve --requests N [--artifacts DIR] [--workers W] [--backend pjrt|soft]
            [--shards N] [--policy rr|least|affinity|capacity]
            [--shard-lanes L1,L2,...]
            [--stream] [--arrival-rate R] [--seed S]
            [--listen ADDR] [--max-proto V] [--event-loop] [--max-conns C]
                                    e2e driver: mixed request stream through
                                    the batched (admission queue + coalescing)
                                    serve path; `--backend soft` runs the
                                    rust-oracle backend (no artifacts needed);
                                    `--shards N` serves through a multi-GTA
                                    rack (per-shard utilization in the
                                    summary; see docs/sharding.md);
                                    `--stream` feeds a long-lived RackSession
                                    as an open-loop Poisson arrival process at
                                    `--arrival-rate R` req/s (default 5000)
                                    with a seeded inter-arrival RNG
                                    (see docs/serving.md);
                                    `--listen ADDR` (e.g. 0.0.0.0:7070) puts
                                    the same rack on TCP instead: every
                                    connection gets its own streaming session
                                    (see docs/transport.md); `--max-proto V`
                                    caps the negotiated wire protocol
                                    (3 = session multiplexing, 2 = binary
                                    tensor frames, 1 = JSON-only v1 server);
                                    `--event-loop` serves with the poll-based
                                    event-loop server — O(workers) threads
                                    however many connections, multiplexed v3
                                    sessions, `--max-conns C` concurrent
                                    connections (default 16384)
  gta client --connect ADDR [--requests N] [--stream] [--arrival-rate R]
             [--seed S] [--proto V] [--sessions K] [--timeout-ms T]
                                    replay the mixed e2e stream against a
                                    `gta serve --listen` server over TCP:
                                    batch submit-then-drain by default,
                                    `--stream` replays the seeded open-loop
                                    Poisson driver (bit-comparable with the
                                    in-process `serve --stream` path);
                                    `--proto V` caps the version this client
                                    announces (1 = v1-forced JSON replay);
                                    `--sessions K` slices the replay across K
                                    logical sessions multiplexed on ONE
                                    connection (needs a v3 `--event-loop`
                                    server); `--timeout-ms T` bounds connect
                                    and per-response waits (default
                                    10000/30000)
  gta trace --requests N [--workers W] [--shards N] [--policy P]
            [--out FILE] [--machine-out FILE]
                                    run the seeded mixed stream through the
                                    soft-backend rack with span tracing ON and
                                    export every request's
                                    admit/route/schedule/coalesce/execute/
                                    respond spans as Chrome trace_event JSON
                                    (--out, default trace.json — open in
                                    chrome://tracing or Perfetto);
                                    `--machine-out FILE` also writes the
                                    gta.obs.trace/1 machine schema
                                    (see docs/observability.md)
  gta stats --connect ADDR [--proto V] [--timeout-ms T]
                                    fetch live telemetry from a running
                                    `gta serve --listen` server (protocol v3):
                                    per-shard counters, exact per-stage
                                    latency percentiles, connection gauges —
                                    no drain, no close, the server keeps
                                    serving
  gta bench-check [--dir DIR] [--analysis FILE]
                                    validate every BENCH_*.json perf baseline
                                    in DIR (default .): must parse, carry a
                                    `gta.bench.<name>/<version>` schema tag
                                    and a pinned `seed` (the CI sanity gate
                                    for the perf-trajectory harness);
                                    `--analysis FILE` additionally validates
                                    a `gta analyze --format json` report
                                    (schema gta.analysis.report/1, ok=true)
  gta analyze [--dir DIR] [--format text|json] [--baseline FILE]
              [--write-baseline]
                                    run the invariant linter over every .rs
                                    file under DIR (default .): ~8 rules
                                    encoding this repo's bug history (silent
                                    narrowing casts in decoders, panics in
                                    the serving hot path, unpoisoned locks,
                                    unjustified Relaxed atomics, ...; see
                                    docs/analysis.md). Pre-existing findings
                                    are grandfathered by analysis/
                                    BASELINE.json (auto-resolved next to
                                    DIR); anything new exits nonzero.
                                    `--write-baseline` regenerates the
                                    baseline from the current tree for
                                    burn-down bookkeeping
";

fn main() -> Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let cmd = args.first().map(String::as_str).unwrap_or("help");
    let flags = Flags::parse(&args[args.len().min(1)..]);
    match cmd {
        "table1" => {
            println!("Table 1: evaluated platforms");
            for p in report::table1() {
                println!(
                    "  {:<18} {:>4}nm {:>6}MHz {:>10.2}mm²  {}",
                    p.name, p.node_nm, p.freq_mhz, p.area_mm2, p.compute_units
                );
            }
        }
        "table2" => {
            println!("Table 2: workload suite");
            for w in workloads::suite() {
                println!(
                    "  {:<5} {:<8} {:>5} ops {:>16} MACs  {}",
                    w.name,
                    w.precision.name(),
                    w.ops.len(),
                    w.total_macs(),
                    w.description
                );
            }
        }
        "table3" => print!("{}", report::render_table3()),
        "fig2" => {
            println!("Fig 2: operator classification (parallelism, intensity)");
            for p in report::fig2() {
                println!(
                    "  {:<8} parallelism={:>12.1} intensity={:>8.2} -> {:?}",
                    p.family, p.parallelism, p.intensity, p.class
                );
            }
        }
        "fig5" => {
            println!("Fig 5: dataflow pattern matching (64-lane, 64x64 array)");
            for r in report::fig5() {
                println!(
                    "  {:<24} mapped {:>4}x{:<5} -> {:<9} max_k_seg={}",
                    r.workload, r.mapped.0, r.mapped.1, r.coverage, r.max_k_segments
                );
            }
        }
        "fig6" => {
            println!("Fig 6: MPRA energy per array-cycle (pJ)");
            for r in report::fig6() {
                println!(
                    "  {:<6} WS={:>6.2} OS={:>6.2} SIMD={:>6.2}  (Ara unit {:>6.2})",
                    r.precision, r.ws_pj, r.os_pj, r.simd_pj, r.ara_unit_pj
                );
            }
        }
        "fig7" => print!("{}", report::render_comparison(&report::fig7())),
        "fig8" => print!("{}", report::render_comparison(&report::fig8())),
        "fig10" => print!("{}", report::render_comparison(&report::fig10())),
        "fig9" => {
            println!("Fig 9: schedule space scatter (Alexnet conv3, 3 precisions)");
            println!(
                "  {:<6} {:<5} {:<6} {:>5} {:>12} {:>12} sel",
                "prec", "flow", "arr", "kseg", "cycles_ratio", "mem_ratio"
            );
            for p in report::fig9() {
                println!(
                    "  {:<6} {:<5} {:<6} {:>5} {:>12.3} {:>12.3} {}",
                    p.precision,
                    p.dataflow,
                    p.arrangement,
                    p.k_segments,
                    p.cycles_ratio,
                    p.mem_ratio,
                    if p.selected { "*" } else { "" }
                );
            }
        }
        "run" => cmd_run(&flags)?,
        "schedule" => cmd_schedule(&flags)?,
        "verify" => cmd_verify(&flags)?,
        "serve" => cmd_serve(&flags)?,
        "client" => cmd_client(&flags)?,
        "trace" => cmd_trace(&flags)?,
        "stats" => cmd_stats(&flags)?,
        "bench-check" => cmd_bench_check(&flags)?,
        "analyze" => cmd_analyze(&flags)?,
        "help" | "--help" | "-h" => print!("{USAGE}"),
        other => {
            eprint!("{USAGE}");
            bail!("unknown command {other:?}");
        }
    }
    Ok(())
}

/// Validate every committed `BENCH_*.json` perf baseline: parseable JSON
/// carrying a `gta.bench.<name>/<version>` schema tag and a pinned seed —
/// the contract the future cross-run comparator (see ROADMAP) relies on.
fn cmd_bench_check(flags: &Flags) -> Result<()> {
    let dir = flags.get("dir").unwrap_or(".");
    let mut names: Vec<String> = std::fs::read_dir(dir)
        .map_err(|e| anyhow!("bench-check: reading {dir:?}: {e}"))?
        .filter_map(|entry| {
            let name = entry.ok()?.file_name().to_string_lossy().into_owned();
            (name.starts_with("BENCH_") && name.ends_with(".json")).then_some(name)
        })
        .collect();
    names.sort();
    if names.is_empty() {
        bail!("bench-check: no BENCH_*.json baselines found in {dir:?}");
    }
    for name in &names {
        let path = std::path::Path::new(dir).join(name);
        let text = std::fs::read_to_string(&path)
            .map_err(|e| anyhow!("bench-check: reading {name}: {e}"))?;
        let json = gta::util::json::parse(&text)
            .map_err(|e| anyhow!("bench-check: {name}: {e}"))?;
        if json.as_obj().is_none() {
            bail!("bench-check: {name}: top level must be an object");
        }
        let schema = json
            .get("schema")
            .and_then(|s| s.as_str())
            .ok_or_else(|| anyhow!("bench-check: {name}: missing string field \"schema\""))?;
        let well_formed = schema
            .strip_prefix("gta.bench.")
            .and_then(|rest| rest.split_once('/'))
            .map(|(tag, ver)| !tag.is_empty() && ver.parse::<u64>().is_ok())
            .unwrap_or(false);
        if !well_formed {
            bail!(
                "bench-check: {name}: schema {schema:?} is not gta.bench.<name>/<version>"
            );
        }
        let seed = json
            .get("seed")
            .and_then(|s| s.as_u64())
            .ok_or_else(|| anyhow!("bench-check: {name}: missing integer field \"seed\""))?;
        let provisional = json.get("provisional") == Some(&gta::util::json::Json::Bool(true));
        println!(
            "  {name}: schema {schema} seed {seed}{}",
            if provisional { " (provisional placeholder)" } else { "" }
        );
    }
    println!("bench-check OK: {} baseline file(s) valid", names.len());
    if let Some(report) = flags.get("analysis") {
        check_analysis_report(report)?;
    }
    Ok(())
}

/// Validate a `gta analyze --format json` report: the schema tag, the
/// verdict, and the findings/grandfathered arrays CI consumers rely on.
fn check_analysis_report(path: &str) -> Result<()> {
    let text = std::fs::read_to_string(path)
        .map_err(|e| anyhow!("bench-check: reading analysis report {path}: {e}"))?;
    let json = gta::util::json::parse(&text)
        .map_err(|e| anyhow!("bench-check: analysis report {path}: {e}"))?;
    let schema = json.get("schema").and_then(|s| s.as_str()).unwrap_or("");
    if schema != gta::analysis::REPORT_SCHEMA {
        bail!(
            "bench-check: analysis report {path}: schema {schema:?} is not {}",
            gta::analysis::REPORT_SCHEMA
        );
    }
    for field in ["findings", "grandfathered"] {
        if json.get(field).and_then(|f| f.as_arr()).is_none() {
            bail!("bench-check: analysis report {path}: missing array field {field:?}");
        }
    }
    match json.get("ok") {
        Some(&gta::util::json::Json::Bool(true)) => {}
        Some(&gta::util::json::Json::Bool(false)) => {
            bail!("bench-check: analysis report {path}: analyze run recorded failures (ok=false)")
        }
        _ => bail!("bench-check: analysis report {path}: missing boolean field \"ok\""),
    }
    println!(
        "  analysis report {path}: schema {schema} ok ({} grandfathered group(s))",
        json.get("grandfathered").and_then(|g| g.as_arr()).map(|a| a.len()).unwrap_or(0)
    );
    Ok(())
}

/// `gta analyze`: run the invariant linter (see `gta::analysis` and
/// docs/analysis.md) over a source tree and gate on new findings.
fn cmd_analyze(flags: &Flags) -> Result<()> {
    use gta::analysis;
    let dir = std::path::PathBuf::from(flags.get("dir").unwrap_or("."));
    if !dir.is_dir() {
        bail!("analyze: {dir:?} is not a directory");
    }
    let (files_scanned, findings) =
        analysis::scan_dir(&dir).map_err(|e| anyhow!("analyze: scanning {dir:?}: {e}"))?;
    let baseline_path = match flags.get("baseline") {
        Some(p) => Some(std::path::PathBuf::from(p)),
        None => analysis::resolve_baseline_path(&dir),
    };
    if flags.get("write-baseline").is_some() {
        let out = baseline_path
            .clone()
            .unwrap_or_else(|| dir.join("analysis").join("BASELINE.json"));
        if let Some(parent) = out.parent() {
            std::fs::create_dir_all(parent)?;
        }
        let b = analysis::baseline_from_findings(
            &findings,
            "grandfathered pre-analysis finding: burn down, do not add to",
        );
        std::fs::write(&out, analysis::render_baseline(&b))
            .map_err(|e| anyhow!("analyze: writing {out:?}: {e}"))?;
        println!(
            "analyze: wrote baseline {out:?} covering {} (rule, file) group(s)",
            b.entries.len()
        );
        return Ok(());
    }
    let baseline = match &baseline_path {
        Some(p) => {
            let text = std::fs::read_to_string(p)
                .map_err(|e| anyhow!("analyze: reading baseline {p:?}: {e}"))?;
            analysis::parse_baseline(&text)
                .map_err(|e| anyhow!("analyze: baseline {p:?}: {e}"))?
        }
        None => analysis::Baseline::default(),
    };
    let (failing, grandfathered) = analysis::apply_baseline(findings, &baseline);
    let report = analysis::Report {
        dir: dir.display().to_string(),
        files_scanned,
        failing,
        grandfathered,
    };
    match flags.get("format").unwrap_or("text") {
        "json" => println!("{}", analysis::report_json(&report).render()),
        "text" => print!("{}", analysis::render_text(&report)),
        other => bail!("analyze: unknown --format {other:?} (text|json)"),
    }
    if !report.ok() {
        bail!(
            "analyze: {} new finding(s) — fix them, suppress with a reasoned \
             `// lint: allow(..)`, or (cold paths only) extend the baseline",
            report.failing.len()
        );
    }
    Ok(())
}

/// Tiny flag parser: `--key value` pairs (`--flag` alone = "true").
struct Flags(std::collections::HashMap<String, String>);

impl Flags {
    fn parse(args: &[String]) -> Flags {
        let mut map = std::collections::HashMap::new();
        let mut i = 0;
        while i < args.len() {
            if let Some(key) = args[i].strip_prefix("--") {
                if i + 1 < args.len() && !args[i + 1].starts_with("--") {
                    map.insert(key.to_string(), args[i + 1].clone());
                    i += 2;
                    continue;
                }
                map.insert(key.to_string(), "true".to_string());
            }
            i += 1;
        }
        Flags(map)
    }

    fn get(&self, key: &str) -> Option<&str> {
        self.0.get(key).map(String::as_str)
    }

    fn get_u64(&self, key: &str, default: u64) -> u64 {
        self.get(key).and_then(|v| v.parse().ok()).unwrap_or(default)
    }
}

fn platform_for(name: &str, lanes: u32) -> Result<Box<dyn Platform>> {
    Ok(match name {
        "gta" => Box::new(GtaSim::new(GtaConfig::with_lanes(lanes))),
        "vpu" => Box::new(VpuSim::default()),
        "gpgpu" => Box::new(GpgpuSim::default()),
        "cgra" => Box::new(CgraSim::default()),
        other => bail!("unknown platform {other:?} (gta|vpu|gpgpu|cgra)"),
    })
}

fn cmd_run(flags: &Flags) -> Result<()> {
    let which = flags.get("workload").unwrap_or("all");
    let lanes = flags.get_u64("lanes", 4) as u32;
    let platform = platform_for(flags.get("platform").unwrap_or("gta"), lanes)?;
    let suite = workloads::suite();
    let selected: Vec<_> = suite
        .iter()
        .filter(|w| which == "all" || w.name.eq_ignore_ascii_case(which))
        .collect();
    if selected.is_empty() {
        bail!("no workload named {which:?}");
    }
    println!(
        "{:<6} {:>16} {:>16} {:>14} {:>8}",
        "name", "cycles", "mem bytes", "energy(uJ)", "util"
    );
    for w in selected {
        let r = platform.run_all(&w.ops);
        println!(
            "{:<6} {:>16} {:>16} {:>14.2} {:>7.1}%  ({} @{}MHz)",
            w.name,
            r.cycles,
            r.memory_access(),
            r.energy_pj / 1e6,
            r.utilization * 100.0,
            platform.name(),
            r.freq_mhz
        );
    }
    Ok(())
}

fn cmd_schedule(flags: &Flags) -> Result<()> {
    let gemm = flags.get("gemm").ok_or_else(|| anyhow!("--gemm MxNxK required"))?;
    let dims: Vec<u64> = gemm
        .split(['x', 'X'])
        .map(|d| d.parse().map_err(|_| anyhow!("bad dim {d:?}")))
        .collect::<Result<_>>()?;
    let [m, n, k] = dims[..] else { bail!("--gemm wants MxNxK") };
    let precision = Precision::parse(flags.get("precision").unwrap_or("int8"))
        .ok_or_else(|| anyhow!("bad precision"))?;
    let cfg = GtaConfig::with_lanes(flags.get_u64("lanes", 16) as u32);
    let g = PGemm::new(m, n, k, precision);
    let cands = scheduler::explore(&g, &cfg);
    let best = scheduler::select(&cands);
    // the serving hot path runs the pruned sweep; show what it saves and
    // assert (cheaply, here) that the selection is identical
    let (survivors, stats) = scheduler::explorer::explore_pruned(&g, &cfg);
    assert_eq!(scheduler::select(&survivors).config, best.config);
    println!(
        "explored {} schedule candidates for {m}x{n}x{k} {} \
         (pruned sweep: {} evaluated, {} skipped, same winner)",
        cands.len(),
        precision,
        stats.evaluated,
        stats.pruned
    );
    for c in &cands {
        let sel = if c.config == best.config { " <= selected" } else { "" };
        println!(
            "  {:<4} {:>2}x{:<2} kseg={:<3} {:?}: cycles={} mem={} util={:.2}{}",
            c.config.dataflow.name(),
            c.config.arrangement.lane_rows,
            c.config.arrangement.lane_cols,
            c.config.k_segments,
            c.config.tile_dir,
            c.report.cycles,
            c.report.memory_access(),
            c.report.utilization,
            sel
        );
    }
    Ok(())
}

fn cmd_verify(flags: &Flags) -> Result<()> {
    let dir: std::path::PathBuf = flags
        .get("artifacts")
        .map(Into::into)
        .unwrap_or_else(default_artifact_dir);
    let outcome = gta::verify::verify_all(&dir, true)?;
    if outcome.failed > 0 {
        bail!("{} artifact verifications FAILED", outcome.failed);
    }
    println!("all {} artifact verifications passed", outcome.passed);
    Ok(())
}

fn cmd_serve(flags: &Flags) -> Result<()> {
    let n = flags.get_u64("requests", 64);
    let workers = flags.get_u64("workers", 4) as usize;
    let shards = flags.get_u64("shards", 1) as usize;
    let policy = flags.get("policy").unwrap_or("least");
    let lanes: Vec<u32> = flags
        .get("shard-lanes")
        .map(|s| s.split(',').filter_map(|t| t.trim().parse().ok()).collect())
        .unwrap_or_default();
    if let Some(addr) = flags.get("listen") {
        // server mode: the same rack the in-process drivers build, on TCP
        let backend = flags.get("backend").unwrap_or("pjrt");
        let artifacts = flags.get("artifacts").map(Into::into);
        let max_proto = flags.get_u64("max-proto", gta::net::PROTO_VERSION);
        let rack = gta::serve::listen_rack(backend, artifacts, shards, &lanes, policy)?;
        let opts = gta::coordinator::ServeOptions::with_workers(workers);
        if flags.get("event-loop").is_some() {
            let max_conns =
                flags.get_u64("max-conns", gta::net::DEFAULT_MAX_CONNS as u64) as usize;
            let mut server =
                gta::net::EventServer::spawn_with(rack, addr, opts, max_proto, max_conns)?;
            println!(
                "gta serving on {} (event loop, {} worker(s), {} shard(s), {} backend, \
                 policy {}, proto <= {}, max {} conns) — \
                 connect with `gta client --connect {}`",
                server.addr(),
                workers.max(1),
                shards.max(1),
                backend,
                policy,
                max_proto,
                max_conns,
                server.addr()
            );
            server.join();
            return Ok(());
        }
        let mut server = gta::net::NetServer::spawn_proto(rack, addr, opts, max_proto)?;
        println!(
            "gta serving on {} ({} shard(s), {} backend, policy {}, proto <= {}) — \
             connect with `gta client --connect {}`",
            server.addr(),
            shards.max(1),
            backend,
            policy,
            max_proto,
            server.addr()
        );
        server.join();
        return Ok(());
    }
    let sharded = shards > 1 || !lanes.is_empty();
    let stream = flags.get("stream").is_some();
    let rate: f64 = flags.get("arrival-rate").and_then(|v| v.parse().ok()).unwrap_or(5000.0);
    if stream && !(rate > 0.0) {
        bail!("--arrival-rate must be a positive req/s rate, got {rate}");
    }
    let seed = flags.get_u64("seed", 2024);
    let summary = match (flags.get("backend").unwrap_or("pjrt"), stream) {
        ("soft", true) => {
            gta::serve::run_open_loop_soft_rack(n, workers, shards, &lanes, policy, rate, seed)?
        }
        ("soft", false) if sharded => {
            gta::serve::run_mixed_stream_soft_rack(n, workers, shards, &lanes, policy)?
        }
        ("soft", false) => gta::serve::run_mixed_stream_soft(n, workers)?,
        ("pjrt", stream) => {
            let dir: std::path::PathBuf = flags
                .get("artifacts")
                .map(Into::into)
                .unwrap_or_else(default_artifact_dir);
            if stream {
                gta::serve::run_open_loop_rack(dir, n, workers, shards, &lanes, policy, rate, seed)?
            } else if sharded {
                gta::serve::run_mixed_stream_rack(dir, n, workers, shards, &lanes, policy)?
            } else {
                gta::serve::run_mixed_stream(dir, n, workers)?
            }
        }
        (other, _) => bail!("unknown backend {other:?} (pjrt|soft)"),
    };
    print!("{}", summary.render());
    Ok(())
}

/// `gta trace`: the seeded mixed-stream rack run with span tracing on,
/// exported as Chrome `trace_event` JSON (+ the machine schema).
fn cmd_trace(flags: &Flags) -> Result<()> {
    let n = flags.get_u64("requests", 64);
    let workers = flags.get_u64("workers", 4) as usize;
    let shards = flags.get_u64("shards", 2) as usize;
    let policy = flags.get("policy").unwrap_or("least");
    let lanes: Vec<u32> = flags
        .get("shard-lanes")
        .map(|s| s.split(',').filter_map(|t| t.trim().parse().ok()).collect())
        .unwrap_or_default();
    let out = flags.get("out").unwrap_or("trace.json");
    gta::obs::reset();
    gta::obs::set_enabled(true);
    let summary = gta::serve::run_mixed_stream_soft_rack(n, workers, shards, &lanes, policy)?;
    gta::obs::set_enabled(false);
    let (events, dropped) = gta::obs::drain();
    std::fs::write(out, gta::obs::chrome::chrome_trace_json(&events).render())
        .map_err(|e| anyhow!("trace: writing {out}: {e}"))?;
    if let Some(mpath) = flags.get("machine-out") {
        std::fs::write(mpath, gta::obs::chrome::machine_trace_json(&events, dropped).render())
            .map_err(|e| anyhow!("trace: writing {mpath}: {e}"))?;
        println!("gta trace: machine schema (gta.obs.trace/1) -> {mpath}");
    }
    let traced = gta::obs::chrome::by_trace(&events).len();
    println!(
        "gta trace: {} span event(s) across {} request trace(s) \
         ({} overwritten in the rings) -> {out}",
        events.len(),
        traced,
        dropped
    );
    print!("{}", summary.render());
    Ok(())
}

/// `gta stats`: live `Stats` round trip against a serving rack.
fn cmd_stats(flags: &Flags) -> Result<()> {
    let addr = flags.get("connect").ok_or_else(|| anyhow!("--connect ADDR required"))?;
    let mut opts = gta::net::ClientOptions {
        max_proto: flags.get_u64("proto", gta::net::PROTO_VERSION),
        ..gta::net::ClientOptions::default()
    };
    if let Some(ms) = flags.get("timeout-ms").and_then(|v| v.parse::<u64>().ok()) {
        if ms == 0 {
            bail!("--timeout-ms must be positive (omit the flag for the defaults)");
        }
        let t = std::time::Duration::from_millis(ms);
        opts.connect_timeout = t;
        opts.read_timeout = Some(t);
    }
    let mut client = gta::net::GtaClient::connect_with(addr, opts)?;
    let snap = client.stats()?;
    drop(client);
    let agg = &snap.aggregate;
    println!("live stats from {addr} ({} shard(s)):", snap.shards.len());
    println!(
        "  requests={} functional={} cache hit/miss={}/{} batches={} (max {})",
        agg.requests,
        agg.functional_execs,
        agg.schedule_cache_hits,
        agg.schedule_cache_misses,
        agg.batches,
        agg.max_batch
    );
    println!(
        "  latency: p50={}us p95={}us p99={}us mean={:.1}us over {} sample(s)",
        agg.p50_us, agg.p95_us, agg.p99_us, agg.mean_us, agg.latency_count
    );
    print!("{}", gta::serve::render_stage_table(&agg.stage_hist));
    for t in &snap.shards {
        println!(
            "  shard {}: routed={} queued={} lanes {}/{} free",
            t.shard, t.routed, t.queued, t.lane_usage.free, t.lane_usage.total
        );
    }
    if let Some(net) = &snap.net {
        println!(
            "  net: {} conn(s), {} session(s), {} B in, {} B out",
            net.active_connections, net.active_sessions, net.bytes_in, net.bytes_out
        );
    }
    Ok(())
}

fn cmd_client(flags: &Flags) -> Result<()> {
    let addr = flags.get("connect").ok_or_else(|| anyhow!("--connect ADDR required"))?;
    let n = flags.get_u64("requests", 64);
    let sessions = flags.get_u64("sessions", 1) as u32;
    let mut opts = gta::net::ClientOptions {
        max_proto: flags.get_u64("proto", gta::net::PROTO_VERSION),
        ..gta::net::ClientOptions::default()
    };
    if let Some(ms) = flags.get("timeout-ms").and_then(|v| v.parse::<u64>().ok()) {
        if ms == 0 {
            bail!("--timeout-ms must be positive (omit the flag for the defaults)");
        }
        let t = std::time::Duration::from_millis(ms);
        opts.connect_timeout = t;
        opts.read_timeout = Some(t);
    }
    let summary = if flags.get("stream").is_some() {
        if sessions > 1 {
            bail!("--sessions multiplexes the batch replay; it does not combine with --stream");
        }
        let rate: f64 = flags.get("arrival-rate").and_then(|v| v.parse().ok()).unwrap_or(5000.0);
        if !(rate > 0.0) {
            bail!("--arrival-rate must be a positive req/s rate, got {rate}");
        }
        let seed = flags.get_u64("seed", 2024);
        gta::serve::run_open_loop_client_with(addr, n, rate, seed, opts)?
    } else if sessions > 1 {
        gta::serve::run_client_mux_with(addr, n, sessions, opts)?
    } else {
        gta::serve::run_client_mixed_with(addr, n, opts)?
    };
    print!("{}", summary.render());
    Ok(())
}
