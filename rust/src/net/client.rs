//! The blocking GTA network client: a [`GtaClient`] mirrors the
//! in-process [`crate::coordinator::RackSession`] API over one TCP
//! connection — `submit` a [`Request`] and get a ticket id back
//! immediately (submissions pipeline; nothing waits for a round trip),
//! consume completions **out of submission order** with
//! [`recv`](GtaClient::recv)/[`try_recv`](GtaClient::try_recv), then
//! [`drain`](GtaClient::drain) (every outstanding response, ordered by
//! id) and [`close`](GtaClient::close) (the server session's final
//! [`ServeSummary`], per-shard telemetry included).
//!
//! Wire-level backpressure surfaces exactly like the in-process batch
//! wrapper's: a server-side `AdmitError::Busy` arrives as a `Busy`
//! frame and is synthesized into an error-carrying [`Response`] with
//! the same `"busy: admission queue at capacity"` message the batch
//! path uses, so a replay over TCP is comparable response-for-response
//! with an in-process replay. Under a blocking-admission server the
//! socket itself is the backpressure: the server stops reading and the
//! client's `submit` eventually stalls in `write`.
//!
//! A dedicated reader thread owns the socket's read side and turns
//! every incoming frame into an event; the caller's thread owns the
//! write side. Fatal protocol errors from the server (or a vanished
//! connection) surface as `Err` from whichever call observes them.

use super::proto::{
    busy_shard, client_hello_v, error_message, negotiate, read_frame, write_frame, DecodeError,
    Frame, FrameType, MIN_PROTO_VERSION, PROTO_VERSION,
};
use crate::coordinator::{order_responses, unserved_response, Request, Response};
use crate::serve::ServeSummary;
use anyhow::{anyhow, bail, Result};
use std::io::{BufReader, BufWriter, Write};
use std::net::{Shutdown, TcpStream};
use std::sync::mpsc;

/// The message a `Busy` frame synthesizes into — the SAME string the
/// in-process batch wrapper uses (re-exported from the coordinator), so
/// the two paths stay comparable response-for-response.
pub use crate::coordinator::BUSY_MESSAGE;

/// What the server said in its `Hello`. `proto` is the **negotiated**
/// version this connection speaks: tensor payloads travel as v2 binary
/// frames when it is ≥ 2, as v1 JSON otherwise.
#[derive(Debug, Clone)]
pub struct ServerInfo {
    pub proto: u64,
    pub shards: usize,
    pub policy: String,
}

/// One decoded frame, classified for the consuming thread.
enum Event {
    Response(Box<Response>),
    Busy { id: u64, shard: Option<usize> },
    RequestError { id: u64, message: String },
    Drained,
    Closed(Box<ServeSummary>),
    Fatal(String),
    Disconnected,
}

/// A blocking client for one GTA serving connection. Not `Sync`: one
/// thread drives it (the reader thread behind it is an implementation
/// detail).
pub struct GtaClient {
    stream: TcpStream,
    writer: BufWriter<TcpStream>,
    events: mpsc::Receiver<Event>,
    reader: Option<std::thread::JoinHandle<()>>,
    server: ServerInfo,
    submitted: u64,
    completed: u64,
    closed: bool,
}

impl GtaClient {
    /// Connect, negotiate the protocol version, and return a live
    /// client. The connection speaks `min(client, server)`; connecting
    /// fails only if the negotiated version falls below
    /// [`MIN_PROTO_VERSION`] (or the server answers with a version it
    /// was never offered).
    pub fn connect(addr: &str) -> Result<GtaClient> {
        GtaClient::connect_proto(addr, PROTO_VERSION)
    }

    /// [`connect`](Self::connect) with an explicit cap on the version
    /// this client announces — `connect_proto(addr, 1)` is a v1-forced
    /// client producing the PR 5 wire behavior byte-for-byte, useful
    /// for compatibility replays against newer servers.
    pub fn connect_proto(addr: &str, max_proto: u64) -> Result<GtaClient> {
        if !(MIN_PROTO_VERSION..=PROTO_VERSION).contains(&max_proto) {
            bail!(
                "this build speaks protocol versions \
                 {MIN_PROTO_VERSION}..={PROTO_VERSION}, not {max_proto}"
            );
        }
        let stream = TcpStream::connect(addr)?;
        stream.set_nodelay(true).ok();
        let mut writer = BufWriter::new(stream.try_clone()?);
        let mut sock_reader = BufReader::new(stream.try_clone()?);
        write_frame(&mut writer, &Frame::new(FrameType::Hello, 0, client_hello_v(max_proto)))?;
        writer.flush()?;
        // the Hello reply is read synchronously, before the reader
        // thread takes over the socket
        let hello = match read_frame(&mut sock_reader) {
            Ok(f) if f.ty == FrameType::Hello => f,
            Ok(f) if f.ty == FrameType::Error => bail!("server refused: {}", error_message(&f.body)),
            Ok(f) => bail!("expected Hello from server, got {:?}", f.ty),
            Err(e) => bail!("handshake failed: {e}"),
        };
        let proto = super::proto::hello_proto(&hello.body)
            .ok_or_else(|| anyhow!("server Hello without a protocol version"))?;
        // the server's answer must be a version we offered and can speak
        if proto > max_proto || negotiate(proto, max_proto) != Some(proto) {
            bail!(
                "server answered protocol {proto}, \
                 outside this client's {MIN_PROTO_VERSION}..={max_proto}"
            );
        }
        let server = ServerInfo {
            proto,
            shards: hello
                .body
                .get("shards")
                .and_then(crate::util::json::Json::as_u64)
                .unwrap_or(1) as usize,
            policy: hello
                .body
                .get("policy")
                .and_then(crate::util::json::Json::as_str)
                .unwrap_or("unknown")
                .to_string(),
        };
        let (tx, events) = mpsc::channel::<Event>();
        let reader = std::thread::Builder::new()
            .name("gta-client-reader".into())
            .spawn(move || loop {
                let event = match read_frame(&mut sock_reader) {
                    Ok(f) => match f.ty {
                        FrameType::Response => match super::proto::decode_response(&f.body) {
                            Ok(resp) => Event::Response(Box::new(resp)),
                            Err(e) => Event::Fatal(format!("undecodable response: {e:#}")),
                        },
                        // decodes straight into HostTensor buffers —
                        // no intermediate JSON values
                        FrameType::ResponseBin => {
                            match super::proto::decode_response_bin(&f.bin) {
                                Ok(resp) => Event::Response(Box::new(resp)),
                                Err(e) => {
                                    Event::Fatal(format!("undecodable binary response: {e:#}"))
                                }
                            }
                        }
                        FrameType::Busy => Event::Busy { id: f.id, shard: busy_shard(&f.body) },
                        FrameType::Error if f.id != 0 => {
                            Event::RequestError { id: f.id, message: error_message(&f.body) }
                        }
                        FrameType::Error => Event::Fatal(error_message(&f.body)),
                        FrameType::Drained => Event::Drained,
                        FrameType::Closed => match super::proto::decode_summary(&f.body) {
                            Ok(s) => Event::Closed(Box::new(s)),
                            Err(e) => Event::Fatal(format!("undecodable summary: {e:#}")),
                        },
                        other => Event::Fatal(format!("unexpected {other:?} frame from server")),
                    },
                    Err(DecodeError::Eof) | Err(DecodeError::Io(_)) => Event::Disconnected,
                    Err(DecodeError::Malformed(m)) => Event::Fatal(m),
                };
                let terminal = matches!(
                    event,
                    Event::Fatal(_) | Event::Disconnected | Event::Closed(_)
                );
                if tx.send(event).is_err() || terminal {
                    break;
                }
            })?;
        Ok(GtaClient {
            stream,
            writer,
            events,
            reader: Some(reader),
            server,
            submitted: 0,
            completed: 0,
            closed: false,
        })
    }

    /// The server's `Hello` (shard count, routing policy).
    pub fn server(&self) -> &ServerInfo {
        &self.server
    }

    /// Tickets submitted but not yet resolved by a response, a `Busy`,
    /// or a per-request error.
    pub fn outstanding(&self) -> u64 {
        self.submitted - self.completed
    }

    /// Submit one request, returning its ticket id immediately (the
    /// shard assignment happens server-side; a rejection arrives later
    /// as a `Busy`-synthesized error response). Under a blocking-
    /// admission server an overloaded queue stalls this call in the
    /// socket write — TCP is the backpressure.
    pub fn submit(&mut self, req: &Request) -> Result<u64> {
        if self.closed {
            bail!("client already closed");
        }
        let frame = if self.server.proto >= 2 {
            // binary tensor frame: element bytes go out as-is, no
            // per-element formatting
            Frame::binary(FrameType::SubmitBin, req.id, super::proto::encode_request_bin(req))
        } else {
            Frame::new(FrameType::Submit, req.id, super::proto::encode_request(req))
        };
        write_frame(&mut self.writer, &frame)?;
        self.writer.flush()?;
        self.submitted += 1;
        Ok(req.id)
    }

    /// Map one event to a response (counting it), or a fatal error.
    fn resolve(&mut self, event: Event) -> Result<Option<Response>> {
        match event {
            Event::Response(resp) => {
                self.completed += 1;
                Ok(Some(*resp))
            }
            Event::Busy { id, shard } => {
                self.completed += 1;
                Ok(Some(unserved_response(id, shard.unwrap_or(0), BUSY_MESSAGE.to_string())))
            }
            Event::RequestError { id, message } => {
                self.completed += 1;
                Ok(Some(unserved_response(id, 0, message)))
            }
            Event::Drained | Event::Closed(_) => {
                bail!("unexpected lifecycle frame while receiving responses")
            }
            Event::Fatal(m) => bail!("protocol error: {m}"),
            Event::Disconnected => bail!("server disconnected"),
        }
    }

    /// Next completion, blocking while tickets are outstanding; `None`
    /// when nothing is outstanding. A server-side rejection or
    /// per-request error comes back as an error-carrying [`Response`],
    /// exactly like the in-process batch wrapper synthesizes.
    pub fn recv(&mut self) -> Result<Option<Response>> {
        if self.outstanding() == 0 {
            return Ok(None);
        }
        match self.events.recv() {
            Ok(event) => self.resolve(event),
            Err(_) => bail!("server disconnected"),
        }
    }

    /// Next completion if one is already here.
    pub fn try_recv(&mut self) -> Result<Option<Response>> {
        match self.events.try_recv() {
            Ok(event) => self.resolve(event),
            Err(mpsc::TryRecvError::Empty) => Ok(None),
            Err(mpsc::TryRecvError::Disconnected) => bail!("server disconnected"),
        }
    }

    /// Ask the server to drain: every admitted request finishes, every
    /// not-yet-consumed response comes back (ordered by id, the shared
    /// completion-ordering rule). After this, submits fail server-side;
    /// only [`close`](Self::close) remains useful.
    pub fn drain(&mut self) -> Result<Vec<Response>> {
        if self.closed {
            bail!("client already closed");
        }
        write_frame(&mut self.writer, &Frame::new(FrameType::Drained, 0, crate::util::json::Json::Null))?;
        self.writer.flush()?;
        let mut out = Vec::new();
        loop {
            match self.events.recv() {
                Ok(Event::Drained) => break,
                Ok(Event::Closed(_)) => bail!("server closed during drain"),
                Ok(event) => {
                    if let Some(resp) = self.resolve(event)? {
                        out.push(resp);
                    }
                }
                Err(_) => bail!("server disconnected mid-drain"),
            }
        }
        order_responses(&mut out);
        Ok(out)
    }

    /// Close the session: the server drains it (any responses still in
    /// flight are folded into the summary, as in-process `close` does)
    /// and sends back the final [`ServeSummary`] with its rack
    /// telemetry. Consumes the connection.
    pub fn close(mut self) -> Result<ServeSummary> {
        self.closed = true;
        write_frame(&mut self.writer, &Frame::new(FrameType::Closed, 0, crate::util::json::Json::Null))?;
        self.writer.flush()?;
        let summary = loop {
            match self.events.recv() {
                Ok(Event::Closed(summary)) => break *summary,
                Ok(Event::Drained) => continue,
                Ok(Event::Fatal(m)) => bail!("protocol error: {m}"),
                Ok(Event::Disconnected) => bail!("server disconnected before the final summary"),
                Ok(event) => {
                    // responses still in flight: folded server-side,
                    // dropped here (call drain() first to keep them)
                    let _ = self.resolve(event)?;
                }
                Err(_) => bail!("server disconnected before the final summary"),
            }
        };
        let _ = self.stream.shutdown(Shutdown::Both);
        if let Some(h) = self.reader.take() {
            let _ = h.join();
        }
        Ok(summary)
    }
}

impl Drop for GtaClient {
    fn drop(&mut self) {
        // kill the socket so the reader thread unblocks, then join it
        let _ = self.stream.shutdown(Shutdown::Both);
        if let Some(h) = self.reader.take() {
            let _ = h.join();
        }
    }
}

