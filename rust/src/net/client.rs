//! The blocking GTA network client: a [`GtaClient`] mirrors the
//! in-process [`crate::coordinator::RackSession`] API over one TCP
//! connection — `submit` a [`Request`] and get a ticket id back
//! immediately (submissions pipeline; nothing waits for a round trip),
//! consume completions **out of submission order** with
//! [`recv`](GtaClient::recv)/[`try_recv`](GtaClient::try_recv), then
//! [`drain`](GtaClient::drain) (every outstanding response, ordered by
//! id) and [`close`](GtaClient::close) (the server session's final
//! [`ServeSummary`], per-shard telemetry included).
//!
//! On a v3 connection one socket carries many logical sessions:
//! [`open_session`](GtaClient::open_session) returns a session id whose
//! `*_on` twins (`submit_on`/`recv_on`/`try_recv_on`/`drain_on`/
//! [`close_session`](GtaClient::close_session)) behave exactly like the
//! defaults — which are themselves just the `*_on` calls for session 0,
//! the implicit session every connection starts with. Frames from
//! different sessions interleave freely on the wire; the client routes
//! them by the v3 `session` header field.
//!
//! Every blocking call is bounded by [`ClientOptions`]: `connect` and
//! the `Hello` exchange by `connect_timeout`, every later wait for a
//! server frame by `read_timeout` — a dead or wedged server surfaces as
//! a clean `Err`, never a hang.
//!
//! Wire-level backpressure surfaces exactly like the in-process batch
//! wrapper's: a server-side `AdmitError::Busy` arrives as a `Busy`
//! frame and is synthesized into an error-carrying [`Response`] with
//! the same `"busy: admission queue at capacity"` message the batch
//! path uses, so a replay over TCP is comparable response-for-response
//! with an in-process replay. Under a blocking-admission server the
//! socket itself is the backpressure: the server stops reading and the
//! client's `submit` eventually stalls in `write`.
//!
//! A dedicated reader thread owns the socket's read side and turns
//! every incoming frame into a `(session, event)` pair; the caller's
//! thread owns the write side. Fatal protocol errors from the server
//! (or a vanished connection) surface as `Err` from whichever call
//! observes them.

use super::proto::{
    busy_shard, client_hello_v, error_message, negotiate, read_frame, read_frame_v, write_frame,
    write_frame_v, DecodeError, Frame, FrameType, MIN_PROTO_VERSION, PROTO_VERSION,
};
use crate::coordinator::metrics::RackSnapshot;
use crate::coordinator::{order_responses, unserved_response, Request, Response};
use crate::serve::ServeSummary;
use anyhow::{anyhow, bail, Result};
use std::collections::{HashMap, VecDeque};
use std::io::{BufReader, BufWriter, Write};
use std::net::{Shutdown, TcpStream, ToSocketAddrs};
use std::sync::mpsc;
use std::time::Duration;

/// The message a `Busy` frame synthesizes into — the SAME string the
/// in-process batch wrapper uses (re-exported from the coordinator), so
/// the two paths stay comparable response-for-response.
pub use crate::coordinator::BUSY_MESSAGE;

/// Connection knobs. The defaults make every blocking call bounded:
/// a client pointed at a dead, unreachable, or wedged server gets a
/// clean error, never an indefinite hang.
#[derive(Debug, Clone, Copy)]
pub struct ClientOptions {
    /// Highest protocol version to announce (the connection speaks
    /// `min(client, server)`).
    pub max_proto: u64,
    /// Bound on TCP connect AND on each `Hello`-exchange read.
    pub connect_timeout: Duration,
    /// Bound on every later wait for a server frame (`recv`, `drain`,
    /// `close`, …). `None` waits forever — only sensible when the
    /// workload itself has unbounded latency.
    pub read_timeout: Option<Duration>,
}

impl Default for ClientOptions {
    fn default() -> ClientOptions {
        ClientOptions {
            max_proto: PROTO_VERSION,
            connect_timeout: Duration::from_secs(10),
            read_timeout: Some(Duration::from_secs(30)),
        }
    }
}

/// What the server said in its `Hello`. `proto` is the **negotiated**
/// version this connection speaks: tensor payloads travel as v2 binary
/// frames when it is ≥ 2, logical sessions multiplex when it is ≥ 3.
#[derive(Debug, Clone)]
pub struct ServerInfo {
    pub proto: u64,
    pub shards: usize,
    pub policy: String,
}

/// One decoded frame, classified for the consuming thread.
enum Event {
    Response(Box<Response>),
    Busy { id: u64, shard: Option<usize> },
    RequestError { id: u64, message: String },
    Drained,
    SessionOpened,
    SessionClosed(Box<ServeSummary>),
    Closed(Box<ServeSummary>),
    Stats(Box<RackSnapshot>),
    Fatal(String),
    Disconnected,
}

/// Per-session bookkeeping: ticket counters plus events that arrived
/// while the caller was waiting on a different session.
#[derive(Default)]
struct SessionTrack {
    submitted: u64,
    completed: u64,
    stashed: VecDeque<Event>,
}

/// A blocking client for one GTA serving connection. Not `Sync`: one
/// thread drives it (the reader thread behind it is an implementation
/// detail).
pub struct GtaClient {
    stream: TcpStream,
    writer: BufWriter<TcpStream>,
    events: mpsc::Receiver<(u32, Event)>,
    reader: Option<std::thread::JoinHandle<()>>,
    server: ServerInfo,
    read_timeout: Option<Duration>,
    /// Session 0 (the connection's implicit default) is always present;
    /// `open_session` adds more on v3 connections.
    sessions: HashMap<u32, SessionTrack>,
    next_session: u32,
    closed: bool,
}

/// Resolve `addr` and try each candidate under the connect timeout.
fn connect_stream(addr: &str, timeout: Duration) -> Result<TcpStream> {
    let mut last: Option<std::io::Error> = None;
    for sa in addr.to_socket_addrs()? {
        match TcpStream::connect_timeout(&sa, timeout) {
            Ok(s) => return Ok(s),
            Err(e) => last = Some(e),
        }
    }
    match last {
        Some(e) => Err(anyhow!("connecting to {addr} failed within {timeout:?}: {e}")),
        None => Err(anyhow!("{addr} resolved to no addresses")),
    }
}

impl GtaClient {
    /// Connect with default options: negotiate the highest shared
    /// protocol version, 10s connect/handshake timeout, 30s read
    /// timeout.
    pub fn connect(addr: &str) -> Result<GtaClient> {
        GtaClient::connect_with(addr, ClientOptions::default())
    }

    /// [`connect`](Self::connect) with an explicit cap on the version
    /// this client announces — `connect_proto(addr, 1)` is a v1-forced
    /// client producing the PR 5 wire behavior byte-for-byte, useful
    /// for compatibility replays against newer servers.
    pub fn connect_proto(addr: &str, max_proto: u64) -> Result<GtaClient> {
        GtaClient::connect_with(addr, ClientOptions { max_proto, ..ClientOptions::default() })
    }

    /// Connect, negotiate the protocol version, and return a live
    /// client. The connection speaks `min(client, server)`; connecting
    /// fails if the negotiated version falls below
    /// [`MIN_PROTO_VERSION`], the server answers with a version it was
    /// never offered, or the server does not complete the `Hello`
    /// exchange within `opts.connect_timeout`.
    pub fn connect_with(addr: &str, opts: ClientOptions) -> Result<GtaClient> {
        let max_proto = opts.max_proto;
        if !(MIN_PROTO_VERSION..=PROTO_VERSION).contains(&max_proto) {
            bail!(
                "this build speaks protocol versions \
                 {MIN_PROTO_VERSION}..={PROTO_VERSION}, not {max_proto}"
            );
        }
        let stream = connect_stream(addr, opts.connect_timeout)?;
        stream.set_nodelay(true).ok();
        // the whole handshake runs under a read deadline: a server that
        // accepted the connection but never answers is an error, not a
        // hang
        stream.set_read_timeout(Some(opts.connect_timeout))?;
        let mut writer = BufWriter::new(stream.try_clone()?);
        let mut sock_reader = BufReader::new(stream.try_clone()?);
        // the Hello exchange always travels in the v1 header layout —
        // neither side knows the negotiated version yet
        write_frame(&mut writer, &Frame::new(FrameType::Hello, 0, client_hello_v(max_proto)))?;
        writer.flush()?;
        // the Hello reply is read synchronously, before the reader
        // thread takes over the socket
        let hello = match read_frame(&mut sock_reader) {
            Ok(f) if f.ty == FrameType::Hello => f,
            Ok(f) if f.ty == FrameType::Error => bail!("server refused: {}", error_message(&f.body)),
            Ok(f) => bail!("expected Hello from server, got {:?}", f.ty),
            Err(DecodeError::Io(e))
                if e.kind() == std::io::ErrorKind::WouldBlock
                    || e.kind() == std::io::ErrorKind::TimedOut =>
            {
                bail!("handshake timed out after {:?} (server accepted but never answered)",
                    opts.connect_timeout)
            }
            Err(e) => bail!("handshake failed: {e}"),
        };
        // steady-state waits are bounded at the event channel instead,
        // so the reader thread can block on the socket indefinitely
        stream.set_read_timeout(None)?;
        let proto = super::proto::hello_proto(&hello.body)
            .ok_or_else(|| anyhow!("server Hello without a protocol version"))?;
        // the server's answer must be a version we offered and can speak
        if proto > max_proto || negotiate(proto, max_proto) != Some(proto) {
            bail!(
                "server answered protocol {proto}, \
                 outside this client's {MIN_PROTO_VERSION}..={max_proto}"
            );
        }
        let server = ServerInfo {
            proto,
            shards: hello
                .body
                .get("shards")
                .and_then(crate::util::json::Json::as_u64)
                .and_then(|s| usize::try_from(s).ok())
                .unwrap_or(1),
            policy: hello
                .body
                .get("policy")
                .and_then(crate::util::json::Json::as_str)
                .unwrap_or("unknown")
                .to_string(),
        };
        let (tx, events) = mpsc::channel::<(u32, Event)>();
        let reader = std::thread::Builder::new()
            .name("gta-client-reader".into())
            .spawn(move || loop {
                // post-handshake frames travel in the negotiated layout
                let (session, event) = match read_frame_v(&mut sock_reader, proto) {
                    Ok(f) => {
                        let session = f.session;
                        let event = match f.ty {
                            FrameType::Response => match super::proto::decode_response(&f.body) {
                                Ok(resp) => Event::Response(Box::new(resp)),
                                Err(e) => Event::Fatal(format!("undecodable response: {e:#}")),
                            },
                            // decodes straight into HostTensor buffers —
                            // no intermediate JSON values
                            FrameType::ResponseBin => {
                                match super::proto::decode_response_bin(&f.bin) {
                                    Ok(resp) => Event::Response(Box::new(resp)),
                                    Err(e) => {
                                        Event::Fatal(format!("undecodable binary response: {e:#}"))
                                    }
                                }
                            }
                            FrameType::Busy => {
                                Event::Busy { id: f.id, shard: busy_shard(&f.body) }
                            }
                            FrameType::Error if f.id != 0 => {
                                Event::RequestError { id: f.id, message: error_message(&f.body) }
                            }
                            FrameType::Error => Event::Fatal(error_message(&f.body)),
                            FrameType::Drained => Event::Drained,
                            FrameType::OpenSession => Event::SessionOpened,
                            FrameType::SessionClosed => {
                                match super::proto::decode_summary(&f.body) {
                                    Ok(s) => Event::SessionClosed(Box::new(s)),
                                    Err(e) => {
                                        Event::Fatal(format!("undecodable summary: {e:#}"))
                                    }
                                }
                            }
                            FrameType::Closed => match super::proto::decode_summary(&f.body) {
                                Ok(s) => Event::Closed(Box::new(s)),
                                Err(e) => Event::Fatal(format!("undecodable summary: {e:#}")),
                            },
                            FrameType::Stats => match super::proto::decode_stats(&f.body) {
                                Ok(s) => Event::Stats(Box::new(s)),
                                Err(e) => Event::Fatal(format!("undecodable stats: {e:#}")),
                            },
                            other => {
                                Event::Fatal(format!("unexpected {other:?} frame from server"))
                            }
                        };
                        (session, event)
                    }
                    Err(DecodeError::Eof) | Err(DecodeError::Io(_)) => (0, Event::Disconnected),
                    Err(DecodeError::Malformed(m)) => (0, Event::Fatal(m)),
                };
                let terminal = matches!(
                    event,
                    Event::Fatal(_) | Event::Disconnected | Event::Closed(_)
                );
                if tx.send((session, event)).is_err() || terminal {
                    break;
                }
            })?;
        let mut sessions = HashMap::new();
        sessions.insert(0u32, SessionTrack::default());
        Ok(GtaClient {
            stream,
            writer,
            events,
            reader: Some(reader),
            server,
            read_timeout: opts.read_timeout,
            sessions,
            next_session: 1,
            closed: false,
        })
    }

    /// The server's `Hello` (shard count, routing policy).
    pub fn server(&self) -> &ServerInfo {
        &self.server
    }

    /// Tickets submitted on the default session but not yet resolved by
    /// a response, a `Busy`, or a per-request error.
    pub fn outstanding(&self) -> u64 {
        self.outstanding_on(0)
    }

    /// [`outstanding`](Self::outstanding) for one logical session.
    pub fn outstanding_on(&self, session: u32) -> u64 {
        self.sessions.get(&session).map_or(0, |t| t.submitted - t.completed)
    }

    /// Next event from the wire, bounded by the read timeout.
    fn recv_event(&self) -> Result<(u32, Event)> {
        match self.read_timeout {
            Some(t) => self.events.recv_timeout(t).map_err(|e| match e {
                mpsc::RecvTimeoutError::Timeout => {
                    anyhow!("no server response within {t:?} (read timeout)")
                }
                mpsc::RecvTimeoutError::Disconnected => anyhow!("server disconnected"),
            }),
            None => self.events.recv().map_err(|_| anyhow!("server disconnected")),
        }
    }

    /// Next event addressed to `session`: stashed first, then the wire
    /// (events for other sessions are stashed for their own consumers;
    /// connection-fatal events surface immediately regardless).
    fn next_event_for(&mut self, session: u32) -> Result<Event> {
        if let Some(ev) = self.sessions.get_mut(&session).and_then(|t| t.stashed.pop_front()) {
            return Ok(ev);
        }
        loop {
            let (esid, event) = self.recv_event()?;
            match event {
                Event::Fatal(m) => bail!("protocol error: {m}"),
                Event::Disconnected => bail!("server disconnected"),
                event if esid == session => return Ok(event),
                event => match self.sessions.get_mut(&esid) {
                    Some(t) => t.stashed.push_back(event),
                    None => bail!("server sent a frame for unknown session {esid}"),
                },
            }
        }
    }

    /// Open a new logical session multiplexed over this connection
    /// (protocol v3). It has its own admission queue, ticket space and
    /// summary on the server; close it with
    /// [`close_session`](Self::close_session). Session 0 — the implicit
    /// default every connection starts with — needs no opening.
    pub fn open_session(&mut self) -> Result<u32> {
        if self.closed {
            bail!("client already closed");
        }
        if self.server.proto < 3 {
            bail!(
                "session multiplexing needs protocol v3 \
                 (this connection negotiated v{})",
                self.server.proto
            );
        }
        let sid = self.next_session;
        self.next_session += 1;
        self.sessions.insert(sid, SessionTrack::default());
        write_frame_v(
            &mut self.writer,
            &Frame::new(FrameType::OpenSession, 0, crate::util::json::Json::Null)
                .with_session(sid),
            self.server.proto,
        )?;
        self.writer.flush()?;
        match self.next_event_for(sid)? {
            Event::SessionOpened => Ok(sid),
            _ => bail!("expected OpenSession ack for session {sid}"),
        }
    }

    /// Submit one request on the default session, returning its ticket
    /// id immediately (the shard assignment happens server-side; a
    /// rejection arrives later as a `Busy`-synthesized error response).
    /// Under a blocking-admission server an overloaded queue stalls
    /// this call in the socket write — TCP is the backpressure.
    pub fn submit(&mut self, req: &Request) -> Result<u64> {
        self.submit_on(0, req)
    }

    /// [`submit`](Self::submit) on one logical session.
    pub fn submit_on(&mut self, session: u32, req: &Request) -> Result<u64> {
        if self.closed {
            bail!("client already closed");
        }
        if !self.sessions.contains_key(&session) {
            bail!("unknown session {session} (open_session first, or 0 for the default)");
        }
        let frame = if self.server.proto >= 2 {
            // binary tensor frame: element bytes go out as-is, no
            // per-element formatting
            Frame::binary(FrameType::SubmitBin, req.id, super::proto::encode_request_bin(req))
        } else {
            Frame::new(FrameType::Submit, req.id, super::proto::encode_request(req))
        };
        write_frame_v(&mut self.writer, &frame.with_session(session), self.server.proto)?;
        self.writer.flush()?;
        if let Some(s) = self.sessions.get_mut(&session) {
            s.submitted += 1;
        }
        Ok(req.id)
    }

    /// Map one event to a response (counting it against `session`), or
    /// a fatal error.
    fn resolve(&mut self, session: u32, event: Event) -> Result<Option<Response>> {
        let completed = |client: &mut Self| {
            if let Some(t) = client.sessions.get_mut(&session) {
                t.completed += 1;
            }
        };
        match event {
            Event::Response(resp) => {
                completed(self);
                Ok(Some(*resp))
            }
            Event::Busy { id, shard } => {
                completed(self);
                Ok(Some(unserved_response(id, shard.unwrap_or(0), BUSY_MESSAGE.to_string())))
            }
            Event::RequestError { id, message } => {
                completed(self);
                Ok(Some(unserved_response(id, 0, message)))
            }
            Event::Drained
            | Event::Closed(_)
            | Event::SessionOpened
            | Event::SessionClosed(_)
            | Event::Stats(_) => {
                bail!("unexpected lifecycle frame while receiving responses")
            }
            Event::Fatal(m) => bail!("protocol error: {m}"),
            Event::Disconnected => bail!("server disconnected"),
        }
    }

    /// Next completion on the default session, blocking (up to the read
    /// timeout) while tickets are outstanding; `None` when nothing is
    /// outstanding. A server-side rejection or per-request error comes
    /// back as an error-carrying [`Response`], exactly like the
    /// in-process batch wrapper synthesizes.
    pub fn recv(&mut self) -> Result<Option<Response>> {
        self.recv_on(0)
    }

    /// [`recv`](Self::recv) on one logical session.
    pub fn recv_on(&mut self, session: u32) -> Result<Option<Response>> {
        let stashed =
            self.sessions.get(&session).map_or(false, |t| !t.stashed.is_empty());
        if !stashed && self.outstanding_on(session) == 0 {
            return Ok(None);
        }
        let event = self.next_event_for(session)?;
        self.resolve(session, event)
    }

    /// Next completion on the default session, if one is already here.
    pub fn try_recv(&mut self) -> Result<Option<Response>> {
        self.try_recv_on(0)
    }

    /// [`try_recv`](Self::try_recv) on one logical session.
    pub fn try_recv_on(&mut self, session: u32) -> Result<Option<Response>> {
        loop {
            if let Some(ev) =
                self.sessions.get_mut(&session).and_then(|t| t.stashed.pop_front())
            {
                return self.resolve(session, ev);
            }
            match self.events.try_recv() {
                Ok((esid, Event::Fatal(m))) => {
                    let _ = esid;
                    bail!("protocol error: {m}")
                }
                Ok((_, Event::Disconnected)) => bail!("server disconnected"),
                Ok((esid, event)) if esid == session => return self.resolve(session, event),
                Ok((esid, event)) => match self.sessions.get_mut(&esid) {
                    Some(t) => t.stashed.push_back(event),
                    None => bail!("server sent a frame for unknown session {esid}"),
                },
                Err(mpsc::TryRecvError::Empty) => return Ok(None),
                Err(mpsc::TryRecvError::Disconnected) => bail!("server disconnected"),
            }
        }
    }

    /// Live rack telemetry without disturbing anything: per-shard
    /// counters, exact per-stage latency histograms, and (on the
    /// event-loop server) connection gauges. Needs protocol v3; the
    /// server answers from its current state — no drain, no close.
    /// Completions racing the reply are kept for the next
    /// [`recv`](Self::recv).
    pub fn stats(&mut self) -> Result<RackSnapshot> {
        if self.closed {
            bail!("client already closed");
        }
        if self.server.proto < 3 {
            bail!(
                "live stats need protocol v3 (this connection negotiated v{})",
                self.server.proto
            );
        }
        write_frame_v(
            &mut self.writer,
            &Frame::new(FrameType::Stats, 0, crate::util::json::Json::Null),
            self.server.proto,
        )?;
        self.writer.flush()?;
        let mut deferred = Vec::new();
        let snap = loop {
            match self.next_event_for(0)? {
                Event::Stats(snap) => break *snap,
                // a completion racing the stats reply: keep it, in
                // order, for the next recv on the default session
                event => deferred.push(event),
            }
        };
        if let Some(t) = self.sessions.get_mut(&0) {
            for ev in deferred.into_iter().rev() {
                t.stashed.push_front(ev);
            }
        }
        Ok(snap)
    }

    /// Ask the server to drain the default session: every admitted
    /// request finishes, every not-yet-consumed response comes back
    /// (ordered by id, the shared completion-ordering rule). After
    /// this, submits fail server-side; only [`close`](Self::close)
    /// remains useful.
    pub fn drain(&mut self) -> Result<Vec<Response>> {
        self.drain_on(0)
    }

    /// [`drain`](Self::drain) for one logical session (the others keep
    /// serving).
    pub fn drain_on(&mut self, session: u32) -> Result<Vec<Response>> {
        if self.closed {
            bail!("client already closed");
        }
        if !self.sessions.contains_key(&session) {
            bail!("unknown session {session}");
        }
        write_frame_v(
            &mut self.writer,
            &Frame::new(FrameType::Drained, 0, crate::util::json::Json::Null)
                .with_session(session),
            self.server.proto,
        )?;
        self.writer.flush()?;
        let mut out = Vec::new();
        loop {
            match self.next_event_for(session)? {
                Event::Drained => break,
                Event::Closed(_) => bail!("server closed during drain"),
                event => {
                    if let Some(resp) = self.resolve(session, event)? {
                        out.push(resp);
                    }
                }
            }
        }
        order_responses(&mut out);
        Ok(out)
    }

    /// Close one logical session: the server drains it (responses still
    /// in flight are folded into the summary — call
    /// [`drain_on`](Self::drain_on) first to keep them) and answers
    /// with that session's final [`ServeSummary`]. The connection and
    /// its other sessions keep serving.
    pub fn close_session(&mut self, session: u32) -> Result<ServeSummary> {
        if self.closed {
            bail!("client already closed");
        }
        if session == 0 {
            bail!("session 0 is the connection's default session; close() the client instead");
        }
        if !self.sessions.contains_key(&session) {
            bail!("unknown session {session}");
        }
        write_frame_v(
            &mut self.writer,
            &Frame::new(FrameType::SessionClosed, 0, crate::util::json::Json::Null)
                .with_session(session),
            self.server.proto,
        )?;
        self.writer.flush()?;
        let summary = loop {
            match self.next_event_for(session)? {
                Event::SessionClosed(summary) => break *summary,
                Event::Drained => continue,
                event => {
                    // responses still in flight: folded server-side,
                    // dropped here
                    let _ = self.resolve(session, event)?;
                }
            }
        };
        self.sessions.remove(&session);
        Ok(summary)
    }

    /// Close the connection: the server drains every remaining session
    /// (any responses still in flight are folded into the summary, as
    /// in-process `close` does) and sends back the final
    /// [`ServeSummary`] with its rack telemetry. Consumes the client.
    pub fn close(mut self) -> Result<ServeSummary> {
        self.closed = true;
        write_frame_v(
            &mut self.writer,
            &Frame::new(FrameType::Closed, 0, crate::util::json::Json::Null),
            self.server.proto,
        )?;
        self.writer.flush()?;
        let summary = loop {
            match self.next_event_for(0)? {
                Event::Closed(summary) => break *summary,
                Event::Drained => continue,
                event => {
                    // responses still in flight: folded server-side,
                    // dropped here (call drain() first to keep them)
                    let _ = self.resolve(0, event)?;
                }
            }
        };
        let _ = self.stream.shutdown(Shutdown::Both);
        if let Some(h) = self.reader.take() {
            let _ = h.join();
        }
        Ok(summary)
    }
}

impl Drop for GtaClient {
    fn drop(&mut self) {
        // kill the socket so the reader thread unblocks, then join it
        let _ = self.stream.shutdown(Shutdown::Both);
        if let Some(h) = self.reader.take() {
            let _ = h.join();
        }
    }
}
