//! The network serving subsystem: the [`crate::coordinator::RackSession`]
//! put on a real transport, with **zero new dependencies** — plain
//! `std::net` TCP carrying a versioned, length-prefixed frame protocol
//! (the in-tree [`crate::util::json`] for control bodies; protocol v2
//! moves tensor payloads to zero-copy binary frames, negotiated per
//! connection in the `Hello` exchange).
//!
//! Four layers:
//!
//! * [`proto`] — the wire format: the versioned frame codec
//!   (`len:u32 | type:u8 | id:u64 | body` through v2; v3 inserts a
//!   `session:u32` between type and id), the
//!   `Hello/SubmitRequest/Response/Busy/Drained/Closed/Error` message
//!   grammar plus the v2 `SubmitBin`/`ResponseBin` binary tensor
//!   frames and the v3 `OpenSession`/`SessionClosed` multiplexing
//!   frames, and exact codecs for requests, responses and the final
//!   serve summary. Hostile bytes decode to clean errors, never
//!   panics.
//! * [`poll`] — a hand-declared `poll(2)` shim plus a self-pipe
//!   [`poll::Waker`]: the event loop's only OS dependency, still zero
//!   external crates.
//! * [`server`] — two servers over one shared
//!   [`crate::coordinator::Rack`]. [`NetServer`]: the threaded
//!   baseline — a `TcpListener` accept loop, two OS threads per
//!   connection, one `RackSession` each. [`EventServer`]: one poll
//!   thread drives every connection as a non-blocking state machine
//!   over a fixed worker pool, and (on v3 connections) one socket
//!   multiplexes many logical sessions. Both: admission `Busy` becomes
//!   a wire frame; disconnects drain the session so no admitted work
//!   is ever lost.
//! * [`client`] — [`GtaClient`]: the blocking client mirror of the
//!   session API (`submit` → ticket id, `recv`/`try_recv`, `drain`,
//!   `close` → final `ServeSummary`), with configurable connect/read
//!   timeouts and `open_session` for logical sessions multiplexed over
//!   one socket.
//!
//! `gta serve --listen ADDR [--event-loop --max-conns N]` serves a rack
//! over this; `gta client --connect ADDR --stream [--sessions K]`
//! replays the seeded open-loop driver through it, bit-comparable with
//! the in-process path. See `docs/transport.md`.

pub mod client;
pub mod poll;
pub mod proto;
pub mod server;

pub use client::{ClientOptions, GtaClient, ServerInfo, BUSY_MESSAGE};
pub use proto::{Frame, FrameType, MAX_BODY_BYTES, MIN_PROTO_VERSION, PROTO_VERSION};
pub use server::{EventServer, NetServer, DEFAULT_MAX_CONNS};
