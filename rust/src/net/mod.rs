//! The network serving subsystem: the [`crate::coordinator::RackSession`]
//! put on a real transport, with **zero new dependencies** — plain
//! `std::net` TCP carrying a versioned, length-prefixed frame protocol
//! (the in-tree [`crate::util::json`] for control bodies; protocol v2
//! moves tensor payloads to zero-copy binary frames, negotiated per
//! connection in the `Hello` exchange).
//!
//! Three layers:
//!
//! * [`proto`] — the wire format: frame codec
//!   (`len:u32 | type:u8 | id:u64 | body`), the
//!   `Hello/SubmitRequest/Response/Busy/Drained/Closed/Error` message
//!   grammar plus the v2 `SubmitBin`/`ResponseBin` binary tensor
//!   frames, and exact codecs for requests, responses and the final
//!   serve summary. Hostile bytes decode to clean errors, never
//!   panics.
//! * [`server`] — [`NetServer`]: a `TcpListener` accept loop; each
//!   connection gets its own `RackSession` over one shared
//!   [`crate::coordinator::Rack`], a reader thread that submits and a
//!   writer thread that pumps completions out as they finish (out of
//!   submission order). Admission `Busy` becomes a wire frame;
//!   disconnects drain the session so no admitted work is ever lost.
//! * [`client`] — [`GtaClient`]: the blocking client mirror of the
//!   session API (`submit` → ticket id, `recv`/`try_recv`, `drain`,
//!   `close` → final `ServeSummary`).
//!
//! `gta serve --listen ADDR` serves a rack over this; `gta client
//! --connect ADDR --stream` replays the seeded open-loop driver through
//! it, bit-comparable with the in-process path. See `docs/transport.md`.

pub mod client;
pub mod proto;
pub mod server;

pub use client::{GtaClient, ServerInfo, BUSY_MESSAGE};
pub use proto::{Frame, FrameType, MAX_BODY_BYTES, MIN_PROTO_VERSION, PROTO_VERSION};
pub use server::NetServer;
