//! A minimal `poll(2)` shim — the event loop's only OS dependency,
//! declared by hand so the crate stays free of external crates.
//!
//! [`Poller`] wraps one readiness wait over a set of file descriptors
//! with per-fd read/write interest; [`Waker`] is a self-pipe (a
//! non-blocking `UnixStream` pair) other threads write one byte into to
//! interrupt the wait — the completion-notification path from rack
//! workers into the event loop.
//!
//! On non-unix targets (no `poll`, no fd-bearing sockets in std's
//! portable surface) the shim degrades to a bounded sleep that reports
//! every registered fd ready: correctness is preserved because all
//! event-loop I/O is non-blocking and level-triggered (a spurious
//! "ready" is just a `WouldBlock`), only wakeup latency suffers.

/// Readiness interest / result bits, mirroring `<poll.h>`.
pub const POLL_IN: i16 = 0x001;
pub const POLL_OUT: i16 = 0x004;
pub const POLL_ERR: i16 = 0x008;
pub const POLL_HUP: i16 = 0x010;
pub const POLL_NVAL: i16 = 0x020;

/// One registered descriptor: which readiness `events` the caller wants
/// and which `revents` the last [`poll_wait`] reported. `repr(C)` —
/// this IS the `struct pollfd` the syscall sees.
#[derive(Debug, Clone, Copy)]
#[repr(C)]
pub struct PollFd {
    pub fd: i32,
    pub events: i16,
    pub revents: i16,
}

impl PollFd {
    pub fn new(fd: i32, events: i16) -> PollFd {
        PollFd { fd, events, revents: 0 }
    }

    /// Readable, or in an error/hangup state the caller must observe by
    /// attempting the read (the portable way to learn *which* error).
    pub fn readable(&self) -> bool {
        self.revents & (POLL_IN | POLL_ERR | POLL_HUP | POLL_NVAL) != 0
    }

    pub fn writable(&self) -> bool {
        self.revents & (POLL_OUT | POLL_ERR | POLL_HUP | POLL_NVAL) != 0
    }
}

#[cfg(unix)]
mod sys {
    use super::PollFd;

    // `struct pollfd` has exactly this layout on every unix libc; nfds_t
    // is unsigned long on linux and unsigned int elsewhere — u64/u32
    // respectively on the targets this crate builds for.
    #[cfg(target_os = "linux")]
    type NFds = u64;
    #[cfg(not(target_os = "linux"))]
    type NFds = u32;

    extern "C" {
        fn poll(fds: *mut PollFd, nfds: NFds, timeout: i32) -> i32;
    }

    /// Block until a registered fd is ready or `timeout_ms` elapses
    /// (negative = forever). Returns how many fds have nonzero
    /// `revents`. `EINTR` reads as a zero-ready wakeup, not an error —
    /// the loop re-derives its state on every iteration anyway.
    pub fn wait(fds: &mut [PollFd], timeout_ms: i32) -> std::io::Result<usize> {
        for f in fds.iter_mut() {
            f.revents = 0;
        }
        // SAFETY: PollFd is repr(C) with pollfd's exact field order,
        // sizes and alignment (i32, i16, i16 — no padding); the slice
        // pointer/length pair is valid for the call's duration.
        let n = unsafe { poll(fds.as_mut_ptr(), fds.len() as NFds, timeout_ms) };
        if n < 0 {
            let err = std::io::Error::last_os_error();
            if err.kind() == std::io::ErrorKind::Interrupted {
                return Ok(0);
            }
            return Err(err);
        }
        usize::try_from(n).map_err(|_| {
            std::io::Error::new(std::io::ErrorKind::InvalidData, "poll() returned a negative count")
        })
    }
}

#[cfg(not(unix))]
mod sys {
    use super::{PollFd, POLL_IN, POLL_OUT};

    /// Portable fallback: sleep a bounded slice and report every
    /// registered interest as ready. All event-loop I/O is non-blocking,
    /// so false positives cost a `WouldBlock` each, nothing more.
    pub fn wait(fds: &mut [PollFd], timeout_ms: i32) -> std::io::Result<usize> {
        let ms = if timeout_ms < 0 { 10 } else { timeout_ms.min(10) };
        std::thread::sleep(std::time::Duration::from_millis(ms as u64));
        for f in fds.iter_mut() {
            f.revents = f.events & (POLL_IN | POLL_OUT);
        }
        Ok(fds.len())
    }
}

/// One `poll(2)` wait over a caller-built fd set.
pub fn poll_wait(fds: &mut [PollFd], timeout_ms: i32) -> std::io::Result<usize> {
    if fds.is_empty() {
        // poll(NULL, 0, ms) is a valid sleep, but express it portably
        if timeout_ms > 0 {
            std::thread::sleep(std::time::Duration::from_millis(timeout_ms as u64));
        }
        return Ok(0);
    }
    sys::wait(fds, timeout_ms)
}

/// A cross-thread wakeup for the event loop: `wake()` from any thread
/// makes a `poll` that includes [`Waker::fd`] return immediately;
/// [`Waker::drain`] swallows the pending bytes so the next wait blocks
/// again. Built on a non-blocking `UnixStream` pair on unix; on other
/// targets the fallback poller's bounded sleep bounds wakeup latency
/// instead and this is a no-op handle.
#[derive(Debug)]
pub struct Waker {
    #[cfg(unix)]
    tx: std::os::unix::net::UnixStream,
    #[cfg(unix)]
    rx: std::os::unix::net::UnixStream,
}

impl Waker {
    #[cfg(unix)]
    pub fn new() -> std::io::Result<Waker> {
        let (tx, rx) = std::os::unix::net::UnixStream::pair()?;
        tx.set_nonblocking(true)?;
        rx.set_nonblocking(true)?;
        Ok(Waker { tx, rx })
    }

    #[cfg(not(unix))]
    pub fn new() -> std::io::Result<Waker> {
        Ok(Waker {})
    }

    /// The fd to register with [`POLL_IN`] interest, or `None` on
    /// targets where the fallback poller never blocks for long.
    #[cfg(unix)]
    pub fn fd(&self) -> Option<i32> {
        use std::os::unix::io::AsRawFd;
        Some(self.rx.as_raw_fd())
    }

    #[cfg(not(unix))]
    pub fn fd(&self) -> Option<i32> {
        None
    }

    /// Interrupt the current (or next) poll wait. A full pipe means a
    /// wakeup is already pending — success either way; any other error
    /// is ignored too, because the poller's bounded timeout is the
    /// fallback wakeup path.
    pub fn wake(&self) {
        #[cfg(unix)]
        {
            use std::io::Write;
            let _ = (&self.tx).write(&[1u8]);
        }
    }

    /// Swallow pending wakeup bytes (call once per loop iteration).
    pub fn drain(&self) {
        #[cfg(unix)]
        {
            use std::io::Read;
            let mut buf = [0u8; 64];
            while matches!((&self.rx).read(&mut buf), Ok(n) if n > 0) {}
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn waker_fd_becomes_readable_on_wake_and_quiet_after_drain() {
        let w = Waker::new().expect("waker");
        let Some(fd) = w.fd() else { return };
        let mut fds = [PollFd::new(fd, POLL_IN)];
        assert_eq!(poll_wait(&mut fds, 0).unwrap(), 0, "no wakeup pending");
        w.wake();
        assert_eq!(poll_wait(&mut fds, 1000).unwrap(), 1);
        assert!(fds[0].readable());
        w.drain();
        assert_eq!(poll_wait(&mut fds, 0).unwrap(), 0, "drained");
    }

    #[test]
    fn wake_from_another_thread_interrupts_a_blocking_wait() {
        let w = std::sync::Arc::new(Waker::new().expect("waker"));
        let Some(fd) = w.fd() else { return };
        let w2 = std::sync::Arc::clone(&w);
        let t = std::thread::spawn(move || {
            std::thread::sleep(std::time::Duration::from_millis(30));
            w2.wake();
        });
        let mut fds = [PollFd::new(fd, POLL_IN)];
        let start = std::time::Instant::now();
        let n = poll_wait(&mut fds, 10_000).unwrap();
        assert_eq!(n, 1);
        assert!(start.elapsed() < std::time::Duration::from_secs(5));
        t.join().unwrap();
    }

    #[test]
    #[cfg(unix)]
    fn tcp_listener_readiness_via_poll() {
        use std::io::Write;
        use std::os::unix::io::AsRawFd;
        let listener = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
        listener.set_nonblocking(true).unwrap();
        let addr = listener.local_addr().unwrap();
        let mut fds = [PollFd::new(listener.as_raw_fd(), POLL_IN)];
        assert_eq!(poll_wait(&mut fds, 0).unwrap(), 0, "nothing to accept yet");
        let mut client = std::net::TcpStream::connect(addr).unwrap();
        assert_eq!(poll_wait(&mut fds, 2000).unwrap(), 1);
        assert!(fds[0].readable(), "pending accept reads as POLLIN");
        let (conn, _) = listener.accept().unwrap();
        conn.set_nonblocking(true).unwrap();
        client.write_all(b"hi").unwrap();
        let mut cfds = [PollFd::new(conn.as_raw_fd(), POLL_IN | POLL_OUT)];
        assert_eq!(poll_wait(&mut cfds, 2000).unwrap(), 1);
        assert!(cfds[0].readable() && cfds[0].writable());
    }
}
