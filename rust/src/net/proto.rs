//! The GTA wire protocol: versioned, length-prefixed frames (via the
//! in-tree [`crate::util::json`] — no serde, no new dependencies). See
//! `docs/transport.md` for the full frame layout and message grammar;
//! the short version:
//!
//! ```text
//! frame := len:u32(BE)  type:u8  id:u64(BE)  body
//! ```
//!
//! `len` counts everything after itself (type + id + body, so `len >= 9`),
//! `type` is a [`FrameType`] discriminant, `id` is the ticket/request id
//! the frame refers to (0 when it refers to the connection), and the
//! body is one UTF-8 JSON document (an empty body decodes as `null`) —
//! except for the **v2 binary tensor frames** ([`FrameType::SubmitBin`]
//! and [`FrameType::ResponseBin`]), whose bodies are a compact binary
//! header plus raw little-endian element bytes (see the "v2 binary
//! bodies" section below). Oversized (`len − 9 > MAX_BODY_BYTES`),
//! truncated, or undecodable frames are [`DecodeError::Malformed`] —
//! the peer answers with an `Error` frame and closes the connection,
//! never a panic.
//!
//! Protocol versions are negotiated in the opening `Hello` exchange:
//! the client announces the highest version it speaks, the server
//! answers with `min(client, server)` and both sides then speak that
//! version for the life of the connection. v1 keeps every body JSON;
//! v2 moves tensor payloads (`Submit` and `Response`) to the binary
//! frames and keeps JSON only for control frames and response
//! metadata.
//!
//! Integers that may exceed 2^53 (ids live in the binary header, but
//! config fingerprints, cycle counts and i64 tensor elements travel in
//! JSON bodies) are encoded as decimal *strings* when they would lose
//! precision as a JSON number, and both forms are accepted on decode —
//! so every `u64`/`i64` round-trips bit-exactly. In v2 binary bodies
//! tensor elements travel as their native little-endian bytes, so the
//! question does not arise (and f32 NaN payload bits, which v1's JSON
//! path canonicalizes, survive untouched).

use crate::coordinator::metrics::{NetGauges, RackSnapshot, ShardTelemetry, Snapshot};
use crate::coordinator::lane_scheduler::LaneUsage;
use crate::coordinator::{ExecKind, Request, Response};
use crate::obs::{Histogram, Stage, StageHists};
use crate::ops::{PGemm, TensorOp, VectorKind, VectorOp};
use crate::precision::Precision;
use crate::runtime::HostTensor;
use crate::scheduler::{Candidate, ScheduleConfig};
use crate::serve::ServeSummary;
use crate::sim::SimReport;
use crate::util::json::Json;
use crate::{Arrangement, Dataflow};
use anyhow::{anyhow, bail, Context, Result};
use std::collections::BTreeMap;
use std::io::{Read, Write};
use std::time::Duration;

/// Highest protocol version this build speaks. `Hello` frames carry
/// the peer's maximum; both sides settle on [`negotiate`]'s answer.
///
/// * **v1** — every body is JSON, tensors as JSON number arrays.
/// * **v2** — tensor payloads move to the binary
///   [`SubmitBin`](FrameType::SubmitBin)/
///   [`ResponseBin`](FrameType::ResponseBin) frames; control frames
///   (`Hello/Busy/Drained/Closed/Error`) and response metadata stay
///   JSON.
/// * **v3** — session multiplexing: the frame header grows a
///   `session:u32` field (between `type` and `id`) and the
///   [`OpenSession`](FrameType::OpenSession)/
///   [`SessionClosed`](FrameType::SessionClosed) control frames let one
///   connection carry many logical `RackSession`s. The `Hello`
///   exchange itself always uses the v1 header layout (the version is
///   not known yet); both sides switch layouts the frame after
///   negotiation settles on ≥ 3.
pub const PROTO_VERSION: u64 = 3;

/// Oldest protocol version this build still serves (v1 clients keep
/// working against a v2 server, bit-identically).
pub const MIN_PROTO_VERSION: u64 = 1;

/// Version-negotiation rule, shared by both sides: settle on the lower
/// of the two maxima, refuse anything below [`MIN_PROTO_VERSION`].
/// A peer announcing a *future* version is served at ours — that is
/// what lets old clients talk to new servers and vice versa.
pub fn negotiate(peer_max: u64, own_max: u64) -> Option<u64> {
    let v = peer_max.min(own_max);
    (v >= MIN_PROTO_VERSION).then_some(v)
}

/// Hard cap on one frame's body. A `len` prefix implying more is
/// malformed and kills the connection — a 4-byte prefix must never make
/// the server allocate gigabytes.
pub const MAX_BODY_BYTES: usize = 16 << 20;

/// Frame header bytes after the length prefix: type (1) + id (8).
const HEADER_AFTER_LEN: usize = 9;

/// v3 frame header bytes after the length prefix:
/// type (1) + session (4) + id (8).
const HEADER_AFTER_LEN_V3: usize = 13;

/// Header bytes after the length prefix for a given negotiated version.
fn header_after_len(proto: u64) -> usize {
    if proto >= 3 {
        HEADER_AFTER_LEN_V3
    } else {
        HEADER_AFTER_LEN
    }
}

/// The message grammar (see `docs/transport.md` for who sends what
/// when). Several types are used in both directions: a client sends
/// `Drained`/`Closed` with an empty body to *request* the transition,
/// and the server echoes the same type back once it is complete.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FrameType {
    /// Version negotiation; first frame in each direction.
    Hello,
    /// Client → server: one [`Request`] to admit (`SubmitRequest`).
    Submit,
    /// Server → client: one completed [`Response`] (out of submission
    /// order).
    Response,
    /// Server → client: the submission with this id was rejected with
    /// `AdmitError::Busy` — wire-level backpressure.
    Busy,
    /// Drain request (client, empty body) / drain-complete ack (server).
    Drained,
    /// Close request (client, empty body) / final frame (server, body =
    /// the session's [`ServeSummary`] with its `RackSnapshot`).
    Closed,
    /// Per-request (`id` != 0 refers to a ticket) or fatal
    /// (`{"fatal": true}`) protocol error.
    Error,
    /// v2 client → server: one [`Request`] as a **binary** body
    /// (compact header + raw little-endian tensor bytes). Only valid
    /// once both peers negotiated v2.
    SubmitBin,
    /// v2 server → client: one [`Response`] as a **binary** body (JSON
    /// metadata blob + raw little-endian output tensor bytes).
    ResponseBin,
    /// v3 client → server: open the logical session named by the
    /// header's `session` field (client-chosen, nonzero); the server
    /// acks with the same type and session. Only valid once both peers
    /// negotiated v3.
    OpenSession,
    /// v3: close one logical session. Client → server with an empty
    /// body requests the close; the server drains that session and
    /// answers with the same type/session carrying its final
    /// [`ServeSummary`].
    SessionClosed,
    /// v3 client → server with an empty body: ask for live telemetry;
    /// the server answers with the same type/id carrying the current
    /// [`RackSnapshot`] (per-shard telemetry + exact per-stage latency
    /// histograms + net gauges) WITHOUT draining or closing anything.
    Stats,
}

impl FrameType {
    pub fn code(self) -> u8 {
        match self {
            FrameType::Hello => 1,
            FrameType::Submit => 2,
            FrameType::Response => 3,
            FrameType::Busy => 4,
            FrameType::Drained => 5,
            FrameType::Closed => 6,
            FrameType::Error => 7,
            FrameType::SubmitBin => 8,
            FrameType::ResponseBin => 9,
            FrameType::OpenSession => 10,
            FrameType::SessionClosed => 11,
            FrameType::Stats => 12,
        }
    }

    pub fn from_code(code: u8) -> Option<FrameType> {
        Some(match code {
            1 => FrameType::Hello,
            2 => FrameType::Submit,
            3 => FrameType::Response,
            4 => FrameType::Busy,
            5 => FrameType::Drained,
            6 => FrameType::Closed,
            7 => FrameType::Error,
            8 => FrameType::SubmitBin,
            9 => FrameType::ResponseBin,
            10 => FrameType::OpenSession,
            11 => FrameType::SessionClosed,
            12 => FrameType::Stats,
            _ => return None,
        })
    }

    /// Whether this frame's body is binary (v2 tensor frames) rather
    /// than a JSON document.
    pub fn is_binary(self) -> bool {
        matches!(self, FrameType::SubmitBin | FrameType::ResponseBin)
    }
}

/// One decoded frame. JSON-bodied frames carry their document in
/// `body` (`bin` empty); binary frames carry their raw payload in
/// `bin` (`body` is `Json::Null`).
#[derive(Debug, Clone, PartialEq)]
pub struct Frame {
    pub ty: FrameType,
    /// Ticket/request id this frame refers to (0 = the connection).
    pub id: u64,
    /// Logical session this frame belongs to (v3; 0 = the connection's
    /// implicit default session, and the only value v1/v2 can express —
    /// their header has no session field).
    pub session: u32,
    /// JSON body (`Json::Null` for an empty or binary body).
    pub body: Json,
    /// Raw payload of a binary frame (empty for JSON frames).
    pub bin: Vec<u8>,
}

impl Frame {
    /// A JSON-bodied frame (every v1 frame, and v2 control frames), on
    /// the default session.
    pub fn new(ty: FrameType, id: u64, body: Json) -> Frame {
        debug_assert!(!ty.is_binary(), "binary frame types take Frame::binary");
        Frame { ty, id, session: 0, body, bin: Vec::new() }
    }

    /// A binary-bodied v2 tensor frame, on the default session.
    pub fn binary(ty: FrameType, id: u64, bin: Vec<u8>) -> Frame {
        debug_assert!(ty.is_binary(), "JSON frame types take Frame::new");
        Frame { ty, id, session: 0, body: Json::Null, bin }
    }

    /// Tag this frame with a v3 logical-session id.
    pub fn with_session(mut self, session: u32) -> Frame {
        self.session = session;
        self
    }
}

/// Why a frame could not be read.
#[derive(Debug)]
pub enum DecodeError {
    /// Clean end of stream at a frame boundary (peer closed).
    Eof,
    /// Transport error mid-read.
    Io(std::io::Error),
    /// The bytes violate the protocol (truncated header/body, unknown
    /// type, oversized length, bad UTF-8, bad JSON). The connection is
    /// unrecoverable — framing can no longer be trusted.
    Malformed(String),
}

impl std::fmt::Display for DecodeError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            DecodeError::Eof => write!(f, "end of stream"),
            DecodeError::Io(e) => write!(f, "transport error: {e}"),
            DecodeError::Malformed(m) => write!(f, "malformed frame: {m}"),
        }
    }
}

impl std::error::Error for DecodeError {}

/// Serialize one frame in the v1/v2 header layout (no session field;
/// the frame's `session` must be 0 — v1/v2 cannot express another). An
/// empty/`null` body is written as zero bytes; binary frame types write
/// their `bin` payload verbatim (no per-element formatting anywhere on
/// the v2 path).
pub fn write_frame<W: Write>(w: &mut W, frame: &Frame) -> std::io::Result<()> {
    write_frame_v(w, frame, 1)
}

/// Serialize one frame in the header layout of negotiated version
/// `proto`: v3 inserts the `session:u32` (big-endian) between `type`
/// and `id`; v1/v2 omit it (and a nonzero session on a v1/v2 frame is
/// a caller bug — debug-asserted, silently dropped in release).
pub fn write_frame_v<W: Write>(w: &mut W, frame: &Frame, proto: u64) -> std::io::Result<()> {
    debug_assert!(
        proto >= 3 || frame.session == 0,
        "a v{proto} header cannot carry session {}",
        frame.session
    );
    let json_body;
    let body: &[u8] = if frame.ty.is_binary() {
        &frame.bin
    } else {
        json_body = match &frame.body {
            Json::Null => String::new(),
            b => b.render(),
        };
        json_body.as_bytes()
    };
    if body.len() > MAX_BODY_BYTES {
        // the read side rejects such a frame anyway; failing here keeps
        // the length prefix from silently wrapping on a >4GiB body
        return Err(std::io::Error::new(
            std::io::ErrorKind::InvalidInput,
            format!("frame body of {} bytes exceeds the {MAX_BODY_BYTES}-byte cap", body.len()),
        ));
    }
    let len = u32::try_from(header_after_len(proto) + body.len()).map_err(|_| {
        std::io::Error::new(std::io::ErrorKind::InvalidInput, "frame length overflows u32")
    })?;
    w.write_all(&len.to_be_bytes())?;
    w.write_all(&[frame.ty.code()])?;
    if proto >= 3 {
        w.write_all(&frame.session.to_be_bytes())?;
    }
    w.write_all(&frame.id.to_be_bytes())?;
    w.write_all(body)
}

/// Read one frame in the v1/v2 header layout. Distinguishes a clean
/// EOF at a frame boundary ([`DecodeError::Eof`]) from a truncation
/// mid-frame (malformed). Never panics on hostile input: unknown
/// types, oversized length prefixes, bad UTF-8 and bad JSON all come
/// back as [`DecodeError::Malformed`].
pub fn read_frame<R: Read>(r: &mut R) -> std::result::Result<Frame, DecodeError> {
    read_frame_v(r, 1)
}

/// [`read_frame`] in the header layout of negotiated version `proto`
/// (v3 reads the `session:u32` field; v1/v2 decode it as 0).
pub fn read_frame_v<R: Read>(r: &mut R, proto: u64) -> std::result::Result<Frame, DecodeError> {
    let mut len_buf = [0u8; 4];
    read_exact_or_eof(r, &mut len_buf)?;
    let len = usize::try_from(u32::from_be_bytes(len_buf)).map_err(|_| {
        DecodeError::Malformed("frame length exceeds this platform's address space".to_string())
    })?;
    let header = header_after_len(proto);
    if len < header {
        return Err(DecodeError::Malformed(format!(
            "frame length {len} shorter than the {header}-byte header"
        )));
    }
    let body_len = len - header;
    if body_len > MAX_BODY_BYTES {
        return Err(DecodeError::Malformed(format!(
            "frame body of {body_len} bytes exceeds the {MAX_BODY_BYTES}-byte cap"
        )));
    }
    let mut payload = vec![0u8; len];
    read_exact_mid_frame(r, &mut payload)?;
    parse_frame_payload(&payload, proto)
}

/// Decode the bytes after a frame's length prefix (header fields +
/// body) under the `proto` header layout. Shared by the blocking
/// reader ([`read_frame_v`]) and the event loop's incremental decoder
/// ([`frame_from_slice`]); `payload.len()` has already been validated
/// against the header size and [`MAX_BODY_BYTES`].
fn parse_frame_payload(payload: &[u8], proto: u64) -> std::result::Result<Frame, DecodeError> {
    let short = || DecodeError::Malformed("frame payload shorter than its header".to_string());
    let &ty_code = payload.first().ok_or_else(short)?;
    let ty = FrameType::from_code(ty_code)
        .ok_or_else(|| DecodeError::Malformed(format!("unknown frame type {ty_code}")))?;
    let (session, id_at) = if proto >= 3 {
        let raw: [u8; 4] =
            payload.get(1..5).and_then(|s| s.try_into().ok()).ok_or_else(short)?;
        (u32::from_be_bytes(raw), 5)
    } else {
        (0, 1)
    };
    let raw: [u8; 8] =
        payload.get(id_at..id_at + 8).and_then(|s| s.try_into().ok()).ok_or_else(short)?;
    let id = u64::from_be_bytes(raw);
    let body_bytes = payload.get(id_at + 8..).unwrap_or(&[]);
    if ty.is_binary() {
        // v2 tensor frames: the payload stays raw; the message-level
        // decoders (decode_request_bin / decode_response_bin) validate
        // it with the same clean-error contract
        return Ok(Frame { ty, id, session, body: Json::Null, bin: body_bytes.to_vec() });
    }
    let body = if body_bytes.is_empty() {
        Json::Null
    } else {
        let text = std::str::from_utf8(body_bytes)
            .map_err(|e| DecodeError::Malformed(format!("body is not UTF-8: {e}")))?;
        crate::util::json::parse(text)
            .map_err(|e| DecodeError::Malformed(format!("body is not JSON: {e}")))?
    };
    Ok(Frame { ty, id, session, body, bin: Vec::new() })
}

/// Incremental decode for a non-blocking read buffer: `Ok(None)` means
/// the buffer holds less than one whole frame (read more bytes and
/// retry — never an error), `Ok(Some((frame, consumed)))` hands back
/// one decoded frame and how many bytes it occupied, and
/// `Err(Malformed)` means the stream can no longer be trusted. This is
/// the event-loop server's decoder: nothing here blocks, and hostile
/// bytes keep the no-panic contract of [`read_frame`].
pub fn frame_from_slice(
    buf: &[u8],
    proto: u64,
) -> std::result::Result<Option<(Frame, usize)>, DecodeError> {
    if buf.len() < 4 {
        return Ok(None);
    }
    let mut len_buf = [0u8; 4];
    len_buf.copy_from_slice(&buf[..4]);
    let len = usize::try_from(u32::from_be_bytes(len_buf)).map_err(|_| {
        DecodeError::Malformed("frame length exceeds this platform's address space".to_string())
    })?;
    let header = header_after_len(proto);
    if len < header {
        return Err(DecodeError::Malformed(format!(
            "frame length {len} shorter than the {header}-byte header"
        )));
    }
    if len - header > MAX_BODY_BYTES {
        return Err(DecodeError::Malformed(format!(
            "frame body of {} bytes exceeds the {MAX_BODY_BYTES}-byte cap",
            len - header
        )));
    }
    if buf.len() < 4 + len {
        return Ok(None);
    }
    let frame = parse_frame_payload(&buf[4..4 + len], proto)?;
    Ok(Some((frame, 4 + len)))
}

/// Fill `buf`, treating 0 bytes at the first read as a clean EOF.
fn read_exact_or_eof<R: Read>(r: &mut R, buf: &mut [u8]) -> std::result::Result<(), DecodeError> {
    let mut filled = 0usize;
    while filled < buf.len() {
        match r.read(&mut buf[filled..]) {
            Ok(0) if filled == 0 => return Err(DecodeError::Eof),
            Ok(0) => {
                return Err(DecodeError::Malformed("stream truncated mid frame header".into()))
            }
            Ok(n) => filled += n,
            Err(e) if e.kind() == std::io::ErrorKind::Interrupted => continue,
            Err(e) => return Err(DecodeError::Io(e)),
        }
    }
    Ok(())
}

/// Fill `buf` strictly inside a frame: any EOF is a truncation.
fn read_exact_mid_frame<R: Read>(r: &mut R, buf: &mut [u8]) -> std::result::Result<(), DecodeError> {
    match r.read_exact(buf) {
        Ok(()) => Ok(()),
        Err(e) if e.kind() == std::io::ErrorKind::UnexpectedEof => {
            Err(DecodeError::Malformed("stream truncated mid frame".into()))
        }
        Err(e) => Err(DecodeError::Io(e)),
    }
}

// ---------------------------------------------------------------------
// JSON mapping helpers: exact u64/i64 round-trips.

/// Largest integer a JSON `f64` number holds exactly.
const MAX_SAFE_INT: u64 = 1 << 53;

fn ju64(v: u64) -> Json {
    if v <= MAX_SAFE_INT {
        Json::Num(v as f64)
    } else {
        Json::Str(v.to_string())
    }
}

fn ji64(v: i64) -> Json {
    if v.unsigned_abs() <= MAX_SAFE_INT {
        Json::Num(v as f64)
    } else {
        Json::Str(v.to_string())
    }
}

/// f32 tensor elements: finite values ride as JSON numbers (f32→f64 is
/// exact); NaN/±inf — which JSON cannot express and `Json::render`
/// would degrade to `null` — ride as the strings `"NaN"`/`"inf"`/
/// `"-inf"` instead, so a functional response containing them crosses
/// the wire as the same special value rather than killing the
/// connection (NaN payload bits are not preserved).
fn jf32(x: f32) -> Json {
    if x.is_finite() {
        Json::Num(x as f64)
    } else {
        Json::Str(format!("{x}"))
    }
}

fn get_u64(j: &Json, key: &str) -> Result<u64> {
    let v = j.get(key).ok_or_else(|| anyhow!("missing field {key:?}"))?;
    match v {
        Json::Num(n) if *n >= 0.0 && n.fract() == 0.0 && *n <= MAX_SAFE_INT as f64 => Ok(*n as u64),
        Json::Str(s) => s.parse().with_context(|| format!("field {key:?} is not a u64")),
        _ => bail!("field {key:?} is not a u64"),
    }
}

fn get_i64_val(v: &Json) -> Result<i64> {
    match v {
        Json::Num(n) if n.fract() == 0.0 && n.abs() <= MAX_SAFE_INT as f64 => Ok(*n as i64),
        Json::Str(s) => s.parse().map_err(|_| anyhow!("not an i64: {s:?}")),
        _ => bail!("not an i64"),
    }
}

fn get_u64_val(v: &Json) -> Result<u64> {
    match v {
        Json::Num(n) if *n >= 0.0 && n.fract() == 0.0 && *n <= MAX_SAFE_INT as f64 => Ok(*n as u64),
        Json::Str(s) => s.parse().map_err(|_| anyhow!("not a u64: {s:?}")),
        _ => bail!("not a u64"),
    }
}

/// Checked u64 → u32 field read: a value past `u32::MAX` is a decode
/// error, never an `as`-wrap — a hostile-but-well-formed JSON body
/// must not be able to smuggle a wrapped config value past validation.
fn get_u32(j: &Json, key: &str) -> Result<u32> {
    let v = get_u64(j, key)?;
    u32::try_from(v).map_err(|_| anyhow!("field {key:?} value {v} exceeds u32"))
}

/// Checked u64 → usize field read (same contract as [`get_u32`]).
fn get_usize(j: &Json, key: &str) -> Result<usize> {
    let v = get_u64(j, key)?;
    usize::try_from(v).map_err(|_| anyhow!("field {key:?} value {v} exceeds usize"))
}

fn get_f64(j: &Json, key: &str) -> Result<f64> {
    match j.get(key) {
        Some(Json::Num(n)) => Ok(*n),
        Some(Json::Null) => Ok(f64::NAN), // non-finite degraded to null on encode
        _ => bail!("missing or non-numeric field {key:?}"),
    }
}

fn get_str<'a>(j: &'a Json, key: &str) -> Result<&'a str> {
    j.get(key).and_then(Json::as_str).ok_or_else(|| anyhow!("missing string field {key:?}"))
}

fn obj(fields: Vec<(&str, Json)>) -> Json {
    Json::Obj(fields.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
}

// ---------------------------------------------------------------------
// Operator / tensor codecs.

fn encode_tensor(t: &HostTensor) -> Json {
    match t {
        HostTensor::I32(v) => obj(vec![
            ("dtype", Json::Str("i32".into())),
            ("data", Json::Arr(v.iter().map(|&x| Json::Num(x as f64)).collect())),
        ]),
        HostTensor::I64(v) => obj(vec![
            ("dtype", Json::Str("i64".into())),
            ("data", Json::Arr(v.iter().map(|&x| ji64(x)).collect())),
        ]),
        HostTensor::F32(v) => obj(vec![
            ("dtype", Json::Str("f32".into())),
            ("data", Json::Arr(v.iter().map(|&x| jf32(x)).collect())),
        ]),
    }
}

fn decode_tensor(j: &Json) -> Result<HostTensor> {
    let data = j.get("data").and_then(Json::as_arr).ok_or_else(|| anyhow!("tensor without data"))?;
    Ok(match get_str(j, "dtype")? {
        "i32" => HostTensor::I32(
            data.iter()
                .map(|v| get_i64_val(v).and_then(|x| i32::try_from(x).map_err(|_| anyhow!("i32 overflow"))))
                .collect::<Result<_>>()?,
        ),
        "i64" => HostTensor::I64(data.iter().map(get_i64_val).collect::<Result<_>>()?),
        "f32" => HostTensor::F32(
            data.iter()
                .map(|v| match v {
                    Json::Num(n) => Ok(*n as f32),
                    Json::Str(s) => s.parse::<f32>().map_err(|_| anyhow!("bad f32 element {s:?}")),
                    _ => bail!("f32 tensor with non-numeric element"),
                })
                .collect::<Result<_>>()?,
        ),
        other => bail!("unknown tensor dtype {other:?}"),
    })
}

fn vector_kind_name(k: VectorKind) -> &'static str {
    match k {
        VectorKind::Map => "map",
        VectorKind::Axpy => "axpy",
        VectorKind::Reduce => "reduce",
        VectorKind::Activation => "activation",
    }
}

fn parse_vector_kind(s: &str) -> Result<VectorKind> {
    Ok(match s {
        "map" => VectorKind::Map,
        "axpy" => VectorKind::Axpy,
        "reduce" => VectorKind::Reduce,
        "activation" => VectorKind::Activation,
        other => bail!("unknown vector kind {other:?}"),
    })
}

fn encode_op(op: &TensorOp) -> Json {
    match op {
        TensorOp::PGemm(g) => obj(vec![
            ("kind", Json::Str("pgemm".into())),
            ("m", ju64(g.m)),
            ("n", ju64(g.n)),
            ("k", ju64(g.k)),
            ("precision", Json::Str(g.precision.name().into())),
        ]),
        TensorOp::Vector(v) => obj(vec![
            ("kind", Json::Str("vector".into())),
            ("len", ju64(v.len)),
            ("precision", Json::Str(v.precision.name().into())),
            ("vkind", Json::Str(vector_kind_name(v.kind).into())),
        ]),
    }
}

fn decode_op(j: &Json) -> Result<TensorOp> {
    let precision = Precision::parse(get_str(j, "precision")?)
        .ok_or_else(|| anyhow!("unknown precision"))?;
    Ok(match get_str(j, "kind")? {
        "pgemm" => {
            let (m, n, k) = (get_u64(j, "m")?, get_u64(j, "n")?, get_u64(j, "k")?);
            if m == 0 || n == 0 || k == 0 {
                bail!("degenerate p-GEMM dims are 1, not 0");
            }
            TensorOp::PGemm(PGemm::new(m, n, k, precision))
        }
        "vector" => {
            let len = get_u64(j, "len")?;
            if len == 0 {
                bail!("vector op over 0 elements");
            }
            TensorOp::Vector(VectorOp::new(len, precision, parse_vector_kind(get_str(j, "vkind")?)?))
        }
        other => bail!("unknown op kind {other:?}"),
    })
}

/// Encode one [`Request`] as a frame body (the id also travels in the
/// frame header; the header wins on decode mismatch).
pub fn encode_request(req: &Request) -> Json {
    let exec = match &req.exec {
        ExecKind::Simulate => obj(vec![("kind", Json::Str("simulate".into()))]),
        ExecKind::Functional { artifact, inputs } => obj(vec![
            ("kind", Json::Str("functional".into())),
            ("artifact", Json::Str(artifact.clone())),
            ("inputs", Json::Arr(inputs.iter().map(encode_tensor).collect())),
        ]),
    };
    obj(vec![("id", ju64(req.id)), ("op", encode_op(&req.op)), ("exec", exec)])
}

pub fn decode_request(j: &Json) -> Result<Request> {
    let id = get_u64(j, "id")?;
    let op = decode_op(j.get("op").ok_or_else(|| anyhow!("request without op"))?)?;
    let exec_j = j.get("exec").ok_or_else(|| anyhow!("request without exec"))?;
    let exec = match get_str(exec_j, "kind")? {
        "simulate" => ExecKind::Simulate,
        "functional" => ExecKind::Functional {
            artifact: get_str(exec_j, "artifact")?.to_string(),
            inputs: exec_j
                .get("inputs")
                .and_then(Json::as_arr)
                .ok_or_else(|| anyhow!("functional exec without inputs"))?
                .iter()
                .map(decode_tensor)
                .collect::<Result<_>>()?,
        },
        other => bail!("unknown exec kind {other:?}"),
    };
    Ok(Request { id, op, exec })
}

// ---------------------------------------------------------------------
// Response codecs.

fn encode_sim(s: &SimReport) -> Json {
    obj(vec![
        ("cycles", ju64(s.cycles)),
        ("freq_mhz", Json::Num(s.freq_mhz as f64)),
        ("sram_bytes", ju64(s.sram_bytes)),
        ("dram_bytes", ju64(s.dram_bytes)),
        ("macs", ju64(s.macs)),
        ("utilization", Json::Num(s.utilization)),
        ("energy_pj", Json::Num(s.energy_pj)),
    ])
}

fn decode_sim(j: &Json) -> Result<SimReport> {
    Ok(SimReport {
        cycles: get_u64(j, "cycles")?,
        freq_mhz: get_u32(j, "freq_mhz")?,
        sram_bytes: get_u64(j, "sram_bytes")?,
        dram_bytes: get_u64(j, "dram_bytes")?,
        macs: get_u64(j, "macs")?,
        utilization: get_f64(j, "utilization")?,
        energy_pj: get_f64(j, "energy_pj")?,
    })
}

fn dataflow_from_name(s: &str) -> Result<Dataflow> {
    Ok(match s {
        "WS" => Dataflow::WS,
        "IS" => Dataflow::IS,
        "OS" => Dataflow::OS,
        "SIMD" => Dataflow::Simd,
        other => bail!("unknown dataflow {other:?}"),
    })
}

fn encode_schedule(c: &ScheduleConfig) -> Json {
    obj(vec![
        ("dataflow", Json::Str(c.dataflow.name().into())),
        ("lane_rows", Json::Num(c.arrangement.lane_rows as f64)),
        ("lane_cols", Json::Num(c.arrangement.lane_cols as f64)),
        ("k_segments", ju64(c.k_segments)),
        (
            "tile_dir",
            Json::Str(
                match c.tile_dir {
                    crate::scheduler::pattern::TileDir::Lateral => "lateral",
                    crate::scheduler::pattern::TileDir::Vertical => "vertical",
                }
                .into(),
            ),
        ),
    ])
}

fn decode_schedule(j: &Json) -> Result<ScheduleConfig> {
    let rows = get_u32(j, "lane_rows")?;
    let cols = get_u32(j, "lane_cols")?;
    if rows == 0 || cols == 0 {
        bail!("degenerate lane arrangement");
    }
    Ok(ScheduleConfig {
        arrangement: Arrangement::new(rows, cols),
        dataflow: dataflow_from_name(get_str(j, "dataflow")?)?,
        k_segments: get_u64(j, "k_segments")?,
        tile_dir: match get_str(j, "tile_dir")? {
            "lateral" => crate::scheduler::pattern::TileDir::Lateral,
            "vertical" => crate::scheduler::pattern::TileDir::Vertical,
            other => bail!("unknown tile direction {other:?}"),
        },
    })
}

/// Everything in a [`Response`] except the output tensors — the part
/// that stays JSON in both protocol versions ("response metadata").
fn response_meta_fields(resp: &Response) -> Vec<(&'static str, Json)> {
    vec![
        ("id", ju64(resp.id)),
        ("shard", Json::Num(resp.shard as f64)),
        (
            "schedule",
            match &resp.schedule {
                Some(c) => encode_schedule(&c.config),
                None => Json::Null,
            },
        ),
        ("sim", encode_sim(&resp.sim)),
        (
            "error",
            match &resp.error {
                Some(e) => Json::Str(e.clone()),
                None => Json::Null,
            },
        ),
        ("latency_us", ju64(resp.latency.as_micros() as u64)),
    ]
}

/// Encode one [`Response`] as a v1 frame body. The schedule travels as
/// its [`ScheduleConfig`] only; the client reconstructs a [`Candidate`]
/// whose report is the response's own `sim` (identical by construction
/// for p-GEMMs — the shard answers with the winning candidate's report)
/// and whose pattern-coverage detail is dropped.
pub fn encode_response(resp: &Response) -> Json {
    let mut fields = response_meta_fields(resp);
    fields.push((
        "outputs",
        match &resp.outputs {
            Some(outs) => Json::Arr(outs.iter().map(encode_tensor).collect()),
            None => Json::Null,
        },
    ));
    obj(fields)
}

pub fn decode_response(j: &Json) -> Result<Response> {
    let sim = decode_sim(j.get("sim").ok_or_else(|| anyhow!("response without sim"))?)?;
    let schedule = match j.get("schedule") {
        None | Some(Json::Null) => None,
        Some(s) => Some(Candidate { config: decode_schedule(s)?, report: sim, coverage: None }),
    };
    let outputs = match j.get("outputs") {
        None | Some(Json::Null) => None,
        Some(Json::Arr(items)) => Some(items.iter().map(decode_tensor).collect::<Result<_>>()?),
        Some(_) => bail!("outputs is neither null nor an array"),
    };
    let error = match j.get("error") {
        None | Some(Json::Null) => None,
        Some(Json::Str(s)) => Some(s.clone()),
        Some(_) => bail!("error is neither null nor a string"),
    };
    Ok(Response {
        id: get_u64(j, "id")?,
        shard: get_usize(j, "shard")?,
        schedule,
        sim,
        outputs,
        error,
        latency: Duration::from_micros(get_u64(j, "latency_us")?),
    })
}

// ---------------------------------------------------------------------
// v2 binary bodies: zero-copy tensor frames.
//
// Layouts (all multi-byte integers little-endian — native on every
// deployment target, so element bytes are memcpy'd; the frame header
// around the body stays big-endian as in v1):
//
// ```text
// tensor          := dtype:u8 (1=i32, 2=i64, 3=f32)
//                    count:u64
//                    raw element bytes (count x elem size, LE)
// SubmitBin body  := op_kind:u8 (1=pgemm, 2=vector)  precision:u8
//                    pgemm:  m:u64 n:u64 k:u64
//                    vector: len:u64 vkind:u8 (1=map..4=activation)
//                    exec:u8 (0=simulate, 1=functional)
//                    functional: artifact_len:u32 artifact:UTF-8
//                                n_inputs:u32 tensor*
// ResponseBin body:= meta_len:u32
//                    meta:UTF-8 JSON (the v1 response body minus
//                                     "outputs")
//                    has_outputs:u8 (0|1)
//                    n_outputs:u32 tensor*      (when has_outputs=1)
// ```
//
// Decode goes straight into [`HostTensor`] buffers with one allocation
// per tensor and no intermediate `Vec<Json>`; encode writes from the
// tensor slice with no per-element formatting. Every read is
// bounds-checked against the declared body, element counts are
// overflow-checked *before* any allocation (an allocation can never
// exceed the already-read body), and trailing bytes are malformed —
// hostile bytes get a clean `Err`, never a panic and never a silently
// wrong tensor.

const DT_I32: u8 = 1;
const DT_I64: u8 = 2;
const DT_F32: u8 = 3;

const OP_PGEMM: u8 = 1;
const OP_VECTOR: u8 = 2;

const EXEC_SIMULATE: u8 = 0;
const EXEC_FUNCTIONAL: u8 = 1;

fn precision_code(p: Precision) -> u8 {
    match p {
        Precision::Int8 => 1,
        Precision::Int16 => 2,
        Precision::Int32 => 3,
        Precision::Int64 => 4,
        Precision::Bp16 => 5,
        Precision::Fp16 => 6,
        Precision::Fp32 => 7,
        Precision::Fp64 => 8,
    }
}

fn precision_from_code(c: u8) -> Result<Precision> {
    Ok(match c {
        1 => Precision::Int8,
        2 => Precision::Int16,
        3 => Precision::Int32,
        4 => Precision::Int64,
        5 => Precision::Bp16,
        6 => Precision::Fp16,
        7 => Precision::Fp32,
        8 => Precision::Fp64,
        other => bail!("unknown binary precision tag {other}"),
    })
}

fn vector_kind_code(k: VectorKind) -> u8 {
    match k {
        VectorKind::Map => 1,
        VectorKind::Axpy => 2,
        VectorKind::Reduce => 3,
        VectorKind::Activation => 4,
    }
}

fn vector_kind_from_code(c: u8) -> Result<VectorKind> {
    Ok(match c {
        1 => VectorKind::Map,
        2 => VectorKind::Axpy,
        3 => VectorKind::Reduce,
        4 => VectorKind::Activation,
        other => bail!("unknown binary vector kind tag {other}"),
    })
}

fn put_u32(out: &mut Vec<u8>, v: u32) {
    out.extend_from_slice(&v.to_le_bytes());
}

fn put_u64(out: &mut Vec<u8>, v: u64) {
    out.extend_from_slice(&v.to_le_bytes());
}

/// Bounds-checked reader over a binary body: every primitive read and
/// slice take fails cleanly at the end of the buffer.
struct Cur<'a> {
    buf: &'a [u8],
}

impl<'a> Cur<'a> {
    fn new(buf: &'a [u8]) -> Cur<'a> {
        Cur { buf }
    }

    fn bytes(&mut self, n: usize) -> Result<&'a [u8]> {
        if self.buf.len() < n {
            bail!("binary body truncated: wanted {n} more bytes, have {}", self.buf.len());
        }
        let (head, rest) = self.buf.split_at(n);
        self.buf = rest;
        Ok(head)
    }

    fn u8(&mut self) -> Result<u8> {
        Ok(self.bytes(1)?[0])
    }

    fn u32(&mut self) -> Result<u32> {
        let raw: [u8; 4] = self
            .bytes(4)?
            .try_into()
            .map_err(|_| anyhow!("binary body truncated inside a u32"))?;
        Ok(u32::from_le_bytes(raw))
    }

    fn u64(&mut self) -> Result<u64> {
        let raw: [u8; 8] = self
            .bytes(8)?
            .try_into()
            .map_err(|_| anyhow!("binary body truncated inside a u64"))?;
        Ok(u64::from_le_bytes(raw))
    }

    /// Trailing bytes after a complete message are malformed — framing
    /// mistakes must never pass silently.
    fn finish(self) -> Result<()> {
        if !self.buf.is_empty() {
            bail!("binary body has {} trailing bytes", self.buf.len());
        }
        Ok(())
    }
}

/// Append one tensor in the v2 binary layout: dtype tag, element
/// count, raw little-endian element bytes straight from the slice.
fn encode_tensor_bin(t: &HostTensor, out: &mut Vec<u8>) {
    match t {
        HostTensor::I32(v) => {
            out.push(DT_I32);
            put_u64(out, v.len() as u64);
            // lint: allow(R7) encode side: sized by our own in-memory tensor, not wire bytes
            out.reserve(v.len() * 4);
            for &x in v {
                out.extend_from_slice(&x.to_le_bytes());
            }
        }
        HostTensor::I64(v) => {
            out.push(DT_I64);
            put_u64(out, v.len() as u64);
            // lint: allow(R7) encode side: sized by our own in-memory tensor, not wire bytes
            out.reserve(v.len() * 8);
            for &x in v {
                out.extend_from_slice(&x.to_le_bytes());
            }
        }
        HostTensor::F32(v) => {
            out.push(DT_F32);
            put_u64(out, v.len() as u64);
            // lint: allow(R7) encode side: sized by our own in-memory tensor, not wire bytes
            out.reserve(v.len() * 4);
            for &x in v {
                out.extend_from_slice(&x.to_le_bytes());
            }
        }
    }
}

/// Wire bytes one tensor occupies in the v2 binary layout.
fn tensor_bin_len(t: &HostTensor) -> usize {
    let elem = match t {
        HostTensor::I32(_) | HostTensor::F32(_) => 4,
        HostTensor::I64(_) => 8,
    };
    1 + 8 + t.len() * elem
}

/// Decode one tensor from the v2 binary layout into a [`HostTensor`]
/// with a single exact-size allocation. The declared element count is
/// overflow-checked and bounds-checked against the remaining body
/// before anything is allocated.
fn decode_tensor_bin(c: &mut Cur<'_>) -> Result<HostTensor> {
    let dtype = c.u8()?;
    let count = c.u64()?;
    let n = usize::try_from(count)
        .map_err(|_| anyhow!("tensor element count {count} overflows this platform"))?;
    let elem = match dtype {
        DT_I32 | DT_F32 => 4usize,
        DT_I64 => 8,
        other => bail!("unknown binary tensor dtype tag {other}"),
    };
    let nbytes = n
        .checked_mul(elem)
        .ok_or_else(|| anyhow!("tensor byte length overflows ({count} x {elem})"))?;
    let raw = c.bytes(nbytes)?;
    Ok(match dtype {
        DT_I32 => HostTensor::I32(
            // lint: allow(R2) chunks_exact(4) yields exactly-4-byte windows
            raw.chunks_exact(4).map(|b| i32::from_le_bytes([b[0], b[1], b[2], b[3]])).collect(),
        ),
        DT_I64 => HostTensor::I64(
            raw.chunks_exact(8)
                // lint: allow(R2) chunks_exact(8) yields exactly-8-byte windows
                .map(|b| i64::from_le_bytes([b[0], b[1], b[2], b[3], b[4], b[5], b[6], b[7]]))
                .collect(),
        ),
        _ => HostTensor::F32(
            // lint: allow(R2) chunks_exact(4) yields exactly-4-byte windows
            raw.chunks_exact(4).map(|b| f32::from_le_bytes([b[0], b[1], b[2], b[3]])).collect(),
        ),
    })
}

/// Encode one [`Request`] as a v2 `SubmitBin` body. The request id
/// travels only in the frame header (it is authoritative in v1 too).
pub fn encode_request_bin(req: &Request) -> Vec<u8> {
    let tensor_bytes = match &req.exec {
        ExecKind::Functional { inputs, .. } => inputs.iter().map(tensor_bin_len).sum(),
        ExecKind::Simulate => 0,
    };
    // lint: allow(R7) encode side: sized from our own request, not wire bytes
    let mut out = Vec::with_capacity(64 + tensor_bytes);
    match &req.op {
        TensorOp::PGemm(g) => {
            out.push(OP_PGEMM);
            out.push(precision_code(g.precision));
            put_u64(&mut out, g.m);
            put_u64(&mut out, g.n);
            put_u64(&mut out, g.k);
        }
        TensorOp::Vector(v) => {
            out.push(OP_VECTOR);
            out.push(precision_code(v.precision));
            put_u64(&mut out, v.len);
            out.push(vector_kind_code(v.kind));
        }
    }
    match &req.exec {
        ExecKind::Simulate => out.push(EXEC_SIMULATE),
        ExecKind::Functional { artifact, inputs } => {
            out.push(EXEC_FUNCTIONAL);
            // lint: allow(R1) a >4 GiB name cannot leave the process: write_frame_v caps bodies
            put_u32(&mut out, artifact.len() as u32);
            out.extend_from_slice(artifact.as_bytes());
            // lint: allow(R1) input count is bounded by the same body cap
            put_u32(&mut out, inputs.len() as u32);
            for t in inputs {
                encode_tensor_bin(t, &mut out);
            }
        }
    }
    out
}

/// Decode a v2 `SubmitBin` body. `id` is the frame header's request id
/// (v2 bodies do not repeat it). Same validation surface as the v1
/// JSON [`decode_request`]: degenerate dims, unknown tags, truncations
/// and trailing bytes are all clean errors.
pub fn decode_request_bin(id: u64, bytes: &[u8]) -> Result<Request> {
    let mut c = Cur::new(bytes);
    let op_kind = c.u8()?;
    let precision = precision_from_code(c.u8()?)?;
    let op = match op_kind {
        OP_PGEMM => {
            let (m, n, k) = (c.u64()?, c.u64()?, c.u64()?);
            if m == 0 || n == 0 || k == 0 {
                bail!("degenerate p-GEMM dims are 1, not 0");
            }
            TensorOp::PGemm(PGemm::new(m, n, k, precision))
        }
        OP_VECTOR => {
            let len = c.u64()?;
            if len == 0 {
                bail!("vector op over 0 elements");
            }
            TensorOp::Vector(VectorOp::new(len, precision, vector_kind_from_code(c.u8()?)?))
        }
        other => bail!("unknown binary op kind {other}"),
    };
    let exec = match c.u8()? {
        EXEC_SIMULATE => ExecKind::Simulate,
        EXEC_FUNCTIONAL => {
            let alen = usize::try_from(c.u32()?)
                .map_err(|_| anyhow!("artifact name length exceeds this platform"))?;
            let artifact = std::str::from_utf8(c.bytes(alen)?)
                .map_err(|e| anyhow!("artifact name is not UTF-8: {e}"))?
                .to_string();
            let n_inputs = c.u32()?;
            // no preallocation from the claimed count: a hostile header
            // cannot make the server reserve more than it sent
            let mut inputs = Vec::new();
            for _ in 0..n_inputs {
                inputs.push(decode_tensor_bin(&mut c)?);
            }
            ExecKind::Functional { artifact, inputs }
        }
        other => bail!("unknown binary exec kind {other}"),
    };
    c.finish()?;
    Ok(Request { id, op, exec })
}

/// Encode one [`Response`] as a v2 `ResponseBin` body: the metadata
/// (id, shard, schedule, sim, error, latency) as one small JSON blob,
/// the output tensors as raw binary sections.
pub fn encode_response_bin(resp: &Response) -> Vec<u8> {
    let meta = obj(response_meta_fields(resp)).render();
    let tensor_bytes: usize = match &resp.outputs {
        Some(outs) => outs.iter().map(tensor_bin_len).sum(),
        None => 0,
    };
    // lint: allow(R7) encode side: sized from our own response, not wire bytes
    let mut out = Vec::with_capacity(4 + meta.len() + 5 + tensor_bytes);
    // lint: allow(R1) metadata JSON is small and ours; write_frame_v caps bodies anyway
    put_u32(&mut out, meta.len() as u32);
    out.extend_from_slice(meta.as_bytes());
    match &resp.outputs {
        None => out.push(0),
        Some(outs) => {
            out.push(1);
            // lint: allow(R1) output count is bounded by the same body cap
            put_u32(&mut out, outs.len() as u32);
            for t in outs {
                encode_tensor_bin(t, &mut out);
            }
        }
    }
    out
}

/// Decode a v2 `ResponseBin` body (metadata JSON + binary outputs).
pub fn decode_response_bin(bytes: &[u8]) -> Result<Response> {
    let mut c = Cur::new(bytes);
    let meta_len = usize::try_from(c.u32()?)
        .map_err(|_| anyhow!("response metadata length exceeds this platform"))?;
    let meta_text = std::str::from_utf8(c.bytes(meta_len)?)
        .map_err(|e| anyhow!("response metadata is not UTF-8: {e}"))?;
    let meta = crate::util::json::parse(meta_text)
        .map_err(|e| anyhow!("response metadata is not JSON: {e}"))?;
    let mut resp = decode_response(&meta)?;
    resp.outputs = match c.u8()? {
        0 => None,
        1 => {
            let n = c.u32()?;
            let mut outs = Vec::new();
            for _ in 0..n {
                outs.push(decode_tensor_bin(&mut c)?);
            }
            Some(outs)
        }
        other => bail!("bad has_outputs tag {other}"),
    };
    c.finish()?;
    Ok(resp)
}

// ---------------------------------------------------------------------
// Telemetry codecs (the Closed frame's ServeSummary + RackSnapshot).

fn encode_count_map<K: ToString>(m: &BTreeMap<K, u64>) -> Json {
    Json::Obj(m.iter().map(|(k, v)| (k.to_string(), ju64(*v))).collect())
}

/// Sparse wire form of one [`Histogram`]: only the non-empty buckets
/// travel, keyed by bucket index, plus the exact count/sum/min/max.
fn encode_hist(h: &Histogram) -> Json {
    obj(vec![
        (
            "counts",
            Json::Obj(h.to_sparse().into_iter().map(|(b, c)| (b.to_string(), ju64(c))).collect()),
        ),
        ("count", ju64(h.count())),
        ("sum", ju64(h.sum())),
        ("min", ju64(h.min())),
        ("max", ju64(h.max())),
    ])
}

fn decode_hist(j: &Json) -> Result<Histogram> {
    let pairs = j
        .get("counts")
        .and_then(Json::as_obj)
        .ok_or_else(|| anyhow!("histogram without counts"))?
        .iter()
        .map(|(k, v)| {
            Ok((k.parse::<usize>().map_err(|_| anyhow!("bad histogram bucket key"))?, get_u64_val(v)?))
        })
        .collect::<Result<Vec<(usize, u64)>>>()?;
    Ok(Histogram::from_sparse(
        &pairs,
        get_u64(j, "count")?,
        get_u64(j, "sum")?,
        get_u64(j, "min")?,
        get_u64(j, "max")?,
    ))
}

/// Per-stage histograms, keyed by stage name; empty stages are omitted.
fn encode_stage_hists(sh: &StageHists) -> Json {
    Json::Obj(sh.non_empty().map(|(s, h)| (s.name().to_string(), encode_hist(h))).collect())
}

fn decode_stage_hists(j: &Json) -> Result<StageHists> {
    let entries = j.as_obj().ok_or_else(|| anyhow!("stage histograms are not an object"))?;
    let mut sh = StageHists::new();
    for (k, v) in entries {
        // Stage names a newer peer knows and we don't are skipped, not
        // an error — same spirit as the version negotiation.
        if let Some(stage) = Stage::from_name(k) {
            *sh.get_mut(stage) = decode_hist(v)?;
        }
    }
    Ok(sh)
}

fn encode_net_gauges(g: &NetGauges) -> Json {
    obj(vec![
        ("active_connections", ju64(g.active_connections)),
        ("active_sessions", ju64(g.active_sessions)),
        ("bytes_in", ju64(g.bytes_in)),
        ("bytes_out", ju64(g.bytes_out)),
    ])
}

fn decode_net_gauges(g: &Json) -> Result<NetGauges> {
    Ok(NetGauges {
        active_connections: get_u64(g, "active_connections")?,
        active_sessions: get_u64(g, "active_sessions")?,
        bytes_in: get_u64(g, "bytes_in")?,
        bytes_out: get_u64(g, "bytes_out")?,
    })
}

fn encode_snapshot(s: &Snapshot) -> Json {
    obj(vec![
        ("requests", ju64(s.requests)),
        ("pgemm_ops", ju64(s.pgemm_ops)),
        ("vector_ops", ju64(s.vector_ops)),
        ("functional_execs", ju64(s.functional_execs)),
        ("functional_errors", ju64(s.functional_errors)),
        ("schedule_cache_hits", ju64(s.schedule_cache_hits)),
        ("schedule_cache_misses", ju64(s.schedule_cache_misses)),
        ("per_artifact", encode_count_map(&s.per_artifact)),
        ("admission_rejected", ju64(s.admission_rejected)),
        ("admission_requeued", ju64(s.admission_requeued)),
        ("queue_peak_depth", ju64(s.queue_peak_depth)),
        ("batches", ju64(s.batches)),
        ("batched_requests", ju64(s.batched_requests)),
        ("batch_hist", encode_count_map(&s.batch_hist)),
        ("max_batch", ju64(s.max_batch)),
        ("sim_cycles", ju64(s.sim_cycles)),
        ("mean_sim_utilization", Json::Num(s.mean_sim_utilization)),
        ("coalesce_window_us", ju64(s.coalesce_window_us)),
        ("latency_ewma_us", Json::Num(s.latency_ewma_us)),
        ("latency_count", ju64(s.latency_count)),
        ("p50_us", ju64(s.p50_us)),
        ("p95_us", ju64(s.p95_us)),
        ("p99_us", ju64(s.p99_us)),
        ("mean_us", Json::Num(s.mean_us)),
        ("lat_hist", encode_hist(&s.lat_hist)),
        ("stage_hist", encode_stage_hists(&s.stage_hist)),
    ])
}

fn decode_snapshot(j: &Json) -> Result<Snapshot> {
    let per_artifact = j
        .get("per_artifact")
        .and_then(Json::as_obj)
        .ok_or_else(|| anyhow!("snapshot without per_artifact"))?
        .iter()
        .map(|(k, v)| Ok((k.clone(), get_u64_val(v)?)))
        .collect::<Result<BTreeMap<String, u64>>>()?;
    let batch_hist = j
        .get("batch_hist")
        .and_then(Json::as_obj)
        .ok_or_else(|| anyhow!("snapshot without batch_hist"))?
        .iter()
        .map(|(k, v)| Ok((k.parse::<u64>().map_err(|_| anyhow!("bad batch size key"))?, get_u64_val(v)?)))
        .collect::<Result<BTreeMap<u64, u64>>>()?;
    Ok(Snapshot {
        requests: get_u64(j, "requests")?,
        pgemm_ops: get_u64(j, "pgemm_ops")?,
        vector_ops: get_u64(j, "vector_ops")?,
        functional_execs: get_u64(j, "functional_execs")?,
        functional_errors: get_u64(j, "functional_errors")?,
        schedule_cache_hits: get_u64(j, "schedule_cache_hits")?,
        schedule_cache_misses: get_u64(j, "schedule_cache_misses")?,
        per_artifact,
        admission_rejected: get_u64(j, "admission_rejected")?,
        admission_requeued: get_u64(j, "admission_requeued")?,
        queue_peak_depth: get_u64(j, "queue_peak_depth")?,
        batches: get_u64(j, "batches")?,
        batched_requests: get_u64(j, "batched_requests")?,
        batch_hist,
        max_batch: get_u64(j, "max_batch")?,
        sim_cycles: get_u64(j, "sim_cycles")?,
        mean_sim_utilization: get_f64(j, "mean_sim_utilization")?,
        coalesce_window_us: get_u64(j, "coalesce_window_us")?,
        latency_ewma_us: get_f64(j, "latency_ewma_us")?,
        latency_count: get_u64(j, "latency_count")?,
        p50_us: get_u64(j, "p50_us")?,
        p95_us: get_u64(j, "p95_us")?,
        p99_us: get_u64(j, "p99_us")?,
        mean_us: get_f64(j, "mean_us")?,
        // Absent/null from pre-obs peers: default to empty histograms
        // so absorb falls back to the legacy max-of-percentiles merge.
        lat_hist: match j.get("lat_hist") {
            None | Some(Json::Null) => Histogram::default(),
            Some(h) => decode_hist(h)?,
        },
        stage_hist: match j.get("stage_hist") {
            None | Some(Json::Null) => StageHists::default(),
            Some(h) => decode_stage_hists(h)?,
        },
    })
}

fn encode_shard_telemetry(t: &ShardTelemetry) -> Json {
    obj(vec![
        ("shard", Json::Num(t.shard as f64)),
        ("lanes", Json::Num(t.lanes as f64)),
        ("config_fingerprint", ju64(t.config_fingerprint)),
        ("routed", ju64(t.routed)),
        ("queued", ju64(t.queued)),
        ("lanes_total", Json::Num(t.lane_usage.total as f64)),
        ("lanes_free", Json::Num(t.lane_usage.free as f64)),
        ("live_partitions", Json::Num(t.lane_usage.live_partitions as f64)),
        ("snapshot", encode_snapshot(&t.snapshot)),
    ])
}

fn decode_shard_telemetry(j: &Json) -> Result<ShardTelemetry> {
    Ok(ShardTelemetry {
        shard: get_usize(j, "shard")?,
        lanes: get_u32(j, "lanes")?,
        config_fingerprint: get_u64(j, "config_fingerprint")?,
        routed: get_u64(j, "routed")?,
        queued: get_u64(j, "queued")?,
        lane_usage: LaneUsage {
            total: get_u32(j, "lanes_total")?,
            free: get_u32(j, "lanes_free")?,
            live_partitions: get_usize(j, "live_partitions")?,
        },
        snapshot: decode_snapshot(
            j.get("snapshot").ok_or_else(|| anyhow!("telemetry without snapshot"))?,
        )?,
    })
}

/// Encode the final [`ServeSummary`] (the `Closed` frame's body),
/// including the per-shard [`RackSnapshot`] when present.
pub fn encode_summary(s: &ServeSummary) -> Json {
    obj(vec![
        ("requests", ju64(s.requests)),
        ("functional", ju64(s.functional)),
        ("verified_ok", ju64(s.verified_ok)),
        ("verified_failed", ju64(s.verified_failed)),
        ("errors", ju64(s.errors)),
        ("prescheduled", ju64(s.prescheduled)),
        ("coalesced_batches", ju64(s.coalesced_batches)),
        ("max_batch", ju64(s.max_batch)),
        ("coalesce_window_us", ju64(s.coalesce_window_us)),
        (
            "shards",
            match &s.shards {
                Some(rs) => Json::Arr(rs.shards.iter().map(encode_shard_telemetry).collect()),
                None => Json::Null,
            },
        ),
        (
            "net",
            match s.shards.as_ref().and_then(|rs| rs.net.as_ref()) {
                Some(g) => encode_net_gauges(g),
                None => Json::Null,
            },
        ),
        ("wall_seconds", Json::Num(s.wall_seconds)),
        ("throughput_rps", Json::Num(s.throughput_rps)),
        ("total_sim_cycles", ju64(s.total_sim_cycles)),
        ("metrics", encode_snapshot(&s.metrics)),
    ])
}

pub fn decode_summary(j: &Json) -> Result<ServeSummary> {
    let mut shards = match j.get("shards") {
        None | Some(Json::Null) => None,
        Some(Json::Arr(items)) => Some(RackSnapshot::from_shards(
            items.iter().map(decode_shard_telemetry).collect::<Result<_>>()?,
        )),
        Some(_) => bail!("shards is neither null nor an array"),
    };
    // Optional network gauges (absent/null from pre-v3 or in-process
    // summaries — tolerated for compatibility in both directions).
    if let (Some(rs), Some(g @ Json::Obj(_))) = (shards.as_mut(), j.get("net")) {
        rs.net = Some(decode_net_gauges(g)?);
    }
    Ok(ServeSummary {
        requests: get_u64(j, "requests")?,
        functional: get_u64(j, "functional")?,
        verified_ok: get_u64(j, "verified_ok")?,
        verified_failed: get_u64(j, "verified_failed")?,
        errors: get_u64(j, "errors")?,
        prescheduled: get_u64(j, "prescheduled")?,
        coalesced_batches: get_u64(j, "coalesced_batches")?,
        max_batch: get_u64(j, "max_batch")?,
        coalesce_window_us: get_u64(j, "coalesce_window_us")?,
        shards,
        wall_seconds: get_f64(j, "wall_seconds")?,
        throughput_rps: get_f64(j, "throughput_rps")?,
        total_sim_cycles: get_u64(j, "total_sim_cycles")?,
        metrics: decode_snapshot(j.get("metrics").ok_or_else(|| anyhow!("summary without metrics"))?)?,
    })
}

/// Encode a live [`RackSnapshot`] — the v3 `Stats` frame's body. Only
/// the per-shard telemetry and optional net gauges travel: the decoder
/// re-derives the aggregate from the shards, and because every shard
/// snapshot carries its exact histograms the re-derived aggregate
/// percentiles equal the sender's (see `RackSnapshot::absorb`).
pub fn encode_stats(rs: &RackSnapshot) -> Json {
    obj(vec![
        ("schema", Json::Str("gta.stats/1".into())),
        ("shards", Json::Arr(rs.shards.iter().map(encode_shard_telemetry).collect())),
        (
            "net",
            match &rs.net {
                Some(g) => encode_net_gauges(g),
                None => Json::Null,
            },
        ),
    ])
}

pub fn decode_stats(j: &Json) -> Result<RackSnapshot> {
    let shards = match j.get("shards") {
        Some(Json::Arr(items)) => {
            items.iter().map(decode_shard_telemetry).collect::<Result<Vec<_>>>()?
        }
        _ => bail!("stats without a shards array"),
    };
    let mut rs = RackSnapshot::from_shards(shards);
    if let Some(g @ Json::Obj(_)) = j.get("net") {
        rs.net = Some(decode_net_gauges(g)?);
    }
    Ok(rs)
}

// ---------------------------------------------------------------------
// Small body builders shared by server and client.

/// `Hello` body a client opens with, announcing the newest protocol it
/// speaks. The server answers with `min(client, server)` — see
/// [`negotiate`].
pub fn client_hello() -> Json {
    client_hello_v(PROTO_VERSION)
}

/// [`client_hello`] pinned to an explicit maximum version (a v1-forced
/// client sends `client_hello_v(1)` and gets exactly the PR 5 wire
/// behavior back).
pub fn client_hello_v(max_proto: u64) -> Json {
    obj(vec![("proto", ju64(max_proto)), ("client", Json::Str("gta".into()))])
}

/// `Hello` body the server answers with; `proto` is the negotiated
/// version the connection will speak.
pub fn server_hello(proto: u64, shards: usize, policy: &str) -> Json {
    obj(vec![
        ("proto", ju64(proto)),
        ("shards", Json::Num(shards as f64)),
        ("policy", Json::Str(policy.into())),
    ])
}

/// Protocol version carried by a `Hello` body.
pub fn hello_proto(body: &Json) -> Option<u64> {
    get_u64(body, "proto").ok()
}

/// `Busy` frame body: the shard the router had picked (if any).
pub fn busy_body(shard: Option<usize>) -> Json {
    obj(vec![(
        "shard",
        match shard {
            Some(s) => Json::Num(s as f64),
            None => Json::Null,
        },
    )])
}

/// Shard carried by a `Busy` body (out-of-range values read as absent,
/// never wrapped).
pub fn busy_shard(body: &Json) -> Option<usize> {
    get_u64(body, "shard").ok().and_then(|s| usize::try_from(s).ok())
}

/// `Error` frame body.
pub fn error_body(message: &str, fatal: bool) -> Json {
    obj(vec![("message", Json::Str(message.into())), ("fatal", Json::Bool(fatal))])
}

/// Message carried by an `Error` body.
pub fn error_message(body: &Json) -> String {
    body.get("message").and_then(Json::as_str).unwrap_or("unspecified protocol error").to_string()
}

/// `Drained` ack body: how many unconsumed responses the drain returned.
pub fn drained_body(returned: u64) -> Json {
    obj(vec![("returned", ju64(returned))])
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::serve::gemm_tile_request;

    fn round_trip(frame: &Frame) -> Frame {
        let mut buf = Vec::new();
        write_frame(&mut buf, frame).unwrap();
        let mut r = &buf[..];
        let out = read_frame(&mut r).unwrap();
        assert!(r.is_empty(), "decoder consumed the exact frame");
        out
    }

    #[test]
    fn frames_round_trip_for_every_type() {
        for (ty, id, body) in [
            (FrameType::Hello, 0u64, client_hello()),
            (FrameType::Submit, 7, encode_request(&gemm_tile_request(7, "mpra_gemm_i8_64", 3))),
            (FrameType::Response, 9, Json::Num(1.0)),
            (FrameType::Busy, u64::MAX, busy_body(Some(3))),
            (FrameType::Drained, 0, drained_body(12)),
            (FrameType::Closed, 0, Json::Null),
            (FrameType::Error, 1 << 60, error_body("boom", true)),
        ] {
            let f = Frame::new(ty, id, body);
            assert_eq!(round_trip(&f), f);
        }
    }

    #[test]
    fn request_and_response_bodies_round_trip() {
        let req = gemm_tile_request(42, "mpra_gemm_i8_64", 17);
        let back = decode_request(&encode_request(&req)).unwrap();
        assert_eq!(back.id, req.id);
        assert_eq!(back.op, req.op);
        match (&back.exec, &req.exec) {
            (
                ExecKind::Functional { artifact: a1, inputs: i1 },
                ExecKind::Functional { artifact: a2, inputs: i2 },
            ) => {
                assert_eq!(a1, a2);
                assert_eq!(i1, i2);
            }
            _ => panic!("exec kind diverged"),
        }

        let sim = SimReport {
            cycles: (1 << 60) + 3, // beyond 2^53: string-encoded, still exact
            freq_mhz: 1000,
            sram_bytes: 12345,
            dram_bytes: 678,
            macs: 262144,
            utilization: 0.875,
            energy_pj: 1.5e9,
        };
        let resp = Response {
            id: 42,
            shard: 1,
            schedule: Some(Candidate {
                config: ScheduleConfig {
                    arrangement: Arrangement::new(4, 4),
                    dataflow: Dataflow::OS,
                    k_segments: 2,
                    tile_dir: crate::scheduler::pattern::TileDir::Vertical,
                },
                report: sim,
                coverage: None,
            }),
            sim,
            outputs: Some(vec![
                HostTensor::I32(vec![-5, 0, 7]),
                HostTensor::I64(vec![i64::MIN, -1, i64::MAX]),
                HostTensor::F32(vec![0.1, -3.5e7]),
            ]),
            error: Some("partly cloudy".into()),
            latency: Duration::from_micros(321),
        };
        let back = decode_response(&encode_response(&resp)).unwrap();
        assert_eq!(back.id, resp.id);
        assert_eq!(back.shard, resp.shard);
        assert_eq!(back.sim, resp.sim);
        assert_eq!(back.outputs, resp.outputs);
        assert_eq!(back.error, resp.error);
        assert_eq!(back.latency, resp.latency);
        assert_eq!(back.schedule.map(|c| c.config), resp.schedule.map(|c| c.config));
    }

    #[test]
    fn non_finite_f32_tensor_elements_survive_the_wire() {
        let t = HostTensor::F32(vec![1.5, f32::NAN, f32::INFINITY, f32::NEG_INFINITY, -0.25]);
        let back = decode_tensor(&encode_tensor(&t)).unwrap();
        let got = match back {
            HostTensor::F32(v) => v,
            other => panic!("dtype diverged: {other:?}"),
        };
        assert_eq!(got[0], 1.5);
        assert!(got[1].is_nan(), "NaN crosses as NaN, not a fatal null");
        assert_eq!(got[2], f32::INFINITY);
        assert_eq!(got[3], f32::NEG_INFINITY);
        assert_eq!(got[4], -0.25);
    }

    #[test]
    fn oversized_truncated_and_garbage_frames_fail_cleanly() {
        // oversized length prefix: rejected before any allocation
        let mut buf = Vec::new();
        buf.extend_from_slice(&(((MAX_BODY_BYTES + HEADER_AFTER_LEN) as u32) + 1).to_be_bytes());
        buf.extend_from_slice(&[FrameType::Hello.code()]);
        buf.extend_from_slice(&0u64.to_be_bytes());
        assert!(matches!(read_frame(&mut &buf[..]), Err(DecodeError::Malformed(_))));

        // every strict prefix of a valid frame is Malformed (or Eof at 0)
        let mut full = Vec::new();
        write_frame(&mut full, &Frame::new(FrameType::Error, 5, error_body("x", false))).unwrap();
        assert!(matches!(read_frame(&mut &full[..0]), Err(DecodeError::Eof)));
        for cut in 1..full.len() {
            match read_frame(&mut &full[..cut]) {
                Err(DecodeError::Malformed(_)) => {}
                other => panic!("prefix of {cut} bytes: {other:?}"),
            }
        }

        // unknown type byte and non-JSON body
        let mut bad_ty = full.clone();
        bad_ty[4] = 200;
        assert!(matches!(read_frame(&mut &bad_ty[..]), Err(DecodeError::Malformed(_))));
        let mut bad_json = Vec::new();
        let body = b"{not json";
        bad_json.extend_from_slice(&((HEADER_AFTER_LEN + body.len()) as u32).to_be_bytes());
        bad_json.push(FrameType::Hello.code());
        bad_json.extend_from_slice(&0u64.to_be_bytes());
        bad_json.extend_from_slice(body);
        assert!(matches!(read_frame(&mut &bad_json[..]), Err(DecodeError::Malformed(_))));
    }

    #[test]
    fn summary_round_trips_with_rack_snapshot() {
        use crate::coordinator::CoalesceConfig;
        use crate::serve::{mixed_stream, run_stream_rack, soft_rack};
        let rack = soft_rack(
            vec![crate::GtaConfig::lanes16(), crate::GtaConfig::with_lanes(4)],
            CoalesceConfig::default(),
            crate::coordinator::rack::policy_by_name("rr").unwrap(),
        )
        .unwrap();
        let (reqs, expected) = mixed_stream(16);
        let summary = run_stream_rack(&rack, reqs, &expected, 4);
        let back = decode_summary(&encode_summary(&summary)).unwrap();
        assert_eq!(back.requests, summary.requests);
        assert_eq!(back.total_sim_cycles, summary.total_sim_cycles);
        assert_eq!(back.metrics.requests, summary.metrics.requests);
        assert_eq!(back.metrics.batch_hist, summary.metrics.batch_hist);
        assert_eq!(back.metrics.per_artifact, summary.metrics.per_artifact);
        let (a, b) = (back.shards.unwrap(), summary.shards.unwrap());
        assert_eq!(a.shards.len(), b.shards.len());
        for (x, y) in a.shards.iter().zip(&b.shards) {
            assert_eq!(x.shard, y.shard);
            assert_eq!(x.config_fingerprint, y.config_fingerprint);
            assert_eq!(x.routed, y.routed);
            assert_eq!(x.snapshot.sim_cycles, y.snapshot.sim_cycles);
        }
        // the re-aggregated rollup matches the original aggregate
        assert_eq!(a.aggregate.requests, b.aggregate.requests);
        assert_eq!(a.aggregate.sim_cycles, b.aggregate.sim_cycles);
    }

    #[test]
    fn negotiation_settles_on_the_lower_version_and_refuses_below_min() {
        assert_eq!(negotiate(1, PROTO_VERSION), Some(1)); // v1 client, v2 server
        assert_eq!(negotiate(PROTO_VERSION, PROTO_VERSION), Some(PROTO_VERSION));
        assert_eq!(negotiate(99, PROTO_VERSION), Some(PROTO_VERSION)); // future client
        assert_eq!(negotiate(PROTO_VERSION, 1), Some(1)); // v1-capped server
        assert_eq!(negotiate(0, PROTO_VERSION), None); // pre-protocol peer
    }

    #[test]
    fn binary_frames_round_trip_verbatim() {
        for (ty, id, bin) in [
            (FrameType::SubmitBin, 7u64, vec![1u8, 2, 3, 0, 255]),
            (FrameType::ResponseBin, u64::MAX, Vec::new()),
        ] {
            let f = Frame::binary(ty, id, bin);
            assert_eq!(round_trip(&f), f);
        }
    }

    #[test]
    fn binary_request_round_trips_and_matches_the_json_decode() {
        let req = gemm_tile_request(42, "mpra_gemm_i8_64", 17);
        let back = decode_request_bin(req.id, &encode_request_bin(&req)).unwrap();
        assert_eq!(back.id, req.id);
        assert_eq!(back.op, req.op);
        match (&back.exec, &req.exec) {
            (
                ExecKind::Functional { artifact: a1, inputs: i1 },
                ExecKind::Functional { artifact: a2, inputs: i2 },
            ) => {
                assert_eq!(a1, a2);
                assert_eq!(i1, i2);
            }
            _ => panic!("exec kind diverged"),
        }
        // a simulate-only request (no tensors) round-trips too
        let sim_only = Request {
            id: 9,
            op: TensorOp::Vector(VectorOp::new(1024, Precision::Fp32, VectorKind::Reduce)),
            exec: ExecKind::Simulate,
        };
        let back = decode_request_bin(9, &encode_request_bin(&sim_only)).unwrap();
        assert_eq!(back.op, sim_only.op);
        assert!(matches!(back.exec, ExecKind::Simulate));
    }

    #[test]
    fn binary_response_round_trips_with_exact_tensor_bits() {
        let sim = SimReport {
            cycles: (1 << 60) + 3,
            freq_mhz: 1000,
            sram_bytes: 12345,
            dram_bytes: 678,
            macs: 262144,
            utilization: 0.875,
            energy_pj: 1.5e9,
        };
        // a NaN with a non-canonical payload: v1's JSON path flattens
        // this to null, the v2 binary path must carry the exact bits
        let odd_nan = f32::from_bits(0x7fc0_1234);
        let resp = Response {
            id: 42,
            shard: 1,
            schedule: None,
            sim,
            outputs: Some(vec![
                HostTensor::I32(vec![i32::MIN, -5, 0, 7, i32::MAX]),
                HostTensor::I64(vec![i64::MIN, -1, i64::MAX]),
                HostTensor::F32(vec![0.1, -3.5e7, odd_nan, f32::NEG_INFINITY, -0.0]),
            ]),
            error: Some("partly cloudy".into()),
            latency: Duration::from_micros(321),
        };
        let back = decode_response_bin(&encode_response_bin(&resp)).unwrap();
        assert_eq!(back.id, resp.id);
        assert_eq!(back.shard, resp.shard);
        assert_eq!(back.sim, resp.sim);
        assert_eq!(back.error, resp.error);
        assert_eq!(back.latency, resp.latency);
        let outs = back.outputs.unwrap();
        assert_eq!(outs[0], HostTensor::I32(vec![i32::MIN, -5, 0, 7, i32::MAX]));
        assert_eq!(outs[1], HostTensor::I64(vec![i64::MIN, -1, i64::MAX]));
        let HostTensor::F32(f) = &outs[2] else { panic!("dtype diverged") };
        assert_eq!(f[2].to_bits(), odd_nan.to_bits(), "NaN payload bits preserved");
        assert_eq!(f[4].to_bits(), (-0.0f32).to_bits(), "signed zero preserved");

        // outputs: None survives
        let bare = Response { outputs: None, ..resp };
        let back = decode_response_bin(&encode_response_bin(&bare)).unwrap();
        assert!(back.outputs.is_none());
    }

    #[test]
    fn binary_decoders_reject_hostile_bodies_cleanly() {
        let req = gemm_tile_request(3, "mpra_gemm_i8_64", 5);
        let good = encode_request_bin(&req);
        // every strict prefix is an error, never a panic
        for cut in 0..good.len() {
            assert!(decode_request_bin(3, &good[..cut]).is_err(), "prefix of {cut} bytes");
        }
        // trailing bytes are malformed
        let mut padded = good.clone();
        padded.push(0);
        assert!(decode_request_bin(3, &padded).is_err());
        // an element count far beyond the body must error before
        // allocating, not wrap or OOM
        let mut huge = Vec::new();
        huge.push(OP_VECTOR);
        huge.push(precision_code(Precision::Fp32));
        put_u64(&mut huge, 8);
        huge.push(vector_kind_code(VectorKind::Map));
        huge.push(EXEC_FUNCTIONAL);
        put_u32(&mut huge, 0); // empty artifact name
        put_u32(&mut huge, 1); // one tensor...
        huge.push(DT_F32);
        put_u64(&mut huge, u64::MAX); // ...claiming 2^64-1 elements
        assert!(decode_request_bin(1, &huge).is_err());
        // unknown dtype / op / exec tags
        for (pos, bad) in [(0usize, 99u8)] {
            let mut b = good.clone();
            b[pos] = bad;
            assert!(decode_request_bin(3, &b).is_err());
        }
        let resp_good = {
            let resp = Response {
                id: 1,
                shard: 0,
                schedule: None,
                sim: SimReport::default(),
                outputs: Some(vec![HostTensor::I32(vec![1, 2, 3])]),
                error: None,
                latency: Duration::from_micros(1),
            };
            encode_response_bin(&resp)
        };
        for cut in 0..resp_good.len() {
            assert!(decode_response_bin(&resp_good[..cut]).is_err(), "prefix of {cut} bytes");
        }
    }

    #[test]
    fn out_of_range_integers_are_rejected_not_wrapped() {
        // a u64 that would wrap to a small u32 if cast with `as`
        let big = (1u64 << 32) + 4;
        let sim = obj(vec![
            ("cycles", ju64(1)),
            ("freq_mhz", ju64(big)),
            ("sram_bytes", ju64(0)),
            ("dram_bytes", ju64(0)),
            ("macs", ju64(0)),
            ("utilization", Json::Num(0.0)),
            ("energy_pj", Json::Num(0.0)),
        ]);
        let err = decode_sim(&sim).unwrap_err().to_string();
        assert!(err.contains("freq_mhz"), "names the offending field: {err}");

        // lane_rows = 2^32 + 4 used to wrap to 4 under `as u32` and
        // smuggle a tiny arrangement into the Rack; now it is refused
        let sched = obj(vec![
            ("dataflow", Json::Str("OS".into())),
            ("lane_rows", Json::Str(format!("{big}"))),
            ("lane_cols", Json::Num(4.0)),
            ("k_segments", ju64(2)),
            ("tile_dir", Json::Str("vertical".into())),
        ]);
        let err = decode_schedule(&sched).unwrap_err().to_string();
        assert!(err.contains("lane_rows"), "names the offending field: {err}");
    }

    fn round_trip_v(frame: &Frame, proto: u64) -> Frame {
        let mut buf = Vec::new();
        write_frame_v(&mut buf, frame, proto).unwrap();
        let mut r = &buf[..];
        let out = read_frame_v(&mut r, proto).unwrap();
        assert!(r.is_empty(), "decoder consumed the exact frame");
        out
    }

    #[test]
    fn v3_frames_round_trip_with_their_session_field() {
        for (ty, session, id) in [
            (FrameType::Submit, 0u32, 7u64),
            (FrameType::Submit, 1, 8),
            (FrameType::Response, u32::MAX, 9),
            (FrameType::OpenSession, 5, 0),
            (FrameType::SessionClosed, 5, 0),
            (FrameType::Drained, 3, 0),
            (FrameType::Busy, 2, u64::MAX),
        ] {
            let f = Frame::new(ty, id, Json::Null).with_session(session);
            let back = round_trip_v(&f, 3);
            assert_eq!(back.session, session);
            assert_eq!(back, f);
        }
        // binary frames carry the session field too
        let f = Frame::binary(FrameType::SubmitBin, 11, vec![1, 2, 3]).with_session(42);
        assert_eq!(round_trip_v(&f, 3), f);
    }

    #[test]
    fn v1_and_v3_header_layouts_differ_by_exactly_the_session_field() {
        let f = Frame::new(FrameType::Drained, 9, Json::Null);
        let (mut v1, mut v3) = (Vec::new(), Vec::new());
        write_frame_v(&mut v1, &f, 1).unwrap();
        write_frame_v(&mut v3, &f, 3).unwrap();
        assert_eq!(v3.len(), v1.len() + 4, "v3 adds a 4-byte session field");
        // len prefix reflects the longer header
        assert_eq!(
            u32::from_be_bytes(v3[..4].try_into().unwrap()),
            u32::from_be_bytes(v1[..4].try_into().unwrap()) + 4
        );
        // type byte in the same place; session zero sits between it and
        // the id, which is bitwise identical after the shift
        assert_eq!(v1[4], v3[4]);
        assert_eq!(&v3[5..9], &[0u8; 4], "session 0");
        assert_eq!(&v1[5..13], &v3[9..17], "id bytes shifted by the session field");
        // a v1-layout frame read as v3 misparses into a clean error or a
        // different frame — never a panic (here: 9-byte header claims
        // less than the 13 bytes a v3 header needs)
        assert!(matches!(read_frame_v(&mut &v1[..], 3), Err(DecodeError::Malformed(_))));
        // writing a nonzero session needs a v3 connection: the v1/v2
        // layouts simply have no place for it
        let s = Frame::new(FrameType::Submit, 1, Json::Null).with_session(7);
        let mut buf = Vec::new();
        write_frame_v(&mut buf, &s, 3).unwrap();
        let back = read_frame_v(&mut &buf[..], 3).unwrap();
        assert_eq!(back.session, 7);
    }

    #[test]
    fn frame_from_slice_decodes_incrementally_and_agrees_with_read_frame() {
        for proto in [1u64, 2, 3] {
            let frames = [
                Frame::new(FrameType::Hello, 0, client_hello()),
                Frame::binary(FrameType::SubmitBin, 7, vec![9u8; 100])
                    .with_session(if proto >= 3 { 3 } else { 0 }),
                Frame::new(FrameType::Drained, 0, drained_body(2)),
            ];
            let mut wire = Vec::new();
            for f in &frames {
                write_frame_v(&mut wire, f, proto).unwrap();
            }
            // whole-buffer walk consumes frame-for-frame
            let mut off = 0;
            for f in &frames {
                let (got, used) = frame_from_slice(&wire[off..], proto).unwrap().unwrap();
                assert_eq!(&got, f);
                let mut r = &wire[off..off + used];
                assert_eq!(read_frame_v(&mut r, proto).unwrap(), *f, "agrees with read_frame_v");
                off += used;
            }
            assert_eq!(off, wire.len());
            // every strict prefix of the first frame is "incomplete",
            // never an error or a panic
            let first_len = {
                let (_, used) = frame_from_slice(&wire, proto).unwrap().unwrap();
                used
            };
            for cut in 0..first_len {
                assert!(
                    frame_from_slice(&wire[..cut], proto).unwrap().is_none(),
                    "prefix of {cut} bytes is incomplete, not an error"
                );
            }
        }
        // the oversized-length guard fires from the prefix alone,
        // without waiting for the (never-arriving) body
        let mut huge = Vec::new();
        huge.extend_from_slice(&(((MAX_BODY_BYTES + HEADER_AFTER_LEN_V3) as u32) + 1).to_be_bytes());
        huge.push(FrameType::Submit.code());
        assert!(matches!(frame_from_slice(&huge, 3), Err(DecodeError::Malformed(_))));
    }

    #[test]
    fn summary_net_gauges_round_trip_and_stay_optional() {
        use crate::coordinator::CoalesceConfig;
        use crate::serve::{mixed_stream, run_stream_rack, soft_rack};
        let rack = soft_rack(
            vec![crate::GtaConfig::with_lanes(4)],
            CoalesceConfig::default(),
            crate::coordinator::rack::policy_by_name("rr").unwrap(),
        )
        .unwrap();
        let (reqs, expected) = mixed_stream(4);
        let mut summary = run_stream_rack(&rack, reqs, &expected, 2);
        // absent gauges stay absent through the codec
        let back = decode_summary(&encode_summary(&summary)).unwrap();
        assert!(back.shards.unwrap().net.is_none());
        // attached gauges round-trip exactly
        let gauges = crate::coordinator::NetGauges {
            active_connections: 3,
            active_sessions: 1000,
            bytes_in: u64::MAX,
            bytes_out: 1 << 40,
        };
        summary.shards.as_mut().unwrap().net = Some(gauges);
        let back = decode_summary(&encode_summary(&summary)).unwrap();
        assert_eq!(back.shards.unwrap().net, Some(gauges));
    }
}
