//! The TCP serving front-end: a [`NetServer`] binds a
//! `std::net::TcpListener` and gives every accepted connection its own
//! [`RackSession`] against ONE shared [`Rack`] — the session-native
//! transport the ROADMAP asked for, with zero new dependencies.
//!
//! Per connection, two threads split the session exactly along its
//! `&self` API:
//!
//! * the **reader** (the connection thread) decodes frames off the
//!   socket and submits — routing therefore happens in wire order on
//!   one thread, so deterministic policies stay deterministic per
//!   connection; an `AdmitError::Busy` becomes a wire-level `Busy`
//!   frame, so admission backpressure reaches the client instead of
//!   dying inside the server (and under `AdmissionPolicy::Block` the
//!   reader itself stalls, which backpressures the socket the TCP way);
//! * the **writer** pumps [`RackSession::recv_timeout`] completions
//!   back as `Response` frames **as they finish, out of submission
//!   order** — the same out-of-order egress the in-process session
//!   gives.
//!
//! Disconnect — graceful (`Closed`), protocol violation, or the peer
//! vanishing mid-stream — always takes the same exit: the session is
//! drained (every queued and in-flight request still executes, so rack
//! metrics/telemetry never lose work) and closed. On a graceful close
//! the final [`crate::serve::ServeSummary`] (with its `RackSnapshot`)
//! travels back in the `Closed` frame. See `docs/transport.md`.

use super::poll::{poll_wait, PollFd, Waker, POLL_IN, POLL_OUT};
use super::proto::{
    busy_body, drained_body, error_body, error_message, frame_from_slice, negotiate, read_frame,
    read_frame_v, server_hello, write_frame, write_frame_v, DecodeError, Frame, FrameType,
    MIN_PROTO_VERSION, PROTO_VERSION,
};
use crate::coordinator::{
    AdmissionPolicy, AdmitError, NetGauges, Rack, RackSession, Response, ServeOptions, SubmitError,
    WorkerPool,
};
use crate::obs;
use crate::util::json::Json;
use std::collections::HashMap;
use std::io::{BufReader, BufWriter, Read, Write};
use std::net::{Shutdown, SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Duration;

/// How long the egress pump waits on the completion channel before
/// re-checking whether the session closed under it.
const PUMP_TICK: Duration = Duration::from_millis(20);

/// Shared, lock-guarded frame writer: the reader (Busy/Error/acks) and
/// the pump (Responses) interleave whole frames, never bytes.
type SharedWriter = Arc<Mutex<BufWriter<TcpStream>>>;

/// Lock the shared writer, treating poison as a dead connection. A
/// poisoned mutex means the other writer thread panicked mid-frame, so
/// framing on this socket can no longer be trusted — but that is a
/// *disconnect* for this connection, never a cascading panic: the
/// caller sees an `Err`, stops writing, and the session still drains.
fn lock_writer(w: &SharedWriter) -> std::io::Result<std::sync::MutexGuard<'_, BufWriter<TcpStream>>> {
    w.lock().map_err(|_| {
        std::io::Error::new(
            std::io::ErrorKind::BrokenPipe,
            "frame writer poisoned by a peer thread panic",
        )
    })
}

fn send_frame(w: &SharedWriter, proto: u64, ty: FrameType, id: u64, body: Json) -> std::io::Result<()> {
    let mut guard = lock_writer(w)?;
    write_frame_v(&mut *guard, &Frame::new(ty, id, body), proto)?;
    guard.flush()
}

/// Build the [`Response`] frame for the connection's negotiated
/// encoding: a binary `ResponseBin` frame on ≥v2, the v1 JSON
/// `Response` frame otherwise.
fn response_frame(proto: u64, session: u32, resp: &Response) -> Frame {
    let frame = if proto >= 2 {
        Frame::binary(FrameType::ResponseBin, resp.id, super::proto::encode_response_bin(resp))
    } else {
        Frame::new(FrameType::Response, resp.id, super::proto::encode_response(resp))
    };
    frame.with_session(session)
}

fn send_response(w: &SharedWriter, proto: u64, resp: &Response) -> std::io::Result<()> {
    let write_start = obs::now_us();
    let frame = response_frame(proto, 0, resp);
    let mut guard = lock_writer(w)?;
    write_frame_v(&mut *guard, &frame, proto)?;
    guard.flush()?;
    obs::emit(&obs::SpanEvent {
        trace_id: resp.id,
        stage: obs::Stage::NetWrite,
        shard: obs::NO_SHARD,
        start_us: write_start,
        dur_us: obs::now_us().saturating_sub(write_start),
        extra: frame.bin.len() as u64,
    });
    Ok(())
}

/// A listening GTA server. Dropping it stops accepting new connections;
/// live connections keep their sessions until their clients disconnect.
pub struct NetServer {
    addr: SocketAddr,
    stop: Arc<AtomicBool>,
    accept: Option<std::thread::JoinHandle<()>>,
}

impl NetServer {
    /// Bind `addr` (e.g. `"127.0.0.1:0"` for an ephemeral port) and
    /// start accepting connections, each served by its own session over
    /// `rack` opened with `opts`. Serves every protocol version up to
    /// [`PROTO_VERSION`].
    pub fn spawn(rack: Arc<Rack>, addr: &str, opts: ServeOptions) -> anyhow::Result<NetServer> {
        NetServer::spawn_proto(rack, addr, opts, PROTO_VERSION)
    }

    /// [`spawn`](Self::spawn) with an explicit cap on the protocol
    /// version this server will negotiate — `spawn_proto(.., 1)` is a
    /// pure-v1 server (the PR 5 wire behavior), useful for replaying
    /// compatibility baselines.
    pub fn spawn_proto(
        rack: Arc<Rack>,
        addr: &str,
        opts: ServeOptions,
        max_proto: u64,
    ) -> anyhow::Result<NetServer> {
        anyhow::ensure!(
            (MIN_PROTO_VERSION..=PROTO_VERSION).contains(&max_proto),
            "this build speaks protocol versions {MIN_PROTO_VERSION}..={PROTO_VERSION}, not {max_proto}"
        );
        let listener = TcpListener::bind(addr)?;
        let local = listener.local_addr()?;
        // non-blocking accept so shutdown() can stop the loop without a
        // wake-up connection
        listener.set_nonblocking(true)?;
        let stop = Arc::new(AtomicBool::new(false));
        let stop_flag = Arc::clone(&stop);
        let accept = std::thread::Builder::new()
            .name("gta-net-accept".into())
            .spawn(move || {
                let mut conns: Vec<std::thread::JoinHandle<()>> = Vec::new();
                let mut conn_id = 0usize;
                while !stop_flag.load(Ordering::Relaxed) {
                    match listener.accept() {
                        Ok((stream, _peer)) => {
                            conn_id += 1;
                            let rack = Arc::clone(&rack);
                            // pre-clone so a failed spawn can still
                            // tell the client before dropping it
                            let refusal = stream.try_clone().ok();
                            let spawned = std::thread::Builder::new()
                                .name(format!("gta-net-conn-{conn_id}"))
                                .spawn(move || {
                                    let _ = handle_connection(stream, rack, opts, max_proto);
                                });
                            match spawned {
                                Ok(h) => {
                                    conns.push(h);
                                    conns.retain(|h| !h.is_finished());
                                }
                                Err(e) => {
                                    // OS out of threads: fail this one
                                    // connection, keep accepting
                                    eprintln!(
                                        "gta-net: connection thread spawn failed \
                                         (refusing connection {conn_id}): {e}"
                                    );
                                    if let Some(s) = refusal {
                                        let mut w = BufWriter::new(s);
                                        let body = error_body(
                                            "server cannot take this connection right now \
                                             (thread spawn failed); retry later",
                                            true,
                                        );
                                        let _ = write_frame(
                                            &mut w,
                                            &Frame::new(FrameType::Error, 0, body),
                                        );
                                        let _ = w.flush();
                                    }
                                }
                            }
                        }
                        Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                            std::thread::sleep(Duration::from_millis(2));
                        }
                        Err(_) => break,
                    }
                }
                // join whatever already finished; a connection still
                // held open by its client outlives the accept loop and
                // cleans itself up on disconnect
                for h in conns.into_iter().filter(|h| h.is_finished()) {
                    let _ = h.join();
                }
            })?;
        Ok(NetServer { addr: local, stop, accept: Some(accept) })
    }

    /// The bound address (resolves the ephemeral port of `":0"` binds).
    pub fn addr(&self) -> SocketAddr {
        self.addr
    }

    /// Stop accepting new connections and join the accept loop.
    pub fn shutdown(&mut self) {
        self.stop.store(true, Ordering::Relaxed);
        if let Some(h) = self.accept.take() {
            let _ = h.join();
        }
    }

    /// Block until the accept loop exits (it runs until
    /// [`shutdown`](Self::shutdown) — this is `gta serve --listen`'s
    /// foreground wait).
    pub fn join(&mut self) {
        if let Some(h) = self.accept.take() {
            let _ = h.join();
        }
    }
}

impl Drop for NetServer {
    fn drop(&mut self) {
        self.shutdown();
    }
}

/// Why the ingest loop stopped reading.
enum Exit {
    /// Client asked to close; send the final summary.
    Close,
    /// Peer vanished (EOF / transport error): silent cleanup.
    Disconnect,
    /// Protocol violation: tell the peer (best effort), then drop the
    /// connection — framing can no longer be trusted.
    Fatal(String),
}

/// Serve one connection to completion. All exits drain the session.
fn handle_connection(
    stream: TcpStream,
    rack: Arc<Rack>,
    opts: ServeOptions,
    max_proto: u64,
) -> anyhow::Result<()> {
    stream.set_nodelay(true).ok();
    let mut reader = BufReader::new(stream.try_clone()?);
    let writer: SharedWriter = Arc::new(Mutex::new(BufWriter::new(stream.try_clone()?)));

    // ---- version negotiation: Hello must be the first frame. The
    // client announces the newest version it speaks; the connection
    // runs at min(client, server), refusing only peers below
    // MIN_PROTO_VERSION.
    let proto = match read_frame(&mut reader) {
        Ok(f) if f.ty == FrameType::Hello => {
            match super::proto::hello_proto(&f.body).and_then(|peer| negotiate(peer, max_proto)) {
                Some(v) => v,
                None => {
                    let _ = send_frame(
                        &writer,
                        1,
                        FrameType::Error,
                        0,
                        error_body(
                            &format!(
                                "unsupported protocol version \
                                 (server speaks {MIN_PROTO_VERSION}..={max_proto})"
                            ),
                            true,
                        ),
                    );
                    let _ = stream.shutdown(Shutdown::Both);
                    return Ok(());
                }
            }
        }
        Ok(f) => {
            let _ = send_frame(
                &writer,
                1,
                FrameType::Error,
                0,
                error_body(&format!("expected Hello, got {:?}", f.ty), true),
            );
            let _ = stream.shutdown(Shutdown::Both);
            return Ok(());
        }
        Err(e) => {
            let _ = send_frame(&writer, 1, FrameType::Error, 0, error_body(&e.to_string(), true));
            let _ = stream.shutdown(Shutdown::Both);
            return Ok(());
        }
    };
    // the Hello exchange always travels in the v1 header layout (the
    // version is unknown until it completes); both sides switch to the
    // negotiated layout from the NEXT frame on
    send_frame(&writer, 1, FrameType::Hello, 0, server_hello(proto, rack.len(), rack.policy_name()))?;

    let session: Arc<RackSession> = Arc::new(rack.open_session(opts));

    // ---- egress pump: completions -> Response frames, out of order
    let pump_spawn = {
        let session = Arc::clone(&session);
        let writer = Arc::clone(&writer);
        std::thread::Builder::new().name("gta-net-pump".into()).spawn(move || {
            loop {
                match session.recv_timeout(PUMP_TICK) {
                    Some(resp) => {
                        if send_response(&writer, proto, &resp).is_err() {
                            // peer gone: stop writing; the reader
                            // will notice and drain
                            break;
                        }
                    }
                    None => {
                        if session.is_closed() {
                            break;
                        }
                    }
                }
            }
        })
    };
    let mut pump = match pump_spawn {
        Ok(h) => Some(h),
        Err(e) => {
            // OS out of threads: fail only this connection — tell the
            // client, drain the (empty) session so accounting stays
            // consistent, and leave the server accepting.
            eprintln!("gta-net: egress pump spawn failed (closing connection): {e}");
            let _ = send_frame(
                &writer,
                proto,
                FrameType::Error,
                0,
                error_body(
                    "server cannot serve this connection right now \
                     (thread spawn failed); retry later",
                    true,
                ),
            );
            let _ = session.drain();
            let _ = session.close();
            let _ = stream.shutdown(Shutdown::Both);
            return Ok(());
        }
    };

    // Drain the session and hand every remaining response to the wire
    // (unless the socket already failed). Joins the pump first so the
    // follow-up ack frame is provably the last thing sent.
    let drain_to_wire = |pump: &mut Option<std::thread::JoinHandle<()>>| -> u64 {
        let rest = session.drain();
        if let Some(h) = pump.take() {
            let _ = h.join();
        }
        let mut returned = 0u64;
        for resp in &rest {
            if send_response(&writer, proto, resp).is_err() {
                break;
            }
            returned += 1;
        }
        returned
    };

    // ---- ingest loop: this thread owns the socket's read side
    let exit = loop {
        match read_frame_v(&mut reader, proto) {
            Ok(f) => match f.ty {
                FrameType::Submit | FrameType::SubmitBin => {
                    if f.ty == FrameType::SubmitBin && proto < 2 {
                        break Exit::Fatal(format!(
                            "binary Submit on a v{proto} connection (negotiate v2 first)"
                        ));
                    }
                    let decode_start = obs::now_us();
                    let decoded = if f.ty == FrameType::SubmitBin {
                        super::proto::decode_request_bin(f.id, &f.bin)
                    } else {
                        super::proto::decode_request(&f.body).map(|mut req| {
                            // the header id is authoritative
                            req.id = f.id;
                            req
                        })
                    };
                    obs::emit(&obs::SpanEvent {
                        trace_id: f.id,
                        stage: obs::Stage::NetDecode,
                        shard: obs::NO_SHARD,
                        start_us: decode_start,
                        dur_us: obs::now_us().saturating_sub(decode_start),
                        extra: f.bin.len() as u64,
                    });
                    match decoded {
                        Ok(req) => match session.try_submit(req) {
                            Ok(_ticket) => {}
                            Err(SubmitError { id, shard, error: AdmitError::Busy }) => {
                                if send_frame(&writer, proto, FrameType::Busy, id, busy_body(shard))
                                    .is_err()
                                {
                                    break Exit::Disconnect;
                                }
                            }
                            Err(SubmitError { id, error: AdmitError::Closed, .. }) => {
                                let body = error_body("session closed (drained)", false);
                                if send_frame(&writer, proto, FrameType::Error, id, body).is_err() {
                                    break Exit::Disconnect;
                                }
                            }
                        },
                        Err(e) => break Exit::Fatal(format!("undecodable request body: {e:#}")),
                    }
                }
                FrameType::Drained => {
                    // drain request: finish everything, flush it, ack
                    let returned = drain_to_wire(&mut pump);
                    if send_frame(&writer, proto, FrameType::Drained, 0, drained_body(returned))
                        .is_err()
                    {
                        break Exit::Disconnect;
                    }
                    // the session is closed now; later Submits get
                    // per-request Error frames, Closed still answers
                }
                FrameType::Closed => break Exit::Close,
                FrameType::Error => {
                    // client-side abort: log-free silent cleanup
                    let _ = error_message(&f.body);
                    break Exit::Disconnect;
                }
                FrameType::Stats => {
                    if proto < 3 {
                        break Exit::Fatal(format!(
                            "Stats frame on a v{proto} connection (negotiate v3 first)"
                        ));
                    }
                    let snap = rack.snapshot();
                    if send_frame(
                        &writer,
                        proto,
                        FrameType::Stats,
                        f.id,
                        super::proto::encode_stats(&snap),
                    )
                    .is_err()
                    {
                        break Exit::Disconnect;
                    }
                }
                FrameType::OpenSession | FrameType::SessionClosed => {
                    break Exit::Fatal(
                        "multiplexed sessions need the event-loop server \
                         (gta serve --event-loop)"
                            .into(),
                    )
                }
                other => break Exit::Fatal(format!("unexpected {other:?} frame from a client")),
            },
            Err(DecodeError::Eof) => break Exit::Disconnect,
            Err(DecodeError::Io(_)) => break Exit::Disconnect,
            Err(DecodeError::Malformed(m)) => break Exit::Fatal(m),
        }
    };

    // ---- one exit path: drain (work is never lost), then say goodbye
    match exit {
        Exit::Close => {
            let _ = drain_to_wire(&mut pump);
            let summary = session.close();
            let _ = send_frame(
                &writer,
                proto,
                FrameType::Closed,
                0,
                super::proto::encode_summary(&summary),
            );
        }
        Exit::Disconnect => {
            let _ = drain_to_wire(&mut pump);
            let _ = session.close();
        }
        Exit::Fatal(message) => {
            let _ = send_frame(&writer, proto, FrameType::Error, 0, error_body(&message, true));
            let _ = drain_to_wire(&mut pump);
            let _ = session.close();
        }
    }
    let _ = stream.shutdown(Shutdown::Both);
    Ok(())
}

// =====================================================================
// Event-loop server: one poll(2) thread, connections as state machines.
//
// Where [`NetServer`] spends two OS threads per connection, the
// [`EventServer`] drives EVERY connection from one thread over
// non-blocking sockets: per-connection read buffers feed the
// incremental frame decoder ([`frame_from_slice`]), per-connection
// write queues carry encoded frames out with bounded backpressure, and
// a fixed [`WorkerPool`] (sessions opened with
// `Rack::open_session_on`) executes the actual work — so 10k live
// connections cost 10k socket buffers, not 20k threads. Completions
// re-enter the loop through the session notify hook
// ([`RackSession::set_notify`]) + [`Waker`]: the loop never parks in
// `recv_timeout`.
//
// On a ≥v3 connection one socket multiplexes many logical sessions
// (`OpenSession`/`SessionClosed`, the `session` header field); v1/v2
// peers get the exact single-session behavior of the threaded server.

/// Encoded-but-unsent bytes a connection may buffer before the loop
/// stops pumping completions for it (they wait in the session's
/// completion channel instead — bounded by the admission queue).
const MAX_WRITE_BUF: usize = 4 << 20;

/// Per-iteration poll timeout: a pure safety net (every state change
/// arrives via an fd or the waker), kept finite so a lost wakeup can
/// only ever cost one tick, not a hang.
const POLL_TICK_MS: i32 = 100;

/// Default cap on concurrent connections (`gta serve --max-conns`).
pub const DEFAULT_MAX_CONNS: usize = 16_384;

#[cfg(unix)]
fn raw_fd<T: std::os::unix::io::AsRawFd>(s: &T) -> i32 {
    s.as_raw_fd()
}

#[cfg(not(unix))]
fn raw_fd<T>(_s: &T) -> i32 {
    -1
}

/// Live counters the event server maintains; [`NetStats::gauges`]
/// freezes them into the [`NetGauges`] that ride in `RackSnapshot`s.
#[derive(Debug, Default)]
pub struct NetStats {
    active_connections: AtomicU64,
    active_sessions: AtomicU64,
    bytes_in: AtomicU64,
    bytes_out: AtomicU64,
}

impl NetStats {
    pub fn gauges(&self) -> NetGauges {
        NetGauges {
            active_connections: self.active_connections.load(Ordering::Relaxed),
            active_sessions: self.active_sessions.load(Ordering::Relaxed),
            bytes_in: self.bytes_in.load(Ordering::Relaxed),
            bytes_out: self.bytes_out.load(Ordering::Relaxed),
        }
    }
}

/// Lifecycle of one logical session on a connection.
enum SlotState {
    Open,
    /// `Drained` requested: ack (and return to `Open`) once idle.
    Draining,
    /// Summary-bearing close requested (`SessionClosed`, or the
    /// connection-level `Closed` for session 0): answer once idle.
    Goodbye,
    /// Close quietly once idle (disconnect/fatal teardown, or a
    /// non-zero session at connection close) — work still completes
    /// and folds into rack metrics; no ack frame.
    Folding,
}

struct Slot {
    session: Arc<RackSession>,
    state: SlotState,
    /// Responses sent for this session since its `Drained` request —
    /// the count the ack reports.
    drain_returned: u64,
}

/// Connection state machine: handshake → open → draining → closed.
enum ConnPhase {
    /// Before the `Hello` exchange (frames travel in the v1 layout).
    Handshake,
    /// Negotiated and serving.
    Open,
    /// Tearing down: sessions are sealed and finishing. `graceful` =
    /// the client asked (`Closed` frame — responses and the final
    /// summary still go out); otherwise disconnect/protocol violation
    /// (completions are consumed and folded, not sent).
    Draining { graceful: bool },
    /// Goodbye queued; flush the write queue, then drop.
    Closed,
}

struct Conn {
    id: u64,
    stream: TcpStream,
    phase: ConnPhase,
    /// Negotiated protocol version (valid once phase leaves Handshake).
    proto: u64,
    /// Received-but-unparsed bytes.
    rbuf: Vec<u8>,
    /// Encoded frames waiting for the socket, plus the write offset
    /// into the front one and the total buffered byte count.
    wq: std::collections::VecDeque<Vec<u8>>,
    wq_off: usize,
    wq_bytes: usize,
    sessions: HashMap<u32, Slot>,
    /// Read interest dropped: the head-of-buffer `Submit` hit a full
    /// `Block`-policy queue. Cleared (and the buffer re-parsed) when
    /// completions free capacity.
    paused: bool,
    /// Completion pumping stopped at [`MAX_WRITE_BUF`]; resume when
    /// the write queue drains.
    pump_stalled: bool,
    /// The write side failed or the peer vanished: queue nothing more.
    dead_write: bool,
    bytes_in: u64,
    bytes_out: u64,
}

impl Conn {
    fn new(id: u64, stream: TcpStream) -> Conn {
        Conn {
            id,
            stream,
            phase: ConnPhase::Handshake,
            proto: 1,
            rbuf: Vec::new(),
            wq: std::collections::VecDeque::new(),
            wq_off: 0,
            wq_bytes: 0,
            sessions: HashMap::new(),
            paused: false,
            pump_stalled: false,
            dead_write: false,
            bytes_in: 0,
            bytes_out: 0,
        }
    }

    /// The header layout frames on this connection travel in right now:
    /// v1 until the `Hello` exchange completes, the negotiated version
    /// after.
    fn wire_proto(&self) -> u64 {
        if matches!(self.phase, ConnPhase::Handshake) {
            1
        } else {
            self.proto
        }
    }

    fn push_frame(&mut self, frame: &Frame) {
        if self.dead_write {
            return;
        }
        let mut bytes = Vec::new();
        if write_frame_v(&mut bytes, frame, self.wire_proto()).is_err() {
            // only an over-cap body can fail a Vec write: the peer would
            // reject the frame anyway, so kill the write side rather than
            // ship a wrapped length prefix
            self.dead_write = true;
            return;
        }
        self.wq_bytes += bytes.len();
        self.wq.push_back(bytes);
    }

    fn write_backlogged(&self) -> bool {
        self.wq_bytes > MAX_WRITE_BUF
    }

    /// Whether completions still go to the wire (vs. consumed and
    /// folded into metrics only).
    fn forwarding(&self) -> bool {
        !self.dead_write && !matches!(self.phase, ConnPhase::Draining { graceful: false })
    }

    /// Write queued bytes until the socket would block or the queue
    /// empties. `Err` = the write side is gone.
    fn flush_writes(&mut self, stats: &NetStats) -> std::io::Result<()> {
        let write_start = obs::now_us();
        let before = self.bytes_out;
        let res = self.flush_writes_inner(stats);
        if self.bytes_out > before {
            obs::emit(&obs::SpanEvent {
                trace_id: self.id,
                stage: obs::Stage::NetWrite,
                shard: obs::NO_SHARD,
                start_us: write_start,
                dur_us: obs::now_us().saturating_sub(write_start),
                extra: self.bytes_out - before,
            });
        }
        res
    }

    fn flush_writes_inner(&mut self, stats: &NetStats) -> std::io::Result<()> {
        loop {
            let (len, n) = {
                let Some(front) = self.wq.front() else { break };
                match (&self.stream).write(&front[self.wq_off..]) {
                    Ok(0) => {
                        return Err(std::io::Error::new(
                            std::io::ErrorKind::WriteZero,
                            "socket accepted 0 bytes",
                        ))
                    }
                    Ok(n) => (front.len(), n),
                    Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => break,
                    Err(e) if e.kind() == std::io::ErrorKind::Interrupted => continue,
                    Err(e) => return Err(e),
                }
            };
            self.wq_off += n;
            self.bytes_out += n as u64;
            stats.bytes_out.fetch_add(n as u64, Ordering::Relaxed);
            if self.wq_off == len {
                if let Some(done) = self.wq.pop_front() {
                    self.wq_bytes -= done.len();
                }
                self.wq_off = 0;
            }
        }
        Ok(())
    }

    /// Read available bytes into the parse buffer. `Ok(true)` = EOF or
    /// a transport error (the peer is gone).
    fn read_available(&mut self, stats: &NetStats) -> bool {
        let mut chunk = [0u8; 64 * 1024];
        loop {
            match (&self.stream).read(&mut chunk) {
                Ok(0) => return true,
                Ok(n) => {
                    self.rbuf.extend_from_slice(&chunk[..n]);
                    self.bytes_in += n as u64;
                    stats.bytes_in.fetch_add(n as u64, Ordering::Relaxed);
                    // parse what we have before buffering more than the
                    // biggest legal frame
                    if self.rbuf.len() > super::proto::MAX_BODY_BYTES + 64 || n < chunk.len() {
                        return false;
                    }
                }
                Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => return false,
                Err(e) if e.kind() == std::io::ErrorKind::Interrupted => continue,
                Err(_) => return true,
            }
        }
    }
}

/// The event loop proper: owns every connection, runs on one thread.
struct EvLoop {
    rack: Arc<Rack>,
    opts: ServeOptions,
    max_proto: u64,
    max_conns: usize,
    pool: Arc<WorkerPool>,
    listener: TcpListener,
    waker: Arc<Waker>,
    /// (connection, session) pairs with completions to pump, pushed by
    /// worker notify callbacks.
    dirty: Arc<Mutex<Vec<(u64, u32)>>>,
    stats: Arc<NetStats>,
    stop: Arc<AtomicBool>,
    conns: HashMap<u64, Conn>,
    next_conn_id: u64,
}

impl EvLoop {
    fn run(mut self) {
        while !self.stop.load(Ordering::Relaxed) {
            // ---- build the poll set (rebuilt per iteration: simple,
            // and O(conns) is what this loop is everywhere else too)
            // lint: allow(R7) sized by our own connection table, not wire bytes
            let mut fds = Vec::with_capacity(self.conns.len() + 2);
            fds.push(PollFd::new(raw_fd(&self.listener), POLL_IN));
            let waker_slot = self.waker.fd().map(|fd| {
                fds.push(PollFd::new(fd, POLL_IN));
                fds.len() - 1
            });
            // lint: allow(R7) sized by our own connection table, not wire bytes
            let mut slots: Vec<(usize, u64)> = Vec::with_capacity(self.conns.len());
            for (id, c) in &self.conns {
                let mut ev = 0i16;
                if !c.paused && !matches!(c.phase, ConnPhase::Closed) {
                    ev |= POLL_IN;
                }
                if !c.wq.is_empty() && !c.dead_write {
                    ev |= POLL_OUT;
                }
                if ev != 0 {
                    fds.push(PollFd::new(raw_fd(&c.stream), ev));
                    slots.push((fds.len() - 1, *id));
                }
            }
            let _ = poll_wait(&mut fds, POLL_TICK_MS);
            self.waker.drain();
            let _ = waker_slot;
            if self.stop.load(Ordering::Relaxed) {
                break;
            }

            // ---- accept (slot 0 is always the listener, pushed above)
            if fds.first().is_some_and(|f| f.readable()) {
                self.accept_ready();
            }

            // ---- socket reads -> parse -> submit/control
            let readable: Vec<u64> =
                slots.iter().filter(|(i, _)| fds[*i].readable()).map(|(_, id)| *id).collect();
            for id in readable {
                self.service_read(id);
            }

            // ---- completions -> response frames
            // a panicked notifier cannot corrupt a Vec of ids: recover it
            let mut dirty: Vec<(u64, u32)> = self
                .dirty
                .lock()
                .unwrap_or_else(|e| e.into_inner())
                .drain(..)
                .collect();
            dirty.sort_unstable();
            dirty.dedup();
            for (cid, sid) in dirty {
                self.with_conn(cid, |lp, conn| {
                    lp.pump_slot(conn, sid);
                });
            }

            // ---- retry Block-policy-paused connections (completions
            // may have freed admission capacity)
            let paused: Vec<u64> =
                self.conns.iter().filter(|(_, c)| c.paused).map(|(id, _)| *id).collect();
            for id in paused {
                self.with_conn(id, |lp, conn| {
                    conn.paused = false;
                    lp.parse_buffer(conn);
                });
            }

            // ---- flush writes; resume backlog-stalled pumping
            let writable: Vec<u64> = self
                .conns
                .iter()
                .filter(|(_, c)| !c.wq.is_empty() && !c.dead_write)
                .map(|(id, _)| *id)
                .collect();
            for id in writable {
                self.with_conn(id, |lp, conn| {
                    if conn.flush_writes(&lp.stats).is_err() {
                        lp.begin_disconnect(conn);
                    } else if conn.pump_stalled && !conn.write_backlogged() {
                        conn.pump_stalled = false;
                        let sids: Vec<u32> = conn.sessions.keys().copied().collect();
                        for sid in sids {
                            lp.pump_slot(conn, sid);
                        }
                    }
                });
            }

            // ---- reap finished connections
            self.reap();
        }
        self.shutdown_all();
        self.pool.shutdown();
    }

    /// Run `f` on one connection with the loop context borrowable too
    /// (the conn is temporarily taken out of the map).
    fn with_conn(&mut self, id: u64, f: impl FnOnce(&mut EvLoop, &mut Conn)) {
        if let Some(mut conn) = self.conns.remove(&id) {
            f(self, &mut conn);
            self.conns.insert(id, conn);
        }
    }

    fn accept_ready(&mut self) {
        loop {
            match self.listener.accept() {
                Ok((stream, _peer)) => {
                    if self.conns.len() >= self.max_conns {
                        // explicit refusal beats a silent backlog stall
                        let frame = Frame::new(
                            FrameType::Error,
                            0,
                            error_body("server at connection capacity; retry later", true),
                        );
                        let mut bytes = Vec::new();
                        let _ = write_frame(&mut bytes, &frame);
                        let _ = (&stream).write_all(&bytes);
                        let _ = stream.shutdown(Shutdown::Both);
                        continue;
                    }
                    if stream.set_nonblocking(true).is_err() {
                        continue;
                    }
                    stream.set_nodelay(true).ok();
                    let id = self.next_conn_id;
                    self.next_conn_id += 1;
                    self.conns.insert(id, Conn::new(id, stream));
                    self.stats.active_connections.fetch_add(1, Ordering::Relaxed);
                }
                Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => break,
                Err(e) if e.kind() == std::io::ErrorKind::Interrupted => continue,
                Err(_) => break,
            }
        }
    }

    fn service_read(&mut self, id: u64) {
        self.with_conn(id, |lp, conn| {
            let read_start = obs::now_us();
            let before = conn.bytes_in;
            let gone = conn.read_available(&lp.stats);
            if conn.bytes_in > before {
                obs::emit(&obs::SpanEvent {
                    trace_id: conn.id,
                    stage: obs::Stage::NetRead,
                    shard: obs::NO_SHARD,
                    start_us: read_start,
                    dur_us: obs::now_us().saturating_sub(read_start),
                    extra: conn.bytes_in - before,
                });
            }
            lp.parse_buffer(conn);
            if gone && !matches!(conn.phase, ConnPhase::Draining { .. } | ConnPhase::Closed) {
                lp.begin_disconnect(conn);
            }
        });
    }

    /// Decode and handle every complete frame in the read buffer.
    /// Stops early (without consuming) when a `Block`-policy admission
    /// queue is full — that pause, plus TCP flow control filling up
    /// behind the unread socket, IS the backpressure.
    fn parse_buffer(&mut self, conn: &mut Conn) {
        let mut consumed = 0usize;
        let fatal: Option<String> = loop {
            if !matches!(conn.phase, ConnPhase::Handshake | ConnPhase::Open) {
                break None;
            }
            match frame_from_slice(&conn.rbuf[consumed..], conn.wire_proto()) {
                Ok(None) => break None,
                Ok(Some((frame, used))) => {
                    if self.must_pause(conn, &frame) {
                        conn.paused = true;
                        break None;
                    }
                    consumed += used;
                    if let Err(m) = self.handle_frame(conn, frame) {
                        break Some(m);
                    }
                }
                Err(e) => break Some(e.to_string()),
            }
        };
        if consumed > 0 {
            conn.rbuf.drain(..consumed);
        }
        if let Some(message) = fatal {
            self.begin_fatal(conn, &message);
        }
    }

    /// `Block`-policy backpressure gate: a `Submit` whose session queue
    /// is at capacity must NOT be consumed yet. The loop is each
    /// session's only submitter, so depth can only fall concurrently —
    /// checking before the submit can never deadlock.
    fn must_pause(&self, conn: &Conn, frame: &Frame) -> bool {
        if !matches!(self.opts.policy, AdmissionPolicy::Block) {
            return false;
        }
        if !matches!(frame.ty, FrameType::Submit | FrameType::SubmitBin) {
            return false;
        }
        match conn.sessions.get(&frame.session) {
            Some(slot) => !slot.session.is_closed() && !slot.session.has_capacity(),
            None => false,
        }
    }

    /// Handle one decoded frame. `Err` = fatal protocol violation.
    fn handle_frame(&mut self, conn: &mut Conn, f: Frame) -> Result<(), String> {
        if matches!(conn.phase, ConnPhase::Handshake) {
            return self.handle_hello(conn, f);
        }
        match f.ty {
            FrameType::Submit | FrameType::SubmitBin => {
                if f.ty == FrameType::SubmitBin && conn.proto < 2 {
                    return Err(format!(
                        "binary Submit on a v{} connection (negotiate v2 first)",
                        conn.proto
                    ));
                }
                let sid = f.session;
                let Some(slot) = conn.sessions.get(&sid) else {
                    // per-request, non-fatal: the stream is still
                    // well-framed, the client just named a session
                    // this connection never opened
                    let body = error_body(&format!("unknown session {sid}"), false);
                    conn.push_frame(&Frame::new(FrameType::Error, f.id, body).with_session(sid));
                    return Ok(());
                };
                let session = Arc::clone(&slot.session);
                let decode_start = obs::now_us();
                let decoded = if f.ty == FrameType::SubmitBin {
                    super::proto::decode_request_bin(f.id, &f.bin)
                } else {
                    super::proto::decode_request(&f.body).map(|mut req| {
                        req.id = f.id; // the header id is authoritative
                        req
                    })
                };
                obs::emit(&obs::SpanEvent {
                    trace_id: f.id,
                    stage: obs::Stage::NetDecode,
                    shard: obs::NO_SHARD,
                    start_us: decode_start,
                    dur_us: obs::now_us().saturating_sub(decode_start),
                    extra: f.bin.len() as u64,
                });
                let req = match decoded {
                    Ok(req) => req,
                    Err(e) => return Err(format!("undecodable request body: {e:#}")),
                };
                match session.try_submit(req) {
                    Ok(_ticket) => {}
                    Err(SubmitError { id, shard, error: AdmitError::Busy }) => {
                        conn.push_frame(
                            &Frame::new(FrameType::Busy, id, busy_body(shard)).with_session(sid),
                        );
                    }
                    Err(SubmitError { id, error: AdmitError::Closed, .. }) => {
                        let body = error_body("session closed (drained)", false);
                        conn.push_frame(&Frame::new(FrameType::Error, id, body).with_session(sid));
                    }
                }
                Ok(())
            }
            FrameType::OpenSession => {
                if conn.proto < 3 {
                    return Err(format!(
                        "OpenSession on a v{} connection (multiplexing needs v3)",
                        conn.proto
                    ));
                }
                let sid = f.session;
                if sid == 0 {
                    return Err("OpenSession with session 0 \
                         (reserved for the connection's default session)"
                        .into());
                }
                if conn.sessions.contains_key(&sid) {
                    return Err(format!("session {sid} is already open"));
                }
                self.open_slot(conn, sid);
                conn.push_frame(&Frame::new(FrameType::OpenSession, 0, Json::Null).with_session(sid));
                Ok(())
            }
            FrameType::SessionClosed => {
                if conn.proto < 3 {
                    return Err(format!(
                        "SessionClosed on a v{} connection (multiplexing needs v3)",
                        conn.proto
                    ));
                }
                let sid = f.session;
                let Some(slot) = conn.sessions.get_mut(&sid) else {
                    return Err(format!("SessionClosed for unknown session {sid}"));
                };
                slot.session.seal();
                slot.state = SlotState::Goodbye;
                self.try_finish_slot(conn, sid);
                Ok(())
            }
            FrameType::Drained => {
                let sid = f.session;
                let Some(slot) = conn.sessions.get_mut(&sid) else {
                    return Err(format!("Drained for unknown session {sid}"));
                };
                slot.session.seal();
                slot.state = SlotState::Draining;
                slot.drain_returned = 0;
                self.try_finish_slot(conn, sid);
                Ok(())
            }
            FrameType::Closed => {
                for (sid, slot) in conn.sessions.iter_mut() {
                    slot.session.seal();
                    slot.state = if *sid == 0 { SlotState::Goodbye } else { SlotState::Folding };
                }
                conn.phase = ConnPhase::Draining { graceful: true };
                self.settle_conn(conn);
                Ok(())
            }
            FrameType::Stats => {
                if conn.proto < 3 {
                    return Err(format!(
                        "Stats frame on a v{} connection (negotiate v3 first)",
                        conn.proto
                    ));
                }
                let mut snap = self.rack.snapshot();
                snap.net = Some(self.stats.gauges());
                conn.push_frame(
                    &Frame::new(FrameType::Stats, f.id, super::proto::encode_stats(&snap))
                        .with_session(f.session),
                );
                Ok(())
            }
            FrameType::Error => {
                // client-side abort: silent cleanup
                let _ = error_message(&f.body);
                self.begin_disconnect(conn);
                Ok(())
            }
            other => Err(format!("unexpected {other:?} frame from a client")),
        }
    }

    fn handle_hello(&mut self, conn: &mut Conn, f: Frame) -> Result<(), String> {
        if f.ty != FrameType::Hello {
            return Err(format!("expected Hello, got {:?}", f.ty));
        }
        let max_proto = self.max_proto;
        let Some(proto) =
            super::proto::hello_proto(&f.body).and_then(|peer| negotiate(peer, max_proto))
        else {
            return Err(format!(
                "unsupported protocol version (server speaks {MIN_PROTO_VERSION}..={max_proto})"
            ));
        };
        // the Hello reply still travels in the v1 layout (pushed while
        // the phase is Handshake); the NEXT frame switches layouts
        conn.push_frame(&Frame::new(
            FrameType::Hello,
            0,
            server_hello(proto, self.rack.len(), self.rack.policy_name()),
        ));
        conn.proto = proto;
        conn.phase = ConnPhase::Open;
        // session 0: the connection's implicit default session
        self.open_slot(conn, 0);
        Ok(())
    }

    /// Open one logical session backed by the shared worker pool and
    /// register its completion wakeup.
    fn open_slot(&self, conn: &mut Conn, sid: u32) {
        let session = Arc::new(self.rack.open_session_on(self.opts, &self.pool));
        let dirty = Arc::clone(&self.dirty);
        let waker = Arc::clone(&self.waker);
        let cid = conn.id;
        session.set_notify(Some(Arc::new(move || {
            dirty.lock().unwrap_or_else(|e| e.into_inner()).push((cid, sid));
            waker.wake();
        })));
        conn.sessions.insert(sid, Slot { session, state: SlotState::Open, drain_returned: 0 });
        self.stats.active_sessions.fetch_add(1, Ordering::Relaxed);
    }

    /// Move completed responses from one session's channel onto the
    /// connection's write queue (or fold them silently when the peer is
    /// gone), respecting the write-buffer cap.
    fn pump_slot(&self, conn: &mut Conn, sid: u32) {
        let Some(slot) = conn.sessions.get(&sid) else { return };
        let session = Arc::clone(&slot.session);
        let forward = conn.forwarding();
        let mut pumped = 0u64;
        loop {
            if forward && conn.write_backlogged() {
                conn.pump_stalled = true;
                break;
            }
            match session.try_recv() {
                Some(resp) => {
                    if forward {
                        let frame = response_frame(conn.proto, sid, &resp);
                        conn.push_frame(&frame);
                    }
                    pumped += 1;
                }
                None => break,
            }
        }
        if pumped > 0 {
            if let Some(slot) = conn.sessions.get_mut(&sid) {
                if matches!(slot.state, SlotState::Draining) {
                    slot.drain_returned += pumped;
                }
            }
        }
        self.try_finish_slot(conn, sid);
    }

    /// Complete a pending drain/close for one session if it has gone
    /// idle (every admitted request consumed).
    fn try_finish_slot(&self, conn: &mut Conn, sid: u32) {
        let Some(slot) = conn.sessions.get(&sid) else { return };
        if matches!(slot.state, SlotState::Open) || slot.session.outstanding() > 0 {
            return;
        }
        // session 0's goodbye is the connection's: it must be the last
        // frame, so wait for every other session to finish first
        if sid == 0 && matches!(slot.state, SlotState::Goodbye) && conn.sessions.len() > 1 {
            return;
        }
        let session = Arc::clone(&slot.session);
        let forward = conn.forwarding();
        // `drain` is instant here (nothing outstanding) and hands back
        // any response a pump race left unconsumed
        let rest = session.drain();
        let mut straggled = 0u64;
        for resp in &rest {
            if forward {
                let frame = response_frame(conn.proto, sid, resp);
                conn.push_frame(&frame);
            }
            straggled += 1;
        }
        let Some(slot) = conn.sessions.get_mut(&sid) else { return };
        let state = std::mem::replace(&mut slot.state, SlotState::Open);
        match state {
            // filtered above: an Open slot already returned early
            SlotState::Open => {}
            SlotState::Draining => {
                let mut returned = straggled;
                if let Some(slot) = conn.sessions.get_mut(&sid) {
                    slot.drain_returned += straggled;
                    returned = slot.drain_returned;
                    slot.drain_returned = 0;
                }
                if forward {
                    conn.push_frame(
                        &Frame::new(FrameType::Drained, 0, drained_body(returned))
                            .with_session(sid),
                    );
                }
                // state already reset to Open: the session is sealed,
                // later submits get per-request Closed errors
            }
            SlotState::Goodbye => {
                let mut summary = session.close();
                if let Some(rs) = summary.shards.as_mut() {
                    rs.net = Some(self.stats.gauges());
                }
                if forward {
                    let (ty, session_field) =
                        if sid == 0 { (FrameType::Closed, 0) } else { (FrameType::SessionClosed, sid) };
                    conn.push_frame(
                        &Frame::new(ty, 0, super::proto::encode_summary(&summary))
                            .with_session(session_field),
                    );
                }
                conn.sessions.remove(&sid);
                self.stats.active_sessions.fetch_sub(1, Ordering::Relaxed);
                if sid == 0 {
                    conn.phase = ConnPhase::Closed;
                }
            }
            SlotState::Folding => {
                let _ = session.close();
                conn.sessions.remove(&sid);
                self.stats.active_sessions.fetch_sub(1, Ordering::Relaxed);
            }
        }
        // removing the last sibling session may unblock a goodbye parked
        // on session 0 (the connection-level close waits to go last)
        if sid != 0 && conn.sessions.len() == 1 && conn.sessions.contains_key(&0) {
            self.try_finish_slot(conn, 0);
        }
    }

    /// Try to finish every pending session transition on a connection.
    fn settle_conn(&self, conn: &mut Conn) {
        let sids: Vec<u32> = conn.sessions.keys().copied().collect();
        for sid in sids {
            self.try_finish_slot(conn, sid);
        }
        // finishing non-zero sessions may have unblocked session 0's
        // connection-level goodbye
        if conn.sessions.len() == 1 && conn.sessions.contains_key(&0) {
            self.try_finish_slot(conn, 0);
        }
    }

    /// Peer vanished (EOF / transport error): consume-and-fold every
    /// session, send nothing more.
    fn begin_disconnect(&self, conn: &mut Conn) {
        conn.dead_write = true;
        conn.wq.clear();
        conn.wq_bytes = 0;
        conn.wq_off = 0;
        conn.rbuf.clear();
        for slot in conn.sessions.values_mut() {
            slot.session.seal();
            slot.state = SlotState::Folding;
        }
        conn.phase = ConnPhase::Draining { graceful: false };
        self.settle_conn(conn);
    }

    /// Protocol violation: tell the peer (best effort — the error frame
    /// still flushes), then tear down like a disconnect.
    fn begin_fatal(&self, conn: &mut Conn, message: &str) {
        conn.push_frame(&Frame::new(FrameType::Error, 0, error_body(message, true)));
        conn.rbuf.clear();
        for slot in conn.sessions.values_mut() {
            slot.session.seal();
            slot.state = SlotState::Folding;
        }
        conn.phase = ConnPhase::Draining { graceful: false };
        self.settle_conn(conn);
    }

    /// Drop connections that have fully finished.
    fn reap(&mut self) {
        let done: Vec<u64> = self
            .conns
            .iter()
            .filter(|(_, c)| match c.phase {
                ConnPhase::Draining { .. } => c.sessions.is_empty() && c.wq.is_empty(),
                ConnPhase::Closed => c.sessions.is_empty() && (c.wq.is_empty() || c.dead_write),
                _ => false,
            })
            .map(|(id, _)| *id)
            .collect();
        for id in done {
            if let Some(conn) = self.conns.remove(&id) {
                let _ = conn.stream.shutdown(Shutdown::Both);
                self.stats.active_connections.fetch_sub(1, Ordering::Relaxed);
            }
        }
    }

    /// Server shutdown: finish every session's admitted work (blocking
    /// is fine now — the loop is done), then close all sockets.
    fn shutdown_all(&mut self) {
        for (_, conn) in self.conns.drain() {
            for (_, slot) in conn.sessions.iter() {
                slot.session.seal();
            }
            for (_, slot) in conn.sessions.iter() {
                let _ = slot.session.close();
                self.stats.active_sessions.fetch_sub(1, Ordering::Relaxed);
            }
            let _ = conn.stream.shutdown(Shutdown::Both);
            self.stats.active_connections.fetch_sub(1, Ordering::Relaxed);
        }
    }
}

/// The event-loop GTA server: one poll thread drives every connection
/// as a non-blocking state machine, a fixed [`WorkerPool`] executes the
/// rack work, and (on v3 connections) one socket multiplexes many
/// logical sessions. The serving semantics — negotiation, admission
/// backpressure, drain/close, disconnect-drains-everything — match
/// [`NetServer`] frame-for-frame for v1/v2 peers; the difference is
/// O(pool) threads instead of O(connections).
pub struct EventServer {
    addr: SocketAddr,
    stop: Arc<AtomicBool>,
    waker: Arc<Waker>,
    stats: Arc<NetStats>,
    loop_thread: Option<std::thread::JoinHandle<()>>,
}

impl EventServer {
    /// Bind `addr` and start serving. `opts.workers` sizes the shared
    /// worker pool (NOT per-connection threads).
    pub fn spawn(rack: Arc<Rack>, addr: &str, opts: ServeOptions) -> anyhow::Result<EventServer> {
        EventServer::spawn_with(rack, addr, opts, PROTO_VERSION, DEFAULT_MAX_CONNS)
    }

    /// [`spawn`](Self::spawn) with an explicit negotiation cap.
    pub fn spawn_proto(
        rack: Arc<Rack>,
        addr: &str,
        opts: ServeOptions,
        max_proto: u64,
    ) -> anyhow::Result<EventServer> {
        EventServer::spawn_with(rack, addr, opts, max_proto, DEFAULT_MAX_CONNS)
    }

    /// [`spawn`](Self::spawn) with explicit protocol and concurrent-
    /// connection caps (`gta serve --event-loop --max-conns N`; above
    /// the cap new connections are refused with a clean `Error` frame).
    pub fn spawn_with(
        rack: Arc<Rack>,
        addr: &str,
        opts: ServeOptions,
        max_proto: u64,
        max_conns: usize,
    ) -> anyhow::Result<EventServer> {
        anyhow::ensure!(
            (MIN_PROTO_VERSION..=PROTO_VERSION).contains(&max_proto),
            "this build speaks protocol versions {MIN_PROTO_VERSION}..={PROTO_VERSION}, not {max_proto}"
        );
        anyhow::ensure!(max_conns > 0, "--max-conns must be at least 1");
        let listener = TcpListener::bind(addr)?;
        let local = listener.local_addr()?;
        listener.set_nonblocking(true)?;
        let waker = Arc::new(Waker::new()?);
        let stats = Arc::new(NetStats::default());
        let stop = Arc::new(AtomicBool::new(false));
        let ev = EvLoop {
            rack,
            opts,
            max_proto,
            max_conns,
            pool: Arc::new(WorkerPool::new(opts.workers.max(1))),
            listener,
            waker: Arc::clone(&waker),
            dirty: Arc::new(Mutex::new(Vec::new())),
            stats: Arc::clone(&stats),
            stop: Arc::clone(&stop),
            conns: HashMap::new(),
            next_conn_id: 1,
        };
        let loop_thread =
            std::thread::Builder::new().name("gta-net-loop".into()).spawn(move || ev.run())?;
        Ok(EventServer { addr: local, stop, waker, stats, loop_thread: Some(loop_thread) })
    }

    /// The bound address (resolves the ephemeral port of `":0"` binds).
    pub fn addr(&self) -> SocketAddr {
        self.addr
    }

    /// Live connection/session gauges and wire byte counters.
    pub fn gauges(&self) -> NetGauges {
        self.stats.gauges()
    }

    /// Stop the loop: live sessions finish their admitted work, all
    /// sockets close, the worker pool joins.
    pub fn shutdown(&mut self) {
        self.stop.store(true, Ordering::SeqCst);
        self.waker.wake();
        if let Some(h) = self.loop_thread.take() {
            let _ = h.join();
        }
    }

    /// Block until the loop exits (`gta serve`'s foreground wait).
    pub fn join(&mut self) {
        if let Some(h) = self.loop_thread.take() {
            let _ = h.join();
        }
    }
}

impl Drop for EventServer {
    fn drop(&mut self) {
        self.shutdown();
    }
}
