//! The TCP serving front-end: a [`NetServer`] binds a
//! `std::net::TcpListener` and gives every accepted connection its own
//! [`RackSession`] against ONE shared [`Rack`] — the session-native
//! transport the ROADMAP asked for, with zero new dependencies.
//!
//! Per connection, two threads split the session exactly along its
//! `&self` API:
//!
//! * the **reader** (the connection thread) decodes frames off the
//!   socket and submits — routing therefore happens in wire order on
//!   one thread, so deterministic policies stay deterministic per
//!   connection; an `AdmitError::Busy` becomes a wire-level `Busy`
//!   frame, so admission backpressure reaches the client instead of
//!   dying inside the server (and under `AdmissionPolicy::Block` the
//!   reader itself stalls, which backpressures the socket the TCP way);
//! * the **writer** pumps [`RackSession::recv_timeout`] completions
//!   back as `Response` frames **as they finish, out of submission
//!   order** — the same out-of-order egress the in-process session
//!   gives.
//!
//! Disconnect — graceful (`Closed`), protocol violation, or the peer
//! vanishing mid-stream — always takes the same exit: the session is
//! drained (every queued and in-flight request still executes, so rack
//! metrics/telemetry never lose work) and closed. On a graceful close
//! the final [`crate::serve::ServeSummary`] (with its `RackSnapshot`)
//! travels back in the `Closed` frame. See `docs/transport.md`.

use super::proto::{
    busy_body, drained_body, error_body, error_message, negotiate, read_frame, server_hello,
    write_frame, DecodeError, Frame, FrameType, MIN_PROTO_VERSION, PROTO_VERSION,
};
use crate::coordinator::{AdmitError, Rack, RackSession, Response, ServeOptions, SubmitError};
use crate::util::json::Json;
use std::io::{BufReader, BufWriter, Write};
use std::net::{Shutdown, SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Duration;

/// How long the egress pump waits on the completion channel before
/// re-checking whether the session closed under it.
const PUMP_TICK: Duration = Duration::from_millis(20);

/// Shared, lock-guarded frame writer: the reader (Busy/Error/acks) and
/// the pump (Responses) interleave whole frames, never bytes.
type SharedWriter = Arc<Mutex<BufWriter<TcpStream>>>;

/// Lock the shared writer, treating poison as a dead connection. A
/// poisoned mutex means the other writer thread panicked mid-frame, so
/// framing on this socket can no longer be trusted — but that is a
/// *disconnect* for this connection, never a cascading panic: the
/// caller sees an `Err`, stops writing, and the session still drains.
fn lock_writer(w: &SharedWriter) -> std::io::Result<std::sync::MutexGuard<'_, BufWriter<TcpStream>>> {
    w.lock().map_err(|_| {
        std::io::Error::new(
            std::io::ErrorKind::BrokenPipe,
            "frame writer poisoned by a peer thread panic",
        )
    })
}

fn send_frame(w: &SharedWriter, ty: FrameType, id: u64, body: Json) -> std::io::Result<()> {
    let mut guard = lock_writer(w)?;
    write_frame(&mut *guard, &Frame::new(ty, id, body))?;
    guard.flush()
}

/// Send one completed [`Response`] in the connection's negotiated
/// encoding: a binary `ResponseBin` frame on v2, the v1 JSON
/// `Response` frame otherwise.
fn send_response(w: &SharedWriter, proto: u64, resp: &Response) -> std::io::Result<()> {
    let frame = if proto >= 2 {
        Frame::binary(FrameType::ResponseBin, resp.id, super::proto::encode_response_bin(resp))
    } else {
        Frame::new(FrameType::Response, resp.id, super::proto::encode_response(resp))
    };
    let mut guard = lock_writer(w)?;
    write_frame(&mut *guard, &frame)?;
    guard.flush()
}

/// A listening GTA server. Dropping it stops accepting new connections;
/// live connections keep their sessions until their clients disconnect.
pub struct NetServer {
    addr: SocketAddr,
    stop: Arc<AtomicBool>,
    accept: Option<std::thread::JoinHandle<()>>,
}

impl NetServer {
    /// Bind `addr` (e.g. `"127.0.0.1:0"` for an ephemeral port) and
    /// start accepting connections, each served by its own session over
    /// `rack` opened with `opts`. Serves every protocol version up to
    /// [`PROTO_VERSION`].
    pub fn spawn(rack: Arc<Rack>, addr: &str, opts: ServeOptions) -> anyhow::Result<NetServer> {
        NetServer::spawn_proto(rack, addr, opts, PROTO_VERSION)
    }

    /// [`spawn`](Self::spawn) with an explicit cap on the protocol
    /// version this server will negotiate — `spawn_proto(.., 1)` is a
    /// pure-v1 server (the PR 5 wire behavior), useful for replaying
    /// compatibility baselines.
    pub fn spawn_proto(
        rack: Arc<Rack>,
        addr: &str,
        opts: ServeOptions,
        max_proto: u64,
    ) -> anyhow::Result<NetServer> {
        anyhow::ensure!(
            (MIN_PROTO_VERSION..=PROTO_VERSION).contains(&max_proto),
            "this build speaks protocol versions {MIN_PROTO_VERSION}..={PROTO_VERSION}, not {max_proto}"
        );
        let listener = TcpListener::bind(addr)?;
        let local = listener.local_addr()?;
        // non-blocking accept so shutdown() can stop the loop without a
        // wake-up connection
        listener.set_nonblocking(true)?;
        let stop = Arc::new(AtomicBool::new(false));
        let stop_flag = Arc::clone(&stop);
        let accept = std::thread::Builder::new()
            .name("gta-net-accept".into())
            .spawn(move || {
                let mut conns: Vec<std::thread::JoinHandle<()>> = Vec::new();
                let mut conn_id = 0usize;
                while !stop_flag.load(Ordering::Relaxed) {
                    match listener.accept() {
                        Ok((stream, _peer)) => {
                            conn_id += 1;
                            let rack = Arc::clone(&rack);
                            // pre-clone so a failed spawn can still
                            // tell the client before dropping it
                            let refusal = stream.try_clone().ok();
                            let spawned = std::thread::Builder::new()
                                .name(format!("gta-net-conn-{conn_id}"))
                                .spawn(move || {
                                    let _ = handle_connection(stream, rack, opts, max_proto);
                                });
                            match spawned {
                                Ok(h) => {
                                    conns.push(h);
                                    conns.retain(|h| !h.is_finished());
                                }
                                Err(e) => {
                                    // OS out of threads: fail this one
                                    // connection, keep accepting
                                    eprintln!(
                                        "gta-net: connection thread spawn failed \
                                         (refusing connection {conn_id}): {e}"
                                    );
                                    if let Some(s) = refusal {
                                        let mut w = BufWriter::new(s);
                                        let body = error_body(
                                            "server cannot take this connection right now \
                                             (thread spawn failed); retry later",
                                            true,
                                        );
                                        let _ = write_frame(
                                            &mut w,
                                            &Frame::new(FrameType::Error, 0, body),
                                        );
                                        let _ = w.flush();
                                    }
                                }
                            }
                        }
                        Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                            std::thread::sleep(Duration::from_millis(2));
                        }
                        Err(_) => break,
                    }
                }
                // join whatever already finished; a connection still
                // held open by its client outlives the accept loop and
                // cleans itself up on disconnect
                for h in conns.into_iter().filter(|h| h.is_finished()) {
                    let _ = h.join();
                }
            })?;
        Ok(NetServer { addr: local, stop, accept: Some(accept) })
    }

    /// The bound address (resolves the ephemeral port of `":0"` binds).
    pub fn addr(&self) -> SocketAddr {
        self.addr
    }

    /// Stop accepting new connections and join the accept loop.
    pub fn shutdown(&mut self) {
        self.stop.store(true, Ordering::Relaxed);
        if let Some(h) = self.accept.take() {
            let _ = h.join();
        }
    }

    /// Block until the accept loop exits (it runs until
    /// [`shutdown`](Self::shutdown) — this is `gta serve --listen`'s
    /// foreground wait).
    pub fn join(&mut self) {
        if let Some(h) = self.accept.take() {
            let _ = h.join();
        }
    }
}

impl Drop for NetServer {
    fn drop(&mut self) {
        self.shutdown();
    }
}

/// Why the ingest loop stopped reading.
enum Exit {
    /// Client asked to close; send the final summary.
    Close,
    /// Peer vanished (EOF / transport error): silent cleanup.
    Disconnect,
    /// Protocol violation: tell the peer (best effort), then drop the
    /// connection — framing can no longer be trusted.
    Fatal(String),
}

/// Serve one connection to completion. All exits drain the session.
fn handle_connection(
    stream: TcpStream,
    rack: Arc<Rack>,
    opts: ServeOptions,
    max_proto: u64,
) -> anyhow::Result<()> {
    stream.set_nodelay(true).ok();
    let mut reader = BufReader::new(stream.try_clone()?);
    let writer: SharedWriter = Arc::new(Mutex::new(BufWriter::new(stream.try_clone()?)));

    // ---- version negotiation: Hello must be the first frame. The
    // client announces the newest version it speaks; the connection
    // runs at min(client, server), refusing only peers below
    // MIN_PROTO_VERSION.
    let proto = match read_frame(&mut reader) {
        Ok(f) if f.ty == FrameType::Hello => {
            match super::proto::hello_proto(&f.body).and_then(|peer| negotiate(peer, max_proto)) {
                Some(v) => v,
                None => {
                    let _ = send_frame(
                        &writer,
                        FrameType::Error,
                        0,
                        error_body(
                            &format!(
                                "unsupported protocol version \
                                 (server speaks {MIN_PROTO_VERSION}..={max_proto})"
                            ),
                            true,
                        ),
                    );
                    let _ = stream.shutdown(Shutdown::Both);
                    return Ok(());
                }
            }
        }
        Ok(f) => {
            let _ = send_frame(
                &writer,
                FrameType::Error,
                0,
                error_body(&format!("expected Hello, got {:?}", f.ty), true),
            );
            let _ = stream.shutdown(Shutdown::Both);
            return Ok(());
        }
        Err(e) => {
            let _ = send_frame(&writer, FrameType::Error, 0, error_body(&e.to_string(), true));
            let _ = stream.shutdown(Shutdown::Both);
            return Ok(());
        }
    };
    send_frame(&writer, FrameType::Hello, 0, server_hello(proto, rack.len(), rack.policy_name()))?;

    let session: Arc<RackSession> = Arc::new(rack.open_session(opts));

    // ---- egress pump: completions -> Response frames, out of order
    let pump_spawn = {
        let session = Arc::clone(&session);
        let writer = Arc::clone(&writer);
        std::thread::Builder::new().name("gta-net-pump".into()).spawn(move || {
            loop {
                match session.recv_timeout(PUMP_TICK) {
                    Some(resp) => {
                        if send_response(&writer, proto, &resp).is_err() {
                            // peer gone: stop writing; the reader
                            // will notice and drain
                            break;
                        }
                    }
                    None => {
                        if session.is_closed() {
                            break;
                        }
                    }
                }
            }
        })
    };
    let mut pump = match pump_spawn {
        Ok(h) => Some(h),
        Err(e) => {
            // OS out of threads: fail only this connection — tell the
            // client, drain the (empty) session so accounting stays
            // consistent, and leave the server accepting.
            eprintln!("gta-net: egress pump spawn failed (closing connection): {e}");
            let _ = send_frame(
                &writer,
                FrameType::Error,
                0,
                error_body(
                    "server cannot serve this connection right now \
                     (thread spawn failed); retry later",
                    true,
                ),
            );
            let _ = session.drain();
            let _ = session.close();
            let _ = stream.shutdown(Shutdown::Both);
            return Ok(());
        }
    };

    // Drain the session and hand every remaining response to the wire
    // (unless the socket already failed). Joins the pump first so the
    // follow-up ack frame is provably the last thing sent.
    let drain_to_wire = |pump: &mut Option<std::thread::JoinHandle<()>>| -> u64 {
        let rest = session.drain();
        if let Some(h) = pump.take() {
            let _ = h.join();
        }
        let mut returned = 0u64;
        for resp in &rest {
            if send_response(&writer, proto, resp).is_err() {
                break;
            }
            returned += 1;
        }
        returned
    };

    // ---- ingest loop: this thread owns the socket's read side
    let exit = loop {
        match read_frame(&mut reader) {
            Ok(f) => match f.ty {
                FrameType::Submit | FrameType::SubmitBin => {
                    if f.ty == FrameType::SubmitBin && proto < 2 {
                        break Exit::Fatal(format!(
                            "binary Submit on a v{proto} connection (negotiate v2 first)"
                        ));
                    }
                    let decoded = if f.ty == FrameType::SubmitBin {
                        super::proto::decode_request_bin(f.id, &f.bin)
                    } else {
                        super::proto::decode_request(&f.body).map(|mut req| {
                            // the header id is authoritative
                            req.id = f.id;
                            req
                        })
                    };
                    match decoded {
                        Ok(req) => match session.try_submit(req) {
                            Ok(_ticket) => {}
                            Err(SubmitError { id, shard, error: AdmitError::Busy }) => {
                                if send_frame(&writer, FrameType::Busy, id, busy_body(shard))
                                    .is_err()
                                {
                                    break Exit::Disconnect;
                                }
                            }
                            Err(SubmitError { id, error: AdmitError::Closed, .. }) => {
                                let body = error_body("session closed (drained)", false);
                                if send_frame(&writer, FrameType::Error, id, body).is_err() {
                                    break Exit::Disconnect;
                                }
                            }
                        },
                        Err(e) => break Exit::Fatal(format!("undecodable request body: {e:#}")),
                    }
                }
                FrameType::Drained => {
                    // drain request: finish everything, flush it, ack
                    let returned = drain_to_wire(&mut pump);
                    if send_frame(&writer, FrameType::Drained, 0, drained_body(returned)).is_err() {
                        break Exit::Disconnect;
                    }
                    // the session is closed now; later Submits get
                    // per-request Error frames, Closed still answers
                }
                FrameType::Closed => break Exit::Close,
                FrameType::Error => {
                    // client-side abort: log-free silent cleanup
                    let _ = error_message(&f.body);
                    break Exit::Disconnect;
                }
                other => break Exit::Fatal(format!("unexpected {other:?} frame from a client")),
            },
            Err(DecodeError::Eof) => break Exit::Disconnect,
            Err(DecodeError::Io(_)) => break Exit::Disconnect,
            Err(DecodeError::Malformed(m)) => break Exit::Fatal(m),
        }
    };

    // ---- one exit path: drain (work is never lost), then say goodbye
    match exit {
        Exit::Close => {
            let _ = drain_to_wire(&mut pump);
            let summary = session.close();
            let _ = send_frame(
                &writer,
                FrameType::Closed,
                0,
                super::proto::encode_summary(&summary),
            );
        }
        Exit::Disconnect => {
            let _ = drain_to_wire(&mut pump);
            let _ = session.close();
        }
        Exit::Fatal(message) => {
            let _ = send_frame(&writer, FrameType::Error, 0, error_body(&message, true));
            let _ = drain_to_wire(&mut pump);
            let _ = session.close();
        }
    }
    let _ = stream.shutdown(Shutdown::Both);
    Ok(())
}
