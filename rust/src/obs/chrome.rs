//! Trace export: the span ring as Chrome `trace_event` JSON (openable
//! in `chrome://tracing` / Perfetto) and as the `gta.obs.trace/1`
//! machine schema (`gta trace`, see `docs/observability.md`).

use super::{SpanEvent, NO_SHARD, NO_TRACE};
use crate::util::json::Json;
use std::collections::BTreeMap;

fn obj(fields: Vec<(&str, Json)>) -> Json {
    Json::Obj(fields.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
}

/// Chrome `trace_event` JSON (the "JSON Object Format": a top-level
/// object with a `traceEvents` array of complete `"ph": "X"` events).
/// Tracks: `pid` 1 is the request pipeline (one `tid` per trace id, so
/// a request's admit → … → respond spans line up on one row); `pid` 2
/// is the network layer (one `tid` per connection); `pid` 3 holds
/// un-traced spans (scheduler sweeps from batch pre-passes).
pub fn chrome_trace_json(events: &[SpanEvent]) -> Json {
    let mut rows = Vec::with_capacity(events.len());
    for ev in events {
        let net = ev.stage.is_net();
        let (pid, tid) = if ev.trace_id == NO_TRACE {
            (3u64, 0u64)
        } else if net {
            (2, ev.trace_id)
        } else {
            (1, ev.trace_id)
        };
        let mut args = vec![("extra", Json::Num(ev.extra as f64))];
        if ev.shard != NO_SHARD {
            args.push(("shard", Json::Num(ev.shard as f64)));
        }
        rows.push(obj(vec![
            ("name", Json::Str(ev.stage.name().to_string())),
            ("cat", Json::Str(if net { "net" } else { "serve" }.to_string())),
            ("ph", Json::Str("X".to_string())),
            ("ts", Json::Num(ev.start_us as f64)),
            ("dur", Json::Num(ev.dur_us.max(1) as f64)),
            ("pid", Json::Num(pid as f64)),
            ("tid", Json::Num(tid as f64)),
            ("args", obj(args)),
        ]));
    }
    obj(vec![
        ("traceEvents", Json::Arr(rows)),
        ("displayTimeUnit", Json::Str("ms".to_string())),
    ])
}

/// The `gta.obs.trace/1` machine schema: every event with its raw
/// fields, plus the exact count of ring-overwritten events.
pub fn machine_trace_json(events: &[SpanEvent], dropped: u64) -> Json {
    let rows = events
        .iter()
        .map(|ev| {
            obj(vec![
                ("trace", Json::Num(if ev.trace_id == NO_TRACE { -1.0 } else { ev.trace_id as f64 })),
                ("stage", Json::Str(ev.stage.name().to_string())),
                ("shard", Json::Num(if ev.shard == NO_SHARD { -1.0 } else { ev.shard as f64 })),
                ("start_us", Json::Num(ev.start_us as f64)),
                ("dur_us", Json::Num(ev.dur_us as f64)),
                ("extra", Json::Num(ev.extra as f64)),
            ])
        })
        .collect();
    obj(vec![
        ("schema", Json::Str("gta.obs.trace/1".to_string())),
        ("dropped", Json::Num(dropped as f64)),
        ("events", Json::Arr(rows)),
    ])
}

/// Per-request span index: events grouped by trace id (un-traced
/// events excluded), each group sorted by start time — the shape the
/// property tests and `gta trace`'s per-request summary consume.
pub fn by_trace(events: &[SpanEvent]) -> BTreeMap<u64, Vec<SpanEvent>> {
    let mut map: BTreeMap<u64, Vec<SpanEvent>> = BTreeMap::new();
    for ev in events {
        if ev.trace_id != NO_TRACE {
            map.entry(ev.trace_id).or_default().push(*ev);
        }
    }
    for spans in map.values_mut() {
        spans.sort_by_key(|e| (e.start_us, e.stage.as_u8()));
    }
    map
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::obs::Stage;

    fn ev(trace: u64, stage: Stage, start: u64) -> SpanEvent {
        SpanEvent { trace_id: trace, stage, shard: 0, start_us: start, dur_us: 5, extra: 0 }
    }

    #[test]
    fn chrome_export_parses_back_and_keeps_every_event() {
        let events = vec![
            ev(1, Stage::Admit, 0),
            ev(1, Stage::Execute, 10),
            ev(2, Stage::NetRead, 3),
            SpanEvent { trace_id: NO_TRACE, stage: Stage::Sweep, shard: NO_SHARD, start_us: 1, dur_us: 9, extra: 7 },
        ];
        let json = chrome_trace_json(&events);
        let text = json.render();
        let back = crate::util::json::parse(&text).expect("chrome export must be valid JSON");
        let rows = back.get("traceEvents").and_then(|t| t.as_arr()).expect("traceEvents array");
        assert_eq!(rows.len(), 4);
        for row in rows {
            assert_eq!(row.get("ph").and_then(|p| p.as_str()), Some("X"));
            assert!(row.get("ts").is_some() && row.get("dur").is_some());
            assert!(row.get("name").and_then(|n| n.as_str()).is_some());
        }
    }

    #[test]
    fn machine_export_carries_schema_and_drop_count() {
        let json = machine_trace_json(&[ev(4, Stage::Respond, 2)], 17);
        assert_eq!(json.get("schema").and_then(|s| s.as_str()), Some("gta.obs.trace/1"));
        assert_eq!(json.get("dropped").and_then(|d| d.as_u64()), Some(17));
        assert_eq!(json.get("events").and_then(|e| e.as_arr()).map(|a| a.len()), Some(1));
    }

    #[test]
    fn by_trace_groups_and_sorts() {
        let events = vec![ev(2, Stage::Execute, 9), ev(1, Stage::Admit, 0), ev(2, Stage::Admit, 1)];
        let idx = by_trace(&events);
        assert_eq!(idx.len(), 2);
        assert_eq!(idx[&2][0].stage, Stage::Admit);
        assert_eq!(idx[&2][1].stage, Stage::Execute);
    }
}
