//! Exact-merging log-bucketed latency histograms (HDR-style).
//!
//! The metrics reservoir gives exact per-shard percentiles but cannot
//! be merged across shards without loss — `RackSnapshot::absorb` used
//! to take the `.max()` of per-shard percentiles, which overstates
//! every aggregate quantile. A [`Histogram`] trades per-value exactness
//! for **exact mergeability**: 64 power-of-two buckets of `u64` counts,
//! so merging two histograms is element-wise addition and the merged
//! quantiles are correct *to bucket resolution* (a factor-of-two band)
//! by construction, however many shards contributed.
//!
//! Bucketing: bucket 0 holds the value 0; bucket `b` (1..=63) holds
//! values in `[2^(b-1), 2^b)`; the last bucket absorbs everything from
//! `2^62` up. Recording is branch-light (`leading_zeros` + a clamp),
//! allocation-free, and saturating — no input can panic or overflow.
//!
//! [`StageHists`] bundles one histogram per pipeline [`Stage`] — the
//! per-stage latency breakdown that rides in metrics snapshots and the
//! `Stats` wire frame (see `docs/observability.md`).

use super::Stage;

/// Number of log2 buckets. Covers the full `u64` range: with
/// microsecond values, bucket 40 is already ~13 days.
pub const BUCKETS: usize = 64;

/// A fixed-size log2-bucketed histogram of `u64` samples (typically
/// microseconds). `merge` is exact; quantiles are exact to bucket
/// resolution and clamped into the observed `[min, max]` range.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Histogram {
    counts: [u64; BUCKETS],
    count: u64,
    sum: u64,
    min_v: u64,
    max_v: u64,
}

impl Default for Histogram {
    fn default() -> Histogram {
        Histogram { counts: [0; BUCKETS], count: 0, sum: 0, min_v: u64::MAX, max_v: 0 }
    }
}

/// Bucket index for a value: 0 for 0, `b` for `[2^(b-1), 2^b)`,
/// clamped into the last bucket.
pub fn bucket_of(v: u64) -> usize {
    if v == 0 {
        0
    } else {
        (64 - v.leading_zeros() as usize).min(BUCKETS - 1)
    }
}

/// Largest value a bucket can hold (the resolution band's upper edge).
fn upper_edge(b: usize) -> u64 {
    if b == 0 {
        0
    } else if b >= BUCKETS - 1 {
        u64::MAX
    } else {
        (1u64 << b) - 1
    }
}

impl Histogram {
    pub fn new() -> Histogram {
        Histogram::default()
    }

    /// Record one sample. Allocation-free, saturating, never panics.
    pub fn record(&mut self, v: u64) {
        self.counts[bucket_of(v)] = self.counts[bucket_of(v)].saturating_add(1);
        self.count = self.count.saturating_add(1);
        self.sum = self.sum.saturating_add(v);
        self.min_v = self.min_v.min(v);
        self.max_v = self.max_v.max(v);
    }

    /// Exact merge: element-wise count addition. `merge(a, b)` then
    /// `value_at_quantile` equals recording all of `a`'s and `b`'s
    /// samples into one histogram — no information is lost.
    pub fn merge(&mut self, other: &Histogram) {
        for (c, o) in self.counts.iter_mut().zip(other.counts.iter()) {
            *c = c.saturating_add(*o);
        }
        self.count = self.count.saturating_add(other.count);
        self.sum = self.sum.saturating_add(other.sum);
        self.min_v = self.min_v.min(other.min_v);
        self.max_v = self.max_v.max(other.max_v);
    }

    pub fn count(&self) -> u64 {
        self.count
    }

    pub fn sum(&self) -> u64 {
        self.sum
    }

    pub fn is_empty(&self) -> bool {
        self.count == 0
    }

    /// Smallest recorded value (0 when empty).
    pub fn min(&self) -> u64 {
        if self.count == 0 {
            0
        } else {
            self.min_v
        }
    }

    /// Largest recorded value (0 when empty).
    pub fn max(&self) -> u64 {
        self.max_v
    }

    /// Mean of the recorded samples (exact — from the running sum).
    pub fn mean(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.sum as f64 / self.count as f64
        }
    }

    /// The value at quantile `q` in `[0, 1]`, exact to bucket
    /// resolution: the true quantile lies in the same power-of-two
    /// band as the returned value. Clamped into `[min, max]` so
    /// single-bucket distributions report exactly.
    pub fn value_at_quantile(&self, q: f64) -> u64 {
        if self.count == 0 {
            return 0;
        }
        let rank = ((q.clamp(0.0, 1.0) * self.count as f64).ceil() as u64).clamp(1, self.count);
        let mut cum = 0u64;
        for (b, &c) in self.counts.iter().enumerate() {
            cum = cum.saturating_add(c);
            if cum >= rank {
                return upper_edge(b).min(self.max_v).max(self.min_v.min(self.max_v));
            }
        }
        self.max_v
    }

    /// Sparse view: the non-empty `(bucket, count)` pairs — the wire
    /// encoding (`docs/observability.md`, Stats frame grammar).
    pub fn to_sparse(&self) -> Vec<(usize, u64)> {
        self.counts.iter().enumerate().filter(|(_, &c)| c > 0).map(|(b, &c)| (b, c)).collect()
    }

    /// Rebuild from the sparse wire form. Out-of-range bucket indices
    /// are clamped into the last bucket (never a panic on hostile
    /// input); `count`/`sum`/`min`/`max` are trusted as decoded.
    pub fn from_sparse(pairs: &[(usize, u64)], count: u64, sum: u64, min_v: u64, max_v: u64) -> Histogram {
        let mut h = Histogram { counts: [0; BUCKETS], count, sum, min_v, max_v };
        if count == 0 {
            h.min_v = u64::MAX;
            h.max_v = 0;
        }
        for &(b, c) in pairs {
            let b = b.min(BUCKETS - 1);
            h.counts[b] = h.counts[b].saturating_add(c);
        }
        h
    }
}

/// One histogram per pipeline [`Stage`] — the per-stage latency
/// breakdown carried in metrics snapshots and merged exactly across
/// shards in `RackSnapshot::absorb`.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct StageHists {
    hists: [Histogram; Stage::COUNT],
}

impl Default for StageHists {
    fn default() -> StageHists {
        StageHists { hists: std::array::from_fn(|_| Histogram::default()) }
    }
}

impl StageHists {
    pub fn new() -> StageHists {
        StageHists::default()
    }

    pub fn record(&mut self, stage: Stage, v: u64) {
        self.hists[stage.as_u8() as usize].record(v);
    }

    pub fn get(&self, stage: Stage) -> &Histogram {
        &self.hists[stage.as_u8() as usize]
    }

    pub fn get_mut(&mut self, stage: Stage) -> &mut Histogram {
        &mut self.hists[stage.as_u8() as usize]
    }

    /// Exact element-wise merge of every stage.
    pub fn merge(&mut self, other: &StageHists) {
        for (h, o) in self.hists.iter_mut().zip(other.hists.iter()) {
            h.merge(o);
        }
    }

    pub fn is_empty(&self) -> bool {
        self.hists.iter().all(Histogram::is_empty)
    }

    /// The stages that saw at least one sample, in pipeline order.
    pub fn non_empty(&self) -> impl Iterator<Item = (Stage, &Histogram)> {
        Stage::ALL.iter().map(|&s| (s, self.get(s))).filter(|(_, h)| !h.is_empty())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bucket_edges_are_powers_of_two() {
        assert_eq!(bucket_of(0), 0);
        assert_eq!(bucket_of(1), 1);
        assert_eq!(bucket_of(2), 2);
        assert_eq!(bucket_of(3), 2);
        assert_eq!(bucket_of(4), 3);
        assert_eq!(bucket_of(1023), 10);
        assert_eq!(bucket_of(1024), 11);
        assert_eq!(bucket_of(u64::MAX), BUCKETS - 1);
    }

    #[test]
    fn record_merge_equals_recording_all() {
        let mut whole = Histogram::new();
        let mut left = Histogram::new();
        let mut right = Histogram::new();
        let mut rng = crate::util::rng::Rng::new(7);
        for i in 0..10_000u64 {
            let v = rng.range_u64(0, 1 << 20);
            whole.record(v);
            if i % 2 == 0 {
                left.record(v);
            } else {
                right.record(v);
            }
        }
        left.merge(&right);
        assert_eq!(left, whole, "merge must be exactly record-all");
    }

    #[test]
    fn quantiles_match_sorted_oracle_within_bucket_resolution() {
        let mut h = Histogram::new();
        let mut vals = Vec::new();
        let mut rng = crate::util::rng::Rng::new(2024);
        for _ in 0..5_000u64 {
            let v = rng.range_u64(1, 1 << 24);
            h.record(v);
            vals.push(v);
        }
        vals.sort_unstable();
        for &q in &[0.0, 0.5, 0.95, 0.99, 1.0] {
            let rank = ((q * vals.len() as f64).ceil() as usize).clamp(1, vals.len());
            let exact = vals[rank - 1];
            let got = h.value_at_quantile(q);
            assert_eq!(
                bucket_of(got),
                bucket_of(exact),
                "q={q}: histogram {got} and oracle {exact} must share a bucket"
            );
            assert!(got >= exact, "q={q}: bucket upper edge {got} must bound the oracle {exact}");
        }
    }

    #[test]
    fn single_value_distributions_are_exact() {
        let mut h = Histogram::new();
        for _ in 0..100 {
            h.record(37);
        }
        assert_eq!(h.value_at_quantile(0.5), 37);
        assert_eq!(h.value_at_quantile(0.99), 37);
        assert_eq!(h.min(), 37);
        assert_eq!(h.max(), 37);
    }

    #[test]
    fn sparse_roundtrip() {
        let mut h = Histogram::new();
        let mut rng = crate::util::rng::Rng::new(99);
        for _ in 0..1_000u64 {
            h.record(rng.range_u64(0, 1 << 30));
        }
        let back =
            Histogram::from_sparse(&h.to_sparse(), h.count(), h.sum(), h.min(), h.max());
        assert_eq!(back, h);
        let empty = Histogram::from_sparse(&[], 0, 0, 0, 0);
        assert_eq!(empty, Histogram::new());
    }

    #[test]
    fn stage_hists_merge_per_stage() {
        let mut a = StageHists::new();
        let mut b = StageHists::new();
        a.record(Stage::Admit, 10);
        b.record(Stage::Admit, 20);
        b.record(Stage::Execute, 500);
        a.merge(&b);
        assert_eq!(a.get(Stage::Admit).count(), 2);
        assert_eq!(a.get(Stage::Execute).count(), 1);
        assert_eq!(a.get(Stage::Route).count(), 0);
        assert_eq!(a.non_empty().count(), 2);
    }
}
