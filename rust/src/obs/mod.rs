//! `obs` — the dependency-free observability layer: end-to-end request
//! tracing (per-stage [`SpanEvent`]s in bounded per-shard rings) and
//! exact-merging log-bucket latency [`Histogram`]s.
//!
//! **Span tracing** answers "where did request #4812 spend its 3ms?":
//! every instrumentation point in the serving pipeline — admit/route in
//! the session, the scheduler sweep, the coalescing dispatcher, the
//! executor, the network server — pushes a fixed-size [`SpanEvent`]
//! keyed by the request's ticket id into a [`ring::SpanRing`]. Tracing
//! is compiled in but **gated by one atomic flag**: disabled, an
//! instrumentation point costs one load and one branch ([`enabled`]);
//! enabled, it costs one clock read and six atomic stores — never a
//! lock, never an allocation. `gta trace` exports the rings as Chrome
//! `trace_event` JSON and as `gta.obs.trace/1` machine JSON
//! ([`chrome`]).
//!
//! **Histograms** ([`hist`]) are always on: they live inside the
//! per-shard metrics (under the mutex those already take) and merge
//! exactly in `RackSnapshot::absorb`, replacing the old lossy
//! max-of-percentiles aggregation. The `Stats` wire frame returns them
//! live from a running server (`gta stats --connect`).
//!
//! See `docs/observability.md` for the span model, ring semantics,
//! bucketing, and the export workflow.

pub mod chrome;
pub mod hist;
pub mod ring;

pub use hist::{Histogram, StageHists};
pub use ring::{SpanRing, RING_CAPACITY};

use std::cell::Cell;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::OnceLock;
use std::time::Instant;

/// One pipeline stage a request (or connection) passes through. The
/// `u8` values are stable: they ride in ring slots and wire frames.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord, Hash)]
#[repr(u8)]
pub enum Stage {
    /// Admission end to end: routing + queue admission (incl. `Reject`
    /// retries; `extra` = requeue attempts).
    Admit = 0,
    /// The routing decision alone (`extra` = chosen shard).
    Route = 1,
    /// Schedule lookup/search in the shard (`extra` = 1 on cache hit).
    Schedule = 2,
    /// The explorer's pruned sweep on a cache miss (`extra` =
    /// candidates evaluated). Absent on cache hits.
    Sweep = 3,
    /// Coalescing wait: dispatcher enqueue → batch flush (`extra` =
    /// batch size).
    Coalesce = 4,
    /// Backend batch execution (`extra` = batch size).
    Execute = 5,
    /// Response assembly after execution/simulation completes.
    Respond = 6,
    /// Network server socket read (`extra` = bytes; trace = conn id).
    NetRead = 7,
    /// Network server frame decode (`extra` = bytes consumed).
    NetDecode = 8,
    /// Network server socket write (`extra` = bytes).
    NetWrite = 9,
}

impl Stage {
    pub const COUNT: usize = 10;

    /// Every stage, in pipeline order.
    pub const ALL: [Stage; Stage::COUNT] = [
        Stage::Admit,
        Stage::Route,
        Stage::Schedule,
        Stage::Sweep,
        Stage::Coalesce,
        Stage::Execute,
        Stage::Respond,
        Stage::NetRead,
        Stage::NetDecode,
        Stage::NetWrite,
    ];

    /// The per-request pipeline in causal order — the order the span
    /// property tests assert start times are monotone in. (`Sweep` is
    /// nested inside `Schedule`; the net stages are per-connection.)
    pub const PIPELINE: [Stage; 6] = [
        Stage::Admit,
        Stage::Route,
        Stage::Schedule,
        Stage::Coalesce,
        Stage::Execute,
        Stage::Respond,
    ];

    pub fn as_u8(self) -> u8 {
        self as u8
    }

    pub fn from_u8(v: u8) -> Option<Stage> {
        Stage::ALL.get(v as usize).copied()
    }

    pub fn name(self) -> &'static str {
        match self {
            Stage::Admit => "admit",
            Stage::Route => "route",
            Stage::Schedule => "schedule",
            Stage::Sweep => "sweep",
            Stage::Coalesce => "coalesce",
            Stage::Execute => "execute",
            Stage::Respond => "respond",
            Stage::NetRead => "net_read",
            Stage::NetDecode => "net_decode",
            Stage::NetWrite => "net_write",
        }
    }

    pub fn from_name(name: &str) -> Option<Stage> {
        Stage::ALL.iter().copied().find(|s| s.name() == name)
    }

    /// Whether this is a network-layer stage (traced per connection,
    /// not per request).
    pub fn is_net(self) -> bool {
        matches!(self, Stage::NetRead | Stage::NetDecode | Stage::NetWrite)
    }
}

/// Shard value for events not attributable to a shard.
pub const NO_SHARD: u16 = u16::MAX;

/// Trace id for events outside any request (batch-pre-pass sweeps).
pub const NO_TRACE: u64 = u64::MAX;

/// One completed span: fixed-size, `Copy`, exactly what a ring slot
/// holds. Times are microseconds since the process-wide [`epoch`].
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct SpanEvent {
    /// The request's ticket id ([`NO_TRACE`] when un-attributed; the
    /// connection id for net stages).
    pub trace_id: u64,
    pub stage: Stage,
    /// Executing shard, [`NO_SHARD`] when not shard-bound.
    pub shard: u16,
    pub start_us: u64,
    pub dur_us: u64,
    /// Stage-specific payload (batch size, cache-hit flag, bytes, …).
    pub extra: u64,
}

/// Trace identity of one request as it moves through the pipeline:
/// trace id = ticket id. `Copy`, 8 bytes — cheap to thread anywhere.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct TraceCtx {
    pub id: u64,
}

impl TraceCtx {
    pub fn new(id: u64) -> TraceCtx {
        TraceCtx { id }
    }

    /// Emit a span for this trace that started at `start_us` and ends
    /// now. No-op (one load + branch) while tracing is disabled.
    pub fn emit_since(self, stage: Stage, shard: u16, start_us: u64, extra: u64) {
        if !enabled() {
            return;
        }
        let end = now_us();
        emit(&SpanEvent {
            trace_id: self.id,
            stage,
            shard,
            start_us,
            dur_us: end.saturating_sub(start_us),
            extra,
        });
    }
}

/// The master switch. All instrumentation points check this first, so
/// the disabled cost is one `Relaxed` load and a branch.
static ENABLED: AtomicBool = AtomicBool::new(false);

pub fn enabled() -> bool {
    // lint: relaxed-ok independent on/off flag; nothing is ordered against it
    ENABLED.load(Ordering::Relaxed)
}

/// Turn span collection on or off process-wide.
pub fn set_enabled(on: bool) {
    // lint: relaxed-ok independent on/off flag; nothing is ordered against it
    ENABLED.store(on, Ordering::Relaxed);
}

/// The process-wide time origin spans are measured against.
fn epoch() -> &'static Instant {
    static EPOCH: OnceLock<Instant> = OnceLock::new();
    EPOCH.get_or_init(Instant::now)
}

/// Microseconds since the process-wide epoch — the span clock. Every
/// instrumentation point shares it, so spans from different threads
/// and shards are directly comparable.
pub fn now_us() -> u64 {
    epoch().elapsed().as_micros() as u64
}

/// Ring registry: one ring per shard slot plus slot 0 for un-sharded
/// events. Shards beyond the table share the last ring (valid, just
/// more contended) — the table is sized for any realistic rack.
const SHARD_SLOTS: usize = 65;

fn rings() -> &'static [SpanRing] {
    static RINGS: OnceLock<Vec<SpanRing>> = OnceLock::new();
    RINGS.get_or_init(|| (0..SHARD_SLOTS).map(|_| SpanRing::new(RING_CAPACITY)).collect())
}

fn ring_slot(shard: u16) -> usize {
    if shard == NO_SHARD {
        0
    } else {
        (shard as usize + 1).min(SHARD_SLOTS - 1)
    }
}

/// Push one completed span into its shard's ring. No-op while tracing
/// is disabled; never blocks or allocates when enabled.
pub fn emit(ev: &SpanEvent) {
    if !enabled() {
        return;
    }
    rings()[ring_slot(ev.shard)].push(ev);
}

/// Collect every buffered span across all rings (oldest first within a
/// ring, then sorted by start time) plus the exact total of events the
/// rings overwrote before collection.
pub fn drain() -> (Vec<SpanEvent>, u64) {
    let mut events = Vec::new();
    let mut dropped = 0u64;
    for r in rings() {
        events.extend(r.snapshot());
        dropped += r.dropped();
    }
    events.sort_by_key(|e| (e.start_us, e.trace_id, e.stage.as_u8()));
    (events, dropped)
}

/// Reset every ring (export/test bookkeeping).
pub fn reset() {
    for r in rings() {
        r.clear();
    }
}

thread_local! {
    /// The request currently being handled on this thread — how code
    /// without a request in its signature (the explorer's sweep)
    /// attributes spans. [`NO_TRACE`] outside any request.
    static CURRENT_TRACE: Cell<u64> = const { Cell::new(NO_TRACE) };
}

/// The trace id of the request this thread is currently handling.
pub fn current_trace() -> u64 {
    CURRENT_TRACE.with(Cell::get)
}

/// Scope guard: restores the previous thread-local trace id on drop.
pub struct TraceGuard {
    prev: u64,
}

/// Mark this thread as handling `trace_id` until the guard drops.
pub fn with_trace(trace_id: u64) -> TraceGuard {
    let prev = CURRENT_TRACE.with(|c| c.replace(trace_id));
    TraceGuard { prev }
}

impl Drop for TraceGuard {
    fn drop(&mut self) {
        let prev = self.prev;
        CURRENT_TRACE.with(|c| c.set(prev));
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stage_codes_roundtrip() {
        for s in Stage::ALL {
            assert_eq!(Stage::from_u8(s.as_u8()), Some(s));
            assert_eq!(Stage::from_name(s.name()), Some(s));
        }
        assert_eq!(Stage::from_u8(200), None);
        assert_eq!(Stage::ALL.len(), Stage::COUNT);
    }

    #[test]
    fn trace_guard_nests_and_restores() {
        assert_eq!(current_trace(), NO_TRACE);
        {
            let _a = with_trace(7);
            assert_eq!(current_trace(), 7);
            {
                let _b = with_trace(9);
                assert_eq!(current_trace(), 9);
            }
            assert_eq!(current_trace(), 7);
        }
        assert_eq!(current_trace(), NO_TRACE);
    }

    #[test]
    fn now_us_is_monotone() {
        let a = now_us();
        let b = now_us();
        assert!(b >= a);
    }
}
