//! The lock-light bounded span ring: fixed capacity, overwrite-oldest,
//! exact drop accounting, zero allocation on the hot path.
//!
//! Writers claim a monotonically increasing **ticket** with one
//! `fetch_add` on the write cursor and publish the event into slot
//! `ticket % capacity` under a per-slot sequence word (a seqlock): the
//! sequence goes odd (`2*ticket + 1`) while the fields are being
//! stored and even (`2*ticket + 2`) once they are complete. A writer
//! therefore **never blocks, never allocates, and never waits on a
//! reader** — two writers racing for the same slot simply means the
//! older ticket's event is overwritten, which is the ring's contract.
//!
//! Readers ([`SpanRing::snapshot`]) validate each slot by reading the
//! sequence before and after the fields: a torn or overwritten slot
//! shows a mismatched sequence and is skipped, never mis-read. Dropped
//! events are exactly `total - capacity` (clamped at zero): every push
//! beyond capacity overwrites precisely one older event.
//!
//! Every atomic here is `Relaxed` except the publishing/validating
//! sequence accesses: the per-slot seqlock is the only ordering that
//! matters, and the cursor is a pure ticket counter.

use super::{SpanEvent, Stage};
use std::sync::atomic::{fence, AtomicU64, Ordering};

/// `u64` words per slot: seq, trace, stage|shard, start, dur, extra.
const WORDS: usize = 6;

/// Default per-ring capacity (events). 4096 events × 48 bytes = 192 KiB
/// per ring — a fixed budget chosen to hold several seconds of serving
/// spans at typical rates; the ring overwrites beyond it by design, so
/// a bigger burst costs dropped *old* events, never memory growth.
pub const RING_CAPACITY: usize = 4096;

/// A bounded multi-producer span ring. One instance per shard (plus
/// one for un-sharded events) lives in the global registry
/// (`obs::emit`); tests may construct private rings freely.
pub struct SpanRing {
    /// `capacity * WORDS` atomics, flat. Fixed at construction — the
    /// hot path never allocates or reserves.
    slots: Box<[AtomicU64]>,
    capacity: usize,
    /// Total events ever pushed (the next ticket).
    cursor: AtomicU64,
}

impl SpanRing {
    /// Allocate a ring of `capacity` slots (min 1). This is the ONLY
    /// allocation the ring ever performs; pushes are allocation-free.
    pub fn new(capacity: usize) -> SpanRing {
        let capacity = capacity.max(1);
        let slots: Vec<AtomicU64> =
            (0..capacity * WORDS).map(|_| AtomicU64::new(0)).collect();
        SpanRing { slots: slots.into_boxed_slice(), capacity, cursor: AtomicU64::new(0) }
    }

    pub fn capacity(&self) -> usize {
        self.capacity
    }

    /// Total events ever pushed into this ring.
    pub fn total(&self) -> u64 {
        // lint: relaxed-ok pure monotone ticket counter; no data is ordered against it
        self.cursor.load(Ordering::Relaxed)
    }

    /// Events overwritten before any reader saw them: exactly
    /// `total - capacity`, clamped at zero — each push past capacity
    /// overwrites exactly one older slot.
    pub fn dropped(&self) -> u64 {
        self.total().saturating_sub(self.capacity as u64)
    }

    /// Publish one event. Never blocks, never allocates; overwrites the
    /// oldest slot when full.
    pub fn push(&self, ev: &SpanEvent) {
        // lint: relaxed-ok ticket claim only orders the slot index; the slot's seqlock orders the payload
        let t = self.cursor.fetch_add(1, Ordering::Relaxed);
        let base = (t as usize % self.capacity) * WORDS;
        let seq = &self.slots[base];
        // odd = write in progress; Release so a reader that saw the
        // previous even value cannot see the new fields early
        seq.store(2 * t + 1, Ordering::Release);
        // lint: relaxed-ok payload stores are ordered by the slot seqlock, not individually
        self.slots[base + 1].store(ev.trace_id, Ordering::Relaxed);
        // lint: relaxed-ok payload stores are ordered by the slot seqlock, not individually
        self.slots[base + 2].store(pack_stage_shard(ev.stage, ev.shard), Ordering::Relaxed);
        // lint: relaxed-ok payload stores are ordered by the slot seqlock, not individually
        self.slots[base + 3].store(ev.start_us, Ordering::Relaxed);
        // lint: relaxed-ok payload stores are ordered by the slot seqlock, not individually
        self.slots[base + 4].store(ev.dur_us, Ordering::Relaxed);
        // lint: relaxed-ok payload stores are ordered by the slot seqlock, not individually
        self.slots[base + 5].store(ev.extra, Ordering::Relaxed);
        // even = complete, tagged with the ticket so readers can tell
        // WHICH event occupies the slot (not just that one does)
        fence(Ordering::Release);
        seq.store(2 * t + 2, Ordering::Release);
    }

    /// Read one slot by ticket; `None` if it was torn or overwritten.
    fn read_ticket(&self, t: u64) -> Option<SpanEvent> {
        let base = (t as usize % self.capacity) * WORDS;
        let seq = &self.slots[base];
        let s1 = seq.load(Ordering::Acquire);
        if s1 != 2 * t + 2 {
            return None; // in-progress write, or a different ticket
        }
        // lint: relaxed-ok payload loads are fenced against the seq re-read below
        let trace_id = self.slots[base + 1].load(Ordering::Relaxed);
        // lint: relaxed-ok payload loads are fenced against the seq re-read below
        let packed = self.slots[base + 2].load(Ordering::Relaxed);
        // lint: relaxed-ok payload loads are fenced against the seq re-read below
        let start_us = self.slots[base + 3].load(Ordering::Relaxed);
        // lint: relaxed-ok payload loads are fenced against the seq re-read below
        let dur_us = self.slots[base + 4].load(Ordering::Relaxed);
        // lint: relaxed-ok payload loads are fenced against the seq re-read below
        let extra = self.slots[base + 5].load(Ordering::Relaxed);
        // the fence keeps the payload loads from drifting past the
        // validating re-read; a concurrent overwrite flips seq first
        fence(Ordering::Acquire);
        if seq.load(Ordering::Acquire) != s1 {
            return None;
        }
        let (stage, shard) = unpack_stage_shard(packed)?;
        Some(SpanEvent { trace_id, stage, shard, start_us, dur_us, extra })
    }

    /// Collect every currently-valid event, oldest first. Runs
    /// concurrently with writers: slots being overwritten mid-read are
    /// skipped, never mis-read.
    pub fn snapshot(&self) -> Vec<SpanEvent> {
        let end = self.total();
        let start = end.saturating_sub(self.capacity as u64);
        let mut out = Vec::with_capacity((end - start) as usize);
        for t in start..end {
            if let Some(ev) = self.read_ticket(t) {
                out.push(ev);
            }
        }
        out
    }

    /// Reset to empty (export/test bookkeeping — racing writers may
    /// land events with stale tickets that then fail validation, which
    /// is safe: they read as absent).
    pub fn clear(&self) {
        for w in self.slots.iter() {
            // lint: relaxed-ok reset path; seq 0 never validates as any ticket's even value
            w.store(0, Ordering::Relaxed);
        }
        // lint: relaxed-ok reset path; see above
        self.cursor.store(0, Ordering::Relaxed);
    }
}

fn pack_stage_shard(stage: Stage, shard: u16) -> u64 {
    ((stage.as_u8() as u64) << 16) | shard as u64
}

fn unpack_stage_shard(packed: u64) -> Option<(Stage, u16)> {
    let stage = Stage::from_u8((packed >> 16) as u8)?;
    Some((stage, (packed & 0xFFFF) as u16))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::obs::NO_SHARD;

    fn ev(trace: u64, start: u64) -> SpanEvent {
        SpanEvent {
            trace_id: trace,
            stage: Stage::Execute,
            shard: NO_SHARD,
            start_us: start,
            dur_us: 1,
            extra: trace,
        }
    }

    #[test]
    fn holds_capacity_then_overwrites_oldest() {
        let r = SpanRing::new(8);
        for i in 0..8u64 {
            r.push(&ev(i, i));
        }
        assert_eq!(r.total(), 8);
        assert_eq!(r.dropped(), 0);
        let snap = r.snapshot();
        assert_eq!(snap.len(), 8);
        assert_eq!(snap[0].trace_id, 0);
        assert_eq!(snap[7].trace_id, 7);

        for i in 8..11u64 {
            r.push(&ev(i, i));
        }
        assert_eq!(r.total(), 11);
        assert_eq!(r.dropped(), 3, "drop count must be exactly total - capacity");
        let snap = r.snapshot();
        assert_eq!(snap.len(), 8);
        assert_eq!(snap.first().map(|e| e.trace_id), Some(3), "oldest 3 overwritten");
        assert_eq!(snap.last().map(|e| e.trace_id), Some(10));
    }

    #[test]
    fn concurrent_pushes_keep_exact_drop_accounting() {
        let r = std::sync::Arc::new(SpanRing::new(64));
        let threads = 8u64;
        let per = 1000u64;
        let handles: Vec<_> = (0..threads)
            .map(|t| {
                let r = std::sync::Arc::clone(&r);
                std::thread::spawn(move || {
                    for i in 0..per {
                        r.push(&ev(t * per + i, i));
                    }
                })
            })
            .collect();
        for h in handles {
            h.join().unwrap();
        }
        assert_eq!(r.total(), threads * per);
        assert_eq!(r.dropped(), threads * per - 64);
        // quiescent now: every slot holds its final ticket, so the
        // snapshot is complete and every event is one that was pushed
        let snap = r.snapshot();
        assert_eq!(snap.len(), 64);
        for e in &snap {
            assert!(e.trace_id < threads * per);
            assert_eq!(e.dur_us, 1);
        }
    }

    #[test]
    fn snapshot_during_writes_never_tears() {
        let r = std::sync::Arc::new(SpanRing::new(16));
        let stop = std::sync::Arc::new(std::sync::atomic::AtomicBool::new(false));
        let writer = {
            let (r, stop) = (std::sync::Arc::clone(&r), std::sync::Arc::clone(&stop));
            std::thread::spawn(move || {
                let mut i = 0u64;
                while !stop.load(Ordering::Relaxed) {
                    // trace_id and extra always match: a torn read would
                    // break the pairing
                    r.push(&ev(i, i));
                    i += 1;
                }
            })
        };
        for _ in 0..200 {
            for e in r.snapshot() {
                assert_eq!(e.trace_id, e.extra, "torn slot surfaced in a snapshot");
                assert_eq!(e.trace_id, e.start_us);
            }
        }
        stop.store(true, Ordering::Relaxed);
        writer.join().unwrap();
    }

    #[test]
    fn clear_resets_counts() {
        let r = SpanRing::new(4);
        for i in 0..10u64 {
            r.push(&ev(i, i));
        }
        r.clear();
        assert_eq!(r.total(), 0);
        assert_eq!(r.dropped(), 0);
        assert!(r.snapshot().is_empty());
    }
}
