//! Fig. 2 — classification of tensor operators along the two axes of §3.2:
//! **algorithmic parallelism** (vectorizable extent) and **arithmetic
//! intensity** (MACs per compulsorily-moved element).
//!
//! The classification decides how GTA executes an operator: intensity
//! above a threshold ⇒ lower to p-GEMM on the systolic array; below ⇒
//! compile to vector (SIMD) mode.

use super::{PGemm, TensorOp, VectorOp};

/// Named operator families placed on the Fig. 2 scatter.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum OperatorFamily {
    Gemm,
    Conv,
    Gemv,
    Mttkrp,
    Ttmc,
    Dot,
    Axpy,
    FirFilter,
    Fft,
    Stencil,
    ElementWise,
    Reduction,
    Ntt,
    BigNumMul,
}

impl OperatorFamily {
    pub const ALL: [OperatorFamily; 14] = [
        OperatorFamily::Gemm,
        OperatorFamily::Conv,
        OperatorFamily::Gemv,
        OperatorFamily::Mttkrp,
        OperatorFamily::Ttmc,
        OperatorFamily::Dot,
        OperatorFamily::Axpy,
        OperatorFamily::FirFilter,
        OperatorFamily::Fft,
        OperatorFamily::Stencil,
        OperatorFamily::ElementWise,
        OperatorFamily::Reduction,
        OperatorFamily::Ntt,
        OperatorFamily::BigNumMul,
    ];

    pub fn name(self) -> &'static str {
        match self {
            OperatorFamily::Gemm => "GEMM",
            OperatorFamily::Conv => "CONV",
            OperatorFamily::Gemv => "GEMV",
            OperatorFamily::Mttkrp => "MTTKRP",
            OperatorFamily::Ttmc => "TTMc",
            OperatorFamily::Dot => "DOT",
            OperatorFamily::Axpy => "AXPY",
            OperatorFamily::FirFilter => "FIR",
            OperatorFamily::Fft => "FFT",
            OperatorFamily::Stencil => "STENCIL",
            OperatorFamily::ElementWise => "ELTWISE",
            OperatorFamily::Reduction => "REDUCE",
            OperatorFamily::Ntt => "NTT",
            OperatorFamily::BigNumMul => "BNM",
        }
    }

    /// Indicative (parallelism, intensity) coordinates for a representative
    /// instance — the Fig. 2 placement. Parallelism = independent outputs;
    /// intensity = MACs/element. Representative sizes follow the paper's
    /// workload suite.
    pub fn representative(self) -> (f64, f64) {
        let g = |m: u64, n: u64, k: u64| {
            let p = PGemm::new(m, n, k, crate::precision::Precision::Fp32);
            (p.parallelism() as f64, p.arithmetic_intensity())
        };
        match self {
            OperatorFamily::Gemm => g(512, 512, 512),
            OperatorFamily::Conv => g(256, 13 * 13, 3 * 3 * 256),
            OperatorFamily::Gemv => g(1, 4096, 4096),
            OperatorFamily::Mttkrp => g(64 * 64, 32, 64),
            OperatorFamily::Ttmc => g(64 * 64, 64, 64),
            OperatorFamily::Dot => g(1, 1, 65536),
            OperatorFamily::Axpy => (65536.0, 1.0 / 3.0),
            OperatorFamily::FirFilter => g(1, 16384, 256),
            OperatorFamily::Fft => (4096.0, 0.75), // butterflies: log-depth, low reuse
            OperatorFamily::Stencil => g(1, 65536, 9),
            OperatorFamily::ElementWise => (1_048_576.0, 1.0 / 3.0),
            OperatorFamily::Reduction => (1.0, 1.0),
            OperatorFamily::Ntt => g(1, 8192, 64),
            OperatorFamily::BigNumMul => g(64, 64, 1),
        }
    }
}

/// Execution class an operator lowers to (§3.2).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum OpClass {
    /// Reuse-bearing: map onto the systolic array as a p-GEMM.
    PGemm,
    /// Reuse-free: execute in the VPU's SIMD mode.
    Vector,
}

/// Intensity threshold below which p-GEMM lowering cannot beat SIMD:
/// at intensity ≤ 1 every fetched element is used at most once, so the
/// systolic array's reuse machinery buys nothing.
pub const INTENSITY_THRESHOLD: f64 = 1.0;

/// Classify a lowered operator.
pub fn classify(op: &TensorOp) -> OpClass {
    match op {
        TensorOp::Vector(_) => OpClass::Vector,
        TensorOp::PGemm(g) => {
            if g.arithmetic_intensity() > INTENSITY_THRESHOLD {
                OpClass::PGemm
            } else {
                OpClass::Vector
            }
        }
    }
}

/// Classify a family by its representative instance (Fig. 2 partition).
pub fn classify_family(f: OperatorFamily) -> OpClass {
    let (_, intensity) = f.representative();
    if intensity > INTENSITY_THRESHOLD {
        OpClass::PGemm
    } else {
        OpClass::Vector
    }
}

/// A point of the Fig. 2 scatter.
#[derive(Debug, Clone)]
pub struct Fig2Point {
    pub family: String,
    pub parallelism: f64,
    pub intensity: f64,
    pub class: OpClass,
}

/// Regenerate the Fig. 2 dataset.
pub fn fig2_points() -> Vec<Fig2Point> {
    OperatorFamily::ALL
        .iter()
        .map(|&f| {
            let (p, i) = f.representative();
            Fig2Point {
                family: f.name().to_string(),
                parallelism: p,
                intensity: i,
                class: classify_family(f),
            }
        })
        .collect()
}

/// Degenerate-GEMM vectorization fallback: a p-GEMM that is really a dot
/// or thin GEMV can be re-expressed as a vector op (the paper's "some
/// p-GEMM operators may get better result from vectorization", §5).
pub fn as_vector_fallback(g: &PGemm) -> Option<VectorOp> {
    if g.m == 1 && g.n == 1 {
        Some(VectorOp::new(g.k, g.precision, super::VectorKind::Axpy))
    } else if g.is_degenerate() {
        Some(VectorOp::new(
            g.m.max(g.n) * g.k,
            g.precision,
            super::VectorKind::Axpy,
        ))
    } else {
        None
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ops::VectorKind;
    use crate::precision::Precision;

    #[test]
    fn gemm_like_families_are_pgemm_class() {
        for f in [
            OperatorFamily::Gemm,
            OperatorFamily::Conv,
            OperatorFamily::Mttkrp,
            OperatorFamily::Ttmc,
        ] {
            assert_eq!(classify_family(f), OpClass::PGemm, "{f:?}");
        }
    }

    #[test]
    fn reuse_free_families_are_vector_class() {
        for f in [
            OperatorFamily::Axpy,
            OperatorFamily::ElementWise,
            OperatorFamily::Fft,
            OperatorFamily::Reduction,
        ] {
            assert_eq!(classify_family(f), OpClass::Vector, "{f:?}");
        }
    }

    #[test]
    fn fig2_has_all_families_and_spread() {
        let pts = fig2_points();
        assert_eq!(pts.len(), OperatorFamily::ALL.len());
        let n_pgemm = pts.iter().filter(|p| p.class == OpClass::PGemm).count();
        let n_vec = pts.len() - n_pgemm;
        // square GEMM/CONV/contractions sit deep in the p-GEMM region;
        // GEMV/FIR/outer-product land near intensity≈1 and vectorize
        assert!(n_pgemm >= 4, "expected a populated p-GEMM region, got {n_pgemm}");
        assert!(n_vec >= 6, "expected a populated vector region, got {n_vec}");
    }

    #[test]
    fn dot_product_falls_back_to_vector() {
        let g = PGemm::new(1, 1, 65536, Precision::Fp32);
        assert_eq!(classify(&TensorOp::PGemm(g)), OpClass::Vector);
        let v = as_vector_fallback(&g).unwrap();
        assert_eq!(v.len, 65536);
        assert_eq!(v.kind, VectorKind::Axpy);
    }

    #[test]
    fn big_gemm_classified_pgemm() {
        let g = PGemm::new(512, 512, 512, Precision::Bp16);
        assert_eq!(classify(&TensorOp::PGemm(g)), OpClass::PGemm);
    }
}
