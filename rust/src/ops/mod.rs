//! Tensor-operator IR: the paper's §3.2 classification of tensor algebra
//! into **p-GEMM** (anything with reuse, lowered to an M×N×K contraction of
//! arbitrary — possibly degenerate — size) and **vector** operators
//! (no arithmetic intensity, compiled to SIMD).

pub mod classify;

use crate::precision::Precision;

/// A pseudo-GEMM: `C[M,N] += A[M,K] · B[K,N]` at some precision.
///
/// "p" is for *pseudo*: M/N/K may be 1 (GEMV, dot, outer product) — the
/// paper folds all reuse-bearing operators into this one shape (§3.2).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct PGemm {
    pub m: u64,
    pub n: u64,
    pub k: u64,
    pub precision: Precision,
}

impl PGemm {
    pub fn new(m: u64, n: u64, k: u64, precision: Precision) -> Self {
        assert!(m > 0 && n > 0 && k > 0, "degenerate dims are 1, not 0");
        PGemm { m, n, k, precision }
    }

    /// Multiply-accumulate count (at workload precision, not limb level).
    pub fn macs(&self) -> u64 {
        self.m * self.n * self.k
    }

    /// Compulsory traffic in elements: read A and B once, write C once.
    pub fn compulsory_elems(&self) -> u64 {
        self.m * self.k + self.k * self.n + self.m * self.n
    }

    /// Compulsory traffic in bytes.
    pub fn compulsory_bytes(&self) -> u64 {
        self.compulsory_elems() * self.precision.bytes()
    }

    /// Arithmetic intensity: MACs per compulsorily-moved element.
    pub fn arithmetic_intensity(&self) -> f64 {
        self.macs() as f64 / self.compulsory_elems() as f64
    }

    /// Algorithmic parallelism: independent output elements (M·N) —
    /// the vectorizable extent of the kernel.
    pub fn parallelism(&self) -> u64 {
        self.m * self.n
    }

    /// Is this effectively a matrix-vector product / dot product?
    pub fn is_degenerate(&self) -> bool {
        self.m == 1 || self.n == 1
    }
}

/// Element-wise/reduction work with no reuse opportunity: runs in the
/// VPU's native SIMD mode on GTA.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum VectorKind {
    /// z = x ⊙ y (mul/add/sub/min/max …), 1 MAC-equivalent per element
    Map,
    /// z = a·x + y
    Axpy,
    /// scalar = Σ reduce
    Reduce,
    /// table lookup / activation / rounding — 1 op per element, no MAC
    Activation,
}

/// A vector operator over `len` elements.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct VectorOp {
    pub len: u64,
    pub precision: Precision,
    pub kind: VectorKind,
}

impl VectorOp {
    pub fn new(len: u64, precision: Precision, kind: VectorKind) -> Self {
        assert!(len > 0);
        VectorOp { len, precision, kind }
    }

    /// Operation count (MAC-equivalents).
    pub fn ops(&self) -> u64 {
        match self.kind {
            VectorKind::Map | VectorKind::Activation | VectorKind::Reduce => self.len,
            VectorKind::Axpy => self.len, // fused mul-add = 1 MAC
        }
    }

    /// Element traffic: inputs + output.
    pub fn elems(&self) -> u64 {
        match self.kind {
            VectorKind::Map | VectorKind::Axpy => 3 * self.len,
            VectorKind::Reduce => self.len + 1,
            VectorKind::Activation => 2 * self.len,
        }
    }

    pub fn bytes(&self) -> u64 {
        self.elems() * self.precision.bytes()
    }
}

/// A tensor operator after decomposition (§3.2): either reuse-bearing
/// (p-GEMM) or reuse-free (vector).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum TensorOp {
    PGemm(PGemm),
    Vector(VectorOp),
}

impl TensorOp {
    pub fn precision(&self) -> Precision {
        match self {
            TensorOp::PGemm(g) => g.precision,
            TensorOp::Vector(v) => v.precision,
        }
    }

    pub fn macs(&self) -> u64 {
        match self {
            TensorOp::PGemm(g) => g.macs(),
            TensorOp::Vector(v) => v.ops(),
        }
    }

    pub fn compulsory_bytes(&self) -> u64 {
        match self {
            TensorOp::PGemm(g) => g.compulsory_bytes(),
            TensorOp::Vector(v) => v.bytes(),
        }
    }

    pub fn gemm(m: u64, n: u64, k: u64, p: Precision) -> TensorOp {
        TensorOp::PGemm(PGemm::new(m, n, k, p))
    }

    pub fn vector(len: u64, p: Precision, kind: VectorKind) -> TensorOp {
        TensorOp::Vector(VectorOp::new(len, p, kind))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pgemm_counts() {
        let g = PGemm::new(4, 8, 16, Precision::Int8);
        assert_eq!(g.macs(), 512);
        assert_eq!(g.compulsory_elems(), 4 * 16 + 16 * 8 + 4 * 8);
        assert_eq!(g.compulsory_bytes(), g.compulsory_elems());
        assert!(!g.is_degenerate());
        assert!(PGemm::new(1, 8, 16, Precision::Int8).is_degenerate());
    }

    #[test]
    fn intensity_grows_with_size() {
        let small = PGemm::new(4, 4, 4, Precision::Fp32);
        let big = PGemm::new(256, 256, 256, Precision::Fp32);
        assert!(big.arithmetic_intensity() > small.arithmetic_intensity());
    }

    #[test]
    fn vector_op_has_no_reuse() {
        let v = VectorOp::new(1024, Precision::Fp32, VectorKind::Map);
        // intensity = ops/elems = 1/3 < 1: no reuse, the Fig 2 bottom band
        assert!((v.ops() as f64 / v.elems() as f64) < 1.0);
    }

    #[test]
    #[should_panic]
    fn zero_dims_rejected() {
        PGemm::new(0, 1, 1, Precision::Int8);
    }
}
