//! Multi-precision accumulator model (paper Fig. 3).
//!
//! The systolic array emits 16-bit limb partial products; the accumulator
//! is a tree of basic units that shift-adds four partial products per
//! doubling of width ("a 16-bit accumulator unit takes four 16-bit
//! operands X₁Y₁, X₂Y₁, X₁Y₂, X₂Y₂ ... and uses shift-add operations").
//! Carries between limbs of a big-number product are also resolved here —
//! the array itself never sees a carry.

/// One basic 16-bit accumulator unit: combine the four cross partial
/// products of a 16×16-bit multiplication split into 8-bit halves.
///
/// `x = x2·2⁸ + x1`, `y = y2·2⁸ + y1` ⇒
/// `x·y = x1y1 + (x2y1 + x1y2)·2⁸ + x2y2·2¹⁶`.
pub fn unit16(x1y1: i64, x2y1: i64, x1y2: i64, x2y2: i64) -> i64 {
    x1y1 + ((x2y1 + x1y2) << 8) + (x2y2 << 16)
}

/// Recursively combine an `n×n` grid of limb partial products
/// (`grid[i][j] = xᵢ·yⱼ`, little-endian limbs) into the full product.
/// This is the accumulator tree the MPRA pairs with an `n`-limb mapping.
pub fn combine(grid: &[Vec<i64>]) -> i64 {
    let n = grid.len();
    let mut acc = 0i64;
    for (i, row) in grid.iter().enumerate() {
        assert_eq!(row.len(), n, "partial-product grid must be square");
        for (j, &p) in row.iter().enumerate() {
            // lint: allow(R1) shift exponent bounded by 8 * (2 * n_limbs) — far below u32::MAX
            acc = acc.wrapping_add(p.wrapping_shl(8 * (i + j) as u32));
        }
    }
    acc
}

/// Carry-propagate a pre-carry limb vector (the BNM accumulator step):
/// turn column sums `c[k] = Σ_{i+j=k} aᵢbⱼ` into proper base-256 limbs.
pub fn carry_propagate(pre: &[i64]) -> Vec<u8> {
    let mut out = Vec::with_capacity(pre.len() + 8);
    let mut carry: i64 = 0;
    for &v in pre {
        let s = v + carry;
        // lint: allow(R1) masked to one byte before the cast — lossless by construction
        out.push((s & 0xFF) as u8);
        carry = s >> 8;
    }
    while carry != 0 {
        // lint: allow(R1) masked to one byte before the cast — lossless by construction
        out.push((carry & 0xFF) as u8);
        carry >>= 8;
    }
    out
}

/// Interpret little-endian base-256 limbs as a big unsigned integer,
/// returned as decimal string (for display/verification of BNM results
/// beyond u128 range).
pub fn limbs_to_decimal(limbs: &[u8]) -> String {
    // schoolbook base conversion; fine for the ≤128-limb artifacts
    let mut digits: Vec<u8> = vec![0]; // little-endian decimal digits
    for &l in limbs.iter().rev() {
        // digits = digits*256 + l
        // lint: allow(R1) u8 -> u32 is a lossless widening
        let mut carry = l as u32;
        for d in digits.iter_mut() {
            // lint: allow(R1) u8 -> u32 is a lossless widening
            let v = (*d as u32) * 256 + carry;
            // lint: allow(R1) v % 10 fits a u8 by construction
            *d = (v % 10) as u8;
            carry = v / 10;
        }
        while carry > 0 {
            // lint: allow(R1) carry % 10 fits a u8 by construction
            digits.push((carry % 10) as u8);
            carry /= 10;
        }
    }
    let s: String = digits.iter().rev().map(|d| (b'0' + d) as char).collect();
    let s = s.trim_start_matches('0');
    if s.is_empty() { "0".to_string() } else { s.to_string() }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::precision::limbs::decompose;

    #[test]
    fn unit16_reconstructs_16bit_product() {
        for &(x, y) in &[(0x1234i64, 0x5678i64), (255, 255), (1, 0x7FFF)] {
            let (x1, x2) = (x & 0xFF, x >> 8);
            let (y1, y2) = (y & 0xFF, y >> 8);
            assert_eq!(unit16(x1 * y1, x2 * y1, x1 * y2, x2 * y2), x * y);
        }
    }

    #[test]
    fn combine_reconstructs_wide_products() {
        // 32-bit (4-limb) signed product, exact in i64
        for &(x, y) in &[(0x1234_5678i64, 0x0EDC_BA98i64), (-123456, 789012)] {
            let xs = decompose(x, 4);
            let ys = decompose(y, 4);
            let grid: Vec<Vec<i64>> =
                xs.iter().map(|&xi| ys.iter().map(|&yj| xi * yj).collect()).collect();
            assert_eq!(combine(&grid), x * y);
        }
    }

    #[test]
    fn carry_propagation_normalizes() {
        // 255*255 = 65025 -> pre-carry [65025]; limbs 0x01 0xFE 0x00 ...
        let limbs = carry_propagate(&[65025]);
        assert_eq!(limbs[0], 0x01);
        assert_eq!(limbs[1], 0xFE);
        assert_eq!(limbs.get(2).copied().unwrap_or(0), 0);
    }

    #[test]
    fn decimal_conversion() {
        assert_eq!(limbs_to_decimal(&[0]), "0");
        assert_eq!(limbs_to_decimal(&[1, 1]), "257");
        // 2^64 = 18446744073709551616 : limb 8 set
        let mut l = vec![0u8; 9];
        l[8] = 1;
        assert_eq!(limbs_to_decimal(&l), "18446744073709551616");
    }
}
