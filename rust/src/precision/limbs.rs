//! Limb algebra: the scalar model of the MPRA datapath, plus the
//! plane-decomposed fast kernels the serve path runs on.
//!
//! Mirrors `python/compile/kernels/ref.py` exactly (little-endian 8-bit
//! limbs, signed-MSB scheme) so the rust side can independently verify the
//! numerics that come back from the PJRT-executed Pallas kernels.
//!
//! Two tiers live here:
//!
//! * **Scalar oracle** ([`limb_mul`], [`limb_gemm`],
//!   [`bignum_mul_precarry`]) — the direct transcription of §3.1: every
//!   scalar product re-decomposes both operands and shift-adds all `n²`
//!   limb cross-products. Deliberately naive; this is the reference the
//!   Pallas kernels AND the fast path below are checked against.
//! * **Plane kernels** ([`Workspace`], [`plane_gemm`]) — each operand
//!   matrix is decomposed ONCE into per-limb planes (plane `p` holds limb
//!   `p` of every element, row-major), then a cache-blocked wrapping-i64
//!   micro-kernel accumulates one partial GEMM per plane pair `(p, q)`,
//!   pre-shifted by `8(p+q)`. That is how the paper's array actually
//!   computes (operand planes stream through the MPRA; nothing is
//!   re-decomposed per MAC), and it is *provably bit-identical* to the
//!   oracle: all intermediate sums are two's-complement wrapping adds,
//!   i.e. addition in ℤ/2⁶⁴ — associative and commutative — and the final
//!   [`truncate`] is reduction mod `2^width`, which every skipped
//!   (`shift ≥ width`) term and every dropped intermediate truncation is
//!   congruent to. See `docs/kernels.md` for the full argument.

/// Split a signed value into `n` little-endian limbs.
///
/// Lower limbs are unsigned bytes; the TOP limb is sign-extended (the
/// signed-MSB scheme of the Fig. 3 accumulator), so the value recomposes
/// exactly for in-range inputs.
pub fn decompose(x: i64, n: u32) -> Vec<i64> {
    (0..n)
        .map(|i| {
            if i == n - 1 {
                x >> (8 * i)
            } else {
                (x >> (8 * i)) & 0xFF
            }
        })
        .collect()
}

/// Inverse of [`decompose`].
pub fn recompose(limbs: &[i64]) -> i64 {
    limbs
        .iter()
        .enumerate()
        // lint: allow(R1) shift exponent bounded by 8 * n_limbs — far below u32::MAX
        .map(|(i, &l)| l.wrapping_shl(8 * i as u32))
        .fold(0i64, i64::wrapping_add)
}

/// One scalar multi-precision product the way the array computes it:
/// all `n²` limb cross-products, shift-added (§3.1, Fig. 1a).
pub fn limb_mul(x: i64, y: i64, n: u32, width: u32) -> i64 {
    let xs = decompose(x, n);
    let ys = decompose(y, n);
    let mut acc = 0i64;
    for (i, &xi) in xs.iter().enumerate() {
        for (j, &yj) in ys.iter().enumerate() {
            // lint: allow(R1) shift exponent bounded by 8 * (2 * n_limbs) — far below u32::MAX
            let shift = 8 * (i + j) as u32;
            if shift >= width {
                continue; // vanishes mod 2^width
            }
            acc = acc.wrapping_add(xi.wrapping_mul(yj).wrapping_shl(shift));
        }
    }
    truncate(acc, width)
}

/// Limb-decomposed GEMM over i64 scalars — the oracle the PJRT results are
/// checked against (`C = A·B` mod `2^width`, row-major).
pub fn limb_gemm(
    a: &[i64],
    b: &[i64],
    m: usize,
    k: usize,
    n: usize,
    n_limbs: u32,
    width: u32,
) -> Vec<i64> {
    assert_eq!(a.len(), m * k);
    assert_eq!(b.len(), k * n);
    let mut c = vec![0i64; m * n];
    for i in 0..m {
        for j in 0..n {
            let mut acc = 0i64;
            for kk in 0..k {
                acc = acc.wrapping_add(limb_mul(a[i * k + kk], b[kk * n + j], n_limbs, width));
            }
            c[i * n + j] = truncate(acc, width);
        }
    }
    c
}

/// Wrap a value to `width` bits with sign extension (two's-complement
/// accumulator semantics).
pub fn truncate(v: i64, width: u32) -> i64 {
    if width >= 64 {
        v
    } else {
        (v << (64 - width)) >> (64 - width)
    }
}

/// Big-number (BNM) pre-carry limb product: `c[k] = Σ_{i+j=k} a_i·b_j`
/// (the rank-1 p-GEMM the bignum Pallas kernel computes).
pub fn bignum_mul_precarry(a: &[u8], b: &[u8]) -> Vec<i64> {
    if a.is_empty() || b.is_empty() {
        return vec![];
    }
    let mut c = vec![0i64; a.len() + b.len() - 1];
    for (i, &ai) in a.iter().enumerate() {
        for (j, &bj) in b.iter().enumerate() {
            c[i + j] += ai as i64 * bj as i64;
        }
    }
    c
}

/// Reusable scratch for the plane kernels: limb planes of both operands
/// plus the shared i64 accumulator. Buffers grow to the high-water mark
/// of the shapes seen and are then reused verbatim — the steady-state hot
/// path allocates nothing. Results are valid until the next call on the
/// same workspace (each call starts by clearing/refilling the buffers it
/// uses, so interleaving arbitrary other calls cannot change what a given
/// input produces — see `prop_workspace_reuse_is_deterministic`).
#[derive(Debug, Default)]
pub struct Workspace {
    /// Plane-major limbs of A: plane `p` occupies `[p·m·k, (p+1)·m·k)`,
    /// row-major within the plane.
    a_planes: Vec<i64>,
    /// Plane-major limbs of B, same layout over `k·n`.
    b_planes: Vec<i64>,
    /// The Fig. 3 accumulator: one wrapping i64 per output element (also
    /// doubles as the pre-carry buffer for [`Workspace::bignum_precarry`]).
    acc: Vec<i64>,
}

/// Cache-block sizes for the plane-pair micro-kernel: a `KC`-deep slice
/// of a B plane row-block is `NC·8 = 1 KiB` per row, so the accumulator
/// row segment and the streamed B rows stay L1-resident across the `kk`
/// loop. The serve-path 64×64 tiles fit a single block; blocking only
/// engages for larger oracle shapes.
const KC: usize = 128;
const NC: usize = 128;

/// One plane pair's contribution: `acc += (A_p << shift) · B_q`, all
/// arithmetic wrapping in i64. `shift` is pre-applied to the A element
/// (valid because `(a·2^s mod 2⁶⁴)·b ≡ a·b·2^s (mod 2⁶⁴)`), so the inner
/// loop is a plain multiply-accumulate.
fn plane_pair_accumulate(
    acc: &mut [i64],
    a_plane: &[i64],
    b_plane: &[i64],
    m: usize,
    k: usize,
    n: usize,
    shift: u32,
) {
    for kk0 in (0..k).step_by(KC) {
        let kc = KC.min(k - kk0);
        for j0 in (0..n).step_by(NC) {
            let nc = NC.min(n - j0);
            for i in 0..m {
                let a_row = &a_plane[i * k + kk0..i * k + kk0 + kc];
                let c_row = &mut acc[i * n + j0..i * n + j0 + nc];
                for (dk, &aik) in a_row.iter().enumerate() {
                    if aik == 0 {
                        continue; // contributes exactly 0 to every lane
                    }
                    let a_shifted = aik.wrapping_shl(shift);
                    let b_row = &b_plane[(kk0 + dk) * n + j0..(kk0 + dk) * n + j0 + nc];
                    for (c, &b) in c_row.iter_mut().zip(b_row) {
                        *c = c.wrapping_add(a_shifted.wrapping_mul(b));
                    }
                }
            }
        }
    }
}

/// Decompose `len` elements into `n_limbs` plane-major limbs (same limb
/// values as [`decompose`]: unsigned bytes below, sign-extended top).
fn fill_planes(dst: &mut Vec<i64>, len: usize, n_limbs: usize, at: impl Fn(usize) -> i64) {
    dst.clear();
    dst.resize(n_limbs * len, 0);
    for idx in 0..len {
        let x = at(idx);
        for p in 0..n_limbs {
            dst[p * len + idx] =
                // lint: allow(R1) shift exponent bounded by 8 * n_limbs — far below u32::MAX
                if p == n_limbs - 1 { x >> (8 * p as u32) } else { (x >> (8 * p as u32)) & 0xFF };
        }
    }
}

impl Workspace {
    pub fn new() -> Workspace {
        Workspace::default()
    }

    /// Plane-decomposed GEMM, bit-identical to [`limb_gemm`] for every
    /// input (property-tested in `tests/proptest_invariants.rs`). The
    /// returned slice (`m·n` row-major, valid until the next call) lives
    /// in the workspace accumulator.
    pub fn plane_gemm(
        &mut self,
        a: &[i64],
        b: &[i64],
        m: usize,
        k: usize,
        n: usize,
        n_limbs: u32,
        width: u32,
    ) -> &[i64] {
        assert_eq!(a.len(), m * k);
        assert_eq!(b.len(), k * n);
        self.run(m, k, n, n_limbs, width, |i| a[i], |i| b[i])
    }

    /// [`Workspace::plane_gemm`] straight from i32 tiles (the serve-path
    /// artifact dtype) — limbs are extracted during plane fill, so no
    /// widened copy of the operands is ever materialized.
    pub fn plane_gemm_i32(
        &mut self,
        a: &[i32],
        b: &[i32],
        m: usize,
        k: usize,
        n: usize,
        n_limbs: u32,
        width: u32,
    ) -> &[i64] {
        assert_eq!(a.len(), m * k);
        assert_eq!(b.len(), k * n);
        self.run(m, k, n, n_limbs, width, |i| a[i] as i64, |i| b[i] as i64)
    }

    fn run(
        &mut self,
        m: usize,
        k: usize,
        n: usize,
        n_limbs: u32,
        width: u32,
        a_at: impl Fn(usize) -> i64,
        b_at: impl Fn(usize) -> i64,
    ) -> &[i64] {
        // lint: allow(R1) u32 -> usize is a lossless widening on every supported target
        let nl = n_limbs as usize;
        fill_planes(&mut self.a_planes, m * k, nl, a_at);
        fill_planes(&mut self.b_planes, k * n, nl, b_at);
        self.acc.clear();
        self.acc.resize(m * n, 0);
        for p in 0..nl {
            for q in 0..nl {
                // lint: allow(R1) shift exponent bounded by 8 * (2 * n_limbs) — far below u32::MAX
                let shift = 8 * (p + q) as u32;
                if shift >= width {
                    continue; // vanishes mod 2^width, exactly as limb_mul skips it
                }
                plane_pair_accumulate(
                    &mut self.acc,
                    &self.a_planes[p * m * k..(p + 1) * m * k],
                    &self.b_planes[q * k * n..(q + 1) * k * n],
                    m,
                    k,
                    n,
                    shift,
                );
            }
        }
        for v in &mut self.acc {
            *v = truncate(*v, width);
        }
        &self.acc
    }

    /// Allocation-free [`bignum_mul_precarry`]: same pre-carry limb
    /// products, accumulated into the reused workspace buffer with the
    /// loop restructured to stream contiguous output windows. Returns
    /// `a.len() + b.len() - 1` coefficients (empty if either input is).
    pub fn bignum_precarry(&mut self, a: &[u8], b: &[u8]) -> &[i64] {
        self.acc.clear();
        if a.is_empty() || b.is_empty() {
            return &self.acc;
        }
        self.acc.resize(a.len() + b.len() - 1, 0);
        for (i, &ai) in a.iter().enumerate() {
            if ai == 0 {
                continue;
            }
            let ai = ai as i64;
            for (c, &bj) in self.acc[i..i + b.len()].iter_mut().zip(b) {
                *c += ai * bj as i64;
            }
        }
        &self.acc
    }
}

/// One-shot convenience over [`Workspace::plane_gemm`] (hot paths should
/// hold a workspace instead and skip the per-call allocation).
pub fn plane_gemm(
    a: &[i64],
    b: &[i64],
    m: usize,
    k: usize,
    n: usize,
    n_limbs: u32,
    width: u32,
) -> Vec<i64> {
    Workspace::new().plane_gemm(a, b, m, k, n, n_limbs, width).to_vec()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn decompose_recompose_roundtrip() {
        for &(x, n) in &[
            (0i64, 1u32),
            (127, 1),
            (-128, 1),
            (32767, 2),
            (-32768, 2),
            (0x1234_5678, 4),
            (-0x1234_5678, 4),
            (i64::MAX, 8),
            (i64::MIN, 8),
        ] {
            assert_eq!(recompose(&decompose(x, n)), x, "x={x} n={n}");
        }
    }

    #[test]
    fn limb_mul_exact_for_in_range_values() {
        // 16-bit operands through the 2-limb path: exact signed product
        for &(x, y) in &[(123i64, 456i64), (-123, 456), (-32768, 32767), (0, -1)] {
            assert_eq!(limb_mul(x, y, 2, 32), x * y, "{x}*{y}");
        }
        // 32-bit operands through the 4-limb path, mod 2^32
        let (x, y) = (0x7fff_0001i64, -0x1234i64);
        assert_eq!(limb_mul(x, y, 4, 32), truncate(x.wrapping_mul(y), 32));
    }

    #[test]
    fn limb_gemm_matches_naive() {
        let m = 3;
        let k = 4;
        let n = 2;
        let a: Vec<i64> = (0..m * k).map(|i| (i as i64 * 37 - 50) % 120).collect();
        let b: Vec<i64> = (0..k * n).map(|i| (i as i64 * 91 - 70) % 120).collect();
        let got = limb_gemm(&a, &b, m, k, n, 1, 32);
        for i in 0..m {
            for j in 0..n {
                let want: i64 = (0..k).map(|kk| a[i * k + kk] * b[kk * n + j]).sum();
                assert_eq!(got[i * n + j], want);
            }
        }
    }

    #[test]
    fn bignum_precarry_matches_wide_product() {
        // (0x0201) * (0x0403) limbs little-endian: [1,2] * [3,4]
        let c = bignum_mul_precarry(&[1, 2], &[3, 4]);
        assert_eq!(c, vec![3, 10, 8]); // 1·3, 1·4+2·3, 2·4
    }

    #[test]
    fn truncate_is_mod_2w_signed() {
        assert_eq!(truncate(0x1_0000_0001, 32), 1);
        assert_eq!(truncate(0xFFFF_FFFF, 32), -1);
        assert_eq!(truncate(-1, 16), -1);
    }

    #[test]
    fn plane_gemm_matches_scalar_oracle_on_fixed_cases() {
        // shapes straddling the KC/NC block boundaries, wraparound-heavy
        // values, every serve-path limb count
        for &(m, k, n) in &[(1usize, 1usize, 1usize), (3, 4, 2), (5, 130, 7), (130, 3, 131)] {
            for &(n_limbs, width) in &[(1u32, 8u32), (1, 32), (2, 32), (4, 32), (8, 64)] {
                let a: Vec<i64> = (0..m * k)
                    .map(|i| (i as i64).wrapping_mul(0x9E37_79B9_7F4A_7C15u64 as i64))
                    .collect();
                let b: Vec<i64> = (0..k * n)
                    .map(|i| (i as i64 + 7).wrapping_mul(-0x61C8_8646_80B5_83EBi64))
                    .collect();
                let want = limb_gemm(&a, &b, m, k, n, n_limbs, width);
                assert_eq!(
                    plane_gemm(&a, &b, m, k, n, n_limbs, width),
                    want,
                    "m={m} k={k} n={n} n_limbs={n_limbs} width={width}"
                );
            }
        }
    }

    #[test]
    fn plane_gemm_i32_matches_i64_entry_point() {
        let (m, k, n) = (6usize, 9usize, 5usize);
        let a32: Vec<i32> = (0..m * k).map(|i| (i as i32).wrapping_mul(-0x3571_1559)).collect();
        let b32: Vec<i32> = (0..k * n).map(|i| (i as i32 + 3).wrapping_mul(0x4D2B_79F1)).collect();
        let a64: Vec<i64> = a32.iter().map(|&v| v as i64).collect();
        let b64: Vec<i64> = b32.iter().map(|&v| v as i64).collect();
        let mut ws = Workspace::new();
        let want = ws.plane_gemm(&a64, &b64, m, k, n, 4, 32).to_vec();
        assert_eq!(ws.plane_gemm_i32(&a32, &b32, m, k, n, 4, 32), want);
    }

    #[test]
    fn plane_gemm_handles_degenerate_shapes() {
        // zero limbs: every product vanishes, exactly like limb_mul(_, _, 0, _)
        assert_eq!(plane_gemm(&[5, 6], &[7, 8], 1, 2, 1, 0, 32), vec![0]);
        // empty dimensions
        assert_eq!(plane_gemm(&[], &[], 0, 3, 0, 2, 32), Vec::<i64>::new());
        assert_eq!(plane_gemm(&[], &[], 2, 0, 2, 2, 32), vec![0; 4]);
    }

    #[test]
    fn workspace_bignum_precarry_matches_naive() {
        let mut ws = Workspace::new();
        assert_eq!(ws.bignum_precarry(&[1, 2], &[3, 4]), &[3, 10, 8]);
        let a: Vec<u8> = (0..64).map(|i| (i * 37 + 11) as u8).collect();
        let b: Vec<u8> = (0..64).map(|i| (i * 91 + 5) as u8).collect();
        let want = bignum_mul_precarry(&a, &b);
        assert_eq!(ws.bignum_precarry(&a, &b), want.as_slice());
        // empty operands, after the buffer held a previous result
        assert_eq!(ws.bignum_precarry(&[], &b), &[] as &[i64]);
    }

    #[test]
    fn workspace_is_reusable_across_mixed_shapes() {
        let mut ws = Workspace::new();
        let a: Vec<i64> = (0..12).map(|i| i * 17 - 90).collect();
        let b: Vec<i64> = (0..12).map(|i| 55 - i * 23).collect();
        let want = limb_gemm(&a, &b, 3, 4, 3, 2, 32);
        assert_eq!(ws.plane_gemm(&a, &b, 3, 4, 3, 2, 32), want.as_slice());
        // shrink, grow, switch kernels — then the same call must
        // reproduce the same bytes
        ws.plane_gemm(&a[..4], &b[..4], 2, 2, 2, 8, 64);
        ws.bignum_precarry(&[9; 64], &[250; 64]);
        ws.plane_gemm_i32(&[1; 256], &[2; 256], 16, 16, 16, 1, 32);
        assert_eq!(ws.plane_gemm(&a, &b, 3, 4, 3, 2, 32), want.as_slice());
    }
}
