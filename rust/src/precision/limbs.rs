//! Limb algebra: the scalar model of the MPRA datapath.
//!
//! Mirrors `python/compile/kernels/ref.py` exactly (little-endian 8-bit
//! limbs, signed-MSB scheme) so the rust side can independently verify the
//! numerics that come back from the PJRT-executed Pallas kernels.

/// Split a signed value into `n` little-endian limbs.
///
/// Lower limbs are unsigned bytes; the TOP limb is sign-extended (the
/// signed-MSB scheme of the Fig. 3 accumulator), so the value recomposes
/// exactly for in-range inputs.
pub fn decompose(x: i64, n: u32) -> Vec<i64> {
    (0..n)
        .map(|i| {
            if i == n - 1 {
                x >> (8 * i)
            } else {
                (x >> (8 * i)) & 0xFF
            }
        })
        .collect()
}

/// Inverse of [`decompose`].
pub fn recompose(limbs: &[i64]) -> i64 {
    limbs
        .iter()
        .enumerate()
        .map(|(i, &l)| l.wrapping_shl(8 * i as u32))
        .fold(0i64, i64::wrapping_add)
}

/// One scalar multi-precision product the way the array computes it:
/// all `n²` limb cross-products, shift-added (§3.1, Fig. 1a).
pub fn limb_mul(x: i64, y: i64, n: u32, width: u32) -> i64 {
    let xs = decompose(x, n);
    let ys = decompose(y, n);
    let mut acc = 0i64;
    for (i, &xi) in xs.iter().enumerate() {
        for (j, &yj) in ys.iter().enumerate() {
            let shift = 8 * (i + j) as u32;
            if shift >= width {
                continue; // vanishes mod 2^width
            }
            acc = acc.wrapping_add(xi.wrapping_mul(yj).wrapping_shl(shift));
        }
    }
    truncate(acc, width)
}

/// Limb-decomposed GEMM over i64 scalars — the oracle the PJRT results are
/// checked against (`C = A·B` mod `2^width`, row-major).
pub fn limb_gemm(
    a: &[i64],
    b: &[i64],
    m: usize,
    k: usize,
    n: usize,
    n_limbs: u32,
    width: u32,
) -> Vec<i64> {
    assert_eq!(a.len(), m * k);
    assert_eq!(b.len(), k * n);
    let mut c = vec![0i64; m * n];
    for i in 0..m {
        for j in 0..n {
            let mut acc = 0i64;
            for kk in 0..k {
                acc = acc.wrapping_add(limb_mul(a[i * k + kk], b[kk * n + j], n_limbs, width));
            }
            c[i * n + j] = truncate(acc, width);
        }
    }
    c
}

/// Wrap a value to `width` bits with sign extension (two's-complement
/// accumulator semantics).
pub fn truncate(v: i64, width: u32) -> i64 {
    if width >= 64 {
        v
    } else {
        (v << (64 - width)) >> (64 - width)
    }
}

/// Big-number (BNM) pre-carry limb product: `c[k] = Σ_{i+j=k} a_i·b_j`
/// (the rank-1 p-GEMM the bignum Pallas kernel computes).
pub fn bignum_mul_precarry(a: &[u8], b: &[u8]) -> Vec<i64> {
    if a.is_empty() || b.is_empty() {
        return vec![];
    }
    let mut c = vec![0i64; a.len() + b.len() - 1];
    for (i, &ai) in a.iter().enumerate() {
        for (j, &bj) in b.iter().enumerate() {
            c[i + j] += ai as i64 * bj as i64;
        }
    }
    c
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn decompose_recompose_roundtrip() {
        for &(x, n) in &[
            (0i64, 1u32),
            (127, 1),
            (-128, 1),
            (32767, 2),
            (-32768, 2),
            (0x1234_5678, 4),
            (-0x1234_5678, 4),
            (i64::MAX, 8),
            (i64::MIN, 8),
        ] {
            assert_eq!(recompose(&decompose(x, n)), x, "x={x} n={n}");
        }
    }

    #[test]
    fn limb_mul_exact_for_in_range_values() {
        // 16-bit operands through the 2-limb path: exact signed product
        for &(x, y) in &[(123i64, 456i64), (-123, 456), (-32768, 32767), (0, -1)] {
            assert_eq!(limb_mul(x, y, 2, 32), x * y, "{x}*{y}");
        }
        // 32-bit operands through the 4-limb path, mod 2^32
        let (x, y) = (0x7fff_0001i64, -0x1234i64);
        assert_eq!(limb_mul(x, y, 4, 32), truncate(x.wrapping_mul(y), 32));
    }

    #[test]
    fn limb_gemm_matches_naive() {
        let m = 3;
        let k = 4;
        let n = 2;
        let a: Vec<i64> = (0..m * k).map(|i| (i as i64 * 37 - 50) % 120).collect();
        let b: Vec<i64> = (0..k * n).map(|i| (i as i64 * 91 - 70) % 120).collect();
        let got = limb_gemm(&a, &b, m, k, n, 1, 32);
        for i in 0..m {
            for j in 0..n {
                let want: i64 = (0..k).map(|kk| a[i * k + kk] * b[kk * n + j]).sum();
                assert_eq!(got[i * n + j], want);
            }
        }
    }

    #[test]
    fn bignum_precarry_matches_wide_product() {
        // (0x0201) * (0x0403) limbs little-endian: [1,2] * [3,4]
        let c = bignum_mul_precarry(&[1, 2], &[3, 4]);
        assert_eq!(c, vec![3, 10, 8]); // 1·3, 1·4+2·3, 2·4
    }

    #[test]
    fn truncate_is_mod_2w_signed() {
        assert_eq!(truncate(0x1_0000_0001, 32), 1);
        assert_eq!(truncate(0xFFFF_FFFF, 32), -1);
        assert_eq!(truncate(-1, 16), -1);
    }
}
