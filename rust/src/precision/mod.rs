//! Precision model: the eight data types GTA supports (Table 1) and their
//! decomposition into 8-bit limbs — the paper's §3.1 insight that an
//! `8n × 8m`-bit multiplication *is* an `n×m` matrix of limb cross-products.
//!
//! Floating-point types map to their mantissa width: BP16→INT8, FP16→INT12,
//! FP32→INT24, FP64→INT53 (§4.1), i.e. 1/2/3/7 limbs.

pub mod accumulator;
pub mod limbs;


/// The eight precisions of the contemporary vector ISAs GTA targets
/// (RISC-V V, AVX-512, SVE — paper §1).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Precision {
    Int8,
    Int16,
    Int32,
    Int64,
    Bp16,
    Fp16,
    Fp32,
    Fp64,
}

impl Precision {
    /// All precisions, in the paper's Table 3 ordering.
    pub const ALL: [Precision; 8] = [
        Precision::Int8,
        Precision::Int16,
        Precision::Int32,
        Precision::Int64,
        Precision::Bp16,
        Precision::Fp16,
        Precision::Fp32,
        Precision::Fp64,
    ];

    /// Storage width in bits.
    pub fn bits(self) -> u32 {
        match self {
            Precision::Int8 => 8,
            Precision::Int16 | Precision::Bp16 | Precision::Fp16 => 16,
            Precision::Int32 | Precision::Fp32 => 32,
            Precision::Int64 | Precision::Fp64 => 64,
        }
    }

    /// Width of the value the multiplier array actually multiplies:
    /// the full word for integers, the (hidden-bit-extended) mantissa for
    /// floats — "the mantissa multiplication for BP16, FP16, FP32, and FP64
    /// can be equivalently represented as the multiplication of INT8, 12,
    /// 24, and 53" (§4.1).
    pub fn multiplier_bits(self) -> u32 {
        match self {
            Precision::Int8 => 8,
            Precision::Int16 => 16,
            Precision::Int32 => 32,
            Precision::Int64 => 64,
            Precision::Bp16 => 8,
            Precision::Fp16 => 12,
            Precision::Fp32 => 24,
            Precision::Fp64 => 53,
        }
    }

    /// Number of 8-bit limbs occupied on the MPRA (`n = ⌈mult_bits/8⌉`).
    pub fn limbs(self) -> u32 {
        self.multiplier_bits().div_ceil(8)
    }

    /// Bytes per stored element.
    pub fn bytes(self) -> u64 {
        (self.bits() / 8) as u64
    }

    pub fn is_float(self) -> bool {
        matches!(
            self,
            Precision::Bp16 | Precision::Fp16 | Precision::Fp32 | Precision::Fp64
        )
    }

    /// Table-3 row label.
    pub fn name(self) -> &'static str {
        match self {
            Precision::Int8 => "INT8",
            Precision::Int16 => "INT16",
            Precision::Int32 => "INT32",
            Precision::Int64 => "INT64",
            Precision::Bp16 => "BP16",
            Precision::Fp16 => "FP16",
            Precision::Fp32 => "FP32",
            Precision::Fp64 => "FP64",
        }
    }

    pub fn parse(s: &str) -> Option<Precision> {
        let t = s.to_ascii_lowercase();
        Some(match t.as_str() {
            "int8" | "i8" => Precision::Int8,
            "int16" | "i16" => Precision::Int16,
            "int32" | "i32" => Precision::Int32,
            "int64" | "i64" => Precision::Int64,
            "bp16" | "bf16" | "bfloat16" => Precision::Bp16,
            "fp16" | "f16" | "half" => Precision::Fp16,
            "fp32" | "f32" | "float" => Precision::Fp32,
            "fp64" | "f64" | "double" => Precision::Fp64,
            _ => return None,
        })
    }
}

impl std::fmt::Display for Precision {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.name())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn limb_counts_match_paper_section_4_1() {
        // §4.1: mantissa of BP16/FP16/FP32/FP64 == INT8/12/24/53
        assert_eq!(Precision::Int8.limbs(), 1);
        assert_eq!(Precision::Int16.limbs(), 2);
        assert_eq!(Precision::Int32.limbs(), 4);
        assert_eq!(Precision::Int64.limbs(), 8);
        assert_eq!(Precision::Bp16.limbs(), 1);
        assert_eq!(Precision::Fp16.limbs(), 2);
        assert_eq!(Precision::Fp32.limbs(), 3);
        assert_eq!(Precision::Fp64.limbs(), 7);
    }

    #[test]
    fn storage_bytes() {
        assert_eq!(Precision::Int8.bytes(), 1);
        assert_eq!(Precision::Bp16.bytes(), 2);
        assert_eq!(Precision::Fp32.bytes(), 4);
        assert_eq!(Precision::Fp64.bytes(), 8);
    }

    #[test]
    fn parse_roundtrip() {
        for p in Precision::ALL {
            assert_eq!(Precision::parse(p.name()), Some(p));
        }
        assert_eq!(Precision::parse("bf16"), Some(Precision::Bp16));
        assert_eq!(Precision::parse("bogus"), None);
    }
}
