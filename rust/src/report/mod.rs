//! Regenerates every table and figure of the paper's evaluation (§6–7)
//! from the simulators — the single source the benches, examples and CLI
//! print from.

use crate::arch::area::{self, PlatformInfo};
use crate::arch::energy::{self, Fig6Row};
use crate::ops::classify::{fig2_points, Fig2Point};
use crate::ops::PGemm;
use crate::precision::Precision;
use crate::scheduler;
use crate::sim::{cgra::CgraSim, gpgpu::GpgpuSim, gta::GtaSim, vpu::VpuSim, Platform, SimReport};
use crate::workloads::{self, Workload};

/// One row of a Fig. 7/8/10 comparison.
#[derive(Debug, Clone)]
pub struct CompareRow {
    pub workload: String,
    pub gta: SimReport,
    pub baseline: SimReport,
    /// cycle ratio baseline/GTA — the paper's "computational speedup"
    /// (§6.3: "We assume the same clock frequency", so cycles compare
    /// directly across platforms)
    pub speedup: f64,
    /// memory-access ratio baseline/GTA (the paper's "memory efficiency")
    pub mem_saving: f64,
    /// wall-time ratio at each platform's own Table 1 clock (extra info)
    pub wall_speedup: f64,
}

/// Aggregate of a comparison.
#[derive(Debug, Clone)]
pub struct Comparison {
    pub baseline_name: String,
    pub rows: Vec<CompareRow>,
    pub avg_speedup: f64,
    pub avg_mem_saving: f64,
    pub geomean_speedup: f64,
    pub geomean_mem_saving: f64,
}

/// Run the suite on GTA and a baseline, produce the comparison.
pub fn compare_suite(gta: &GtaSim, baseline: &dyn Platform, suite: &[Workload]) -> Comparison {
    let rows: Vec<CompareRow> = suite
        .iter()
        .map(|w| {
            let g = gta.run_all(&w.ops);
            let b = baseline.run_all(&w.ops);
            CompareRow {
                workload: w.name.to_string(),
                speedup: b.cycles as f64 / g.cycles.max(1) as f64,
                mem_saving: b.memory_access() as f64 / g.memory_access().max(1) as f64,
                wall_speedup: b.seconds() / g.seconds().max(1e-12),
                gta: g,
                baseline: b,
            }
        })
        .collect();
    let n = rows.len().max(1) as f64;
    let avg = |f: &dyn Fn(&CompareRow) -> f64| rows.iter().map(|r| f(r)).sum::<f64>() / n;
    let geo = |f: &dyn Fn(&CompareRow) -> f64| {
        (rows.iter().map(|r| f(r).ln()).sum::<f64>() / n).exp()
    };
    Comparison {
        baseline_name: baseline.name().to_string(),
        avg_speedup: avg(&|r| r.speedup),
        avg_mem_saving: avg(&|r| r.mem_saving),
        geomean_speedup: geo(&|r| r.speedup),
        geomean_mem_saving: geo(&|r| r.mem_saving),
        rows,
    }
}

/// Fig. 7 — GTA vs VPU (full suite: vector + p-GEMM ops).
pub fn fig7() -> Comparison {
    compare_suite(&GtaSim::table1(), &VpuSim::default(), &workloads::suite())
}

/// Fig. 8 — GTA vs GPGPU. Same-area comparison (§6.3): the GTA instance
/// is scaled up ("configure different number of MPRA") to the H100's
/// 14 nm-equivalent area; p-GEMM → tensor cores, vector → CUDA cores.
pub fn fig8() -> Comparison {
    let lanes = GpgpuSim::equal_area_gta_lanes();
    compare_suite(
        &GtaSim::new(crate::arch::GtaConfig::with_lanes(lanes)),
        &GpgpuSim::default(),
        &workloads::suite(),
    )
}

/// Fig. 10 — GTA vs CGRA "in p-GEMM operators".
pub fn fig10() -> Comparison {
    compare_suite(
        &GtaSim::table1(),
        &CgraSim::default(),
        &workloads::suite_pgemm_only(),
    )
}

/// Table 1 rows.
pub fn table1() -> Vec<PlatformInfo> {
    area::table1()
}

/// Table 3 rows: (precision, derived gain).
pub fn table3() -> Vec<(Precision, f64)> {
    Precision::ALL
        .iter()
        .map(|&p| (p, crate::sim::mpra::simd_gain(p)))
        .collect()
}

/// Fig. 2 scatter points.
pub fn fig2() -> Vec<Fig2Point> {
    fig2_points()
}

/// Fig. 6 rows.
pub fn fig6() -> Vec<Fig6Row> {
    energy::fig6_rows()
}

/// One Fig. 9 scatter point: a schedule candidate's normalized metrics.
#[derive(Debug, Clone)]
pub struct Fig9Point {
    pub precision: String,
    pub dataflow: String,
    pub arrangement: String,
    pub k_segments: u64,
    pub cycles_ratio: f64,
    pub mem_ratio: f64,
    pub selected: bool,
}

/// Fig. 9 — the mixed precision × dataflow scheduling scatter for one
/// Alexnet conv layer (conv3: M=384, N=169, K=2304) at three precisions,
/// swept concurrently through the batch explorer.
pub fn fig9() -> Vec<Fig9Point> {
    let gta = crate::arch::GtaConfig::lanes16();
    let ops: Vec<PGemm> = [Precision::Int8, Precision::Fp16, Precision::Fp32]
        .iter()
        .map(|&p| PGemm::new(384, 169, 2304, p))
        .collect();
    let sets = scheduler::explore_batch(&ops, &gta);
    let mut out = Vec::new();
    for (g, cands) in ops.iter().zip(&sets) {
        let p = g.precision;
        let best = scheduler::select(cands);
        let min_c = cands.iter().map(|c| c.report.cycles).min().unwrap().max(1) as f64;
        let min_m = cands
            .iter()
            .map(|c| c.report.memory_access())
            .min()
            .unwrap()
            .max(1) as f64;
        for c in cands.iter() {
            out.push(Fig9Point {
                precision: p.name().to_string(),
                dataflow: c.config.dataflow.name().to_string(),
                arrangement: format!(
                    "{}x{}",
                    c.config.arrangement.lane_rows, c.config.arrangement.lane_cols
                ),
                k_segments: c.config.k_segments,
                cycles_ratio: c.report.cycles as f64 / min_c,
                mem_ratio: c.report.memory_access() as f64 / min_m,
                selected: c.config == best.config,
            });
        }
    }
    out
}

/// Fig. 5 — the dataflow-pattern-matching case table for a 64-lane GTA
/// (the paper's running example) across representative workloads.
#[derive(Debug, Clone)]
pub struct Fig5Row {
    pub workload: String,
    pub dataflow: String,
    pub mapped: (u64, u64),
    pub array: (u64, u64),
    pub coverage: String,
    pub max_k_segments: u64,
}

pub fn fig5() -> Vec<Fig5Row> {
    use crate::arch::Dataflow;
    let gta = crate::arch::GtaConfig::with_lanes(64);
    let arr = crate::arch::Arrangement::new(8, 8); // 64×64 PE array
    let (r, c) = gta.array_shape(arr);
    let cases = [
        ("tiny GEMV 16x16x16", PGemm::new(16, 16, 16, Precision::Int8)),
        ("tall 256x16x64", PGemm::new(256, 16, 64, Precision::Int8)),
        ("wide 16x256x64", PGemm::new(16, 256, 64, Precision::Int8)),
        ("tall-cover 512x48x64", PGemm::new(512, 48, 64, Precision::Int8)),
        ("wide-cover 48x512x64", PGemm::new(48, 512, 64, Precision::Int8)),
        ("huge 512x512x512", PGemm::new(512, 512, 512, Precision::Int8)),
    ];
    cases
        .iter()
        .map(|(name, g)| {
            let mapped = crate::sim::mpra::map_gemm(g, Dataflow::OS);
            let cov = scheduler::pattern::classify(mapped, r, c);
            Fig5Row {
                workload: name.to_string(),
                dataflow: "OS".into(),
                mapped: (mapped.rows, mapped.cols),
                array: (r, c),
                coverage: format!("{cov:?}"),
                max_k_segments: scheduler::pattern::max_k_segments(mapped, r, c),
            }
        })
        .collect()
}

/// Render a comparison as an aligned text table.
pub fn render_comparison(c: &Comparison) -> String {
    let mut s = String::new();
    s.push_str(&format!(
        "GTA vs {:<18} {:>14} {:>14} {:>10} {:>10} {:>10}\n",
        c.baseline_name, "GTA cycles", "base cycles", "speedup", "mem-save", "wall"
    ));
    for r in &c.rows {
        s.push_str(&format!(
            "  {:<24} {:>14} {:>14} {:>9.2}x {:>9.2}x {:>9.2}x\n",
            r.workload, r.gta.cycles, r.baseline.cycles, r.speedup, r.mem_saving, r.wall_speedup
        ));
    }
    s.push_str(&format!(
        "  {:<24} {:>14} {:>14} {:>9.2}x {:>9.2}x   (geomean {:.2}x / {:.2}x)\n",
        "AVERAGE", "", "", c.avg_speedup, c.avg_mem_saving, c.geomean_speedup, c.geomean_mem_saving
    ));
    s
}

/// Render Table 3.
pub fn render_table3() -> String {
    let mut s = String::from("Table 3: SIMD gains for all data types\n");
    for (p, g) in table3() {
        s.push_str(&format!("  {:<6} {:>6.2}x\n", p.name(), g));
    }
    s
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table3_rows_complete() {
        let t = table3();
        assert_eq!(t.len(), 8);
    }

    #[test]
    fn fig9_contains_three_precisions_and_selection() {
        let pts = fig9();
        let precs: std::collections::HashSet<_> =
            pts.iter().map(|p| p.precision.clone()).collect();
        assert_eq!(precs.len(), 3);
        // exactly one selected point per precision
        for prec in precs {
            assert_eq!(
                pts.iter().filter(|p| p.precision == prec && p.selected).count(),
                1
            );
        }
        // normalized ratios are >= 1
        assert!(pts.iter().all(|p| p.cycles_ratio >= 1.0 && p.mem_ratio >= 1.0));
    }

    #[test]
    fn fig5_covers_multiple_cases() {
        let rows = fig5();
        let cases: std::collections::HashSet<_> =
            rows.iter().map(|r| r.coverage.clone()).collect();
        assert!(cases.len() >= 4, "want variety of coverage cases, got {cases:?}");
    }
}
