//! Artifact manifest: the contract between `python/compile/aot.py` and the
//! rust runtime (names, files, shapes, dtypes), parsed with the in-tree
//! JSON parser.

use crate::util::json::{parse, Json};
use anyhow::{anyhow, Context, Result};
use std::collections::BTreeMap;
use std::path::Path;

/// Artifact tensor dtypes (the host formats the runtime supports).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum DType {
    S32,
    S64,
    F32,
}

impl DType {
    pub fn parse(s: &str) -> Result<DType> {
        Ok(match s {
            "s32" => DType::S32,
            "s64" => DType::S64,
            "f32" => DType::F32,
            other => return Err(anyhow!("unsupported artifact dtype {other:?}")),
        })
    }

    pub fn name(self) -> &'static str {
        match self {
            DType::S32 => "s32",
            DType::S64 => "s64",
            DType::F32 => "f32",
        }
    }
}

/// Shape + dtype of one artifact input/output.
#[derive(Debug, Clone, PartialEq)]
pub struct TensorSpec {
    pub shape: Vec<u64>,
    pub dtype: DType,
}

impl TensorSpec {
    pub fn elements(&self) -> u64 {
        self.shape.iter().product()
    }

    fn from_json(j: &Json) -> Result<TensorSpec> {
        let shape = j
            .get("shape")
            .and_then(Json::as_arr)
            .ok_or_else(|| anyhow!("missing shape"))?
            .iter()
            .map(|d| d.as_u64().ok_or_else(|| anyhow!("bad dim")))
            .collect::<Result<Vec<u64>>>()?;
        let dtype = DType::parse(
            j.get("dtype")
                .and_then(Json::as_str)
                .ok_or_else(|| anyhow!("missing dtype"))?,
        )?;
        Ok(TensorSpec { shape, dtype })
    }
}

/// One artifact entry.
#[derive(Debug, Clone, PartialEq)]
pub struct Entry {
    pub file: String,
    pub doc: String,
    pub sha256: String,
    pub inputs: Vec<TensorSpec>,
    pub outputs: Vec<TensorSpec>,
}

/// The whole manifest.
#[derive(Debug, Clone)]
pub struct Manifest {
    pub entries: BTreeMap<String, Entry>,
}

impl Manifest {
    pub fn read(path: impl AsRef<Path>) -> Result<Manifest> {
        let text = std::fs::read_to_string(path.as_ref())
            .with_context(|| format!("reading {}", path.as_ref().display()))?;
        Self::parse(&text)
    }

    pub fn parse(text: &str) -> Result<Manifest> {
        let root = parse(text).map_err(|e| anyhow!("{e}"))?;
        let obj = root.as_obj().ok_or_else(|| anyhow!("manifest must be an object"))?;
        let mut entries = BTreeMap::new();
        for (name, j) in obj {
            let specs = |key: &str| -> Result<Vec<TensorSpec>> {
                j.get(key)
                    .and_then(Json::as_arr)
                    .ok_or_else(|| anyhow!("{name}: missing {key}"))?
                    .iter()
                    .map(TensorSpec::from_json)
                    .collect()
            };
            entries.insert(
                name.clone(),
                Entry {
                    file: j
                        .get("file")
                        .and_then(Json::as_str)
                        .ok_or_else(|| anyhow!("{name}: missing file"))?
                        .to_string(),
                    doc: j.get("doc").and_then(Json::as_str).unwrap_or("").to_string(),
                    sha256: j
                        .get("sha256")
                        .and_then(Json::as_str)
                        .unwrap_or("")
                        .to_string(),
                    inputs: specs("inputs")?,
                    outputs: specs("outputs")?,
                },
            );
        }
        Ok(Manifest { entries })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const DOC: &str = r#"{
        "k": {
            "doc": "test kernel",
            "file": "k.hlo.txt",
            "sha256": "ab",
            "inputs": [{"shape": [2, 3], "dtype": "s32"}],
            "outputs": [{"shape": [6], "dtype": "f32"}]
        }
    }"#;

    #[test]
    fn parses_entries() {
        let m = Manifest::parse(DOC).unwrap();
        let e = &m.entries["k"];
        assert_eq!(e.file, "k.hlo.txt");
        assert_eq!(e.inputs[0].shape, vec![2, 3]);
        assert_eq!(e.inputs[0].dtype, DType::S32);
        assert_eq!(e.inputs[0].elements(), 6);
        assert_eq!(e.outputs[0].dtype, DType::F32);
    }

    #[test]
    fn rejects_unknown_dtype() {
        let bad = DOC.replace("s32", "u4");
        assert!(Manifest::parse(&bad).is_err());
    }

    #[test]
    fn real_manifest_parses_if_present() {
        // integration-adjacent: if artifacts were built, the real manifest
        // must satisfy this parser
        let path = concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts/manifest.json");
        if let Ok(m) = Manifest::read(path) {
            assert!(!m.entries.is_empty());
            for (name, e) in &m.entries {
                assert!(!e.inputs.is_empty(), "{name} has no inputs");
                assert!(!e.outputs.is_empty(), "{name} has no outputs");
            }
        }
    }
}
