//! PJRT execution runtime (the L3 ↔ artifact bridge).
//!
//! Loads the HLO-text artifacts `make artifacts` produced (HLO **text** is
//! the interchange format — jax ≥ 0.5 emits protos with 64-bit ids that
//! xla_extension 0.5.1 rejects; the text parser reassigns ids), compiles
//! them once on the PJRT CPU client, and executes them from the hot path.
//! Python never runs here.
//!
//! The real engine needs the vendored `xla` crate and is gated behind the
//! `pjrt` cargo feature; offline builds get a stub [`Engine`] with the
//! same API whose `load` fails cleanly, so everything that exercises
//! functional numerics skips (all such tests/examples already check for
//! the artifact directory first).

pub mod manifest;

use crate::precision::limbs;
use anyhow::Result;
use manifest::DType;
use std::path::PathBuf;

/// A host-side tensor in one of the artifact dtypes.
#[derive(Debug, Clone, PartialEq)]
pub enum HostTensor {
    I32(Vec<i32>),
    I64(Vec<i64>),
    F32(Vec<f32>),
}

impl HostTensor {
    pub fn len(&self) -> usize {
        match self {
            HostTensor::I32(v) => v.len(),
            HostTensor::I64(v) => v.len(),
            HostTensor::F32(v) => v.len(),
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn dtype(&self) -> DType {
        match self {
            HostTensor::I32(_) => DType::S32,
            HostTensor::I64(_) => DType::S64,
            HostTensor::F32(_) => DType::F32,
        }
    }

    pub fn as_i32(&self) -> Option<&[i32]> {
        match self {
            HostTensor::I32(v) => Some(v),
            _ => None,
        }
    }

    pub fn as_i64(&self) -> Option<&[i64]> {
        match self {
            HostTensor::I64(v) => Some(v),
            _ => None,
        }
    }

    pub fn as_f32(&self) -> Option<&[f32]> {
        match self {
            HostTensor::F32(v) => Some(v),
            _ => None,
        }
    }
}

/// What the coordinator's executor thread drives: anything that can run a
/// named artifact on host tensors. The PJRT [`Engine`] is the production
/// implementation; [`SoftBackend`] is the in-tree rust-oracle stand-in
/// that works in every build (no artifacts, no PJRT).
///
/// `execute_batch` is the coalescing dispatch point: the default runs the
/// batch back-to-back on the owning thread, which already amortizes the
/// per-request channel round-trip; backends with true batched submission
/// can override it.
pub trait ExecBackend {
    /// Execute artifact `name` with host inputs; returns tuple fields.
    fn execute(&self, name: &str, inputs: &[HostTensor]) -> Result<Vec<HostTensor>>;

    /// Execute a batch of same-artifact invocations, one result per
    /// element. A failure is per-invocation: one bad input set must not
    /// poison its batch-mates.
    fn execute_batch(&self, name: &str, batch: &[Vec<HostTensor>]) -> Vec<Result<Vec<HostTensor>>> {
        batch.iter().map(|inputs| self.execute(name, inputs)).collect()
    }

    /// Artifact names this backend can execute.
    fn names(&self) -> Vec<String>;

    /// Human-readable platform tag.
    fn platform(&self) -> String {
        "unknown".to_string()
    }
}

#[cfg(feature = "pjrt")]
mod engine {
    use super::{HostTensor, Result};
    use crate::runtime::manifest::{self, DType, Manifest};
    use anyhow::{anyhow, Context};
    use std::collections::HashMap;
    use std::path::{Path, PathBuf};

    /// A compiled artifact ready to execute.
    struct LoadedArtifact {
        exe: xla::PjRtLoadedExecutable,
        entry: manifest::Entry,
    }

    /// The artifact engine: one PJRT client, one compiled executable per
    /// artifact, keyed by manifest name.
    pub struct Engine {
        client: xla::PjRtClient,
        artifacts: HashMap<String, LoadedArtifact>,
        dir: PathBuf,
    }

    impl Engine {
        /// Load + compile every artifact listed in `<dir>/manifest.json`.
        pub fn load(dir: impl AsRef<Path>) -> Result<Engine> {
            Self::load_filtered(dir, |_| true)
        }

        /// Load only the artifacts `keep` accepts (faster startup for
        /// tools that need a single kernel).
        pub fn load_filtered(
            dir: impl AsRef<Path>,
            keep: impl Fn(&str) -> bool,
        ) -> Result<Engine> {
            let dir = dir.as_ref().to_path_buf();
            let manifest = Manifest::read(dir.join("manifest.json"))
                .context("reading artifact manifest (run `make artifacts`?)")?;
            let client = xla::PjRtClient::cpu().map_err(|e| anyhow!("PJRT client: {e}"))?;
            let mut artifacts = HashMap::new();
            for (name, entry) in manifest.entries {
                if !keep(&name) {
                    continue;
                }
                let path = dir.join(&entry.file);
                let proto = xla::HloModuleProto::from_text_file(
                    path.to_str().ok_or_else(|| anyhow!("non-utf8 path"))?,
                )
                .map_err(|e| anyhow!("parsing {}: {e}", entry.file))?;
                let comp = xla::XlaComputation::from_proto(&proto);
                let exe = client
                    .compile(&comp)
                    .map_err(|e| anyhow!("compiling {name}: {e}"))?;
                artifacts.insert(name, LoadedArtifact { exe, entry });
            }
            Ok(Engine { client, artifacts, dir })
        }

        /// Sorted artifact names available.
        pub fn names(&self) -> Vec<&str> {
            let mut v: Vec<&str> = self.artifacts.keys().map(|s| s.as_str()).collect();
            v.sort();
            v
        }

        /// The manifest entry for `name`.
        pub fn entry(&self, name: &str) -> Option<&manifest::Entry> {
            self.artifacts.get(name).map(|a| &a.entry)
        }

        pub fn platform(&self) -> String {
            self.client.platform_name()
        }

        pub fn artifact_dir(&self) -> &Path {
            &self.dir
        }

        /// Execute artifact `name` with host inputs; returns tuple fields.
        pub fn execute(&self, name: &str, inputs: &[HostTensor]) -> Result<Vec<HostTensor>> {
            let art = self
                .artifacts
                .get(name)
                .ok_or_else(|| anyhow!("unknown artifact {name}"))?;
            if inputs.len() != art.entry.inputs.len() {
                return Err(anyhow!(
                    "{name}: expected {} inputs, got {}",
                    art.entry.inputs.len(),
                    inputs.len()
                ));
            }
            let mut literals = Vec::with_capacity(inputs.len());
            for (i, (t, spec)) in inputs.iter().zip(&art.entry.inputs).enumerate() {
                if t.dtype() != spec.dtype {
                    return Err(anyhow!(
                        "{name} input {i}: dtype {} != manifest {}",
                        t.dtype().name(),
                        spec.dtype.name()
                    ));
                }
                let expect = spec.elements() as usize;
                if t.len() != expect {
                    return Err(anyhow!(
                        "{name} input {i}: {} elements != shape {:?}",
                        t.len(),
                        spec.shape
                    ));
                }
                let lit = match t {
                    HostTensor::I32(v) => xla::Literal::vec1(v),
                    HostTensor::I64(v) => xla::Literal::vec1(v),
                    HostTensor::F32(v) => xla::Literal::vec1(v),
                };
                let dims: Vec<i64> = spec.shape.iter().map(|&d| d as i64).collect();
                let lit = if dims.len() == 1 {
                    lit
                } else {
                    lit.reshape(&dims)
                        .map_err(|e| anyhow!("reshape input {i}: {e}"))?
                };
                literals.push(lit);
            }
            let result = art
                .exe
                .execute::<xla::Literal>(&literals)
                // lint: allow(R2) PJRT returns one replica on one device for this single-device executable
                .map_err(|e| anyhow!("executing {name}: {e}"))?[0][0]
                .to_literal_sync()
                .map_err(|e| anyhow!("fetching {name} result: {e}"))?;
            // aot.py lowers with return_tuple=True: outputs arrive tupled
            let tuple = result
                .to_tuple()
                .map_err(|e| anyhow!("untupling {name}: {e}"))?;
            let mut out = Vec::with_capacity(tuple.len());
            for (lit, spec) in tuple.into_iter().zip(&art.entry.outputs) {
                out.push(match spec.dtype {
                    DType::S32 => HostTensor::I32(lit.to_vec().map_err(|e| anyhow!("{e}"))?),
                    DType::S64 => HostTensor::I64(lit.to_vec().map_err(|e| anyhow!("{e}"))?),
                    DType::F32 => HostTensor::F32(lit.to_vec().map_err(|e| anyhow!("{e}"))?),
                });
            }
            Ok(out)
        }
    }
}

#[cfg(not(feature = "pjrt"))]
mod engine {
    use super::{HostTensor, Result};
    use crate::runtime::manifest;
    use anyhow::anyhow;
    use std::path::{Path, PathBuf};

    /// Offline stub: same API as the PJRT engine, but `load` always
    /// fails (there is nothing to execute artifacts with), so no
    /// instance ever exists at runtime and the post-load methods are
    /// unreachable in practice.
    pub struct Engine {
        dir: PathBuf,
    }

    impl Engine {
        pub fn load(dir: impl AsRef<Path>) -> Result<Engine> {
            Self::load_filtered(dir, |_| true)
        }

        pub fn load_filtered(
            dir: impl AsRef<Path>,
            _keep: impl Fn(&str) -> bool,
        ) -> Result<Engine> {
            Err(anyhow!(
                "PJRT engine unavailable in this build (artifact dir {}): \
                 compile with `--features pjrt` and the vendored `xla` crate",
                dir.as_ref().display()
            ))
        }

        pub fn names(&self) -> Vec<&str> {
            Vec::new()
        }

        pub fn entry(&self, _name: &str) -> Option<&manifest::Entry> {
            None
        }

        pub fn platform(&self) -> String {
            "stub (no PJRT)".to_string()
        }

        pub fn artifact_dir(&self) -> &Path {
            &self.dir
        }

        pub fn execute(&self, _name: &str, _inputs: &[HostTensor]) -> Result<Vec<HostTensor>> {
            Err(anyhow!("PJRT engine unavailable in this build (stub)"))
        }
    }
}

pub use engine::Engine;

impl ExecBackend for Engine {
    fn execute(&self, name: &str, inputs: &[HostTensor]) -> Result<Vec<HostTensor>> {
        Engine::execute(self, name, inputs)
    }

    fn names(&self) -> Vec<String> {
        Engine::names(self).iter().map(|s| s.to_string()).collect()
    }

    fn platform(&self) -> String {
        Engine::platform(self)
    }
}

/// Artifacts the soft backend implements (the serve-path tile set).
pub const SOFT_ARTIFACTS: &[&str] = &["bignum_mul_64", "mpra_gemm_i16_64", "mpra_gemm_i8_64"];

/// Artifact name that always fails — failure-path injection for tests and
/// chaos runs: a request naming it exercises the per-request error path
/// without touching its batch-mates.
pub const FAIL_ARTIFACT: &str = "fail_inject";

/// Software reference backend: executes the serve-path artifacts with the
/// in-tree limb kernels ([`crate::precision::limbs`]) instead of PJRT, so
/// the full batched-serving path (admission queue, coalescing dispatch,
/// verification) runs and is testable in every build. Numerics are
/// bit-identical to the Pallas kernels by construction — the plane
/// kernels are property-tested against the scalar oracle, which is what
/// `gta verify` checks those kernels against.
///
/// Hot-path discipline (see `docs/kernels.md`): tiles execute straight
/// from their i32 inputs through a thread-local [`limbs::Workspace`]
/// (limb planes + accumulator reused across requests), and
/// [`ExecBackend::execute_batch`] fans a coalesced batch out over a small
/// scoped worker pool — each worker thread brings its own workspace, and
/// every item runs under `catch_unwind`, so one poisoned invocation
/// cannot take down its batch-mates.
#[derive(Debug, Default, Clone, Copy)]
pub struct SoftBackend;

thread_local! {
    /// Per-thread kernel scratch: limb planes + i64 accumulator, reused
    /// across requests so the steady-state hot path allocates only the
    /// output tensor.
    static KERNEL_WORKSPACE: std::cell::RefCell<limbs::Workspace> =
        std::cell::RefCell::new(limbs::Workspace::new());
}

/// Cap on batch fan-out workers: serve tiles are ~10⁵ multiply-adds, so a
/// handful of threads already saturates the win and more just contend
/// with other shards' executors.
const MAX_BATCH_WORKERS: usize = 8;

impl SoftBackend {
    fn two_i32<'a>(
        name: &str,
        inputs: &'a [HostTensor],
        len: usize,
    ) -> Result<(&'a [i32], &'a [i32])> {
        use anyhow::anyhow;
        let [first, second] = inputs else {
            return Err(anyhow!("{name}: expected 2 inputs, got {}", inputs.len()));
        };
        let a = first.as_i32().ok_or_else(|| anyhow!("{name} input 0: want s32"))?;
        let b = second.as_i32().ok_or_else(|| anyhow!("{name} input 1: want s32"))?;
        if a.len() != len || b.len() != len {
            return Err(anyhow!(
                "{name}: inputs {}x{} != expected {len} elements each",
                a.len(),
                b.len()
            ));
        }
        Ok((a, b))
    }

    fn gemm64(name: &str, inputs: &[HostTensor], n_limbs: u32) -> Result<Vec<HostTensor>> {
        let dim = 64usize;
        let (a, b) = Self::two_i32(name, inputs, dim * dim)?;
        // plane kernel straight off the i32 tiles: no widened operand
        // copies, scratch reused across requests on this thread
        let out = KERNEL_WORKSPACE.with(|ws| {
            let mut ws = ws.borrow_mut();
            let c = ws.plane_gemm_i32(a, b, dim, dim, dim, n_limbs, 32);
            c.iter().map(|&v| v as i32).collect::<Vec<i32>>()
        });
        Ok(vec![HostTensor::I32(out)])
    }

    fn bignum64(name: &str, inputs: &[HostTensor]) -> Result<Vec<HostTensor>> {
        use anyhow::anyhow;
        const L: usize = 64;
        let (a, b) = Self::two_i32(name, inputs, L)?;
        // bignum limbs are unsigned bytes on the wire: narrowing must be
        // checked, not wrapping — a 256 or a -1 silently folded `as u8`
        // would produce a plausible-looking wrong product
        let mut a8 = [0u8; L];
        let mut b8 = [0u8; L];
        for (input_idx, (src, dst)) in [(a, &mut a8), (b, &mut b8)].into_iter().enumerate() {
            for (i, &v) in src.iter().enumerate() {
                if !(0..=255).contains(&v) {
                    return Err(anyhow!(
                        "{name} input {input_idx}: limb {i} = {v} outside 0..=255 \
                         (bignum limbs are unsigned bytes)"
                    ));
                }
                dst[i] = v as u8;
            }
        }
        let out = KERNEL_WORKSPACE.with(|ws| {
            let mut ws = ws.borrow_mut();
            ws.bignum_precarry(&a8, &b8).iter().map(|&v| v as i32).collect::<Vec<i32>>()
        });
        Ok(vec![HostTensor::I32(out)])
    }

    /// Worker threads for one batch fan-out: cover the batch, never
    /// oversubscribe the machine, respect [`MAX_BATCH_WORKERS`].
    fn batch_workers(batch_len: usize) -> usize {
        let cores = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
        batch_len.min(cores).min(MAX_BATCH_WORKERS)
    }

    /// [`ExecBackend::execute`] with panics converted to per-item errors,
    /// so a poisoned invocation degrades to its own error response.
    fn execute_caught(&self, name: &str, inputs: &[HostTensor]) -> Result<Vec<HostTensor>> {
        use anyhow::anyhow;
        match std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| self.execute(name, inputs)))
        {
            Ok(r) => r,
            Err(payload) => {
                let msg = payload
                    .downcast_ref::<&str>()
                    .map(|s| s.to_string())
                    .or_else(|| payload.downcast_ref::<String>().cloned())
                    .unwrap_or_else(|| "non-string panic payload".to_string());
                Err(anyhow!("{name}: panicked during execution: {msg}"))
            }
        }
    }
}

impl ExecBackend for SoftBackend {
    fn execute(&self, name: &str, inputs: &[HostTensor]) -> Result<Vec<HostTensor>> {
        use anyhow::anyhow;
        match name {
            "mpra_gemm_i8_64" => Self::gemm64(name, inputs, 1),
            "mpra_gemm_i16_64" => Self::gemm64(name, inputs, 2),
            "bignum_mul_64" => Self::bignum64(name, inputs),
            n if n == FAIL_ARTIFACT => Err(anyhow!("{FAIL_ARTIFACT}: deliberate failure")),
            other => Err(anyhow!("soft backend: unknown artifact {other:?}")),
        }
    }

    /// Parallel fan-out over a small scoped worker pool: workers steal
    /// item indices from a shared atomic cursor, execute with their own
    /// thread-local workspace, and every item runs under `catch_unwind` —
    /// identical per-item results to the serial default (each item is an
    /// independent pure function of its inputs), with per-item failure
    /// isolation preserved.
    fn execute_batch(&self, name: &str, batch: &[Vec<HostTensor>]) -> Vec<Result<Vec<HostTensor>>> {
        use std::sync::atomic::{AtomicUsize, Ordering};
        let workers = Self::batch_workers(batch.len());
        if workers <= 1 {
            return batch.iter().map(|inputs| self.execute_caught(name, inputs)).collect();
        }
        let next = AtomicUsize::new(0);
        let mut slots: Vec<Option<Result<Vec<HostTensor>>>> =
            (0..batch.len()).map(|_| None).collect();
        std::thread::scope(|s| {
            let handles: Vec<_> = (0..workers)
                .map(|_| {
                    let next = &next;
                    s.spawn(move || {
                        let mut done = Vec::new();
                        loop {
                            // lint: relaxed-ok independent work-stealing cursor; no memory ordered against it
                            let i = next.fetch_add(1, Ordering::Relaxed);
                            if i >= batch.len() {
                                break;
                            }
                            done.push((i, self.execute_caught(name, &batch[i])));
                        }
                        done
                    })
                })
                .collect();
            for h in handles {
                // lint: allow(R2) workers cannot panic: every item runs under catch_unwind
                for (i, res) in h.join().expect("batch worker exited cleanly") {
                    slots[i] = Some(res);
                }
            }
        });
        // lint: allow(R2) the atomic cursor hands out every index in 0..len exactly once
        slots.into_iter().map(|r| r.expect("work stealing covers every index")).collect()
    }

    fn names(&self) -> Vec<String> {
        SOFT_ARTIFACTS.iter().map(|s| s.to_string()).collect()
    }

    fn platform(&self) -> String {
        "soft (rust limb oracle)".to_string()
    }
}

/// Pad a row-major `rows × cols` i32 matrix up to `(pr, pc)` with zeros
/// (artifact tiles are fixed-shape; the coordinator pads ragged tiles).
pub fn pad_matrix_i32(data: &[i32], rows: usize, cols: usize, pr: usize, pc: usize) -> Vec<i32> {
    assert!(pr >= rows && pc >= cols, "cannot pad down");
    assert_eq!(data.len(), rows * cols);
    let mut out = vec![0i32; pr * pc];
    for r in 0..rows {
        out[r * pc..r * pc + cols].copy_from_slice(&data[r * cols..(r + 1) * cols]);
    }
    out
}

/// Slice the top-left `rows × cols` out of a padded `(pr, pc)` matrix.
pub fn unpad_matrix_i32(data: &[i32], pr: usize, pc: usize, rows: usize, cols: usize) -> Vec<i32> {
    assert!(pr >= rows && pc >= cols);
    assert_eq!(data.len(), pr * pc);
    let mut out = Vec::with_capacity(rows * cols);
    for r in 0..rows {
        out.extend_from_slice(&data[r * pc..r * pc + cols]);
    }
    out
}

/// Locate the artifacts directory: `$GTA_ARTIFACTS` or `<repo>/artifacts`.
pub fn default_artifact_dir() -> PathBuf {
    if let Ok(d) = std::env::var("GTA_ARTIFACTS") {
        return PathBuf::from(d);
    }
    PathBuf::from(concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts"))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pad_unpad_roundtrip() {
        let m: Vec<i32> = (0..6).collect(); // 2x3
        let p = pad_matrix_i32(&m, 2, 3, 4, 5);
        assert_eq!(p.len(), 20);
        assert_eq!(p[0..3], [0, 1, 2]);
        assert_eq!(p[5..8], [3, 4, 5]);
        assert_eq!(p[3], 0);
        assert_eq!(unpad_matrix_i32(&p, 4, 5, 2, 3), m);
    }

    #[test]
    fn host_tensor_dtypes() {
        assert_eq!(HostTensor::I32(vec![1]).dtype(), DType::S32);
        assert_eq!(HostTensor::F32(vec![1.0]).dtype(), DType::F32);
        assert_eq!(HostTensor::I64(vec![1]).len(), 1);
        assert!(!HostTensor::I64(vec![1]).is_empty());
    }

    #[test]
    fn soft_backend_matches_limb_oracle_and_isolates_batch_failures() {
        let be = SoftBackend;
        let a: Vec<i32> = (0..64 * 64).map(|i| (i % 200) - 100).collect();
        let b: Vec<i32> = (0..64 * 64).map(|i| ((i * 7) % 200) - 100).collect();
        let inputs = vec![HostTensor::I32(a.clone()), HostTensor::I32(b.clone())];
        let a64: Vec<i64> = a.iter().map(|&v| v as i64).collect();
        let b64: Vec<i64> = b.iter().map(|&v| v as i64).collect();
        let want = crate::precision::limbs::limb_gemm(&a64, &b64, 64, 64, 64, 1, 32);
        let out = be.execute("mpra_gemm_i8_64", &inputs).unwrap();
        assert_eq!(out[0].as_i32().unwrap().len(), want.len());
        for (g, w) in out[0].as_i32().unwrap().iter().zip(&want) {
            assert_eq!(*g as i64, *w);
        }
        // batch dispatch: per-item results, one failure does not poison
        // the batch — same inputs reproduce the same outputs bit-exactly
        let bad = vec![HostTensor::I32(vec![1, 2, 3]), HostTensor::I32(vec![4])];
        let batch = vec![inputs.clone(), bad, inputs.clone()];
        let results = be.execute_batch("mpra_gemm_i8_64", &batch);
        assert_eq!(results.len(), 3);
        assert_eq!(results[0].as_ref().unwrap(), &out);
        assert!(results[1].is_err());
        assert_eq!(results[2].as_ref().unwrap(), &out);
        // failure injection artifact always errors
        assert!(be.execute(FAIL_ARTIFACT, &inputs).is_err());
        assert_eq!(be.names(), SOFT_ARTIFACTS.to_vec());
    }

    #[test]
    fn parallel_batch_is_bit_identical_to_serial_with_poison_isolated() {
        let be = SoftBackend;
        let tile = |seed: i32| -> Vec<HostTensor> {
            let a: Vec<i32> = (0..64 * 64).map(|i| ((i as i32 + seed) % 256) - 128).collect();
            let b: Vec<i32> = (0..64 * 64).map(|i| ((i as i32 * 31 + seed) % 256) - 128).collect();
            vec![HostTensor::I32(a), HostTensor::I32(b)]
        };
        // a batch wide enough to engage every worker, with poison spread
        // through it: wrong arity, wrong shape, wrong dtype
        let mut batch: Vec<Vec<HostTensor>> = (0..17).map(|i| tile(i * 7)).collect();
        batch[3] = vec![HostTensor::I32(vec![1, 2, 3])];
        batch[9] = vec![HostTensor::I32(vec![0; 16]), HostTensor::I32(vec![0; 16])];
        batch[14] = vec![HostTensor::F32(vec![0.0; 64 * 64]), HostTensor::I32(vec![0; 64 * 64])];
        // serial ground truth, item by item through the public execute
        let serial: Vec<_> =
            batch.iter().map(|inputs| be.execute("mpra_gemm_i8_64", inputs)).collect();
        let parallel = be.execute_batch("mpra_gemm_i8_64", &batch);
        assert_eq!(parallel.len(), batch.len());
        for (i, (p, s)) in parallel.iter().zip(&serial).enumerate() {
            match (p, s) {
                (Ok(pv), Ok(sv)) => assert_eq!(pv, sv, "item {i} diverged from serial"),
                (Err(pe), Err(se)) => {
                    assert_eq!(pe.to_string(), se.to_string(), "item {i} error text")
                }
                _ => panic!("item {i}: parallel {p:?} vs serial ok={}", s.is_ok()),
            }
        }
        assert!(parallel[3].is_err() && parallel[9].is_err() && parallel[14].is_err());
        assert!(parallel[2].is_ok() && parallel[4].is_ok(), "poison stays per-item");
        // repeated runs are deterministic despite work stealing
        let again = be.execute_batch("mpra_gemm_i8_64", &batch);
        for (i, (x, y)) in parallel.iter().zip(&again).enumerate() {
            assert_eq!(x.is_ok(), y.is_ok(), "item {i}");
            if let (Ok(xv), Ok(yv)) = (x, y) {
                assert_eq!(xv, yv, "item {i} not reproducible");
            }
        }
    }

    #[test]
    fn bignum_rejects_out_of_range_limbs_naming_the_index() {
        let be = SoftBackend;
        let good: Vec<i32> = (0..64).map(|i| (i * 3) % 256).collect();
        let mut high = good.clone();
        high[13] = 256;
        let err = be
            .execute("bignum_mul_64", &[HostTensor::I32(high), HostTensor::I32(good.clone())])
            .unwrap_err()
            .to_string();
        assert!(err.contains("input 0") && err.contains("limb 13") && err.contains("256"), "{err}");
        let mut neg = good.clone();
        neg[60] = -1;
        let err = be
            .execute("bignum_mul_64", &[HostTensor::I32(good.clone()), HostTensor::I32(neg)])
            .unwrap_err()
            .to_string();
        assert!(err.contains("input 1") && err.contains("limb 60"), "{err}");
        // boundary values 0 and 255 stay accepted and match the oracle
        let mut edge = good.clone();
        edge[0] = 255;
        edge[1] = 0;
        let out = be
            .execute("bignum_mul_64", &[HostTensor::I32(edge.clone()), HostTensor::I32(good)])
            .unwrap();
        let e8: Vec<u8> = edge.iter().map(|&v| v as u8).collect();
        let g8: Vec<u8> = (0..64u32).map(|i| ((i * 3) % 256) as u8).collect();
        let want = crate::precision::limbs::bignum_mul_precarry(&e8, &g8);
        assert_eq!(
            out[0].as_i32().unwrap().iter().map(|&v| v as i64).collect::<Vec<i64>>(),
            want
        );
    }

    #[test]
    fn engine_load_on_missing_dir_errors_instead_of_panicking() {
        // holds for both the stub (always errors) and the real engine
        // (manifest read fails) — the serve/verify paths rely on this
        let r = Engine::load(std::path::Path::new("/definitely/not/an/artifact/dir"));
        assert!(r.is_err());
    }
}
