//! Shared memoization for the §5 exploration engine.
//!
//! Workloads repeat operator shapes heavily (every AlexNet training step
//! replays the same five conv GEMMs three times; the serve path replays
//! identical tiles per request), so schedule search is memoized at three
//! granularities, all safe to share across worker threads:
//!
//! * [`EvalCache`] — single candidate evaluations, keyed by
//!   `(PGemm, GtaConfig, ScheduleConfig)`; lets a pruned selection pass
//!   and a later full sweep of the same operator share work.
//! * [`ExploreCache`] — whole candidate sweeps, keyed by
//!   `(PGemm, GtaConfig)`.
//! * [`ScheduleCache`] — the selected schedule, keyed by
//!   `(PGemm, GtaConfig)`; repeated operators schedule in O(1).
//!
//! All three are instances of [`Memo`], a sharded map whose values live
//! in `OnceLock` cells: concurrent requests for the same key compute the
//! value exactly once (later arrivals block on the cell instead of
//! duplicating the search), which keeps the coordinator's cache-hit
//! metrics exact under `serve`'s worker pool. A memo built with
//! [`Memo::with_capacity`] additionally sheds least-recently-used entries
//! per shard, so a long-lived server seeing unbounded distinct shapes
//! stays bounded in memory.
//!
//! **Rack sharing:** a multi-GTA rack (`coordinator::rack`) hands ONE
//! `Explorer` — hence one set of these memos — to every rack shard. The
//! keys carry the full [`GtaConfig`] (its compact identity is
//! [`GtaConfig::fingerprint`], which rack telemetry reports), so
//! heterogeneous shards coexist in the same memo without collision,
//! while a shape scheduled on any shard is a rack-wide hit for every
//! shard with the same config.

use super::{Candidate, ScheduleConfig};
use crate::arch::GtaConfig;
use crate::ops::PGemm;
use std::collections::{BTreeMap, HashMap};
use std::hash::{Hash, Hasher};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex, OnceLock};

/// Key of a whole-operator exploration.
pub type ExploreKey = (PGemm, GtaConfig);
/// Key of one evaluated point of the schedule space.
pub type EvalKey = (PGemm, GtaConfig, ScheduleConfig);

/// Memoized single-candidate evaluations.
pub type EvalCache = Memo<EvalKey, Candidate>;
/// Memoized full sweeps (shared, so callers clone an `Arc`).
pub type ExploreCache = Memo<ExploreKey, Arc<Vec<Candidate>>>;
/// Memoized selected schedules.
pub type ScheduleCache = Memo<ExploreKey, Candidate>;

/// One memo slot: the compute-once cell, its LRU recency stamp, and
/// whether its completion has been counted against the shard's cap.
#[derive(Debug)]
struct Slot<V> {
    cell: Arc<OnceLock<V>>,
    last_used: u64,
    /// Set by `complete`: only counted slots are evictable, so an entry
    /// whose computation is in flight (or just initialized but not yet
    /// recency-stamped) can neither be shed nor crowd out resident ones.
    counted: bool,
}

/// One shard: the key→slot map plus an ordered recency index so LRU
/// eviction is O(log n), not a scan of the shard. Invariant (maintained
/// under the shard lock): every map entry has exactly one index entry at
/// tick `slot.last_used`; ticks come from one global counter, so they
/// are unique. `completed` counts the `counted` slots — the population
/// the capacity bound applies to.
#[derive(Debug)]
struct ShardState<K, V> {
    map: HashMap<K, Slot<V>>,
    by_recency: BTreeMap<u64, K>,
    completed: usize,
}

impl<K: Eq + Hash + Clone, V> ShardState<K, V> {
    fn new() -> Self {
        ShardState { map: HashMap::new(), by_recency: BTreeMap::new(), completed: 0 }
    }

    /// Move `key`'s recency stamp to `now` (no-op for unknown keys).
    fn touch(&mut self, key: &K, now: u64) {
        if let Some(slot) = self.map.get_mut(key) {
            let old = slot.last_used;
            slot.last_used = now;
            self.by_recency.remove(&old);
            self.by_recency.insert(now, key.clone());
        }
    }
}

/// A sharded concurrent memo table with compute-once semantics and an
/// optional per-shard LRU capacity (see [`Memo::with_capacity`]): a
/// long-lived server seeing unbounded distinct shapes sheds the least
/// recently used entries instead of growing without bound.
#[derive(Debug)]
pub struct Memo<K, V> {
    shards: Vec<Mutex<ShardState<K, V>>>,
    /// LRU cap per shard; `None` = unbounded (the default).
    cap_per_shard: Option<usize>,
    /// Global recency clock (monotonic, relaxed — ticks unique).
    tick: AtomicU64,
    hits: AtomicU64,
    misses: AtomicU64,
    evictions: AtomicU64,
}

/// Evict completed LRU entries until at most `cap` completed entries
/// remain. In-flight cells are never evicted (concurrent callers hold
/// their `Arc` and compute-once semantics must survive) and never count
/// against the cap — a burst of new concurrent keys cannot crowd out
/// resident values; the map only transiently exceeds `cap` by the number
/// of outstanding computations.
fn evict_lru<K: Eq + Hash + Clone, V>(
    shard: &mut ShardState<K, V>,
    cap: usize,
    evictions: &AtomicU64,
) {
    while shard.completed > cap {
        // oldest-first walk of the recency index, skipping in-flight cells
        let victim = shard
            .by_recency
            .iter()
            .find(|&(_, k)| shard.map.get(k).is_some_and(|s| s.counted))
            .map(|(t, k)| (*t, k.clone()));
        match victim {
            Some((tick, key)) => {
                shard.by_recency.remove(&tick);
                shard.map.remove(&key);
                shard.completed -= 1;
                evictions.fetch_add(1, Ordering::Relaxed);
            }
            None => break,
        }
    }
}

impl<K: Eq + Hash + Clone, V: Clone> Memo<K, V> {
    pub fn new() -> Self {
        Self::with_shards(16)
    }

    pub fn with_shards(n: usize) -> Self {
        Memo {
            shards: (0..n.max(1)).map(|_| Mutex::new(ShardState::new())).collect(),
            cap_per_shard: None,
            tick: AtomicU64::new(0),
            hits: AtomicU64::new(0),
            misses: AtomicU64::new(0),
            evictions: AtomicU64::new(0),
        }
    }

    /// A memo holding at most ~`capacity` initialized entries, shedding
    /// least-recently-used ones past that. The cap is enforced per shard
    /// (`ceil(capacity / shards)` each, with `shards = min(capacity, 16)`),
    /// so the total initialized count at rest never exceeds
    /// `shards * ceil(capacity / shards)` — exactly `capacity` whenever
    /// `capacity` is a multiple of the shard count.
    pub fn with_capacity(capacity: usize) -> Self {
        let capacity = capacity.max(1);
        let shards = capacity.min(16);
        let mut memo = Self::with_shards(shards);
        memo.cap_per_shard = Some(capacity.div_ceil(shards));
        memo
    }

    fn shard(&self, key: &K) -> usize {
        let mut h = std::collections::hash_map::DefaultHasher::new();
        key.hash(&mut h);
        (h.finish() as usize) % self.shards.len()
    }

    fn now(&self) -> u64 {
        self.tick.fetch_add(1, Ordering::Relaxed)
    }

    /// The cell for `key`, creating an empty (in-flight, uncounted) one
    /// if absent. Holding the shard lock only for the map access keeps
    /// computation outside locks; eviction happens in [`Memo::complete`],
    /// the only place the completed population can grow.
    fn cell(&self, key: K) -> Arc<OnceLock<V>> {
        let now = self.now();
        let mut shard = self.shards[self.shard(&key)].lock().unwrap();
        if let Some(slot) = shard.map.get(&key) {
            let cell = Arc::clone(&slot.cell);
            shard.touch(&key, now);
            return cell;
        }
        let cell = Arc::new(OnceLock::new());
        shard
            .map
            .insert(key.clone(), Slot { cell: Arc::clone(&cell), last_used: now, counted: false });
        shard.by_recency.insert(now, key);
        cell
    }

    /// A computation for `key` just completed: stamp its recency at
    /// completion time — eviction must see how fresh the *value* is, not
    /// when its cell was created, or a slow expensive search would finish
    /// as the LRU victim — count it against the cap, and shed overflow.
    fn complete(&self, key: &K) {
        let now = self.now();
        let mut shard = self.shards[self.shard(key)].lock().unwrap();
        let freshly_counted = match shard.map.get_mut(key) {
            Some(slot) if !slot.counted => {
                slot.counted = true;
                true
            }
            Some(_) => false,
            None => return, // nothing can evict an uncounted cell, so: absent = never inserted
        };
        if freshly_counted {
            shard.completed += 1;
        }
        shard.touch(key, now);
        if let Some(cap) = self.cap_per_shard {
            evict_lru(&mut shard, cap, &self.evictions);
        }
    }

    /// Initialized value for `key`, if any (refreshes LRU recency).
    pub fn get(&self, key: &K) -> Option<V> {
        let now = self.now();
        let mut shard = self.shards[self.shard(key)].lock().unwrap();
        let v = shard.map.get(key)?.cell.get().cloned();
        shard.touch(key, now);
        v
    }

    /// Return the cached value or compute it exactly once. The returned
    /// flag is `true` iff THIS call performed the computation — under
    /// contention every other caller blocks on the cell and reports a
    /// hit, so hit/miss counts stay exact per distinct key. (An evicted
    /// key that comes back is a genuine recompute and counts as a miss.)
    pub fn get_or_compute(&self, key: K, f: impl FnOnce() -> V) -> (V, bool) {
        let cell = self.cell(key.clone());
        let mut computed = false;
        let v = cell
            .get_or_init(|| {
                computed = true;
                f()
            })
            .clone();
        if computed {
            self.misses.fetch_add(1, Ordering::Relaxed);
            self.complete(&key);
        } else {
            self.hits.fetch_add(1, Ordering::Relaxed);
        }
        (v, computed)
    }

    /// Publish a value computed elsewhere. Returns `false` (and keeps the
    /// existing value) if the key was already initialized.
    pub fn insert(&self, key: K, v: V) -> bool {
        let fresh = self.cell(key.clone()).set(v).is_ok();
        if fresh {
            self.complete(&key);
        }
        fresh
    }

    /// Number of initialized entries.
    pub fn len(&self) -> usize {
        self.shards
            .iter()
            .map(|s| s.lock().unwrap().map.values().filter(|c| c.cell.get().is_some()).count())
            .sum()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Total capacity (`None` = unbounded).
    pub fn capacity(&self) -> Option<usize> {
        self.cap_per_shard.map(|c| c * self.shards.len())
    }

    pub fn hits(&self) -> u64 {
        self.hits.load(Ordering::Relaxed)
    }

    pub fn misses(&self) -> u64 {
        self.misses.load(Ordering::Relaxed)
    }

    pub fn evictions(&self) -> u64 {
        self.evictions.load(Ordering::Relaxed)
    }
}

impl<K: Eq + Hash + Clone, V: Clone> Default for Memo<K, V> {
    fn default() -> Self {
        Memo::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU64;

    #[test]
    fn computes_once_then_hits() {
        let memo: Memo<u32, u32> = Memo::new();
        let (a, fresh_a) = memo.get_or_compute(7, || 42);
        let (b, fresh_b) = memo.get_or_compute(7, || panic!("must not recompute"));
        assert_eq!((a, b), (42, 42));
        assert!(fresh_a && !fresh_b);
        assert_eq!(memo.misses(), 1);
        assert_eq!(memo.hits(), 1);
        assert_eq!(memo.len(), 1);
        assert_eq!(memo.get(&7), Some(42));
        assert_eq!(memo.get(&8), None);
    }

    #[test]
    fn insert_respects_first_writer() {
        let memo: Memo<u32, u32> = Memo::new();
        assert!(memo.insert(1, 10));
        assert!(!memo.insert(1, 11));
        assert_eq!(memo.get(&1), Some(10));
        assert!(!memo.is_empty());
    }

    #[test]
    fn capped_memo_sheds_lru_sequentially() {
        // capacity 32 -> 16 shards x 2 per shard
        let memo: Memo<u64, u64> = Memo::with_capacity(32);
        assert_eq!(memo.capacity(), Some(32));
        for k in 0..200u64 {
            let (v, fresh) = memo.get_or_compute(k, || k * 2);
            assert_eq!(v, k * 2);
            assert!(fresh);
        }
        assert!(memo.len() <= 32, "len {} over capacity", memo.len());
        assert_eq!(memo.evictions(), 200 - memo.len() as u64);
        // an evicted key recomputes (miss), a resident key hits
        let resident = (0..200u64).find(|k| memo.get(k).is_some()).unwrap();
        let (_, fresh) = memo.get_or_compute(resident, || unreachable!());
        assert!(!fresh);
        let evicted = (0..200u64).find(|k| memo.get(k).is_none()).unwrap();
        let (v, fresh) = memo.get_or_compute(evicted, || evicted * 2);
        assert_eq!(v, evicted * 2);
        assert!(fresh, "evicted key must recompute");
    }

    #[test]
    fn capped_memo_respects_capacity_under_concurrent_access() {
        let memo: Memo<u64, u64> = Memo::with_capacity(32);
        let calls = AtomicU64::new(0);
        std::thread::scope(|scope| {
            for t in 0..8u64 {
                let memo = &memo;
                let calls = &calls;
                scope.spawn(move || {
                    for i in 0..400u64 {
                        let key = (t * 131 + i * 7) % 257;
                        let (v, _) = memo.get_or_compute(key, || {
                            calls.fetch_add(1, Ordering::SeqCst);
                            key + 1000
                        });
                        assert_eq!(v, key + 1000, "values stay exact across evictions");
                    }
                });
            }
        });
        // at rest every in-flight cell is initialized and every shard has
        // been shed to its cap, so the total obeys the capacity bound
        assert!(memo.len() <= 32, "len {} over capacity", memo.len());
        assert!(memo.evictions() > 0);
        // accounting stays exact: every call is either a hit or a miss,
        // and every miss corresponds to one actual computation
        assert_eq!(memo.hits() + memo.misses(), 8 * 400);
        assert_eq!(memo.misses(), calls.load(Ordering::SeqCst));
    }

    #[test]
    fn recency_index_stays_in_sync_with_the_map() {
        let memo: Memo<u64, u64> = Memo::with_capacity(16);
        for k in 0..100u64 {
            memo.get_or_compute(k % 37, || k);
            memo.get(&(k % 11));
            memo.insert(k % 53, k);
        }
        for shard in &memo.shards {
            let s = shard.lock().unwrap();
            assert_eq!(s.map.len(), s.by_recency.len(), "one index entry per slot");
            for (tick, key) in &s.by_recency {
                assert_eq!(s.map[key].last_used, *tick, "index points at the live stamp");
            }
            assert_eq!(
                s.completed,
                s.map.values().filter(|slot| slot.counted).count(),
                "completed counter tracks counted slots"
            );
        }
    }

    #[test]
    fn completion_time_not_insertion_time_drives_eviction() {
        let mut memo: Memo<u64, u64> = Memo::with_shards(1);
        memo.cap_per_shard = Some(3);
        // key 1's cell is created first (oldest insertion tick) but stays
        // in flight while 2 and 3 complete
        let slow = memo.cell(1);
        memo.get_or_compute(2, || 20);
        memo.get_or_compute(3, || 30);
        // the slow computation finishes last: stamped at completion
        slow.set(10).unwrap();
        memo.complete(&1);
        // the next insert overflows the cap: the victim must be key 2
        // (oldest completion), not key 1 (oldest insertion)
        memo.get_or_compute(4, || 40);
        assert_eq!(memo.get(&1), Some(10), "freshly completed entry survives");
        assert_eq!(memo.get(&2), None, "oldest completed entry is shed");
        assert_eq!(memo.get(&3), Some(30));
        assert_eq!(memo.get(&4), Some(40));
        assert_eq!(memo.evictions(), 1);
    }

    #[test]
    fn lru_hit_refreshes_recency() {
        let mut memo: Memo<u64, u64> = Memo::with_shards(1);
        memo.cap_per_shard = Some(2);
        memo.get_or_compute(1, || 10);
        memo.get_or_compute(2, || 20);
        // touch 1 so 2 becomes the LRU, then overflow with 3
        assert_eq!(memo.get(&1), Some(10));
        memo.get_or_compute(3, || 30);
        assert_eq!(memo.get(&1), Some(10), "recently read entry survives");
        assert_eq!(memo.get(&2), None, "LRU entry is shed");
        assert_eq!(memo.get(&3), Some(30));
    }

    #[test]
    fn uncapped_memo_never_evicts() {
        let memo: Memo<u64, u64> = Memo::new();
        for k in 0..500u64 {
            memo.get_or_compute(k, || k);
        }
        assert_eq!(memo.len(), 500);
        assert_eq!(memo.evictions(), 0);
        assert_eq!(memo.capacity(), None);
    }

    #[test]
    fn concurrent_callers_compute_each_key_exactly_once() {
        let memo: Memo<u64, u64> = Memo::new();
        let calls = AtomicU64::new(0);
        std::thread::scope(|scope| {
            for t in 0..8u64 {
                let memo = &memo;
                let calls = &calls;
                scope.spawn(move || {
                    for i in 0..64u64 {
                        let key = (t + i) % 4;
                        let (v, _) = memo.get_or_compute(key, || {
                            calls.fetch_add(1, Ordering::SeqCst);
                            key * 10
                        });
                        assert_eq!(v, key * 10);
                    }
                });
            }
        });
        assert_eq!(calls.load(Ordering::SeqCst), 4, "one compute per distinct key");
        assert_eq!(memo.misses(), 4);
        assert_eq!(memo.hits(), 8 * 64 - 4);
    }
}
