//! Shared memoization for the §5 exploration engine.
//!
//! Workloads repeat operator shapes heavily (every AlexNet training step
//! replays the same five conv GEMMs three times; the serve path replays
//! identical tiles per request), so schedule search is memoized at three
//! granularities, all safe to share across worker threads:
//!
//! * [`EvalCache`] — single candidate evaluations, keyed by
//!   `(PGemm, GtaConfig, ScheduleConfig)`; lets a pruned selection pass
//!   and a later full sweep of the same operator share work.
//! * [`ExploreCache`] — whole candidate sweeps, keyed by
//!   `(PGemm, GtaConfig)`.
//! * [`ScheduleCache`] — the selected schedule, keyed by
//!   `(PGemm, GtaConfig)`; repeated operators schedule in O(1).
//!
//! All three are instances of [`Memo`], a sharded map whose values live
//! in `OnceLock` cells: concurrent requests for the same key compute the
//! value exactly once (later arrivals block on the cell instead of
//! duplicating the search), which keeps the coordinator's cache-hit
//! metrics exact under `serve`'s worker pool.

use super::{Candidate, ScheduleConfig};
use crate::arch::GtaConfig;
use crate::ops::PGemm;
use std::collections::HashMap;
use std::hash::{Hash, Hasher};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex, OnceLock};

/// Key of a whole-operator exploration.
pub type ExploreKey = (PGemm, GtaConfig);
/// Key of one evaluated point of the schedule space.
pub type EvalKey = (PGemm, GtaConfig, ScheduleConfig);

/// Memoized single-candidate evaluations.
pub type EvalCache = Memo<EvalKey, Candidate>;
/// Memoized full sweeps (shared, so callers clone an `Arc`).
pub type ExploreCache = Memo<ExploreKey, Arc<Vec<Candidate>>>;
/// Memoized selected schedules.
pub type ScheduleCache = Memo<ExploreKey, Candidate>;

/// A sharded concurrent memo table with compute-once semantics.
#[derive(Debug)]
pub struct Memo<K, V> {
    shards: Vec<Mutex<HashMap<K, Arc<OnceLock<V>>>>>,
    hits: AtomicU64,
    misses: AtomicU64,
}

impl<K: Eq + Hash, V: Clone> Memo<K, V> {
    pub fn new() -> Self {
        Self::with_shards(16)
    }

    pub fn with_shards(n: usize) -> Self {
        Memo {
            shards: (0..n.max(1)).map(|_| Mutex::new(HashMap::new())).collect(),
            hits: AtomicU64::new(0),
            misses: AtomicU64::new(0),
        }
    }

    fn shard(&self, key: &K) -> usize {
        let mut h = std::collections::hash_map::DefaultHasher::new();
        key.hash(&mut h);
        (h.finish() as usize) % self.shards.len()
    }

    /// The cell for `key`, creating an empty one if absent. Holding the
    /// shard lock only for the map access keeps computation outside locks.
    fn cell(&self, key: K) -> Arc<OnceLock<V>> {
        let mut shard = self.shards[self.shard(&key)].lock().unwrap();
        shard.entry(key).or_insert_with(|| Arc::new(OnceLock::new())).clone()
    }

    /// Initialized value for `key`, if any.
    pub fn get(&self, key: &K) -> Option<V> {
        let cell = self.shards[self.shard(key)].lock().unwrap().get(key).cloned();
        cell.and_then(|c| c.get().cloned())
    }

    /// Return the cached value or compute it exactly once. The returned
    /// flag is `true` iff THIS call performed the computation — under
    /// contention every other caller blocks on the cell and reports a
    /// hit, so hit/miss counts stay exact per distinct key.
    pub fn get_or_compute(&self, key: K, f: impl FnOnce() -> V) -> (V, bool) {
        let cell = self.cell(key);
        let mut computed = false;
        let v = cell
            .get_or_init(|| {
                computed = true;
                f()
            })
            .clone();
        if computed {
            self.misses.fetch_add(1, Ordering::Relaxed);
        } else {
            self.hits.fetch_add(1, Ordering::Relaxed);
        }
        (v, computed)
    }

    /// Publish a value computed elsewhere. Returns `false` (and keeps the
    /// existing value) if the key was already initialized.
    pub fn insert(&self, key: K, v: V) -> bool {
        self.cell(key).set(v).is_ok()
    }

    /// Number of initialized entries.
    pub fn len(&self) -> usize {
        self.shards
            .iter()
            .map(|s| s.lock().unwrap().values().filter(|c| c.get().is_some()).count())
            .sum()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn hits(&self) -> u64 {
        self.hits.load(Ordering::Relaxed)
    }

    pub fn misses(&self) -> u64 {
        self.misses.load(Ordering::Relaxed)
    }
}

impl<K: Eq + Hash, V: Clone> Default for Memo<K, V> {
    fn default() -> Self {
        Memo::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU64;

    #[test]
    fn computes_once_then_hits() {
        let memo: Memo<u32, u32> = Memo::new();
        let (a, fresh_a) = memo.get_or_compute(7, || 42);
        let (b, fresh_b) = memo.get_or_compute(7, || panic!("must not recompute"));
        assert_eq!((a, b), (42, 42));
        assert!(fresh_a && !fresh_b);
        assert_eq!(memo.misses(), 1);
        assert_eq!(memo.hits(), 1);
        assert_eq!(memo.len(), 1);
        assert_eq!(memo.get(&7), Some(42));
        assert_eq!(memo.get(&8), None);
    }

    #[test]
    fn insert_respects_first_writer() {
        let memo: Memo<u32, u32> = Memo::new();
        assert!(memo.insert(1, 10));
        assert!(!memo.insert(1, 11));
        assert_eq!(memo.get(&1), Some(10));
        assert!(!memo.is_empty());
    }

    #[test]
    fn concurrent_callers_compute_each_key_exactly_once() {
        let memo: Memo<u64, u64> = Memo::new();
        let calls = AtomicU64::new(0);
        std::thread::scope(|scope| {
            for t in 0..8u64 {
                let memo = &memo;
                let calls = &calls;
                scope.spawn(move || {
                    for i in 0..64u64 {
                        let key = (t + i) % 4;
                        let (v, _) = memo.get_or_compute(key, || {
                            calls.fetch_add(1, Ordering::SeqCst);
                            key * 10
                        });
                        assert_eq!(v, key * 10);
                    }
                });
            }
        });
        assert_eq!(calls.load(Ordering::SeqCst), 4, "one compute per distinct key");
        assert_eq!(memo.misses(), 4);
        assert_eq!(memo.hits(), 8 * 64 - 4);
    }
}
