//! The §5 exploration engine: enumerate the dataflow × arrangement ×
//! K-segmentation × tile-direction space, evaluate candidates across a
//! worker-thread pool, prune dominated configurations before they are
//! fully costed, and memoize everything through [`super::cache`].
//!
//! Three exploration modes, all returning bit-identical candidates for
//! the same inputs (the cost model is pure arithmetic):
//!
//! * [`explore`] — the sequential reference sweep, in the canonical
//!   [`configs`] enumeration order.
//! * [`explore_parallel`] — the same sweep fanned across workers; results
//!   are re-ordered by enumeration index, so output equals [`explore`].
//! * [`explore_pruned`] — a selection-only sweep that skips candidates
//!   whose *lower bounds* are already strictly dominated by an evaluated
//!   candidate. Strict domination in both metrics implies a strictly
//!   larger sum of normalized squares, and a strictly-dominated candidate
//!   can never set either normalization minimum, so `select` over the
//!   survivors provably equals `select` over the full space. The bounds
//!   are precision-aware (a limb-work cycles floor) and the cheap SIMD
//!   fallback is costed first as an extra dominator, so high-limb
//!   (FP64/INT64) sweeps — whose spaces balloon with limbs² — prune
//!   hardest.
//!
//! Batch entry points ([`explore_batch`], [`schedule_batch`], and the
//! cache-sharing [`Explorer`]) distribute whole operators across the
//! pool — the shape that matters under serving traffic, where schedule
//! search (not the PE array) is the throughput bottleneck.

use super::cache::{EvalCache, ExploreCache, ScheduleCache};
use super::pattern::{self, Coverage, TileDir, EARLY_FILL_RECOVERY};
use super::{evaluate, select, Candidate, ScheduleConfig};
use crate::arch::{Dataflow, GtaConfig};
use crate::obs;
use crate::ops::PGemm;
use crate::sim::mpra;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{mpsc, Arc};

/// Worker count the parallel paths default to (bounded: schedule search
/// is compute-light per item, so more threads than cores only adds churn).
pub fn default_workers() -> usize {
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1).min(8)
}

/// Enumerate the schedule space for `g` in the canonical deterministic
/// order: arrangements (as `GtaConfig::arrangements` yields them) ×
/// systolic dataflows × power-of-two K-segmentation × tile direction,
/// with the arrangement-independent SIMD fallback last.
pub fn configs(g: &PGemm, gta: &GtaConfig) -> Vec<ScheduleConfig> {
    let mut out = Vec::new();
    for arrangement in gta.arrangements() {
        for flow in Dataflow::SYSTOLIC {
            let (r, c) = gta.array_shape(arrangement);
            let mapped = super::apply_cover_wrap(mpra::map_gemm(g, flow), r, c);
            let s_max = pattern::max_k_segments(mapped, r, c);
            let mut s = 1u64;
            while s <= s_max {
                for dir in TileDir::BOTH {
                    out.push(ScheduleConfig {
                        arrangement,
                        dataflow: flow,
                        k_segments: s,
                        tile_dir: dir,
                    });
                }
                s *= 2;
            }
        }
    }
    out.push(ScheduleConfig {
        arrangement: gta.arrangements()[0],
        dataflow: Dataflow::Simd,
        k_segments: 1,
        tile_dir: TileDir::Lateral,
    });
    out
}

/// Sequential reference sweep: evaluate every point of the space.
pub fn explore(g: &PGemm, gta: &GtaConfig) -> Vec<Candidate> {
    configs(g, gta).into_iter().map(|cfg| evaluate(g, cfg, gta)).collect()
}

/// The reference sweep fanned across `workers` threads. Results are
/// collected with their enumeration index and re-sorted, so the output
/// is identical to [`explore`] — order included.
pub fn explore_parallel(g: &PGemm, gta: &GtaConfig, workers: usize) -> Vec<Candidate> {
    let cfgs = configs(g, gta);
    parallel_map(&cfgs, workers, |cfg| evaluate(g, *cfg, gta))
}

/// Map `f` over `items` on a pool of `workers` threads (std::thread +
/// mpsc, the same idiom as `coordinator::serve`). Output order matches
/// input order regardless of completion order.
pub fn parallel_map<T, R, F>(items: &[T], workers: usize, f: F) -> Vec<R>
where
    T: Sync,
    R: Send,
    F: Fn(&T) -> R + Sync,
{
    let workers = workers.clamp(1, items.len().max(1));
    if workers <= 1 {
        return items.iter().map(|t| f(t)).collect();
    }
    let cursor = AtomicUsize::new(0);
    let (tx, rx) = mpsc::channel::<(usize, R)>();
    std::thread::scope(|scope| {
        for _ in 0..workers {
            let tx = tx.clone();
            let cursor = &cursor;
            let f = &f;
            scope.spawn(move || loop {
                // lint: relaxed-ok independent work-stealing cursor; no memory ordered against it
                let i = cursor.fetch_add(1, Ordering::Relaxed);
                if i >= items.len() {
                    break;
                }
                if tx.send((i, f(&items[i]))).is_err() {
                    break;
                }
            });
        }
    });
    drop(tx);
    let mut out: Vec<(usize, R)> = rx.into_iter().collect();
    out.sort_unstable_by_key(|&(i, _)| i);
    out.into_iter().map(|(_, r)| r).collect()
}

/// Statistics of a pruned sweep.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct PruneStats {
    /// Candidates fully evaluated (the survivors).
    pub evaluated: usize,
    /// Candidates skipped because their lower bounds were strictly
    /// dominated by an already-evaluated candidate.
    pub pruned: usize,
}

/// Conservative lower bounds `(cycles, memory_access)` for a systolic
/// config, computed without running the full systolic/energy model:
///
/// * cycles ≥ fold-count × stream depth of the adjusted footprint (the
///   model adds fill + drain on top), and never below the **limb-work
///   floor**: the array retires at most `r·c` limb-MACs per cycle, so an
///   `n`-limb precision (whose word MACs each cost `n²` limb products)
///   needs at least `limb_macs / (r·c)` cycles regardless of mapping —
///   the precision-aware bound that keeps FP64/INT64 sweeps tight. For
///   Cover cases the early-fill recovery can shave at most
///   `EARLY_FILL_RECOVERY` of the total, so the bound scales by the
///   residue.
/// * memory ≥ stationary fill + streamed re-reads + output writes +
///   K-segmentation merge traffic, plus the compulsory DRAM traffic —
///   exactly the model's terms minus the non-negative partial-sum
///   spill traffic.
fn lower_bounds(g: &PGemm, cfg: ScheduleConfig, gta: &GtaConfig) -> (u64, u64) {
    debug_assert!(cfg.dataflow != Dataflow::Simd);
    let (r, c) = gta.array_shape(cfg.arrangement);
    let mapped = mpra::map_gemm(g, cfg.dataflow);
    let coverage = pattern::classify(mapped, r, c);
    let wrapped = super::apply_cover_wrap(mapped, r, c);
    let s_max = pattern::max_k_segments(wrapped, r, c);
    let s = cfg.k_segments.clamp(1, s_max);
    let (adjusted, merge_elems) = super::apply_k_segments(wrapped, cfg.dataflow, s, g, r, c);
    let fr = adjusted.rows.div_ceil(r);
    let fc = adjusted.cols.div_ceil(c);
    let limb_floor = mpra::limb_macs(g).div_ceil(r * c);
    let base = (fr * fc * adjusted.temporal).max(limb_floor);
    let cycles_lb = match coverage {
        Coverage::Cover1 | Coverage::Cover2 | Coverage::Cover3 => {
            (base as f64 * (1.0 - EARLY_FILL_RECOVERY)).floor() as u64
        }
        _ => base,
    };
    let (m, n, k) = (g.m, g.n, g.k);
    let stream_elems = match cfg.dataflow {
        Dataflow::WS => k * n + m * k * fc + m * n,
        Dataflow::IS => m * k + k * n * fc + m * n,
        Dataflow::OS => m * k * fc + k * n * fr + m * n,
        Dataflow::Simd => unreachable!(),
    };
    let mem_lb = (stream_elems + 2 * merge_elems) * g.precision.bytes() + g.compulsory_bytes();
    (cycles_lb, mem_lb)
}

/// Selection-only sweep with early pruning: a config is skipped when some
/// already-evaluated candidate beats its lower bounds *strictly* in both
/// cycles and memory access. The SIMD fallback — O(1) to cost, with
/// cycles scaling limbs² — is evaluated FIRST and seeds the dominator
/// set, so high-limb (FP64/INT64) spaces prune against it before any
/// systolic candidate is costed. Returns the surviving candidates (in
/// enumeration order, SIMD last as in [`configs`]) and the prune
/// statistics; `select` over the survivors equals `select` over the full
/// space — every dominator (the SIMD fallback included) is itself a
/// survivor, so a pruned candidate is strictly dominated by a member of
/// the surviving set: it can neither win the least-sum-of-squares pick
/// nor set either normalization minimum.
pub fn explore_pruned(g: &PGemm, gta: &GtaConfig) -> (Vec<Candidate>, PruneStats) {
    explore_pruned_into(g, gta, None)
}

fn explore_pruned_into(
    g: &PGemm,
    gta: &GtaConfig,
    evals: Option<&EvalCache>,
) -> (Vec<Candidate>, PruneStats) {
    let eval_one = |cfg: ScheduleConfig| match evals {
        Some(cache) => cache.get_or_compute((*g, *gta, cfg), || evaluate(g, cfg, gta)).0,
        None => evaluate(g, cfg, gta),
    };
    let cfgs = configs(g, gta);
    let (simd_cfg, systolic) = cfgs.split_last().expect("configs is never empty");
    debug_assert_eq!(simd_cfg.dataflow, Dataflow::Simd);
    let simd = eval_one(*simd_cfg);
    let mut survivors: Vec<Candidate> = Vec::new();
    let mut stats = PruneStats { evaluated: 1, pruned: 0 };
    for cfg in systolic {
        let (cycles_lb, mem_lb) = lower_bounds(g, *cfg, gta);
        let dominated = std::iter::once(&simd)
            .chain(survivors.iter())
            .any(|y| y.report.cycles < cycles_lb && y.report.memory_access() < mem_lb);
        if dominated {
            stats.pruned += 1;
            continue;
        }
        survivors.push(eval_one(*cfg));
        stats.evaluated += 1;
    }
    // enumeration order is preserved: SIMD comes last, as in `configs`
    survivors.push(simd);
    (survivors, stats)
}

/// Explore + select through the pruned sweep — the hot-path entry point.
/// Provably returns the same least-sum-of-squares winner as
/// `select(&explore(g, gta))`.
pub fn schedule(g: &PGemm, gta: &GtaConfig) -> Candidate {
    let (survivors, _) = explore_pruned(g, gta);
    select(&survivors)
}

/// Shared exploration state: the three memo layers of [`super::cache`]
/// behind one handle, safe to use from many threads at once. The
/// coordinator owns one per process; batch helpers below create a
/// transient one.
#[derive(Debug, Default)]
pub struct Explorer {
    /// Whole-sweep memo, `(PGemm, GtaConfig)` → all candidates.
    pub sweeps: ExploreCache,
    /// Per-candidate memo, `(PGemm, GtaConfig, ScheduleConfig)` →
    /// evaluation; shared between pruned selection and full sweeps.
    pub evals: EvalCache,
    /// Selected-schedule memo, `(PGemm, GtaConfig)` → winner.
    pub selected: ScheduleCache,
}

impl Explorer {
    pub fn new() -> Explorer {
        Explorer::default()
    }

    /// An explorer whose memo layers shed least-recently-used entries
    /// past ~`schedules` distinct operator shapes (the per-candidate eval
    /// cache gets proportionally more room — a sweep evaluates dozens of
    /// candidates per shape). This is what a long-lived server wants:
    /// bounded memory under unbounded distinct request shapes, identical
    /// results, exact hit/miss accounting.
    pub fn with_capacity(schedules: usize) -> Explorer {
        let schedules = schedules.max(1);
        Explorer {
            sweeps: ExploreCache::with_capacity(schedules),
            evals: EvalCache::with_capacity(schedules.saturating_mul(64)),
            selected: ScheduleCache::with_capacity(schedules),
        }
    }

    /// Memoized full sweep; candidate evaluations go through the
    /// triple-keyed eval cache so a prior pruned pass is reused.
    pub fn explore(&self, g: &PGemm, gta: &GtaConfig) -> Arc<Vec<Candidate>> {
        self.sweeps
            .get_or_compute((*g, *gta), || {
                Arc::new(
                    configs(g, gta)
                        .into_iter()
                        .map(|cfg| {
                            self.evals
                                .get_or_compute((*g, *gta, cfg), || evaluate(g, cfg, gta))
                                .0
                        })
                        .collect(),
                )
            })
            .0
    }

    /// Memoized pruned schedule. The flag is `true` iff this call ran the
    /// search (i.e. a cache miss), which keeps caller metrics exact even
    /// when concurrent requests race on the same operator.
    ///
    /// A cache miss emits a `Sweep` span on the ambient trace (the
    /// request that paid for the search; racing requests that dedup onto
    /// it get a `Schedule` span only), tagged with the survivor count.
    pub fn schedule(&self, g: &PGemm, gta: &GtaConfig) -> (Candidate, bool) {
        self.selected.get_or_compute((*g, *gta), || {
            let sweep_start = obs::now_us();
            let (survivors, _) = explore_pruned_into(g, gta, Some(&self.evals));
            obs::emit(&obs::SpanEvent {
                trace_id: obs::current_trace(),
                stage: obs::Stage::Sweep,
                shard: obs::NO_SHARD,
                start_us: sweep_start,
                dur_us: obs::now_us().saturating_sub(sweep_start),
                extra: survivors.len() as u64,
            });
            select(&survivors)
        })
    }

    /// Full sweeps for a batch of operators across the worker pool.
    /// Output order matches `ops`; duplicate shapes share one sweep.
    pub fn explore_batch(
        &self,
        ops: &[PGemm],
        gta: &GtaConfig,
        workers: usize,
    ) -> Vec<Arc<Vec<Candidate>>> {
        parallel_map(ops, workers, |g| self.explore(g, gta))
    }

    /// Selected schedules for a batch of operators across the worker
    /// pool, with per-op freshness flags as in [`Explorer::schedule`].
    pub fn schedule_batch(
        &self,
        ops: &[PGemm],
        gta: &GtaConfig,
        workers: usize,
    ) -> Vec<(Candidate, bool)> {
        parallel_map(ops, workers, |g| self.schedule(g, gta))
    }
}

/// One-shot batch sweep: full candidate sets for every operator,
/// memoized within the batch, using the default worker count.
pub fn explore_batch(ops: &[PGemm], gta: &GtaConfig) -> Vec<Arc<Vec<Candidate>>> {
    Explorer::new().explore_batch(ops, gta, default_workers())
}

/// One-shot batch scheduling: the selected schedule for every operator,
/// memoized within the batch, using the default worker count.
pub fn schedule_batch(ops: &[PGemm], gta: &GtaConfig) -> Vec<Candidate> {
    Explorer::new()
        .schedule_batch(ops, gta, default_workers())
        .into_iter()
        .map(|(c, _)| c)
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::precision::Precision;

    fn gta() -> GtaConfig {
        GtaConfig::lanes16()
    }

    fn shapes() -> Vec<PGemm> {
        vec![
            PGemm::new(384, 169, 2304, Precision::Int8),
            PGemm::new(96, 169, 576, Precision::Fp32),
            PGemm::new(8, 8, 512, Precision::Int16),
            PGemm::new(1, 1, 4096, Precision::Fp64),
            PGemm::new(512, 48, 64, Precision::Bp16),
        ]
    }

    #[test]
    fn configs_enumeration_matches_explore_output() {
        let g = PGemm::new(64, 64, 64, Precision::Int8);
        let cfgs = configs(&g, &gta());
        let cands = explore(&g, &gta());
        assert_eq!(cfgs.len(), cands.len());
        for (cfg, cand) in cfgs.iter().zip(&cands) {
            assert_eq!(*cfg, cand.config);
        }
        assert_eq!(cfgs.last().unwrap().dataflow, Dataflow::Simd);
    }

    #[test]
    fn parallel_sweep_is_bit_identical_to_sequential() {
        for g in shapes() {
            let seq = explore(&g, &gta());
            for workers in [2, 3, 8] {
                let par = explore_parallel(&g, &gta(), workers);
                assert_eq!(seq, par, "workers={workers} {g:?}");
            }
        }
    }

    #[test]
    fn pruned_selection_equals_full_selection() {
        for lanes in [4u32, 16] {
            let cfg = GtaConfig::with_lanes(lanes);
            for g in shapes() {
                let full = select(&explore(&g, &cfg));
                let (survivors, stats) = explore_pruned(&g, &cfg);
                let pruned = select(&survivors);
                assert_eq!(full.config, pruned.config, "{g:?} lanes={lanes}");
                assert_eq!(full.report, pruned.report);
                assert_eq!(
                    stats.evaluated + stats.pruned,
                    configs(&g, &cfg).len(),
                    "every config accounted for"
                );
                assert_eq!(stats.evaluated, survivors.len());
            }
        }
    }

    #[test]
    fn pruning_actually_skips_work_somewhere() {
        // skewed shapes spawn K-seg candidates with heavy merge traffic
        // that a better arrangement's candidate strictly dominates —
        // prime pruning territory; at least one shape must prune
        let mut pruned = 0usize;
        for g in [
            PGemm::new(512, 8, 8, Precision::Int8),
            PGemm::new(8, 512, 8, Precision::Int8),
            PGemm::new(8, 8, 2048, Precision::Int8),
            PGemm::new(1024, 16, 16, Precision::Int16),
            PGemm::new(16, 1024, 16, Precision::Fp32),
        ] {
            for lanes in [16u32, 64] {
                pruned += explore_pruned(&g, &GtaConfig::with_lanes(lanes)).1.pruned;
            }
        }
        assert!(pruned > 0, "expected the prune pass to skip at least one candidate");
    }

    #[test]
    fn high_limb_sweeps_prune_and_still_select_the_true_winner() {
        // FP64/INT64 analogues of the skewed prune-territory shapes:
        // limbs² footprints tighten the limb-work floor and the
        // SIMD-seeded dominator, so pruning must fire somewhere while
        // selection stays provably exact everywhere
        let mut pruned = 0usize;
        for g in [
            PGemm::new(512, 8, 8, Precision::Int64),
            PGemm::new(8, 512, 8, Precision::Fp64),
            PGemm::new(8, 8, 2048, Precision::Fp64),
            PGemm::new(1024, 16, 16, Precision::Int64),
            PGemm::new(1, 1, 4096, Precision::Fp64),
        ] {
            for lanes in [16u32, 64] {
                let cfg = GtaConfig::with_lanes(lanes);
                let full = select(&explore(&g, &cfg));
                let (survivors, stats) = explore_pruned(&g, &cfg);
                let picked = select(&survivors);
                assert_eq!(full.config, picked.config, "{g:?} lanes={lanes}");
                assert_eq!(full.report, picked.report);
                assert_eq!(
                    stats.evaluated + stats.pruned,
                    configs(&g, &cfg).len(),
                    "every config accounted for: {g:?} lanes={lanes}"
                );
                pruned += stats.pruned;
            }
        }
        assert!(pruned > 0, "high-limb sweeps must prune somewhere");
    }

    #[test]
    fn explorer_caches_share_work_across_paths() {
        let ex = Explorer::new();
        let g = PGemm::new(128, 128, 256, Precision::Int8);
        let cfg = gta();
        let (_, fresh) = ex.schedule(&g, &cfg);
        assert!(fresh);
        let evals_after_schedule = ex.evals.len();
        assert!(evals_after_schedule > 0);
        // the full sweep must reuse the pruned pass's evaluations
        let sweep = ex.explore(&g, &cfg);
        assert_eq!(sweep.len(), configs(&g, &cfg).len());
        assert!(ex.evals.hits() > 0, "full sweep should hit pruned-pass evals");
        // and a repeat schedule is a pure cache hit
        let (_, fresh2) = ex.schedule(&g, &cfg);
        assert!(!fresh2);
    }

    #[test]
    fn capped_explorer_sheds_but_stays_correct() {
        let capped = Explorer::with_capacity(2);
        let cfg = gta();
        // 5 distinct shapes through a 2-entry schedule cache: later shapes
        // evict earlier ones, revisits recompute, winners never change
        for round in 0..2 {
            for g in shapes() {
                let (cand, _) = capped.schedule(&g, &cfg);
                assert_eq!(cand.config, schedule(&g, &cfg).config, "round {round} {g:?}");
            }
        }
        assert!(capped.selected.len() <= 2);
        assert!(capped.selected.evictions() > 0);
    }

    #[test]
    fn batch_results_match_per_op_results_in_order() {
        let ops = shapes();
        let cfg = gta();
        let batch = schedule_batch(&ops, &cfg);
        assert_eq!(batch.len(), ops.len());
        for (g, cand) in ops.iter().zip(&batch) {
            assert_eq!(cand.config, schedule(g, &cfg).config);
        }
        let sets = explore_batch(&ops, &cfg);
        for (g, set) in ops.iter().zip(&sets) {
            assert_eq!(**set, explore(g, &cfg));
        }
    }

    #[test]
    fn batch_dedups_repeated_operators() {
        let g = PGemm::new(256, 27 * 27, 5 * 5 * 96, Precision::Int8);
        let ops = vec![g; 12];
        let ex = Explorer::new();
        let out = ex.schedule_batch(&ops, &gta(), 4);
        assert_eq!(out.len(), 12);
        assert_eq!(out.iter().filter(|(_, fresh)| *fresh).count(), 1);
        assert_eq!(ex.selected.misses(), 1);
        assert_eq!(ex.selected.hits(), 11);
        for (cand, _) in &out {
            assert_eq!(cand.config, out[0].0.config);
        }
    }

    #[test]
    fn parallel_map_preserves_order() {
        let items: Vec<u64> = (0..100).collect();
        let out = parallel_map(&items, 7, |&x| x * 3);
        assert_eq!(out, items.iter().map(|x| x * 3).collect::<Vec<_>>());
        assert_eq!(parallel_map(&[] as &[u64], 4, |&x| x), Vec::<u64>::new());
    }
}
