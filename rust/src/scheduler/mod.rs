//! Scheduling-space exploration (§5): for a p-GEMM operator, jointly
//! choose **dataflow** (WS/IS/OS/SIMD), **array resize** (lane
//! arrangement), **K-segmentation** and **tiling direction**, trading
//! computing cycles against memory access; the final pick is the
//! normalized least-sum-of-squares point ("the preference is given to the
//! one with the least sum of squares").
//!
//! The cost model ([`evaluate`]) and selection rule ([`select`]) live
//! here; the search machinery lives in [`explorer`] (worker-pool batch
//! sweeps, Pareto pruning) on top of the shared memo layers in
//! [`cache`]. The convenience entry points below ([`explore`],
//! [`schedule`], [`explore_batch`], [`schedule_batch`]) delegate there.

pub mod cache;
pub mod explorer;
pub mod pattern;

pub use explorer::{Explorer, PruneStats};

use crate::arch::{Arrangement, Dataflow, GtaConfig};
use crate::ops::PGemm;
use crate::sim::systolic::{self, MappedGemm};
use crate::sim::{mpra, SimReport};
use crate::arch::energy;
use pattern::{Coverage, TileDir, EARLY_FILL_RECOVERY};

/// One point of the scheduling space.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct ScheduleConfig {
    pub arrangement: Arrangement,
    pub dataflow: Dataflow,
    /// K-segmentation factor (1 = none); only meaningful for Uncover cases.
    pub k_segments: u64,
    /// Tiling walk order for Cover1.
    pub tile_dir: TileDir,
}

/// An evaluated schedule candidate.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Candidate {
    pub config: ScheduleConfig,
    pub report: SimReport,
    pub coverage: Option<Coverage>,
}

/// Evaluate one schedule configuration for `g` on `gta`.
pub fn evaluate(g: &PGemm, cfg: ScheduleConfig, gta: &GtaConfig) -> Candidate {
    if cfg.dataflow == Dataflow::Simd {
        return Candidate {
            config: cfg,
            report: simd_gemm(g, gta),
            coverage: None,
        };
    }
    let (r, c) = gta.array_shape(cfg.arrangement);
    let mapped = mpra::map_gemm(g, cfg.dataflow);
    let coverage = pattern::classify(mapped, r, c);

    // ---- Cover2/3 wrap: fold the oversized spatial dim into the idle
    // other dimension ("tasks from the next column or row can be brought
    // in prematurely to fill the idle array", §5). Wrapping row folds of
    // the contraction dim (WS/IS) re-injects partial sums, which the
    // traffic model already counts; wrapping M/N folds (OS) is free.
    let wrapped = apply_cover_wrap(mapped, r, c);

    // ---- K-segmentation: replicate the (possibly wrapped) footprint
    // while it still under-covers the array ----
    let s_max = pattern::max_k_segments(wrapped, r, c);
    let s = cfg.k_segments.clamp(1, s_max);
    let (adjusted, merge_elems) = apply_k_segments(wrapped, cfg.dataflow, s, g, r, c);

    let run = systolic::run(cfg.dataflow, r, c, adjusted, g.m, g.n, g.k);

    // ---- early fill: recover ragged-edge idle cycles for Cover cases ----
    let cycles = match coverage {
        Coverage::Cover1 | Coverage::Cover2 | Coverage::Cover3 => {
            let idle = pattern::ragged_idle_fraction(adjusted, r, c, cfg.tile_dir);
            (run.cycles as f64 * (1.0 - EARLY_FILL_RECOVERY * idle)).ceil() as u64
        }
        _ => run.cycles,
    };

    let bytes = g.precision.bytes();
    let sram_bytes = (run.sram_read_elems + run.sram_write_elems + 2 * merge_elems) * bytes;
    // DRAM: compulsory traffic — the same idealized backing-store model
    // every baseline uses, so the cross-platform ratio isolates the
    // on-chip reuse difference the paper measures.
    let dram_bytes = g.compulsory_bytes();

    let macs = g.macs();
    let energy_pj = energy::total_energy_pj(macs, g.precision, cfg.dataflow, sram_bytes, dram_bytes);
    Candidate {
        config: cfg,
        report: SimReport {
            cycles,
            freq_mhz: gta.freq_mhz,
            sram_bytes,
            dram_bytes,
            macs,
            // `adjusted` already carries wrap + K-seg replication, so the
            // systolic run's utilization is the real figure
            utilization: run.utilization,
            energy_pj,
        },
        coverage: Some(coverage),
    }
}

/// SIMD (vector-mode) execution of a p-GEMM: no reuse — every MAC fetches
/// its operands from the VRF/SRAM stream (the Fig. 2 "no intensity" path).
fn simd_gemm(g: &PGemm, gta: &GtaConfig) -> SimReport {
    let per_lane = mpra::simd_mults_per_cycle(g.precision);
    let throughput = per_lane * gta.lanes as f64; // word-MACs/cycle
    let macs = g.macs();
    let cycles = (macs as f64 / throughput).ceil() as u64;
    let bytes = g.precision.bytes();
    // A broadcast + B stream per MAC, C write per output
    let sram_bytes = (2 * macs + g.m * g.n) * bytes;
    let dram_bytes = g.compulsory_bytes();
    SimReport {
        cycles: cycles.max(1),
        freq_mhz: gta.freq_mhz,
        sram_bytes,
        dram_bytes,
        macs,
        utilization: (throughput / (gta.total_pes() as f64 / (g.precision.limbs().pow(2) as f64)))
            .min(1.0),
        energy_pj: energy::total_energy_pj(macs, g.precision, Dataflow::Simd, sram_bytes, dram_bytes),
    }
}

/// Apply K-segmentation: `s` replicas placed into whichever spatial
/// dimension has slack, each carrying `1/s` of the contraction; merging
/// the replicas' partial outputs costs `(s-1)·M·N` extra element
/// reads+writes (§5's utilization-vs-reuse conflict).
pub(crate) fn apply_k_segments(
    mapped: MappedGemm,
    flow: Dataflow,
    s: u64,
    g: &PGemm,
    r: u64,
    c: u64,
) -> (MappedGemm, u64) {
    if s <= 1 {
        return (mapped, 0);
    }
    let merge = (s - 1) * g.m * g.n;
    let adjusted = match flow {
        // WS/IS: contraction is the ROW spatial dim — split rows, widen cols
        Dataflow::WS | Dataflow::IS => MappedGemm {
            rows: mapped.rows.div_ceil(s),
            cols: mapped.cols * s,
            temporal: mapped.temporal,
        },
        // OS: contraction is temporal — shorten the stream and replicate
        // the C tile into the slack dimension(s)
        Dataflow::OS => {
            let fit_r = (r / mapped.rows.max(1)).max(1);
            let s_r = s.min(fit_r);
            let s_c = (s / s_r).min((c / mapped.cols.max(1)).max(1)).max(1);
            MappedGemm {
                rows: mapped.rows * s_r,
                cols: mapped.cols * s_c,
                temporal: mapped.temporal.div_ceil(s_r * s_c),
            }
        }
        Dataflow::Simd => mapped,
    };
    (adjusted, merge)
}

/// Fold an over-covering dimension into idle capacity of the other
/// (Cover2: rows over, columns idle → wrap row folds sideways; Cover3:
/// symmetric). Leaves Uncover/Cover1 mappings untouched.
pub(crate) fn apply_cover_wrap(g: MappedGemm, r: u64, c: u64) -> MappedGemm {
    match pattern::classify(g, r, c) {
        Coverage::Cover2 => {
            let wrap = (c / g.cols.max(1)).min(g.rows.div_ceil(r)).max(1);
            MappedGemm {
                rows: g.rows.div_ceil(wrap),
                cols: g.cols * wrap,
                temporal: g.temporal,
            }
        }
        Coverage::Cover3 => {
            let wrap = (r / g.rows.max(1)).min(g.cols.div_ceil(c)).max(1);
            MappedGemm {
                rows: g.rows * wrap,
                cols: g.cols.div_ceil(wrap),
                temporal: g.temporal,
            }
        }
        _ => g,
    }
}

/// Enumerate + evaluate the whole scheduling space for `g` on `gta`
/// (the sequential reference sweep; see [`explorer`] for the parallel
/// and pruned variants).
pub fn explore(g: &PGemm, gta: &GtaConfig) -> Vec<Candidate> {
    explorer::explore(g, gta)
}

/// Full candidate sets for a batch of operators, evaluated across the
/// explorer's worker pool with repeated shapes memoized.
pub fn explore_batch(ops: &[PGemm], gta: &GtaConfig) -> Vec<std::sync::Arc<Vec<Candidate>>> {
    explorer::explore_batch(ops, gta)
}

/// Selected schedules for a batch of operators, searched concurrently.
pub fn schedule_batch(ops: &[PGemm], gta: &GtaConfig) -> Vec<Candidate> {
    explorer::schedule_batch(ops, gta)
}

/// §5 selection: normalize cycles and memory access by their minima over
/// the space, pick the candidate with the least sum of squares.
pub fn select(candidates: &[Candidate]) -> Candidate {
    assert!(!candidates.is_empty());
    let min_cycles = candidates.iter().map(|c| c.report.cycles).min().unwrap().max(1);
    let min_mem = candidates
        .iter()
        .map(|c| c.report.memory_access())
        .min()
        .unwrap()
        .max(1);
    *candidates
        .iter()
        .min_by(|a, b| {
            let score = |x: &Candidate| {
                let nc = x.report.cycles as f64 / min_cycles as f64;
                let nm = x.report.memory_access() as f64 / min_mem as f64;
                nc * nc + nm * nm
            };
            score(a).partial_cmp(&score(b)).unwrap()
        })
        .unwrap()
}

/// Explore + select in one call — the coordinator's entry point. Runs
/// the pruned sweep, which provably returns the same winner as
/// `select(&explore(g, gta))` (see [`explorer::explore_pruned`]).
pub fn schedule(g: &PGemm, gta: &GtaConfig) -> Candidate {
    explorer::schedule(g, gta)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::precision::Precision;

    fn gta() -> GtaConfig {
        GtaConfig::lanes16()
    }

    #[test]
    fn explore_covers_all_dataflows_and_arrangements() {
        let g = PGemm::new(64, 64, 64, Precision::Int8);
        let cands = explore(&g, &gta());
        let arrs: std::collections::HashSet<_> =
            cands.iter().map(|c| c.config.arrangement).collect();
        assert_eq!(arrs.len(), 5); // 16 lanes: 1x16..16x1
        for flow in Dataflow::ALL {
            assert!(
                cands.iter().any(|c| c.config.dataflow == flow),
                "{flow:?} missing"
            );
        }
    }

    #[test]
    fn selection_is_in_space_and_pareto_sane() {
        let g = PGemm::new(128, 128, 512, Precision::Bp16);
        let cands = explore(&g, &gta());
        let best = select(&cands);
        assert!(cands.iter().any(|c| c.config == best.config));
        // the selected point must not be strictly dominated
        for c in &cands {
            let dominates = c.report.cycles < best.report.cycles
                && c.report.memory_access() < best.report.memory_access();
            assert!(!dominates, "{:?} dominates selection", c.config);
        }
    }

    #[test]
    fn k_segmentation_helps_small_workloads() {
        // tiny GEMM on the big array: Uncover1; segmented candidates must
        // beat s=1 on cycles for the same dataflow/arrangement
        let g = PGemm::new(8, 8, 512, Precision::Int8);
        let cands = explore(&g, &gta());
        let os: Vec<_> = cands
            .iter()
            .filter(|c| {
                c.config.dataflow == Dataflow::OS
                    && c.config.arrangement == Arrangement::new(4, 4)
                    && c.config.tile_dir == TileDir::Lateral
            })
            .collect();
        assert!(os.len() > 1, "expected segmented OS candidates");
        let s1 = os.iter().find(|c| c.config.k_segments == 1).unwrap();
        let sbig = os.iter().max_by_key(|c| c.config.k_segments).unwrap();
        assert!(sbig.report.cycles < s1.report.cycles, "segmentation should cut cycles");
        assert!(
            sbig.report.memory_access() > s1.report.memory_access(),
            "…but cost memory (the §5 conflict)"
        );
    }

    #[test]
    fn precision_changes_the_chosen_schedule_space_shape() {
        // Fig 9's observation: different precisions give nonlinear,
        // different distributions for the same operator
        let g8 = PGemm::new(96, 169, 576, Precision::Int8);
        let g32 = PGemm::new(96, 169, 576, Precision::Int32);
        let r8 = schedule(&g8, &gta()).report;
        let r32 = schedule(&g32, &gta()).report;
        assert!(r32.cycles > r8.cycles, "more limbs -> more cycles");
    }

    #[test]
    fn simd_fallback_wins_for_pure_dot() {
        let g = PGemm::new(1, 1, 4096, Precision::Fp64);
        let best = schedule(&g, &gta());
        assert_eq!(best.config.dataflow, Dataflow::Simd, "dot should vectorize");
    }

    #[test]
    fn utilization_bounded() {
        for g in [
            PGemm::new(8, 8, 8, Precision::Int8),
            PGemm::new(500, 300, 700, Precision::Fp32),
        ] {
            for c in explore(&g, &gta()) {
                assert!(
                    c.report.utilization <= 1.0 + 1e-9,
                    "{:?} util {}",
                    c.config,
                    c.report.utilization
                );
            }
        }
    }
}
