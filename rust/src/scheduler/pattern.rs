//! Dataflow pattern matching (§5, Fig. 5): classify how a mapped workload
//! covers the physical array, and model the two utilization levers the
//! paper describes — K-segmentation for under-covering workloads and
//! early-fill tiling (Lateral/Vertical) for over-covering ones.

use crate::sim::systolic::MappedGemm;

/// The six Fig. 5 coverage cases.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Coverage {
    /// Workload short of the array in BOTH spatial directions.
    Uncover1,
    /// Exceeds in the ROW direction only; total still under array size.
    Uncover2,
    /// Exceeds in the COLUMN direction only; total still under array size.
    Uncover3,
    /// Exceeds in the ROW direction and covers the array.
    Cover2,
    /// Exceeds in the COLUMN direction and covers the array.
    Cover3,
    /// Exceeds in BOTH directions (tiled Lateral or Vertical).
    Cover1,
}

/// Tiling walk order for Cover1 (§5: "the tiling placement could be in
/// direction of Lateral or Vertical").
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum TileDir {
    Lateral,
    Vertical,
}

impl TileDir {
    pub const BOTH: [TileDir; 2] = [TileDir::Lateral, TileDir::Vertical];
}

/// Classify a mapped workload against an `r × c` array.
pub fn classify(g: MappedGemm, r: u64, c: u64) -> Coverage {
    let over_r = g.rows > r;
    let over_c = g.cols > c;
    match (over_r, over_c) {
        (false, false) => Coverage::Uncover1,
        (true, false) => {
            if g.rows * g.cols >= r * c {
                Coverage::Cover2
            } else {
                Coverage::Uncover2
            }
        }
        (false, true) => {
            if g.rows * g.cols >= r * c {
                Coverage::Cover3
            } else {
                Coverage::Uncover3
            }
        }
        (true, true) => Coverage::Cover1,
    }
}

/// Maximum useful K-segmentation factor for a coverage case: how many
/// replicas of the under-covering footprint fit in the array.
pub fn max_k_segments(g: MappedGemm, r: u64, c: u64) -> u64 {
    match classify(g, r, c) {
        Coverage::Uncover1 => {
            let fit_r = (r / g.rows.max(1)).max(1);
            let fit_c = (c / g.cols.max(1)).max(1);
            fit_r * fit_c
        }
        Coverage::Uncover2 | Coverage::Uncover3 => {
            // one free direction left
            let free = if g.rows > r { c / g.cols.max(1) } else { r / g.rows.max(1) };
            free.max(1)
        }
        _ => 1, // covering workloads cannot be replicated
    }
}

/// Fraction of total fold-cycles idled by the ragged edge in a direction,
/// for the Cover cases. Early fill ("tasks from the next column or row can
/// be brought in prematurely") recovers most of this.
pub fn ragged_idle_fraction(g: MappedGemm, r: u64, c: u64, dir: TileDir) -> f64 {
    let fr = g.rows.div_ceil(r);
    let fc = g.cols.div_ceil(c);
    // idle rows/cols on the last (ragged) fold; 0 when tiling is exact
    let rag_r = if g.rows % r == 0 { 0 } else { r - g.rows % r };
    let rag_c = if g.cols % c == 0 { 0 } else { c - g.cols % c };
    let last_r = g.rows - (fr - 1) * r; // used rows in last fold
    let last_c = g.cols - (fc - 1) * c;
    let total_area = (fr * fc * r * c) as f64;
    match dir {
        // lateral walk: the ragged COLUMN edge occurs once per row band
        TileDir::Lateral => (fr * rag_c * last_r.min(r)) as f64 / total_area,
        // vertical walk: the ragged ROW edge occurs once per column band
        TileDir::Vertical => (fc * rag_r * last_c.min(c)) as f64 / total_area,
    }
}

/// Fraction of the ragged-edge idle area the early-fill mechanism
/// recovers (the next tile's fill overlaps the edge fold's drain; the
/// first fill of each band cannot be hidden).
pub const EARLY_FILL_RECOVERY: f64 = 0.8;

#[cfg(test)]
mod tests {
    use super::*;

    fn g(rows: u64, cols: u64) -> MappedGemm {
        MappedGemm { rows, cols, temporal: 32 }
    }

    #[test]
    fn six_cases_classified() {
        let (r, c) = (16, 16);
        assert_eq!(classify(g(8, 8), r, c), Coverage::Uncover1);
        assert_eq!(classify(g(32, 4), r, c), Coverage::Uncover2);
        assert_eq!(classify(g(4, 32), r, c), Coverage::Uncover3);
        assert_eq!(classify(g(64, 8), r, c), Coverage::Cover2);
        assert_eq!(classify(g(8, 64), r, c), Coverage::Cover3);
        assert_eq!(classify(g(64, 64), r, c), Coverage::Cover1);
    }

    #[test]
    fn boundary_exact_fit_is_uncover1() {
        // workload == array: nothing exceeds, no segmentation needed
        assert_eq!(classify(g(16, 16), 16, 16), Coverage::Uncover1);
        assert_eq!(max_k_segments(g(16, 16), 16, 16), 1);
    }

    #[test]
    fn k_segments_fill_the_array() {
        // quarter-size workload: 4 replicas fit
        assert_eq!(max_k_segments(g(8, 8), 16, 16), 4);
        // half-row workload: 2 fit
        assert_eq!(max_k_segments(g(8, 16), 16, 16), 2);
        // covering workload: none
        assert_eq!(max_k_segments(g(64, 64), 16, 16), 1);
    }

    #[test]
    fn ragged_fraction_zero_for_perfect_tiling() {
        let f = ragged_idle_fraction(g(32, 32), 16, 16, TileDir::Lateral);
        assert_eq!(f, 0.0);
    }

    #[test]
    fn ragged_fraction_positive_and_direction_dependent() {
        // 40 cols on 16-wide array: ragged col edge of 8; rows perfect
        let lat = ragged_idle_fraction(g(32, 40), 16, 16, TileDir::Lateral);
        let ver = ragged_idle_fraction(g(32, 40), 16, 16, TileDir::Vertical);
        assert!(lat > 0.0);
        assert_eq!(ver, 0.0); // rows tile perfectly -> no row raggedness
    }

    #[test]
    fn ragged_fraction_bounded() {
        for (rows, cols) in [(17, 33), (100, 9), (5, 5), (31, 31)] {
            for dir in TileDir::BOTH {
                let f = ragged_idle_fraction(g(rows, cols), 16, 16, dir);
                assert!((0.0..1.0).contains(&f), "{rows}x{cols} {dir:?}: {f}");
            }
        }
    }
}
