//! End-to-end serving driver: replay a mixed stream of tensor-operator
//! requests through the coordinator — scheduling each through the §5
//! explorer, simulating cycles/traffic on the GTA model, and executing
//! the functional tiles through PJRT with inline numeric verification.
//! This is the `examples/e2e_serve.rs` workhorse (EXPERIMENTS.md §E2E).

use crate::coordinator::{Coordinator, ExecKind, Request};
use crate::ops::{PGemm, TensorOp};
use crate::precision::{limbs, Precision};
use crate::runtime::HostTensor;
use crate::util::rng::Rng;
use crate::GtaConfig;
use anyhow::Result;
use std::path::PathBuf;
use std::sync::Arc;
use std::time::Instant;

/// Summary of one serving run.
#[derive(Debug, Clone)]
pub struct ServeSummary {
    pub requests: u64,
    pub functional: u64,
    pub verified_ok: u64,
    pub verified_failed: u64,
    /// Distinct p-GEMM shapes scheduled concurrently by the batch
    /// pre-pass before the request workers started (all their serve-path
    /// schedules are memo hits).
    pub prescheduled: u64,
    pub wall_seconds: f64,
    pub throughput_rps: f64,
    pub total_sim_cycles: u64,
    pub metrics: crate::coordinator::metrics::Snapshot,
}

impl ServeSummary {
    pub fn render(&self) -> String {
        format!(
            "e2e serve: {} requests ({} functional, {} verified ok, {} failed)\n\
             wall {:.3}s -> {:.1} req/s; {} p-GEMMs batch-prescheduled; simulated GTA cycles {}\n{}",
            self.requests,
            self.functional,
            self.verified_ok,
            self.verified_failed,
            self.wall_seconds,
            self.throughput_rps,
            self.prescheduled,
            self.total_sim_cycles,
            self.metrics.render()
        )
    }
}

/// One functional request template: artifact + generated inputs + oracle.
struct FunctionalCase {
    artifact: &'static str,
    op: TensorOp,
    inputs: Vec<HostTensor>,
    /// expected i32 outputs for exact-integer artifacts (None = skip check)
    expect_i32: Option<Vec<i32>>,
}

fn make_case(kind: usize, rng: &mut Rng) -> FunctionalCase {
    match kind % 3 {
        0 => {
            // INT8 MPRA GEMM tile
            let dim = 64usize;
            let a: Vec<i64> = (0..dim * dim).map(|_| rng.range_i64(-100, 100)).collect();
            let b: Vec<i64> = (0..dim * dim).map(|_| rng.range_i64(-100, 100)).collect();
            let want = limbs::limb_gemm(&a, &b, dim, dim, dim, 1, 32);
            FunctionalCase {
                artifact: "mpra_gemm_i8_64",
                op: TensorOp::gemm(64, 64, 64, Precision::Int8),
                inputs: vec![
                    HostTensor::I32(a.iter().map(|&v| v as i32).collect()),
                    HostTensor::I32(b.iter().map(|&v| v as i32).collect()),
                ],
                expect_i32: Some(want.iter().map(|&v| v as i32).collect()),
            }
        }
        1 => {
            // INT16 MPRA GEMM tile
            let dim = 64usize;
            let a: Vec<i64> = (0..dim * dim).map(|_| rng.range_i64(-3000, 3000)).collect();
            let b: Vec<i64> = (0..dim * dim).map(|_| rng.range_i64(-3000, 3000)).collect();
            let want = limbs::limb_gemm(&a, &b, dim, dim, dim, 2, 32);
            FunctionalCase {
                artifact: "mpra_gemm_i16_64",
                op: TensorOp::gemm(64, 64, 64, Precision::Int16),
                inputs: vec![
                    HostTensor::I32(a.iter().map(|&v| v as i32).collect()),
                    HostTensor::I32(b.iter().map(|&v| v as i32).collect()),
                ],
                expect_i32: Some(want.iter().map(|&v| v as i32).collect()),
            }
        }
        _ => {
            // BNM: 512-bit big-number product
            let l = 64usize;
            let a: Vec<u8> = (0..l).map(|_| rng.range_u64(0, 255) as u8).collect();
            let b: Vec<u8> = (0..l).map(|_| rng.range_u64(0, 255) as u8).collect();
            let want = limbs::bignum_mul_precarry(&a, &b);
            FunctionalCase {
                artifact: "bignum_mul_64",
                op: TensorOp::gemm(64, 64, 1, Precision::Int8),
                inputs: vec![
                    HostTensor::I32(a.iter().map(|&v| v as i32).collect()),
                    HostTensor::I32(b.iter().map(|&v| v as i32).collect()),
                ],
                expect_i32: Some(want.iter().map(|&v| v as i32).collect()),
            }
        }
    }
}

/// Replay `n` mixed requests (functional MPRA/BNM tiles interleaved with
/// simulate-only workload operators) on `workers` threads.
pub fn run_mixed_stream(artifact_dir: PathBuf, n: u64, workers: usize) -> Result<ServeSummary> {
    let coord = Arc::new(Coordinator::with_engine(GtaConfig::lanes16(), artifact_dir)?);
    let mut rng = Rng::new(2024);

    // simulate-only operators drawn from the Table 2 suite
    let sim_ops: Vec<TensorOp> = crate::workloads::suite()
        .into_iter()
        .flat_map(|w| w.ops.into_iter().take(3))
        .collect();

    let mut expected: Vec<Option<Vec<i32>>> = Vec::new();
    let mut requests = Vec::new();
    for i in 0..n {
        if i % 2 == 0 {
            let case = make_case((i / 2) as usize, &mut rng);
            expected.push(case.expect_i32);
            requests.push(Request {
                id: i,
                op: case.op,
                exec: ExecKind::Functional {
                    artifact: case.artifact.to_string(),
                    inputs: case.inputs,
                },
            });
        } else {
            expected.push(None);
            requests.push(Request {
                id: i,
                op: sim_ops[(i as usize / 2) % sim_ops.len()],
                exec: ExecKind::Simulate,
            });
        }
    }

    let t0 = Instant::now();
    // Batch pre-pass: explore the schedule space of every distinct
    // p-GEMM in the stream concurrently, so the request workers below
    // hit the memo instead of searching inline.
    let mut seen = std::collections::HashSet::new();
    let gemms: Vec<PGemm> = requests
        .iter()
        .filter_map(|r| match &r.op {
            TensorOp::PGemm(g) => Some(*g),
            TensorOp::Vector(_) => None,
        })
        .filter(|g| seen.insert(*g))
        .collect();
    let prescheduled = coord.schedule_batch(&gemms).len() as u64;
    let responses = coord.serve(requests, workers);
    let wall = t0.elapsed().as_secs_f64();

    let mut functional = 0u64;
    let mut ok = 0u64;
    let mut failed = 0u64;
    let mut total_cycles = 0u64;
    for r in &responses {
        total_cycles += r.sim.cycles;
        if let Some(outs) = &r.outputs {
            functional += 1;
            if let Some(want) = &expected[r.id as usize] {
                match outs[0].as_i32() {
                    Some(got) if got == want.as_slice() => ok += 1,
                    _ => failed += 1,
                }
            }
        }
    }
    Ok(ServeSummary {
        requests: n,
        functional,
        verified_ok: ok,
        verified_failed: failed,
        prescheduled,
        wall_seconds: wall,
        throughput_rps: n as f64 / wall.max(1e-9),
        total_sim_cycles: total_cycles,
        metrics: coord.metrics.snapshot(),
    })
}
