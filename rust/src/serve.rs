//! End-to-end serving driver: replay a mixed stream of tensor-operator
//! requests through the coordinator — scheduling each through the §5
//! explorer, simulating cycles/traffic on the GTA model, and executing
//! the functional tiles through the coalescing batched dispatch path with
//! inline numeric verification. This is the `examples/e2e_serve.rs`
//! workhorse (EXPERIMENTS.md §E2E).
//!
//! Two backends drive the same path: the PJRT engine over AOT artifacts
//! ([`run_mixed_stream`]) and the in-tree rust-oracle
//! [`crate::runtime::SoftBackend`] ([`run_mixed_stream_soft`]), which
//! needs no artifacts and therefore runs in every build. Both also run
//! shard-aware: the `*_rack` drivers replay the identical stream across
//! a multi-GTA [`Rack`] (`gta serve --shards N`), with per-shard
//! utilization/traffic in the summary.
//!
//! Two feeding modes share the verification contract: the **batch**
//! drivers ([`run_stream`]/[`run_stream_rack`]) push the whole
//! pre-materialized stream through `serve`, while the **open-loop**
//! driver ([`run_open_loop_stream`], `gta serve --stream`) feeds a
//! long-lived [`crate::coordinator::RackSession`] with seeded
//! exponential inter-arrival gaps — realistic continuous ingest, which
//! is what lets the adaptive coalescing window engage.

use crate::coordinator::metrics::RackSnapshot;
use crate::coordinator::rack::{policy_by_name, Rack, RoutePolicy};
use crate::coordinator::{CoalesceConfig, Coordinator, ExecKind, Request, Response, ServeOptions};
use crate::net::{ClientOptions, GtaClient};
use crate::obs::StageHists;
use crate::ops::{PGemm, TensorOp};
use crate::precision::{limbs, Precision};
use crate::runtime::{default_artifact_dir, Engine, ExecBackend, HostTensor, SoftBackend};
use crate::util::rng::Rng;
use crate::GtaConfig;
use anyhow::{anyhow, Result};
use std::collections::HashSet;
use std::path::PathBuf;
use std::sync::Arc;
use std::time::Instant;

/// A coordinator over the soft rust-oracle backend — the offline stand-in
/// for the PJRT deployment, shared by the e2e tests, benches and
/// examples.
pub fn soft_coordinator(gta: GtaConfig, coalesce: CoalesceConfig) -> Result<Arc<Coordinator>> {
    Ok(Arc::new(Coordinator::with_backend_opts(
        gta,
        || Ok(Box::new(SoftBackend) as Box<dyn ExecBackend>),
        coalesce,
    )?))
}

/// A multi-GTA rack of soft-backend shards (one [`SoftBackend`] per
/// shard, each behind its own coalescing dispatcher).
pub fn soft_rack(
    configs: Vec<GtaConfig>,
    coalesce: CoalesceConfig,
    policy: Box<dyn RoutePolicy>,
) -> Result<Arc<Rack>> {
    Ok(Arc::new(Rack::with_backend(
        configs,
        |_shard| Ok(Box::new(SoftBackend) as Box<dyn ExecBackend>),
        coalesce,
        policy,
    )?))
}

/// Per-shard configs for a rack of `shards` instances: `lanes[i]` lanes
/// for shard `i` (cycled when shorter), 16-lane instances when empty.
pub fn shard_configs(shards: usize, lanes: &[u32]) -> Vec<GtaConfig> {
    (0..shards.max(1))
        .map(|i| {
            if lanes.is_empty() {
                GtaConfig::lanes16()
            } else {
                GtaConfig::with_lanes(lanes[i % lanes.len()])
            }
        })
        .collect()
}

/// A deterministic 64×64 INT8 MPRA functional tile request (the
/// serve-path unit of work the tests and benches replay).
pub fn gemm_tile_request(id: u64, artifact: &str, seed: i32) -> Request {
    let a: Vec<i32> = (0..64 * 64).map(|i| ((i + seed) % 200) - 100).collect();
    let b: Vec<i32> = (0..64 * 64).map(|i| ((i * 5 + seed) % 200) - 100).collect();
    Request {
        id,
        op: TensorOp::gemm(64, 64, 64, Precision::Int8),
        exec: ExecKind::Functional {
            artifact: artifact.to_string(),
            inputs: vec![HostTensor::I32(a), HostTensor::I32(b)],
        },
    }
}

/// Summary of one serving run.
#[derive(Debug, Clone)]
pub struct ServeSummary {
    pub requests: u64,
    /// Functional requests in the stream (each yields outputs or an error).
    pub functional: u64,
    pub verified_ok: u64,
    pub verified_failed: u64,
    /// Responses that carried a per-request error (failed execution,
    /// admission rejection, worker panic).
    pub errors: u64,
    /// Distinct p-GEMM shapes scheduled concurrently by the batch
    /// pre-pass before the request workers started (all their serve-path
    /// schedules are memo hits).
    pub prescheduled: u64,
    /// Coalesced executor dispatches (see batch histogram in `metrics`).
    pub coalesced_batches: u64,
    /// Largest coalesced batch.
    pub max_batch: u64,
    /// Coalescing window at end of run (µs): the static config, or the
    /// adaptive controller's chosen value (rack runs report the maximum
    /// across shards).
    pub coalesce_window_us: u64,
    /// Per-shard telemetry when the run went through a [`Rack`] (`None`
    /// for the single-coordinator drivers).
    pub shards: Option<RackSnapshot>,
    pub wall_seconds: f64,
    pub throughput_rps: f64,
    pub total_sim_cycles: u64,
    /// The coordinator's **cumulative** metrics snapshot at the end of
    /// the run (as are `coalesced_batches`/`max_batch`, which are taken
    /// from it): when several streams replay through one coordinator,
    /// counters span all of them. The stock drivers build a fresh
    /// coordinator per run, so there the numbers are per-run.
    pub metrics: crate::coordinator::metrics::Snapshot,
}

impl ServeSummary {
    pub fn render(&self) -> String {
        let mut s = format!(
            "e2e serve: {} requests ({} functional, {} verified ok, {} failed, {} errored)\n\
             wall {:.3}s -> {:.1} req/s; {} p-GEMMs batch-prescheduled; \
             {} coalesced dispatches (max batch {}, window {}us); simulated GTA cycles {}\n{}",
            self.requests,
            self.functional,
            self.verified_ok,
            self.verified_failed,
            self.errors,
            self.wall_seconds,
            self.throughput_rps,
            self.prescheduled,
            self.coalesced_batches,
            self.max_batch,
            self.coalesce_window_us,
            self.total_sim_cycles,
            self.metrics.render()
        );
        s.push_str(&render_stage_table(&self.metrics.stage_hist));
        if let Some(rack) = &self.shards {
            s.push_str(&rack.render());
        }
        s
    }
}

/// The per-stage latency breakdown table: one row per pipeline stage
/// that saw samples, with percentiles taken from the exact-merging
/// histograms (correct to bucket resolution however many shards
/// contributed). Empty when stage recording never ran.
pub fn render_stage_table(stage_hist: &StageHists) -> String {
    if stage_hist.is_empty() {
        return String::new();
    }
    let mut s = format!(
        "  {:<10} {:>10} {:>9} {:>9} {:>9} {:>10}\n",
        "stage", "samples", "p50(us)", "p95(us)", "p99(us)", "mean(us)"
    );
    for (stage, h) in stage_hist.non_empty() {
        s.push_str(&format!(
            "  {:<10} {:>10} {:>9} {:>9} {:>9} {:>10.1}\n",
            stage.name(),
            h.count(),
            h.value_at_quantile(0.5),
            h.value_at_quantile(0.95),
            h.value_at_quantile(0.99),
            h.mean()
        ));
    }
    s
}

/// One functional request template: artifact + generated inputs + oracle.
struct FunctionalCase {
    artifact: &'static str,
    op: TensorOp,
    inputs: Vec<HostTensor>,
    /// expected i32 outputs for exact-integer artifacts (None = skip check)
    expect_i32: Option<Vec<i32>>,
}

fn make_case(kind: usize, rng: &mut Rng) -> FunctionalCase {
    match kind % 3 {
        0 => {
            // INT8 MPRA GEMM tile
            let dim = 64usize;
            let a: Vec<i64> = (0..dim * dim).map(|_| rng.range_i64(-100, 100)).collect();
            let b: Vec<i64> = (0..dim * dim).map(|_| rng.range_i64(-100, 100)).collect();
            let want = limbs::limb_gemm(&a, &b, dim, dim, dim, 1, 32);
            FunctionalCase {
                artifact: "mpra_gemm_i8_64",
                op: TensorOp::gemm(64, 64, 64, Precision::Int8),
                inputs: vec![
                    HostTensor::I32(a.iter().map(|&v| v as i32).collect()),
                    HostTensor::I32(b.iter().map(|&v| v as i32).collect()),
                ],
                expect_i32: Some(want.iter().map(|&v| v as i32).collect()),
            }
        }
        1 => {
            // INT16 MPRA GEMM tile
            let dim = 64usize;
            let a: Vec<i64> = (0..dim * dim).map(|_| rng.range_i64(-3000, 3000)).collect();
            let b: Vec<i64> = (0..dim * dim).map(|_| rng.range_i64(-3000, 3000)).collect();
            let want = limbs::limb_gemm(&a, &b, dim, dim, dim, 2, 32);
            FunctionalCase {
                artifact: "mpra_gemm_i16_64",
                op: TensorOp::gemm(64, 64, 64, Precision::Int16),
                inputs: vec![
                    HostTensor::I32(a.iter().map(|&v| v as i32).collect()),
                    HostTensor::I32(b.iter().map(|&v| v as i32).collect()),
                ],
                expect_i32: Some(want.iter().map(|&v| v as i32).collect()),
            }
        }
        _ => {
            // BNM: 512-bit big-number product
            let l = 64usize;
            let a: Vec<u8> = (0..l).map(|_| rng.range_u64(0, 255) as u8).collect();
            let b: Vec<u8> = (0..l).map(|_| rng.range_u64(0, 255) as u8).collect();
            let want = limbs::bignum_mul_precarry(&a, &b);
            FunctionalCase {
                artifact: "bignum_mul_64",
                op: TensorOp::gemm(64, 64, 1, Precision::Int8),
                inputs: vec![
                    HostTensor::I32(a.iter().map(|&v| v as i32).collect()),
                    HostTensor::I32(b.iter().map(|&v| v as i32).collect()),
                ],
                expect_i32: Some(want.iter().map(|&v| v as i32).collect()),
            }
        }
    }
}

/// Build the standard mixed stream: `n` requests with ids `0..n`,
/// functional MPRA/BNM tiles (even ids) interleaved with simulate-only
/// workload operators (odd ids). Returns the requests plus the id-indexed
/// verification oracle.
pub fn mixed_stream(n: u64) -> (Vec<Request>, Vec<Option<Vec<i32>>>) {
    let mut rng = Rng::new(2024);

    // simulate-only operators drawn from the Table 2 suite
    let sim_ops: Vec<TensorOp> = crate::workloads::suite()
        .into_iter()
        .flat_map(|w| w.ops.into_iter().take(3))
        .collect();

    let mut expected: Vec<Option<Vec<i32>>> = Vec::new();
    let mut requests = Vec::new();
    for i in 0..n {
        if i % 2 == 0 {
            let case = make_case((i / 2) as usize, &mut rng);
            expected.push(case.expect_i32);
            requests.push(Request {
                id: i,
                op: case.op,
                exec: ExecKind::Functional {
                    artifact: case.artifact.to_string(),
                    inputs: case.inputs,
                },
            });
        } else {
            expected.push(None);
            requests.push(Request {
                id: i,
                op: sim_ops[(i as usize / 2) % sim_ops.len()],
                exec: ExecKind::Simulate,
            });
        }
    }
    (requests, expected)
}

/// Replay `requests` on `workers` threads through `coord` and verify
/// functional outputs against `expected` (indexed by request id; ids at
/// or past `expected.len()` and `None` slots are simply unchecked).
///
/// Verification is total and panic-free: a functional response with an
/// error, missing outputs, an empty output tuple, or a wrong dtype counts
/// as `verified_failed` — and `serve` guarantees one response per
/// request, so nothing is silently lost.
pub fn run_stream(
    coord: &Arc<Coordinator>,
    requests: Vec<Request>,
    expected: &[Option<Vec<i32>>],
    workers: usize,
) -> ServeSummary {
    let functional_ids = functional_ids(&requests);
    let t0 = Instant::now();
    // Batch pre-pass: explore the schedule space of every distinct
    // p-GEMM in the stream concurrently, so the request workers below
    // hit the memo instead of searching inline.
    let prescheduled = coord.schedule_batch(&distinct_gemms(&requests)).len() as u64;
    let responses = coord.serve(requests, workers);
    let wall = t0.elapsed().as_secs_f64();
    summarize(&responses, expected, &functional_ids, wall, prescheduled, coord.metrics.snapshot(), None)
}

/// Replay `requests` through a shard-aware [`Rack`] on `workers`
/// threads, with the same verification contract as [`run_stream`]. The
/// schedule pre-pass warms the rack-shared cache once per DISTINCT shard
/// config, so every shard's workers hit the memo no matter where the
/// router places each request; the summary carries per-shard telemetry.
pub fn run_stream_rack(
    rack: &Arc<Rack>,
    requests: Vec<Request>,
    expected: &[Option<Vec<i32>>],
    workers: usize,
) -> ServeSummary {
    let functional_ids = functional_ids(&requests);
    let t0 = Instant::now();
    let gemms = distinct_gemms(&requests);
    let mut seen_cfgs = HashSet::new();
    let mut prescheduled = 0u64;
    for shard in rack.shards() {
        if seen_cfgs.insert(shard.gta) {
            prescheduled += shard.schedule_batch(&gemms).len() as u64;
        }
    }
    let responses = rack.serve(requests, workers);
    let wall = t0.elapsed().as_secs_f64();
    let rs = rack.snapshot();
    summarize(
        &responses,
        expected,
        &functional_ids,
        wall,
        prescheduled,
        rs.aggregate.clone(),
        Some(rs),
    )
}

/// Replay `requests` through a long-lived
/// [`crate::coordinator::RackSession`] as an **open-loop arrival
/// process**: inter-arrival gaps are exponential
/// (Poisson arrivals) at `rate_rps`, drawn from a [`Rng`] seeded with
/// `seed` — the same seed replays the same arrival schedule. The driver
/// thread submits each request at its arrival time (blocking admission:
/// overload turns into backpressure, not loss), opportunistically
/// consuming completions between arrivals, then drains the session and
/// verifies like [`run_stream`]. Unlike the batch drivers there is no
/// schedule pre-pass — the cache warms the way it would in production,
/// and the adaptive coalescing window sees real arrival gaps.
pub fn run_open_loop_stream(
    rack: &Arc<Rack>,
    requests: Vec<Request>,
    expected: &[Option<Vec<i32>>],
    workers: usize,
    rate_rps: f64,
    seed: u64,
) -> ServeSummary {
    let functional_ids = functional_ids(&requests);
    let session = rack.open_session(ServeOptions::with_workers(workers));
    let t0 = Instant::now();
    let mut responses = open_loop_replay(
        requests,
        rate_rps,
        seed,
        t0,
        |req| {
            session
                .submit(req)
                .map(|_ticket| ())
                .map_err(|e| anyhow!("open-loop submission under blocking admission rejected: {e:?}"))
        },
        || Ok(session.try_recv()),
    )
    // lint: allow(R2) both driver closures above return Ok — no transport to fail in-process
    .expect("in-process open-loop submission cannot fail");
    while let Some(r) = session.recv() {
        responses.push(r);
    }
    responses.append(&mut session.drain());
    crate::coordinator::order_responses(&mut responses);
    let wall = t0.elapsed().as_secs_f64();
    let rs = rack.snapshot();
    summarize(&responses, expected, &functional_ids, wall, 0, rs.aggregate.clone(), Some(rs))
}

/// THE seeded open-loop arrival loop — one copy of the exponential
/// inter-arrival draw (Poisson arrivals at `rate_rps`, `Rng::new(seed)`)
/// and the submit/consume interleaving, shared by the in-process session
/// driver ([`run_open_loop_stream`]) and the TCP client driver
/// ([`run_open_loop_client`]); a replay of one seed is comparable
/// in-process vs. over the wire *by construction*. Submits each request
/// at its arrival time, opportunistically consuming completions between
/// arrivals; returns everything consumed (the caller drains the rest).
fn open_loop_replay(
    requests: Vec<Request>,
    rate_rps: f64,
    seed: u64,
    t0: Instant,
    mut submit: impl FnMut(Request) -> Result<()>,
    mut try_recv: impl FnMut() -> Result<Option<Response>>,
) -> Result<Vec<Response>> {
    let mut rng = Rng::new(seed);
    let mut due = std::time::Duration::ZERO;
    let mut responses: Vec<Response> = Vec::with_capacity(requests.len());
    for req in requests {
        // exponential inter-arrival gap for a Poisson process at rate_rps
        let gap = -(1.0 - rng.f64()).ln() / rate_rps.max(1e-9);
        due += std::time::Duration::from_secs_f64(gap);
        loop {
            let elapsed = t0.elapsed();
            if elapsed >= due {
                break;
            }
            // consume completions while waiting for the next arrival
            match try_recv()? {
                Some(r) => responses.push(r),
                None => {
                    let remaining = due - elapsed;
                    if remaining > std::time::Duration::from_micros(200) {
                        std::thread::sleep(std::time::Duration::from_micros(100));
                    } else {
                        std::thread::yield_now();
                    }
                }
            }
        }
        while let Some(r) = try_recv()? {
            responses.push(r);
        }
        submit(req)?;
    }
    Ok(responses)
}

/// Ids of the functional requests in a stream.
fn functional_ids(requests: &[Request]) -> HashSet<u64> {
    requests
        .iter()
        .filter(|r| matches!(r.exec, ExecKind::Functional { .. }))
        .map(|r| r.id)
        .collect()
}

/// Distinct p-GEMM shapes in a stream, in first-seen order.
fn distinct_gemms(requests: &[Request]) -> Vec<PGemm> {
    let mut seen = HashSet::new();
    requests
        .iter()
        .filter_map(|r| match &r.op {
            TensorOp::PGemm(g) => Some(*g),
            TensorOp::Vector(_) => None,
        })
        .filter(|g| seen.insert(*g))
        .collect()
}

/// Verify responses against the oracle and fold everything into a
/// [`ServeSummary`] — shared by the coordinator and rack drivers.
fn summarize(
    responses: &[Response],
    expected: &[Option<Vec<i32>>],
    functional_ids: &HashSet<u64>,
    wall: f64,
    prescheduled: u64,
    snap: crate::coordinator::metrics::Snapshot,
    shards: Option<RackSnapshot>,
) -> ServeSummary {
    let n = responses.len() as u64;
    let mut functional = 0u64;
    let mut ok = 0u64;
    let mut failed = 0u64;
    let mut errors = 0u64;
    let mut total_cycles = 0u64;
    for r in responses {
        total_cycles += r.sim.cycles;
        if r.error.is_some() {
            errors += 1;
        }
        if !functional_ids.contains(&r.id) {
            continue;
        }
        functional += 1;
        match &r.outputs {
            Some(outs) if r.error.is_none() => {
                if let Some(want) = expected.get(r.id as usize).and_then(|w| w.as_ref()) {
                    match outs.first().and_then(|t| t.as_i32()) {
                        Some(got) if got == want.as_slice() => ok += 1,
                        _ => failed += 1,
                    }
                }
            }
            // failed execution / missing outputs: a verification failure,
            // never a panic
            _ => failed += 1,
        }
    }
    ServeSummary {
        requests: n,
        functional,
        verified_ok: ok,
        verified_failed: failed,
        errors,
        prescheduled,
        coalesced_batches: snap.batches,
        max_batch: snap.max_batch,
        coalesce_window_us: snap.coalesce_window_us,
        shards,
        wall_seconds: wall,
        throughput_rps: n as f64 / wall.max(1e-9),
        total_sim_cycles: total_cycles,
        metrics: snap,
    }
}

/// Replay `n` mixed requests on `workers` threads against the PJRT
/// engine over the AOT artifacts in `artifact_dir`.
pub fn run_mixed_stream(artifact_dir: PathBuf, n: u64, workers: usize) -> Result<ServeSummary> {
    let coord = Arc::new(Coordinator::with_engine(GtaConfig::lanes16(), artifact_dir)?);
    let (requests, expected) = mixed_stream(n);
    Ok(run_stream(&coord, requests, &expected, workers))
}

/// Replay `n` mixed requests on `workers` threads against the soft
/// (rust limb oracle) backend — no artifacts or PJRT required, numerics
/// identical by construction.
pub fn run_mixed_stream_soft(n: u64, workers: usize) -> Result<ServeSummary> {
    let coord = soft_coordinator(GtaConfig::lanes16(), CoalesceConfig::default())?;
    let (requests, expected) = mixed_stream(n);
    Ok(run_stream(&coord, requests, &expected, workers))
}

/// Resolve a routing policy name or fail with the accepted spellings.
fn parse_policy(policy: &str) -> Result<Box<dyn RoutePolicy>> {
    policy_by_name(policy)
        .ok_or_else(|| anyhow!("unknown routing policy {policy:?} (rr|least|affinity)"))
}

/// Replay `n` mixed requests across a `shards`-wide soft-backend rack
/// (`gta serve --backend soft --shards N`): one `SoftBackend` +
/// dispatcher per shard, `lanes[i]` lanes per shard (16 when empty),
/// routing per `policy` (`rr` | `least` | `affinity`).
pub fn run_mixed_stream_soft_rack(
    n: u64,
    workers: usize,
    shards: usize,
    lanes: &[u32],
    policy: &str,
) -> Result<ServeSummary> {
    let rack = soft_rack(
        shard_configs(shards, lanes),
        CoalesceConfig::default(),
        parse_policy(policy)?,
    )?;
    let (requests, expected) = mixed_stream(n);
    Ok(run_stream_rack(&rack, requests, &expected, workers))
}

/// Replay `n` mixed requests across a PJRT-backed rack: every shard
/// compiles the AOT artifacts in `artifact_dir` on its own executor
/// thread (one engine per shard).
pub fn run_mixed_stream_rack(
    artifact_dir: PathBuf,
    n: u64,
    workers: usize,
    shards: usize,
    lanes: &[u32],
    policy: &str,
) -> Result<ServeSummary> {
    let rack = Arc::new(Rack::with_backend(
        shard_configs(shards, lanes),
        move |_shard| Ok(Box::new(Engine::load(&artifact_dir)?) as Box<dyn ExecBackend>),
        CoalesceConfig::default(),
        parse_policy(policy)?,
    )?);
    let (requests, expected) = mixed_stream(n);
    Ok(run_stream_rack(&rack, requests, &expected, workers))
}

/// `gta serve --stream --backend soft`: drive `n` mixed requests as a
/// seeded open-loop Poisson arrival process at `rate_rps` through a
/// streaming session over a soft-backend rack (adaptive coalescing
/// window, so sustained arrival rates visibly engage it).
pub fn run_open_loop_soft_rack(
    n: u64,
    workers: usize,
    shards: usize,
    lanes: &[u32],
    policy: &str,
    rate_rps: f64,
    seed: u64,
) -> Result<ServeSummary> {
    let rack = soft_rack(
        shard_configs(shards, lanes),
        CoalesceConfig::with_adaptive_window(),
        parse_policy(policy)?,
    )?;
    let (requests, expected) = mixed_stream(n);
    Ok(run_open_loop_stream(&rack, requests, &expected, workers, rate_rps, seed))
}

/// Build the rack `gta serve --listen` exposes over TCP: soft or PJRT
/// backend, `shards`/`lanes`/`policy` exactly as the in-process serve
/// modes, with the adaptive coalescing window on — continuous open-loop
/// arrivals are the expected traffic for a network server.
pub fn listen_rack(
    backend: &str,
    artifact_dir: Option<PathBuf>,
    shards: usize,
    lanes: &[u32],
    policy: &str,
) -> Result<Arc<Rack>> {
    let coalesce = CoalesceConfig::with_adaptive_window();
    match backend {
        "soft" => soft_rack(shard_configs(shards, lanes), coalesce, parse_policy(policy)?),
        "pjrt" => {
            let dir = artifact_dir.unwrap_or_else(default_artifact_dir);
            Ok(Arc::new(Rack::with_backend(
                shard_configs(shards, lanes),
                move |_shard| Ok(Box::new(Engine::load(&dir)?) as Box<dyn ExecBackend>),
                coalesce,
                parse_policy(policy)?,
            )?))
        }
        other => Err(anyhow!("unknown backend {other:?} (pjrt|soft)")),
    }
}

/// Replay `n` mixed requests through a remote GTA server
/// (`gta client --connect ADDR`): submit everything, drain, then verify
/// client-side against the same oracle as [`run_stream`]. The summary's
/// metrics/telemetry are the server session's (cumulative for the
/// server's rack, like repeated streams through one coordinator).
pub fn run_client_mixed(addr: &str, n: u64) -> Result<ServeSummary> {
    run_client_mixed_proto(addr, n, crate::net::PROTO_VERSION)
}

/// [`run_client_mixed`] with an explicit protocol-version cap for the
/// client's `Hello` (`gta client --proto 1` replays the PR 5 v1 wire
/// behavior against any server).
pub fn run_client_mixed_proto(addr: &str, n: u64, max_proto: u64) -> Result<ServeSummary> {
    run_client_mixed_with(addr, n, ClientOptions { max_proto, ..ClientOptions::default() })
}

/// [`run_client_mixed`] with full [`ClientOptions`] control (protocol
/// cap, connect/read timeouts).
pub fn run_client_mixed_with(addr: &str, n: u64, opts: ClientOptions) -> Result<ServeSummary> {
    let mut client = GtaClient::connect_with(addr, opts)?;
    let (requests, expected) = mixed_stream(n);
    let functional_ids = functional_ids(&requests);
    let t0 = Instant::now();
    for req in &requests {
        client.submit(req)?;
    }
    let mut responses = client.drain()?;
    let server = client.close()?;
    let wall = t0.elapsed().as_secs_f64();
    crate::coordinator::order_responses(&mut responses);
    Ok(summarize(
        &responses,
        &expected,
        &functional_ids,
        wall,
        0,
        server.metrics.clone(),
        server.shards.clone(),
    ))
}

/// [`run_client_mixed`] over K logical sessions multiplexed on ONE
/// connection (`gta client --sessions K`, protocol v3): requests
/// round-robin across the sessions, every session drains independently
/// (each drain is ordered within its session), the extra sessions close
/// with their own summaries, and the combined responses verify against
/// the same oracle as the single-session replay — the workload's
/// responses are identical however it is sliced across sessions.
pub fn run_client_mux(addr: &str, n: u64, sessions: u32) -> Result<ServeSummary> {
    run_client_mux_proto(addr, n, sessions, crate::net::PROTO_VERSION)
}

/// [`run_client_mux`] with an explicit protocol-version cap (opening a
/// second session fails cleanly below v3).
pub fn run_client_mux_proto(
    addr: &str,
    n: u64,
    sessions: u32,
    max_proto: u64,
) -> Result<ServeSummary> {
    run_client_mux_with(addr, n, sessions, ClientOptions { max_proto, ..ClientOptions::default() })
}

/// [`run_client_mux`] with full [`ClientOptions`] control.
pub fn run_client_mux_with(
    addr: &str,
    n: u64,
    sessions: u32,
    opts: ClientOptions,
) -> Result<ServeSummary> {
    let mut client = GtaClient::connect_with(addr, opts)?;
    // session 0 comes free with the connection; open the rest
    let mut sids = vec![0u32];
    for _ in 1..sessions.max(1) {
        sids.push(client.open_session()?);
    }
    let (requests, expected) = mixed_stream(n);
    let functional_ids = functional_ids(&requests);
    let t0 = Instant::now();
    for (i, req) in requests.iter().enumerate() {
        client.submit_on(sids[i % sids.len()], req)?;
    }
    let mut responses = Vec::new();
    for &sid in &sids {
        responses.append(&mut client.drain_on(sid)?);
    }
    // the opened sessions' summaries fold into the rack totals the
    // connection summary reports
    for &sid in sids.iter().skip(1) {
        let _ = client.close_session(sid)?;
    }
    let server = client.close()?;
    let wall = t0.elapsed().as_secs_f64();
    crate::coordinator::order_responses(&mut responses);
    Ok(summarize(
        &responses,
        &expected,
        &functional_ids,
        wall,
        0,
        server.metrics.clone(),
        server.shards.clone(),
    ))
}

/// The seeded open-loop Poisson driver over TCP (`gta client --connect
/// ADDR --stream --arrival-rate R --seed S`): the same seeded arrival
/// schedule, submit/consume interleaving and verification as
/// [`run_open_loop_stream`], with a [`GtaClient`] in place of the
/// in-process session — so one seed is bit-comparable in-process vs.
/// over the wire.
pub fn run_open_loop_client(addr: &str, n: u64, rate_rps: f64, seed: u64) -> Result<ServeSummary> {
    run_open_loop_client_proto(addr, n, rate_rps, seed, crate::net::PROTO_VERSION)
}

/// [`run_open_loop_client`] with an explicit protocol-version cap for
/// the client's `Hello`.
pub fn run_open_loop_client_proto(
    addr: &str,
    n: u64,
    rate_rps: f64,
    seed: u64,
    max_proto: u64,
) -> Result<ServeSummary> {
    let opts = ClientOptions { max_proto, ..ClientOptions::default() };
    run_open_loop_client_with(addr, n, rate_rps, seed, opts)
}

/// [`run_open_loop_client`] with full [`ClientOptions`] control.
pub fn run_open_loop_client_with(
    addr: &str,
    n: u64,
    rate_rps: f64,
    seed: u64,
    opts: ClientOptions,
) -> Result<ServeSummary> {
    let client = std::cell::RefCell::new(GtaClient::connect_with(addr, opts)?);
    let (requests, expected) = mixed_stream(n);
    let functional_ids = functional_ids(&requests);
    let t0 = Instant::now();
    // the RefCell lets the two single-threaded closures share the one
    // &mut client (they never run at once)
    let mut responses = open_loop_replay(
        requests,
        rate_rps,
        seed,
        t0,
        |req| client.borrow_mut().submit(&req).map(|_id| ()),
        || client.borrow_mut().try_recv(),
    )?;
    let mut client = client.into_inner();
    while let Some(r) = client.recv()? {
        responses.push(r);
    }
    responses.append(&mut client.drain()?);
    let server = client.close()?;
    crate::coordinator::order_responses(&mut responses);
    let wall = t0.elapsed().as_secs_f64();
    Ok(summarize(
        &responses,
        &expected,
        &functional_ids,
        wall,
        0,
        server.metrics.clone(),
        server.shards.clone(),
    ))
}

/// `gta serve --stream` against the PJRT engine: the open-loop arrival
/// driver over a rack whose every shard compiles the artifacts in
/// `artifact_dir`.
pub fn run_open_loop_rack(
    artifact_dir: PathBuf,
    n: u64,
    workers: usize,
    shards: usize,
    lanes: &[u32],
    policy: &str,
    rate_rps: f64,
    seed: u64,
) -> Result<ServeSummary> {
    let rack = Arc::new(Rack::with_backend(
        shard_configs(shards, lanes),
        move |_shard| Ok(Box::new(Engine::load(&artifact_dir)?) as Box<dyn ExecBackend>),
        CoalesceConfig::with_adaptive_window(),
        parse_policy(policy)?,
    )?);
    let (requests, expected) = mixed_stream(n);
    Ok(run_open_loop_stream(&rack, requests, &expected, workers, rate_rps, seed))
}
