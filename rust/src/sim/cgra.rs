//! CGRA baseline: a HyCube-style 4×4 word-level array (Table 1 column 4).
//!
//! CGRAs buy flexibility with word-level reconfigurability: each PE has a
//! full-width FU and datapath-oriented interconnect, so arrays stay small
//! (4×4) and — the paper's §7.4 point — exhibit weak acceleration and
//! data reuse: operands flow through the load/store PEs for every
//! iteration of the modulo-scheduled loop.

use super::{Platform, SimReport};
use crate::arch::energy;
use crate::ops::{PGemm, TensorOp, VectorOp};
use crate::precision::Precision;

/// HyCube configuration.
#[derive(Debug, Clone)]
pub struct CgraSim {
    pub rows: u32,
    pub cols: u32,
    pub freq_mhz: u32,
    /// PEs with memory (load/store) capability — HyCube ties them to the
    /// array edge.
    pub ls_ports: u32,
    /// Non-MAC ops in the GEMM inner-loop body (address gen, branch,
    /// accumulate move) that occupy PE slots in the modulo schedule.
    pub loop_overhead_ops: u32,
}

impl Default for CgraSim {
    fn default() -> Self {
        CgraSim { rows: 4, cols: 4, freq_mhz: 704, ls_ports: 4, loop_overhead_ops: 3 }
    }
}

impl CgraSim {
    fn pes(&self) -> u64 {
        (self.rows * self.cols) as u64
    }

    /// Parallel FU count for a precision: the word-level datapath gives
    /// the CGRA FULL-width units, so high precisions run at the same
    /// per-PE rate as low ones ("high-precision units such as FP64 have a
    /// larger number of settings and can be on par with GTA", §7.4) —
    /// but low precisions cannot subdivide a PE, wasting its width.
    fn macs_per_cycle(&self, _p: Precision) -> f64 {
        // modulo schedule: each iteration = 1 MAC + loop_overhead ops;
        // II*PEs slots per iteration set the steady-state rate
        let ops_per_iter = 1.0 + self.loop_overhead_ops as f64;
        self.pes() as f64 / ops_per_iter
    }

    fn run_gemm(&self, g: &PGemm) -> SimReport {
        let macs = g.macs();
        let compute_rate = self.macs_per_cycle(g.precision);
        // memory-port bound: one streamed word per MAC through ls_ports
        // (the stationary operand is held in a PE register across the
        // modulo-scheduled inner loop)
        let mem_rate = self.ls_ports as f64;
        let rate = compute_rate.min(mem_rate);
        let prologue = (self.rows + self.cols) as u64; // pipeline fill depth
        let cycles = (macs as f64 / rate).ceil() as u64 + prologue;

        let bytes = g.precision.bytes();
        // no array-level reuse: both operands re-fetched per MAC; C
        // accumulators held in PE registers per output, spilled per tile
        let sram_bytes = (2 * macs + g.m * g.n) * bytes;
        let dram_bytes = g.compulsory_bytes();
        SimReport {
            cycles,
            freq_mhz: self.freq_mhz,
            sram_bytes,
            dram_bytes,
            macs,
            utilization: rate / self.pes() as f64, // MAC-busy PEs only
            energy_pj: macs as f64 * energy::ara_mac_pj(g.precision) * 1.4 // 28nm penalty
                + sram_bytes as f64 * energy::SRAM_PJ_PER_BYTE
                + dram_bytes as f64 * energy::DRAM_PJ_PER_BYTE,
        }
    }

    fn run_vector(&self, v: &VectorOp) -> SimReport {
        let ops = v.ops();
        // element-wise loops map 1 op/PE/II with the same overhead;
        // two fresh operands per op through the load/store PEs
        let rate = self
            .macs_per_cycle(v.precision)
            .min(self.ls_ports as f64 / 2.0);
        let cycles = (ops as f64 / rate).ceil().max(1.0) as u64;
        let sram_bytes = v.bytes();
        SimReport {
            cycles,
            freq_mhz: self.freq_mhz,
            sram_bytes,
            dram_bytes: v.bytes(),
            macs: ops,
            utilization: rate / self.pes() as f64,
            energy_pj: ops as f64 * energy::ara_mac_pj(v.precision) * 1.4
                + sram_bytes as f64
                    * (energy::SRAM_PJ_PER_BYTE + energy::DRAM_PJ_PER_BYTE),
        }
    }
}

impl Platform for CgraSim {
    fn name(&self) -> &'static str {
        "CGRA-hycube"
    }

    fn run(&self, op: &TensorOp) -> SimReport {
        match op {
            TensorOp::PGemm(g) => self.run_gemm(g),
            TensorOp::Vector(v) => self.run_vector(v),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::gta::GtaSim;

    #[test]
    fn rate_is_precision_independent() {
        let c = CgraSim::default();
        assert_eq!(
            c.macs_per_cycle(Precision::Int8),
            c.macs_per_cycle(Precision::Fp64)
        );
    }

    #[test]
    fn memory_port_bound() {
        let c = CgraSim::default();
        // 16 PEs / 4 ops = 4 MACs/cycle compute == 4 ports streaming rate
        let g = TensorOp::gemm(64, 64, 64, Precision::Int32);
        let r = c.run(&g);
        assert!(r.cycles >= 64 * 64 * 64 / 4);
    }

    #[test]
    fn gta_advantage_shrinks_at_fp64() {
        // §7.4: FP64 "can be on par with GTA"; INT8 is a blowout
        let cgra = CgraSim::default();
        let gta = GtaSim::table1();
        let g8 = TensorOp::gemm(128, 128, 128, Precision::Int8);
        let g64 = TensorOp::gemm(128, 128, 128, Precision::Fp64);
        let sp8 = cgra.run(&g8).seconds() / gta.run(&g8).seconds();
        let sp64 = cgra.run(&g64).seconds() / gta.run(&g64).seconds();
        assert!(sp8 > 3.0 * sp64, "INT8 speedup {sp8:.1} vs FP64 {sp64:.1}");
        assert!(sp64 >= 0.8, "FP64 roughly on par, got {sp64:.2}");
    }

    #[test]
    fn no_reuse_traffic() {
        let c = CgraSim::default();
        let g = PGemm::new(64, 64, 64, Precision::Int8);
        let r = c.run(&TensorOp::PGemm(g));
        assert!(r.sram_bytes as f64 >= 2.0 * g.macs() as f64);
    }
}
