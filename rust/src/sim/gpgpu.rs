//! GPGPU baseline: an H100-class model (Table 1 column 3).
//!
//! Following the paper's §7.3 methodology, decomposed **p-GEMM operators
//! go to the tensor cores** (small-cube MMA units) and **vector operators
//! go to the CUDA cores**. "For precision that Tensor Core cannot support,
//! we use the closely higher precision."
//!
//! Comparison is **same-area** (§6.3: "configure different number of MPRA
//! to match the same area"): the H100 is modeled at full chip scale and
//! the GTA side of Fig. 8 is scaled UP to the H100's 14 nm-equivalent
//! area (see `report::fig8` / `area::gta_lanes_for_area`). The small MMA
//! cube means every MAC drags a fixed shared-memory operand quota — the
//! paper's "large numbers of memory operations and high on-chip memory
//! bandwidth" observation — and ragged workloads pay whole-cube cycles.

use super::{Platform, SimReport};
use crate::arch::area;
use crate::ops::{PGemm, TensorOp, VectorOp};
use crate::precision::Precision;

/// Whole-chip dense MAC rates per cycle (H100 SXM at 1755 MHz), derived
/// from the public TOPS/TFLOPS figures.
fn chip_macs_per_cycle(p: Precision) -> f64 {
    match p {
        // 1979 TOPS INT8 (TC)
        Precision::Int8 => 563_000.0,
        // INT16/INT32: promoted to the INT32 CUDA-core path
        Precision::Int16 | Precision::Int32 => 19_000.0,
        // INT64: CUDA-core 64-bit integer path (quarter INT32 rate)
        Precision::Int64 => 4_750.0,
        // 989 TFLOPS FP16/BF16 (TC)
        Precision::Bp16 | Precision::Fp16 => 281_000.0,
        // FP32 runs on the TF32 TC path (494 TFLOPS)
        Precision::Fp32 => 141_000.0,
        // FP64 TC: 67 TFLOPS
        Precision::Fp64 => 19_000.0,
    }
}

/// MMA cube shape the tensor core executes for `p` (m, n, k).
fn mma_cube(p: Precision) -> (u64, u64, u64) {
    match p {
        Precision::Int8 => (16, 8, 32),
        Precision::Bp16 | Precision::Fp16 => (16, 8, 16),
        Precision::Fp32 => (16, 8, 8), // TF32 cube
        Precision::Fp64 => (8, 8, 4),
        Precision::Int16 | Precision::Int32 | Precision::Int64 => (8, 8, 4),
    }
}

/// H100 model (full chip by default; `slice` scales it for ablations).
#[derive(Debug, Clone)]
pub struct GpgpuSim {
    pub freq_mhz: u32,
    /// Fraction of the whole chip's compute simulated (1.0 = full H100).
    pub slice: f64,
}

impl Default for GpgpuSim {
    fn default() -> Self {
        GpgpuSim { freq_mhz: 1755, slice: 1.0 }
    }
}

impl GpgpuSim {
    /// The number of GTA lanes occupying the same silicon area as this
    /// H100 model at 14 nm-equivalent density (the Fig. 8 normalization),
    /// rounded down to a power of two so the lane grid has usable
    /// arrangements (a GTA would be built with a power-of-two lane count).
    pub fn equal_area_gta_lanes() -> u32 {
        let raw = area::gta_lanes_for_area(814.0, 4);
        1 << (31 - raw.leading_zeros())
    }

    fn tc_macs_per_cycle(&self, p: Precision) -> f64 {
        (chip_macs_per_cycle(p) * self.slice).max(0.25)
    }

    fn run_gemm(&self, g: &PGemm) -> SimReport {
        let macs = g.macs();
        let rate = self.tc_macs_per_cycle(g.precision);
        // the TC executes whole cubes: ragged/small workloads pay for the
        // full (tm,tn,tk) volume — the cube-quantization penalty
        let (tm, tn, tk) = mma_cube(g.precision);
        let n_cubes = g.m.div_ceil(tm) * g.n.div_ceil(tn) * g.k.div_ceil(tk);
        let cube_macs = n_cubes * tm * tn * tk;
        // a runtime (cuBLAS-style heuristic) would send GEMMs that badly
        // under-fill the cube to the CUDA cores instead
        if (macs as f64) < 0.25 * cube_macs as f64 {
            return self.run_vector(&VectorOp::new(
                macs.max(1),
                g.precision,
                crate::ops::VectorKind::Axpy,
            ));
        }
        let cycles = (cube_macs as f64 / rate).ceil().max(1.0) as u64;
        let per_cube = tm * tk + tk * tn; // operand elements per MMA
        let bytes = g.precision.bytes();
        let sram_bytes = (n_cubes * per_cube + g.m * g.n) * bytes;
        let dram_bytes = g.compulsory_bytes();
        SimReport {
            cycles,
            freq_mhz: self.freq_mhz,
            sram_bytes,
            dram_bytes,
            macs,
            // the cube quantizes the workload: ragged edges idle the TC
            utilization: macs as f64 / (n_cubes * tm * tn * tk) as f64,
            energy_pj: macs as f64 * 0.4 // 4nm MAC, fused datapath
                + sram_bytes as f64 * crate::arch::energy::SRAM_PJ_PER_BYTE
                + dram_bytes as f64 * crate::arch::energy::DRAM_PJ_PER_BYTE,
        }
    }

    fn run_vector(&self, v: &VectorOp) -> SimReport {
        // CUDA cores: FP32-class lanes; the slice's share of 132 SMs × 128
        // lanes, at most the INT32 rate for integer work
        let cuda_rate = (19_000.0 * self.slice).max(0.25);
        let ops = v.ops();
        let cycles = (ops as f64 / cuda_rate).ceil().max(1.0) as u64;
        let sram_bytes = v.bytes();
        SimReport {
            cycles,
            freq_mhz: self.freq_mhz,
            sram_bytes,
            dram_bytes: v.bytes(),
            macs: ops,
            utilization: 1.0,
            energy_pj: ops as f64 * 0.4
                + sram_bytes as f64
                    * (crate::arch::energy::SRAM_PJ_PER_BYTE
                        + crate::arch::energy::DRAM_PJ_PER_BYTE),
        }
    }
}

impl Platform for GpgpuSim {
    fn name(&self) -> &'static str {
        "GPGPU-H100"
    }

    fn run(&self, op: &TensorOp) -> SimReport {
        match op {
            TensorOp::PGemm(g) => self.run_gemm(g),
            TensorOp::Vector(v) => self.run_vector(v),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::gta::GtaSim;

    #[test]
    fn equal_area_normalization_is_large() {
        // H100's 814mm² @4nm is worth tens of thousands of 14nm GTA lanes
        let lanes = GpgpuSim::equal_area_gta_lanes();
        assert!((20_000..80_000).contains(&lanes), "got {lanes}");
    }

    #[test]
    fn tc_precisions_fast_promoted_slow() {
        let s = GpgpuSim::default();
        assert!(s.tc_macs_per_cycle(Precision::Int8) > s.tc_macs_per_cycle(Precision::Fp16));
        assert!(s.tc_macs_per_cycle(Precision::Fp16) > s.tc_macs_per_cycle(Precision::Int16));
    }

    #[test]
    fn small_cube_costs_memory() {
        // per-MAC shared memory quota must exceed the systolic compulsory
        // fraction for a big GEMM — the paper's §7.3 memory argument
        let s = GpgpuSim::default();
        let g = PGemm::new(512, 512, 512, Precision::Bp16);
        let r = s.run(&TensorOp::PGemm(g));
        assert!(r.sram_bytes > g.compulsory_bytes() * 4);
    }

    #[test]
    fn gta_saves_memory_vs_gpgpu_on_bp16_gemm() {
        // equal-area comparison, as in Fig. 8
        let gpu = GpgpuSim::default();
        let gta = GtaSim::new(crate::GtaConfig::with_lanes(1024));
        let g = TensorOp::gemm(512, 512, 2048, Precision::Bp16);
        assert!(gpu.run(&g).memory_access() > gta.run(&g).memory_access());
    }

    #[test]
    fn ragged_workload_underutilizes_cube() {
        let s = GpgpuSim::default();
        // ragged but big enough to stay on the TC (no CUDA fallback)
        let r = s.run(&TensorOp::gemm(24, 12, 40, Precision::Fp16));
        assert!(r.utilization < 0.8, "util {}", r.utilization);
    }

    #[test]
    fn tiny_gemm_falls_back_to_cuda_cores() {
        let s = GpgpuSim::default();
        // M=K=3: the cube would be ~1% utilized -> heuristic reroutes
        let r = s.run(&TensorOp::gemm(3, 4096, 3, Precision::Int8));
        assert!((r.utilization - 1.0).abs() < 1e-9);
    }
}
