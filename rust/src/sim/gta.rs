//! Whole-GTA simulator: p-GEMM operators go through the §5 scheduler onto
//! the MPRA systolic model; vector operators run in the VPU-native SIMD
//! mode at the Table 3 MPRA throughput.

use super::{Platform, SimReport};
use crate::arch::{Dataflow, GtaConfig};
use crate::arch::energy;
use crate::ops::{PGemm, TensorOp, VectorOp};
use crate::scheduler::{self, cache::Memo, explorer};
use crate::sim::mpra;

/// The GTA platform model.
#[derive(Debug)]
pub struct GtaSim {
    pub config: GtaConfig,
    /// Memoized §5 exploration: workloads repeat layer shapes, so the
    /// schedule search runs once per distinct p-GEMM (§Perf L3); the
    /// compute-once memo also dedups the concurrent `run_all` pre-pass.
    cache: Memo<PGemm, SimReport>,
}

impl Clone for GtaSim {
    fn clone(&self) -> Self {
        GtaSim::new(self.config)
    }
}

impl GtaSim {
    pub fn new(config: GtaConfig) -> Self {
        GtaSim { config, cache: Default::default() }
    }

    /// Table 1 configuration (4 lanes, 1 GHz).
    pub fn table1() -> Self {
        GtaSim::new(GtaConfig::default())
    }

    /// Vector-mode execution at MPRA SIMD throughput.
    fn run_vector(&self, v: &VectorOp) -> SimReport {
        let per_lane = mpra::simd_mults_per_cycle(v.precision);
        let throughput = (per_lane * self.config.lanes as f64).max(1.0);
        let ops = v.ops();
        let cycles = (ops as f64 / throughput).ceil().max(1.0) as u64;
        let sram_bytes = v.bytes();
        let dram_bytes = v.bytes();
        SimReport {
            cycles,
            freq_mhz: self.config.freq_mhz,
            sram_bytes,
            dram_bytes,
            macs: ops,
            utilization: 1.0, // element-wise work saturates the partitions
            energy_pj: energy::total_energy_pj(
                ops,
                v.precision,
                Dataflow::Simd,
                sram_bytes,
                dram_bytes,
            ),
        }
    }
}

impl Platform for GtaSim {
    fn name(&self) -> &'static str {
        "GTA"
    }

    fn run(&self, op: &TensorOp) -> SimReport {
        match op {
            TensorOp::Vector(v) => self.run_vector(v),
            // degenerate / reuse-free p-GEMMs fall back to SIMD inside
            // the scheduler's space (it contains the SIMD point)
            TensorOp::PGemm(g) => {
                self.cache
                    .get_or_compute(*g, || scheduler::schedule(g, &self.config).report)
                    .0
            }
        }
    }

    fn run_all(&self, ops: &[TensorOp]) -> SimReport {
        // Schedule the distinct p-GEMMs concurrently before the (cheap)
        // sequential accumulation — the Table 2 suite and the fig7/8/10
        // comparisons spend nearly all their time in this search.
        let mut seen = std::collections::HashSet::new();
        let distinct: Vec<TensorOp> = ops
            .iter()
            .filter(|o| matches!(o, TensorOp::PGemm(_)) && seen.insert(**o))
            .copied()
            .collect();
        if distinct.len() > 1 {
            explorer::parallel_map(&distinct, explorer::default_workers(), |op| self.run(op));
        }
        let reports: Vec<SimReport> = ops.iter().map(|op| self.run(op)).collect();
        SimReport::sum(reports.iter())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ops::VectorKind;
    use crate::precision::Precision;

    #[test]
    fn vector_throughput_follows_table3() {
        let sim = GtaSim::table1();
        let v8 = TensorOp::vector(4096, Precision::Int8, VectorKind::Map);
        let v64 = TensorOp::vector(4096, Precision::Int64, VectorKind::Map);
        let r8 = sim.run(&v8);
        let r64 = sim.run(&v64);
        // INT8 64/lane/cycle vs INT64 1/lane/cycle: 64x cycle gap
        assert_eq!(r64.cycles, r8.cycles * 64);
    }

    #[test]
    fn gemm_goes_through_scheduler() {
        let sim = GtaSim::table1();
        let g = TensorOp::gemm(128, 128, 128, Precision::Int8);
        let r = sim.run(&g);
        assert!(r.cycles > 0);
        assert_eq!(r.macs, 128 * 128 * 128);
        assert!(r.utilization > 0.2, "large GEMM should use the array well");
    }

    #[test]
    fn more_lanes_cut_cycles() {
        let small = GtaSim::new(GtaConfig::with_lanes(4));
        let big = GtaSim::new(GtaConfig::with_lanes(16));
        let g = TensorOp::gemm(256, 256, 256, Precision::Bp16);
        assert!(big.run(&g).cycles < small.run(&g).cycles);
    }

    #[test]
    fn workload_reports_accumulate() {
        let sim = GtaSim::table1();
        let ops = vec![
            TensorOp::gemm(64, 64, 64, Precision::Int8),
            TensorOp::vector(1024, Precision::Int8, VectorKind::Activation),
        ];
        let total = sim.run_all(&ops);
        let parts: u64 = ops.iter().map(|o| sim.run(o).cycles).sum();
        assert_eq!(total.cycles, parts);
    }
}
