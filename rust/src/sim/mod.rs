//! Platform simulators (§6.3): GTA and the three baselines.
//!
//! All simulators report the two metrics the paper compares — **computing
//! cycles** and **memory access** — plus energy and utilization. They are
//! analytic cycle models in the scale-sim tradition (the same methodology
//! the paper builds its own simulators on), counting fills, streams,
//! drains and per-operand traffic rather than simulating RTL.

pub mod cgra;
pub mod gpgpu;
pub mod gta;
pub mod mpra;
pub mod systolic;
pub mod trace;
pub mod vpu;

use crate::ops::TensorOp;

/// Result of simulating one operator (or a whole workload) on a platform.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct SimReport {
    /// Compute cycles at the platform's own clock.
    pub cycles: u64,
    /// Platform clock in MHz (to convert cycles to wall time).
    pub freq_mhz: u32,
    /// Bytes moved to/from the on-chip operand memory (SRAM / shared mem /
    /// VRF fill traffic). This is the paper's "memory access" metric.
    pub sram_bytes: u64,
    /// Bytes moved to/from off-chip (or next-level) memory.
    pub dram_bytes: u64,
    /// Multiply-accumulates executed, at workload precision.
    pub macs: u64,
    /// Average fraction of compute resources busy (0..=1).
    pub utilization: f64,
    /// Total energy in pJ (compute + memory).
    pub energy_pj: f64,
}

impl SimReport {
    /// Wall-clock seconds.
    pub fn seconds(&self) -> f64 {
        self.cycles as f64 / (self.freq_mhz as f64 * 1e6)
    }

    /// The paper's memory-access index: total bytes through the memory
    /// hierarchy (SRAM + DRAM weighted equally, as access counts).
    pub fn memory_access(&self) -> u64 {
        self.sram_bytes + self.dram_bytes
    }

    /// Accumulate another report (sequential composition of operators).
    pub fn add(&mut self, other: &SimReport) {
        debug_assert!(
            self.freq_mhz == 0 || other.freq_mhz == 0 || self.freq_mhz == other.freq_mhz,
            "cannot add reports across clock domains"
        );
        let total_cycles = self.cycles + other.cycles;
        // cycle-weighted utilization
        self.utilization = if total_cycles > 0 {
            (self.utilization * self.cycles as f64 + other.utilization * other.cycles as f64)
                / total_cycles as f64
        } else {
            0.0
        };
        self.cycles = total_cycles;
        self.freq_mhz = self.freq_mhz.max(other.freq_mhz);
        self.sram_bytes += other.sram_bytes;
        self.dram_bytes += other.dram_bytes;
        self.macs += other.macs;
        self.energy_pj += other.energy_pj;
    }

    /// Sum a sequence of reports.
    pub fn sum<'a>(reports: impl IntoIterator<Item = &'a SimReport>) -> SimReport {
        let mut acc = SimReport::default();
        for r in reports {
            acc.add(r);
        }
        acc
    }
}

/// A platform that can execute (simulate) a decomposed tensor operator.
pub trait Platform {
    fn name(&self) -> &'static str;
    /// Simulate one operator.
    fn run(&self, op: &TensorOp) -> SimReport;
    /// Simulate a workload (operator sequence).
    fn run_all(&self, ops: &[TensorOp]) -> SimReport {
        let reports: Vec<SimReport> = ops.iter().map(|op| self.run(op)).collect();
        SimReport::sum(reports.iter())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn report_add_accumulates() {
        let mut a = SimReport {
            cycles: 100,
            freq_mhz: 1000,
            sram_bytes: 10,
            dram_bytes: 1,
            macs: 50,
            utilization: 1.0,
            energy_pj: 5.0,
        };
        let b = SimReport {
            cycles: 300,
            freq_mhz: 1000,
            utilization: 0.5,
            ..a
        };
        a.add(&b);
        assert_eq!(a.cycles, 400);
        assert_eq!(a.sram_bytes, 20);
        // cycle-weighted utilization: (1.0*100 + 0.5*300)/400
        assert!((a.utilization - 0.625).abs() < 1e-12);
    }

    #[test]
    fn seconds_uses_frequency() {
        let r = SimReport { cycles: 1_000_000, freq_mhz: 1000, ..Default::default() };
        assert!((r.seconds() - 1e-3).abs() < 1e-12);
    }
}
