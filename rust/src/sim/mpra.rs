//! MPRA precision mapping (§3.1, §4.1): how an `n`-limb precision expands
//! a workload GEMM onto the 8-bit PE grid, and the SIMD-mode throughput
//! model that *derives* Table 3.
//!
//! Mapping rules (Fig. 1):
//! * **WS**: the stationary operand's limbs occupy `n` consecutive column
//!   positions (spatial cols ×n); the streaming operand's limbs pass
//!   temporally (temporal ×n). Rows (contraction) unchanged — "it only
//!   affects the row direction" of the workload footprint.
//! * **IS**: dual of WS.
//! * **OS**: both operands are mapped, so BOTH spatial dims expand ×n;
//!   the temporal (contraction) depth is unchanged.
//! * **SIMD**: the 64-PE MPRA performs `64/n²` independent word-multiplies
//!   per cycle (each needs an `n×n` limb-product grid), vs the original
//!   Ara lane's `8/⌈bits/8⌉` packed-SIMD ops — the Table 3 gain.

use crate::arch::Dataflow;
use crate::ops::PGemm;
use crate::precision::Precision;
use crate::sim::systolic::MappedGemm;

/// Expand a workload GEMM into array coordinates under `flow` at its
/// precision (limb factor `n`).
pub fn map_gemm(g: &PGemm, flow: Dataflow) -> MappedGemm {
    let n = g.precision.limbs() as u64;
    match flow {
        Dataflow::WS => MappedGemm {
            rows: g.k,
            cols: g.n * n,
            temporal: g.m * n,
        },
        Dataflow::IS => MappedGemm {
            rows: g.k,
            cols: g.m * n,
            temporal: g.n * n,
        },
        Dataflow::OS => MappedGemm {
            rows: g.m * n,
            cols: g.n * n,
            temporal: g.k,
        },
        Dataflow::Simd => panic!("SIMD mapping is not spatial"),
    }
}

/// Limb-level MACs the PEs perform for this GEMM (each word MAC costs n²).
pub fn limb_macs(g: &PGemm) -> u64 {
    let n = g.precision.limbs() as u64;
    g.macs() * n * n
}

/// Word-multiplies per cycle of ONE 8×8 MPRA in SIMD mode.
///
/// Integer paths partition the array into ⌊64/n²⌋ independent groups;
/// FP mantissa paths yield the fractional 64/n² average the paper reports
/// (Table 3: FP32 → 64/9 ≈ 7.11 mults/cycle).
pub fn simd_mults_per_cycle(p: Precision) -> f64 {
    let n = p.limbs() as f64;
    64.0 / (n * n)
}

/// Word-multiplies per cycle of one ORIGINAL Ara lane (64-bit packed SIMD
/// datapath: 8/⌈bits/8⌉ elements per cycle).
pub fn ara_mults_per_cycle(p: Precision) -> f64 {
    8.0 / (p.bits() as f64 / 8.0)
}

/// Table 3: SIMD throughput gain of an MPRA lane over an Ara lane.
pub fn simd_gain(p: Precision) -> f64 {
    simd_mults_per_cycle(p) / ara_mults_per_cycle(p)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table3_simd_gains_exact() {
        // The paper's Table 3, derived — not hardcoded.
        let cases = [
            (Precision::Int8, 8.0),
            (Precision::Int16, 4.0),
            (Precision::Int32, 2.0),
            (Precision::Int64, 1.0),
            (Precision::Bp16, 16.0),
            (Precision::Fp16, 4.0),
            (Precision::Fp32, 3.56),
            (Precision::Fp64, 1.3),
        ];
        for (p, want) in cases {
            let got = simd_gain(p);
            assert!(
                (got - want).abs() / want < 0.01,
                "{}: got {got:.3}, paper says {want}",
                p.name()
            );
        }
    }

    #[test]
    fn ws_expands_cols_and_temporal() {
        let g = PGemm::new(16, 8, 32, Precision::Int32); // n=4
        let m = map_gemm(&g, Dataflow::WS);
        assert_eq!(m.rows, 32); // K unchanged
        assert_eq!(m.cols, 8 * 4); // N × limbs
        assert_eq!(m.temporal, 16 * 4); // M × limbs
    }

    #[test]
    fn os_expands_both_spatial_dims() {
        let g = PGemm::new(16, 8, 32, Precision::Fp32); // n=3
        let m = map_gemm(&g, Dataflow::OS);
        assert_eq!(m.rows, 48);
        assert_eq!(m.cols, 24);
        assert_eq!(m.temporal, 32);
    }

    #[test]
    fn int8_maps_identity() {
        let g = PGemm::new(4, 5, 6, Precision::Int8);
        let m = map_gemm(&g, Dataflow::WS);
        assert_eq!((m.rows, m.cols, m.temporal), (6, 5, 4));
    }

    #[test]
    fn limb_macs_quadratic_in_limbs() {
        let g8 = PGemm::new(4, 4, 4, Precision::Int8);
        let g32 = PGemm::new(4, 4, 4, Precision::Int32);
        assert_eq!(limb_macs(&g8), 64);
        assert_eq!(limb_macs(&g32), 64 * 16);
    }
}
