//! Scale-sim-style analytic model of a systolic array executing a GEMM
//! under the three dataflows (§3.1, §5). This is the timing/traffic core
//! both the GTA simulator and the scheduler cost model are built on.
//!
//! Orientation convention (rows × cols of the PE grid):
//! * **WS**: rows ← K (contraction), cols ← N; M streams temporally.
//! * **IS**: rows ← K, cols ← M; N streams temporally.
//! * **OS**: rows ← M, cols ← N; K streams temporally.
//!
//! Traffic is counted in *elements* at the interface of the array's
//! operand SRAM (the caller converts to bytes at workload precision), in
//! the style of scale-sim's counted read/write traces.

use crate::arch::Dataflow;

/// A GEMM already *mapped* to array coordinates (after any precision
/// expansion — see [`crate::sim::mpra`]): spatial dims include limb
/// multiplication, temporal dim likewise.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct MappedGemm {
    /// Elements along the array-row spatial dimension.
    pub rows: u64,
    /// Elements along the array-column spatial dimension.
    pub cols: u64,
    /// Temporal (streamed) extent.
    pub temporal: u64,
}

/// Timing + traffic of one GEMM on an `r × c` array.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct SystolicRun {
    pub cycles: u64,
    /// Element reads of the streamed operand(s) + stationary fills.
    pub sram_read_elems: u64,
    /// Element writes of results (incl. partial-sum spill traffic).
    pub sram_write_elems: u64,
    /// Average PE utilization over the run.
    pub utilization: f64,
    /// Number of (row, col) fold iterations executed.
    pub folds: u64,
}

/// Simulate `gemm` (already in array coordinates) on an `r × c` array
/// under `flow`. `m`, `n`, `k` are the ORIGINAL workload dims (for traffic
/// accounting of A/B/C at word granularity); `gemm` carries the mapped
/// (possibly limb-expanded) extents.
pub fn run(
    flow: Dataflow,
    r: u64,
    c: u64,
    gemm: MappedGemm,
    m: u64,
    n: u64,
    k: u64,
) -> SystolicRun {
    assert!(r > 0 && c > 0);
    match flow {
        Dataflow::WS | Dataflow::IS => run_stationary(r, c, gemm, m, n, k, flow),
        Dataflow::OS => run_os(r, c, gemm, m, n, k),
        Dataflow::Simd => panic!("SIMD mode is not a systolic dataflow"),
    }
}

/// WS / IS: one operand resident, the other streams past it.
fn run_stationary(
    r: u64,
    c: u64,
    g: MappedGemm,
    m: u64,
    n: u64,
    k: u64,
    flow: Dataflow,
) -> SystolicRun {
    // Double-buffered folds: while fold (i,j) streams its `temporal`
    // values, the next fold's stationary panel loads into the shadow
    // registers and the skew drain overlaps the next fill. Only the first
    // fill and last drain are exposed. Closed form, O(1) (§Perf L3).
    let fr = g.rows.div_ceil(r);
    let fc = g.cols.div_ceil(c);
    let fill = g.rows.min(r);
    let drain = g.rows.min(r) + g.cols.min(c) - 1;
    let cycles = fr * fc * g.temporal + fill + drain;
    let busy_pe_cycles = g.rows * g.cols * g.temporal;
    let utilization = busy_pe_cycles as f64 / (cycles.max(1) as f64 * (r * c) as f64);

    // ---- traffic at word granularity (original dims) ----
    // stationary operand loaded exactly once; streamed operand re-read per
    // fold of the stationary operand's non-shared spatial dim; outputs
    // accumulate partial sums across contraction folds.
    let fk = k_folds(flow, g, r);
    let (stationary_elems, streamed_elems, out_elems) = match flow {
        // WS: B (k×n) resident; A (m×k) streams once per N-fold; C = m×n
        Dataflow::WS => (k * n, m * k * fc, m * n),
        // IS: A (m×k) resident; B (k×n) streams once per M-fold; C = m×n
        Dataflow::IS => (m * k, k * n * fc, m * n),
        _ => unreachable!(),
    };
    // partial sums cross the array boundary once per extra contraction fold
    let psum_traffic = out_elems * (fk.saturating_sub(1));
    SystolicRun {
        cycles,
        sram_read_elems: stationary_elems + streamed_elems + psum_traffic,
        sram_write_elems: out_elems + psum_traffic,
        utilization,
        folds: fr * fc,
    }
}

/// OS: the C tile is resident; A and B stream K-deep into the array.
fn run_os(r: u64, c: u64, g: MappedGemm, m: u64, n: u64, k: u64) -> SystolicRun {
    // Double-buffered OS folds: the K-deep stream of the next C-tile
    // follows the current one back-to-back; the output drain overlaps the
    // next fill (scale-sim's 2r+c+T−2 with the skews amortized across
    // folds). Closed form as in run_stationary.
    let fr = g.rows.div_ceil(r);
    let fc = g.cols.div_ceil(c);
    let fill = g.rows.min(r);
    let drain = g.rows.min(r) + g.cols.min(c) - 1;
    let cycles = fr * fc * g.temporal + fill + drain;
    let busy_pe_cycles = g.rows * g.cols * g.temporal;
    let utilization = busy_pe_cycles as f64 / (cycles.max(1) as f64 * (r * c) as f64);
    // A re-read per column fold, B re-read per row fold, C written once
    // (partial sums never leave the array — the OS advantage).
    SystolicRun {
        cycles,
        sram_read_elems: m * k * fc + k * n * fr,
        sram_write_elems: m * n,
        utilization,
        folds: fr * fc,
    }
}

/// Contraction folds: how many times partial sums must leave the array.
fn k_folds(flow: Dataflow, g: MappedGemm, r: u64) -> u64 {
    match flow {
        // WS/IS: contraction is the ROW spatial dim; each row-fold produces
        // partial sums that are re-injected
        Dataflow::WS | Dataflow::IS => g.rows.div_ceil(r),
        // OS: contraction is temporal; partial sums stay put
        Dataflow::OS => 1,
        Dataflow::Simd => 1,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn g(rows: u64, cols: u64, t: u64) -> MappedGemm {
        MappedGemm { rows, cols, temporal: t }
    }

    #[test]
    fn perfectly_mapped_ws_single_fold() {
        // 8×8 array, K=8,N=8,M=16 : one fold
        let run = run(Dataflow::WS, 8, 8, g(8, 8, 16), 16, 8, 8);
        assert_eq!(run.folds, 1);
        // stream 16 + fill 8 + drain 8+8-1 = 39
        assert_eq!(run.cycles, 39);
        // B once (64), A once (16*8=128), C 128 writes, no psum traffic
        assert_eq!(run.sram_read_elems, 64 + 128);
        assert_eq!(run.sram_write_elems, 128);
    }

    #[test]
    fn os_partial_sums_stay_on_array() {
        let ws = run(Dataflow::WS, 8, 8, g(64, 8, 16), 16, 8, 64, );
        let os = run(Dataflow::OS, 8, 8, g(16, 8, 64), 16, 8, 64);
        // WS folds K=64 over 8 rows: 8 folds -> psum traffic; OS has none
        assert!(ws.sram_write_elems > os.sram_write_elems);
    }

    #[test]
    fn utilization_bounded_and_degrades_with_bad_fit() {
        let good = run(Dataflow::OS, 8, 8, g(8, 8, 64), 8, 8, 64);
        let bad = run(Dataflow::OS, 8, 8, g(9, 9, 64), 9, 9, 64);
        assert!(good.utilization <= 1.0 && good.utilization > 0.5);
        assert!(bad.utilization < good.utilization, "ragged folds waste PEs");
    }

    #[test]
    fn cycles_scale_linearly_in_temporal_extent() {
        let a = run(Dataflow::WS, 8, 8, g(8, 8, 100), 100, 8, 8).cycles;
        let b = run(Dataflow::WS, 8, 8, g(8, 8, 200), 200, 8, 8).cycles;
        assert!(b > a && b < 2 * a + 30);
    }

    #[test]
    fn streamed_operand_rereads_per_fold() {
        // N=16 on 8 cols -> 2 column folds -> A read twice under WS
        let run2 = run(Dataflow::WS, 8, 8, g(8, 16, 4), 4, 16, 8);
        assert_eq!(run2.folds, 2);
        assert_eq!(run2.sram_read_elems, 8 * 16 + 4 * 8 * 2);
    }

    #[test]
    fn is_mirrors_ws() {
        // IS with (M,N) swapped should match WS traffic symmetrically
        let ws = run(Dataflow::WS, 8, 8, g(8, 8, 32), 32, 8, 8);
        let is = run(Dataflow::IS, 8, 8, g(8, 8, 32), 8, 32, 8);
        assert_eq!(ws.cycles, is.cycles);
    }
}
