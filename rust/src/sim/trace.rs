//! Event-level systolic simulation: a literal cycle-by-cycle PE-grid
//! simulator for single-tile workloads. This is the repo's analogue of
//! the paper's "we verify the GTA's simulator against our verilog
//! implementation": the analytic model in [`super::systolic`] is checked
//! against these per-cycle events for both *numerics* (the dataflow must
//! compute the exact GEMM) and *timing* (cycle counts must agree up to
//! the fill/drain conventions).
//!
//! Only small tiles are simulated (O(R·C·cycles) work) — this is a
//! validation oracle, not the production model.

use crate::arch::Dataflow;

/// Result of an event-level run.
#[derive(Debug, Clone)]
pub struct TraceRun {
    /// Cycle at which the last output element left the array.
    pub cycles: u64,
    /// The computed C matrix (row-major M×N).
    pub output: Vec<i64>,
    /// Per-cycle count of PEs that performed a MAC (the occupancy trace).
    pub occupancy: Vec<u32>,
}

impl TraceRun {
    /// Total MACs executed (from the occupancy trace).
    pub fn macs(&self) -> u64 {
        self.occupancy.iter().map(|&x| x as u64).sum()
    }

    /// Average utilization over the run against an `r × c` array.
    pub fn utilization(&self, r: u64, c: u64) -> f64 {
        self.macs() as f64 / (self.cycles.max(1) * r * c) as f64
    }
}

/// Event-level **Output-Stationary** run: `C[M,N] = A[M,K]·B[K,N]` on an
/// `r × c` grid with `M ≤ r`, `N ≤ c`. A enters from the left with row
/// skew, B from the top with column skew; each PE accumulates its C
/// element in place and forwards operands right/down.
pub fn run_os(a: &[i64], b: &[i64], m: usize, k: usize, n: usize, r: usize, c: usize) -> TraceRun {
    assert!(m <= r && n <= c, "single-tile oracle: workload must fit");
    assert_eq!(a.len(), m * k);
    assert_eq!(b.len(), k * n);
    // a_wave[i][t]: operand entering row i at cycle t is a[i][t - i]
    // b_wave[j][t]: operand entering col j at cycle t is b[t - j][j]
    let mut acc = vec![0i64; r * c];
    // horizontal/vertical operand registers between PEs
    let mut h = vec![None::<i64>; r * c]; // value held at PE, moving right
    let mut v = vec![None::<i64>; r * c]; // value held at PE, moving down
    let mut occupancy = Vec::new();
    let total_cycles = (m - 1) + (n - 1) + k + 1; // skew + stream depth
    for t in 0..total_cycles {
        // shift right/down (back to front), then inject at the edges
        let mut nh = vec![None; r * c];
        let mut nv = vec![None; r * c];
        for i in 0..r {
            for j in (0..c).rev() {
                if j > 0 {
                    nh[i * c + j] = h[i * c + j - 1];
                }
            }
        }
        for i in (0..r).rev() {
            for j in 0..c {
                if i > 0 {
                    nv[i * c + j] = v[(i - 1) * c + j];
                }
            }
        }
        // edge injection with systolic skew
        for (i, slot) in nh.iter_mut().step_by(c).take(m).enumerate() {
            if t >= i && t - i < k {
                *slot = Some(a[i * k + (t - i)]);
            }
        }
        for (j, slot) in nv.iter_mut().take(n).enumerate() {
            if t >= j && t - j < k {
                *slot = Some(b[(t - j) * n + j]);
            }
        }
        // MAC wherever both operands are present
        let mut busy = 0u32;
        for i in 0..r {
            for j in 0..c {
                if let (Some(x), Some(y)) = (nh[i * c + j], nv[i * c + j]) {
                    acc[i * c + j] += x * y;
                    busy += 1;
                }
            }
        }
        occupancy.push(busy);
        h = nh;
        v = nv;
    }
    let mut output = vec![0i64; m * n];
    for i in 0..m {
        for j in 0..n {
            output[i * n + j] = acc[i * c + j];
        }
    }
    TraceRun { cycles: total_cycles as u64, output, occupancy }
}

/// Event-level **Weight-Stationary** run: B[K,N] preloaded onto the grid
/// (`K ≤ r`, `N ≤ c`), A streams row-skewed from the left while partial
/// sums cascade down the columns and drain from the bottom row.
pub fn run_ws(a: &[i64], b: &[i64], m: usize, k: usize, n: usize, r: usize, c: usize) -> TraceRun {
    assert!(k <= r && n <= c, "single-tile oracle: weights must fit");
    assert_eq!(a.len(), m * k);
    assert_eq!(b.len(), k * n);
    let fill = k; // weight preload, one row per cycle
    // psum[i][j] pipeline registers between rows; a values skewed so that
    // row kk sees a[i][kk] exactly when the psum for output row i arrives
    let mut psum = vec![0i64; r * c];
    let mut output = vec![0i64; m * n];
    let mut occupancy = vec![0u32; fill];
    // stream cycles: output row i's contribution enters row 0 at t=i,
    // reaches row kk at t=i+kk, exits the bottom (row k-1) at t=i+k-1;
    // the column skew adds j cycles before the value is architecturally
    // final — modeled in the drain term.
    let stream = (m - 1) + (k - 1) + 1;
    for t in 0..stream {
        let mut busy = 0u32;
        // process rows bottom-up so psums shift one row per cycle
        for kk in (0..k).rev() {
            // which output row's wave is at PE row kk this cycle?
            if t >= kk {
                let i = t - kk;
                if i < m {
                    let a_val = a[i * k + kk];
                    for j in 0..n {
                        let incoming = if kk == 0 { 0 } else { psum[(kk - 1) * c + j] };
                        let val = incoming + a_val * b[kk * n + j];
                        psum[kk * c + j] = val;
                        if kk == k - 1 {
                            output[i * n + j] = val;
                        }
                        busy += 1;
                    }
                }
            }
        }
        occupancy.push(busy);
    }
    let drain = (n as u64).max(1) - 1 + 1; // column skew on the way out
    TraceRun {
        cycles: fill as u64 + stream as u64 + drain,
        output,
        occupancy,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::systolic::{self, MappedGemm};
    use crate::util::rng::{property, Rng};

    fn naive(a: &[i64], b: &[i64], m: usize, k: usize, n: usize) -> Vec<i64> {
        let mut c = vec![0i64; m * n];
        for i in 0..m {
            for kk in 0..k {
                for j in 0..n {
                    c[i * n + j] += a[i * k + kk] * b[kk * n + j];
                }
            }
        }
        c
    }

    fn rand_mat(rng: &mut Rng, len: usize) -> Vec<i64> {
        (0..len).map(|_| rng.range_i64(-50, 50)).collect()
    }

    #[test]
    fn os_dataflow_computes_exact_gemm() {
        property("event OS == naive GEMM", 60, |rng: &mut Rng| {
            let (m, k, n) = (
                rng.range_u64(1, 8) as usize,
                rng.range_u64(1, 12) as usize,
                rng.range_u64(1, 8) as usize,
            );
            let a = rand_mat(rng, m * k);
            let b = rand_mat(rng, k * n);
            let run = run_os(&a, &b, m, k, n, 8, 8);
            assert_eq!(run.output, naive(&a, &b, m, k, n));
        });
    }

    #[test]
    fn ws_dataflow_computes_exact_gemm() {
        property("event WS == naive GEMM", 60, |rng: &mut Rng| {
            let (m, k, n) = (
                rng.range_u64(1, 12) as usize,
                rng.range_u64(1, 8) as usize,
                rng.range_u64(1, 8) as usize,
            );
            let a = rand_mat(rng, m * k);
            let b = rand_mat(rng, k * n);
            let run = run_ws(&a, &b, m, k, n, 8, 8);
            assert_eq!(run.output, naive(&a, &b, m, k, n));
        });
    }

    #[test]
    fn event_macs_match_workload() {
        // every MAC the grid performs is accounted in the occupancy trace
        let mut rng = Rng::new(3);
        let (m, k, n) = (4usize, 6usize, 5usize);
        let a = rand_mat(&mut rng, m * k);
        let b = rand_mat(&mut rng, k * n);
        assert_eq!(run_os(&a, &b, m, k, n, 8, 8).macs(), (m * k * n) as u64);
        assert_eq!(run_ws(&a, &b, m, k, n, 8, 8).macs(), (m * k * n) as u64);
    }

    #[test]
    fn analytic_model_matches_event_sim_timing() {
        // the closed-form single-tile cycle count must track the event
        // simulator within the fill/drain convention (±(r+c) slack)
        property("analytic ≈ event cycles", 40, |rng: &mut Rng| {
            let (r, c) = (8u64, 8u64);
            let m = rng.range_u64(1, 8);
            let k = rng.range_u64(1, 8);
            let n = rng.range_u64(1, 8);
            let a = rand_mat(rng, (m * k) as usize);
            let b = rand_mat(rng, (k * n) as usize);

            let ev = run_os(&a, &b, m as usize, k as usize, n as usize, 8, 8);
            let an = systolic::run(
                crate::arch::Dataflow::OS,
                r,
                c,
                MappedGemm { rows: m, cols: n, temporal: k },
                m,
                n,
                k,
            );
            let slack = r + c;
            assert!(
                an.cycles + slack >= ev.cycles && ev.cycles + slack >= an.cycles,
                "analytic {} vs event {} (m={m} n={n} k={k})",
                an.cycles,
                ev.cycles
            );

            let ev = run_ws(&a, &b, m as usize, k as usize, n as usize, 8, 8);
            let an = systolic::run(
                crate::arch::Dataflow::WS,
                r,
                c,
                MappedGemm { rows: k, cols: n, temporal: m },
                m,
                n,
                k,
            );
            assert!(
                an.cycles + slack >= ev.cycles && ev.cycles + slack >= an.cycles,
                "WS analytic {} vs event {}",
                an.cycles,
                ev.cycles
            );
        });
    }

    #[test]
    fn occupancy_trace_has_ramp_and_drain() {
        // the wavefront ramps up, saturates, then drains — no occupancy
        // after the last cycle, none before the first operand lands
        let mut rng = Rng::new(9);
        let (m, k, n) = (8usize, 16usize, 8usize);
        let a = rand_mat(&mut rng, m * k);
        let b = rand_mat(&mut rng, k * n);
        let run = run_os(&a, &b, m, k, n, 8, 8);
        let peak = *run.occupancy.iter().max().unwrap();
        assert_eq!(peak as usize, m * n, "steady state saturates the tile");
        assert!(run.occupancy[0] <= 1);
        assert!(*run.occupancy.last().unwrap() <= peak);
        assert!(run.utilization(8, 8) > 0.3);
    }

    /// Hardware-level demonstration of §3.1: an INT32 multiplication run
    /// as a 4×4 limb GEMM ON THE EVENT-LEVEL ARRAY reproduces the wide
    /// product exactly — Fig. 1 executed cycle by cycle.
    #[test]
    fn multi_precision_mult_on_the_event_array() {
        use crate::precision::{accumulator, limbs};
        property("Fig1 on the grid", 50, |rng: &mut Rng| {
            let x = rng.range_i64(-(1 << 30), (1 << 30) - 1);
            let y = rng.range_i64(-(1 << 30), (1 << 30) - 1);
            let xs = limbs::decompose(x, 4);
            let ys = limbs::decompose(y, 4);
            // rank-1 limb GEMM on the array: xs (4×1) · ysᵀ (1×4)
            let run = run_os(&xs, &ys, 4, 1, 4, 8, 8);
            // the accumulator combines the 4×4 partial-product grid
            let grid: Vec<Vec<i64>> =
                (0..4).map(|i| (0..4).map(|j| run.output[i * 4 + j]).collect()).collect();
            assert_eq!(accumulator::combine(&grid), x.wrapping_mul(y));
        });
    }
}
