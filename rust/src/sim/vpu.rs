//! VPU baseline: an Ara-style RISC-V vector processor (Table 1 column 2).
//!
//! Each lane has a 64-bit multi-precision MAC datapath (packed SIMD:
//! `8/⌈bits/8⌉` word-MACs per cycle) and the machine executes GEMMs as
//! strip-mined, chained AXPY sequences. The paper's point: chaining gives
//! *weak* data reuse — the streamed operand is re-fetched for every output
//! row, so memory access grows with M·N·K instead of the systolic
//! compulsory traffic.

use super::{Platform, SimReport};
use crate::arch::energy;
use crate::ops::{PGemm, TensorOp, VectorOp};
use crate::precision::Precision;

/// Ara configuration.
#[derive(Debug, Clone)]
pub struct VpuSim {
    pub lanes: u32,
    pub freq_mhz: u32,
    /// Vector length in 64-bit element slots (per vector register).
    pub vlen64: u32,
    /// Architectural vector registers available for C-tile residency.
    pub vregs: u32,
    /// Per-instruction issue/stripmine overhead in cycles.
    pub issue_overhead: u32,
}

impl Default for VpuSim {
    fn default() -> Self {
        // Ara [4]: 4 lanes, 250 MHz under the paper's SAED14 library.
        // issue_overhead=1: chaining overlaps loads with MACs, so long
        // vector instructions approach ideal utilization and only the
        // per-instruction issue slot remains exposed.
        VpuSim { lanes: 4, freq_mhz: 250, vlen64: 64, vregs: 8, issue_overhead: 1 }
    }
}

impl VpuSim {
    pub fn new(lanes: u32) -> Self {
        VpuSim { lanes, ..Default::default() }
    }

    /// Word-MACs per cycle across the machine for `p` (packed SIMD).
    pub fn macs_per_cycle(&self, p: Precision) -> f64 {
        let per_lane = 8.0 / (p.bits() as f64 / 8.0);
        per_lane * self.lanes as f64
    }

    /// Elements per vector register at `p`.
    fn vl(&self, p: Precision) -> u64 {
        (self.vlen64 as u64) * (64 / p.bits() as u64)
    }

    fn run_gemm(&self, g: &PGemm) -> SimReport {
        let vl = self.vl(g.precision);
        let macs = g.macs();
        // strip-mined vmacc over N for each (m, k): M·K·⌈N/VL⌉ instructions
        let chunks = g.n.div_ceil(vl);
        let instrs = g.m * g.k * chunks;
        let compute = (macs as f64 / self.macs_per_cycle(g.precision)).ceil() as u64;
        // chaining overlaps compute with loads but each instruction still
        // pays issue/stripmine overhead
        let cycles = compute + instrs * self.issue_overhead as u64;

        let bytes = g.precision.bytes();
        // weak reuse: B re-streamed for every output row; A scalar-read per
        // (m,k); C resident in VRF only while it fits
        let b_reads = g.m * g.k * g.n;
        let a_reads = g.m * g.k;
        let c_capacity = (self.vregs as u64) * vl;
        let c_spill_rounds = if g.n <= c_capacity { 0 } else { g.k };
        let c_traffic = g.m * g.n * (1 + 2 * c_spill_rounds);
        let sram_bytes = (b_reads + a_reads + c_traffic) * bytes;
        let dram_bytes = g.compulsory_bytes();
        SimReport {
            cycles,
            freq_mhz: self.freq_mhz,
            sram_bytes,
            dram_bytes,
            macs,
            utilization: compute as f64 / cycles.max(1) as f64,
            energy_pj: macs as f64 * energy::ara_mac_pj(g.precision)
                + sram_bytes as f64 * energy::SRAM_PJ_PER_BYTE
                + dram_bytes as f64 * energy::DRAM_PJ_PER_BYTE,
        }
    }

    fn run_vector(&self, v: &VectorOp) -> SimReport {
        let ops = v.ops();
        let compute = (ops as f64 / self.macs_per_cycle(v.precision)).ceil() as u64;
        let instrs = v.len.div_ceil(self.vl(v.precision));
        let cycles = compute + instrs * self.issue_overhead as u64;
        let sram_bytes = v.bytes();
        SimReport {
            cycles: cycles.max(1),
            freq_mhz: self.freq_mhz,
            sram_bytes,
            dram_bytes: v.bytes(),
            macs: ops,
            utilization: compute as f64 / cycles.max(1) as f64,
            energy_pj: ops as f64 * energy::ara_mac_pj(v.precision)
                + sram_bytes as f64 * (energy::SRAM_PJ_PER_BYTE + energy::DRAM_PJ_PER_BYTE),
        }
    }
}

impl Platform for VpuSim {
    fn name(&self) -> &'static str {
        "VPU-Ara"
    }

    fn run(&self, op: &TensorOp) -> SimReport {
        match op {
            TensorOp::PGemm(g) => self.run_gemm(g),
            TensorOp::Vector(v) => self.run_vector(v),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ops::VectorKind;
    use crate::sim::gta::GtaSim;

    #[test]
    fn packed_simd_rates() {
        let v = VpuSim::default();
        assert_eq!(v.macs_per_cycle(Precision::Int8), 32.0); // 8/lane·4
        assert_eq!(v.macs_per_cycle(Precision::Int64), 4.0);
        assert_eq!(v.macs_per_cycle(Precision::Fp32), 8.0);
    }

    #[test]
    fn gemm_memory_grows_with_mnk() {
        let v = VpuSim::default();
        let small = v.run(&TensorOp::gemm(32, 32, 32, Precision::Fp32));
        let big = v.run(&TensorOp::gemm(32, 32, 64, Precision::Fp32));
        // doubling K doubles B restream traffic (no reuse across rows)
        assert!(big.sram_bytes > small.sram_bytes * 3 / 2);
    }

    #[test]
    fn gta_beats_vpu_on_gemm_memory() {
        // the Fig. 7 direction: systolic reuse vs chained AXPY
        let vpu = VpuSim::default();
        let gta = GtaSim::table1();
        let g = TensorOp::gemm(128, 169, 576, Precision::Int8);
        let rv = vpu.run(&g);
        let rg = gta.run(&g);
        assert!(
            rv.memory_access() > 3 * rg.memory_access(),
            "VPU {} vs GTA {}",
            rv.memory_access(),
            rg.memory_access()
        );
    }

    #[test]
    fn vector_ops_cost_similar_per_element() {
        let v = VpuSim::default();
        let r = v.run(&TensorOp::vector(4096, Precision::Fp32, VectorKind::Axpy));
        assert!(r.cycles >= 4096 / 8);
        assert!(r.utilization > 0.5);
    }
}
