//! Micro-benchmark harness (criterion stand-in): warmup + timed samples,
//! median/mean/min reporting, consistent text output shared by every
//! `rust/benches/*.rs` target.

use std::time::{Duration, Instant};

/// Timing summary of one benchmark.
#[derive(Debug, Clone)]
pub struct Sample {
    pub name: String,
    pub iters: u64,
    pub mean: Duration,
    pub median: Duration,
    pub min: Duration,
}

impl Sample {
    pub fn report(&self) -> String {
        format!(
            "bench {:<44} iters {:>6}  mean {:>12?}  median {:>12?}  min {:>12?}",
            self.name, self.iters, self.mean, self.median, self.min
        )
    }
}

/// Run `f` repeatedly: a warmup, then enough iterations to fill the time
/// budget (default 1s), and collect per-iteration timings. Prevents the
/// optimizer from deleting the work via `std::hint::black_box` in callers.
pub fn bench(name: &str, mut f: impl FnMut()) -> Sample {
    bench_with_budget(name, Duration::from_millis(600), &mut f)
}

pub fn bench_with_budget(name: &str, budget: Duration, f: &mut dyn FnMut()) -> Sample {
    // warmup + calibration
    let t0 = Instant::now();
    f();
    let once = t0.elapsed().max(Duration::from_nanos(50));
    let iters = (budget.as_nanos() / once.as_nanos()).clamp(5, 10_000) as u64;

    let mut times: Vec<Duration> = Vec::with_capacity(iters as usize);
    for _ in 0..iters {
        let t = Instant::now();
        f();
        times.push(t.elapsed());
    }
    times.sort();
    let mean = times.iter().sum::<Duration>() / iters as u32;
    let sample = Sample {
        name: name.to_string(),
        iters,
        mean,
        median: times[times.len() / 2],
        min: times[0],
    };
    println!("{}", sample.report());
    sample
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_runs_and_reports() {
        let mut n = 0u64;
        let s = bench_with_budget(
            "noop",
            Duration::from_millis(10),
            &mut || n = std::hint::black_box(n + 1),
        );
        assert!(s.iters >= 5);
        assert!(s.min <= s.median && s.median <= s.mean * 10);
        assert!(n >= s.iters);
    }
}
