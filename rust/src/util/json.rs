//! Minimal JSON parser — enough for the artifact manifest (objects,
//! arrays, strings, numbers, bools, null). Strict on structure, no
//! serde dependency.

use std::collections::BTreeMap;
use std::fmt;

/// A parsed JSON value.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_u64(&self) -> Option<u64> {
        match self {
            Json::Num(n) if *n >= 0.0 && n.fract() == 0.0 => Some(*n as u64),
            _ => None,
        }
    }

    pub fn get(&self, key: &str) -> Option<&Json> {
        self.as_obj().and_then(|m| m.get(key))
    }

    /// Serialize to a compact JSON document that [`parse`] round-trips:
    /// strings are escaped, numbers use Rust's shortest round-trip float
    /// formatting, and non-finite numbers (which JSON cannot express)
    /// degrade to `null`.
    pub fn render(&self) -> String {
        let mut out = String::new();
        self.render_into(&mut out);
        out
    }

    fn render_into(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                if n.is_finite() {
                    out.push_str(&format!("{n}"));
                    // `{}` prints integral floats without a dot; that is
                    // still valid JSON, so leave them bare
                } else {
                    out.push_str("null");
                }
            }
            Json::Str(s) => render_str(s, out),
            Json::Arr(items) => {
                out.push('[');
                for (i, v) in items.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    v.render_into(out);
                }
                out.push(']');
            }
            Json::Obj(map) => {
                out.push('{');
                for (i, (k, v)) in map.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    render_str(k, out);
                    out.push(':');
                    v.render_into(out);
                }
                out.push('}');
            }
        }
    }
}

fn render_str(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\t' => out.push_str("\\t"),
            '\r' => out.push_str("\\r"),
            '\u{8}' => out.push_str("\\b"),
            '\u{c}' => out.push_str("\\f"),
            // lint: allow(R1) char -> u32 is a lossless widening (escape path for control chars)
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
}

/// Parse error with byte offset.
#[derive(Debug, Clone)]
pub struct ParseError {
    pub offset: usize,
    pub message: String,
}

impl fmt::Display for ParseError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json parse error at byte {}: {}", self.offset, self.message)
    }
}

impl std::error::Error for ParseError {}

/// Parse a complete JSON document.
pub fn parse(input: &str) -> Result<Json, ParseError> {
    let bytes = input.as_bytes();
    let mut p = Parser { bytes, pos: 0 };
    p.skip_ws();
    let v = p.value()?;
    p.skip_ws();
    if p.pos != bytes.len() {
        return Err(p.err("trailing data"));
    }
    Ok(v)
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, m: &str) -> ParseError {
        ParseError { offset: self.pos, message: m.to_string() }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn bump(&mut self) -> Option<u8> {
        let b = self.peek();
        if b.is_some() {
            self.pos += 1;
        }
        b
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, b: u8) -> Result<(), ParseError> {
        if self.bump() == Some(b) {
            Ok(())
        } else {
            self.pos = self.pos.saturating_sub(1);
            Err(self.err(&format!("expected '{}'", b as char)))
        }
    }

    fn value(&mut self) -> Result<Json, ParseError> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.literal("true", Json::Bool(true)),
            Some(b'f') => self.literal("false", Json::Bool(false)),
            Some(b'n') => self.literal("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("unexpected character")),
        }
    }

    fn literal(&mut self, word: &str, v: Json) -> Result<Json, ParseError> {
        if self.bytes[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected '{word}'")))
        }
    }

    fn object(&mut self) -> Result<Json, ParseError> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b'}') => return Ok(Json::Obj(map)),
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }

    fn array(&mut self) -> Result<Json, ParseError> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            self.skip_ws();
            items.push(self.value()?);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b']') => return Ok(Json::Arr(items)),
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn string(&mut self) -> Result<String, ParseError> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.bump() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => return Ok(out),
                Some(b'\\') => match self.bump() {
                    Some(b'"') => out.push('"'),
                    Some(b'\\') => out.push('\\'),
                    Some(b'/') => out.push('/'),
                    Some(b'n') => out.push('\n'),
                    Some(b't') => out.push('\t'),
                    Some(b'r') => out.push('\r'),
                    Some(b'b') => out.push('\u{8}'),
                    Some(b'f') => out.push('\u{c}'),
                    Some(b'u') => {
                        let mut code = 0u32;
                        for _ in 0..4 {
                            let c = self.bump().ok_or_else(|| self.err("bad \\u"))?;
                            code = code * 16
                                + (c as char).to_digit(16).ok_or_else(|| self.err("bad hex"))?;
                        }
                        out.push(char::from_u32(code).unwrap_or('\u{FFFD}'));
                    }
                    _ => return Err(self.err("bad escape")),
                },
                Some(c) if c < 0x80 => out.push(c as char),
                Some(c) => {
                    // re-assemble UTF-8 multibyte sequences
                    let len = if c >= 0xF0 {
                        4
                    } else if c >= 0xE0 {
                        3
                    } else {
                        2
                    };
                    let start = self.pos - 1;
                    for _ in 1..len {
                        self.bump();
                    }
                    let s = std::str::from_utf8(&self.bytes[start..self.pos])
                        .map_err(|_| self.err("bad utf8"))?;
                    out.push_str(s);
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, ParseError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.pos += 1;
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        std::str::from_utf8(&self.bytes[start..self.pos])
            .ok()
            .and_then(|s| s.parse::<f64>().ok())
            .map(Json::Num)
            .ok_or_else(|| self.err("bad number"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_manifest_like_document() {
        let doc = r#"{
            "mpra_gemm_i8_64": {
                "file": "mpra_gemm_i8_64.hlo.txt",
                "inputs": [{"shape": [64, 64], "dtype": "s32"}],
                "outputs": [{"shape": [64, 64], "dtype": "s32"}]
            }
        }"#;
        let v = parse(doc).unwrap();
        let entry = v.get("mpra_gemm_i8_64").unwrap();
        assert_eq!(entry.get("file").unwrap().as_str(), Some("mpra_gemm_i8_64.hlo.txt"));
        let inputs = entry.get("inputs").unwrap().as_arr().unwrap();
        let shape = inputs[0].get("shape").unwrap().as_arr().unwrap();
        assert_eq!(shape[0].as_u64(), Some(64));
    }

    #[test]
    fn scalars_and_nesting() {
        assert_eq!(parse("null").unwrap(), Json::Null);
        assert_eq!(parse("true").unwrap(), Json::Bool(true));
        assert_eq!(parse("-1.5e2").unwrap(), Json::Num(-150.0));
        assert_eq!(
            parse(r#"["a", [1, 2], {}]"#).unwrap().as_arr().unwrap().len(),
            3
        );
    }

    #[test]
    fn escapes() {
        assert_eq!(
            parse(r#""a\nb\t\"c\" A""#).unwrap().as_str(),
            Some("a\nb\t\"c\" A")
        );
    }

    #[test]
    fn rejects_garbage() {
        assert!(parse("{").is_err());
        assert!(parse("[1,]").is_err());
        assert!(parse("1 2").is_err());
        assert!(parse("{'single': 1}").is_err());
    }

    #[test]
    fn unicode_passthrough() {
        assert_eq!(parse(r#""héllo §""#).unwrap().as_str(), Some("héllo §"));
    }

    #[test]
    fn render_round_trips() {
        let doc = r#"{"a": [1, -2.5, 1e300], "b": "x\n\"y\"\\z", "c": null, "d": true, "é": {}}"#;
        let v = parse(doc).unwrap();
        let rendered = v.render();
        assert_eq!(parse(&rendered).unwrap(), v, "{rendered}");
        // control characters escape and survive
        let s = Json::Str("a\u{1}\u{8}\u{c}b".to_string());
        assert_eq!(parse(&s.render()).unwrap(), s);
        // non-finite numbers degrade to null instead of invalid JSON
        assert_eq!(Json::Num(f64::NAN).render(), "null");
        assert_eq!(Json::Num(f64::INFINITY).render(), "null");
        // floats round-trip bit-exactly through the shortest repr
        for x in [0.1f64, 1.0 / 3.0, 2.0f64.powi(60), -1.5e-9] {
            let r = Json::Num(x).render();
            assert_eq!(parse(&r).unwrap(), Json::Num(x), "{r}");
        }
    }
}
