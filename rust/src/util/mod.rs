//! In-tree substrates for an offline build: deterministic PRNG, a JSON
//! parser (for the artifact manifest), a micro-benchmark harness and a
//! property-testing loop. The build environment vendors only the `xla`
//! PJRT crate, so these stand in for rand/serde_json/criterion/proptest.

pub mod bench;
pub mod json;
pub mod rng;
