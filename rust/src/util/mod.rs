//! In-tree substrates for an offline build: deterministic PRNG, a JSON
//! parser (for the artifact manifest), a micro-benchmark harness and a
//! property-testing loop. Only `anyhow` (and, behind the `pjrt` feature,
//! a vendored `xla` crate) come from outside the tree, so these stand in
//! for rand/serde_json/criterion/proptest.

pub mod bench;
pub mod json;
pub mod rng;
