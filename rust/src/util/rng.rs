//! Deterministic PRNG (xoshiro256** seeded via splitmix64) — reproducible
//! workload generation and in-tree property testing.

/// xoshiro256** generator.
#[derive(Debug, Clone)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    /// Seed deterministically (splitmix64 expansion).
    pub fn new(seed: u64) -> Rng {
        let mut x = seed;
        let mut next = || {
            x = x.wrapping_add(0x9E37_79B9_7F4A_7C15);
            let mut z = x;
            z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
            z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
            z ^ (z >> 31)
        };
        Rng { s: [next(), next(), next(), next()] }
    }

    pub fn next_u64(&mut self) -> u64 {
        let r = self.s[1]
            .wrapping_mul(5)
            .rotate_left(7)
            .wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        r
    }

    /// Uniform in `[lo, hi]` (inclusive).
    pub fn range_u64(&mut self, lo: u64, hi: u64) -> u64 {
        assert!(lo <= hi);
        lo + self.next_u64() % (hi - lo + 1)
    }

    /// Uniform signed value in `[lo, hi]` (inclusive).
    pub fn range_i64(&mut self, lo: i64, hi: i64) -> i64 {
        assert!(lo <= hi);
        lo.wrapping_add((self.next_u64() % ((hi - lo) as u64 + 1)) as i64)
    }

    /// Uniform f64 in [0, 1).
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 / (1u64 << 53) as f64
    }

    /// f32 roughly standard-normal (sum of uniforms, CLT approximation —
    /// plenty for test data).
    pub fn normal_f32(&mut self) -> f32 {
        let s: f64 = (0..12).map(|_| self.f64()).sum::<f64>() - 6.0;
        s as f32
    }

    /// Pick one element of a slice.
    pub fn choose<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[(self.next_u64() as usize) % xs.len()]
    }
}

/// Minimal property-testing loop: run `cases` random cases of `f`,
/// panicking with the seed of the failing case for reproduction.
pub fn property(name: &str, cases: u64, mut f: impl FnMut(&mut Rng)) {
    for case in 0..cases {
        let seed = 0xC0FFEE ^ (case.wrapping_mul(0x9E37_79B9));
        let mut rng = Rng::new(seed);
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| f(&mut rng)));
        if let Err(e) = result {
            panic!("property '{name}' failed at case {case} (seed {seed:#x}): {e:?}");
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_per_seed() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
        let mut c = Rng::new(43);
        assert_ne!(Rng::new(42).next_u64(), c.next_u64());
    }

    #[test]
    fn ranges_respect_bounds() {
        let mut r = Rng::new(7);
        for _ in 0..1000 {
            let v = r.range_u64(3, 9);
            assert!((3..=9).contains(&v));
            let s = r.range_i64(-5, 5);
            assert!((-5..=5).contains(&s));
            let f = r.f64();
            assert!((0.0..1.0).contains(&f));
        }
    }

    #[test]
    fn normal_has_sane_moments() {
        let mut r = Rng::new(11);
        let n = 10_000;
        let xs: Vec<f32> = (0..n).map(|_| r.normal_f32()).collect();
        let mean = xs.iter().sum::<f32>() / n as f32;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f32>() / n as f32;
        assert!(mean.abs() < 0.05, "mean {mean}");
        assert!((var - 1.0).abs() < 0.1, "var {var}");
    }

    #[test]
    fn property_runs_all_cases() {
        let mut count = 0;
        property("count", 25, |_| count += 1);
        assert_eq!(count, 25);
    }
}
