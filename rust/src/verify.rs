//! Functional verification: execute every AOT artifact through PJRT with
//! deterministic random inputs and check the numerics against independent
//! rust oracles (limb GEMM, direct convolution, naive f32 GEMM). This is
//! the end-to-end proof that the three-layer stack — Pallas kernel → HLO
//! text → rust PJRT runtime — computes what the paper's §3.1 says it does.

use crate::precision::limbs;
use crate::runtime::{Engine, HostTensor};
use crate::util::rng::Rng;
use anyhow::{anyhow, Result};
use std::path::Path;

/// Result of a verification sweep.
#[derive(Debug, Clone, Default)]
pub struct Outcome {
    pub passed: u32,
    pub failed: u32,
    pub details: Vec<(String, bool, String)>,
}

impl Outcome {
    fn record(&mut self, name: &str, r: Result<()>) {
        match r {
            Ok(()) => {
                self.passed += 1;
                self.details.push((name.to_string(), true, "ok".into()));
            }
            Err(e) => {
                self.failed += 1;
                self.details.push((name.to_string(), false, format!("{e:#}")));
            }
        }
    }
}

/// Verify every artifact the manifest lists. `verbose` prints per-artifact
/// PASS/FAIL lines.
pub fn verify_all(dir: &Path, verbose: bool) -> Result<Outcome> {
    let engine = Engine::load(dir)?;
    let mut out = Outcome::default();
    let names: Vec<String> = engine.names().iter().map(|s| s.to_string()).collect();
    for name in names {
        let r = verify_one(&engine, &name);
        if verbose {
            match &r {
                Ok(()) => println!("  PASS {name}"),
                Err(e) => println!("  FAIL {name}: {e:#}"),
            }
        }
        out.record(&name, r);
    }
    Ok(out)
}

/// Verify a single artifact by name.
pub fn verify_one(engine: &Engine, name: &str) -> Result<()> {
    let mut rng = Rng::new(0xDEAD_BEEF ^ name.len() as u64);
    match name {
        "mpra_gemm_i8_64" => verify_mpra_i32(engine, name, 64, 1, &mut rng),
        "mpra_gemm_i16_64" => verify_mpra_i32(engine, name, 64, 2, &mut rng),
        "mpra_gemm_i32_64" => verify_mpra_i32(engine, name, 64, 4, &mut rng),
        "mpra_gemm_i64_32" => verify_mpra_i64(engine, name, 32, &mut rng),
        "bignum_mul_64" => verify_bignum(engine, name, 64, &mut rng),
        "matmul_f32_128" => verify_matmul_f32(engine, name, 128, &mut rng),
        "alexnet_conv_i8" => verify_conv_i8(engine, name, &mut rng),
        "ffl_bf16" => verify_ffl(engine, name, &mut rng),
        "pca_cov_f32" => verify_pca(engine, name, &mut rng),
        "nerf_mlp_f32" => verify_nerf(engine, name, &mut rng),
        "md_update_i32" => verify_md(engine, name, &mut rng),
        "rgb_convert_i8" => verify_rgb(engine, name, &mut rng),
        "fir_i16" => verify_fir(engine, name, &mut rng),
        other => Err(anyhow!("no oracle registered for artifact {other:?}")),
    }
}

// ------------------------------------------------------------- oracles --

/// Naive row-major f32 GEMM.
pub fn gemm_f32(a: &[f32], b: &[f32], m: usize, k: usize, n: usize) -> Vec<f32> {
    let mut c = vec![0f32; m * n];
    for i in 0..m {
        for kk in 0..k {
            let av = a[i * k + kk];
            for j in 0..n {
                c[i * n + j] += av * b[kk * n + j];
            }
        }
    }
    c
}

/// Round-to-nearest-even f32 → bf16 → f32 quantization (what the BP16
/// datapath sees).
pub fn quantize_bf16(x: f32) -> f32 {
    let bits = x.to_bits();
    let lsb = (bits >> 16) & 1;
    let rounded = bits.wrapping_add(0x7FFF + lsb) & 0xFFFF_0000;
    f32::from_bits(rounded)
}

fn assert_allclose(got: &[f32], want: &[f32], rtol: f32, atol: f32) -> Result<()> {
    if got.len() != want.len() {
        return Err(anyhow!("length {} != {}", got.len(), want.len()));
    }
    for (i, (g, w)) in got.iter().zip(want).enumerate() {
        let tol = atol + rtol * w.abs();
        if (g - w).abs() > tol {
            return Err(anyhow!("mismatch at {i}: got {g}, want {w} (tol {tol})"));
        }
    }
    Ok(())
}

// --------------------------------------------------------- verifications --

fn verify_mpra_i32(engine: &Engine, name: &str, dim: usize, n_limbs: u32, rng: &mut Rng) -> Result<()> {
    let bits = 8 * n_limbs as i64;
    let (lo, hi) = (-(1i64 << (bits - 1)), (1i64 << (bits - 1)) - 1);
    // keep magnitudes small enough that the i32 accumulator cannot
    // overflow over K=64 — the EXACT regime of the §3.1 claim
    let clamp = ((i32::MAX as i64 / (dim as i64)) as f64).sqrt() as i64;
    let (lo, hi) = (lo.max(-clamp), hi.min(clamp));
    let a: Vec<i64> = (0..dim * dim).map(|_| rng.range_i64(lo, hi)).collect();
    let b: Vec<i64> = (0..dim * dim).map(|_| rng.range_i64(lo, hi)).collect();
    let outs = engine.execute(
        name,
        &[
            HostTensor::I32(a.iter().map(|&v| v as i32).collect()),
            HostTensor::I32(b.iter().map(|&v| v as i32).collect()),
        ],
    )?;
    let got = outs[0].as_i32().ok_or_else(|| anyhow!("bad output dtype"))?;
    let want = limbs::limb_gemm(&a, &b, dim, dim, dim, n_limbs, 32);
    for (i, (&g, &w)) in got.iter().zip(&want).enumerate() {
        if g as i64 != w {
            return Err(anyhow!("[{i}] got {g}, oracle {w}"));
        }
    }
    Ok(())
}

fn verify_mpra_i64(engine: &Engine, name: &str, dim: usize, rng: &mut Rng) -> Result<()> {
    let a: Vec<i64> = (0..dim * dim).map(|_| rng.range_i64(-(1 << 20), 1 << 20)).collect();
    let b: Vec<i64> = (0..dim * dim).map(|_| rng.range_i64(-(1 << 20), 1 << 20)).collect();
    let outs = engine.execute(name, &[HostTensor::I64(a.clone()), HostTensor::I64(b.clone())])?;
    let got = outs[0].as_i64().ok_or_else(|| anyhow!("bad output dtype"))?;
    let want = limbs::limb_gemm(&a, &b, dim, dim, dim, 8, 64);
    for (i, (&g, &w)) in got.iter().zip(&want).enumerate() {
        if g != w {
            return Err(anyhow!("[{i}] got {g}, oracle {w}"));
        }
    }
    Ok(())
}

fn verify_bignum(engine: &Engine, name: &str, l: usize, rng: &mut Rng) -> Result<()> {
    let a: Vec<u8> = (0..l).map(|_| rng.range_u64(0, 255) as u8).collect();
    let b: Vec<u8> = (0..l).map(|_| rng.range_u64(0, 255) as u8).collect();
    let outs = engine.execute(
        name,
        &[
            HostTensor::I32(a.iter().map(|&v| v as i32).collect()),
            HostTensor::I32(b.iter().map(|&v| v as i32).collect()),
        ],
    )?;
    let got = outs[0].as_i32().ok_or_else(|| anyhow!("bad output dtype"))?;
    let want = limbs::bignum_mul_precarry(&a, &b);
    if got.len() != want.len() {
        return Err(anyhow!("len {} != {}", got.len(), want.len()));
    }
    for (i, (&g, &w)) in got.iter().zip(&want).enumerate() {
        if g as i64 != w {
            return Err(anyhow!("[{i}] got {g}, oracle {w}"));
        }
    }
    Ok(())
}

fn verify_matmul_f32(engine: &Engine, name: &str, dim: usize, rng: &mut Rng) -> Result<()> {
    let a: Vec<f32> = (0..dim * dim).map(|_| rng.normal_f32()).collect();
    let b: Vec<f32> = (0..dim * dim).map(|_| rng.normal_f32()).collect();
    let outs = engine.execute(name, &[HostTensor::F32(a.clone()), HostTensor::F32(b.clone())])?;
    let got = outs[0].as_f32().ok_or_else(|| anyhow!("bad output dtype"))?;
    let want = gemm_f32(&a, &b, dim, dim, dim);
    assert_allclose(got, &want, 1e-4, 1e-4)
}

fn verify_conv_i8(engine: &Engine, name: &str, rng: &mut Rng) -> Result<()> {
    let (c, hw, k, r) = (64usize, 15usize, 64usize, 3usize);
    let x: Vec<i64> = (0..c * hw * hw).map(|_| rng.range_i64(-128, 127)).collect();
    let w: Vec<i64> = (0..k * c * r * r).map(|_| rng.range_i64(-128, 127)).collect();
    let outs = engine.execute(
        name,
        &[
            HostTensor::I32(x.iter().map(|&v| v as i32).collect()),
            HostTensor::I32(w.iter().map(|&v| v as i32).collect()),
        ],
    )?;
    let got = outs[0].as_i32().ok_or_else(|| anyhow!("bad output dtype"))?;
    // direct convolution oracle (valid padding, stride 1)
    let o = hw - r + 1;
    let mut want = vec![0i64; k * o * o];
    for kk in 0..k {
        for y in 0..o {
            for xx in 0..o {
                let mut acc = 0i64;
                for ch in 0..c {
                    for dr in 0..r {
                        for ds in 0..r {
                            acc += x[ch * hw * hw + (y + dr) * hw + (xx + ds)]
                                * w[kk * c * r * r + ch * r * r + dr * r + ds];
                        }
                    }
                }
                want[kk * o * o + y * o + xx] = acc;
            }
        }
    }
    for (i, (&g, &w)) in got.iter().zip(&want).enumerate() {
        if g as i64 != w {
            return Err(anyhow!("[{i}] got {g}, oracle {w}"));
        }
    }
    Ok(())
}

fn verify_ffl(engine: &Engine, name: &str, rng: &mut Rng) -> Result<()> {
    let (b, d, f) = (16usize, 256usize, 1024usize);
    let x: Vec<f32> = (0..b * d).map(|_| rng.normal_f32() * 0.5).collect();
    let w1: Vec<f32> = (0..d * f).map(|_| rng.normal_f32() * 0.05).collect();
    let w2: Vec<f32> = (0..f * d).map(|_| rng.normal_f32() * 0.05).collect();
    let outs = engine.execute(
        name,
        &[
            HostTensor::F32(x.clone()),
            HostTensor::F32(w1.clone()),
            HostTensor::F32(w2.clone()),
        ],
    )?;
    let got = outs[0].as_f32().ok_or_else(|| anyhow!("bad output dtype"))?;
    let q = |v: &[f32]| -> Vec<f32> { v.iter().map(|&x| quantize_bf16(x)).collect() };
    let mut h = gemm_f32(&q(&x), &q(&w1), b, d, f);
    for v in h.iter_mut() {
        *v = v.max(0.0);
    }
    let want = gemm_f32(&q(&h), &q(&w2), b, f, d);
    // bf16 mantissa: loose tolerance
    assert_allclose(got, &want, 2e-2, 2e-2)
}

fn verify_pca(engine: &Engine, name: &str, rng: &mut Rng) -> Result<()> {
    let (n, d) = (256usize, 64usize);
    let x: Vec<f32> = (0..n * d).map(|_| rng.normal_f32()).collect();
    let outs = engine.execute(name, &[HostTensor::F32(x.clone())])?;
    let got = outs[0].as_f32().ok_or_else(|| anyhow!("bad output dtype"))?;
    // center then covariance
    let mut xc = x.clone();
    for j in 0..d {
        let mean: f32 = (0..n).map(|i| x[i * d + j]).sum::<f32>() / n as f32;
        for i in 0..n {
            xc[i * d + j] -= mean;
        }
    }
    // want = xcᵀ·xc / (n-1): (d×n)·(n×d)
    let mut xt = vec![0f32; d * n];
    for i in 0..n {
        for j in 0..d {
            xt[j * n + i] = xc[i * d + j];
        }
    }
    let mut want = gemm_f32(&xt, &xc, d, n, d);
    for v in want.iter_mut() {
        *v /= (n - 1) as f32;
    }
    assert_allclose(got, &want, 1e-3, 1e-3)
}

fn verify_nerf(engine: &Engine, name: &str, rng: &mut Rng) -> Result<()> {
    let (b, d, h) = (128usize, 64usize, 256usize);
    let x: Vec<f32> = (0..b * d).map(|_| rng.normal_f32()).collect();
    let w1: Vec<f32> = (0..d * h).map(|_| rng.normal_f32() * 0.1).collect();
    let w2: Vec<f32> = (0..h * d).map(|_| rng.normal_f32() * 0.1).collect();
    let outs = engine.execute(
        name,
        &[
            HostTensor::F32(x.clone()),
            HostTensor::F32(w1.clone()),
            HostTensor::F32(w2.clone()),
        ],
    )?;
    let got = outs[0].as_f32().ok_or_else(|| anyhow!("bad output dtype"))?;
    let mut hidden = gemm_f32(&x, &w1, b, d, h);
    for v in hidden.iter_mut() {
        *v = v.max(0.0);
    }
    let want = gemm_f32(&hidden, &w2, b, h, d);
    assert_allclose(got, &want, 1e-4, 1e-4)
}

fn verify_md(engine: &Engine, name: &str, rng: &mut Rng) -> Result<()> {
    let (n, b) = (64usize, 32usize);
    let a22: Vec<i64> = (0..n * n).map(|_| rng.range_i64(-1000, 1000)).collect();
    let a21: Vec<i64> = (0..n * b).map(|_| rng.range_i64(-1000, 1000)).collect();
    let a12: Vec<i64> = (0..b * n).map(|_| rng.range_i64(-1000, 1000)).collect();
    let to_i32 = |v: &[i64]| HostTensor::I32(v.iter().map(|&x| x as i32).collect());
    let outs = engine.execute(name, &[to_i32(&a22), to_i32(&a21), to_i32(&a12)])?;
    let got = outs[0].as_i32().ok_or_else(|| anyhow!("bad output dtype"))?;
    for i in 0..n {
        for j in 0..n {
            let mut prod = 0i64;
            for kk in 0..b {
                prod += a21[i * b + kk] * a12[kk * n + j];
            }
            let want = a22[i * n + j] - prod;
            let g = got[i * n + j] as i64;
            if g != want {
                return Err(anyhow!("[{i},{j}] got {g}, oracle {want}"));
            }
        }
    }
    Ok(())
}

fn verify_rgb(engine: &Engine, name: &str, rng: &mut Rng) -> Result<()> {
    let pixels = 1024usize;
    let mat: Vec<i64> = (0..9).map(|_| rng.range_i64(-128, 127)).collect();
    let img: Vec<i64> = (0..3 * pixels).map(|_| rng.range_i64(-128, 127)).collect();
    let outs = engine.execute(
        name,
        &[
            HostTensor::I32(mat.iter().map(|&v| v as i32).collect()),
            HostTensor::I32(img.iter().map(|&v| v as i32).collect()),
        ],
    )?;
    let got = outs[0].as_i32().ok_or_else(|| anyhow!("bad output dtype"))?;
    // direct 3×3 colour-matrix oracle
    for ch in 0..3 {
        for p in 0..pixels {
            let want: i64 = (0..3).map(|c| mat[ch * 3 + c] * img[c * pixels + p]).sum();
            let g = got[ch * pixels + p] as i64;
            if g != want {
                return Err(anyhow!("[{ch},{p}] got {g}, oracle {want}"));
            }
        }
    }
    Ok(())
}

fn verify_fir(engine: &Engine, name: &str, rng: &mut Rng) -> Result<()> {
    let (n, taps) = (256usize, 64usize);
    let x: Vec<i64> = (0..n + taps - 1).map(|_| rng.range_i64(-3000, 3000)).collect();
    let h: Vec<i64> = (0..taps).map(|_| rng.range_i64(-3000, 3000)).collect();
    let outs = engine.execute(
        name,
        &[
            HostTensor::I32(x.iter().map(|&v| v as i32).collect()),
            HostTensor::I32(h.iter().map(|&v| v as i32).collect()),
        ],
    )?;
    let got = outs[0].as_i32().ok_or_else(|| anyhow!("bad output dtype"))?;
    // direct FIR oracle: y[i] = Σ_t h[t]·x[i+t]
    for i in 0..n {
        let want: i64 = (0..taps).map(|t| h[t] * x[i + t]).sum();
        let g = got[i] as i64;
        if g != want {
            return Err(anyhow!("[{i}] got {g}, oracle {want}"));
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bf16_quantization_properties() {
        assert_eq!(quantize_bf16(1.0), 1.0);
        assert_eq!(quantize_bf16(0.0), 0.0);
        // bf16 has 8 significand bits: relative error < 2^-8
        for &x in &[3.14159f32, -123.456, 1e-3, 7.5e6] {
            let q = quantize_bf16(x);
            assert!(((q - x) / x).abs() < 1.0 / 256.0, "{x} -> {q}");
        }
    }

    #[test]
    fn gemm_f32_oracle_identity() {
        // A · I = A
        let a: Vec<f32> = (0..9).map(|i| i as f32).collect();
        let mut eye = vec![0f32; 9];
        for i in 0..3 {
            eye[i * 3 + i] = 1.0;
        }
        assert_eq!(gemm_f32(&a, &eye, 3, 3, 3), a);
    }

    #[test]
    fn allclose_catches_mismatch() {
        assert!(assert_allclose(&[1.0], &[1.0001], 1e-3, 0.0).is_ok());
        assert!(assert_allclose(&[1.0], &[1.1], 1e-3, 1e-3).is_err());
        assert!(assert_allclose(&[1.0, 2.0], &[1.0], 1e-3, 0.0).is_err());
    }
}
