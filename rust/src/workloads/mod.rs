//! The Table 2 workload suite: nine tensor applications across domains and
//! precisions, each decomposed into p-GEMM and vector operators "for
//! execution" exactly as §6.2 prescribes.

use crate::lowering;
use crate::ops::{PGemm, TensorOp, VectorKind};
use crate::precision::Precision;

/// A Table 2 workload: name, description, dominant precision, operator list.
#[derive(Debug, Clone)]
pub struct Workload {
    pub name: &'static str,
    pub description: &'static str,
    pub precision: Precision,
    pub ops: Vec<TensorOp>,
}

impl Workload {
    pub fn total_macs(&self) -> u64 {
        self.ops.iter().map(|o| o.macs()).sum()
    }

    /// The workload's p-GEMM operators in execution order — the input
    /// shape the schedule explorer's batch API takes.
    pub fn pgemms(&self) -> Vec<PGemm> {
        self.ops
            .iter()
            .filter_map(|o| match o {
                TensorOp::PGemm(g) => Some(*g),
                TensorOp::Vector(_) => None,
            })
            .collect()
    }
}

/// BNM — Big-Number Multiplication (scientific computing / encryption):
/// a batch of 512-bit (64-limb) products, each a rank-1 limb p-GEMM +
/// carry pass (§3.1).
pub fn bnm() -> Workload {
    let mut ops = Vec::new();
    for _ in 0..128 {
        ops.extend(lowering::bignum_mul(64));
    }
    Workload {
        name: "BNM",
        description: "Big Numbers Multiplication in Scientific Computing and Encryption",
        precision: Precision::Int64,
        ops,
    }
}

/// RGB — SRGB2XYZ colour conversion over a 1080p frame, INT8.
pub fn rgb() -> Workload {
    Workload {
        name: "RGB",
        description: "SRGB2XYZ in Image Processing",
        precision: Precision::Int8,
        ops: lowering::color_convert(1920 * 1080, Precision::Int8),
    }
}

/// FFE — feed-forward equalizer (audio), INT16: a bank of FIR filters.
pub fn ffe() -> Workload {
    let mut ops = Vec::new();
    for _ in 0..8 {
        ops.extend(lowering::fir_filter(48_000, 256, Precision::Int16));
    }
    Workload {
        name: "FFE",
        description: "FFE in Audio Processing",
        precision: Precision::Int16,
        ops,
    }
}

/// MD — blocked matrix decomposition (mathematical analysis), INT32
/// fixed-point.
pub fn md() -> Workload {
    Workload {
        name: "MD",
        description: "Matrix Decomposition in Mathematical Analysis",
        precision: Precision::Int32,
        ops: lowering::matrix_decomposition(512, 32, Precision::Int32),
    }
}

/// PCA — covariance + power iteration (data analysis), FP64.
pub fn pca() -> Workload {
    Workload {
        name: "PCA",
        description: "PCA in Data Analysis",
        precision: Precision::Fp64,
        ops: lowering::pca(4096, 128, 16, Precision::Fp64),
    }
}

/// Alexnet convolution stack as im2col GEMMs (canonical layer shapes),
/// batch-scaled; shared by ALT and ALI.
fn alexnet_convs(p: Precision, batch: u64) -> Vec<TensorOp> {
    // (C, H/W in, K, R, OH/OW) per conv layer (stride folded into OH/OW)
    let layers: [(u64, u64, u64, u64); 5] = [
        (96, 55 * 55, 11 * 11 * 3, 1),  // conv1
        (256, 27 * 27, 5 * 5 * 96, 1),  // conv2 (groups flattened)
        (384, 13 * 13, 3 * 3 * 256, 1), // conv3
        (384, 13 * 13, 3 * 3 * 384, 1), // conv4
        (256, 13 * 13, 3 * 3 * 384, 1), // conv5
    ];
    let mut ops = Vec::new();
    for (k, spatial, patch, _) in layers {
        let n = spatial * batch;
        ops.push(TensorOp::vector(patch * n, p, VectorKind::Map)); // im2col
        ops.push(TensorOp::gemm(k, n, patch, p));
        ops.push(TensorOp::vector(k * n, p, VectorKind::Activation)); // relu
    }
    // fully-connected head
    for (m, k) in [(4096, 9216), (4096, 4096), (1000, 4096)] {
        ops.push(TensorOp::gemm(m, batch, k, p));
        ops.push(TensorOp::vector(m * batch, p, VectorKind::Activation));
    }
    ops
}

/// ALT — Alexnet training step, FP32: forward + input-grad + weight-grad
/// (each conv/fc GEMM appears three times at training batch size).
pub fn alt() -> Workload {
    let fwd = alexnet_convs(Precision::Fp32, 8);
    let mut ops = Vec::new();
    for _ in 0..3 {
        ops.extend(fwd.iter().cloned());
    }
    Workload {
        name: "ALT",
        description: "Alexnet Training in ML",
        precision: Precision::Fp32,
        ops,
    }
}

/// FFL — GPT-3 feed-forward layer, BP16: d_model=12288, d_ff=4·d_model,
/// over a 512-token microbatch.
pub fn ffl() -> Workload {
    let (tokens, d_model, d_ff) = (512, 12_288, 49_152);
    let mut ops = lowering::dense(tokens, d_model, d_ff, Precision::Bp16, true);
    ops.extend(lowering::dense(tokens, d_ff, d_model, Precision::Bp16, false));
    Workload {
        name: "FFL",
        description: "GPT3 Feed-Forward Layers in ML",
        precision: Precision::Bp16,
        ops,
    }
}

/// ALI — Alexnet inference, INT8, batch 1.
pub fn ali() -> Workload {
    Workload {
        name: "ALI",
        description: "Alexnet Inference in ML",
        precision: Precision::Int8,
        ops: alexnet_convs(Precision::Int8, 1),
    }
}

/// Nerf — positional-encoding MLP, FP32: 8 layers × 256 wide over a ray
/// batch.
pub fn nerf() -> Workload {
    let (rays, width) = (4096, 256);
    let mut ops = lowering::dense(rays, 60, width, Precision::Fp32, true);
    for _ in 0..7 {
        ops.extend(lowering::dense(rays, width, width, Precision::Fp32, true));
    }
    ops.extend(lowering::dense(rays, width, 4, Precision::Fp32, false));
    Workload {
        name: "Nerf",
        description: "Nerf in ML",
        precision: Precision::Fp32,
        ops,
    }
}

/// The full Table 2 suite in paper order.
pub fn suite() -> Vec<Workload> {
    vec![bnm(), rgb(), ffe(), md(), pca(), alt(), ffl(), ali(), nerf()]
}

/// Every p-GEMM of the Table 2 suite in paper order — the multi-operator
/// batch the schedule explorer is sized (and benchmarked) against.
pub fn suite_pgemms() -> Vec<PGemm> {
    suite().iter().flat_map(|w| w.pgemms()).collect()
}

/// The p-GEMM-only view of the suite (for the Fig. 10 CGRA comparison,
/// which the paper runs "in p-GEMM operators").
pub fn suite_pgemm_only() -> Vec<Workload> {
    suite()
        .into_iter()
        .map(|mut w| {
            w.ops.retain(|o| matches!(o, TensorOp::PGemm(_)));
            w
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn suite_matches_table2() {
        let s = suite();
        let names: Vec<_> = s.iter().map(|w| w.name).collect();
        assert_eq!(names, ["BNM", "RGB", "FFE", "MD", "PCA", "ALT", "FFL", "ALI", "Nerf"]);
        let precisions: Vec<_> = s.iter().map(|w| w.precision).collect();
        assert!(precisions.contains(&Precision::Int8));
        assert!(precisions.contains(&Precision::Bp16));
        assert!(precisions.contains(&Precision::Fp64));
    }

    #[test]
    fn every_workload_has_both_op_classes_where_expected() {
        for w in suite() {
            assert!(!w.ops.is_empty(), "{} empty", w.name);
            assert!(
                w.ops.iter().any(|o| matches!(o, TensorOp::PGemm(_))),
                "{} must contain p-GEMM work",
                w.name
            );
        }
    }

    #[test]
    fn ffl_is_the_macs_heavyweight() {
        let s = suite();
        let ffl_macs = s.iter().find(|w| w.name == "FFL").unwrap().total_macs();
        let rgb_macs = s.iter().find(|w| w.name == "RGB").unwrap().total_macs();
        assert!(ffl_macs > 100 * rgb_macs);
    }

    #[test]
    fn pgemm_only_strips_vectors() {
        for w in suite_pgemm_only() {
            assert!(w.ops.iter().all(|o| matches!(o, TensorOp::PGemm(_))));
        }
    }

    #[test]
    fn suite_pgemms_flattens_the_whole_suite() {
        let flat = suite_pgemms();
        let per_workload: usize = suite().iter().map(|w| w.pgemms().len()).sum();
        assert_eq!(flat.len(), per_workload);
        assert!(flat.len() > 20, "the suite should carry plenty of p-GEMM work");
        // every op in the flat list appears in some workload's decomposition
        assert!(flat.iter().all(|g| g.m > 0 && g.n > 0 && g.k > 0));
    }
}
