//! Self-tests for `gta analyze`: every rule gets a firing fixture and a
//! clean fixture, the suppression grammar is exercised end to end, the
//! baseline round-trips, and a meta-test asserts the committed tree itself
//! scans clean under the committed baseline (the same check CI runs).
//!
//! Fixtures live in `tests/fixtures/analysis/` — the directory walker
//! skips `tests/` and `fixtures/`, so they are only ever scanned when a
//! test feeds them to [`scan_source`] with a hot-path label.

use gta::analysis::{
    apply_baseline, baseline_from_findings, lex, norm_path, parse_baseline, render_baseline,
    report_json, resolve_baseline_path, scan_dir, scan_source, Baseline, BaselineEntry, Finding,
    Report, BASELINE_SCHEMA, REPORT_SCHEMA,
};
use std::path::Path;

fn rules_of(findings: &[Finding]) -> Vec<&'static str> {
    findings.iter().map(|f| f.rule).collect()
}

// ---------------------------------------------------------------------------
// Lexer.
// ---------------------------------------------------------------------------

#[test]
fn lexer_blanks_strings_and_comments() {
    let lines = lex("let s = \"x as u32\"; // but as u32 in a comment\n");
    assert_eq!(lines.len(), 1, "trailing newline does not open a phantom line");
    assert!(!lines[0].code.contains("as u32"), "string/comment text must leave code");
    assert!(lines[0].code.contains("let s ="));
    assert!(lines[0].comment.contains("but as u32 in a comment"));
}

#[test]
fn lexer_handles_raw_strings_and_block_comments() {
    let src = "let r = r#\"x.unwrap()\"#; /* outer /* nested .expect( */ still comment */ let y = 1;\n";
    let lines = lex(src);
    assert!(!lines[0].code.contains(".unwrap()"));
    assert!(!lines[0].code.contains(".expect("));
    assert!(lines[0].code.contains("let y = 1;"));
}

#[test]
fn lexer_string_continuation_keeps_line_numbers() {
    // a `\<newline>` continuation inside a string still splits lines, so
    // findings after it land on the right line number
    let src = "let s = \"a\\\n   b\";\nlet n = x as u32;\n";
    let f = scan_source("src/net/proto.rs", src);
    assert_eq!(rules_of(&f), ["R1"]);
    assert_eq!(f[0].line, 3);
}

#[test]
fn lexer_distinguishes_lifetimes_from_char_literals() {
    let lines = lex("fn f<'a>(x: &'a str) -> char { 'y' }\n");
    assert!(lines[0].code.contains("<'a>"), "lifetime stays in code");
    assert!(!lines[0].code.contains("'y'"), "char literal interior blanked");
}

// ---------------------------------------------------------------------------
// Rules: one firing + one clean fixture each.
// ---------------------------------------------------------------------------

#[test]
fn r1_narrowing_cast_fires_and_checked_idiom_passes() {
    let bad = scan_source("src/net/proto.rs", include_str!("fixtures/analysis/r1_bad.rs"));
    assert_eq!(rules_of(&bad), ["R1"]);
    assert_eq!(bad[0].line, 2);
    let good = scan_source("src/net/proto.rs", include_str!("fixtures/analysis/r1_good.rs"));
    assert!(good.is_empty(), "try_from is the sanctioned idiom: {good:?}");
}

#[test]
fn r1_only_fires_in_decoder_scope() {
    // the same cast in a module outside the R1 scope is allowed
    let f = scan_source("src/scheduler/explorer.rs", include_str!("fixtures/analysis/r1_bad.rs"));
    assert!(f.is_empty(), "R1 is scoped to decoder/wire/limb modules: {f:?}");
}

#[test]
fn r2_unwrap_and_literal_index_fire_in_hot_path() {
    let bad = scan_source("src/net/server.rs", include_str!("fixtures/analysis/r2_bad.rs"));
    assert_eq!(rules_of(&bad), ["R2", "R2"], "one for .unwrap(), one for frames[0]");
    let good = scan_source("src/net/server.rs", include_str!("fixtures/analysis/r2_good.rs"));
    assert!(good.is_empty(), "{good:?}");
}

#[test]
fn r3_bare_lock_unwrap_fires_and_poison_recovery_passes() {
    let bad = scan_source("src/coordinator/metrics.rs", include_str!("fixtures/analysis/r3_bad.rs"));
    assert_eq!(rules_of(&bad), ["R3"]);
    let good =
        scan_source("src/coordinator/metrics.rs", include_str!("fixtures/analysis/r3_good.rs"));
    assert!(good.is_empty(), "into_inner() recovery is the sanctioned idiom: {good:?}");
}

#[test]
fn r4_relaxed_ordering_needs_justification() {
    let bad = scan_source("src/scheduler/cache.rs", include_str!("fixtures/analysis/r4_bad.rs"));
    assert_eq!(rules_of(&bad), ["R4"]);
    let good = scan_source("src/scheduler/cache.rs", include_str!("fixtures/analysis/r4_good.rs"));
    assert!(good.is_empty(), "relaxed-ok with a reason suppresses R4: {good:?}");
}

#[test]
fn r5_todo_fires_outside_main() {
    let bad = scan_source("src/util/pending.rs", include_str!("fixtures/analysis/r5_bad.rs"));
    assert_eq!(rules_of(&bad), ["R5"]);
    let good = scan_source("src/util/pending.rs", include_str!("fixtures/analysis/r5_good.rs"));
    assert!(good.is_empty(), "{good:?}");
    // the same source under main.rs is out of scope
    let in_main = scan_source("src/main.rs", include_str!("fixtures/analysis/r5_bad.rs"));
    assert!(in_main.is_empty(), "{in_main:?}");
}

#[test]
fn r6_infallible_decode_signature_fires() {
    let bad = scan_source("src/net/codec.rs", include_str!("fixtures/analysis/r6_bad.rs"));
    assert_eq!(rules_of(&bad), ["R6"]);
    assert!(bad[0].message.contains("decode_frame"));
    let good = scan_source("src/net/codec.rs", include_str!("fixtures/analysis/r6_good.rs"));
    assert!(good.is_empty(), "Result-returning decode passes: {good:?}");
}

#[test]
fn r7_capacity_reservation_needs_bound_justification() {
    let bad = scan_source("src/net/codec.rs", include_str!("fixtures/analysis/r7_bad.rs"));
    assert_eq!(rules_of(&bad), ["R7"]);
    let good = scan_source("src/net/codec.rs", include_str!("fixtures/analysis/r7_good.rs"));
    assert!(good.is_empty(), "cap-checked reservation with allow(R7) passes: {good:?}");
}

#[test]
fn r8_bench_baseline_writer_must_stamp_schema() {
    let bad = scan_source("benches/fixture_bench.rs", include_str!("fixtures/analysis/r8_bad.rs"));
    assert_eq!(rules_of(&bad), ["R8"]);
    let good = scan_source("benches/fixture_bench.rs", include_str!("fixtures/analysis/r8_good.rs"));
    assert!(good.is_empty(), "{good:?}");
    // R8 is bench-only: the same text under src/ is out of scope
    let in_src = scan_source("src/sim/mod.rs", include_str!("fixtures/analysis/r8_bad.rs"));
    assert!(in_src.is_empty(), "{in_src:?}");
}

// ---------------------------------------------------------------------------
// Suppressions and the test mask.
// ---------------------------------------------------------------------------

#[test]
fn suppression_with_reason_covers_next_line() {
    let f = scan_source("src/net/proto.rs", include_str!("fixtures/analysis/suppress_ok.rs"));
    assert!(f.is_empty(), "{f:?}");
}

#[test]
fn suppression_without_reason_is_r0_and_does_not_suppress() {
    let f =
        scan_source("src/net/proto.rs", include_str!("fixtures/analysis/suppress_no_reason.rs"));
    assert_eq!(rules_of(&f), ["R0", "R1"], "reasonless allow is rejected AND ineffective");
}

#[test]
fn unknown_directive_is_r0() {
    let f = scan_source("src/util/x.rs", include_str!("fixtures/analysis/suppress_unknown.rs"));
    assert_eq!(rules_of(&f), ["R0"]);
}

#[test]
fn suppression_does_not_reach_two_lines_down() {
    let f = scan_source("src/net/proto.rs", include_str!("fixtures/analysis/suppress_too_far.rs"));
    assert_eq!(rules_of(&f), ["R1"], "an allow covers its own line and the next only");
    assert_eq!(f[0].line, 4);
}

#[test]
fn cfg_test_blocks_are_masked() {
    let f = scan_source("src/net/masked.rs", include_str!("fixtures/analysis/masked_tests.rs"));
    assert!(f.is_empty(), "unwrap() inside #[cfg(test)] mod tests is fine: {f:?}");
}

// ---------------------------------------------------------------------------
// Paths, baseline, report.
// ---------------------------------------------------------------------------

#[test]
fn norm_path_is_invariant_to_scan_root() {
    for label in
        ["src/net/proto.rs", "./src/net/proto.rs", "rust/src/net/proto.rs", "/a/b/src/net/proto.rs"]
    {
        assert_eq!(norm_path(label), "src/net/proto.rs");
    }
    assert_eq!(norm_path("benches/kernel_throughput.rs"), "benches/kernel_throughput.rs");
}

#[test]
fn baseline_round_trips_through_render_and_parse() {
    let b = Baseline {
        entries: vec![BaselineEntry {
            rule: "R3".to_string(),
            file: "src/coordinator/session.rs".to_string(),
            max: 16,
            note: "burn down".to_string(),
        }],
    };
    let parsed = parse_baseline(&render_baseline(&b)).expect("rendered baseline must parse");
    assert_eq!(parsed.entries.len(), 1);
    assert_eq!(parsed.entries[0].rule, "R3");
    assert_eq!(parsed.entries[0].file, "src/coordinator/session.rs");
    assert_eq!(parsed.entries[0].max, 16);
    assert_eq!(parsed.entries[0].note, "burn down");
}

#[test]
fn baseline_rejects_wrong_schema() {
    assert!(parse_baseline("{\"schema\":\"nope/1\",\"entries\":[]}").is_err());
    assert!(parse_baseline(&format!("{{\"schema\":\"{BASELINE_SCHEMA}\",\"entries\":[]}}")).is_ok());
}

#[test]
fn apply_baseline_grandfathers_at_ceiling_and_fails_above() {
    let mk = |n: usize| -> Vec<Finding> {
        (0..n)
            .map(|i| Finding {
                rule: "R3",
                file: "src/coordinator/session.rs".to_string(),
                line: i + 1,
                message: "m".to_string(),
            })
            .collect()
    };
    let b = Baseline {
        entries: vec![BaselineEntry {
            rule: "R3".to_string(),
            file: "src/coordinator/session.rs".to_string(),
            max: 2,
            note: "n".to_string(),
        }],
    };
    let (failing, grand) = apply_baseline(mk(2), &b);
    assert!(failing.is_empty(), "at the ceiling is grandfathered");
    assert_eq!(grand.len(), 1);
    assert_eq!((grand[0].count, grand[0].max), (2, 2));

    let (failing, grand) = apply_baseline(mk(3), &b);
    assert_eq!(failing.len(), 3, "over the ceiling fails the whole group");
    assert!(grand.is_empty());

    // a group with no entry at all fails outright
    let (failing, _) = apply_baseline(
        vec![Finding { rule: "R1", file: "src/net/proto.rs".to_string(), line: 1, message: "m".to_string() }],
        &b,
    );
    assert_eq!(failing.len(), 1);
}

#[test]
fn baseline_from_findings_exactly_covers_them() {
    let findings = vec![
        Finding { rule: "R4", file: "src/a.rs".to_string(), line: 1, message: "m".to_string() },
        Finding { rule: "R4", file: "src/a.rs".to_string(), line: 9, message: "m".to_string() },
    ];
    let b = baseline_from_findings(&findings, "seed");
    assert_eq!(b.entries.len(), 1);
    assert_eq!(b.entries[0].max, 2);
    let (failing, grand) = apply_baseline(findings, &b);
    assert!(failing.is_empty());
    assert_eq!(grand.len(), 1);
}

#[test]
fn report_json_carries_the_contract_fields() {
    let r = Report {
        dir: "src".to_string(),
        files_scanned: 3,
        failing: vec![Finding {
            rule: "R1",
            file: "src/net/proto.rs".to_string(),
            line: 7,
            message: "m".to_string(),
        }],
        grandfathered: vec![],
    };
    let j = gta::util::json::parse(&report_json(&r).render()).expect("report renders valid JSON");
    assert_eq!(j.get("schema").and_then(|s| s.as_str()), Some(REPORT_SCHEMA));
    assert_eq!(j.get("ok"), Some(&gta::util::json::Json::Bool(false)));
    assert_eq!(j.get("files_scanned").and_then(|n| n.as_u64()), Some(3));
    let findings = j.get("findings").and_then(|f| f.as_arr()).expect("findings array");
    assert_eq!(findings.len(), 1);
    assert_eq!(findings[0].get("rule").and_then(|s| s.as_str()), Some("R1"));
    assert_eq!(findings[0].get("line").and_then(|n| n.as_u64()), Some(7));
    assert!(j.get("grandfathered").and_then(|g| g.as_arr()).is_some());
}

// ---------------------------------------------------------------------------
// The committed tree itself.
// ---------------------------------------------------------------------------

#[test]
fn committed_tree_scans_clean_under_committed_baseline() {
    // integration tests run with cwd = crate root (rust/)
    let (files, findings) = scan_dir(Path::new(".")).expect("scan the crate");
    assert!(files > 20, "walker found the tree ({files} files)");
    let path = resolve_baseline_path(Path::new(".")).expect("analysis/BASELINE.json is committed");
    let text = std::fs::read_to_string(path).expect("read baseline");
    let baseline = parse_baseline(&text).expect("committed baseline parses");
    let (failing, grandfathered) = apply_baseline(findings, &baseline);
    assert!(
        failing.is_empty(),
        "the committed tree must scan clean — fix, suppress with a reason, or \
         (cold paths only) extend the baseline:\n{failing:#?}"
    );
    assert!(!grandfathered.is_empty(), "burn-down groups are still tracked");
}

#[test]
fn seeding_a_narrowing_cast_into_proto_is_caught() {
    let clean = include_str!("../src/net/proto.rs");
    assert!(
        scan_source("src/net/proto.rs", clean).is_empty(),
        "proto.rs carries no baselined findings — any regression is a new finding"
    );
    let seeded = format!("{clean}\npub fn sneak(x: u64) -> u32 {{ x as u32 }}\n");
    let f = scan_source("src/net/proto.rs", &seeded);
    assert_eq!(rules_of(&f), ["R1"], "the seeded decoder cast must be flagged");
}

// ---------------------------------------------------------------------------
// CLI surface.
// ---------------------------------------------------------------------------

fn gta_bin() -> std::process::Command {
    std::process::Command::new(env!("CARGO_BIN_EXE_gta"))
}

#[test]
fn cli_analyze_passes_on_the_committed_tree() {
    let out = gta_bin().args(["analyze", "--dir", "."]).output().expect("run gta analyze");
    let stdout = String::from_utf8_lossy(&out.stdout);
    assert!(out.status.success(), "gta analyze must pass on the committed tree:\n{stdout}");
    assert!(stdout.contains("analysis OK"), "{stdout}");
}

#[test]
fn cli_analyze_fails_on_a_seeded_violation() {
    let dir = std::env::temp_dir().join(format!("gta_analyze_seed_{}", std::process::id()));
    let net = dir.join("src").join("net");
    std::fs::create_dir_all(&net).expect("mk temp tree");
    std::fs::write(net.join("bad.rs"), "pub fn f(x: u64) -> u32 {\n    x as u32\n}\n")
        .expect("write bad file");
    let out =
        gta_bin().args(["analyze", "--dir", dir.to_str().expect("utf8 temp path")]).output().expect("run");
    let stdout = String::from_utf8_lossy(&out.stdout);
    let stderr = String::from_utf8_lossy(&out.stderr);
    std::fs::remove_dir_all(&dir).ok();
    assert!(!out.status.success(), "a seeded R1 violation must fail analyze:\n{stdout}");
    assert!(stdout.contains("FAIL R1"), "{stdout}");
    assert!(stderr.contains("new finding"), "{stderr}");
}

#[test]
fn cli_analyze_json_report_satisfies_bench_check() {
    let out = gta_bin()
        .args(["analyze", "--dir", ".", "--format", "json"])
        .output()
        .expect("run gta analyze --format json");
    assert!(out.status.success());
    let report = std::env::temp_dir().join(format!("gta_analysis_{}.json", std::process::id()));
    std::fs::write(&report, &out.stdout).expect("write report");
    let check = gta_bin()
        .args(["bench-check", "--dir", ".", "--analysis", report.to_str().expect("utf8 temp path")])
        .output()
        .expect("run gta bench-check");
    let stdout = String::from_utf8_lossy(&check.stdout);
    let stderr = String::from_utf8_lossy(&check.stderr);
    std::fs::remove_file(&report).ok();
    assert!(check.status.success(), "bench-check must accept the report:\n{stdout}\n{stderr}");
    assert!(stdout.contains("analysis report"), "{stdout}");
}
