//! Shared integration-test scaffolding: the deterministic **gated**
//! execution backend — executions park inside the backend until the
//! test releases them, which is the reproducible way to hold a session
//! worker busy and fill a bounded admission queue (used by the
//! backpressure tests in `serve_stream.rs` and `net_loopback.rs`).

use gta::coordinator::{CoalesceConfig, ExecKind, Rack, Request, RoundRobin};
use gta::precision::Precision;
use gta::runtime::{ExecBackend, HostTensor};
use gta::{GtaConfig, TensorOp};
use std::sync::{mpsc, Arc, Mutex};
use std::time::Duration;

/// An `ExecBackend` whose executions block until released: signals
/// `started` on entry, then parks on `release`.
pub struct GatedBackend {
    pub started: mpsc::Sender<()>,
    pub release: Mutex<mpsc::Receiver<()>>,
}

impl ExecBackend for GatedBackend {
    fn execute(&self, _name: &str, inputs: &[HostTensor]) -> anyhow::Result<Vec<HostTensor>> {
        self.started.send(()).ok();
        self.release.lock().unwrap().recv().ok();
        Ok(inputs.to_vec())
    }

    fn names(&self) -> Vec<String> {
        vec!["gate".to_string()]
    }
}

/// A one-shard 16-lane rack over a [`GatedBackend`] (zero coalescing
/// window so the gated execution starts immediately), plus its control
/// channels: recv on the first to learn a worker reached the backend,
/// send on the second to release one parked execution.
pub fn gated_rack() -> (Arc<Rack>, mpsc::Receiver<()>, mpsc::Sender<()>) {
    let (started_tx, started_rx) = mpsc::channel::<()>();
    let (release_tx, release_rx) = mpsc::channel::<()>();
    // Sender/Receiver are !Sync; the Sync factory hands them to the one
    // backend through take-once slots
    let started_slot = Mutex::new(Some(started_tx));
    let release_slot = Mutex::new(Some(release_rx));
    let rack = Arc::new(
        Rack::with_backend(
            vec![GtaConfig::lanes16()],
            move |_shard| {
                Ok(Box::new(GatedBackend {
                    started: started_slot.lock().unwrap().take().expect("one shard, one backend"),
                    release: Mutex::new(
                        release_slot.lock().unwrap().take().expect("one shard, one backend"),
                    ),
                }) as Box<dyn ExecBackend>)
            },
            CoalesceConfig { window: Duration::ZERO, ..Default::default() },
            Box::new(RoundRobin::default()),
        )
        .unwrap(),
    );
    (rack, started_rx, release_tx)
}

/// A functional request against the gated backend's `"gate"` artifact.
pub fn gated_request(id: u64) -> Request {
    Request {
        id,
        op: TensorOp::gemm(64, 64, 64, Precision::Int8),
        exec: ExecKind::Functional {
            artifact: "gate".to_string(),
            inputs: vec![HostTensor::I32(vec![id as i32; 4])],
        },
    }
}
