//! Integration: the coordinator serving mixed simulate + functional
//! request streams end-to-end (scheduling, simulation, PJRT execution,
//! lane allocation, metrics).

use gta::coordinator::{lane_scheduler::LaneAllocator, Coordinator, ExecKind, Request};
use gta::precision::{limbs, Precision};
use gta::runtime::{default_artifact_dir, HostTensor};
use gta::{Dataflow, GtaConfig, TensorOp};
use std::sync::Arc;

fn artifacts_ready() -> bool {
    default_artifact_dir().join("manifest.json").exists()
}

#[test]
fn functional_gemm_through_coordinator() {
    if !artifacts_ready() {
        eprintln!("skipping: artifacts not built");
        return;
    }
    let coord =
        Coordinator::with_engine(GtaConfig::lanes16(), default_artifact_dir()).unwrap();
    let dim = 64usize;
    let a: Vec<i64> = (0..dim * dim).map(|i| (i as i64 % 200) - 100).collect();
    let b: Vec<i64> = (0..dim * dim).map(|i| ((i as i64 * 7) % 200) - 100).collect();
    let resp = coord.handle(Request {
        id: 1,
        op: TensorOp::gemm(64, 64, 64, Precision::Int8),
        exec: ExecKind::Functional {
            artifact: "mpra_gemm_i8_64".into(),
            inputs: vec![
                HostTensor::I32(a.iter().map(|&v| v as i32).collect()),
                HostTensor::I32(b.iter().map(|&v| v as i32).collect()),
            ],
        },
    });
    // the schedule must exist and the numerics must match the limb oracle
    assert!(resp.schedule.is_some());
    let want = limbs::limb_gemm(&a, &b, dim, dim, dim, 1, 32);
    let got = resp.outputs.unwrap()[0].as_i32().unwrap().to_vec();
    assert_eq!(got.len(), want.len());
    for (g, w) in got.iter().zip(&want) {
        assert_eq!(*g as i64, *w);
    }
}

#[test]
fn mixed_stream_serves_and_verifies() {
    if !artifacts_ready() {
        return;
    }
    let summary = gta::serve::run_mixed_stream(default_artifact_dir(), 24, 4).unwrap();
    assert_eq!(summary.requests, 24);
    assert_eq!(summary.functional, 12);
    assert_eq!(summary.verified_failed, 0, "numeric mismatches in serve path");
    assert_eq!(summary.verified_ok, 12);
    assert!(summary.throughput_rps > 1.0);
    assert!(summary.metrics.requests == 24);
}

#[test]
fn multi_tenant_lane_partitions_run_concurrently() {
    // two operators sharing the 16-lane pool via mask-match partitions
    let mut alloc = LaneAllocator::new(GtaConfig::lanes16());
    let p1 = alloc.allocate(8).expect("first tenant");
    let p2 = alloc.allocate(8).expect("second tenant");
    let csr1 = alloc.syscsr_for(p1.id, Dataflow::WS).unwrap();
    let csr2 = alloc.syscsr_for(p2.id, Dataflow::OS).unwrap();
    // the two partitions must have disjoint lanes and distinct masks
    for l in &p1.lanes {
        assert!(!p2.lanes.contains(l));
    }
    assert_ne!(p1.mask, p2.mask);
    // mask groups agree between the two CSR programs (global state)
    assert_eq!(csr1.mask_groups, csr2.mask_groups);
    // releasing one tenant lets a wider arrangement in
    alloc.release(p1.id);
    assert!(alloc.allocate(8).is_some());
}

#[test]
fn simulate_only_stream_scales_with_workers() {
    let coord = Arc::new(Coordinator::new(GtaConfig::default()));
    let reqs: Vec<Request> = (0..64)
        .map(|i| Request {
            id: i,
            op: TensorOp::gemm(64 + (i % 8), 64, 256, Precision::Bp16),
            exec: ExecKind::Simulate,
        })
        .collect();
    let resps = coord.serve(reqs, 8);
    assert_eq!(resps.len(), 64);
    assert!(resps.iter().all(|r| r.sim.cycles > 0));
    // 8 distinct shapes -> at least 8 cache misses, the rest hits
    let snap = coord.metrics.snapshot();
    assert_eq!(snap.schedule_cache_misses, 8);
    assert_eq!(snap.schedule_cache_hits, 56);
}
