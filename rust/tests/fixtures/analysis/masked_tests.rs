pub fn live() -> u8 {
    1
}

#[cfg(test)]
mod tests {
    #[test]
    fn exercises_live() {
        assert_eq!(super::live(), 1);
        let v: Option<u8> = Some(1);
        v.unwrap();
    }
}
