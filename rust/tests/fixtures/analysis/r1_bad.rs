pub fn shrink(len: u64) -> u32 {
    len as u32
}
