pub fn first_frame(frames: &[u8]) -> u8 {
    let head = frames.first().copied().unwrap();
    head + frames[0]
}
