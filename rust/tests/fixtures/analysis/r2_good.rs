pub fn first_frame(frames: &[u8]) -> Result<u8, String> {
    frames.first().copied().ok_or_else(|| "empty frame list".to_string())
}
