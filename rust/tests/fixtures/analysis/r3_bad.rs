pub fn bump(counter: &std::sync::Mutex<u64>) {
    *counter.lock().unwrap() += 1;
}
