pub fn bump(counter: &std::sync::Mutex<u64>) {
    *counter.lock().unwrap_or_else(|e| e.into_inner()) += 1;
}
