use std::sync::atomic::{AtomicU64, Ordering};

pub fn bump(c: &AtomicU64) {
    // lint: relaxed-ok monotonic stat counter; nothing orders against it
    c.fetch_add(1, Ordering::Relaxed);
}
