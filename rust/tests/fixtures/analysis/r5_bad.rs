pub fn not_done() {
    todo!("finish the slide unit model")
}
