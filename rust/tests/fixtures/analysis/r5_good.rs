pub fn not_done() -> Result<(), String> {
    Err("slide unit model not implemented yet".to_string())
}
