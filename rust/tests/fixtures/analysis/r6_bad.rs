pub struct Frame;

pub fn decode_frame(_bytes: &[u8]) -> Frame {
    Frame
}
