pub struct Frame;

pub fn decode_frame(bytes: &[u8]) -> Result<Frame, String> {
    if bytes.is_empty() {
        return Err("empty frame".to_string());
    }
    Ok(Frame)
}
