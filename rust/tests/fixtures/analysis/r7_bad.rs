pub fn body_buffer(wire_len: usize) -> Vec<u8> {
    Vec::with_capacity(wire_len)
}
