pub fn body_buffer(wire_len: usize) -> Option<Vec<u8>> {
    if wire_len > 16 << 20 {
        return None;
    }
    // lint: allow(R7) capped at MAX_BODY_BYTES just above
    Some(Vec::with_capacity(wire_len))
}
