fn main() {
    let report = "{}";
    std::fs::write("BENCH_fixture.json", report).ok();
}
