fn main() {
    let report = "{\"schema\":\"gta.bench.fixture/1\"}";
    std::fs::write("BENCH_fixture.json", report).ok();
}
