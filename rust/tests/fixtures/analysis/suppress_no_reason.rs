pub fn lane_word(lanes: u64) -> u32 {
    // lint: allow(R1)
    lanes as u32
}
