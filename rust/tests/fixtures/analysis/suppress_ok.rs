pub fn lane_word(lanes: u64) -> u32 {
    // lint: allow(R1) lanes is bounded by config validation at construction
    lanes as u32
}
