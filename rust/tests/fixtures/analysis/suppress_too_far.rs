pub fn lane_word(lanes: u64) -> u32 {
    // lint: allow(R1) covers only the next line, not two below
    let _pad = 0;
    lanes as u32
}
