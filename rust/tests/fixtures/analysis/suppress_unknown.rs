// lint: allowedlist nonsense
pub fn nothing() {}
